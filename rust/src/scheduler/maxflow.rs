//! Preflow-push (push–relabel) maximum flow (§3.3 of the paper, after
//! Cheriyan & Maheshwari 1989), with FIFO active-node selection and the gap
//! heuristic, over real-valued capacities.
//!
//! Besides the flow value, callers need the *flow assignment* per edge
//! (the paper uses these to set KV-communication frequencies, §3.3) and the
//! bottleneck / underutilized edge classification that drives the
//! max-flow-guided edge swap (§3.4) — both exposed here.

/// Opaque handle to an added edge (for querying flow afterwards).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeRef {
    node: usize,
    idx: usize,
}

#[derive(Clone, Debug)]
struct Edge {
    to: usize,
    cap: f64,
    flow: f64,
    /// index of the reverse edge in adj[to]
    rev: usize,
}

/// A directed flow network with float capacities.
#[derive(Clone, Debug)]
pub struct FlowNetwork {
    adj: Vec<Vec<Edge>>,
}

const EPS: f64 = 1e-9;

impl FlowNetwork {
    pub fn new(n: usize) -> FlowNetwork {
        FlowNetwork { adj: vec![Vec::new(); n] }
    }

    pub fn n(&self) -> usize {
        self.adj.len()
    }

    pub fn add_node(&mut self) -> usize {
        self.adj.push(Vec::new());
        self.adj.len() - 1
    }

    /// Add a directed edge u -> v with the given capacity.
    pub fn add_edge(&mut self, u: usize, v: usize, cap: f64) -> EdgeRef {
        assert!(u != v, "self-loop");
        assert!(cap >= 0.0, "negative capacity");
        let ui = self.adj[u].len();
        let vi = self.adj[v].len();
        self.adj[u].push(Edge { to: v, cap, flow: 0.0, rev: vi });
        self.adj[v].push(Edge { to: u, cap: 0.0, flow: 0.0, rev: ui });
        EdgeRef { node: u, idx: ui }
    }

    pub fn capacity(&self, e: EdgeRef) -> f64 {
        self.adj[e.node][e.idx].cap
    }

    /// Update an existing edge's capacity in place. The carried flow is left
    /// untouched (possibly over the new capacity); call
    /// [`FlowNetwork::max_flow_incremental`] afterwards to repair and
    /// re-maximize from the warm residual state instead of re-solving from
    /// scratch — the §3.4 edge-swap / type-flip proposals change only a
    /// handful of capacities per step.
    pub fn set_capacity(&mut self, e: EdgeRef, cap: f64) {
        assert!(cap >= 0.0, "negative capacity");
        self.adj[e.node][e.idx].cap = cap;
    }

    /// Flow currently routed through the edge (after `max_flow`).
    pub fn flow(&self, e: EdgeRef) -> f64 {
        self.adj[e.node][e.idx].flow.max(0.0)
    }

    /// Utilization in [0,1]; 0 for zero-capacity edges.
    pub fn utilization(&self, e: EdgeRef) -> f64 {
        let c = self.capacity(e);
        if c <= 0.0 {
            0.0
        } else {
            (self.flow(e) / c).clamp(0.0, 1.0)
        }
    }

    /// Is this edge saturated (a bottleneck in §3.4's sense)?
    pub fn is_bottleneck(&self, e: EdgeRef) -> bool {
        let ed = &self.adj[e.node][e.idx];
        ed.cap > 0.0 && ed.flow >= ed.cap - EPS * (1.0 + ed.cap)
    }

    /// Zero every edge's flow, returning the network to its freshly-built
    /// state (capacities kept). A subsequent [`max_flow_incremental`]
    /// performs exactly the cold Edmonds–Karp pass a brand-new network
    /// would — which is what lets callers recycle a network's allocation
    /// across independent solves without changing any result.
    ///
    /// [`max_flow_incremental`]: FlowNetwork::max_flow_incremental
    pub(super) fn reset_flows(&mut self) {
        for v in &mut self.adj {
            for e in v {
                e.flow = 0.0;
            }
        }
    }

    /// Push–relabel max flow from s to t. Returns the flow value; per-edge
    /// assignments are queryable afterwards via `flow`.
    pub fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        let n = self.n();
        assert!(s != t && s < n && t < n);
        self.reset_flows();
        let mut height = vec![0usize; n];
        let mut excess = vec![0.0f64; n];
        height[s] = n;

        // Saturate all source edges.
        for i in 0..self.adj[s].len() {
            let (to, cap) = {
                let e = &self.adj[s][i];
                (e.to, e.cap)
            };
            if cap > 0.0 {
                self.push_raw(s, i, cap);
                excess[to] += cap;
                excess[s] -= cap;
            }
        }

        let mut queue: std::collections::VecDeque<usize> = (0..n)
            .filter(|&v| v != s && v != t && excess[v] > EPS)
            .collect();
        let mut in_queue = vec![false; n];
        for &v in &queue {
            in_queue[v] = true;
        }
        // Gap heuristic bookkeeping.
        let mut height_count = vec![0usize; 2 * n + 1];
        for &h in &height {
            height_count[h] += 1;
        }

        while let Some(u) = queue.pop_front() {
            in_queue[u] = false;
            // Discharge u.
            while excess[u] > EPS {
                let mut pushed = false;
                for i in 0..self.adj[u].len() {
                    let (to, residual) = {
                        let e = &self.adj[u][i];
                        (e.to, e.cap - e.flow)
                    };
                    if residual > EPS && height[u] == height[to] + 1 {
                        let delta = excess[u].min(residual);
                        self.push_raw(u, i, delta);
                        excess[u] -= delta;
                        excess[to] += delta;
                        if to != s && to != t && !in_queue[to] {
                            queue.push_back(to);
                            in_queue[to] = true;
                        }
                        pushed = true;
                        if excess[u] <= EPS {
                            break;
                        }
                    }
                }
                if !pushed {
                    // Relabel u to 1 + min reachable height.
                    let old = height[u];
                    let mut min_h = usize::MAX;
                    for e in &self.adj[u] {
                        if e.cap - e.flow > EPS {
                            min_h = min_h.min(height[e.to]);
                        }
                    }
                    if min_h == usize::MAX {
                        break; // no residual edges; excess is stuck (shouldn't happen)
                    }
                    height_count[old] -= 1;
                    height[u] = min_h + 1;
                    height_count[height[u]] += 1;
                    // Gap heuristic: if no node remains at `old`, lift all
                    // nodes above the gap out of reach.
                    if height_count[old] == 0 && old < n {
                        for v in 0..n {
                            if v != s && height[v] > old && height[v] <= n {
                                height_count[height[v]] -= 1;
                                height[v] = n + 1;
                                height_count[height[v]] += 1;
                            }
                        }
                    }
                    if height[u] > 2 * n {
                        break;
                    }
                }
            }
        }
        // Max flow = total into t.
        self.adj[t]
            .iter()
            .map(|e| -e.flow) // reverse edges carry negative of inflow
            .filter(|f| *f > 0.0)
            .sum()
    }

    fn push_raw(&mut self, u: usize, i: usize, delta: f64) {
        let (to, rev) = {
            let e = &mut self.adj[u][i];
            e.flow += delta;
            (e.to, e.rev)
        };
        self.adj[to][rev].flow -= delta;
    }

    /// Slow Edmonds–Karp reference implementation (tests only): BFS
    /// augmenting paths. Used by the property tests to cross-check
    /// push–relabel on random graphs.
    pub fn max_flow_reference(&mut self, s: usize, t: usize) -> f64 {
        self.reset_flows();
        let mut total = 0.0;
        while let Some(delta) = self.augment_path(s, t, f64::INFINITY, None) {
            total += delta;
        }
        total
    }

    /// BFS one shortest augmenting path from `s2` to `t2` in the residual
    /// graph and push `min(limit, bottleneck)` along it. Returns the pushed
    /// amount, or `None` when `t2` is unreachable. Nodes in `block` are
    /// never expanded *through* (they may still terminate the path): the
    /// incremental repair uses this to keep reroutes from threading flow
    /// through the source or sink, which would break the "no flow out of t
    /// / value = reverse-edge inflow at t" invariant.
    fn augment_path(
        &mut self,
        s2: usize,
        t2: usize,
        limit: f64,
        block: Option<(usize, usize)>,
    ) -> Option<f64> {
        let n = self.n();
        let blocked = |v: usize| match block {
            Some((a, b)) => v != s2 && (v == a || v == b),
            None => false,
        };
        let mut prev: Vec<Option<(usize, usize)>> = vec![None; n];
        let mut q = std::collections::VecDeque::new();
        q.push_back(s2);
        let mut seen = vec![false; n];
        seen[s2] = true;
        while let Some(u) = q.pop_front() {
            if blocked(u) {
                continue;
            }
            for (i, e) in self.adj[u].iter().enumerate() {
                if !seen[e.to] && e.cap - e.flow > EPS {
                    seen[e.to] = true;
                    prev[e.to] = Some((u, i));
                    q.push_back(e.to);
                }
            }
        }
        if !seen[t2] {
            return None;
        }
        let mut delta = limit;
        let mut v = t2;
        while let Some((u, i)) = prev[v] {
            let e = &self.adj[u][i];
            delta = delta.min(e.cap - e.flow);
            v = u;
        }
        let mut v = t2;
        while let Some((u, i)) = prev[v] {
            self.push_raw(u, i, delta);
            v = u;
        }
        Some(delta)
    }

    /// Max flow warm-started from the current flow assignment (typically
    /// after [`FlowNetwork::set_capacity`] updates). Where a capacity
    /// dropped below the carried flow the overage is first rerouted through
    /// the residual graph; what cannot be rerouted is cancelled along the
    /// upstream (s→u) and downstream (v→t) flow decomposition. BFS
    /// augmenting paths then restore maximality — from a zero flow state
    /// this is plain Edmonds–Karp. The returned flow *value* always matches
    /// [`FlowNetwork::max_flow`] (the max-flow value is unique); the
    /// per-edge assignment may legitimately differ (max flows are not).
    pub fn max_flow_incremental(&mut self, s: usize, t: usize) -> f64 {
        let n = self.n();
        assert!(s != t && s < n && t < n);
        if !self.repair(s, t) {
            // Defensive: a repair that cannot restore conservation falls
            // back to a cold solve (guarded by the randomized parity tests;
            // not observed in practice).
            self.reset_flows();
        }
        while self.augment_path(s, t, f64::INFINITY, None).is_some() {}
        self.adj[t]
            .iter()
            .map(|e| -e.flow)
            .filter(|f| *f > 0.0)
            .sum()
    }

    /// Restore capacity-feasibility after `set_capacity` decreases. Returns
    /// false if a flow decomposition unexpectedly runs dry (caller resets).
    fn repair(&mut self, s: usize, t: usize) -> bool {
        loop {
            // Find an overflowing edge. Only real edges can overflow:
            // reverse edges carry flow <= 0 <= cap.
            let mut found = None;
            'outer: for u in 0..self.n() {
                for i in 0..self.adj[u].len() {
                    let e = &self.adj[u][i];
                    if e.flow > e.cap + EPS {
                        found = Some((u, i));
                        break 'outer;
                    }
                }
            }
            let Some((u, i)) = found else { return true };
            let (v, mut over) = {
                let e = &self.adj[u][i];
                (e.to, e.flow - e.cap)
            };
            // Clamp to the new capacity; u is now left with excess inflow
            // `over` and v with the matching deficit.
            self.push_raw(u, i, -over);
            // (1) Reroute u -> v through the residual graph where possible
            // (the clamped edge itself has zero residual, so it is skipped;
            // s and t are blocked as intermediates so the reroute cannot
            // thread flow through the terminals).
            while over > EPS {
                match self.augment_path(u, v, over, Some((s, t))) {
                    Some(delta) => over -= delta,
                    None => break,
                }
            }
            // (2) The irreparable remainder shrinks the s->t value: cancel
            // the same amount of carried flow downstream (v..t) and
            // upstream (s..u).
            if over > EPS {
                if v != t && !self.cancel_flow(v, t, over) {
                    return false;
                }
                if u != s && !self.cancel_flow(s, u, over) {
                    return false;
                }
            }
        }
    }

    /// Cancel `need` units of carried flow along `from`→`to` paths of
    /// positive-flow edges. Flow cycles encountered on the way (push–relabel
    /// and earlier repairs can leave them) are cancelled outright — they
    /// carry no s→t value. Returns false if the decomposition runs dry
    /// before `need` is cancelled.
    fn cancel_flow(&mut self, from: usize, to: usize, mut need: f64) -> bool {
        'search: while need > EPS {
            // DFS along real edges with positive flow; `on_path[w]` is w's
            // position in the node path (usize::MAX = not on it).
            let mut path: Vec<(usize, usize)> = Vec::new(); // (node, edge idx)
            let mut on_path = vec![usize::MAX; self.n()];
            let mut next_idx = vec![0usize; self.n()];
            let mut cur = from;
            on_path[from] = 0;
            loop {
                if cur == to {
                    let mut delta = need;
                    for &(u, i) in &path {
                        delta = delta.min(self.adj[u][i].flow);
                    }
                    for &(u, i) in &path {
                        self.push_raw(u, i, -delta);
                    }
                    need -= delta;
                    continue 'search;
                }
                let mut advanced = false;
                while next_idx[cur] < self.adj[cur].len() {
                    let i = next_idx[cur];
                    next_idx[cur] += 1;
                    let e = &self.adj[cur][i];
                    if e.flow > EPS {
                        let w = e.to;
                        if on_path[w] != usize::MAX {
                            // Flow cycle w .. cur -> w: cancel its minimum.
                            let start = on_path[w];
                            let mut delta = self.adj[cur][i].flow;
                            for &(u2, i2) in &path[start..] {
                                delta = delta.min(self.adj[u2][i2].flow);
                            }
                            self.push_raw(cur, i, -delta);
                            for &(u2, i2) in &path[start..] {
                                self.push_raw(u2, i2, -delta);
                            }
                            continue 'search;
                        }
                        path.push((cur, i));
                        on_path[w] = path.len();
                        cur = w;
                        advanced = true;
                        break;
                    }
                }
                if !advanced {
                    if cur == from {
                        return false; // decomposition ran dry
                    }
                    on_path[cur] = usize::MAX;
                    let (pu, _pi) = path.pop().expect("non-root node has a path entry");
                    cur = pu;
                }
            }
        }
        true
    }

    /// Check flow conservation at every node except s and t (tests).
    pub fn check_conservation(&self, s: usize, t: usize) -> Result<(), String> {
        for v in 0..self.n() {
            if v == s || v == t {
                continue;
            }
            let net: f64 = self.adj[v].iter().map(|e| e.flow).sum();
            if net.abs() > 1e-6 {
                return Err(format!("node {v} violates conservation: net {net}"));
            }
        }
        for v in 0..self.n() {
            for e in &self.adj[v] {
                if e.flow > e.cap + 1e-6 {
                    return Err(format!("edge {v}->{} over capacity", e.to));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn trivial_path() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 5.0);
        g.add_edge(1, 2, 3.0);
        assert!((g.max_flow(0, 2) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn classic_diamond() {
        // Two disjoint paths 0->1->3 (cap 2) and 0->2->3 (cap 3), plus a
        // cross edge 1->2 enabling rerouting.
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 4.0);
        g.add_edge(0, 2, 3.0);
        let e12 = g.add_edge(1, 2, 2.0);
        g.add_edge(1, 3, 2.0);
        g.add_edge(2, 3, 5.0);
        let f = g.max_flow(0, 3);
        assert!((f - 7.0).abs() < 1e-9, "{f}");
        g.check_conservation(0, 3).unwrap();
        assert!(g.flow(e12) <= 2.0 + 1e-9);
    }

    #[test]
    fn disconnected_is_zero() {
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 5.0);
        g.add_edge(2, 3, 5.0);
        assert_eq!(g.max_flow(0, 3), 0.0);
    }

    #[test]
    fn bottleneck_detection() {
        let mut g = FlowNetwork::new(3);
        let a = g.add_edge(0, 1, 1.0);
        let b = g.add_edge(1, 2, 10.0);
        g.max_flow(0, 2);
        assert!(g.is_bottleneck(a));
        assert!(!g.is_bottleneck(b));
        assert!(g.utilization(b) < 0.2);
        assert!((g.utilization(a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fractional_capacities() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 0.3);
        g.add_edge(0, 1, 0.45); // parallel edge
        g.add_edge(1, 2, 0.5);
        let f = g.max_flow(0, 2);
        assert!((f - 0.5).abs() < 1e-9, "{f}");
    }

    #[test]
    fn matches_reference_on_random_graphs() {
        check(0xF10, 150, |rng| {
            let n = rng.range(4, 12);
            let mut g = FlowNetwork::new(n);
            let m = rng.range(n, 4 * n);
            for _ in 0..m {
                let u = rng.range(0, n);
                let mut v = rng.range(0, n);
                if u == v {
                    v = (v + 1) % n;
                }
                g.add_edge(u, v, rng.range_f64(0.0, 10.0));
            }
            let mut g2 = g.clone();
            let f1 = g.max_flow(0, n - 1);
            let f2 = g2.max_flow_reference(0, n - 1);
            prop_assert!((f1 - f2).abs() < 1e-6, "push-relabel {f1} != reference {f2}");
            g.check_conservation(0, n - 1).map_err(|e| e)?;
            Ok(())
        });
    }

    #[test]
    fn incremental_from_zero_matches_reference() {
        check(0xF12, 100, |rng| {
            let n = rng.range(4, 12);
            let mut g = FlowNetwork::new(n);
            for _ in 0..rng.range(n, 4 * n) {
                let u = rng.range(0, n);
                let mut v = rng.range(0, n);
                if u == v {
                    v = (v + 1) % n;
                }
                g.add_edge(u, v, rng.range_f64(0.0, 10.0));
            }
            let mut g2 = g.clone();
            let f1 = g.max_flow_incremental(0, n - 1);
            let f2 = g2.max_flow_reference(0, n - 1);
            prop_assert!(
                (f1 - f2).abs() < 1e-9 * (1.0 + f2.abs()),
                "incremental {f1} != reference {f2}"
            );
            g.check_conservation(0, n - 1).map_err(|e| e)?;
            Ok(())
        });
    }

    #[test]
    fn incremental_matches_reference_after_capacity_updates() {
        // The §3.4 usage pattern: solve, retune a handful of capacities
        // (including down to zero — disabling an edge), warm-start from the
        // residual state, and land on the same max-flow value as a cold
        // reference solve.
        check(0xF13, 80, |rng| {
            let n = rng.range(4, 10);
            let mut g = FlowNetwork::new(n);
            let mut edges = Vec::new();
            for _ in 0..rng.range(n, 4 * n) {
                let u = rng.range(0, n);
                let mut v = rng.range(0, n);
                if u == v {
                    v = (v + 1) % n;
                }
                edges.push(g.add_edge(u, v, rng.range_f64(0.0, 10.0)));
            }
            let _ = g.max_flow_incremental(0, n - 1);
            for _round in 0..4 {
                for _ in 0..rng.range(1, 4) {
                    let e = edges[rng.range(0, edges.len())];
                    // Bias toward hard cases: zeroing an edge that may
                    // carry flow forces the cancel path.
                    let cap = if rng.bool(0.3) { 0.0 } else { rng.range_f64(0.0, 10.0) };
                    g.set_capacity(e, cap);
                }
                let f = g.max_flow_incremental(0, n - 1);
                let mut r = g.clone();
                let fr = r.max_flow_reference(0, n - 1);
                prop_assert!(
                    (f - fr).abs() < 1e-9 * (1.0 + fr.abs()),
                    "incremental {f} != reference {fr} after updates"
                );
                g.check_conservation(0, n - 1).map_err(|e| e)?;
                // Feasibility: no edge above its (new) capacity.
                for &e in &edges {
                    prop_assert!(
                        g.flow(e) <= g.capacity(e) + 1e-9,
                        "edge over capacity after incremental solve"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn flow_value_equals_out_of_source() {
        check(0xF11, 60, |rng| {
            let n = rng.range(4, 10);
            let mut g = FlowNetwork::new(n);
            for _ in 0..rng.range(n, 3 * n) {
                let u = rng.range(0, n);
                let mut v = rng.range(0, n);
                if u == v {
                    v = (v + 1) % n;
                }
                g.add_edge(u, v, rng.range_f64(0.0, 5.0));
            }
            let f = g.max_flow(0, n - 1);
            let out_s: f64 = g.adj[0].iter().map(|e| e.flow.max(0.0)).sum::<f64>()
                - g.adj[0].iter().map(|e| (-e.flow).max(0.0)).sum::<f64>();
            prop_assert!((f - out_s).abs() < 1e-6, "value {f} vs source net {out_s}");
            Ok(())
        });
    }
}
