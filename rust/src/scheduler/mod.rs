//! The HexGen-2 scheduling algorithm (paper §3): graph partition (spectral +
//! Kernighan–Lin), coarsen + secondary partition for group types, per-group
//! parallel-strategy search, preflow-push max-flow for KV routing, and the
//! max-flow-guided edge-swap iterative refinement.
//!
//! Entry point: [`schedule`]. The genetic-algorithm and random-swap variants
//! used by the §5.3 convergence study live in [`genetic`] and are selected
//! via [`SwapMode`].

pub mod coarsen;
pub mod evalcache;
pub mod flownet;
pub mod genetic;
pub mod hierarchy;
pub mod kl;
pub mod maxflow;
pub mod objective;
pub mod placement;
pub mod spectral;
pub mod strategy;

pub use evalcache::{EvalCache, EvalCounters};
pub use objective::Objective;
pub use placement::{GroupPlan, KvRoute, Placement};

use std::time::Instant;

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::TaskProfile;
use crate::kvtransfer::LinkModel;
use crate::model::LlmSpec;
use crate::util::rng::Rng;
use crate::workload::WorkloadKind;
use strategy::StrategyCache;

/// Refinement mode (§5.3 ablation axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapMode {
    /// Max-flow-guided edge swap (the paper's contribution, §3.4).
    Guided,
    /// Truncated variant: random swaps (the paper's "w/o edge swap").
    Random,
    /// No iterative refinement: one-shot two-phase output.
    None,
}

#[derive(Clone, Debug)]
pub struct ScheduleOptions {
    pub workload: WorkloadKind,
    /// What candidate placements are ranked by ([`Objective::Throughput`] is
    /// the paper default and reproduces the pre-objective behaviour).
    pub objective: Objective,
    /// Scheduling period T in seconds (§3.3 uses e.g. 10 minutes).
    pub period: f64,
    /// Maximum refinement rounds.
    pub max_rounds: usize,
    /// Stop after this many rounds without improvement.
    pub patience: usize,
    pub seed: u64,
    pub swap_mode: SwapMode,
    /// How many type assignments to max-flow-evaluate per partition.
    pub type_candidates: usize,
    /// Proposals evaluated per refinement round.
    pub proposals_per_round: usize,
    /// Override the memory-derived group count (tests/case studies).
    pub force_k: Option<usize>,
    /// Warm-start seed: a group partition (typically the incumbent
    /// placement's) evaluated ahead of the spectral/uniform seeds in phase 1.
    /// The incumbent is guaranteed to be *in* the evaluated seed set, so a
    /// warm-started schedule never ends below the incumbent's objective
    /// under the same workload. Used by `rescheduler::warmstart`; also lets
    /// tests pin a starting partition.
    pub initial_groups: Option<Vec<Vec<DeviceId>>>,
    /// Worker threads for candidate evaluation (1 = sequential). Plans are
    /// bit-identical across thread counts: candidates are deduplicated and
    /// ordered before the fan-out, evaluation is pure, and the accept fold
    /// replays in proposal order.
    pub threads: usize,
    /// Memoize whole partition evaluations (see [`EvalCache`]). `false`
    /// re-executes every evaluation — same plans, useful only as the perf
    /// harness's uncached baseline.
    pub use_eval_cache: bool,
    /// Choose plans *under KV link contention*: every candidate's objective
    /// score is discounted by its predicted NIC overcommit under this link
    /// model ([`objective::kv_nic_utilization`] /
    /// [`objective::apply_kv_contention`] — the planner half of the
    /// planner↔engine loop, DESIGN.md §11). `None` (default) is the legacy
    /// contention-blind ranking; `Some(LinkModel::PerRoute)` is a no-op by
    /// max-flow feasibility and only `Some(LinkModel::SharedNic)` can
    /// change plans — and only on placements whose shared NICs would be
    /// overcommitted.
    pub kv_contention: Option<LinkModel>,
    /// Capture one [`AuditRecord`](crate::telemetry::AuditRecord) per
    /// candidate evaluation (partition signature, score breakdown,
    /// KV-contention discount, cache hit/miss) into
    /// [`ScheduleResult::audit`] — the planner half of the flight
    /// recorder's decision audit (`--audit`; DESIGN.md §12).
    pub audit: bool,
    /// Hierarchical zone planning ([`hierarchy`], DESIGN.md §14):
    /// `Some(z)` coarsens the cluster into `z` zones (`Some(0)` auto-sizes
    /// to ~32 devices per zone), plans each zone independently — zones fan
    /// out over [`ScheduleOptions::threads`] — and stitches the zone plans
    /// with a top-level max-flow over zone aggregates. `None` (default) is
    /// the flat §3 search. Plans stay bit-identical across thread counts,
    /// but hierarchical plans legitimately differ from flat ones: the point
    /// is a planner wall-clock that scales with zone size, not cluster
    /// size, at a bounded objective cost.
    pub hierarchical: Option<usize>,
    /// Cache-aware planning (DESIGN.md §15): discount expected prefill
    /// demand by this expected prefix-pool hit rate in [0, 1). Each
    /// candidate's prefill capacity is computed against a task whose input
    /// length is scaled by `1 - prefix_hit_rate` — a hit serves only the
    /// suffix — while KV-transfer volume, ingress, and memory keep the full
    /// prompt (the pool reserves full-length KV). The prefill analogue of
    /// [`ScheduleOptions::kv_contention`]; `0.0` (default) is the
    /// hit-blind legacy ranking. Set from
    /// [`DeploymentSpec::expected_prefix_hit_rate`](crate::deploy::DeploymentSpec::expected_prefix_hit_rate)
    /// under `--prefix-hit-aware`.
    pub prefix_hit_rate: f64,
}

impl ScheduleOptions {
    pub fn new(workload: WorkloadKind) -> ScheduleOptions {
        ScheduleOptions {
            workload,
            objective: Objective::Throughput,
            period: 600.0,
            max_rounds: 60,
            patience: 8,
            seed: 0,
            swap_mode: SwapMode::Guided,
            type_candidates: 6,
            proposals_per_round: 16,
            force_k: None,
            initial_groups: None,
            threads: 1,
            use_eval_cache: true,
            kv_contention: None,
            audit: false,
            hierarchical: None,
            prefix_hit_rate: 0.0,
        }
    }
}

/// Is `groups` a valid partition of the cluster's devices (every device in
/// exactly one non-empty group)?
pub fn is_valid_partition(cluster: &Cluster, groups: &[Vec<DeviceId>]) -> bool {
    if groups.is_empty() || groups.iter().any(|g| g.is_empty()) {
        return false;
    }
    let mut all: Vec<DeviceId> = groups.iter().flatten().copied().collect();
    all.sort_unstable();
    all == (0..cluster.n()).collect::<Vec<_>>()
}

/// One point of the convergence trace (paper Fig. 10 axes).
#[derive(Clone, Copy, Debug)]
pub struct ConvergencePoint {
    pub elapsed_s: f64,
    pub round: usize,
    pub tokens_per_s: f64,
    /// The incumbent's score under the run's chosen objective (equals the
    /// flow value for [`Objective::Throughput`]).
    pub score: f64,
}

/// Search-effort accounting of one scheduling run (perf-regression proxy:
/// counters are deterministic where wall-clock is not).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// `evaluate_partition` executions actually performed by this search.
    pub evals: usize,
    /// Evaluations served from the [`EvalCache`] memo instead.
    pub eval_cache_hits: usize,
    /// Per-group strategy-search executions / memo hits (inner layer).
    pub strategy_misses: usize,
    pub strategy_hits: usize,
    /// Unique partitions this search put through evaluation (its seen-set).
    pub partitions_explored: usize,
    /// Worker threads used for candidate evaluation.
    pub threads: usize,
}

impl SearchStats {
    /// Counter deltas between two [`EvalCounters`] snapshots of the same
    /// cache — the per-search stats both `schedule_with_cache` and
    /// `schedule_genetic_with_cache` report.
    pub fn delta(
        c0: &EvalCounters,
        c1: &EvalCounters,
        partitions_explored: usize,
        threads: usize,
    ) -> SearchStats {
        SearchStats {
            evals: c1.misses - c0.misses,
            eval_cache_hits: c1.hits - c0.hits,
            strategy_misses: c1.strategy_misses - c0.strategy_misses,
            strategy_hits: c1.strategy_hits - c0.strategy_hits,
            partitions_explored,
            threads: threads.max(1),
        }
    }

    /// Cache hit rate over the full-evaluation layer, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let total = self.evals + self.eval_cache_hits;
        if total == 0 {
            0.0
        } else {
            self.eval_cache_hits as f64 / total as f64
        }
    }
}

#[derive(Clone, Debug)]
pub struct ScheduleResult {
    pub placement: Placement,
    pub history: Vec<ConvergencePoint>,
    pub rounds: usize,
    pub elapsed_s: f64,
    /// Evaluation-effort counters for this run (deltas, not cache totals).
    pub stats: SearchStats,
    /// Per-candidate decision audit ([`ScheduleOptions::audit`]); empty
    /// when capture is off. Record order is thread-interleaved under
    /// parallel evaluation — read it, don't byte-diff it.
    pub audit: Vec<crate::telemetry::AuditRecord>,
}

/// Appendix A: memory needed by one model replica = parameters + 32
/// concurrent requests' KV caches.
pub fn replica_memory_requirement(model: &LlmSpec, task: &TaskProfile) -> f64 {
    let kv_per_req = model.kv_bytes_per_token(model.n_layers) * (task.s_in + task.s_out);
    model.param_bytes() + 32.0 * kv_per_req
}

/// §3.2: K = total cluster memory / single-replica memory estimate,
/// clamped to [2, n_devices].
pub fn choose_k(cluster: &Cluster, model: &LlmSpec, task: &TaskProfile) -> usize {
    let k = (cluster.total_memory() / replica_memory_requirement(model, task)).floor() as usize;
    k.clamp(2, cluster.n())
}

/// Task profile representing a workload class (mean lengths, batch 1).
pub fn task_for(workload: WorkloadKind) -> TaskProfile {
    let (s_in, s_out) = workload.mean_lengths();
    TaskProfile::new(1, s_in, s_out)
}

/// Evaluate a partition: secondary-partition candidates (coarsen) then
/// max-flow on each, returning the placement with the best score under
/// `objective` (each candidate's `objective_score` is filled in).
///
/// One [`flownet::PartitionFlowNet`] serves the whole candidate sweep: the
/// typed network is built once and each assignment only retunes capacity
/// deltas, warm-starting max-flow from the previous residual state. This is
/// a pure function of its arguments — [`EvalCache::evaluate`] memoizes it
/// across searches.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_partition(
    cluster: &Cluster,
    model: &LlmSpec,
    task: &TaskProfile,
    period: f64,
    groups: &[Vec<DeviceId>],
    n_type_candidates: usize,
    objective: Objective,
    cache: &StrategyCache,
) -> Option<Placement> {
    evaluate_partition_with(
        cluster,
        model,
        task,
        period,
        groups,
        n_type_candidates,
        objective,
        None,
        cache,
    )
}

/// [`evaluate_partition`] with the optional contention-aware objective
/// term: when `kv_contention` is set, every candidate's score is discounted
/// by its predicted NIC overcommit under that link model
/// ([`objective::kv_nic_utilization`]), so the inner type-assignment argmax
/// — not just the outer partition ranking — prefers placements whose KV
/// fan-out the fabric can actually carry.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_partition_with(
    cluster: &Cluster,
    model: &LlmSpec,
    task: &TaskProfile,
    period: f64,
    groups: &[Vec<DeviceId>],
    n_type_candidates: usize,
    objective: Objective,
    kv_contention: Option<LinkModel>,
    cache: &StrategyCache,
) -> Option<Placement> {
    evaluate_partition_pooled(
        cluster,
        model,
        task,
        period,
        groups,
        n_type_candidates,
        objective,
        kv_contention,
        cache,
        1,
        &mut flownet::FlowNetPool::new(),
        0.0,
    )
}

/// [`evaluate_partition_with`] with a worker budget for the per-group
/// strategy search and a recycled solver allocation
/// ([`flownet::FlowNetPool`]): the evaluator adopts the pool's skeleton and
/// hands it back when the sweep is done. Results are bit-identical for any
/// `threads` value or pool state — both knobs only cut wall-clock, which is
/// what lets [`EvalCache`] memoize this as a pure function of the partition.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_partition_pooled(
    cluster: &Cluster,
    model: &LlmSpec,
    task: &TaskProfile,
    period: f64,
    groups: &[Vec<DeviceId>],
    n_type_candidates: usize,
    objective: Objective,
    kv_contention: Option<LinkModel>,
    cache: &StrategyCache,
    threads: usize,
    pool: &mut flownet::FlowNetPool,
    prefix_hit_rate: f64,
) -> Option<Placement> {
    let mut net = flownet::PartitionFlowNet::new_in(
        cluster, model, task, period, groups, cache, threads, pool, prefix_hit_rate,
    );
    // Per-group phase capacities feed the secondary-partition scoring.
    let caps = net.phase_caps();
    let w = coarsen::inter_group_bandwidth(cluster, groups);
    // With few groups the full 2^K type space is cheap to max-flow-evaluate
    // (strategy search is cached); only large K relies on the ranked subset.
    let n_cand = if groups.len() <= 6 { 64 } else { n_type_candidates };
    let mut best: Option<Placement> = None;
    for assign in coarsen::type_candidates(&w, &caps, n_cand) {
        if let Some(mut p) = net.evaluate(&assign) {
            let mut score = objective.score(cluster, model, task, &p);
            if let Some(link) = kv_contention {
                score =
                    objective::apply_kv_contention(score, objective::kv_nic_utilization(&p, link));
            }
            p.objective_score = score;
            if best.as_ref().map(|b| p.objective_score > b.objective_score).unwrap_or(true) {
                best = Some(p);
            }
        }
    }
    net.recycle(pool);
    best
}

// ---------------------------------------------------------------------------
// Refinement proposals
// ---------------------------------------------------------------------------

type Groups = Vec<Vec<DeviceId>>;

fn swap_devices(groups: &Groups, ga: usize, ia: usize, gb: usize, ib: usize) -> Groups {
    let mut g = groups.clone();
    let (da, db) = (g[ga][ia], g[gb][ib]);
    g[ga][ia] = db;
    g[gb][ib] = da;
    g
}

fn move_device(groups: &Groups, from: usize, idx: usize, to: usize) -> Groups {
    let mut g = groups.clone();
    let d = g[from].remove(idx);
    g[to].push(d);
    g
}

/// Max-flow-guided proposals (§3.4): use the flow assignment to find
/// bottleneck and underutilized edges, then propose device moves/swaps that
/// (i) rebalance compute between over- and under-utilized groups and
/// (ii) raise the bandwidth of bottlenecked KV edges.
fn guided_proposals(
    cluster: &Cluster,
    groups: &Groups,
    placement: &Placement,
    rng: &mut Rng,
    max_out: usize,
) -> Vec<Groups> {
    let mut out: Vec<Groups> = Vec::new();
    let k = groups.len();
    let util = &placement.group_utilization;

    // (i) Compute rebalancing: saturated groups pull devices from slack ones.
    let mut hot: Vec<usize> = (0..k).filter(|&g| util[g] > 0.98).collect();
    let mut cold: Vec<usize> = (0..k).filter(|&g| util[g] < 0.6).collect();
    // Order hottest-first / coldest-first.
    hot.sort_by(|&a, &b| util[b].partial_cmp(&util[a]).unwrap());
    cold.sort_by(|&a, &b| util[a].partial_cmp(&util[b]).unwrap());
    for &h in hot.iter().take(3) {
        for &c in cold.iter().take(3) {
            if h == c || groups[c].len() <= 1 {
                continue;
            }
            // Move the cold group's device best-connected to the hot group.
            let (best_idx, _) = groups[c]
                .iter()
                .enumerate()
                .map(|(i, &d)| {
                    let bw: f64 = groups[h].iter().map(|&x| cluster.bandwidth[d][x]).sum();
                    (i, bw)
                })
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            out.push(move_device(groups, c, best_idx, h));
            // Also propose a swap: strongest cold device for weakest hot device.
            let (wi, _) = groups[h]
                .iter()
                .enumerate()
                .min_by(|(_, &a), (_, &b)| {
                    cluster.devices[a]
                        .gpu
                        .tflops()
                        .partial_cmp(&cluster.devices[b].gpu.tflops())
                        .unwrap()
                })
                .unwrap();
            let (si, _) = groups[c]
                .iter()
                .enumerate()
                .max_by(|(_, &a), (_, &b)| {
                    cluster.devices[a]
                        .gpu
                        .tflops()
                        .partial_cmp(&cluster.devices[b].gpu.tflops())
                        .unwrap()
                })
                .unwrap();
            out.push(swap_devices(groups, h, wi, c, si));
        }
    }

    // (ii) KV bottleneck repair: for saturated KV routes, swap a device of
    // the decode group with one (from any other group) that is better
    // connected to the prefill group.
    for r in &placement.routes {
        if r.capacity <= 0.0 || r.flow < r.capacity * 0.98 {
            continue;
        }
        let (pg, dg) = (r.prefill, r.decode);
        for other in 0..k {
            if other == pg || other == dg {
                continue;
            }
            // Candidate from `other` with the best bandwidth to the prefill group.
            let Some((oi, obw)) = groups[other]
                .iter()
                .enumerate()
                .map(|(i, &d)| {
                    (i, groups[pg].iter().map(|&x| cluster.bandwidth[d][x]).sum::<f64>())
                })
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            else {
                continue;
            };
            // Decode-group device with the worst bandwidth to the prefill group.
            let Some((di, dbw)) = groups[dg]
                .iter()
                .enumerate()
                .map(|(i, &d)| {
                    (i, groups[pg].iter().map(|&x| cluster.bandwidth[d][x]).sum::<f64>())
                })
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            else {
                continue;
            };
            if obw > dbw * 1.2 {
                out.push(swap_devices(groups, dg, di, other, oi));
            }
        }
    }

    // Keep at most half the budget for targeted repairs (randomly sampled
    // when more exist); fill the rest with random exploration (escaping the
    // local minima the paper's §5.3 ablation attributes to purely-random
    // refinement is the job of the guided half, but exploration must not
    // starve).
    rng.shuffle(&mut out);
    out.truncate(max_out / 2);
    while out.len() < max_out {
        out.push(random_mutation(groups, rng));
    }
    out
}

/// Canonical signature of a partition (ignores group/device order): the key
/// of both the per-search seen-set memo and the cross-search [`EvalCache`].
pub fn partition_signature(groups: &[Vec<DeviceId>]) -> Vec<usize> {
    let mut gs: Vec<Vec<usize>> = groups
        .iter()
        .map(|g| {
            let mut v = g.clone();
            v.sort_unstable();
            v
        })
        .collect();
    gs.sort();
    let mut sig = Vec::new();
    for g in gs {
        sig.extend(g);
        sig.push(usize::MAX);
    }
    sig
}

/// Random mutation (move or swap) — the truncated §5.3 variant's proposal.
fn random_mutation(groups: &Groups, rng: &mut Rng) -> Groups {
    let k = groups.len();
    loop {
        let ga = rng.range(0, k);
        let gb = rng.range(0, k);
        if ga == gb {
            continue;
        }
        if rng.bool(0.5) && groups[ga].len() > 1 {
            let ia = rng.range(0, groups[ga].len());
            return move_device(groups, ga, ia, gb);
        }
        let ia = rng.range(0, groups[ga].len());
        let ib = rng.range(0, groups[gb].len());
        return swap_devices(groups, ga, ia, gb, ib);
    }
}

// ---------------------------------------------------------------------------
// Main entry point
// ---------------------------------------------------------------------------

/// Evaluate a batch of candidate partitions through the cache, fanning out
/// over `threads` scoped workers when asked to. Results come back in input
/// order, so the caller's accept fold is independent of the thread count —
/// and evaluation is a pure function, so the plans are bit-identical to a
/// sequential run.
#[allow(clippy::too_many_arguments)]
fn evaluate_batch(
    cluster: &Cluster,
    model: &LlmSpec,
    task: &TaskProfile,
    period: f64,
    cands: &[Groups],
    n_type_candidates: usize,
    objective: Objective,
    kv_contention: Option<LinkModel>,
    cache: &EvalCache,
    threads: usize,
    prefix_hit_rate: f64,
) -> Vec<Option<Placement>> {
    // Leftover parallelism fans *into* each evaluation's per-group strategy
    // search when there are more workers than candidates (a single huge
    // partition — the hierarchical planner's zone batches, a lone seed —
    // would otherwise leave threads idle). Each worker also carries one
    // FlowNetPool across its chunk so consecutive proposals recycle the
    // solver allocation. Neither affects results (see evaluate_pooled).
    let inner = (threads / cands.len().max(1)).max(1);
    let eval = |g: &Groups, inner: usize, pool: &mut flownet::FlowNetPool| {
        cache.evaluate_pooled(
            cluster, model, task, period, g, n_type_candidates, objective, kv_contention, inner,
            pool, prefix_hit_rate,
        )
    };
    if threads <= 1 || cands.len() <= 1 {
        let mut pool = flownet::FlowNetPool::new();
        return cands.iter().map(|g| eval(g, inner, &mut pool)).collect();
    }
    // Contiguous chunks keep the join order deterministic; the chunk count
    // matches the worker count so every thread gets one spawn.
    let chunk = cands.len().div_ceil(threads.min(cands.len()));
    std::thread::scope(|s| {
        let handles: Vec<_> = cands
            .chunks(chunk)
            .map(|part| {
                let eval = &eval;
                s.spawn(move || {
                    let mut pool = flownet::FlowNetPool::new();
                    part.iter().map(|g| eval(g, inner, &mut pool)).collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("evaluation worker panicked"))
            .collect()
    })
}

/// Run the full HexGen-2 scheduling algorithm on a cluster with a private
/// evaluation cache (memoized within the run when
/// [`ScheduleOptions::use_eval_cache`] holds).
pub fn schedule(cluster: &Cluster, model: &LlmSpec, opts: &ScheduleOptions) -> Option<ScheduleResult> {
    let cache = if opts.use_eval_cache { EvalCache::new() } else { EvalCache::disabled() };
    schedule_with_cache(cluster, model, opts, &cache)
}

/// [`schedule`] against a caller-owned [`EvalCache`]: the §3.3 serving loop
/// shares one cache across periodic re-plans, warm starts, and GA runs so
/// repeated partitions are never re-evaluated. Sharing never changes plans
/// (memoized results are bit-identical to recomputation); it only changes
/// how many evaluations execute — reported in [`ScheduleResult::stats`].
pub fn schedule_with_cache(
    cluster: &Cluster,
    model: &LlmSpec,
    opts: &ScheduleOptions,
    cache: &EvalCache,
) -> Option<ScheduleResult> {
    if let Some(zones) = opts.hierarchical {
        return hierarchy::schedule_hierarchical(cluster, model, opts, cache, zones);
    }
    // hexcheck: allow(D2) -- wall-clock timing of the planner itself (ScheduleStats::elapsed); never feeds plan decisions
    let t0 = Instant::now();
    if opts.audit {
        // Sticky on a shared cache; per-run records are drained into
        // `ScheduleResult::audit` either way.
        cache.enable_audit();
    }
    let c0 = cache.counters();
    let task = task_for(opts.workload);
    let k = opts.force_k.unwrap_or_else(|| choose_k(cluster, model, &task));
    let mut rng = Rng::new(opts.seed);

    // Phase 1: initial partition (spectral + KL), plus uniform-split seeds —
    // the search space contains DistServe-style homogeneous layouts as
    // special cases, and seeding them guarantees we never start below them.
    let devs: Vec<DeviceId> = (0..cluster.n()).collect();
    let mut seeds: Vec<Groups> = Vec::new();
    // Warm start (rescheduling / pinned tests): the caller-provided partition
    // is evaluated first, so on ties it wins and the result can never fall
    // below the incumbent's objective under this workload.
    if let Some(g) = &opts.initial_groups {
        if is_valid_partition(cluster, g) {
            seeds.push(g.clone());
        }
    }
    {
        let mut spectral_seed = spectral::partition_k(cluster, &devs, k);
        kl::refine(cluster, &mut spectral_seed, 3.0);
        seeds.push(spectral_seed);
        // DistServe-style uniform layouts: every group size dividing n with
        // at least two groups. K is an *estimate* (Appendix A), so exploring
        // nearby group counts is legitimate — except when a caller pinned K.
        for gs in [1usize, 2, 4, 8] {
            if gs <= cluster.n() && cluster.n() % gs == 0 && cluster.n() / gs >= 2 {
                let k2 = cluster.n() / gs;
                if opts.force_k.is_some() && k2 != k {
                    continue;
                }
                seeds.push((0..k2).map(|g| (g * gs..(g + 1) * gs).collect()).collect());
            }
        }
    }

    // Per-search seen-set: unique partitions this run put through
    // evaluation. Seeds enter it too, so phase 3 never re-proposes a seed
    // (their phase-2 scores already lost to — or are — the incumbent, so
    // skipping them cannot change the outcome).
    let mut seen: std::collections::HashSet<Vec<usize>> = std::collections::HashSet::new();
    let stats_of = |seen: &std::collections::HashSet<Vec<usize>>, cache: &EvalCache| {
        SearchStats::delta(&c0, &cache.counters(), seen.len(), opts.threads)
    };

    // Phase 2 (+ type assignment): evaluate seeds, keep the best under the
    // chosen objective. The fold replays in seed order (earliest wins ties)
    // regardless of how the batch was fanned out.
    seeds.retain(|g| seen.insert(partition_signature(g)));
    let evals = evaluate_batch(
        cluster,
        model,
        &task,
        opts.period,
        &seeds,
        opts.type_candidates,
        opts.objective,
        opts.kv_contention,
        cache,
        opts.threads,
        opts.prefix_hit_rate,
    );
    let mut best_placement: Option<Placement> = None;
    let mut best_groups: Groups = Vec::new();
    for (groups, p) in seeds.into_iter().zip(evals) {
        if let Some(p) = p {
            if best_placement.as_ref().map(|b| p.objective_score > b.objective_score).unwrap_or(true)
            {
                best_placement = Some(p);
                best_groups = groups;
            }
        }
    }
    let mut best_placement = best_placement?;
    let mut history = vec![ConvergencePoint {
        elapsed_s: t0.elapsed().as_secs_f64(),
        round: 0,
        tokens_per_s: best_placement.tokens_per_s,
        score: best_placement.objective_score,
    }];

    if opts.swap_mode == SwapMode::None {
        let stats = stats_of(&seen, cache);
        return Some(ScheduleResult {
            placement: best_placement,
            history,
            rounds: 0,
            elapsed_s: t0.elapsed().as_secs_f64(),
            stats,
            audit: cache.take_audit(),
        });
    }

    // Phase 3: iterative refinement (§3.4). The seen-set memo keeps the
    // proposal budget pointed at *new* partitions; the cross-search
    // EvalCache additionally serves any partition some earlier run (seed,
    // re-plan, GA generation) already evaluated.
    let mut stall = 0usize;
    let mut rounds = 0usize;
    for round in 1..=opts.max_rounds {
        rounds = round;
        let proposals = match opts.swap_mode {
            SwapMode::Guided => guided_proposals(
                cluster,
                &best_groups,
                &best_placement,
                &mut rng,
                opts.proposals_per_round,
            ),
            SwapMode::Random => (0..opts.proposals_per_round)
                .map(|_| random_mutation(&best_groups, &mut rng))
                .collect(),
            SwapMode::None => unreachable!(),
        };
        // Dedup in proposal order, then evaluate the fresh ones as one
        // (possibly parallel) batch; the accept fold replays sequentially.
        let fresh: Vec<Groups> = proposals
            .into_iter()
            .filter(|cand| !cand.iter().any(|g| g.is_empty()))
            .filter(|cand| seen.insert(partition_signature(cand)))
            .collect();
        let evals = evaluate_batch(
            cluster,
            model,
            &task,
            opts.period,
            &fresh,
            opts.type_candidates,
            opts.objective,
            opts.kv_contention,
            cache,
            opts.threads,
            opts.prefix_hit_rate,
        );
        let mut improved = false;
        for (cand, p) in fresh.into_iter().zip(evals) {
            if let Some(p) = p {
                if opts.objective.improves(p.objective_score, best_placement.objective_score) {
                    best_placement = p;
                    best_groups = cand;
                    improved = true;
                }
            }
        }
        history.push(ConvergencePoint {
            elapsed_s: t0.elapsed().as_secs_f64(),
            round,
            tokens_per_s: best_placement.tokens_per_s,
            score: best_placement.objective_score,
        });
        if improved {
            stall = 0;
        } else {
            stall += 1;
            if stall >= opts.patience {
                break;
            }
        }
    }

    let stats = stats_of(&seen, cache);
    Some(ScheduleResult {
        placement: best_placement,
        history,
        rounds,
        elapsed_s: t0.elapsed().as_secs_f64(),
        stats,
        audit: cache.take_audit(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::{LLAMA2_70B, OPT_30B};

    #[test]
    fn choose_k_is_memory_driven() {
        let task = TaskProfile::new(1, 1020.0, 211.0);
        let het1 = settings::het1();
        let k70 = choose_k(&het1, &LLAMA2_70B, &task);
        let k30 = choose_k(&het1, &OPT_30B, &task);
        assert!(k30 > k70, "more replicas of the smaller model: {k30} vs {k70}");
        assert!((4..=8).contains(&k70), "llama70b K = {k70}");
        assert!((8..=14).contains(&k30), "opt30b K = {k30}");
    }

    #[test]
    fn schedule_case_study_cluster() {
        // Appendix E: 4xH100 + 4xA100, LPHD workload.
        let c = settings::case_study();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 10;
        opts.force_k = Some(4);
        let r = schedule(&c, &OPT_30B, &opts).expect("schedules");
        let p = &r.placement;
        assert!(p.tokens_per_s > 0.0);
        assert!(!p.prefill_indices().is_empty());
        assert!(!p.decode_indices().is_empty());
        // Every device used exactly once.
        let mut all: Vec<usize> = p.groups.iter().flat_map(|g| g.devices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..c.n()).collect::<Vec<_>>());
        // History is monotone non-decreasing.
        for w in r.history.windows(2) {
            assert!(w[1].tokens_per_s >= w[0].tokens_per_s - 1e-9);
        }
    }

    #[test]
    fn initial_groups_seed_never_undercut() {
        // Warm-start contract: the schedule's objective is >= the one-shot
        // evaluation of the provided seed partition (it is in the seed set).
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Lphd);
        let groups: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        let cache = strategy::StrategyCache::new();
        let seed_eval =
            evaluate_partition(&c, &OPT_30B, &task, 600.0, &groups, 64, Objective::Throughput, &cache)
                .expect("seed");
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 4;
        opts.force_k = Some(4);
        opts.initial_groups = Some(groups);
        let r = schedule(&c, &OPT_30B, &opts).expect("schedules");
        assert!(
            r.placement.flow_value >= seed_eval.flow_value - 1e-9,
            "warm start fell below its seed: {} < {}",
            r.placement.flow_value,
            seed_eval.flow_value
        );
    }

    #[test]
    fn invalid_initial_groups_ignored() {
        let c = settings::case_study();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 2;
        opts.force_k = Some(4);
        // Device 7 missing, device 0 duplicated: not a partition.
        opts.initial_groups = Some(vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 0]]);
        let r = schedule(&c, &OPT_30B, &opts).expect("falls back to spectral seeds");
        let mut all: Vec<usize> = r.placement.groups.iter().flat_map(|g| g.devices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..c.n()).collect::<Vec<_>>());
    }

    #[test]
    fn guided_beats_or_matches_oneshot() {
        let c = settings::het1();
        let mut base = ScheduleOptions::new(WorkloadKind::Hphd);
        base.max_rounds = 8;
        base.patience = 4;
        let mut oneshot = base.clone();
        oneshot.swap_mode = SwapMode::None;
        let g = schedule(&c, &OPT_30B, &base).unwrap();
        let o = schedule(&c, &OPT_30B, &oneshot).unwrap();
        assert!(
            g.placement.tokens_per_s >= o.placement.tokens_per_s - 1e-9,
            "guided {} < one-shot {}",
            g.placement.tokens_per_s,
            o.placement.tokens_per_s
        );
    }
}
