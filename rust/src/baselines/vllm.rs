//! vLLM-style baseline (Appendix F): colocated continuous batching on a
//! homogeneous cluster. Searches the best uniform (TP, replica count) split
//! by colocated-throughput estimate; serving behaviour (iteration-level
//! batching, optional chunked prefill per Appendix D) comes from
//! `simulator::colocated`.

use crate::cluster::Cluster;
use crate::costmodel::{ReplicaConfig, TaskProfile};
use crate::model::LlmSpec;
use crate::workload::WorkloadKind;

use super::hexgen::colocated_throughput;

/// A vLLM deployment: identical colocated replicas.
#[derive(Clone, Debug)]
pub struct VllmPlan {
    pub replicas: Vec<ReplicaConfig>,
    pub tensor_parallel: usize,
    pub tokens_per_s: f64,
}

/// Pick the best uniform TP degree (replicating the engine across the rest
/// of the cluster, data-parallel style).
pub fn schedule_vllm(cluster: &Cluster, model: &LlmSpec, workload: WorkloadKind) -> Option<VllmPlan> {
    let (s_in, s_out) = workload.mean_lengths();
    let task = TaskProfile::new(1, s_in, s_out);
    let n = cluster.n();
    let mut best: Option<VllmPlan> = None;
    for tp in [1usize, 2, 4, 8] {
        if tp > n || n % tp != 0 {
            continue;
        }
        let replicas: Vec<ReplicaConfig> = (0..n / tp)
            .map(|r| ReplicaConfig::new(vec![(r * tp..(r + 1) * tp).collect()], vec![model.n_layers]))
            .collect();
        let tput: f64 = replicas
            .iter()
            .map(|cfg| colocated_throughput(cluster, model, cfg, &task))
            .sum();
        if tput > 0.0 && best.as_ref().map(|b| tput > b.tokens_per_s).unwrap_or(true) {
            best = Some(VllmPlan { replicas, tensor_parallel: tp, tokens_per_s: tput });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::{LLAMA2_70B, OPT_30B};
    use crate::simulator::run_colocated;
    use crate::workload::Trace;

    #[test]
    fn picks_feasible_tp() {
        let c = settings::homogeneous();
        let plan = schedule_vllm(&c, &LLAMA2_70B, WorkloadKind::Hphd).expect("plan");
        // 70B needs TP >= 4 on 80G GPUs.
        assert!(plan.tensor_parallel >= 4, "tp {}", plan.tensor_parallel);
        assert!(plan.tokens_per_s > 0.0);
    }

    #[test]
    fn smaller_model_allows_more_replicas() {
        let c = settings::homogeneous();
        let p70 = schedule_vllm(&c, &LLAMA2_70B, WorkloadKind::Lpld).unwrap();
        let p30 = schedule_vllm(&c, &OPT_30B, WorkloadKind::Lpld).unwrap();
        assert!(p30.replicas.len() >= p70.replicas.len());
    }

    #[test]
    fn plan_simulates() {
        let c = settings::homogeneous();
        let plan = schedule_vllm(&c, &OPT_30B, WorkloadKind::Lphd).unwrap();
        let trace = Trace::offline(WorkloadKind::Lphd, 40, 1);
        let rep = run_colocated(&c, &OPT_30B, &plan.replicas, &trace, None);
        assert_eq!(rep.records.len(), 40);
    }
}
