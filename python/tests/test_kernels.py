"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The hypothesis sweeps cover shapes, block sizes, and ragged lengths —
the CORE correctness signal for the compiled artifacts (DESIGN.md §8).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    flash_prefill,
    paged_decode,
    decode_attention_ref,
    prefill_attention_ref,
)

RNG = np.random.default_rng(0)


def rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


class TestFlashPrefill:
    def test_matches_ref_full_lengths(self):
        q, k, v = rand(4, 128, 32), rand(4, 128, 32), rand(4, 128, 32)
        lengths = jnp.full((4,), 128, jnp.int32)
        out = flash_prefill(q, k, v, lengths)
        ref = prefill_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_ragged_lengths(self):
        q, k, v = rand(5, 64, 16), rand(5, 64, 16), rand(5, 64, 16)
        lengths = jnp.asarray([1, 2, 33, 64, 17], jnp.int32)
        out = flash_prefill(q, k, v, lengths, block_q=32, block_k=32)
        ref = prefill_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_rows_past_length_are_zero(self):
        q, k, v = rand(2, 64, 16), rand(2, 64, 16), rand(2, 64, 16)
        lengths = jnp.asarray([10, 64], jnp.int32)
        out = np.asarray(flash_prefill(q, k, v, lengths))
        assert np.all(out[0, 10:] == 0.0)
        assert np.any(out[0, :10] != 0.0)

    def test_causality(self):
        # Changing K/V beyond a query's position must not change its output.
        q, k, v = rand(1, 64, 16), rand(1, 64, 16), rand(1, 64, 16)
        lengths = jnp.asarray([64], jnp.int32)
        base = np.asarray(flash_prefill(q, k, v, lengths))
        k2 = k.at[0, 40:].set(99.0)
        v2 = v.at[0, 40:].set(-99.0)
        pert = np.asarray(flash_prefill(q, k2, v2, lengths))
        np.testing.assert_allclose(base[0, :40], pert[0, :40], atol=2e-5)
        assert not np.allclose(base[0, 40:], pert[0, 40:])

    def test_rejects_indivisible_blocks(self):
        q = rand(1, 96, 8)
        with pytest.raises(ValueError):
            flash_prefill(q, q, q, jnp.asarray([96], jnp.int32), block_q=64, block_k=64)

    @settings(max_examples=20, deadline=None)
    @given(
        bh=st.integers(1, 6),
        s_pow=st.integers(4, 7),  # S in {16..128}
        dh=st.sampled_from([8, 16, 32]),
        bq=st.sampled_from([16, 32, 64]),
        bk=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, bh, s_pow, dh, bq, bk, seed):
        s = 2**s_pow
        rng = np.random.default_rng(seed)
        q, k, v = (jnp.asarray(rng.standard_normal((bh, s, dh)), jnp.float32) for _ in range(3))
        lengths = jnp.asarray(rng.integers(1, s + 1, bh), jnp.int32)
        out = flash_prefill(q, k, v, lengths, block_q=bq, block_k=bk)
        ref = prefill_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(out, ref, atol=3e-5)


class TestPagedDecode:
    def test_matches_ref(self):
        q = rand(6, 32)
        kc, vc = rand(6, 128, 32), rand(6, 128, 32)
        lengths = jnp.asarray([1, 5, 64, 128, 100, 33], jnp.int32)
        out = paged_decode(q, kc, vc, lengths)
        ref = decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_stale_cache_entries_ignored(self):
        q = rand(1, 16)
        kc, vc = rand(1, 64, 16), rand(1, 64, 16)
        lengths = jnp.asarray([20], jnp.int32)
        base = np.asarray(paged_decode(q, kc, vc, lengths, page_size=16))
        kc2 = kc.at[0, 20:].set(1e3)  # garbage beyond the live region
        vc2 = vc.at[0, 20:].set(-1e3)
        pert = np.asarray(paged_decode(q, kc2, vc2, lengths, page_size=16))
        np.testing.assert_allclose(base, pert, atol=2e-5)

    def test_rejects_bad_page_size(self):
        q = rand(1, 8)
        kc = rand(1, 96, 8)
        with pytest.raises(ValueError):
            paged_decode(q, kc, kc, jnp.asarray([5], jnp.int32), page_size=64)

    @settings(max_examples=20, deadline=None)
    @given(
        bh=st.integers(1, 8),
        s_max=st.sampled_from([32, 64, 128, 192]),
        dh=st.sampled_from([8, 16, 32]),
        page=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, bh, s_max, dh, page, seed):
        if s_max % page != 0:
            return
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((bh, dh)), jnp.float32)
        kc = jnp.asarray(rng.standard_normal((bh, s_max, dh)), jnp.float32)
        vc = jnp.asarray(rng.standard_normal((bh, s_max, dh)), jnp.float32)
        lengths = jnp.asarray(rng.integers(1, s_max + 1, bh), jnp.int32)
        out = paged_decode(q, kc, vc, lengths, page_size=page)
        ref = decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(out, ref, atol=3e-5)
