//! Deterministic perf-proxy contracts (ISSUE 4 satellite coverage). No
//! wall-time assertions — everything here is counter- or bit-identity
//! based, so it cannot flake on a loaded CI machine:
//! - memoization drops the evaluate_partition execution count on the
//!   case-study setting (a periodic re-plan is free; the full serving-loop
//!   3x gate lives in `experiments::perf`'s unit test);
//! - `--threads 4` plans are bit-identical to sequential ones;
//! - plans are bit-identical with the cache on and off;
//! - a shared cache never changes what a warm-started re-plan picks.

use hexgen2::cluster::settings;
use hexgen2::model::OPT_30B;
use hexgen2::rescheduler::warmstart;
use hexgen2::scheduler::{self, EvalCache, Placement, ScheduleOptions};
use hexgen2::workload::WorkloadKind;

fn opts(kind: WorkloadKind) -> ScheduleOptions {
    let mut o = ScheduleOptions::new(kind);
    o.max_rounds = 6;
    o.patience = 3;
    o.proposals_per_round = 8;
    o.type_candidates = 4;
    o.seed = 3;
    o
}

/// Bitwise plan fingerprint: f64 Debug prints the shortest round-trip
/// representation, so equal strings == equal bits (no NaNs in plans).
fn fp(p: &Placement) -> String {
    format!("{p:?}")
}

#[test]
fn periodic_replan_is_free_with_shared_cache() {
    let c = settings::case_study();
    let cache = EvalCache::new();
    let o = opts(WorkloadKind::Lphd);
    let a = scheduler::schedule_with_cache(&c, &OPT_30B, &o, &cache).expect("schedules");
    assert!(a.stats.evals > 0, "first plan executed nothing?");
    assert_eq!(a.stats.evals, a.stats.partitions_explored);
    // The §3.3 loop re-plans every period; under steady traffic the re-plan
    // is an identical search — pure cache hits, zero executions.
    let b = scheduler::schedule_with_cache(&c, &OPT_30B, &o, &cache).expect("schedules");
    assert_eq!(b.stats.evals, 0, "periodic re-plan re-executed evaluations");
    assert_eq!(b.stats.eval_cache_hits, a.stats.evals);
    assert_eq!(fp(&a.placement), fp(&b.placement), "memoized re-plan changed the plan");
    assert_eq!(a.rounds, b.rounds);
}

#[test]
fn threaded_plan_bit_identical_to_sequential() {
    let c = settings::case_study();
    let mut seq = opts(WorkloadKind::Lphd);
    seq.threads = 1;
    let mut par = seq.clone();
    par.threads = 4;
    let a = scheduler::schedule(&c, &OPT_30B, &seq).expect("schedules");
    let b = scheduler::schedule(&c, &OPT_30B, &par).expect("schedules");
    assert_eq!(fp(&a.placement), fp(&b.placement), "threads changed the plan");
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.stats.partitions_explored, b.stats.partitions_explored);
    let scores_a: Vec<u64> = a.history.iter().map(|h| h.score.to_bits()).collect();
    let scores_b: Vec<u64> = b.history.iter().map(|h| h.score.to_bits()).collect();
    assert_eq!(scores_a, scores_b, "convergence history diverged under threads");
    assert_eq!(b.stats.threads, 4);
}

#[test]
fn cache_on_off_bit_identical() {
    let c = settings::het1();
    let mut on = opts(WorkloadKind::Hphd);
    on.use_eval_cache = true;
    let mut off = on.clone();
    off.use_eval_cache = false;
    let a = scheduler::schedule(&c, &OPT_30B, &on).expect("schedules");
    let b = scheduler::schedule(&c, &OPT_30B, &off).expect("schedules");
    assert_eq!(fp(&a.placement), fp(&b.placement), "eval cache changed the plan");
    // Same search trajectory => same explored set either way.
    assert_eq!(a.stats.partitions_explored, b.stats.partitions_explored);
    assert_eq!(b.stats.eval_cache_hits, 0, "disabled cache served a hit");
}

#[test]
fn shared_cache_never_changes_warm_replans() {
    // Drift away and back with a shared cache vs fresh caches: identical
    // placements, strictly fewer executions on the shared path.
    let c = settings::case_study();
    let base = opts(WorkloadKind::Lphd);
    let shared = EvalCache::new();
    let incumbent =
        scheduler::schedule_with_cache(&c, &OPT_30B, &base, &shared).expect("schedules").placement;

    let mut away = base.clone();
    away.workload = WorkloadKind::Hpld;
    let mut back = base.clone();
    back.workload = WorkloadKind::Lphd;

    // Fresh-cache (per-replan) trajectory; the return leg repeats once
    // (the next period under now-steady traffic) and pays full price again.
    let f1 = warmstart::replan(&c, &OPT_30B, &away, &incumbent).expect("replans");
    let f2 = warmstart::replan(&c, &OPT_30B, &back, &f1.placement).expect("replans");
    let f2b = warmstart::replan(&c, &OPT_30B, &back, &f1.placement).expect("replans");
    // Shared-cache trajectory: the identical periodic repeat is free.
    let s1 = warmstart::replan_with_cache(&c, &OPT_30B, &away, &incumbent, &shared)
        .expect("replans");
    let s2 = warmstart::replan_with_cache(&c, &OPT_30B, &back, &s1.placement, &shared)
        .expect("replans");
    let s2b = warmstart::replan_with_cache(&c, &OPT_30B, &back, &s1.placement, &shared)
        .expect("replans");

    assert_eq!(fp(&f1.placement), fp(&s1.placement), "shared cache changed the away re-plan");
    assert_eq!(fp(&f2.placement), fp(&s2.placement), "shared cache changed the return re-plan");
    assert_eq!(fp(&f2b.placement), fp(&s2b.placement), "periodic repeat changed the plan");
    assert_eq!(s2b.stats.evals, 0, "identical periodic re-plan re-executed evaluations");
    assert!(f2b.stats.evals > 0, "fresh-cache repeat was unexpectedly free");
    let fresh_execs = f1.stats.evals + f2.stats.evals + f2b.stats.evals;
    let shared_execs = s1.stats.evals + s2.stats.evals + s2b.stats.evals;
    assert!(
        shared_execs < fresh_execs,
        "shared cache saved nothing: {shared_execs} vs {fresh_execs} executions"
    );
}
