//! Deterministic xoshiro256** PRNG plus the distribution helpers the
//! workload generator and schedulers need.
//!
//! The offline crate registry has no `rand`; this is a small, fully
//! deterministic replacement so every experiment harness is
//! reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) (half-open). Panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson (Knuth for small lambda, normal approx above 64).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda > 64.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range(0, i + 1);
            v.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.range(0, v.len())]
    }

    /// Sample an index proportionally to non-negative weights.
    /// Falls back to uniform if all weights are zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.range(0, weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let s: u64 = (0..n).map(|_| r.poisson(4.2)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 4.2).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let s: f64 = (0..n).map(|_| r.exp(2.0)).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn weighted_all_zero_is_uniform() {
        let mut r = Rng::new(10);
        let w = [0.0, 0.0];
        for _ in 0..100 {
            assert!(r.weighted(&w) < 2);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
