//! Rescheduler subsystem contracts (ISSUE 1 + ISSUE 2 satellite coverage):
//! - warm-start from the incumbent never ends below the incumbent's
//!   objective under the new workload;
//! - the drift detector fires exactly once per sustained shift (hysteresis,
//!   no flapping) on a deterministic phased trace;
//! - the migration planner refuses a switch whose drain+transfer cost
//!   exceeds the projected gain;
//! - on an *oscillating* trace the full closed loop (`rescheduler::drive`)
//!   keeps the switch count bounded, holds the net-benefit gate across
//!   every approved `PlacementSwitch`, and the simulator preserves every
//!   request across multiple switches.

use hexgen2::cluster::settings;
use hexgen2::model::OPT_30B;
use hexgen2::rescheduler::{self, migration, warmstart, DriftKind, MonitorConfig, Rescheduler};
use hexgen2::scheduler::{self, Objective, ScheduleOptions};
use hexgen2::simulator::{run_disaggregated, run_disaggregated_with_resched, PlacementSwitch};
use hexgen2::workload::{Trace, WorkloadKind};

fn incumbent_for(kind: WorkloadKind, seed: u64) -> (hexgen2::cluster::Cluster, scheduler::Placement) {
    let c = settings::case_study();
    let mut o = ScheduleOptions::new(kind);
    o.max_rounds = 6;
    o.patience = 3;
    o.force_k = Some(4);
    o.seed = seed;
    let p = scheduler::schedule(&c, &OPT_30B, &o).expect("incumbent schedules").placement;
    (c, p)
}

#[test]
fn warm_start_never_below_incumbent_under_new_workload() {
    let (c, incumbent) = incumbent_for(WorkloadKind::Lphd, 1);
    // The workload drifts to HPLD. Baseline: the incumbent partition
    // re-evaluated under the new mix (what "keep the placement" would yield).
    let task = scheduler::task_for(WorkloadKind::Hpld);
    let groups = warmstart::incumbent_groups(&incumbent);
    let cache = hexgen2::scheduler::strategy::StrategyCache::new();
    let keep = scheduler::evaluate_partition(
        &c,
        &OPT_30B,
        &task,
        600.0,
        &groups,
        64,
        Objective::Throughput,
        &cache,
    )
    .expect("incumbent evaluates under HPLD");
    let mut shifted = ScheduleOptions::new(WorkloadKind::Hpld);
    shifted.max_rounds = 6;
    shifted.patience = 3;
    let warm = warmstart::replan(&c, &OPT_30B, &shifted, &incumbent).expect("warm replan");
    assert!(
        warm.placement.tokens_per_s >= keep.tokens_per_s - 1e-9,
        "warm re-plan {} fell below the incumbent's {} under the new workload",
        warm.placement.tokens_per_s,
        keep.tokens_per_s
    );
}

#[test]
fn drift_detector_fires_exactly_once_per_sustained_shift() {
    let cfg = MonitorConfig::case_study();
    // One sustained LPHD→HPLD shift: exactly one event, workload-kind drift.
    let spec = [(WorkloadKind::Lphd, 4.0, 120.0), (WorkloadKind::Hpld, 4.0, 120.0)];
    let trace = Trace::phases(&spec, 5);
    let mut sensor = Rescheduler::new(cfg);
    let mut events = Vec::new();
    for r in &trace.requests {
        if let Some(e) = sensor.observe(r.arrival, r.input_len, r.output_len) {
            events.push(e);
        }
    }
    assert_eq!(events.len(), 1, "expected exactly one drift event, got {events:?}");
    let e = &events[0];
    assert!(e.at > 120.0 && e.at < 165.0, "drift at {:.1}s", e.at);
    match e.kind {
        DriftKind::Workload { from, to } => {
            assert_eq!(from, WorkloadKind::Lphd);
            assert_eq!(to, WorkloadKind::Hpld);
        }
        other => panic!("expected a workload drift, got {other:?}"),
    }

    // A steady trace must produce no events at all (no flapping around the
    // detector's own noise).
    let steady = Trace::online(WorkloadKind::Lphd, 4.0, 240.0, 6);
    let mut sensor = Rescheduler::new(cfg);
    for r in &steady.requests {
        assert!(
            sensor.observe(r.arrival, r.input_len, r.output_len).is_none(),
            "spurious drift on a steady trace"
        );
    }
}

#[test]
fn migration_refuses_switch_costlier_than_gain() {
    let (c, p) = incumbent_for(WorkloadKind::Lphd, 2);
    let task = scheduler::task_for(WorkloadKind::Lphd);
    // Candidate with a vanishing projected gain but a real drain cost.
    let mut marginal = p.clone();
    marginal.tokens_per_s = p.tokens_per_s * 1.00001;
    let m = migration::plan(&c, &OPT_30B, &p, &marginal, &task, 600.0, Objective::Throughput);
    assert!(m.tokens_lost > 0.0, "no migration cost modeled: {m:?}");
    assert!(!m.migrate, "unprofitable switch approved: {m:?}");
    // And a candidate that is outright worse must always be refused.
    let mut worse = p.clone();
    worse.tokens_per_s = p.tokens_per_s * 0.5;
    assert!(!migration::plan(&c, &OPT_30B, &p, &worse, &task, 600.0, Objective::Throughput).migrate);
    // Under a non-throughput objective the gate re-scores BOTH placements
    // under the current task (stored scores may come from a different
    // workload) and requires a >1% improvement: a structurally identical
    // candidate re-scores equal, so the switch is refused — hysteresis.
    let identical = p.clone();
    assert!(
        !migration::plan(&c, &OPT_30B, &p, &identical, &task, 600.0, Objective::CostPerToken)
            .migrate,
        "no-gain switch approved under CostPerToken"
    );
}

#[test]
fn resched_simulation_preserves_every_request() {
    // End-to-end over the simulator: a priced, approved switch mid-trace
    // must not lose or duplicate requests versus the static run.
    let (c, p) = incumbent_for(WorkloadKind::Lphd, 3);
    let mut shifted = ScheduleOptions::new(WorkloadKind::Hpld);
    shifted.max_rounds = 4;
    shifted.patience = 2;
    let warm = warmstart::replan(&c, &OPT_30B, &shifted, &p).expect("replan");
    let spec = [(WorkloadKind::Lphd, 2.0, 80.0), (WorkloadKind::Hpld, 2.0, 120.0)];
    let trace = Trace::phases(&spec, 9);
    let n = trace.requests.len();
    let static_rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
    let sw = PlacementSwitch {
        at: 100.0,
        delay: 4.0,
        placement: warm.placement,
        workload: Some(WorkloadKind::Hpld),
    };
    let resched_rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &[sw], &trace);
    assert_eq!(static_rep.records.len(), n);
    assert_eq!(resched_rep.records.len(), n, "switch lost requests");
    let mut ids: Vec<usize> = resched_rep.records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "switch duplicated requests");
}

#[test]
fn oscillating_trace_does_not_thrash() {
    // ROADMAP open item: a trace that oscillates between workload mixes.
    // The closed loop must (a) fire at most once per sustained shift
    // (hysteresis holds system-wide), (b) hold the net-benefit gate on every
    // approved switch, (c) emit sorted, non-overlapping switches, and
    // (d) preserve every request through multiple mid-trace switches.
    let (c, incumbent) = incumbent_for(WorkloadKind::Lphd, 7);
    let mut base = ScheduleOptions::new(WorkloadKind::Lphd);
    base.max_rounds = 4;
    base.patience = 2;
    base.force_k = Some(4);
    let spec = [
        (WorkloadKind::Lphd, 3.0, 80.0),
        (WorkloadKind::Hpld, 3.0, 80.0),
        (WorkloadKind::Lphd, 3.0, 80.0),
        (WorkloadKind::Hpld, 3.0, 80.0),
    ];
    let trace = Trace::phases(&spec, 13);
    let cfg = MonitorConfig::case_study();
    let drive = rescheduler::drive(&c, &OPT_30B, &incumbent, &trace, cfg, &base, 10.0);

    // (a) bounded: three real shifts, at most one event each; hysteresis
    // means an oscillation can never produce more events than shifts.
    assert!(drive.events.len() >= 1, "no drift detected on an oscillating trace");
    assert!(
        drive.events.len() <= 3,
        "hysteresis broke: {} events for 3 sustained shifts",
        drive.events.len()
    );
    assert_eq!(drive.outcomes.len(), drive.events.len());
    // (b) net-benefit gate holds across every approved switch.
    assert!(drive.switches.len() <= drive.events.len(), "more switches than drift events");
    let approved: Vec<_> = drive
        .outcomes
        .iter()
        .flatten()
        .filter(|o| o.migration.migrate)
        .collect();
    assert_eq!(approved.len(), drive.switches.len());
    for o in &approved {
        assert!(
            o.migration.gain_tokens > o.migration.tokens_lost,
            "approved switch fails the net-benefit gate: {:?}",
            o.migration
        );
    }
    // (c) sorted and non-overlapping, as the simulator requires.
    for w in drive.switches.windows(2) {
        assert!(w[0].at + w[0].delay <= w[1].at, "overlapping switches");
    }
    // (d) the simulator preserves every request across all switches.
    let n = trace.requests.len();
    let rep = run_disaggregated_with_resched(&c, &OPT_30B, &incumbent, &drive.switches, &trace);
    assert_eq!(rep.records.len(), n, "requests lost across oscillating switches");
    let mut ids: Vec<usize> = rep.records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicated requests");
}
