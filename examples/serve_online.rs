//! Online serving comparison on heterogeneous setting 2: HexGen-2's
//! disaggregated placement vs the HexGen colocated baseline, at 75% of peak
//! arrival rate (paper §5.1 online protocol), both planned and run through
//! the unified deploy API (one `Planner` per system, one simulator
//! `Backend`). Reports throughput, latency percentiles and SLO attainment
//! (Fig. 8 axes).
//!
//! Run:  cargo run --release --example serve_online

use hexgen2::cluster::settings;
use hexgen2::deploy::{DeploymentSpec, HexGen2Planner, HexGenPlanner, Planner, SimBackend};
use hexgen2::experiments::{online_rate, ExpOpts};
use hexgen2::model::OPT_30B;
use hexgen2::workload::{Trace, WorkloadKind};

fn main() {
    let cluster = settings::het2();
    let opts = ExpOpts::quick();
    let rate = online_rate(&cluster, &OPT_30B, &opts);
    let trace = Trace::online(WorkloadKind::Online, rate, 240.0, 3);
    println!(
        "online trace: {} requests at {:.2} req/s on {}\n",
        trace.requests.len(),
        rate,
        cluster.name
    );

    let spec = DeploymentSpec::new(cluster, OPT_30B).workload(WorkloadKind::Online).quick(true);
    let planners: [&dyn Planner; 2] = [&HexGen2Planner, &HexGenPlanner];
    for planner in planners {
        let dep = spec.plan(planner).expect("plans");
        let rep = dep.run(&SimBackend, &trace).expect("simulates");
        println!(
            "{:26} {:>6.0} tokens/s | avg {:.2}s p95 {:.2}s | TTFT {:.2}s | SLO@99 scale {:.1}",
            planner.display_name(),
            rep.tokens_per_s(),
            rep.avg_latency(),
            rep.p_latency(95.0),
            rep.avg_ttft(),
            rep.slo_scale_for_attainment(0.99),
        );
    }
}
