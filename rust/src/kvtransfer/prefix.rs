//! Cluster-wide prefix KV pool (DESIGN.md §15).
//!
//! KV caches of shared prompt prefixes (system prompts, hot RAG
//! documents, re-sent agent histories) are a *reusable, poolable asset*,
//! not a one-shot prefill→decode byte stream. The pool tracks, per
//! prefix id, where that prefix's KV currently lives:
//!
//! - **GPU tier**: resident on one prefill replica, charged against that
//!   replica's pool budget (a slice of `CostModel::token_capacity`).
//!   A GPU hit steers the request to the holder, which prefills only
//!   the suffix.
//! - **Host tier**: LRU-spilled to cluster host memory. A host hit pays
//!   a re-load transfer (prefix KV bytes over the host-reload
//!   bandwidth) before the suffix prefill can start, then the entry is
//!   promoted back to the serving replica's GPU tier.
//! - **Evicted**: LRU-dropped from the host tier once it overflows; the
//!   next request for the prefix is a full miss and re-publishes.
//!
//! All bookkeeping is deterministic: recency is a logical u64 clock
//! bumped on every lookup/publish (no wall time), LRU scans iterate
//! `BTreeMap`s in ascending id order, and ties break toward the smaller
//! prefix id — so pool state is bit-identical across `--threads`.

use std::collections::BTreeMap;

/// Host tier budget as a multiple of the summed per-replica GPU budgets
/// (when no explicit override is configured).
pub const HOST_BUDGET_FACTOR: f64 = 4.0;

/// Where a prefix's KV currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefixTier {
    /// GPU-resident on the prefill replica with this arena index.
    Gpu(usize),
    /// Spilled to the cluster host-memory tier.
    Host,
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    tokens: f64,
    tier: PrefixTier,
    /// Logical LRU clock stamp (monotone, deterministic).
    touched: u64,
}

/// One spill or eviction performed while making room.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvictRecord {
    pub prefix: usize,
    pub tokens: f64,
    /// `true`: GPU → host spill (KV survives, re-loadable).
    /// `false`: dropped from the host tier (KV gone).
    pub to_host: bool,
}

/// The pool. Owned by the simulator engine (one per run) and registered
/// with every prefill replica at build time; the same structure can back
/// a live coordinator since it does plain token arithmetic.
#[derive(Clone, Debug)]
pub struct PrefixPool {
    entries: BTreeMap<usize, Entry>,
    /// Per-replica GPU pool budget / usage in tokens (arena index key).
    gpu_budget: BTreeMap<usize, f64>,
    gpu_used: BTreeMap<usize, f64>,
    host_budget_override: Option<f64>,
    host_used: f64,
    clock: u64,
    /// Cumulative tokens first published into the pool.
    pub published_tokens: f64,
    /// Cumulative tokens spilled GPU → host.
    pub spilled_tokens: f64,
    /// Cumulative tokens dropped from the host tier.
    pub evicted_tokens: f64,
}

impl Default for PrefixPool {
    fn default() -> PrefixPool {
        PrefixPool::new(None)
    }
}

impl PrefixPool {
    pub fn new(host_budget_override: Option<f64>) -> PrefixPool {
        PrefixPool {
            entries: BTreeMap::new(),
            gpu_budget: BTreeMap::new(),
            gpu_used: BTreeMap::new(),
            host_budget_override,
            host_used: 0.0,
            clock: 0,
            published_tokens: 0.0,
            spilled_tokens: 0.0,
            evicted_tokens: 0.0,
        }
    }

    /// Register a prefill replica's GPU pool budget (tokens).
    pub fn register_replica(&mut self, replica: usize, budget_tokens: f64) {
        self.gpu_budget.insert(replica, budget_tokens.max(0.0));
        self.gpu_used.entry(replica).or_insert(0.0);
    }

    /// Drop registrations for replicas with arena index ≥ `base` (the
    /// engine's placement-rollback path; no entries exist on them yet).
    pub fn unregister_from(&mut self, base: usize) {
        self.gpu_budget.split_off(&base);
        self.gpu_used.split_off(&base);
    }

    pub fn replicas(&self) -> usize {
        self.gpu_budget.len()
    }

    fn host_budget(&self) -> f64 {
        match self.host_budget_override {
            Some(b) => b.max(0.0),
            None => HOST_BUDGET_FACTOR * self.gpu_budget.values().sum::<f64>(),
        }
    }

    /// Where does `prefix` live right now? Bumps the entry's recency.
    pub fn lookup(&mut self, prefix: usize) -> Option<PrefixTier> {
        self.clock += 1;
        let clock = self.clock;
        let e = self.entries.get_mut(&prefix)?;
        e.touched = clock;
        Some(e.tier)
    }

    /// The prefix's KV is now materialized on `replica`'s GPU: a fresh
    /// publish on a miss, or a promotion after a host-hit re-load.
    /// Idempotent — an entry already GPU-resident just has its recency
    /// bumped (it stays with its original holder). Returns `true` when
    /// tokens were newly published (first sighting of this prefix).
    /// Spills/evictions performed to make room are appended to `out`.
    pub fn publish(
        &mut self,
        prefix: usize,
        tokens: f64,
        replica: usize,
        out: &mut Vec<EvictRecord>,
    ) -> bool {
        if !self.gpu_budget.contains_key(&replica) {
            return false;
        }
        self.clock += 1;
        let clock = self.clock;
        let fresh = match self.entries.get_mut(&prefix) {
            Some(e) => {
                e.touched = clock;
                match e.tier {
                    PrefixTier::Gpu(_) => return false,
                    PrefixTier::Host => {
                        // Promote host → GPU of the serving replica.
                        e.tier = PrefixTier::Gpu(replica);
                        let t = e.tokens;
                        self.host_used = (self.host_used - t).max(0.0);
                        *self.gpu_used.entry(replica).or_insert(0.0) += t;
                        false
                    }
                }
            }
            None => {
                self.entries
                    .insert(prefix, Entry { tokens, tier: PrefixTier::Gpu(replica), touched: clock });
                *self.gpu_used.entry(replica).or_insert(0.0) += tokens;
                self.published_tokens += tokens;
                true
            }
        };
        self.make_room(replica, out);
        fresh
    }

    /// Spill every entry held on `replica`'s GPU to the host tier (the
    /// replica is being deactivated by a placement switch — its GPU cache
    /// flushes, the host tier persists). Evictions from the resulting
    /// host-tier overflow are appended to `out`.
    pub fn flush_replica(&mut self, replica: usize, out: &mut Vec<EvictRecord>) {
        let mut moved = 0.0;
        for (&id, e) in self.entries.iter_mut() {
            if e.tier == PrefixTier::Gpu(replica) {
                e.tier = PrefixTier::Host;
                moved += e.tokens;
                out.push(EvictRecord { prefix: id, tokens: e.tokens, to_host: true });
            }
        }
        if moved > 0.0 {
            self.spilled_tokens += moved;
            self.host_used += moved;
            if let Some(u) = self.gpu_used.get_mut(&replica) {
                *u = (*u - moved).max(0.0);
            }
            self.evict_host_overflow(out);
        }
    }

    /// Enforce `replica`'s GPU budget (LRU spill to host), then the host
    /// budget (LRU drop).
    fn make_room(&mut self, replica: usize, out: &mut Vec<EvictRecord>) {
        let budget = self.gpu_budget.get(&replica).copied().unwrap_or(0.0);
        loop {
            let used = self.gpu_used.get(&replica).copied().unwrap_or(0.0);
            if used <= budget {
                break;
            }
            // LRU victim on this replica: oldest clock, ties to the
            // smallest prefix id (ascending BTreeMap scan + strict `<`).
            let mut victim: Option<(usize, f64, u64)> = None;
            for (&id, e) in &self.entries {
                if e.tier == PrefixTier::Gpu(replica)
                    && victim.map_or(true, |(_, _, c)| e.touched < c)
                {
                    victim = Some((id, e.tokens, e.touched));
                }
            }
            let Some((id, t, _)) = victim else { break };
            if let Some(e) = self.entries.get_mut(&id) {
                e.tier = PrefixTier::Host;
            }
            if let Some(u) = self.gpu_used.get_mut(&replica) {
                *u = (*u - t).max(0.0);
            }
            self.host_used += t;
            self.spilled_tokens += t;
            out.push(EvictRecord { prefix: id, tokens: t, to_host: true });
        }
        self.evict_host_overflow(out);
    }

    fn evict_host_overflow(&mut self, out: &mut Vec<EvictRecord>) {
        let budget = self.host_budget();
        while self.host_used > budget {
            let mut victim: Option<(usize, f64, u64)> = None;
            for (&id, e) in &self.entries {
                if e.tier == PrefixTier::Host && victim.map_or(true, |(_, _, c)| e.touched < c) {
                    victim = Some((id, e.tokens, e.touched));
                }
            }
            let Some((id, t, _)) = victim else { break };
            self.entries.remove(&id);
            self.host_used = (self.host_used - t).max(0.0);
            self.evicted_tokens += t;
            out.push(EvictRecord { prefix: id, tokens: t, to_host: false });
        }
    }

    /// Tokens currently GPU-resident across all replicas.
    pub fn gpu_resident(&self) -> f64 {
        self.gpu_used.values().sum()
    }

    /// Tokens currently in the host tier.
    pub fn host_resident(&self) -> f64 {
        self.host_used
    }

    /// Token conservation: everything ever published is either still
    /// resident (GPU or host) or was dropped from the host tier.
    /// Returns (published, resident + evicted) for assertion.
    pub fn conservation(&self) -> (f64, f64) {
        (self.published_tokens, self.gpu_resident() + self.host_resident() + self.evicted_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_lookup_roundtrip() {
        let mut pool = PrefixPool::new(None);
        pool.register_replica(0, 1000.0);
        pool.register_replica(1, 1000.0);
        let mut out = Vec::new();
        assert!(pool.publish(7, 300.0, 0, &mut out));
        assert!(out.is_empty());
        assert_eq!(pool.lookup(7), Some(PrefixTier::Gpu(0)));
        assert_eq!(pool.lookup(8), None);
        // Idempotent: re-publishing (even from another replica) does not
        // move or double-count the entry.
        assert!(!pool.publish(7, 300.0, 1, &mut out));
        assert_eq!(pool.lookup(7), Some(PrefixTier::Gpu(0)));
        assert_eq!(pool.published_tokens, 300.0);
        assert_eq!(pool.gpu_resident(), 300.0);
    }

    #[test]
    fn lru_spills_to_host_then_evicts() {
        let mut pool = PrefixPool::new(Some(250.0));
        pool.register_replica(0, 500.0);
        let mut out = Vec::new();
        pool.publish(1, 200.0, 0, &mut out);
        pool.publish(2, 200.0, 0, &mut out);
        assert!(out.is_empty());
        // Touch 1 so 2 becomes LRU.
        pool.lookup(1);
        pool.publish(3, 200.0, 0, &mut out);
        // 2 spilled to host (oldest), fits the 250-token host budget.
        assert_eq!(out, vec![EvictRecord { prefix: 2, tokens: 200.0, to_host: true }]);
        assert_eq!(pool.lookup(2), Some(PrefixTier::Host));
        out.clear();
        // Another overflow: 1 is now LRU on GPU (3 is newest), spills;
        // host would hold 400 > 250, so 2 (older in host) is dropped.
        pool.publish(4, 200.0, 0, &mut out);
        assert_eq!(
            out,
            vec![
                EvictRecord { prefix: 1, tokens: 200.0, to_host: true },
                EvictRecord { prefix: 2, tokens: 200.0, to_host: false },
            ]
        );
        assert_eq!(pool.lookup(2), None);
        let (published, accounted) = pool.conservation();
        assert!((published - accounted).abs() < 1e-9, "{published} vs {accounted}");
    }

    #[test]
    fn host_hit_promotes_back_to_gpu() {
        let mut pool = PrefixPool::new(None);
        pool.register_replica(0, 300.0);
        pool.register_replica(1, 300.0);
        let mut out = Vec::new();
        pool.publish(1, 200.0, 0, &mut out);
        pool.publish(2, 200.0, 0, &mut out); // spills 1 to host
        assert_eq!(pool.lookup(1), Some(PrefixTier::Host));
        out.clear();
        // Re-load lands on replica 1: promotion moves host → Gpu(1).
        assert!(!pool.publish(1, 200.0, 1, &mut out));
        assert_eq!(pool.lookup(1), Some(PrefixTier::Gpu(1)));
        assert!(out.is_empty());
        assert_eq!(pool.published_tokens, 400.0);
        assert_eq!(pool.host_resident(), 0.0);
        assert_eq!(pool.gpu_resident(), 400.0);
    }

    #[test]
    fn flush_replica_moves_everything_to_host() {
        let mut pool = PrefixPool::new(None);
        pool.register_replica(0, 1000.0);
        let mut out = Vec::new();
        pool.publish(1, 100.0, 0, &mut out);
        pool.publish(2, 150.0, 0, &mut out);
        out.clear();
        pool.flush_replica(0, &mut out);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|e| e.to_host));
        assert_eq!(pool.lookup(1), Some(PrefixTier::Host));
        assert_eq!(pool.lookup(2), Some(PrefixTier::Host));
        assert_eq!(pool.gpu_resident(), 0.0);
        assert_eq!(pool.host_resident(), 250.0);
        let (published, accounted) = pool.conservation();
        assert!((published - accounted).abs() < 1e-9);
    }

    #[test]
    fn unregistered_replica_cannot_publish() {
        let mut pool = PrefixPool::new(None);
        let mut out = Vec::new();
        assert!(!pool.publish(1, 100.0, 0, &mut out));
        assert_eq!(pool.lookup(1), None);
        pool.register_replica(0, 100.0);
        pool.register_replica(1, 100.0);
        pool.unregister_from(1);
        assert_eq!(pool.replicas(), 1);
        assert!(!pool.publish(1, 50.0, 1, &mut out));
        assert!(pool.publish(1, 50.0, 0, &mut out));
    }
}
