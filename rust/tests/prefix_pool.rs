//! Cluster-wide prefix KV pool contracts (DESIGN.md §15): token
//! conservation through the tiered pool, equal-load share sweeps (share 0
//! never touches the pool and replays bit-identical arrivals), Zipf-skew
//! monotonicity (skew → hit rate → TTFT), the cache-aware planner's
//! decode-heavy partition shift with thread-count determinism, and
//! t-digest percentile parity between `RecordMode::Windowed` and full
//! per-request records at 50k-completion scale.

use hexgen2::cluster::settings;
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{self, Placement, ScheduleOptions};
use hexgen2::simulator::metrics::{RequestRecord, SimReport, WindowedAgg};
use hexgen2::simulator::{run_disaggregated_cfg, RecordMode, SimConfig};
use hexgen2::util::rng::Rng;
use hexgen2::workload::{PrefixParams, Trace, TraceSource, WorkloadKind};

fn schedule(kind: WorkloadKind, seed: u64) -> Placement {
    let mut opts = ScheduleOptions::new(kind);
    opts.max_rounds = 4;
    opts.force_k = Some(4);
    opts.seed = seed;
    scheduler::schedule(&settings::case_study(), &OPT_30B, &opts).expect("schedules").placement
}

fn decode_device_share(p: &Placement) -> f64 {
    let total: usize = p.groups.iter().map(|g| g.devices.len()).sum();
    let dec: usize = p.groups.iter().filter(|g| !g.is_prefill).map(|g| g.devices.len()).sum();
    dec as f64 / total.max(1) as f64
}

#[test]
fn pool_conserves_tokens_and_resolves_every_prefixed_request() {
    // Every prefix-declaring request is resolved against the pool exactly
    // once (hit, host hit, or miss), and every token ever published is
    // either still resident (GPU or host tier) or was dropped from the
    // host tier — the ledger never mints or leaks KV.
    let c = settings::case_study();
    let p = schedule(WorkloadKind::Agent, 0);
    let trace = Trace::offline(WorkloadKind::Agent, 160, 9);
    let prefixed = trace.requests.iter().filter(|r| r.prefix.is_some()).count();
    assert!(prefixed > 100, "agent class should declare most prefixes, got {prefixed}");
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &SimConfig::default());
    assert_eq!(rep.stats.unserved, 0, "feasible agent trace left requests unserved");
    let s = &rep.stats;
    assert_eq!(
        s.prefix_hits + s.prefix_host_hits + s.prefix_misses,
        prefixed,
        "lookups ({} + {} + {}) must cover each prefixed request once",
        s.prefix_hits,
        s.prefix_host_hits,
        s.prefix_misses
    );
    assert!(s.prefix_hits > 0, "hot Zipf prefixes never hit");
    assert!(s.prefix_reused_tokens > 0.0);
    assert!(s.prefix_published_tokens > 0.0);
    let accounted = s.prefix_gpu_tokens + s.prefix_host_tokens + s.prefix_evicted_tokens;
    assert!(
        (s.prefix_published_tokens - accounted).abs() <= 1e-9 * s.prefix_published_tokens,
        "token conservation broke: published {} vs resident+evicted {}",
        s.prefix_published_tokens,
        accounted
    );
}

#[test]
fn share_sweep_is_equal_load_and_share_zero_never_touches_pool() {
    // The fixed-draw RNG discipline: a share sweep replays bit-identical
    // arrivals and lengths, only the declared-reusable flag moves. At
    // share 0 no request carries a prefix, so the engine's pool machinery
    // must stay provably cold — every counter exactly zero.
    let t0 = Trace::from_source(
        TraceSource::offline(WorkloadKind::Agent, 120, 5).with_prefix_share(0.0),
    );
    let t95 = Trace::from_source(
        TraceSource::offline(WorkloadKind::Agent, 120, 5).with_prefix_share(0.95),
    );
    assert_eq!(t0.requests.len(), t95.requests.len());
    for (a, b) in t0.requests.iter().zip(&t95.requests) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "arrival moved with share");
        assert_eq!(a.input_len, b.input_len, "input_len moved with share on {}", a.id);
        assert_eq!(a.output_len, b.output_len, "output_len moved with share on {}", a.id);
        assert!(a.prefix.is_none(), "share 0 declared a prefix on {}", a.id);
    }
    assert!(
        t95.requests.iter().filter(|r| r.prefix.is_some()).count() > 80,
        "share 0.95 declared almost nothing"
    );
    let c = settings::case_study();
    let p = schedule(WorkloadKind::Agent, 0);
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &t0, &SimConfig::default());
    let s = &rep.stats;
    assert_eq!(s.prefix_hits, 0);
    assert_eq!(s.prefix_host_hits, 0);
    assert_eq!(s.prefix_misses, 0);
    assert_eq!(s.prefix_reused_tokens, 0.0);
    assert_eq!(s.prefix_published_tokens, 0.0);
    assert_eq!(s.prefix_spilled_tokens, 0.0);
    assert_eq!(s.prefix_evicted_tokens, 0.0);
    assert_eq!(s.prefix_gpu_tokens, 0.0);
    assert_eq!(s.prefix_host_tokens, 0.0);
    assert_eq!(s.prefix_reload_s, 0.0);
}

#[test]
fn higher_zipf_skew_raises_hit_rate_and_cuts_mean_ttft() {
    // Monotonicity headline: at fixed share and population, a more skewed
    // prefix popularity concentrates traffic on fewer hot prefixes — more
    // reuse, fewer full prefills, lower mean TTFT. Hit rate must rise
    // strictly with skew; TTFT must be strictly better at the high end.
    let c = settings::case_study();
    let p = schedule(WorkloadKind::Agent, 0);
    let mut rates = Vec::new();
    let mut ttfts = Vec::new();
    for &skew in &[0.2, 1.1, 2.5] {
        let params =
            PrefixParams { n_prefixes: 64, zipf_s: skew, share: 0.95, len_base: 768, len_step: 96 };
        let trace = Trace::from_source(
            TraceSource::offline(WorkloadKind::Agent, 200, 5).with_prefix_params(params),
        );
        let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &SimConfig::default());
        assert_eq!(rep.stats.unserved, 0);
        rates.push(rep.stats.prefix_hit_rate());
        ttfts.push(rep.avg_ttft());
    }
    assert!(
        rates[0] < rates[1] && rates[1] < rates[2],
        "hit rate not strictly increasing in skew: {rates:?}"
    );
    assert!(
        ttfts[2] < ttfts[0],
        "more reuse should cut mean TTFT: {:?} (hit rates {:?})",
        ttfts,
        rates
    );
}

#[test]
fn hit_aware_planner_shifts_partition_decode_heavy() {
    // Acceptance: with `ScheduleOptions::prefix_hit_rate` set, the planner
    // discounts expected prefill demand, so the optimal typed partition
    // allocates a strictly larger device share to decode than the
    // hit-blind ranking at the same load.
    let c = settings::case_study();
    let plan_at = |hit_rate: f64, threads: usize| -> Placement {
        let mut o = ScheduleOptions::new(WorkloadKind::Agent);
        o.max_rounds = 8;
        o.force_k = Some(4);
        o.seed = 0;
        o.prefix_hit_rate = hit_rate;
        o.threads = threads;
        scheduler::schedule(&c, &OPT_30B, &o).expect("schedules").placement
    };
    let blind = decode_device_share(&plan_at(0.0, 1));
    let aware: Vec<f64> =
        [0.5, 0.75, 0.95].iter().map(|&f| decode_device_share(&plan_at(f, 1))).collect();
    for (f, a) in [0.5, 0.75, 0.95].iter().zip(&aware) {
        assert!(
            *a >= blind - 1e-12,
            "hit rate {f} went prefill-heavier than blind: {a} vs {blind}"
        );
    }
    let best = aware.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        best > blind,
        "hit-aware planner never shifted decode-heavy: blind {blind}, aware {aware:?}"
    );
}

#[test]
fn hit_aware_plans_bit_identical_across_threads() {
    // Acceptance: the cache-aware discount keys into the eval cache and
    // the strategy fan-out deterministically — `--threads 1` and
    // `--threads 4` produce bit-identical plans at a nonzero hit rate.
    let c = settings::case_study();
    let plan_at = |threads: usize| -> Placement {
        let mut o = ScheduleOptions::new(WorkloadKind::Agent);
        o.max_rounds = 6;
        o.force_k = Some(4);
        o.seed = 3;
        o.prefix_hit_rate = 0.75;
        o.threads = threads;
        scheduler::schedule(&c, &OPT_30B, &o).expect("schedules").placement
    };
    let (t1, t4) = (plan_at(1), plan_at(4));
    assert_eq!(
        format!("{t1:?}"),
        format!("{t4:?}"),
        "hit-aware plan differs across thread counts"
    );
}

#[test]
fn windowed_engine_run_matches_full_within_sketch_bound() {
    // End-to-end t-digest check on a prefix workload: windowed mode keeps
    // the exact aggregates bit-identical and the sketch percentiles within
    // the documented ≲2% relative error (the run exceeds the 256-centroid
    // exact regime).
    let c = settings::case_study();
    let p = schedule(WorkloadKind::Agent, 0);
    let trace = Trace::online(WorkloadKind::Agent, 4.0, 120.0, 3);
    assert!(trace.requests.len() > 300, "need enough completions to leave the exact regime");
    let full = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &SimConfig::default());
    let cfg = SimConfig { record_mode: RecordMode::Windowed, ..SimConfig::default() };
    let win = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
    assert!(win.records.is_empty());
    assert_eq!(win.completed(), full.completed());
    assert_eq!(win.makespan, full.makespan);
    assert_eq!(win.total_output_tokens, full.total_output_tokens);
    assert_eq!(win.avg_latency(), full.avg_latency());
    assert_eq!(win.avg_ttft(), full.avg_ttft());
    assert_eq!(win.stats.prefix_hits, full.stats.prefix_hits);
    assert_eq!(win.stats.prefix_misses, full.stats.prefix_misses);
    for q in [50.0, 90.0, 99.0] {
        let (w, f) = (win.p_latency(q), full.p_latency(q));
        assert!(
            (w - f).abs() <= 0.02 * f.abs().max(1e-12),
            "p{q}: windowed {w} vs full {f}"
        );
    }
}

#[test]
fn tdigest_matches_full_records_on_50k_completions() {
    // Satellite parity: the `WindowedAgg` t-digest against
    // `RecordMode::Full` ground truth on a 50k-request trace's worth of
    // completions with a heavy-tailed latency profile. Exact fields are
    // bit-identical; percentiles land within 2% relative error — roughly
    // a 10x improvement on the ~13%-error log-bucket histograms the
    // sketch replaced.
    let n = 50_000;
    let mut rng = Rng::new(77);
    let mut agg = WindowedAgg::new();
    let mut records = Vec::with_capacity(n);
    for id in 0..n {
        let arrival = id as f64 * 0.01;
        let latency = 0.5 + rng.exp(1.0) * (1.0 + 9.0 * rng.f64());
        let r = RequestRecord {
            id,
            arrival,
            prefill_done: arrival + 0.2 * latency,
            completion: arrival + latency,
            input_len: 512,
            output_len: 64,
            slo_base: 1.0,
        };
        agg.push(&r);
        records.push(r);
    }
    let full = SimReport::from_records(records);
    let win = SimReport::from_windowed(agg);
    assert_eq!(win.completed(), full.completed());
    assert_eq!(win.total_output_tokens, full.total_output_tokens);
    assert_eq!(win.makespan.to_bits(), full.makespan.to_bits());
    assert_eq!(win.avg_latency().to_bits(), full.avg_latency().to_bits());
    assert_eq!(win.avg_ttft().to_bits(), full.avg_ttft().to_bits());
    for q in [50.0, 90.0, 95.0, 99.0, 99.9] {
        let (w, f) = (win.p_latency(q), full.p_latency(q));
        assert!(
            (w - f).abs() <= 0.02 * f.abs(),
            "p{q}: sketch {w} vs exact {f} (rel {})",
            ((w - f) / f).abs()
        );
    }
    // SLO attainment derives from the same sketch: CDF within 2%.
    for scale in [2.0, 5.0, 10.0] {
        let (w, f) = (win.slo_attainment(scale), full.slo_attainment(scale));
        assert!((w - f).abs() <= 0.02, "attainment@{scale}: {w} vs {f}");
    }
}
