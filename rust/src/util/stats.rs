//! Small statistics helpers used by simulator metrics and the bench harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (0..=100) with linear interpolation; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Fraction of samples <= threshold (SLO attainment).
pub fn attainment(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x <= threshold).count() as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_basics() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn attainment_counts() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(attainment(&xs, 2.0), 0.5);
        assert_eq!(attainment(&xs, 0.5), 0.0);
        assert_eq!(attainment(&xs, 9.0), 1.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
