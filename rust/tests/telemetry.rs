//! Flight-recorder contracts (DESIGN.md §12): trace/counter conservation —
//! metrics re-derived purely from the event stream must match the engine's
//! `SimReport` / transfer-ledger counters exactly — byte-identical traces
//! across same-seed runs, per-window counter reconstruction in
//! `SimReport::windowed`, sampling semantics, and the satellite closed
//! loop: a simulated epoch's KV ledger replayed into the monitor fires
//! `DriftKind::KvContention` end-to-end through `ReschedBackend`, with the
//! decision audit explaining the re-plan.

use hexgen2::cluster::settings;
use hexgen2::costmodel::ReplicaConfig;
use hexgen2::deploy::{DeploymentSpec, HexGen2Planner, ReschedBackend, SimBackend};
use hexgen2::model::OPT_30B;
use hexgen2::rescheduler::MonitorConfig;
use hexgen2::scheduler::{self, Placement, ScheduleOptions};
use hexgen2::simulator::{
    run_colocated_cfg, run_disaggregated_cfg, LinkModel, SimConfig, SimReport, Sizing,
};
use hexgen2::telemetry::{chrome_trace, derive_metrics, prometheus_dump, AuditRecord};
use hexgen2::workload::{Trace, WorkloadKind};

fn schedule(
    cluster: &hexgen2::cluster::Cluster,
    kind: WorkloadKind,
    k: usize,
    seed: u64,
) -> Placement {
    let mut opts = ScheduleOptions::new(kind);
    opts.max_rounds = 4;
    opts.force_k = Some(k);
    opts.seed = seed;
    scheduler::schedule(cluster, &OPT_30B, &opts).expect("schedules").placement
}

fn traced(cfg: SimConfig) -> SimConfig {
    SimConfig { trace: true, trace_sample_rate: 1.0, ..cfg }
}

/// The conservation property: every headline metric re-derived purely from
/// the complete event stream equals the engine's own counters — the
/// aggregates exactly (min/max folds and usize counts), the f64
/// accumulators bit-for-bit because `derive_metrics` mirrors the engine's
/// accumulation order.
fn assert_conserved(rep: &SimReport, what: &str) {
    let log = rep.trace.as_ref().unwrap_or_else(|| panic!("{what}: tracing was on"));
    assert_eq!(log.dropped, 0, "{what}: ring buffer dropped events");
    assert_eq!(log.sample_rate, 1.0, "{what}: full sampling required");
    let m = derive_metrics(log);
    assert_eq!(m.completions, rep.records.len(), "{what}: completions");
    assert_eq!(m.total_output_tokens, rep.total_output_tokens, "{what}: output tokens");
    assert_eq!(m.makespan, rep.makespan, "{what}: makespan");
    assert_eq!(m.tokens_per_s, rep.tokens_per_s(), "{what}: tokens/s");
    for r in &rep.records {
        let req = r.id as u32;
        assert_eq!(
            m.latency.get(&req).copied(),
            Some(r.latency()),
            "{what}: latency of request {}",
            r.id
        );
        assert_eq!(
            m.ttft.get(&req).copied(),
            Some(r.ttft()),
            "{what}: TTFT of request {}",
            r.id
        );
    }
    assert_eq!(m.mem_stalls, rep.stats.mem_stalls, "{what}: mem stalls");
    assert_eq!(m.rejects, rep.stats.rejected, "{what}: rejects");
    // Prefix-pool counters (DESIGN.md §15): hit/miss/spill totals
    // re-derived from `PrefixHit`/`PrefixMiss`/`PrefixEvict` events equal
    // the engine's counters exactly (token sums are whole numbers, so f64
    // addition order is immaterial). All-zero on prefix-free workloads.
    assert_eq!(m.prefix_hits, rep.stats.prefix_hits, "{what}: prefix GPU hits");
    assert_eq!(m.prefix_host_hits, rep.stats.prefix_host_hits, "{what}: prefix host hits");
    assert_eq!(m.prefix_misses, rep.stats.prefix_misses, "{what}: prefix misses");
    assert_eq!(
        m.prefix_spilled_tokens, rep.stats.prefix_spilled_tokens,
        "{what}: prefix spilled tokens"
    );
    assert_eq!(
        m.prefix_evicted_tokens, rep.stats.prefix_evicted_tokens,
        "{what}: prefix evicted tokens"
    );
    // The engine adds each transfer's queue wait at enqueue time; the
    // derivation folds the same values in the same (event) order.
    assert_eq!(
        m.kv_wait_total_s, rep.stats.kv_link_wait_s,
        "{what}: total KV queue wait not bit-exact"
    );
    let transfers: usize = m.route_transfers.values().sum();
    assert_eq!(transfers, rep.stats.kv_transfers, "{what}: transfer count");
    let bytes: f64 = m.route_bytes.values().sum();
    assert!(
        (bytes - rep.stats.kv_bytes).abs() <= 1e-9 * rep.stats.kv_bytes.max(1.0),
        "{what}: KV bytes {} vs ledger {}",
        bytes,
        rep.stats.kv_bytes
    );
    // Per-route detail against the transfer ledger, bit-exact (per-route
    // sums accumulate in the same enqueue order on both sides).
    let used: Vec<_> = rep.link_loads.iter().filter(|l| l.transfers > 0).collect();
    assert_eq!(m.route_transfers.len(), used.len(), "{what}: route set");
    for l in used {
        let key = (l.src as u32, l.dst as u32);
        assert_eq!(
            m.route_transfers.get(&key).copied(),
            Some(l.transfers),
            "{what}: transfers on {}→{}",
            l.src,
            l.dst
        );
        assert_eq!(
            m.route_bytes.get(&key).copied(),
            Some(l.bytes),
            "{what}: bytes on {}→{}",
            l.src,
            l.dst
        );
        assert_eq!(
            m.route_wait_s.get(&key).copied(),
            Some(l.wait_s),
            "{what}: queue wait on {}→{}",
            l.src,
            l.dst
        );
    }
}

#[test]
fn trace_conserves_disaggregated_counters_case_study() {
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let trace = Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 11);
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(SimConfig::default()));
    assert!(rep.stats.kv_transfers > 0, "disagg run moved no KV");
    assert_conserved(&rep, "case_study disagg");
}

#[test]
fn trace_conserves_counters_on_het1() {
    // The heterogeneous setting exercises slow (10GbE) routes and the
    // shared-NIC contention model — waits are nonzero and must still
    // re-derive exactly.
    let c = settings::het1();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 7);
    let trace = Trace::offline(WorkloadKind::Lphd, 80, 13);
    let cfg = SimConfig { link: LinkModel::SharedNic, ..SimConfig::default() };
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(cfg));
    assert_conserved(&rep, "het1 shared-NIC disagg");
}

#[test]
fn trace_conserves_counters_under_memory_pressure() {
    // Per-request admission on a heavy-tail flood: mem-stall events must
    // count exactly what the engine counted.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::HeavyTail, 4, 21);
    let trace = Trace::offline(WorkloadKind::HeavyTail, 400, 21);
    let cfg = SimConfig { sizing: Sizing::PerRequest, ..SimConfig::default() };
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(cfg));
    assert!(rep.stats.mem_stalls > 0, "flood produced no memory pressure");
    assert_conserved(&rep, "heavy-tail per-request disagg");
}

#[test]
fn trace_conserves_prefix_pool_counters() {
    // ISSUE 9 satellite: on a prefix workload at sample rate 1.0, the
    // trace-derived hit/miss/spill totals must equal the engine counters
    // exactly — the flight recorder never under- or over-reports reuse.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Agent, 4, 0);
    let trace = Trace::offline(WorkloadKind::Agent, 160, 9);
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(SimConfig::default()));
    assert!(rep.stats.prefix_hits > 0, "agent workload never hit the pool");
    assert!(
        rep.stats.prefix_hits + rep.stats.prefix_host_hits + rep.stats.prefix_misses > 0,
        "no prefix lookups recorded"
    );
    assert_conserved(&rep, "agent prefix pool");
}

#[test]
fn trace_conserves_colocated_counters() {
    let c = settings::homogeneous_small();
    let replicas = vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])];
    let trace = Trace::online(WorkloadKind::Lpld, 1.5, 60.0, 3);
    let rep = run_colocated_cfg(
        &c,
        &OPT_30B,
        &replicas,
        &trace,
        Some(512),
        &traced(SimConfig::default()),
    );
    assert_conserved(&rep, "colocated chunked prefill");
    // Colocated serving moves no KV.
    assert_eq!(rep.stats.kv_transfers, 0);
}

#[test]
fn same_seed_runs_export_byte_identical_traces() {
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let run = || {
        let trace = Trace::online(WorkloadKind::Lphd, 2.0, 60.0, 11);
        run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(SimConfig::default()))
    };
    let (a, b) = (run(), run());
    let (la, lb) = (a.trace.as_ref().unwrap(), b.trace.as_ref().unwrap());
    assert_eq!(
        chrome_trace(la).to_string_pretty(),
        chrome_trace(lb).to_string_pretty(),
        "same-seed Chrome trace files differ"
    );
    assert_eq!(
        prometheus_dump(la, 10.0),
        prometheus_dump(lb, 10.0),
        "same-seed Prometheus dumps differ"
    );
}

#[test]
fn tracing_does_not_perturb_the_simulation() {
    // The recorder is observation only: the traced run's records and
    // counters must equal the untraced run's bit-for-bit.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let trace = Trace::online(WorkloadKind::Lphd, 2.0, 60.0, 11);
    let off = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &SimConfig::default());
    let on = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(SimConfig::default()));
    assert!(off.trace.is_none());
    assert!(on.trace.is_some());
    assert_eq!(off.records.len(), on.records.len());
    assert_eq!(off.tokens_per_s(), on.tokens_per_s());
    assert_eq!(off.stats.events, on.stats.events);
    assert_eq!(off.stats.mem_stalls, on.stats.mem_stalls);
    assert_eq!(off.stats.kv_link_wait_s, on.stats.kv_link_wait_s);
    for (x, y) in off.records.iter().zip(&on.records) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.completion, y.completion);
    }
}

#[test]
fn sampling_keeps_or_drops_whole_requests() {
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let trace = Trace::online(WorkloadKind::Lphd, 2.0, 60.0, 11);
    let cfg = SimConfig { trace: true, trace_sample_rate: 0.35, ..SimConfig::default() };
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
    let log = rep.trace.as_ref().unwrap();
    let m = derive_metrics(log);
    assert!(m.completions > 0, "sampling dropped everything");
    assert!(
        m.completions < rep.records.len(),
        "rate 0.35 kept every request ({} of {})",
        m.completions,
        rep.records.len()
    );
    // Per-request sampling: any request with an Arrive also has its Finish
    // (it completed — the engine served everything on this trace).
    assert_eq!(rep.stats.unserved, 0);
    let arrived: std::collections::BTreeSet<u32> = log
        .events
        .iter()
        .filter_map(|s| match s.ev {
            hexgen2::telemetry::TraceEvent::Arrive { req } => Some(req),
            _ => None,
        })
        .collect();
    assert_eq!(arrived.len(), m.completions, "a sampled request lost spans");
    for req in &arrived {
        assert!(m.latency.contains_key(req), "request {req} arrived but never finished");
    }
}

#[test]
fn windowed_reconstructs_engine_counters_from_trace() {
    // Satellite fix: `SimReport::windowed` used to zero `SimStats`
    // wholesale; with a trace attached it now reconstructs the per-window
    // mem-stall and KV-wait counters, and a partition of the run must add
    // back up to the totals.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::HeavyTail, 4, 21);
    let trace = Trace::offline(WorkloadKind::HeavyTail, 400, 21);
    let cfg = SimConfig { sizing: Sizing::PerRequest, ..SimConfig::default() };
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &traced(cfg));
    assert!(rep.stats.mem_stalls > 0 && rep.stats.kv_link_wait_s >= 0.0);
    let t_end = rep.records.iter().map(|r| r.completion).fold(0.0f64, f64::max) + 1.0;
    let n_win = 8;
    let mut stalls = 0usize;
    let mut wait = 0.0f64;
    for w in 0..n_win {
        let (t0, t1) = (t_end * w as f64 / n_win as f64, t_end * (w + 1) as f64 / n_win as f64);
        let sub = rep.windowed(t0, t1);
        let log = rep.trace.as_ref().unwrap();
        assert_eq!(sub.stats.mem_stalls, log.mem_stalls_in(t0, t1));
        assert_eq!(sub.stats.kv_link_wait_s, log.kv_wait_in(t0, t1));
        stalls += sub.stats.mem_stalls;
        wait += sub.stats.kv_link_wait_s;
    }
    assert_eq!(stalls, rep.stats.mem_stalls, "window partition loses stalls");
    assert!(
        (wait - rep.stats.kv_link_wait_s).abs() <= 1e-9 * rep.stats.kv_link_wait_s.max(1.0),
        "window partition loses KV wait: {} vs {}",
        wait,
        rep.stats.kv_link_wait_s
    );
    // Without a trace the counters cannot be attributed to a window and
    // stay zero — the documented limitation.
    let untraced = run_disaggregated_cfg(
        &c,
        &OPT_30B,
        &p,
        &trace,
        &SimConfig { sizing: Sizing::PerRequest, ..SimConfig::default() },
    );
    let sub = untraced.windowed(0.0, t_end);
    assert_eq!(sub.stats.mem_stalls, 0);
    assert_eq!(sub.stats.kv_link_wait_s, 0.0);
}

#[test]
fn report_json_carries_span_summaries_and_audit_counts() {
    let spec = DeploymentSpec::new(settings::case_study(), OPT_30B)
        .workload(WorkloadKind::Lphd)
        .quick(true)
        .force_k(4)
        .max_rounds(4)
        .trace(true)
        .audit(true);
    let dep = spec.plan(&HexGen2Planner).expect("plans");
    assert!(
        dep.plan.audit.iter().any(|r| matches!(r, AuditRecord::Candidate { .. })),
        "audit-on planning recorded no candidates"
    );
    let trace = Trace::offline(WorkloadKind::Lphd, 40, 4);
    let rep = dep.run(&SimBackend, &trace).expect("runs");
    let j = dep.report_json(&rep);
    assert!(j.get("trace_events").unwrap().as_usize().unwrap() > 0);
    assert_eq!(j.get("trace_dropped").unwrap().as_usize(), Some(0));
    let spans = j.get("request_spans").unwrap().as_arr().unwrap();
    assert_eq!(spans.len(), rep.records.len(), "one span summary per completion");
    for s in spans {
        assert!(s.get("req").is_some());
        assert!(s.get("ttft_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(s.get("latency_s").unwrap().as_f64().unwrap() >= 0.0);
    }
    assert!(j.get("audit_records").unwrap().as_usize().unwrap() > 0);
}

#[test]
fn kv_contention_drift_fires_end_to_end_through_resched_backend() {
    // The satellite closed loop: `ReschedBackend` flight-records one epoch
    // on the incumbent, replays its KV ledger (KvEnqueue queue waits) into
    // `monitor::observe_kv`, and a microsecond contention threshold turns
    // the shared-NIC queueing into a sustained `DriftKind::KvContention`
    // drift — re-planned and recorded in the decision audit.
    let spec = DeploymentSpec::new(settings::case_study(), OPT_30B)
        .workload(WorkloadKind::Lphd)
        .quick(true)
        .force_k(4)
        .max_rounds(4)
        .link(LinkModel::SharedNic)
        .audit(true);
    let dep = spec.plan(&HexGen2Planner).expect("plans");
    let trace = Trace::online(WorkloadKind::Lphd, 6.0, 120.0, 5);

    // Sanity: at this arrival rate the serialized NICs must queue, so the
    // replayed ledger carries positive waits for the monitor to see.
    let plain = dep.run(&SimBackend, &trace).expect("sim runs");
    assert!(
        plain.stats.kv_link_wait_s > 0.0,
        "shared NIC never queued at 6 req/s — the contention feed is empty"
    );

    let backend = ReschedBackend {
        monitor: MonitorConfig {
            window: 30.0,
            min_samples: 10,
            dwell: 3.0,
            // Rate drift suppressed so the KV signal is the only trigger on
            // this steady single-kind trace.
            rate_band: 1e9,
            kv_wait_threshold_s: 1e-6,
        },
        modeled_replan_s: 5.0,
    };
    let rep = dep.run(&backend, &trace).expect("resched runs");
    assert_eq!(
        rep.records.len() + rep.stats.unserved,
        trace.requests.len(),
        "closed loop lost requests"
    );

    let kv_drifts: Vec<&AuditRecord> = rep
        .audit
        .iter()
        .filter(|r| matches!(r, AuditRecord::Drift { kind, .. } if kind == "kv"))
        .collect();
    assert!(
        !kv_drifts.is_empty(),
        "KvContention never fired: audit = {:?}",
        rep.audit
            .iter()
            .filter(|r| !matches!(r, AuditRecord::Candidate { .. }))
            .collect::<Vec<_>>()
    );
    for d in &kv_drifts {
        let AuditRecord::Drift { mean_kv_wait_s, .. } = d else { unreachable!() };
        assert!(*mean_kv_wait_s > 0.0, "KV drift fired with zero observed wait");
    }
    // Every drift is explained: a Replan verdict follows it, and an
    // audit-on re-plan records the candidates it weighed.
    assert!(
        rep.audit.iter().any(|r| matches!(r, AuditRecord::Replan { .. })),
        "drift fired but no re-plan verdict was recorded"
    );
    assert!(
        rep.audit.iter().any(|r| matches!(r, AuditRecord::Candidate { .. })),
        "audit-on re-plan recorded no candidate evaluations"
    );
    // A migration-gate record prices any re-plan that produced a placement.
    if rep.audit.iter().any(|r| matches!(r, AuditRecord::Replan { accepted: true, .. })) {
        assert!(
            rep.audit.iter().any(
                |r| matches!(r, AuditRecord::MigrationGate { accepted: true, .. })
            ),
            "accepted re-plan without an accepting migration gate"
        );
    }
}

#[test]
fn drive_with_empty_kv_feed_is_exactly_drive() {
    // `drive_with_kv(.., &[])` must be byte-identical to the blind loop —
    // the invariant that keeps `ReschedBackend`'s default (infinite
    // threshold) behavior unchanged.
    let c = settings::case_study();
    let mut base = ScheduleOptions::new(WorkloadKind::Lphd);
    base.max_rounds = 4;
    base.force_k = Some(4);
    let incumbent = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let phases = [(WorkloadKind::Lphd, 3.0, 60.0), (WorkloadKind::Hpld, 3.0, 90.0)];
    let trace = Trace::phases(&phases, 6);
    let mcfg = MonitorConfig::case_study();
    let a = hexgen2::rescheduler::drive(&c, &OPT_30B, &incumbent, &trace, mcfg, &base, 10.0);
    let b = hexgen2::rescheduler::drive_with_kv(
        &c, &OPT_30B, &incumbent, &trace, mcfg, &base, 10.0, &[], None,
    );
    assert_eq!(a.events.len(), b.events.len());
    assert_eq!(a.switches.len(), b.switches.len());
    for (x, y) in a.switches.iter().zip(&b.switches) {
        assert_eq!(x.at, y.at);
        assert_eq!(x.delay, y.delay);
    }
    assert_eq!(a.audit.len(), b.audit.len());
}
