//! Genetic-algorithm scheduler baseline (§5.3): HexGen's population-based
//! search with merge / split / swap operations over GPU groupings, used for
//! the Fig. 10/11 convergence comparison. We keep the same evaluation
//! pipeline (strategy search + max-flow) so the comparison isolates the
//! *search* strategy, exactly as the paper does ("we replaced the group
//! generation step ... and the iterative refinement phases of our algorithm
//! with the genetic algorithm").

use std::time::Instant;

use crate::cluster::{Cluster, DeviceId};
use crate::model::LlmSpec;
use crate::util::rng::Rng;

use super::{
    task_for, ConvergencePoint, EvalCache, Placement, ScheduleOptions, ScheduleResult, SearchStats,
};

type Groups = Vec<Vec<DeviceId>>;

fn random_partition(n: usize, k: usize, rng: &mut Rng) -> Groups {
    loop {
        let mut groups: Groups = vec![Vec::new(); k];
        for d in 0..n {
            groups[rng.range(0, k)].push(d);
        }
        if groups.iter().all(|g| !g.is_empty()) {
            return groups;
        }
    }
}

/// One GA mutation: merge two groups then re-split randomly, or swap/move
/// devices between two groups (HexGen's merge/split/swap operators).
fn mutate(groups: &Groups, rng: &mut Rng) -> Groups {
    let k = groups.len();
    let mut g = groups.clone();
    match rng.range(0, 3) {
        0 if k >= 2 => {
            // merge + split: combine two groups, redistribute randomly.
            let a = rng.range(0, k);
            let mut b = rng.range(0, k);
            if a == b {
                b = (b + 1) % k;
            }
            let mut pool: Vec<DeviceId> = g[a].drain(..).collect();
            pool.extend(g[b].drain(..));
            rng.shuffle(&mut pool);
            let cut = rng.range(1, pool.len());
            g[a] = pool[..cut].to_vec();
            g[b] = pool[cut..].to_vec();
        }
        1 => {
            // swap
            let a = rng.range(0, k);
            let mut b = rng.range(0, k);
            if a == b {
                b = (b + 1) % k;
            }
            let ia = rng.range(0, g[a].len());
            let ib = rng.range(0, g[b].len());
            let tmp = g[a][ia];
            g[a][ia] = g[b][ib];
            g[b][ib] = tmp;
        }
        _ => {
            // move
            let a = rng.range(0, k);
            if g[a].len() > 1 {
                let b = (a + 1 + rng.range(0, k - 1)) % k;
                let ia = rng.range(0, g[a].len());
                let d = g[a].remove(ia);
                g[b].push(d);
            }
        }
    }
    g
}

/// Run the GA scheduler. Interface mirrors [`super::schedule`].
pub fn schedule_genetic(
    cluster: &Cluster,
    model: &LlmSpec,
    opts: &ScheduleOptions,
) -> Option<ScheduleResult> {
    let cache = if opts.use_eval_cache { EvalCache::new() } else { EvalCache::disabled() };
    schedule_genetic_with_cache(cluster, model, opts, &cache)
}

/// [`schedule_genetic`] against a caller-owned [`EvalCache`]. Fitness calls
/// route through the cache keyed by the canonical partition signature, so a
/// genome re-bred in a later generation (or an earlier GA/schedule run
/// sharing the cache) is scored for free instead of re-running the
/// strategy-search + max-flow pipeline — GA populations repeat partitions
/// heavily.
pub fn schedule_genetic_with_cache(
    cluster: &Cluster,
    model: &LlmSpec,
    opts: &ScheduleOptions,
    cache: &EvalCache,
) -> Option<ScheduleResult> {
    // hexcheck: allow(D2) -- wall-clock timing of the planner itself (ScheduleStats::elapsed); never feeds plan decisions
    let t0 = Instant::now();
    if opts.audit {
        cache.enable_audit();
    }
    let c0 = cache.counters();
    let task = task_for(opts.workload);
    let k = opts.force_k.unwrap_or_else(|| super::choose_k(cluster, model, &task));
    let mut rng = Rng::new(opts.seed ^ 0x6E6E);
    let mut explored: std::collections::HashSet<Vec<usize>> = std::collections::HashSet::new();

    const POP: usize = 12;
    const ELITE: usize = 4;

    let eval = |groups: &Groups,
                explored: &mut std::collections::HashSet<Vec<usize>>|
     -> Option<Placement> {
        explored.insert(super::partition_signature(groups));
        cache.evaluate(
            cluster,
            model,
            &task,
            opts.period,
            groups,
            opts.type_candidates,
            opts.objective,
            opts.kv_contention,
        )
    };

    // Initial population: random partitions (the GA baseline has no spectral
    // seed — that is the point of the comparison).
    let mut pop: Vec<(Groups, Option<Placement>)> = (0..POP)
        .map(|_| {
            let g = random_partition(cluster.n(), k, &mut rng);
            let p = eval(&g, &mut explored);
            (g, p)
        })
        .collect();

    // GA fitness is the same per-objective score the main scheduler ranks by
    // (the flow value under the paper-default throughput objective). The
    // neutral element must sort below every real score, including negative
    // MeanLatency scores.
    let fitness =
        |p: &Option<Placement>| p.as_ref().map(|x| x.objective_score).unwrap_or(f64::NEG_INFINITY);
    pop.sort_by(|a, b| fitness(&b.1).partial_cmp(&fitness(&a.1)).unwrap());

    let mut history = vec![ConvergencePoint {
        elapsed_s: t0.elapsed().as_secs_f64(),
        round: 0,
        tokens_per_s: pop[0].1.as_ref().map(|p| p.tokens_per_s).unwrap_or(0.0),
        score: fitness(&pop[0].1),
    }];

    let mut stall = 0;
    let mut rounds = 0;
    for round in 1..=opts.max_rounds {
        rounds = round;
        let best_before = fitness(&pop[0].1);
        // Children: mutate elites.
        let mut children: Vec<(Groups, Option<Placement>)> = Vec::new();
        while children.len() + ELITE < POP {
            let parent = &pop[rng.range(0, ELITE)].0;
            let child = mutate(parent, &mut rng);
            if child.iter().any(|g| g.is_empty()) {
                continue;
            }
            let p = eval(&child, &mut explored);
            children.push((child, p));
        }
        pop.truncate(ELITE);
        pop.extend(children);
        pop.sort_by(|a, b| fitness(&b.1).partial_cmp(&fitness(&a.1)).unwrap());
        history.push(ConvergencePoint {
            elapsed_s: t0.elapsed().as_secs_f64(),
            round,
            tokens_per_s: pop[0].1.as_ref().map(|p| p.tokens_per_s).unwrap_or(0.0),
            score: fitness(&pop[0].1),
        });
        if opts.objective.improves(fitness(&pop[0].1), best_before) {
            stall = 0;
        } else {
            stall += 1;
            if stall >= opts.patience {
                break;
            }
        }
    }

    let stats = SearchStats::delta(&c0, &cache.counters(), explored.len(), 1);
    let (_g, best) = pop.into_iter().next().unwrap();
    best.map(|placement| ScheduleResult {
        placement,
        history,
        rounds,
        elapsed_s: t0.elapsed().as_secs_f64(),
        stats,
        audit: cache.take_audit(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::workload::WorkloadKind;

    #[test]
    fn ga_finds_a_feasible_placement() {
        let c = settings::case_study();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 6;
        opts.patience = 3;
        opts.force_k = Some(4);
        let r = schedule_genetic(&c, &OPT_30B, &opts).expect("GA schedules");
        assert!(r.placement.tokens_per_s > 0.0);
        // Still a valid partition.
        let mut all: Vec<usize> =
            r.placement.groups.iter().flat_map(|g| g.devices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..c.n()).collect::<Vec<_>>());
    }

    #[test]
    fn repeated_ga_run_is_free_with_shared_cache() {
        // The §3.3 loop re-runs the GA per period; with a shared EvalCache
        // an identical re-run costs zero evaluations and lands on a
        // bit-identical plan.
        let c = settings::case_study();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 4;
        opts.patience = 2;
        opts.force_k = Some(4);
        let cache = EvalCache::new();
        let a = schedule_genetic_with_cache(&c, &OPT_30B, &opts, &cache).expect("GA schedules");
        assert!(a.stats.evals > 0);
        let b = schedule_genetic_with_cache(&c, &OPT_30B, &opts, &cache).expect("GA schedules");
        assert_eq!(b.stats.evals, 0, "identical GA re-run re-executed evaluations");
        assert_eq!(b.stats.eval_cache_hits, a.stats.evals + a.stats.eval_cache_hits);
        assert_eq!(format!("{:?}", a.placement), format!("{:?}", b.placement));
    }

    #[test]
    fn mutation_preserves_device_multiset() {
        let mut rng = Rng::new(5);
        let groups: Groups = vec![vec![0, 1, 2], vec![3, 4], vec![5]];
        for _ in 0..200 {
            let m = mutate(&groups, &mut rng);
            let mut all: Vec<usize> = m.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
        }
    }
}
