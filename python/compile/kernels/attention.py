"""Pallas flash-attention prefill kernel (TPU-shaped, interpret=True on CPU).

Hardware adaptation of the CUDA FlashAttention the paper's runtime uses
(DESIGN.md section "Hardware-Adaptation"): the CUDA threadblock-per-query-tile
with shared-memory K/V staging becomes a Pallas grid over
(batch*heads, query blocks) whose BlockSpecs express the HBM->VMEM schedule;
the online-softmax running (max, denominator, accumulator) live in kernel
registers/VMEM rather than CUDA registers, and the two matmuls (Q.K^T and
P.V) are MXU-shaped (tile sizes multiples of the 128-lane MXU where the model
dims allow).

The kernel MUST be lowered with interpret=True for the CPU PJRT runtime:
real TPU lowering emits a Mosaic custom-call the CPU plugin cannot execute.
Under interpret=True the pallas_call lowers to portable HLO (while-loops +
dots), so the identical module text runs in the Rust PJRT engine.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, seq_len):
    """Grid point = (q_block,). Online-softmax over K/V tiles, vectorized
    over all batch*head rows inside the kernel body.

    On a real TPU the grid would also span bh for cross-core parallelism
    (one MXU tile per (bh, q_block)); under interpret=True each grid point
    costs an interpreter dispatch, so bh is folded into the kernel as the
    leading vector axis — same math, ~100x fewer interpreted iterations
    (EXPERIMENTS.md §Perf L1).

    Refs (per grid point):
      len_ref: [BH]          int32 real sequence lengths.
      q_ref:   [BH, bq, Dh]  query tiles (VMEM).
      k_ref:   [BH, S, Dh]   full K rows (VMEM-staged per BlockSpec).
      v_ref:   [BH, S, Dh]   full V rows.
      o_ref:   [BH, bq, Dh]  output tiles.
    """
    bh, block_q, dh = q_ref.shape
    qi = pl.program_id(0)
    lengths = len_ref[...]  # [BH]

    q = q_ref[...] * (1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32)))
    row = qi * block_q + lax.iota(jnp.int32, block_q)  # [bq] query positions

    # Causal: the last query row of this tile attends up to position
    # qi*bq + bq - 1, so only ceil((qi+1)*bq / bk) K tiles contribute.
    num_kb = (qi * block_q + block_q + block_k - 1) // block_k
    num_kb = jnp.minimum(num_kb, (seq_len + block_k - 1) // block_k)

    def body(j, carry):
        m, l, acc = carry
        kb = pl.load(k_ref, (slice(None), pl.dslice(j * block_k, block_k), slice(None)))
        vb = pl.load(v_ref, (slice(None), pl.dslice(j * block_k, block_k), slice(None)))
        s = jnp.einsum("bqd,bkd->bqk", q, kb, preferred_element_type=jnp.float32)
        col = j * block_k + lax.iota(jnp.int32, block_k)
        mask = (col[None, None, :] <= row[None, :, None]) & (
            col[None, None, :] < lengths[:, None, None]
        )
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=2))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, :, None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=2)
        acc_new = acc * alpha[:, :, None] + jnp.einsum(
            "bqk,bkd->bqd", p, vb, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((bh, block_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bh, block_q), jnp.float32)
    acc0 = jnp.zeros((bh, block_q, dh), jnp.float32)
    m, l, acc = lax.fori_loop(0, num_kb, body, (m0, l0, acc0))

    out = acc / jnp.maximum(l, 1e-30)[:, :, None]
    # Zero rows past the real length (padding queries).
    out = jnp.where((row[None, :] < lengths[:, None])[:, :, None], out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def flash_prefill(q, k, v, lengths, *, block_q=64, block_k=64, interpret=True):
    """Causal flash attention over padded sequences.

    Args:
      q, k, v: [BH, S, Dh] float32.
      lengths: [BH] int32 real sequence lengths.
      block_q, block_k: tile sizes (clamped to S; S % block_q must be 0
        after clamping — callers use power-of-two S).

    Returns:
      [BH, S, Dh] float32, rows past `lengths` zeroed.
    """
    bh, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        raise ValueError(f"seq len {s} not divisible by blocks {block_q},{block_k}")
    grid = (s // block_q,)
    kernel = functools.partial(_flash_prefill_kernel, block_k=block_k, seq_len=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh,), lambda i: (0,)),
            pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
