//! §5.4-style rescheduling case study: serve a phased trace whose
//! prefill/decode mix shifts mid-run (e.g. LPHD → HPLD, possibly several
//! times), once with the static placement the §3 scheduler chose for the
//! opening mix, and once with the full online loop — drift detection →
//! warm-started re-plan → priced migration → mid-trace placement switch —
//! then report per-phase throughput and the warm-vs-cold re-plan
//! wall-clock. Oscillating traces exercise the hysteresis system-wide: the
//! switch count stays bounded by the number of real sustained shifts.
//!
//! Driven by `hexgen2 reschedule` and `benches/case_resched.rs`. The loop
//! itself is [`rescheduler::drive`]; generic deployments get the same
//! behaviour through [`deploy::ReschedBackend`](crate::deploy::ReschedBackend).
//! Switch execution happens in the unified simulation core
//! ([`simulator::simulate`](crate::simulator::simulate)), whose
//! quiesce/drain/activate path also accepts colocated epochs — see
//! `tests/sim_core.rs` for baseline-rescheduling scenarios.

use crate::cluster::Cluster;
use crate::model::LlmSpec;
use crate::rescheduler::{self, DriftEvent, MigrationPlan, MonitorConfig};
use crate::scheduler;
use crate::simulator::{run_disaggregated, run_disaggregated_with_resched, SimReport};
use crate::util::bench::Table;
use crate::workload::{Trace, WorkloadKind};

use super::ExpOpts;

/// Modeled online re-planning budget (simulated seconds between detection
/// and the switch landing); re-exported from the rescheduler subsystem.
pub use crate::rescheduler::MODELED_REPLAN_S;

/// Everything the case study measures.
pub struct ReschedCaseStudy {
    /// Per-phase throughput rows: phase, workload, window, static, resched.
    pub table: Table,
    /// First detected drift, if any.
    pub drift: Option<DriftEvent>,
    /// First re-plan's priced migration, if any.
    pub migration: Option<MigrationPlan>,
    /// Simulated time at which the first new placement was activated.
    pub switch_at: Option<f64>,
    /// Total drift events detected over the whole trace.
    pub n_events: usize,
    /// Approved placement switches (bounded by `n_events`; the hysteresis +
    /// net-benefit gate keep oscillating traces from thrashing).
    pub n_switches: usize,
    /// Warm-started re-plan wall-clock, seconds (0 when no drift fired).
    pub warm_replan_s: f64,
    /// Cold re-plan wall-clock on the same cluster/workload, for comparison.
    pub cold_replan_s: f64,
    /// Post-shift (final phase) throughput, static placement.
    pub static_post_tput: f64,
    /// Post-shift (final phase) throughput, with rescheduling.
    pub resched_post_tput: f64,
}

/// Default phased spec for a cluster: LPHD at 75% of the static placement's
/// estimated peak, shifting to HPLD at the same arrival rate (the mix —
/// not the load — drifts, as in the paper's case study). The rate estimate
/// uses a one-shot (no-refinement) schedule: it only needs a throughput
/// ballpark, and `case_resched` runs the full scheduler itself.
pub fn default_phases(
    cluster: &Cluster,
    model: &LlmSpec,
    opts: &ExpOpts,
) -> Option<Vec<(WorkloadKind, f64, f64)>> {
    let mut base = opts.sched_opts(WorkloadKind::Lphd);
    base.swap_mode = crate::scheduler::SwapMode::None;
    let peak = scheduler::schedule(cluster, model, &base)?.placement.tokens_per_s;
    let (_s_in, s_out) = WorkloadKind::Lphd.mean_lengths();
    let rate = (0.75 * peak / s_out).max(0.2);
    let (d1, d2) = if opts.quick { (180.0, 360.0) } else { (300.0, 600.0) };
    Some(vec![(WorkloadKind::Lphd, rate, d1), (WorkloadKind::Hpld, rate, d2)])
}

/// Run the case study over a phased spec (two or more phases; the loop
/// handles every sustained shift, not just the first). Returns None only
/// when the static scheduler cannot place the model on the cluster at all.
pub fn case_resched(
    cluster: &Cluster,
    model: &LlmSpec,
    spec: &[(WorkloadKind, f64, f64)],
    opts: &ExpOpts,
) -> Option<ReschedCaseStudy> {
    assert!(spec.len() >= 2, "a rescheduling case study needs at least two phases");
    let base = opts.sched_opts(spec[0].0);
    let static_p = scheduler::schedule(cluster, model, &base)?.placement;
    let trace = Trace::phases(spec, opts.seed.wrapping_add(41));
    let static_rep = run_disaggregated(cluster, model, &static_p, &trace);

    // The full online loop: sense every sustained drift, warm-start a
    // re-plan from the current incumbent, price each migration.
    let mcfg = MonitorConfig::case_study();
    let drive =
        rescheduler::drive(cluster, model, &static_p, &trace, mcfg, &base, MODELED_REPLAN_S);

    let resched_rep: SimReport = if drive.switches.is_empty() {
        static_rep.clone()
    } else {
        run_disaggregated_with_resched(cluster, model, &static_p, &drive.switches, &trace)
    };

    // Warm/cold re-plan wall-clock for the FIRST drift event (index-aligned
    // with `drift` below — outcomes[i] belongs to events[i], and a None
    // outcome means that event's re-plan found no placement).
    let first_out = drive.outcomes.first().and_then(|o| o.as_ref());
    let warm_replan_s = first_out.map(|o| o.result.elapsed_s).unwrap_or(0.0);
    let cold_replan_s = first_out
        .map(|o| {
            let mut cold = base.clone();
            cold.workload = o.to_kind;
            scheduler::schedule(cluster, model, &cold).map(|r| r.elapsed_s).unwrap_or(0.0)
        })
        .unwrap_or(0.0);

    // Per-phase throughput table.
    let mut bounds = vec![0.0];
    bounds.extend(Trace::phase_boundaries(spec));
    bounds.push(spec.iter().map(|&(_, _, d)| d).sum());
    let mut table =
        Table::new(&["phase", "workload", "window (s)", "static tok/s", "resched tok/s"]);
    let mut static_post_tput = 0.0;
    let mut resched_post_tput = 0.0;
    for (i, &(kind, _rate, _d)) in spec.iter().enumerate() {
        let (t0, t1) = (bounds[i], bounds[i + 1]);
        let s = static_rep.windowed(t0, t1).tokens_per_s();
        let r = resched_rep.windowed(t0, t1).tokens_per_s();
        if i == spec.len() - 1 {
            static_post_tput = s;
            resched_post_tput = r;
        }
        table.row(&[
            (i + 1).to_string(),
            kind.name().to_string(),
            format!("{t0:.0}-{t1:.0}"),
            format!("{s:.0}"),
            format!("{r:.0}"),
        ]);
    }

    Some(ReschedCaseStudy {
        table,
        drift: drive.events.first().copied(),
        migration: first_out.map(|o| o.migration),
        switch_at: drive.switches.first().map(|s| s.at + s.delay),
        n_events: drive.events.len(),
        n_switches: drive.switches.len(),
        warm_replan_s,
        cold_replan_s,
        static_post_tput,
        resched_post_tput,
    })
}

/// Human-readable summary lines (shared by the CLI and the bench).
pub fn print_summary(cs: &ReschedCaseStudy) {
    match &cs.drift {
        Some(e) => println!(
            "drift detected at t={:.1}s ({:?}); {} event(s), {} switch(es) over the trace",
            e.at, e.kind, cs.n_events, cs.n_switches
        ),
        None => println!("no drift detected: static placement kept"),
    }
    if let Some(m) = &cs.migration {
        println!(
            "migration: drain {:.2}s + transfer {:.2}s ({:.1} MiB KV) = {:.2}s stall; \
             gain {:.0} tokens/T vs {:.0} lost -> {}",
            m.drain_s,
            m.transfer_s,
            m.kv_bytes / (1u64 << 20) as f64,
            m.total_delay_s,
            m.gain_tokens,
            m.tokens_lost,
            if m.migrate { "MIGRATE" } else { "KEEP" }
        );
    }
    if let Some(at) = cs.switch_at {
        println!("new placement live at t={at:.1}s (simulated)");
    }
    if cs.warm_replan_s > 0.0 {
        println!(
            "re-plan wall-clock: warm {:.2}s vs cold {:.2}s ({:.1}x)",
            cs.warm_replan_s,
            cs.cold_replan_s,
            cs.cold_replan_s / cs.warm_replan_s
        );
    }
    println!(
        "post-shift phase: static {:.0} tok/s vs rescheduled {:.0} tok/s ({:+.0}%)",
        cs.static_post_tput,
        cs.resched_post_tput,
        if cs.static_post_tput > 0.0 {
            100.0 * (cs.resched_post_tput / cs.static_post_tput - 1.0)
        } else {
            0.0
        }
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;

    #[test]
    fn case_study_runs_and_detects_shift() {
        let c = settings::case_study();
        let opts = ExpOpts { quick: true, seed: 1 };
        let spec = [(WorkloadKind::Lphd, 3.0, 60.0), (WorkloadKind::Hpld, 3.0, 90.0)];
        let cs = case_resched(&c, &OPT_30B, &spec, &opts).expect("case study runs");
        assert_eq!(cs.table.rows_for_test().len(), 2);
        let e = cs.drift.expect("sustained LPHD->HPLD shift must be detected");
        assert!(e.at > 60.0 && e.at < 110.0, "drift at {:.1}", e.at);
        assert!(cs.warm_replan_s > 0.0, "no re-plan timed");
        assert!(cs.cold_replan_s > 0.0);
        assert!(cs.n_events >= 1);
        assert!(cs.n_switches <= cs.n_events);
        // The migration verdict exists and is internally consistent.
        let m = cs.migration.expect("migration priced");
        if m.migrate {
            assert!(m.gain_tokens > m.tokens_lost);
            assert!(cs.switch_at.is_some());
        }
        // Throughput columns are populated.
        assert!(cs.static_post_tput > 0.0);
        assert!(cs.resched_post_tput > 0.0);
    }

    #[test]
    fn oscillating_case_study_bounds_switch_count() {
        // Four phases, three sustained shifts: the monitor may fire at most
        // once per shift, and every approved switch must hold the
        // net-benefit gate — the system never thrashes.
        let c = settings::case_study();
        let opts = ExpOpts { quick: true, seed: 2 };
        let spec = [
            (WorkloadKind::Lphd, 3.0, 70.0),
            (WorkloadKind::Hpld, 3.0, 70.0),
            (WorkloadKind::Lphd, 3.0, 70.0),
            (WorkloadKind::Hpld, 3.0, 70.0),
        ];
        let cs = case_resched(&c, &OPT_30B, &spec, &opts).expect("oscillating case study runs");
        assert_eq!(cs.table.rows_for_test().len(), 4);
        assert!(cs.n_events >= 1, "no shift detected on an oscillating trace");
        assert!(cs.n_events <= 3, "hysteresis broke: {} events for 3 shifts", cs.n_events);
        assert!(cs.n_switches <= cs.n_events);
        assert!(cs.static_post_tput > 0.0 && cs.resched_post_tput > 0.0);
    }

    #[test]
    fn default_phases_shift_mix_not_rate() {
        let c = settings::case_study();
        let opts = ExpOpts { quick: true, seed: 2 };
        let spec = default_phases(&c, &OPT_30B, &opts).expect("default spec");
        assert_eq!(spec.len(), 2);
        assert_eq!(spec[0].0, WorkloadKind::Lphd);
        assert_eq!(spec[1].0, WorkloadKind::Hpld);
        assert_eq!(spec[0].1, spec[1].1, "rate must stay constant");
        assert!(spec[0].1 > 0.0);
    }
}
