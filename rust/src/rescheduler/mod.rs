//! Online elastic rescheduling: close the loop from observed traffic back
//! into the §3 scheduler.
//!
//! HexGen-2 schedules once per period T (§3.3), and the paper's §5.4 case
//! study shows the optimal placement *flips* as the prefill/decode mix
//! shifts — a static placement leaves throughput on the table the moment
//! traffic drifts. This subsystem supplies the three pieces of the loop:
//!
//! - [`monitor`]: windowed request statistics and a hysteresis
//!   [`DriftDetector`](monitor::DriftDetector) that fires exactly once per
//!   sustained shift of the effective workload class or arrival rate.
//! - [`warmstart`]: re-runs [`scheduler::schedule`] seeded from the incumbent
//!   group partition (`ScheduleOptions::initial_groups`), guaranteeing the
//!   re-plan never lands below the incumbent under the new workload while
//!   converging in a fraction of the cold-start rounds.
//! - [`migration`]: prices the switch (per-group drain time + KV-cache bytes
//!   over the cluster bandwidth matrix) and approves it only when the
//!   projected gain amortizes the cost within one period T.
//!
//! The unified simulation core executes approved switches via
//! [`simulator::run_disaggregated_with_resched`](crate::simulator::run_disaggregated_with_resched)
//! (a wrapper over [`simulator::simulate`](crate::simulator::simulate));
//! because the quiesce/drain/activate machinery lives in the core rather
//! than a disagg-only loop, [`PlacementSwitch`]es generalize to
//! [`SwitchSpec`](crate::simulator::SwitchSpec)s whose target epoch may be
//! colocated — rescheduling case studies run against the baselines too.
//! `experiments::resched` and the `hexgen2 reschedule` CLI subcommand drive
//! §5.4-style case studies end to end.

pub mod migration;
pub mod monitor;
pub mod warmstart;

pub use migration::MigrationPlan;
pub use monitor::{DriftDetector, DriftEvent, DriftKind, MonitorConfig, WindowStats, WorkloadMonitor};

use crate::cluster::Cluster;
use crate::model::LlmSpec;
use crate::scheduler::{self, Placement, ScheduleOptions, ScheduleResult};
use crate::simulator::PlacementSwitch;
use crate::telemetry::AuditRecord;
use crate::workload::{Trace, WorkloadKind};

/// Modeled online re-planning budget, simulated seconds: an approved switch
/// lands this long after its drift was detected. A fixed model — not the
/// host's measured wall-clock — keeps seeded simulations deterministic
/// across machines; the *measured* warm/cold re-plan times are reported
/// separately by the case-study harness.
pub const MODELED_REPLAN_S: f64 = 10.0;

/// Streaming sensor: one monitor + detector pair fed per-request.
pub struct Rescheduler {
    monitor: WorkloadMonitor,
    detector: DriftDetector,
}

impl Rescheduler {
    pub fn new(cfg: MonitorConfig) -> Rescheduler {
        Rescheduler { monitor: WorkloadMonitor::new(cfg), detector: DriftDetector::new(cfg) }
    }

    /// Feed one request observation; returns a drift event when a sustained
    /// shift has just been detected.
    pub fn observe(&mut self, t: f64, input_len: usize, output_len: usize) -> Option<DriftEvent> {
        self.monitor.observe(t, input_len, output_len);
        let stats = self.monitor.stats(t)?;
        self.detector.update(&stats)
    }

    /// Feed one KV-transfer observation (the per-transfer queue wait the
    /// transfer engine's ledger measured): with
    /// [`MonitorConfig::kv_wait_threshold_s`] set, sustained congestion
    /// fires a [`DriftKind::KvContention`] event on a later [`observe`].
    pub fn observe_kv(&mut self, t: f64, wait_s: f64) {
        self.monitor.observe_kv(t, wait_s);
    }

    pub fn baseline(&self) -> Option<(WorkloadKind, f64)> {
        self.detector.baseline()
    }
}

/// Outcome of reacting to one drift event: the warm re-plan and the priced
/// migration decision.
#[derive(Clone)]
pub struct ReplanOutcome {
    pub to_kind: WorkloadKind,
    pub result: ScheduleResult,
    pub migration: MigrationPlan,
    /// The incumbent's predicted NIC busy fraction the migration was priced
    /// under (0.0 when contention-aware planning is off) — recorded so the
    /// decision audit can show *why* the transfer was priced as it was.
    pub nic_util: f64,
}

/// React to a drift event: warm-start a re-plan for the observed workload
/// and price the migration, both under `base.objective`. The caller
/// switches placements only when `outcome.migration.migrate` holds.
pub fn replan_for_drift(
    cluster: &Cluster,
    model: &LlmSpec,
    incumbent: &Placement,
    event: &DriftEvent,
    base: &ScheduleOptions,
) -> Option<ReplanOutcome> {
    replan_for_drift_with_cache(cluster, model, incumbent, event, base, &scheduler::EvalCache::new())
}

/// [`replan_for_drift`] against a caller-owned [`EvalCache`]: the closed
/// loop re-plans on every sustained drift, and oscillating traffic revisits
/// earlier workloads — a shared cache makes those re-plans mostly memo
/// hits. Never changes the chosen plan.
pub fn replan_for_drift_with_cache(
    cluster: &Cluster,
    model: &LlmSpec,
    incumbent: &Placement,
    event: &DriftEvent,
    base: &ScheduleOptions,
    cache: &scheduler::EvalCache,
) -> Option<ReplanOutcome> {
    let to_kind = event.stats.effective_kind();
    let mut opts = base.clone();
    opts.workload = to_kind;
    let result = warmstart::replan_with_cache(cluster, model, &opts, incumbent, cache)?;
    let task = scheduler::task_for(to_kind);
    // Contention-aware planning also prices the migration under load: the
    // incumbent's predicted NIC busy fraction derates the bandwidth its
    // in-flight KV moves would get (migration bytes share the fabric with
    // serving traffic).
    let nic_util = opts
        .kv_contention
        .map(|link| scheduler::objective::kv_nic_utilization(incumbent, link))
        .unwrap_or(0.0);
    let migration = migration::plan_under_load(
        cluster,
        model,
        incumbent,
        &result.placement,
        &task,
        opts.period,
        opts.objective,
        nic_util,
    );
    Some(ReplanOutcome { to_kind, result, migration, nic_util })
}

/// Everything one closed-loop pass over a trace produced: the drift events
/// in detection order, the re-plan outcome attempted for each, and the
/// *approved* placement switches — sorted, non-overlapping, and ready for
/// [`run_disaggregated_with_resched`](crate::simulator::run_disaggregated_with_resched).
pub struct DriveOutcome {
    pub events: Vec<DriftEvent>,
    /// One entry per event: `None` when the warm re-plan found no placement.
    pub outcomes: Vec<Option<ReplanOutcome>>,
    pub switches: Vec<PlacementSwitch>,
    /// Flight-recorder decision audit of the whole closed loop, in decision
    /// order: for each drift, a [`AuditRecord::Drift`] record, the re-plan's
    /// per-candidate records (when `base.audit` is on), the priced
    /// [`AuditRecord::MigrationGate`] verdict, and the
    /// [`AuditRecord::Replan`] summary (`--audit`; DESIGN.md §12).
    pub audit: Vec<AuditRecord>,
}

/// Run the full §3.3 online loop over a trace's arrival stream: sense every
/// sustained drift (not just the first), warm-start a re-plan from the
/// *current* incumbent, price each migration, and emit the approved
/// switches. Handles oscillating traces: after an approved switch the new
/// placement becomes the incumbent, and the hysteresis detector re-baselines,
/// so the switch count is bounded by the number of real sustained shifts.
pub fn drive(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &Placement,
    trace: &Trace,
    mcfg: MonitorConfig,
    base: &ScheduleOptions,
    modeled_replan_s: f64,
) -> DriveOutcome {
    drive_with_kv(cluster, model, initial, trace, mcfg, base, modeled_replan_s, &[], None)
}

/// Coarse drift-blame default when no attribution report is on hand: the
/// component family the drift kind itself implicates (DESIGN.md §16).
fn default_blame(kind: &DriftKind) -> &'static str {
    match kind {
        DriftKind::Workload { .. } => "mix",
        DriftKind::Rate { .. } => "rate",
        DriftKind::KvContention { .. } => "kv-transfer",
    }
}

/// [`drive`] with a KV-congestion feed: `kv_feed` is a time-ordered list of
/// `(t, wait_s)` per-transfer queue waits — typically the previous epoch's
/// transfer-engine ledger, replayed from a flight-recorder trace's
/// `KvEnqueue` events ([`deploy::ReschedBackend`](crate::deploy)). Entries
/// are streamed into [`Rescheduler::observe_kv`] in arrival order so, with
/// [`MonitorConfig::kv_wait_threshold_s`] finite, sustained fabric
/// congestion fires [`DriftKind::KvContention`] and gets a (preferably
/// contention-aware) re-plan even when the request mix is steady. An empty
/// feed is exactly [`drive`].
///
/// `blame` is optional attribution context for the drift audit records:
/// when the caller ran critical-path attribution over the previous epoch
/// ([`crate::telemetry::AttrReport::dominant_name`]), every
/// [`AuditRecord::Drift`] this pass emits names that component; otherwise
/// the record falls back to a coarse default derived from the drift kind.
#[allow(clippy::too_many_arguments)]
pub fn drive_with_kv(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &Placement,
    trace: &Trace,
    mcfg: MonitorConfig,
    base: &ScheduleOptions,
    modeled_replan_s: f64,
    kv_feed: &[(f64, f64)],
    blame: Option<&str>,
) -> DriveOutcome {
    let mut sensor = Rescheduler::new(mcfg);
    let mut incumbent = initial.clone();
    let mut events = Vec::new();
    let mut outcomes = Vec::new();
    let mut switches: Vec<PlacementSwitch> = Vec::new();
    let mut audit: Vec<AuditRecord> = Vec::new();
    // One evaluation cache for the whole closed loop: every re-plan seeds
    // from some recent incumbent and oscillating traffic revisits earlier
    // workloads, so most re-plan evaluations are repeats of work already
    // done — served from the memo instead of re-executed. Honors the
    // caller's `use_eval_cache` (the perf harness's uncached A/B baseline).
    let cache = if base.use_eval_cache {
        scheduler::EvalCache::new()
    } else {
        scheduler::EvalCache::disabled()
    };
    // Two-pointer merge: all KV observations up to each arrival are fed
    // before the request itself (both streams are time-ordered).
    let mut kv_i = 0usize;
    for r in &trace.requests {
        // hexcheck: allow(P1) -- short-circuit && bounds kv_i < kv_feed.len() before indexing
        while kv_i < kv_feed.len() && kv_feed[kv_i].0 <= r.arrival {
            let (t, w) = kv_feed[kv_i]; // hexcheck: allow(P1) -- guarded by the while condition on this index
            sensor.observe_kv(t, w);
            kv_i += 1;
        }
        let Some(e) = sensor.observe(r.arrival, r.input_len, r.output_len) else { continue };
        events.push(e);
        audit.push(AuditRecord::Drift {
            at: e.at,
            kind: match e.kind {
                DriftKind::Workload { .. } => "workload".to_string(),
                DriftKind::Rate { .. } => "rate".to_string(),
                DriftKind::KvContention { .. } => "kv".to_string(),
            },
            rate: e.stats.rate,
            mean_input: e.stats.mean_input,
            mean_output: e.stats.mean_output,
            n: e.stats.n as u32,
            mean_kv_wait_s: e.stats.mean_kv_wait_s,
            blamed: blame.unwrap_or_else(|| default_blame(&e.kind)).to_string(),
        });
        let out = replan_for_drift_with_cache(cluster, model, &incumbent, &e, base, &cache);
        if let Some(o) = &out {
            audit.extend(o.result.audit.iter().cloned());
            audit.push(AuditRecord::MigrationGate {
                at: e.at,
                nic_util: o.nic_util,
                drain_s: o.migration.drain_s,
                kv_bytes: o.migration.kv_bytes,
                transfer_s: o.migration.transfer_s,
                total_delay_s: o.migration.total_delay_s,
                tokens_lost: o.migration.tokens_lost,
                gain_tokens: o.migration.gain_tokens,
                accepted: o.migration.migrate,
            });
            audit.push(AuditRecord::Replan {
                at: e.at,
                to: format!("{:?}", o.to_kind),
                accepted: o.migration.migrate,
            });
            if o.migration.migrate {
                // The switch lands after the modeled re-planning budget, and
                // never before the previous switch has fully activated (the
                // simulator requires non-overlapping switches).
                let floor = switches.last().map(|s| s.at + s.delay).unwrap_or(0.0);
                let at = (e.at + modeled_replan_s).max(floor);
                incumbent = o.result.placement.clone();
                switches.push(PlacementSwitch {
                    at,
                    delay: o.migration.total_delay_s,
                    placement: o.result.placement.clone(),
                    workload: Some(o.to_kind),
                });
            }
        } else {
            audit.push(AuditRecord::Replan {
                at: e.at,
                to: format!("{:?}", e.stats.effective_kind()),
                accepted: false,
            });
        }
        outcomes.push(out);
    }
    DriveOutcome { events, outcomes, switches, audit }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::workload::Trace;

    #[test]
    fn end_to_end_drift_to_replan() {
        // Phased LPHD→HPLD trace: the sensor fires once, the warm re-plan
        // for the new mix is at least as good as the incumbent evaluated
        // under it, and the migration verdict is internally consistent.
        let c = settings::case_study();
        let mut base = ScheduleOptions::new(WorkloadKind::Lphd);
        base.max_rounds = 6;
        base.force_k = Some(4);
        let incumbent = scheduler::schedule(&c, &OPT_30B, &base).unwrap().placement;

        let spec = [(WorkloadKind::Lphd, 4.0, 90.0), (WorkloadKind::Hpld, 4.0, 90.0)];
        let trace = Trace::phases(&spec, 3);
        let cfg = MonitorConfig::case_study();
        let mut rs = Rescheduler::new(cfg);
        let mut events = Vec::new();
        for r in &trace.requests {
            if let Some(e) = rs.observe(r.arrival, r.input_len, r.output_len) {
                events.push(e);
            }
        }
        assert_eq!(events.len(), 1, "expected exactly one drift event: {events:?}");
        let outcome = replan_for_drift(&c, &OPT_30B, &incumbent, &events[0], &base)
            .expect("replan succeeds");
        assert_eq!(outcome.to_kind, WorkloadKind::Hpld);
        assert!(outcome.result.placement.tokens_per_s > 0.0);
        if outcome.migration.migrate {
            assert!(outcome.migration.gain_tokens > outcome.migration.tokens_lost);
        }
    }
}
