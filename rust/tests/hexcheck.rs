//! Self-check: `hexcheck` must run clean over this repository's own
//! source tree (DESIGN.md §13).
//!
//! "Clean" means: no deny findings, no ratchet bucket above the checked-in
//! baseline, no malformed suppressions, and no stale (unused) allows. This
//! is the same gate CI applies via `hexgen2 check --json`; keeping it in
//! the test suite means `cargo test` catches a regression before the CI
//! job does, and that the baseline file can never drift out of sync with
//! the tree unnoticed.

use std::path::Path;

use hexgen2::analysis::{self, baseline::Baseline, lexer, lockorder};

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn run_check() -> (analysis::Report, Baseline) {
    let files = analysis::load_tree(&src_root()).expect("walk rust/src");
    assert!(files.len() > 20, "expected the full source tree, got {} files", files.len());
    let report = analysis::check_files(&files);
    let base_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("hexcheck-baseline.json");
    let text = std::fs::read_to_string(&base_path)
        .unwrap_or_else(|e| panic!("read {}: {e}", base_path.display()));
    let baseline = Baseline::parse(&text).expect("baseline parses");
    (report, baseline)
}

#[test]
fn repo_gates_clean_against_baseline() {
    let (report, baseline) = run_check();
    let gate = analysis::baseline::gate(&report.findings, &baseline);
    assert!(
        gate.ok(),
        "hexcheck gate failed — fix the finding or (with a written reason) \
         suppress it; never raise the baseline:\n{:#?}\nfindings:\n{}",
        gate.failures,
        report
            .findings
            .iter()
            .map(|f| format!("  {} {}:{} {}", f.rule, f.file, f.line, f.msg))
            .collect::<Vec<_>>()
            .join("\n"),
    );
}

#[test]
fn no_deny_findings_survive_suppression() {
    let (report, _) = run_check();
    let deny: Vec<_> = report
        .findings
        .iter()
        .filter(|f| analysis::baseline::is_deny(&f.rule, &f.module))
        .collect();
    assert!(deny.is_empty(), "deny findings in tree: {deny:#?}");
}

#[test]
fn no_malformed_or_stale_allows() {
    let (report, _) = run_check();
    let a0: Vec<_> = report.findings.iter().filter(|f| f.rule == "A0").collect();
    assert!(a0.is_empty(), "malformed suppressions: {a0:#?}");
    assert!(
        report.unused_allows.is_empty(),
        "stale allows (delete them): {:#?}",
        report.unused_allows
    );
}

#[test]
fn every_suppression_has_a_written_reason() {
    let (report, _) = run_check();
    for s in &report.suppressed {
        assert!(
            s.reason.trim().len() >= 10,
            "suppression at {}:{} has no substantive reason: {:?}",
            s.finding.file,
            s.finding.line,
            s.reason
        );
    }
}

#[test]
fn lock_rank_table_matches_real_mutex_sites() {
    // Every declared lock must still exist at its declared site — a rank
    // table entry pointing at deleted code is as stale as a bad baseline.
    let files = analysis::load_tree(&src_root()).expect("walk rust/src");
    for &(file, name, _rank) in lockorder::LOCK_RANKS {
        let f = files
            .iter()
            .find(|f| f.path == file)
            .unwrap_or_else(|| panic!("lock rank table names missing file {file}"));
        let decls = lockorder::lock_decls(&lexer::clean(&f.src));
        assert!(
            decls.iter().any(|(_, d)| d == name),
            "lock rank table: no Mutex/RwLock field `{name}` declared in {file} (found {decls:?})"
        );
    }
    // And the one real nesting the repo has today must be visible to the
    // analysis: EvalCache::bind_owner acquires `map` while holding `owner`.
    let (report, _) = run_check();
    assert!(
        report
            .lock_edges
            .iter()
            .any(|e| e.held == "owner" && e.acquired == "map" && e.file.ends_with("evalcache.rs")),
        "expected the owner->map edge in scheduler/evalcache.rs, got {:#?}",
        report.lock_edges
    );
}
