//! Replica workers: OS threads owning their own PJRT runtime (the handles
//! are not Send), connected by channels. Prefill workers batch incoming
//! requests, run the compiled prefill module, extract each request's KV
//! column, and ship it *directly* to a decode worker (the coordinator is not
//! on the KV path, matching §4's NCCL-SendRecv design). Decode workers run
//! continuous batching over slot-managed caches.
//!
//! KV routing and pacing go through the same
//! [`TransferScheduler`](crate::kvtransfer::TransferScheduler) the
//! simulator uses: every prefill worker enqueues against one shared,
//! coordinator-owned scheduler, so route deficits are cluster-wide (not
//! per-worker) and a throttled shared NIC queues transfers from *all*
//! workers on one busy-until reservation instead of each worker sleeping
//! blindly.

use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::kvtransfer::TransferScheduler;

use crate::runtime::{argmax_rows, ModelRuntime};

use super::kvcache::KvSlots;

/// A request as the live coordinator sees it.
#[derive(Clone, Debug)]
pub struct LiveRequest {
    pub id: usize,
    pub tokens: Vec<i32>,
    pub output_len: usize,
}

/// KV transfer payload: prefill → decode (per-request cache column).
pub struct KvPacket {
    pub req: LiveRequest,
    pub first_token: i32,
    /// [L, S_max, H] row-major.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub dispatched_at: Instant,
    pub prefill_done_at: Instant,
}

/// Completion record sent back to the coordinator.
#[derive(Clone, Debug)]
pub struct Completion {
    pub req_id: usize,
    /// All generated tokens (first token from prefill + decode steps).
    pub generated: Vec<i32>,
    pub dispatched_at: Instant,
    pub prefill_done_at: Instant,
    pub done_at: Instant,
    pub kv_bytes: usize,
}

pub enum PrefillMsg {
    Req(LiveRequest, Instant),
    Stop,
}

pub enum DecodeMsg {
    Kv(KvPacket),
    Stop,
}

/// Simulated-bandwidth throttle for KV transfers (models the heterogeneous
/// links of the paper's settings on a single host). None = full speed.
#[derive(Clone, Copy, Debug)]
pub struct KvThrottle {
    pub bytes_per_s: f64,
}

/// Prefill worker main loop. Each finished request's KV packet is routed
/// and paced by the shared [`TransferScheduler`] (`kv`): the scheduler
/// picks the decode destination (flow-proportional deficit, §3.3, with
/// cluster-wide deficit counters) and reserves the link; the worker sleeps
/// out the reserved window before handing the packet over. `t0` is the
/// shared clock anchor that converts wall time to the scheduler's f64
/// seconds.
#[allow(clippy::too_many_arguments)]
pub fn prefill_worker(
    worker_id: usize,
    rt: ModelRuntime,
    rx: Receiver<PrefillMsg>,
    decode_txs: Vec<Sender<DecodeMsg>>,
    kv: Arc<Mutex<TransferScheduler>>,
    t0: Instant,
    throttle: Option<KvThrottle>,
) -> Result<usize> {
    let variants = rt.prefill_variants();
    let max_batch = variants.iter().map(|&(b, _)| b).max().unwrap_or(1);
    let cands: Vec<usize> = (0..decode_txs.len()).collect();
    let mut queue: Vec<(LiveRequest, Instant)> = Vec::new();
    let mut processed = 0usize;
    let mut stopping = false;

    loop {
        // Blocking receive when idle; drain opportunistically otherwise.
        if queue.is_empty() && !stopping {
            match rx.recv() {
                Ok(PrefillMsg::Req(r, t)) => queue.push((r, t)),
                Ok(PrefillMsg::Stop) | Err(_) => stopping = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(PrefillMsg::Req(r, t)) => queue.push((r, t)),
                Ok(PrefillMsg::Stop) => {
                    stopping = true;
                    break;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
        }
        if queue.is_empty() {
            if stopping {
                return Ok(processed);
            }
            continue;
        }

        // Batch: take up to max_batch requests, pad to the smallest variant
        // covering the longest prompt in the batch.
        let take = queue.len().min(max_batch);
        let batch_items: Vec<(LiveRequest, Instant)> = queue.drain(..take).collect();
        let longest = batch_items.iter().map(|(r, _)| r.tokens.len()).max().unwrap();
        let (vb, vs) = rt
            .select_prefill_variant(batch_items.len(), longest)
            .unwrap_or_else(|| panic!("prefill worker {worker_id}: no variant for b{} s{longest}", batch_items.len()));
        let mut tokens = vec![0i32; vb * vs];
        let mut lengths = vec![1i32; vb];
        for (i, (r, _)) in batch_items.iter().enumerate() {
            tokens[i * vs..i * vs + r.tokens.len()].copy_from_slice(&r.tokens);
            lengths[i] = r.tokens.len() as i32;
        }
        let out = rt.prefill(vb, vs, &tokens, &lengths)?;
        // hexcheck: allow(D2) -- live-serving latency measurement (TTFT telemetry); this module never runs inside the deterministic simulator
        let done = Instant::now();
        let first = argmax_rows(&out.logits, rt.vocab());
        let dims = rt.manifest.cache_dims(vb);

        for (i, (r, dispatched_at)) in batch_items.into_iter().enumerate() {
            let k = KvSlots::extract_request(&out.k_cache, dims, i);
            let v = KvSlots::extract_request(&out.v_cache, dims, i);
            let bytes = ((k.len() + v.len()) * 4) as f64;
            // Transmission seconds under the (optional) bandwidth throttle;
            // an unthrottled link transfers "instantly" and the scheduler
            // degenerates to pure routing.
            let xfer_s = throttle.map(|t| bytes / t.bytes_per_s).unwrap_or(0.0);
            let now = t0.elapsed().as_secs_f64();
            let transfer = {
                let mut sched =
                    kv.lock().map_err(|_| anyhow!("transfer scheduler mutex poisoned"))?;
                sched.enqueue(worker_id, bytes, now, 0.0, &cands, |_| xfer_s)
            };
            // Pace the transfer to its reserved window: queueing behind
            // other workers' reservations shows up here as extra sleep.
            let delay = transfer.done - t0.elapsed().as_secs_f64();
            if delay > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(delay));
            }
            {
                let mut sched =
                    kv.lock().map_err(|_| anyhow!("transfer scheduler mutex poisoned"))?;
                sched.complete(worker_id, transfer.dst);
            }
            decode_txs[transfer.dst]
                .send(DecodeMsg::Kv(KvPacket {
                    first_token: first[i],
                    req: r,
                    k,
                    v,
                    dispatched_at,
                    prefill_done_at: done,
                }))
                .ok();
            processed += 1;
        }
    }
}

struct Slot {
    req: LiveRequest,
    slot: usize,
    generated: Vec<i32>,
    pos: i32,
    dispatched_at: Instant,
    prefill_done_at: Instant,
    kv_bytes: usize,
}

/// Decode worker main loop: continuous batching over slot-managed caches.
pub fn decode_worker(
    _worker_id: usize,
    rt: ModelRuntime,
    rx: Receiver<DecodeMsg>,
    completions: Sender<Completion>,
) -> Result<usize> {
    let batch = *rt.decode_variants().last().expect("no decode variants");
    let dims = rt.manifest.cache_dims(batch);
    let s_max = rt.manifest.config.max_seq;
    let mut slots = KvSlots::new(dims);
    let mut running: Vec<Slot> = Vec::new();
    let mut waiting: Vec<KvPacket> = Vec::new();
    let mut done = 0usize;
    let mut stopping = false;

    loop {
        // Admission: blocking when idle, drain otherwise.
        if running.is_empty() && waiting.is_empty() && !stopping {
            match rx.recv() {
                Ok(DecodeMsg::Kv(p)) => waiting.push(p),
                Ok(DecodeMsg::Stop) | Err(_) => stopping = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(DecodeMsg::Kv(p)) => waiting.push(p),
                Ok(DecodeMsg::Stop) => {
                    stopping = true;
                    break;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
        }
        // Continuous batching: admit while slots free.
        while !slots.is_full() && !waiting.is_empty() {
            let p = waiting.remove(0);
            let slot = slots.alloc().unwrap();
            let kv_bytes = (p.k.len() + p.v.len()) * 4;
            slots.insert(slot, &p.k, &p.v);
            running.push(Slot {
                slot,
                generated: vec![p.first_token],
                pos: p.req.tokens.len() as i32,
                req: p.req,
                dispatched_at: p.dispatched_at,
                prefill_done_at: p.prefill_done_at,
                kv_bytes,
            });
        }
        if running.is_empty() {
            if stopping && waiting.is_empty() {
                return Ok(done);
            }
            continue;
        }

        // One decode step for the whole batch (empty slots carry dummies).
        let mut token = vec![0i32; batch];
        let mut pos = vec![0i32; batch];
        for s in &running {
            token[s.slot] = *s.generated.last().unwrap();
            pos[s.slot] = s.pos;
        }
        let out = rt.decode_step(batch, &token, &pos, slots.k(), slots.v())?;
        slots.update(out.k_cache, out.v_cache);
        let next = argmax_rows(&out.logits, rt.vocab());
        // hexcheck: allow(D2) -- live-serving latency measurement (per-token telemetry); this module never runs inside the deterministic simulator
        let now = Instant::now();

        let mut finished: Vec<usize> = Vec::new();
        for (i, s) in running.iter_mut().enumerate() {
            s.generated.push(next[s.slot]);
            s.pos += 1;
            let budget_hit = (s.pos as usize) >= s_max - 1;
            if s.generated.len() >= s.req.output_len || budget_hit {
                finished.push(i);
            }
        }
        for &i in finished.iter().rev() {
            let s = running.swap_remove(i);
            slots.free(s.slot);
            completions
                .send(Completion {
                    req_id: s.req.id,
                    generated: s.generated,
                    dispatched_at: s.dispatched_at,
                    prefill_done_at: s.prefill_done_at,
                    done_at: now,
                    kv_bytes: s.kv_bytes,
                })
                .ok();
            done += 1;
        }
    }
}
