//! Bench: regenerate Appendix D (chunked prefill vs plain colocation).
use hexgen2::experiments::{tables, ExpOpts};
use hexgen2::model::OPT_30B;

fn main() {
    tables::appd_chunked_prefill(&OPT_30B, &ExpOpts::from_env())
        .print("Appendix D: chunked prefill vs plain colocation (OPT-30B)");
}
