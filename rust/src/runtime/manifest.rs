//! AOT artifact manifest: the ABI between `python/compile/aot.py` and the
//! Rust runtime. Parsed with the in-tree JSON module.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    /// "f32" or "s32".
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModuleMeta {
    pub name: String,
    /// "prefill" or "decode".
    pub kind: String,
    pub batch: usize,
    pub seq: usize,
    pub file: String,
    pub extra_inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset into the params blob.
    pub offset: usize,
    pub elems: usize,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfigMeta {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub config: ModelConfigMeta,
    pub params_file: String,
    pub params_bytes: usize,
    pub params: Vec<ParamMeta>,
    pub modules: Vec<ModuleMeta>,
}

impl ModelManifest {
    pub fn prefill_modules(&self) -> impl Iterator<Item = &ModuleMeta> {
        self.modules.iter().filter(|m| m.kind == "prefill")
    }

    pub fn decode_modules(&self) -> impl Iterator<Item = &ModuleMeta> {
        self.modules.iter().filter(|m| m.kind == "decode")
    }

    /// KV-cache dims [L, B, S_max, H] for a given batch.
    pub fn cache_dims(&self, batch: usize) -> [usize; 4] {
        [self.config.n_layers, batch, self.config.max_seq, self.config.d_model]
    }
}

fn tensor(j: &Json) -> Result<TensorMeta> {
    Ok(TensorMeta {
        name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
        dtype: j.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor missing shape"))?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect(),
    })
}

/// Load `<dir>/manifest.json` and return per-model manifests.
pub fn load_manifests(dir: &Path) -> Result<BTreeMap<String, ModelManifest>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
    if j.get("format").and_then(Json::as_usize) != Some(1) {
        bail!("unsupported manifest format");
    }
    let models = j.get("models").and_then(Json::as_obj).ok_or_else(|| anyhow!("no models"))?;
    let mut out = BTreeMap::new();
    for (name, m) in models {
        let cfg = m.get("config").ok_or_else(|| anyhow!("{name}: no config"))?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("{name}: config.{k}"))
        };
        let config = ModelConfigMeta {
            n_layers: get("n_layers")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            vocab: get("vocab")?,
            max_seq: get("max_seq")?,
        };
        let params = m
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: no params"))?
            .iter()
            .map(|p| {
                Ok(ParamMeta {
                    name: p.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").and_then(Json::as_usize).unwrap_or(0),
                    elems: p.get("elems").and_then(Json::as_usize).unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let modules = m
            .get("modules")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: no modules"))?
            .iter()
            .map(|md| {
                Ok(ModuleMeta {
                    name: md.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                    kind: md.get("kind").and_then(Json::as_str).unwrap_or_default().to_string(),
                    batch: md.get("batch").and_then(Json::as_usize).unwrap_or(0),
                    seq: md.get("seq").and_then(Json::as_usize).unwrap_or(0),
                    file: md.get("file").and_then(Json::as_str).unwrap_or_default().to_string(),
                    extra_inputs: md
                        .get("extra_inputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(tensor)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: md
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(tensor)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        out.insert(
            name.clone(),
            ModelManifest {
                name: name.clone(),
                config,
                params_file: m
                    .get("params_file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: params_file"))?
                    .to_string(),
                params_bytes: m.get("params_bytes").and_then(Json::as_usize).unwrap_or(0),
                params,
                modules,
            },
        );
    }
    Ok(out)
}

/// Default artifacts directory (repo-root relative), overridable via env.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("HEXGEN2_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn parses_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = load_manifests(&artifacts_dir()).unwrap();
        let tiny = m.get("tiny").expect("tiny model");
        assert_eq!(tiny.config.n_layers, 4);
        assert_eq!(tiny.config.d_model, 256);
        assert!(tiny.prefill_modules().count() >= 2);
        assert!(tiny.decode_modules().count() >= 2);
        // Params cover the blob exactly.
        let total: usize = tiny.params.iter().map(|p| p.elems * 4).sum();
        assert_eq!(total, tiny.params_bytes);
        // Param shapes consistent with elems.
        for p in &tiny.params {
            assert_eq!(p.shape.iter().product::<usize>(), p.elems, "{}", p.name);
        }
        // Modules reference existing files.
        for md in &tiny.modules {
            assert!(artifacts_dir().join(&md.file).exists(), "{}", md.file);
            assert_eq!(md.outputs.len(), 3);
        }
        assert_eq!(tiny.cache_dims(2), [4, 2, 192, 256]);
    }

    #[test]
    fn missing_dir_is_error() {
        let e = load_manifests(Path::new("/nonexistent-hexgen2")).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }
}
