//! Contention-aware KV route selection.
//!
//! A prefill replica whose max-flow assignment connects it to several decode
//! replicas must pick one per transfer. The paper's rule (§3.3,
//! "communication frequency is set to be proportional to these flow
//! values") is a *static* split that ignores what the links are doing right
//! now; the policies here also see the live link state the
//! [`TransferScheduler`](super::TransferScheduler) maintains — backlog
//! seconds, queued transfers, per-route transmission time — and can route
//! around a busy link or NIC.
//!
//! Adding a policy (DESIGN.md §11): implement [`RoutePolicy::pick`] over the
//! [`Candidate`] slice (every candidate is max-flow-feasible and
//! memory-feasible by the time it reaches the policy), add a [`RouteModel`]
//! variant, and wire its `name`/`from_name`/`policy` arms — the scheduler,
//! ledger, CLI (`--kv-route`), and experiment table pick it up from there.

/// Which route-selection policy the transfer engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RouteModel {
    /// The legacy §3.3 rule: deficit-weighted by max-flow route weight
    /// (argmax `weight / (assigned + 1)`). Bit-identical to the pre-refactor
    /// in-core KV path (`tests/golden_parity.rs`).
    #[default]
    FlowProportional,
    /// Route around congestion: pick the link with the least queued work
    /// (backlog seconds, then queued-transfer count, then route weight).
    LeastLoaded,
    /// Minimize the predicted KV arrival time: argmin over candidates of
    /// `backlog + transmission`, i.e. when this cache would land if sent
    /// down that route right now.
    EtaGreedy,
}

impl RouteModel {
    pub const ALL: [RouteModel; 3] =
        [RouteModel::FlowProportional, RouteModel::LeastLoaded, RouteModel::EtaGreedy];

    pub fn name(self) -> &'static str {
        match self {
            RouteModel::FlowProportional => "flow",
            RouteModel::LeastLoaded => "least-loaded",
            RouteModel::EtaGreedy => "eta-greedy",
        }
    }

    /// Parse `flow` | `least-loaded` | `eta-greedy` (plus aliases).
    pub fn from_name(s: &str) -> Option<RouteModel> {
        match s.to_ascii_lowercase().as_str() {
            "flow" | "flow-proportional" | "flow_proportional" | "proportional" => {
                Some(RouteModel::FlowProportional)
            }
            "least-loaded" | "least_loaded" | "ll" => Some(RouteModel::LeastLoaded),
            "eta-greedy" | "eta_greedy" | "eta" => Some(RouteModel::EtaGreedy),
            _ => None,
        }
    }

    /// The policy object implementing this model.
    pub fn policy(self) -> &'static dyn RoutePolicy {
        match self {
            RouteModel::FlowProportional => &FlowProportionalPolicy,
            RouteModel::LeastLoaded => &LeastLoadedPolicy,
            RouteModel::EtaGreedy => &EtaGreedyPolicy,
        }
    }

    /// Does this model's `pick` read [`Candidate::xfer_s`]? Transfer times
    /// are a per-candidate cost-model query (a device-pair link scan), so
    /// the scheduler computes them up front only for policies that rank by
    /// them — everyone else gets the chosen route's time computed once,
    /// after the pick. A new policy that ranks by transmission time must
    /// add itself here or it will see `xfer_s == 0`.
    pub fn needs_xfer(self) -> bool {
        matches!(self, RouteModel::EtaGreedy)
    }
}

/// One max-flow-feasible destination for a transfer, with the live link
/// state the policies rank by. Built by the scheduler in ascending
/// destination order.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    /// Decode replica index (the engine's arena index).
    pub dst: usize,
    /// Max-flow route weight (the §3.3 flow value; 1e-6 fallback floor).
    pub weight: f64,
    /// Transfers already routed (dst ← src) — the deficit counter.
    pub assigned: f64,
    /// Seconds of already-reserved work on the link this transfer would use
    /// (0 when the link is idle).
    pub backlog_s: f64,
    /// Transfers queued or in flight on that link.
    pub queue_len: usize,
    /// Transmission seconds of *this* cache on this route (Table 1).
    /// Populated only for policies whose [`RouteModel::needs_xfer`] holds
    /// (0.0 otherwise — computing it per candidate is a hot-path cost).
    pub xfer_s: f64,
}

/// A KV route-selection discipline. `pick` returns an index into `cands`
/// (never empty). Policies must be deterministic: ties break toward a fixed
/// candidate so seeded simulations replay bit-identically.
pub trait RoutePolicy {
    fn name(&self) -> &'static str;
    fn pick(&self, cands: &[Candidate]) -> usize;
}

/// Legacy flow-proportional deficit routing. Tie-breaking deliberately
/// mirrors `Iterator::max_by` (the pre-refactor implementation): among
/// equal keys the *last* candidate wins.
pub struct FlowProportionalPolicy;

impl RoutePolicy for FlowProportionalPolicy {
    fn name(&self) -> &'static str {
        "flow"
    }

    fn pick(&self, cands: &[Candidate]) -> usize {
        let mut best = 0usize;
        for i in 1..cands.len() {
            let wb = cands[best].weight / (cands[best].assigned + 1.0); // hexcheck: allow(P1) -- best starts at 0 and only takes values of i, both in-bounds loop indices
            let wi = cands[i].weight / (cands[i].assigned + 1.0); // hexcheck: allow(P1) -- i ranges over 1..cands.len()
            if wi >= wb {
                best = i;
            }
        }
        best
    }
}

/// Least queued work first; ties prefer the heavier max-flow route (it was
/// provisioned to carry more), then the earliest candidate.
pub struct LeastLoadedPolicy;

impl RoutePolicy for LeastLoadedPolicy {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&self, cands: &[Candidate]) -> usize {
        let mut best = 0usize;
        for i in 1..cands.len() {
            let a = &cands[best]; // hexcheck: allow(P1) -- best starts at 0 and only takes values of i, both in-bounds loop indices
            let b = &cands[i]; // hexcheck: allow(P1) -- i ranges over 1..cands.len()
            let better = b.backlog_s < a.backlog_s
                || (b.backlog_s == a.backlog_s
                    && (b.queue_len < a.queue_len
                        || (b.queue_len == a.queue_len && b.weight > a.weight)));
            if better {
                best = i;
            }
        }
        best
    }
}

/// Earliest predicted arrival first (`backlog + transmission`); ties prefer
/// the heavier route, then the earliest candidate.
pub struct EtaGreedyPolicy;

impl RoutePolicy for EtaGreedyPolicy {
    fn name(&self) -> &'static str {
        "eta-greedy"
    }

    fn pick(&self, cands: &[Candidate]) -> usize {
        let mut best = 0usize;
        for i in 1..cands.len() {
            let a = &cands[best]; // hexcheck: allow(P1) -- best starts at 0 and only takes values of i, both in-bounds loop indices
            let b = &cands[i]; // hexcheck: allow(P1) -- i ranges over 1..cands.len()
            let (ea, eb) = (a.backlog_s + a.xfer_s, b.backlog_s + b.xfer_s);
            if eb < ea || (eb == ea && b.weight > a.weight) {
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(dst: usize, weight: f64, assigned: f64, backlog: f64, q: usize, xfer: f64) -> Candidate {
        Candidate { dst, weight, assigned, backlog_s: backlog, queue_len: q, xfer_s: xfer }
    }

    #[test]
    fn names_roundtrip() {
        for m in RouteModel::ALL {
            assert_eq!(RouteModel::from_name(m.name()), Some(m));
            assert_eq!(m.policy().name(), m.name());
        }
        assert_eq!(RouteModel::from_name("eta"), Some(RouteModel::EtaGreedy));
        assert_eq!(RouteModel::from_name("ospf"), None);
    }

    #[test]
    fn flow_proportional_is_deficit_weighted_last_tie() {
        let p = FlowProportionalPolicy;
        // weight/(assigned+1): 10/1=10, 30/2=15, 6/1=6 → index 1.
        let cands = [
            cand(0, 10.0, 0.0, 0.0, 0, 1.0),
            cand(1, 30.0, 1.0, 9.0, 3, 9.0),
            cand(2, 6.0, 0.0, 0.0, 0, 0.1),
        ];
        assert_eq!(p.pick(&cands), 1);
        // Exact tie: the LAST maximum wins (Iterator::max_by semantics —
        // what the legacy engine did).
        let tied = [cand(0, 10.0, 0.0, 0.0, 0, 1.0), cand(1, 10.0, 0.0, 0.0, 0, 1.0)];
        assert_eq!(p.pick(&tied), 1);
    }

    #[test]
    fn least_loaded_prefers_idle_links() {
        let p = LeastLoadedPolicy;
        let cands = [
            cand(0, 100.0, 0.0, 5.0, 2, 1.0),
            cand(1, 1.0, 0.0, 0.0, 0, 4.0), // idle but slow: still preferred
            cand(2, 50.0, 0.0, 2.0, 1, 0.5),
        ];
        assert_eq!(p.pick(&cands), 1);
        // Backlog tie → fewer queued; full tie → heavier route.
        let tied = [cand(0, 1.0, 0.0, 1.0, 2, 1.0), cand(1, 1.0, 0.0, 1.0, 1, 1.0)];
        assert_eq!(p.pick(&tied), 1);
        let weight_tie = [cand(0, 2.0, 0.0, 1.0, 1, 1.0), cand(1, 1.0, 0.0, 1.0, 1, 1.0)];
        assert_eq!(p.pick(&weight_tie), 0);
    }

    #[test]
    fn eta_greedy_minimizes_arrival() {
        let p = EtaGreedyPolicy;
        // ETAs: 5+1=6, 0+4=4, 2+0.5=2.5 → index 2.
        let cands = [
            cand(0, 100.0, 0.0, 5.0, 2, 1.0),
            cand(1, 1.0, 0.0, 0.0, 0, 4.0),
            cand(2, 50.0, 0.0, 2.0, 1, 0.5),
        ];
        assert_eq!(p.pick(&cands), 2);
        // Equal ETA → heavier route wins; full tie → earliest.
        let tied = [cand(0, 1.0, 0.0, 1.0, 1, 1.0), cand(1, 5.0, 0.0, 0.0, 0, 2.0)];
        assert_eq!(p.pick(&tied), 1);
        let full_tie = [cand(0, 1.0, 0.0, 1.0, 1, 1.0), cand(1, 1.0, 0.0, 1.0, 1, 1.0)];
        assert_eq!(p.pick(&full_tie), 0);
    }
}
