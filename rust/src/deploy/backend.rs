//! The [`Backend`] trait: one interface over every execution substrate —
//! the discrete-event simulator ([`SimBackend`]), the rescheduling-enabled
//! simulator that closes the §3.3 online loop mid-trace ([`ReschedBackend`]),
//! and the live PJRT coordinator ([`LiveBackend`]). All return the same
//! [`SimReport`], so callers compare substrates without new plumbing.

use anyhow::{anyhow, Result};

use crate::coordinator::{self, CoordinatorConfig, KvThrottle, LiveRequest};
use crate::rescheduler::{self, MonitorConfig, MODELED_REPLAN_S};
use crate::runtime;
use crate::simulator::{
    run_colocated_cfg, run_disaggregated_cfg, simulate, RecordMode, ServingSpec, SimConfig,
    SimReport, SwitchSpec,
};
use crate::util::rng::Rng;
use crate::workload::Trace;

use super::{DeploymentSpec, Plan, PlanKind};

/// The engine knobs a spec implies: admission model and (for disaggregated
/// prefill replicas) the chunk size. Colocated plans carry their chunk in
/// the plan itself.
fn sim_config(spec: &DeploymentSpec) -> SimConfig {
    SimConfig {
        sizing: spec.admission,
        chunked_prefill: spec.chunked_prefill,
        link: spec.link,
        kv_route: spec.kv_route,
        kv_chunk_layers: spec.kv_chunk_layers,
        // Attribution folds the blame vectors out of the event stream, so
        // it implies tracing even when `--trace` itself is off.
        trace: spec.trace || spec.attribution,
        trace_sample_rate: spec.trace_sample,
        record_mode: if spec.windowed { RecordMode::Windowed } else { RecordMode::Full },
        attribution: spec.attribution,
        ..SimConfig::default()
    }
}

/// An execution substrate for a planned deployment.
pub trait Backend {
    fn name(&self) -> &'static str;
    /// Serve `trace` with `plan` and report per-request metrics.
    fn run(&self, spec: &DeploymentSpec, plan: &Plan, trace: &Trace) -> Result<SimReport>;
}

/// Discrete-event simulation (DESIGN.md §1): disaggregated placements run
/// the prefill/KV/decode pipeline, colocated plans the continuous-batching
/// engine (with optional chunked prefill).
pub struct SimBackend;

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(&self, spec: &DeploymentSpec, plan: &Plan, trace: &Trace) -> Result<SimReport> {
        let cfg = sim_config(spec);
        Ok(match &plan.kind {
            PlanKind::Disaggregated(p) => {
                run_disaggregated_cfg(&spec.cluster, &spec.model, p, trace, &cfg)
            }
            PlanKind::Colocated { replicas, chunked_prefill } => {
                run_colocated_cfg(&spec.cluster, &spec.model, replicas, trace, *chunked_prefill, &cfg)
            }
        })
    }
}

/// Simulation with the online rescheduling loop enabled: the monitor senses
/// every sustained drift in the arrival stream, each drift triggers a
/// warm-started re-plan from the current incumbent (under the spec's
/// objective), approved migrations become mid-trace placement switches.
/// Colocated plans fall back to plain simulation (the §3.3 loop re-plans
/// disaggregated placements).
pub struct ReschedBackend {
    pub monitor: MonitorConfig,
    /// Simulated seconds between drift detection and the switch landing.
    pub modeled_replan_s: f64,
}

impl Default for ReschedBackend {
    fn default() -> ReschedBackend {
        ReschedBackend { monitor: MonitorConfig::case_study(), modeled_replan_s: MODELED_REPLAN_S }
    }
}

impl Backend for ReschedBackend {
    fn name(&self) -> &'static str {
        "resched"
    }

    fn run(&self, spec: &DeploymentSpec, plan: &Plan, trace: &Trace) -> Result<SimReport> {
        let PlanKind::Disaggregated(initial) = &plan.kind else {
            return SimBackend.run(spec, plan, trace);
        };
        let base = spec.sched_opts();
        let cfg = sim_config(spec);
        // KV-contention sensing (monitor threshold finite): the live loop
        // would feed the transfer engine's ledger into the monitor as
        // transfers complete. The simulated loop gets the same signal by
        // flight-recording one epoch on the incumbent placement and
        // replaying its `KvEnqueue` (time, queue-wait) stream into
        // `monitor::observe_kv` — so sustained fabric congestion fires
        // `DriftKind::KvContention` and gets re-planned end to end. With
        // the default infinite threshold the feed is empty and this path
        // is byte-identical to the blind drive.
        // Bottleneck-attributed drift context: when attribution is on, the
        // same pre-epoch run folds a blame report, and its dominant
        // component is stamped into every `AuditRecord::Drift` this pass
        // emits (DESIGN.md §16).
        let mut pre_blame: Option<&'static str> = None;
        let kv_feed: Vec<(f64, f64)> = if self.monitor.kv_wait_threshold_s.is_finite() {
            let mut tcfg = cfg;
            tcfg.trace = true;
            tcfg.trace_sample_rate = 1.0;
            let pre = simulate(
                &spec.cluster,
                &spec.model,
                &ServingSpec::Disaggregated(initial.clone()),
                &[],
                trace,
                &tcfg,
            );
            pre_blame = pre.attr.as_ref().map(|a| a.dominant_name());
            pre.trace
                .map(|log| {
                    log.events
                        .iter()
                        .filter_map(|s| match s.ev {
                            crate::telemetry::TraceEvent::KvEnqueue { wait_s, .. } => {
                                Some((s.t, wait_s))
                            }
                            _ => None,
                        })
                        .collect()
                })
                .unwrap_or_default()
        } else {
            Vec::new()
        };
        let drive = rescheduler::drive_with_kv(
            &spec.cluster,
            &spec.model,
            initial,
            trace,
            self.monitor,
            &base,
            self.modeled_replan_s,
            &kv_feed,
            pre_blame,
        );
        let switches: Vec<SwitchSpec> = drive.switches.iter().map(SwitchSpec::from).collect();
        let mut rep = simulate(
            &spec.cluster,
            &spec.model,
            &ServingSpec::Disaggregated(initial.clone()),
            &switches,
            trace,
            &cfg,
        );
        rep.audit = drive.audit;
        Ok(rep)
    }
}

/// The live disaggregated coordinator (paper §4): real tensors through the
/// AOT-compiled PJRT modules. Worker counts and routing weights come from
/// the plan; trace requests become live token streams (ids sampled
/// deterministically from the spec seed, lengths clamped to the compiled
/// module limits). Requires `make artifacts` and a PJRT-capable `xla` crate
/// — with the in-tree stub this returns an error rather than panicking.
pub struct LiveBackend {
    pub kv_throttle: Option<KvThrottle>,
}

impl Default for LiveBackend {
    fn default() -> LiveBackend {
        LiveBackend { kv_throttle: None }
    }
}

impl Backend for LiveBackend {
    fn name(&self) -> &'static str {
        "live"
    }

    fn run(&self, spec: &DeploymentSpec, plan: &Plan, trace: &Trace) -> Result<SimReport> {
        let mut cfg = CoordinatorConfig::new(spec.model.name);
        cfg.kv_throttle = self.kv_throttle;
        match &plan.kind {
            PlanKind::Disaggregated(p) => {
                let pidx = p.prefill_indices();
                let didx = p.decode_indices();
                cfg.n_prefill = pidx.len().max(1);
                cfg.n_decode = didx.len().max(1);
                // Flow-proportional routing weights (§3.3), with a floor so
                // no worker pair is ever completely unroutable.
                let mut w = vec![vec![1e-6; cfg.n_decode]; cfg.n_prefill];
                for r in &p.routes {
                    if r.flow <= 1e-9 {
                        continue;
                    }
                    if let (Some(pi), Some(di)) = (
                        pidx.iter().position(|&g| g == r.prefill),
                        didx.iter().position(|&g| g == r.decode),
                    ) {
                        w[pi][di] += r.flow;
                    }
                }
                cfg.route_weights = Some(w);
            }
            PlanKind::Colocated { replicas, .. } => {
                // The live path is disaggregated-only; emulate N colocated
                // replicas as N prefill + N decode workers.
                cfg.n_prefill = replicas.len().max(1);
                cfg.n_decode = replicas.len().max(1);
            }
        }

        let manifests = runtime::load_manifests(&cfg.artifacts)?;
        let mm = manifests.get(&cfg.model).ok_or_else(|| {
            anyhow!("model {} not in compiled artifacts (run `make artifacts`)", cfg.model)
        })?;
        let max_prompt =
            mm.prefill_modules().map(|m| m.seq).max().unwrap_or(64).min(mm.config.max_seq / 2).max(2);
        let vocab = mm.config.vocab;
        let mut rng = Rng::new(spec.seed ^ 0x11FE);
        let reqs: Vec<LiveRequest> = trace
            .requests
            .iter()
            .map(|r| {
                let len = r.input_len.clamp(2, max_prompt);
                let budget = mm.config.max_seq.saturating_sub(len).max(2);
                LiveRequest {
                    id: r.id,
                    tokens: (0..len).map(|_| rng.range(0, vocab) as i32).collect(),
                    output_len: r.output_len.clamp(1, budget - 1),
                }
            })
            .collect();
        let rep = coordinator::serve(&cfg, reqs)?;
        Ok(rep.report)
    }
}

/// Resolve a backend by its CLI name.
pub fn backend_by_name(name: &str) -> Option<Box<dyn Backend>> {
    match name.to_ascii_lowercase().as_str() {
        "sim" | "simulate" => Some(Box::new(SimBackend)),
        "resched" | "rescheduling" => Some(Box::new(ReschedBackend::default())),
        "live" => Some(Box::new(LiveBackend::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::deploy::HexGen2Planner;
    use crate::model::OPT_30B;
    use crate::workload::WorkloadKind;

    #[test]
    fn resched_backend_matches_sim_on_steady_traffic() {
        // A steady trace produces no drift events, so the rescheduling
        // backend must reduce to the plain simulation exactly.
        let spec = DeploymentSpec::new(settings::case_study(), OPT_30B)
            .workload(WorkloadKind::Lphd)
            .quick(true)
            .force_k(4)
            .max_rounds(4);
        let dep = spec.plan(&HexGen2Planner).expect("plans");
        let trace = Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 5);
        let a = dep.run(&SimBackend, &trace).unwrap();
        let b = dep.run(&ReschedBackend::default(), &trace).unwrap();
        assert_eq!(a.records.len(), b.records.len());
        assert_eq!(a.tokens_per_s(), b.tokens_per_s());
    }

    #[test]
    fn resched_backend_survives_drifting_traffic() {
        // A drifting trace exercises the full loop; every request must
        // complete whether or not a switch was approved.
        let spec = DeploymentSpec::new(settings::case_study(), OPT_30B)
            .workload(WorkloadKind::Lphd)
            .quick(true)
            .force_k(4)
            .max_rounds(4);
        let dep = spec.plan(&HexGen2Planner).expect("plans");
        let phases = [(WorkloadKind::Lphd, 3.0, 60.0), (WorkloadKind::Hpld, 3.0, 90.0)];
        let trace = Trace::phases(&phases, 6);
        let rep = dep.run(&ReschedBackend::default(), &trace).unwrap();
        assert_eq!(rep.records.len(), trace.requests.len(), "requests lost");
    }

    #[test]
    fn backend_names_resolve() {
        for n in ["sim", "resched", "live"] {
            assert!(backend_by_name(n).is_some(), "{n}");
        }
        assert!(backend_by_name("cloud").is_none());
    }

    #[test]
    fn live_backend_errors_cleanly_without_artifacts() {
        // No compiled artifacts in the test environment: the live backend
        // must return an error, never panic.
        let spec = DeploymentSpec::new(settings::homogeneous_small(), crate::model::TINY)
            .workload(WorkloadKind::Lpld)
            .quick(true);
        // Plan with vLLM (cheap) — the backend only needs worker counts.
        let Ok(dep) = spec.plan(&crate::deploy::VllmPlanner) else { return };
        let trace = Trace::offline(WorkloadKind::Lpld, 4, 1);
        let _ = dep.run(&LiveBackend::default(), &trace);
    }
}
