//! Bench: regenerate paper Fig. 10 (scheduler convergence: ours vs
//! random-swap vs genetic, het1) and time one full scheduling run.
use hexgen2::cluster::settings;
use hexgen2::experiments::{convergence, ExpOpts};
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{schedule, ScheduleOptions};
use hexgen2::util::bench;
use hexgen2::workload::WorkloadKind;

fn main() {
    let opts = ExpOpts::from_env();
    let runs = if opts.quick { 3 } else { 15 };
    convergence::fig10_convergence(&OPT_30B, runs, &opts)
        .print(&format!("Fig. 10: scheduler convergence (het1, OPT-30B, {runs} runs)"));
    let c = settings::het1();
    bench::time("fig10/full-schedule-het1-opt30b", 1, 5, || {
        std::hint::black_box(schedule(&c, &OPT_30B, &ScheduleOptions::new(WorkloadKind::Hphd)));
    });
}
