"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: slow, obviously-right implementations
of causal prefill attention and single-token decode attention. The pytest
suite asserts the Pallas kernels (interpret=True) match these to tight
tolerances across a hypothesis-driven sweep of shapes.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def prefill_attention_ref(q, k, v, lengths):
    """Causal masked attention over full sequences.

    Args:
      q, k, v: [BH, S, Dh] float arrays (BH = batch * heads).
      lengths: [BH] int32, the real (unpadded) sequence length per row.

    Returns:
      [BH, S, Dh] attention output; rows at positions >= length are zero.
    """
    bh, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale  # [BH, S, S]
    row = jnp.arange(s)[None, :, None]  # query positions
    col = jnp.arange(s)[None, None, :]  # key positions
    causal = col <= row
    valid_k = col < lengths[:, None, None]
    mask = causal & valid_k
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs * mask  # kill fully-masked contributions exactly
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", probs / jnp.maximum(denom, 1e-30), v)
    valid_q = (jnp.arange(s)[None, :] < lengths[:, None])[..., None]
    return jnp.where(valid_q, out, 0.0)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """One query token attends over the first `lengths` cached KV entries.

    Args:
      q: [BH, Dh] query for the current token.
      k_cache, v_cache: [BH, S_max, Dh] KV cache (garbage beyond lengths).
      lengths: [BH] int32, number of valid cache entries (inclusive of the
        current token, whose KV must already be written into the cache).

    Returns:
      [BH, Dh] attention output.
    """
    bh, s_max, dh = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bd,bkd->bk", q, k_cache) * scale  # [BH, S_max]
    valid = jnp.arange(s_max)[None, :] < lengths[:, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs * valid
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bk,bkd->bd", probs / jnp.maximum(denom, 1e-30), v_cache)
