//! DistServe baseline (Zhong et al., 2024): disaggregated prefill/decode on
//! a *homogeneous* cluster. DistServe searches per-phase parallelism
//! (intra-node TP, inter-node PP) and a prefill:decode replica ratio but has
//! no heterogeneity-aware placement — on a homogeneous cluster that search
//! is an exhaustive sweep over uniform splits, which we implement directly.
//! The resulting placement executes on the same unified simulation core as
//! HexGen-2's (`simulator::core`'s `DisaggPrefill`/`DisaggDecode`
//! policies), so engine scenarios — chunked prefill, per-request KV
//! admission, shared-NIC contention — apply to this baseline unchanged.

use std::time::Instant;

use crate::cluster::Cluster;
use crate::costmodel::TaskProfile;
use crate::kvtransfer::LinkModel;
use crate::model::LlmSpec;
use crate::scheduler::flownet;
use crate::scheduler::objective::{apply_kv_contention, kv_nic_utilization};
use crate::scheduler::strategy::StrategyCache;
use crate::scheduler::{Objective, Placement};
use crate::workload::WorkloadKind;

/// A DistServe deployment (uniform groups, typed).
#[derive(Clone, Debug)]
pub struct DistServePlan {
    pub placement: Placement,
    pub group_size: usize,
    pub n_prefill: usize,
    pub elapsed_s: f64,
}

/// Enumerate uniform group sizes × prefill counts; evaluate each with the
/// shared flow-network machinery; return the best (throughput objective,
/// DistServe's own criterion).
pub fn schedule_distserve(
    cluster: &Cluster,
    model: &LlmSpec,
    workload: WorkloadKind,
) -> Option<DistServePlan> {
    schedule_distserve_with(cluster, model, workload, Objective::Throughput, None)
}

/// Objective-aware DistServe sweep: the same uniform enumeration, with each
/// candidate ranked under the caller's [`Objective`] (the deploy layer's
/// unified `Planner` path). With `kv_contention` set, every candidate's
/// score is discounted by its analytic worst-NIC overcommit under that
/// link model ([`apply_kv_contention`]) — the same weighting the HexGen-2
/// planner applies under `--contention-aware` — so the ratio sweep stops
/// picking prefill-heavy splits whose KV flow a shared NIC cannot carry.
/// Identity for uncontended candidates (utilization ≤ 1): plans are
/// bit-identical to the blind sweep when the fabric keeps up.
pub fn schedule_distserve_with(
    cluster: &Cluster,
    model: &LlmSpec,
    workload: WorkloadKind,
    objective: Objective,
    kv_contention: Option<LinkModel>,
) -> Option<DistServePlan> {
    // hexcheck: allow(D2) -- wall-clock timing of the planner itself (reported as plan_ms); never feeds plan decisions
    let t0 = Instant::now();
    let (s_in, s_out) = workload.mean_lengths();
    let task = TaskProfile::new(1, s_in, s_out);
    let n = cluster.n();
    let cache = StrategyCache::new();
    let mut best: Option<DistServePlan> = None;

    for gs in [1usize, 2, 4, 8] {
        if gs > n || n % gs != 0 {
            continue;
        }
        let k = n / gs;
        if k < 2 {
            continue;
        }
        let groups: Vec<Vec<usize>> = (0..k).map(|g| (g * gs..(g + 1) * gs).collect()).collect();
        // One incremental flow net per uniform split: the k-1 prefill
        // ratios only retune capacities on it (same partition throughout).
        let mut net =
            flownet::PartitionFlowNet::new(cluster, model, &task, 600.0, &groups, &cache);
        for n_prefill in 1..k {
            let assign: Vec<bool> = (0..k).map(|g| g < n_prefill).collect();
            if let Some(mut p) = net.evaluate(&assign) {
                p.objective_score = objective.score(cluster, model, &task, &p);
                if let Some(link) = kv_contention {
                    p.objective_score =
                        apply_kv_contention(p.objective_score, kv_nic_utilization(&p, link));
                }
                if best
                    .as_ref()
                    .map(|b| p.objective_score > b.placement.objective_score)
                    .unwrap_or(true)
                {
                    best = Some(DistServePlan {
                        placement: p,
                        group_size: gs,
                        n_prefill,
                        elapsed_s: t0.elapsed().as_secs_f64(),
                    });
                }
            }
        }
    }
    best.map(|mut b| {
        b.elapsed_s = t0.elapsed().as_secs_f64();
        b
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::{LLAMA2_70B, OPT_30B};
    use crate::simulator::run_disaggregated;
    use crate::workload::Trace;

    #[test]
    fn schedules_homogeneous_cluster() {
        let c = settings::homogeneous();
        let plan = schedule_distserve(&c, &LLAMA2_70B, WorkloadKind::Hphd).expect("plan");
        assert!(plan.placement.tokens_per_s > 0.0);
        assert!(plan.n_prefill >= 1);
        // Uniform groups by construction.
        let sizes: Vec<usize> =
            plan.placement.groups.iter().map(|g| g.devices.len()).collect();
        assert!(sizes.windows(2).all(|w| w[0] == w[1]), "{sizes:?}");
    }

    #[test]
    fn workload_shifts_phase_ratio() {
        // HPLD needs relatively more prefill than LPHD (§5.2 finding 3).
        let c = settings::homogeneous();
        let hpld = schedule_distserve(&c, &OPT_30B, WorkloadKind::Hpld).unwrap();
        let lphd = schedule_distserve(&c, &OPT_30B, WorkloadKind::Lphd).unwrap();
        let frac_h = hpld.n_prefill as f64 / hpld.placement.groups.len() as f64;
        let frac_l = lphd.n_prefill as f64 / lphd.placement.groups.len() as f64;
        assert!(frac_h >= frac_l, "HPLD prefill frac {frac_h} < LPHD {frac_l}");
    }

    #[test]
    fn contention_weighting_discounts_scores_consistently() {
        // The contention-aware sweep's winning score must be exactly the
        // raw objective score of its own placement run through
        // `apply_kv_contention` at that placement's shared-NIC overcommit —
        // and identical to the blind sweep whenever the winner's NIC is
        // uncontended.
        let c = settings::homogeneous();
        let (s_in, s_out) = WorkloadKind::Hpld.mean_lengths();
        let task = TaskProfile::new(1, s_in, s_out);
        let blind = schedule_distserve_with(
            &c,
            &OPT_30B,
            WorkloadKind::Hpld,
            Objective::Throughput,
            None,
        )
        .expect("blind plan");
        let aware = schedule_distserve_with(
            &c,
            &OPT_30B,
            WorkloadKind::Hpld,
            Objective::Throughput,
            Some(LinkModel::SharedNic),
        )
        .expect("contention-aware plan");
        let raw = Objective::Throughput.score(&c, &OPT_30B, &task, &aware.placement);
        let util = kv_nic_utilization(&aware.placement, LinkModel::SharedNic);
        assert_eq!(
            aware.placement.objective_score,
            apply_kv_contention(raw, util),
            "winner's score is not its discounted raw score"
        );
        assert!(aware.placement.objective_score <= raw + 1e-12);
        if util <= 1.0 && kv_nic_utilization(&blind.placement, LinkModel::SharedNic) <= 1.0 {
            assert_eq!(blind.placement.objective_score, aware.placement.objective_score);
            assert_eq!(blind.n_prefill, aware.n_prefill);
            assert_eq!(blind.group_size, aware.group_size);
        }
    }

    #[test]
    fn plan_simulates() {
        let c = settings::homogeneous();
        let plan = schedule_distserve(&c, &OPT_30B, WorkloadKind::Lpld).unwrap();
        let trace = Trace::offline(WorkloadKind::Lpld, 50, 1);
        let rep = run_disaggregated(&c, &OPT_30B, &plan.placement, &trace);
        assert_eq!(rep.records.len(), 50);
    }
}
