//! The [`Planner`] trait: one interface over all four systems of the
//! paper's evaluation (§5.1) — HexGen-2's graph-partition scheduler and the
//! HexGen / DistServe / vLLM baselines — plus the genetic-algorithm variant
//! used by the §5.3 convergence study. Every planner consumes the same
//! [`DeploymentSpec`] and returns the same [`Plan`], so harnesses iterate
//! over `&[&dyn Planner]` instead of calling four bespoke functions.

use crate::baselines::{distserve, hexgen, vllm};
use crate::costmodel::ReplicaConfig;
use crate::scheduler::{self, genetic, ConvergencePoint, Placement, SearchStats};

use super::DeploymentSpec;

/// What a planner decided to run.
#[derive(Clone, Debug)]
pub enum PlanKind {
    /// Disaggregated prefill/decode groups with KV routes (HexGen-2,
    /// DistServe).
    Disaggregated(Placement),
    /// Colocated continuous-batching replicas (HexGen, vLLM), optionally
    /// with SARATHI-style chunked prefill.
    Colocated { replicas: Vec<ReplicaConfig>, chunked_prefill: Option<usize> },
}

/// Common planner output: the deployment decision plus its estimates.
#[derive(Clone, Debug)]
pub struct Plan {
    /// CLI name of the planner that produced this ("hexgen2", "vllm", ...).
    pub planner: &'static str,
    /// Table label ("HEXGEN-2", "VLLM", ...).
    pub display: &'static str,
    pub kind: PlanKind,
    /// Estimated serving throughput, tokens/s.
    pub est_tokens_per_s: f64,
    /// Score under the spec's [`Objective`] (higher is better).
    pub objective_score: f64,
    /// Planning wall-clock, seconds.
    pub elapsed_s: f64,
    /// Convergence trace of the search (empty for one-shot baselines).
    pub history: Vec<ConvergencePoint>,
    /// Search-effort counters (zeroed for baselines that don't run the
    /// evaluation pipeline through the cache).
    pub stats: SearchStats,
    /// Per-candidate decision audit (`DeploymentSpec::audit`; empty when
    /// off, and always empty for the baselines that bypass the EvalCache).
    pub audit: Vec<crate::telemetry::AuditRecord>,
}

/// A deployment planner: turns a [`DeploymentSpec`] into a [`Plan`], or
/// `None` when no feasible deployment exists.
pub trait Planner {
    /// CLI name (`--planner=<name>`).
    fn name(&self) -> &'static str;
    /// Paper-table label.
    fn display_name(&self) -> &'static str;
    fn plan(&self, spec: &DeploymentSpec) -> Option<Plan>;
}

/// HexGen-2 (§3): spectral partition → max-flow → guided refinement, ranked
/// by the spec's objective.
pub struct HexGen2Planner;

impl Planner for HexGen2Planner {
    fn name(&self) -> &'static str {
        "hexgen2"
    }

    fn display_name(&self) -> &'static str {
        "HEXGEN-2"
    }

    fn plan(&self, spec: &DeploymentSpec) -> Option<Plan> {
        let r = scheduler::schedule(&spec.cluster, &spec.model, &spec.sched_opts())?;
        Some(Plan {
            planner: self.name(),
            display: self.display_name(),
            est_tokens_per_s: r.placement.tokens_per_s,
            objective_score: r.placement.objective_score,
            elapsed_s: r.elapsed_s,
            history: r.history,
            stats: r.stats,
            audit: r.audit,
            kind: PlanKind::Disaggregated(r.placement),
        })
    }
}

/// Genetic-algorithm variant of the HexGen-2 pipeline (§5.3 ablation).
pub struct GeneticPlanner;

impl Planner for GeneticPlanner {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn display_name(&self) -> &'static str {
        "HEXGEN-2 (GA)"
    }

    fn plan(&self, spec: &DeploymentSpec) -> Option<Plan> {
        let r = genetic::schedule_genetic(&spec.cluster, &spec.model, &spec.sched_opts())?;
        Some(Plan {
            planner: self.name(),
            display: self.display_name(),
            est_tokens_per_s: r.placement.tokens_per_s,
            objective_score: r.placement.objective_score,
            elapsed_s: r.elapsed_s,
            history: r.history,
            stats: r.stats,
            audit: r.audit,
            kind: PlanKind::Disaggregated(r.placement),
        })
    }
}

/// HexGen (Jiang et al., 2024b): colocated replicas, GA-scheduled. The GA's
/// internal fitness ranks by the spec's [`Objective`] (the published
/// algorithm's throughput fitness is the `Objective::Throughput` special
/// case), so the search optimizes what the caller asked for instead of
/// searching for throughput and re-scoring the winner.
pub struct HexGenPlanner;

impl Planner for HexGenPlanner {
    fn name(&self) -> &'static str {
        "hexgen"
    }

    fn display_name(&self) -> &'static str {
        "HEXGEN"
    }

    fn plan(&self, spec: &DeploymentSpec) -> Option<Plan> {
        let generations = if spec.quick { 6 } else { 25 };
        let p = hexgen::schedule_hexgen_with(
            &spec.cluster,
            &spec.model,
            spec.workload,
            spec.objective,
            spec.seed,
            generations,
        )?;
        Some(Plan {
            planner: self.name(),
            display: self.display_name(),
            est_tokens_per_s: p.tokens_per_s,
            objective_score: p.objective_score,
            elapsed_s: p.elapsed_s,
            history: Vec::new(),
            stats: SearchStats::default(),
            audit: Vec::new(),
            kind: PlanKind::Colocated { replicas: p.replicas, chunked_prefill: None },
        })
    }
}

/// DistServe (Zhong et al., 2024): uniform disaggregated sweep, with each
/// candidate ranked under the spec's objective.
pub struct DistServePlanner;

impl Planner for DistServePlanner {
    fn name(&self) -> &'static str {
        "distserve"
    }

    fn display_name(&self) -> &'static str {
        "DISTSERVE"
    }

    fn plan(&self, spec: &DeploymentSpec) -> Option<Plan> {
        let p = distserve::schedule_distserve_with(
            &spec.cluster,
            &spec.model,
            spec.workload,
            spec.objective,
            // `--contention-aware` weighs the spec's link model into the
            // ratio sweep, mirroring the HexGen-2 planner's discount.
            if spec.contention_aware { Some(spec.link) } else { None },
        )?;
        Some(Plan {
            planner: self.name(),
            display: self.display_name(),
            est_tokens_per_s: p.placement.tokens_per_s,
            objective_score: p.placement.objective_score,
            elapsed_s: p.elapsed_s,
            history: Vec::new(),
            stats: SearchStats::default(),
            audit: Vec::new(),
            kind: PlanKind::Disaggregated(p.placement),
        })
    }
}

/// vLLM-style baseline (Appendix F): identical colocated replicas at the
/// best uniform TP degree; `spec.chunked_prefill` enables the Appendix-D
/// chunked mode.
pub struct VllmPlanner;

impl Planner for VllmPlanner {
    fn name(&self) -> &'static str {
        "vllm"
    }

    fn display_name(&self) -> &'static str {
        "VLLM"
    }

    fn plan(&self, spec: &DeploymentSpec) -> Option<Plan> {
        let p =
            vllm::schedule_vllm_with(&spec.cluster, &spec.model, spec.workload, spec.objective)?;
        Some(Plan {
            planner: self.name(),
            display: self.display_name(),
            est_tokens_per_s: p.tokens_per_s,
            objective_score: p.objective_score,
            elapsed_s: 0.0,
            history: Vec::new(),
            stats: SearchStats::default(),
            audit: Vec::new(),
            kind: PlanKind::Colocated {
                replicas: p.replicas,
                chunked_prefill: spec.chunked_prefill,
            },
        })
    }
}

/// The four compared systems, in the paper's Table-3 order.
pub fn standard_planners() -> [&'static dyn Planner; 4] {
    [&HexGen2Planner, &HexGenPlanner, &DistServePlanner, &VllmPlanner]
}

/// Resolve a planner by its CLI name.
pub fn planner_by_name(name: &str) -> Option<&'static dyn Planner> {
    match name.to_ascii_lowercase().as_str() {
        "hexgen2" | "ours" => Some(&HexGen2Planner),
        "hexgen" => Some(&HexGenPlanner),
        "distserve" => Some(&DistServePlanner),
        "vllm" => Some(&VllmPlanner),
        "genetic" | "ga" => Some(&GeneticPlanner),
        _ => None,
    }
}

// Colocated-plan objective scoring lives in
// `objective::colocated_objective_score` (it moved out of this module so
// the HexGen GA and vLLM TP sweeps can rank their *internal* searches by
// it — ROADMAP PR-2 follow-up); the planners above report the score their
// search ranked by.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::deploy::DeploymentSpec;
    use crate::model::OPT_30B;
    use crate::workload::WorkloadKind;

    fn spec(cluster: crate::cluster::Cluster) -> DeploymentSpec {
        DeploymentSpec::new(cluster, OPT_30B).workload(WorkloadKind::Lpld).quick(true).seed(3)
    }

    #[test]
    fn all_four_systems_plan_through_the_trait() {
        let hom = settings::homogeneous_small();
        for planner in standard_planners() {
            let s = spec(hom.clone());
            let plan = planner.plan(&s).unwrap_or_else(|| panic!("{} failed", planner.name()));
            assert!(plan.est_tokens_per_s > 0.0, "{} zero estimate", planner.name());
            assert!(
                plan.objective_score > 0.0,
                "{} zero throughput score",
                planner.name()
            );
            match plan.kind {
                PlanKind::Disaggregated(ref p) => assert!(!p.groups.is_empty()),
                PlanKind::Colocated { ref replicas, .. } => assert!(!replicas.is_empty()),
            }
        }
    }

    #[test]
    fn planner_names_resolve() {
        for planner in standard_planners() {
            let resolved = planner_by_name(planner.name()).expect("resolves");
            assert_eq!(resolved.name(), planner.name());
        }
        assert!(planner_by_name("genetic").is_some());
        assert!(planner_by_name("ours").is_some());
        assert!(planner_by_name("sglang").is_none());
    }

    #[test]
    fn colocated_planners_report_their_ranking_score() {
        // The score a colocated planner reports is the one its internal
        // search ranked by (objective::colocated_objective_score — its
        // per-objective semantics are tested in scheduler::objective).
        let hom = settings::homogeneous_small();
        let s = spec(hom).objective(crate::scheduler::Objective::CostPerToken);
        for planner in [&HexGenPlanner as &dyn Planner, &VllmPlanner] {
            let plan = planner.plan(&s).unwrap_or_else(|| panic!("{} plans", planner.name()));
            let PlanKind::Colocated { ref replicas, .. } = plan.kind else {
                panic!("{} is a colocated planner", planner.name());
            };
            let rescore = crate::scheduler::objective::colocated_objective_score(
                &s.cluster,
                &s.model,
                &s.task(),
                s.objective,
                replicas,
                plan.est_tokens_per_s,
            );
            assert!(
                (plan.objective_score - rescore).abs() <= 1e-9 * rescore.abs().max(1.0),
                "{}: reported {} != ranking score {}",
                planner.name(),
                plan.objective_score,
                rescore
            );
        }
    }
}
