//! The HexGen generative-inference cost model (paper Table 1 / Appendix A).
//!
//! Every scheduling decision in HexGen-2 — node capacities, edge capacities,
//! parallel-strategy selection — and the discrete-event simulator are driven
//! by these formulas. The paper validates that "the estimated serving
//! throughput closely aligns with the actual throughput" (§5.3), which is
//! what licenses using the cost model as the executable substrate for the
//! paper-scale experiments (DESIGN.md §1).
//!
//! Notation follows Table 1: `b` batch size, `s_in`/`s_out` input/output
//! sequence lengths, `H` hidden dim, `B` bytes per element, `c_d` tensor
//! compute, `m_d` HBM bandwidth, `α/β` link latency/bandwidth, `d_ij` the
//! device set of stage j, `l_ij` its layer count.

pub mod replica;

pub use replica::ReplicaConfig;

use crate::cluster::{Cluster, DeviceId};
use crate::model::LlmSpec;

/// An inference task profile: Table 1's (b_t, s_in, s_out).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskProfile {
    pub batch: usize,
    pub s_in: f64,
    pub s_out: f64,
}

impl TaskProfile {
    pub fn new(batch: usize, s_in: f64, s_out: f64) -> TaskProfile {
        TaskProfile { batch, s_in, s_out }
    }

    pub fn with_batch(self, batch: usize) -> TaskProfile {
        TaskProfile { batch, ..self }
    }
}

/// GPU compute saturates once a prefill batch reaches this many total tokens
/// (paper Fig. 1: "once the total number of batched tokens reaches 2048, no
/// further improvement in throughput is observed"). Below it the kernel is
/// memory/launch-bound, so the wall time floors at the 2048-token time.
pub const PREFILL_SATURATION_TOKENS: f64 = 2048.0;

/// Hard cap on decode batch (continuous-batching slot limit).
pub const MAX_DECODE_BATCH: usize = 256;

/// Cost model bound to one cluster + one model.
#[derive(Clone, Copy)]
pub struct CostModel<'a> {
    pub cluster: &'a Cluster,
    pub model: &'a LlmSpec,
}

impl<'a> CostModel<'a> {
    pub fn new(cluster: &'a Cluster, model: &'a LlmSpec) -> Self {
        CostModel { cluster, model }
    }

    fn h2(&self) -> f64 {
        let h = self.model.hidden as f64;
        h * h
    }

    // ---------------- Table 1, row "Computation cost" ----------------

    /// Prefill compute time of one stage:
    /// max_d( 24 b s_in H^2 / (|d| c_d) ) * l, with the Fig.-1 saturation
    /// floor at 2048 batched tokens.
    pub fn stage_prefill_compute(&self, stage: &[DeviceId], layers: usize, t: &TaskProfile) -> f64 {
        let tokens = (t.batch as f64 * t.s_in).max(PREFILL_SATURATION_TOKENS);
        let flops = 24.0 * tokens * self.h2();
        let worst = stage
            .iter()
            .map(|&d| flops / (stage.len() as f64 * self.cluster.devices[d].gpu.effective_tflops()))
            .fold(0.0f64, f64::max);
        worst * layers as f64
    }

    /// Decode compute time of one stage for the full s_out generation:
    /// max_d( 12 H^2 B s_out / (|d| m_d) ) * l        (weight scan, IO-bound)
    ///   + max_d( 2 b s_ctx H B s_out / (|d| m_d) ) * l  (KV-cache scan)
    ///   + max_d( 24 b s_out H^2 / (|d| c_d) ) * l      (arithmetic).
    ///
    /// The KV-scan term extends paper Table 1 (which models only the weight
    /// scan): at large batch x context, reading the KV cache dominates HBM
    /// traffic and is what makes decode throughput track memory bandwidth —
    /// the effect the paper's cost-efficiency results rest on (DESIGN.md
    /// §Deviations). s_ctx is the mean context over the generation,
    /// s_in + s_out/2.
    pub fn stage_decode_compute(&self, stage: &[DeviceId], layers: usize, t: &TaskProfile) -> f64 {
        let tp = stage.len() as f64;
        let h = self.model.hidden as f64;
        let s_ctx = t.s_in + 0.5 * t.s_out;
        let weight_bytes = 12.0 * self.h2() * self.model.bytes_per_elem * t.s_out;
        let kv_bytes = 2.0 * t.batch as f64 * s_ctx * h * self.model.bytes_per_elem * t.s_out;
        let scan_bytes = weight_bytes + kv_bytes;
        let io = stage
            .iter()
            .map(|&d| scan_bytes / (tp * self.cluster.devices[d].gpu.mem_bw_eff()))
            .fold(0.0f64, f64::max);
        let flops = 24.0 * t.batch as f64 * t.s_out * self.h2();
        let comp = stage
            .iter()
            .map(|&d| flops / (tp * self.cluster.devices[d].gpu.effective_tflops()))
            .fold(0.0f64, f64::max);
        (io + comp) * layers as f64
    }

    // ---------------- Table 1, row "TP communication cost" ----------------

    /// Prefill TP communication of one stage:
    /// max_d Σ_{d'≠d} ( α + b s_in H B / (|d| β) ) * 4 l.
    pub fn stage_prefill_tp_comm(&self, stage: &[DeviceId], layers: usize, t: &TaskProfile) -> f64 {
        self.tp_comm_inner(stage, t.batch as f64 * t.s_in) * 4.0 * layers as f64
    }

    /// Decode TP communication for the full generation:
    /// max_d Σ_{d'≠d} ( α + b H B / (|d| β) ) * 4 s_out l.
    pub fn stage_decode_tp_comm(&self, stage: &[DeviceId], layers: usize, t: &TaskProfile) -> f64 {
        self.tp_comm_inner(stage, t.batch as f64) * 4.0 * t.s_out * layers as f64
    }

    fn tp_comm_inner(&self, stage: &[DeviceId], tokens: f64) -> f64 {
        if stage.len() <= 1 {
            return 0.0;
        }
        let msg = tokens * self.model.hidden as f64 * self.model.bytes_per_elem / stage.len() as f64;
        stage
            .iter()
            .map(|&d| {
                stage
                    .iter()
                    .filter(|&&d2| d2 != d)
                    .map(|&d2| self.cluster.latency[d][d2] + msg / self.cluster.bandwidth[d][d2])
                    .sum::<f64>()
            })
            .fold(0.0f64, f64::max)
    }

    // ---------------- Table 1, row "PP communication cost" ----------------

    /// Prefill activation hop between consecutive stages:
    /// min_{d∈j, d'∈j+1} ( α + b s_in H B / β ).
    pub fn pp_comm_prefill(&self, from: &[DeviceId], to: &[DeviceId], t: &TaskProfile) -> f64 {
        let msg = t.batch as f64 * t.s_in * self.model.hidden as f64 * self.model.bytes_per_elem;
        self.pp_best_pair(from, to, msg)
    }

    /// Decode activation hops for the full generation:
    /// min pair ( α + b H B / β ) * s_out.
    pub fn pp_comm_decode(&self, from: &[DeviceId], to: &[DeviceId], t: &TaskProfile) -> f64 {
        let msg = t.batch as f64 * self.model.hidden as f64 * self.model.bytes_per_elem;
        self.pp_best_pair(from, to, msg) * t.s_out
    }

    fn pp_best_pair(&self, from: &[DeviceId], to: &[DeviceId], msg: f64) -> f64 {
        let mut best = f64::INFINITY;
        for &d in from {
            for &d2 in to {
                if d == d2 {
                    continue;
                }
                let c = self.cluster.latency[d][d2] + msg / self.cluster.bandwidth[d][d2];
                best = best.min(c);
            }
        }
        if best.is_infinite() {
            0.0 // degenerate single-device "pipeline"
        } else {
            best
        }
    }

    // ---------------- End-to-end replica latencies ----------------

    /// Prefill latency of one request batch through the whole replica.
    pub fn prefill_latency(&self, cfg: &ReplicaConfig, t: &TaskProfile) -> f64 {
        let mut total = 0.0;
        for (i, stage) in cfg.stages.iter().enumerate() {
            total += self.stage_prefill_compute(stage, cfg.layers[i], t);
            total += self.stage_prefill_tp_comm(stage, cfg.layers[i], t);
            if i + 1 < cfg.stages.len() {
                total += self.pp_comm_prefill(stage, &cfg.stages[i + 1], t);
            }
        }
        total
    }

    /// Decode latency for generating all s_out tokens of a batch.
    pub fn decode_latency(&self, cfg: &ReplicaConfig, t: &TaskProfile) -> f64 {
        let mut total = 0.0;
        for (i, stage) in cfg.stages.iter().enumerate() {
            total += self.stage_decode_compute(stage, cfg.layers[i], t);
            total += self.stage_decode_tp_comm(stage, cfg.layers[i], t);
            if i + 1 < cfg.stages.len() {
                total += self.pp_comm_decode(stage, &cfg.stages[i + 1], t);
            }
        }
        total
    }

    /// Per-token decode step latency at the current batch/context.
    pub fn decode_step_latency(&self, cfg: &ReplicaConfig, batch: usize, s_ctx: f64) -> f64 {
        let t = TaskProfile { batch, s_in: s_ctx, s_out: 1.0 };
        self.decode_latency(cfg, &t)
    }

    // ---------------- Table 1, row "Memory limit" ----------------

    /// Per-device memory demand of a stage:
    /// ( 12 H^2 B / |d| + 2 b (s_in+s_out) H B / |d| ) * l
    ///   + 4 b (s_in+s_out) H B   (activations).
    pub fn stage_memory_per_device(&self, tp: usize, layers: usize, t: &TaskProfile) -> f64 {
        let h = self.model.hidden as f64;
        let b = self.model.bytes_per_elem;
        let seq = t.s_in + t.s_out;
        let bt = t.batch as f64;
        let per_layer = 12.0 * h * h * b / tp as f64 + 2.0 * bt * seq * h * b / tp as f64;
        per_layer * layers as f64 + 4.0 * bt * seq * h * b
    }

    /// Does the replica fit in its devices' memory for this task?
    pub fn memory_ok(&self, cfg: &ReplicaConfig, t: &TaskProfile) -> bool {
        cfg.stages.iter().enumerate().all(|(i, stage)| {
            let need = self.stage_memory_per_device(stage.len(), cfg.layers[i], t);
            let cap = stage
                .iter()
                .map(|&d| self.cluster.devices[d].gpu.mem_bytes())
                .fold(f64::INFINITY, f64::min);
            need <= cap
        })
    }

    /// Largest decode batch that fits in memory (Appendix A's "maximum
    /// available batch size"), capped at MAX_DECODE_BATCH.
    pub fn max_decode_batch(&self, cfg: &ReplicaConfig, t: &TaskProfile) -> usize {
        let mut best = 0usize;
        for b in 1..=MAX_DECODE_BATCH {
            if self.memory_ok(cfg, &t.with_batch(b)) {
                best = b;
            } else {
                break;
            }
        }
        best
    }

    /// Largest prefill batch that fits in memory at input length `s_in`,
    /// searched up to `cap` (memory demand is monotone in batch, so the
    /// first failure ends the scan). This is the memory-derived bound that
    /// replaces the simulator's old hardcoded `1..=16` scan; pass
    /// `MAX_DECODE_BATCH` for an effectively unbounded search. Returns at
    /// least 1 (the old engines floored infeasible replicas at batch 1 and
    /// let the per-iteration token budget bound the work).
    pub fn max_prefill_batch(&self, cfg: &ReplicaConfig, s_in: f64, cap: usize) -> usize {
        let mut best = 1usize;
        for b in 1..=cap {
            if self.memory_ok(cfg, &TaskProfile::new(b, s_in, 0.0)) {
                best = b;
            } else {
                break;
            }
        }
        best
    }

    /// Resident-token capacity of a replica: the largest total number of
    /// sequence tokens (prompt + generated, summed over all resident
    /// requests) whose KV cache and activations fit alongside the weights.
    ///
    /// Derived per stage from the Table-1 memory row, which is linear in
    /// b·(s_in+s_out): headroom_i = min-device-mem − weight bytes, and each
    /// resident token costs `2 H B l_i / |d_i| + 4 H B` bytes on the
    /// binding device. The replica capacity is the minimum over stages;
    /// 0.0 when the weights alone do not fit. This is what the simulator's
    /// per-request admission ledger charges actual request lengths against
    /// (in place of mean-length batch sizing).
    pub fn token_capacity(&self, cfg: &ReplicaConfig) -> f64 {
        let h = self.model.hidden as f64;
        let b = self.model.bytes_per_elem;
        let mut cap = f64::INFINITY;
        for (i, stage) in cfg.stages.iter().enumerate() {
            let tp = stage.len() as f64;
            let layers = cfg.layers[i] as f64;
            let mem = stage
                .iter()
                .map(|&d| self.cluster.devices[d].gpu.mem_bytes())
                .fold(f64::INFINITY, f64::min);
            let weights = 12.0 * h * h * b * layers / tp;
            let per_token = 2.0 * h * b * layers / tp + 4.0 * h * b;
            let headroom = mem - weights;
            if headroom <= 0.0 {
                return 0.0;
            }
            cap = cap.min(headroom / per_token);
        }
        if cap.is_finite() {
            cap
        } else {
            0.0
        }
    }

    // ---------------- Appendix A: node capacities ----------------

    /// Prefill node capacity: requests per period T. Batching does not raise
    /// throughput *past saturation* (Appendix A / Fig. 1), so the replica
    /// batches just enough requests to fill the 2048-token saturation window
    /// (subject to memory): capacity = b* · T / latency(b*). For prompts at
    /// or above saturation this reduces to the paper's T / single-request
    /// latency.
    pub fn prefill_capacity(&self, cfg: &ReplicaConfig, t: &TaskProfile, period: f64) -> f64 {
        let mut b = ((PREFILL_SATURATION_TOKENS / t.s_in.max(1.0)).floor() as usize).max(1);
        // Respect the memory limit at this batch.
        while b > 1 && !self.memory_ok(cfg, &TaskProfile { batch: b, s_out: 0.0, ..*t }) {
            b -= 1;
        }
        let lat = self.prefill_latency(cfg, &TaskProfile { batch: b, s_out: 0.0, ..*t });
        if lat <= 0.0 {
            return 0.0;
        }
        b as f64 * period / lat
    }

    /// Decode node capacity: max_batch * T / full-generation latency
    /// (Appendix A: decode is IO-bound and benefits from batching).
    pub fn decode_capacity(&self, cfg: &ReplicaConfig, t: &TaskProfile, period: f64) -> f64 {
        let mb = self.max_decode_batch(cfg, t);
        if mb == 0 {
            return 0.0;
        }
        let lat = self.decode_latency(cfg, &t.with_batch(mb));
        if lat <= 0.0 {
            return 0.0;
        }
        mb as f64 * period / lat
    }

    // ---------------- Table 1, row "KV cache communication cost" ----------

    /// KV bytes one request of s_in tokens carries across `layers` layers:
    /// Table 1's 2 b s_in H B per layer.
    pub fn kv_bytes(&self, s_in: f64, layers: usize) -> f64 {
        2.0 * s_in * self.model.hidden as f64 * self.model.bytes_per_elem * layers as f64
    }

    /// Transfer time of one request's KV cache from a prefill replica to a
    /// decode replica. Each prefill stage sends the KV of its layer range to
    /// the decode stage(s) holding those layers; device pairs within a
    /// stage-pair transmit shards in parallel ("the edge capacity is
    /// determined by the collective performance of all GPU-to-GPU
    /// transmission connections", §3.3). Decode stage order is permuted to
    /// minimize the cost when PP is small (Appendix A).
    pub fn kv_transfer_time(&self, p: &ReplicaConfig, d: &ReplicaConfig, t: &TaskProfile) -> f64 {
        let dpp = d.stages.len();
        if dpp <= 4 {
            // Try all layer-range orderings of the decode stages.
            let mut order: Vec<usize> = (0..dpp).collect();
            let mut best = f64::INFINITY;
            permute(&mut order, 0, &mut |perm| {
                let c = self.kv_transfer_time_ordered(p, d, perm, t);
                if c < best {
                    best = c;
                }
            });
            best
        } else {
            let order: Vec<usize> = (0..dpp).collect();
            self.kv_transfer_time_ordered(p, d, &order, t)
        }
    }

    /// KV transfer time with decode stages assigned to layer ranges in the
    /// given order (order[k] = which decode stage holds the k-th layer range).
    fn kv_transfer_time_ordered(
        &self,
        p: &ReplicaConfig,
        d: &ReplicaConfig,
        order: &[usize],
        t: &TaskProfile,
    ) -> f64 {
        // Layer boundaries for both replicas.
        let p_bounds = bounds(&p.layers);
        let mut d_layers_perm = vec![0usize; d.layers.len()];
        for (slot, &stage_idx) in order.iter().enumerate() {
            d_layers_perm[slot] = d.layers[stage_idx];
        }
        let d_bounds = bounds(&d_layers_perm);

        let mut worst = 0.0f64;
        for (pi, pstage) in p.stages.iter().enumerate() {
            for (slot, &dstage_idx) in order.iter().enumerate() {
                let lo = p_bounds[pi].0.max(d_bounds[slot].0);
                let hi = p_bounds[pi].1.min(d_bounds[slot].1);
                if lo >= hi {
                    continue;
                }
                let bytes = self.kv_bytes(t.s_in, hi - lo) * t.batch as f64;
                let dstage = &d.stages[dstage_idx];
                // Round-robin pairing of TP ranks; shards move in parallel.
                let nlinks = pstage.len().max(dstage.len());
                let mut agg_bw = 0.0;
                let mut max_lat = 0.0f64;
                for r in 0..nlinks {
                    let a = pstage[r % pstage.len()];
                    let b = dstage[r % dstage.len()];
                    if a == b {
                        // Same physical GPU serving both phases' layer: free.
                        agg_bw = f64::INFINITY;
                    } else {
                        agg_bw += self.cluster.bandwidth[a][b];
                        max_lat = max_lat.max(self.cluster.latency[a][b]);
                    }
                }
                let time = if agg_bw.is_infinite() { 0.0 } else { max_lat + bytes / agg_bw };
                worst = worst.max(time);
            }
        }
        worst
    }
}

/// Cumulative (start, end) layer ranges from per-stage layer counts.
fn bounds(layers: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(layers.len());
    let mut acc = 0;
    for &l in layers {
        out.push((acc, acc + l));
        acc += l;
    }
    out
}

/// Heap-permute helper (small n only).
fn permute(v: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == v.len() {
        f(v);
        return;
    }
    for i in k..v.len() {
        v.swap(k, i);
        permute(v, k + 1, f);
        v.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::{LLAMA2_70B, OPT_30B};

    fn hom() -> Cluster {
        settings::homogeneous()
    }

    fn cfg(stages: Vec<Vec<DeviceId>>, layers: Vec<usize>) -> ReplicaConfig {
        ReplicaConfig::new(stages, layers)
    }

    #[test]
    fn tp_reduces_prefill_compute() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        let tp1 = m.stage_prefill_compute(&[0], 80, &t);
        let tp4 = m.stage_prefill_compute(&[0, 1, 2, 3], 80, &t);
        assert!(tp4 < tp1 / 3.5, "tp4={tp4} tp1={tp1}");
    }

    #[test]
    fn prefill_saturation_floor() {
        // Below 2048 batched tokens the wall time is flat (Fig. 1).
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t128 = m.stage_prefill_compute(&[0], 1, &TaskProfile::new(1, 128.0, 0.0));
        let t2048 = m.stage_prefill_compute(&[0], 1, &TaskProfile::new(1, 2048.0, 0.0));
        let t4096 = m.stage_prefill_compute(&[0], 1, &TaskProfile::new(1, 4096.0, 0.0));
        assert_eq!(t128, t2048);
        assert!((t4096 / t2048 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_is_io_bound_at_small_batch() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        let scan = 12.0 * 8192.0f64 * 8192.0 * 2.0 * 128.0 / 3.35e12 * 80.0;
        let got = m.stage_decode_compute(&[0], 80, &t);
        // IO term dominates; compute adds a small fraction.
        assert!(got >= scan && got < scan * 1.3, "got {got} scan {scan}");
    }

    #[test]
    fn decode_throughput_scales_with_batch() {
        // tokens/s at batch 32 should be much higher than at batch 1 (Fig. 1).
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let r = cfg(vec![vec![0, 1, 2, 3]], vec![80]);
        let lat1 = m.decode_latency(&r, &TaskProfile::new(1, 512.0, 128.0));
        let lat32 = m.decode_latency(&r, &TaskProfile::new(32, 512.0, 128.0));
        let tput1 = 128.0 / lat1;
        let tput32 = 32.0 * 128.0 / lat32;
        assert!(tput32 > tput1 * 10.0, "{tput1} vs {tput32}");
    }

    #[test]
    fn tp1_has_no_tp_comm() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(4, 512.0, 128.0);
        assert_eq!(m.stage_prefill_tp_comm(&[0], 80, &t), 0.0);
        assert!(m.stage_prefill_tp_comm(&[0, 1], 80, &t) > 0.0);
    }

    #[test]
    fn memory_limit_bounds_decode_batch() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        // 70B on a single 80G GPU: does not even fit the weights.
        assert!(!m.memory_ok(&cfg(vec![vec![0]], vec![80]), &t));
        // 8-way TP fits, with a nontrivial max batch.
        let r8 = cfg(vec![(0..8).collect()], vec![80]);
        assert!(m.memory_ok(&r8, &t));
        let mb = m.max_decode_batch(&r8, &t);
        assert!(mb >= 8, "max batch {mb}");
        // OPT-30B fits more batch than LLaMA-70B on the same hardware.
        let m30 = CostModel::new(&c, &OPT_30B);
        let r30 = cfg(vec![(0..8).collect()], vec![48]);
        assert!(m30.max_decode_batch(&r30, &t) > mb);
    }

    #[test]
    fn pipeline_latency_adds_pp_hops() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        let pp1 = cfg(vec![(0..8).collect()], vec![80]);
        let pp2 = cfg(vec![(0..4).collect(), (4..8).collect()], vec![40, 40]);
        // Same total compute resources; pp2 pays activation hops but less TP
        // overhead. Both must be positive and finite.
        for r in [&pp1, &pp2] {
            let l = m.prefill_latency(r, &t);
            assert!(l.is_finite() && l > 0.0);
        }
    }

    #[test]
    fn kv_transfer_prefers_fast_links() {
        let het = settings::het1();
        let m = CostModel::new(&het, &OPT_30B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        // Prefill on H100 pair (node 0), decode on A100 trio (node 1): IB.
        let p = cfg(vec![vec![0, 1]], vec![48]);
        let d_fast = cfg(vec![vec![2, 3, 4]], vec![48]);
        // Decode on A6000s in the other DC: WAN link.
        let d_slow = cfg(vec![vec![15, 16, 17]], vec![48]);
        let fast = m.kv_transfer_time(&p, &d_fast, &t);
        let slow = m.kv_transfer_time(&p, &d_slow, &t);
        assert!(fast < slow / 20.0, "fast {fast} slow {slow}");
    }

    #[test]
    fn kv_transfer_zero_when_colocated() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        let p = cfg(vec![vec![0, 1]], vec![80]);
        let same = m.kv_transfer_time(&p, &p, &t);
        assert_eq!(same, 0.0);
    }

    #[test]
    fn kv_transfer_stage_order_optimized() {
        // With decode PP=2 the permutation search must do no worse than the
        // identity order.
        let het = settings::het1();
        let m = CostModel::new(&het, &OPT_30B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        let p = cfg(vec![vec![0, 1]], vec![48]);
        let d = cfg(vec![vec![2, 3], vec![15, 16]], vec![24, 24]);
        let opt = m.kv_transfer_time(&p, &d, &t);
        let ident = m.kv_transfer_time_ordered(&p, &d, &[0, 1], &t);
        let swapped = m.kv_transfer_time_ordered(&p, &d, &[1, 0], &t);
        assert!(opt <= ident + 1e-12 && opt <= swapped + 1e-12);
    }

    #[test]
    fn max_prefill_batch_matches_memory_ok() {
        let c = hom();
        let m = CostModel::new(&c, &OPT_30B);
        let r = cfg(vec![(0..4).collect()], vec![48]);
        // Pinned to the old hardcoded bound, the scan reproduces the legacy
        // "largest b in 1..=16 that fits" exactly.
        let legacy = {
            let mut mb = 1;
            for b in 1..=16 {
                if m.memory_ok(&r, &TaskProfile::new(b, 512.0, 0.0)) {
                    mb = b;
                }
            }
            mb
        };
        assert_eq!(m.max_prefill_batch(&r, 512.0, 16), legacy);
        // The memory-derived bound is at least as large and still feasible.
        let derived = m.max_prefill_batch(&r, 512.0, MAX_DECODE_BATCH);
        assert!(derived >= legacy);
        assert!(m.memory_ok(&r, &TaskProfile::new(derived, 512.0, 0.0)));
        // Longer prompts admit fewer batched requests.
        assert!(m.max_prefill_batch(&r, 4096.0, MAX_DECODE_BATCH) <= derived);
    }

    #[test]
    fn token_capacity_consistent_with_memory_ok() {
        let c = hom();
        let m = CostModel::new(&c, &OPT_30B);
        let r = cfg(vec![(0..4).collect()], vec![48]);
        let cap = m.token_capacity(&r);
        assert!(cap > 0.0, "weights must fit");
        // A batch whose total tokens sit just under the capacity passes the
        // memory check; just over fails (same linear model, two views).
        let seq = 1000.0;
        let b_fit = (cap / seq * 0.98) as usize;
        let b_over = (cap / seq * 1.02) as usize + 1;
        assert!(m.memory_ok(&r, &TaskProfile::new(b_fit.max(1), seq, 0.0)));
        assert!(!m.memory_ok(&r, &TaskProfile::new(b_over, seq, 0.0)));
        // A replica that cannot even hold the weights has zero capacity.
        let tiny = cfg(vec![vec![0]], vec![80]);
        let m70 = CostModel::new(&c, &LLAMA2_70B);
        assert_eq!(m70.token_capacity(&tiny), 0.0);
    }

    #[test]
    fn capacities_positive_and_sane() {
        let c = hom();
        let m = CostModel::new(&c, &LLAMA2_70B);
        let t = TaskProfile::new(1, 512.0, 128.0);
        let r = cfg(vec![(0..4).collect()], vec![80]);
        let pc = m.prefill_capacity(&r, &t, 600.0);
        let dc = m.decode_capacity(&r, &t, 600.0);
        assert!(pc > 0.0 && dc > 0.0);
        // Decode capacity (batched) exceeds prefill capacity per Appendix A
        // logic on this IO-bound model? Not necessarily — just sanity-bound.
        assert!(pc.is_finite() && dc.is_finite());
    }
}
