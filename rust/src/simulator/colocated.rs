//! Event-driven simulation of *colocated* serving (the paradigm the paper
//! disaggregates away from): each replica interleaves prefill and decode in
//! shared iterations — continuous batching à la Orca/vLLM — so every
//! admitted prefill delays all running decodes (the interference of Fig. 1).
//! Optional SARATHI-style chunked prefill (Appendix D) caps the prefill
//! tokens per iteration, trading interference for prefill latency.
//!
//! Used by the HexGen and vLLM baselines (`baselines/`).

use std::collections::VecDeque;

use crate::cluster::Cluster;
use crate::costmodel::{CostModel, ReplicaConfig, TaskProfile};
use crate::model::LlmSpec;
use crate::workload::{Request, Trace};

use super::events::EventQueue;
use super::metrics::{RequestRecord, SimReport};
use super::{slo_base, PREFILL_TOKEN_BUDGET};

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(usize),
    IterDone(usize),
}

struct PendingPrefill {
    req: usize,
    remaining: usize,
}

struct Running {
    req: usize,
    generated: usize,
}

struct Replica {
    cfg: ReplicaConfig,
    queue: VecDeque<PendingPrefill>,
    /// Requests whose prefill completed this iteration (first token pending).
    running: Vec<Running>,
    iterating: bool,
    max_batch: usize,
    /// Prefills being chunk-processed, still occupying a slot.
    inflight_prefill: Vec<PendingPrefill>,
}

/// Simulate colocated continuous batching over one or more replicas.
/// `chunk` = Some(c) enables chunked prefill with c-token chunks.
pub fn run_colocated(
    cluster: &Cluster,
    model: &LlmSpec,
    replicas: &[ReplicaConfig],
    trace: &Trace,
    chunk: Option<usize>,
) -> SimReport {
    let cm = CostModel::new(cluster, model);
    let (s_in_mean, s_out_mean) = trace.kind.mean_lengths();
    let task = TaskProfile::new(1, s_in_mean, s_out_mean);

    let mut reps: Vec<Replica> = replicas
        .iter()
        .filter(|cfg| cm.memory_ok(cfg, &task))
        .map(|cfg| {
            let mb = cm.max_decode_batch(cfg, &task).max(1);
            Replica {
                cfg: cfg.clone(),
                queue: VecDeque::new(),
                running: Vec::new(),
                iterating: false,
                max_batch: mb,
                inflight_prefill: Vec::new(),
            }
        })
        .collect();
    if reps.is_empty() {
        return SimReport::from_records(vec![]);
    }

    let reqs = &trace.requests;
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (i, r) in reqs.iter().enumerate() {
        q.push(r.arrival, Ev::Arrive(i));
    }

    let mut prefill_done_at = vec![0.0f64; reqs.len()];
    let mut records: Vec<RequestRecord> = Vec::new();

    // One shared iteration scheduler: admit prefill work, run (prefill +
    // decode) serially, finish after the combined latency.
    fn maybe_start_iter(
        ri: usize,
        now: f64,
        reps: &mut [Replica],
        reqs: &[Request],
        cm: &CostModel,
        chunk: Option<usize>,
        q: &mut EventQueue<Ev>,
    ) {
        let st = &mut reps[ri];
        if st.iterating {
            return;
        }
        // Per-iteration prefill token budget (Fig. 1 saturation point); in
        // chunked mode `chunk` additionally bounds per-request work so long
        // prompts spread over iterations.
        let per_req = chunk.unwrap_or(usize::MAX);
        let projected = |infl: &[PendingPrefill]| -> f64 {
            infl.iter().map(|p| p.remaining.min(per_req) as f64).sum()
        };
        while st.running.len() + st.inflight_prefill.len() < st.max_batch {
            let Some(p) = st.queue.front() else { break };
            let next_work = p.remaining.min(per_req) as f64;
            if !st.inflight_prefill.is_empty()
                && projected(&st.inflight_prefill) + next_work > PREFILL_TOKEN_BUDGET
            {
                break;
            }
            let p = st.queue.pop_front().unwrap();
            st.inflight_prefill.push(p);
        }
        if st.running.is_empty() && st.inflight_prefill.is_empty() {
            return;
        }
        // Prefill work this iteration: chunks (or whole remainders) within
        // the shared iteration budget.
        let mut pf_tokens = 0.0;
        let mut pf_reqs = 0usize;
        for p in st.inflight_prefill.iter_mut() {
            if pf_tokens >= PREFILL_TOKEN_BUDGET && pf_reqs > 0 {
                break;
            }
            let work = p.remaining.min(per_req);
            if work == 0 {
                continue;
            }
            pf_tokens += work as f64;
            p.remaining -= work;
            pf_reqs += 1;
        }
        let avg_ctx = if st.running.is_empty() {
            0.0
        } else {
            st.running
                .iter()
                .map(|r| (reqs[r.req].input_len + r.generated) as f64)
                .sum::<f64>()
                / st.running.len() as f64
        };
        let mut lat = 0.0;
        if pf_reqs > 0 && chunk.is_some() {
            // SARATHI-style chunked prefill piggybacks the running decode
            // tokens into the prefill chunk: one fused kernel over
            // (chunk + batch) tokens. The weight scan that bounds the decode
            // step is shared with the prefill GEMM, so the fused iteration
            // costs the max of the two phases rather than their sum — this
            // is why chunking helps (Appendix D).
            let fused_tokens = pf_tokens + st.running.len() as f64;
            let pf_t = cm.prefill_latency(&st.cfg, &TaskProfile::new(1, fused_tokens, 0.0));
            let dec_t = if st.running.is_empty() {
                0.0
            } else {
                cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx)
            };
            lat += pf_t.max(dec_t);
        } else {
            // Plain continuous batching: prefill and decode serialize in the
            // iteration (the prefill-decoding interference of Fig. 1).
            if pf_reqs > 0 {
                let t = TaskProfile::new(pf_reqs, pf_tokens / pf_reqs as f64, 0.0);
                lat += cm.prefill_latency(&st.cfg, &t);
            }
            if !st.running.is_empty() {
                lat += cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx);
            }
        }
        st.iterating = true;
        q.push(now + lat, Ev::IterDone(ri));
    }

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::Arrive(r) => {
                // Least-outstanding-work routing.
                let ri = (0..reps.len())
                    .min_by_key(|&i| {
                        reps[i].queue.len() + reps[i].running.len() + reps[i].inflight_prefill.len()
                    })
                    .unwrap();
                reps[ri]
                    .queue
                    .push_back(PendingPrefill { req: r, remaining: reqs[r].input_len });
                maybe_start_iter(ri, now, &mut reps, reqs, &cm, chunk, &mut q);
            }
            Ev::IterDone(ri) => {
                let st = &mut reps[ri];
                st.iterating = false;
                // Decode progress.
                let mut finished = Vec::new();
                for run in st.running.iter_mut() {
                    run.generated += 1;
                    if run.generated >= reqs[run.req].output_len {
                        finished.push(run.req);
                    }
                }
                st.running.retain(|run| run.generated < reqs[run.req].output_len);
                // Prefills that completed all chunks: first token produced.
                let mut done_pf = Vec::new();
                st.inflight_prefill.retain(|p| {
                    if p.remaining == 0 {
                        done_pf.push(p.req);
                        false
                    } else {
                        true
                    }
                });
                for r in done_pf {
                    prefill_done_at[r] = now;
                    if reqs[r].output_len <= 1 {
                        finished.push(r);
                    } else {
                        st.running.push(Running { req: r, generated: 1 });
                    }
                }
                for r in finished {
                    records.push(RequestRecord {
                        id: reqs[r].id,
                        arrival: reqs[r].arrival,
                        prefill_done: prefill_done_at[r],
                        completion: now,
                        input_len: reqs[r].input_len,
                        output_len: reqs[r].output_len,
                        slo_base: slo_base(model, &reqs[r]),
                    });
                }
                maybe_start_iter(ri, now, &mut reps, reqs, &cm, chunk, &mut q);
            }
        }
    }

    SimReport::from_records(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::workload::WorkloadKind;

    fn one_replica(_c: &Cluster) -> Vec<ReplicaConfig> {
        vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])]
    }

    #[test]
    fn completes_all_requests() {
        let c = settings::homogeneous_small();
        let trace = Trace::offline(WorkloadKind::Lpld, 40, 1);
        let rep = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, None);
        assert_eq!(rep.records.len(), 40);
        assert!(rep.tokens_per_s() > 0.0);
    }

    #[test]
    fn prefill_storm_inflates_decode_latency() {
        // The interference mechanism itself (Fig. 1 bottom): the same trace
        // with an added storm of heavy prefills must delay the completions of
        // decode-heavy requests on a colocated replica.
        let c = settings::homogeneous_small();
        let quiet = Trace::offline(WorkloadKind::Lphd, 10, 7);
        let mut stormy = quiet.clone();
        let base = stormy.requests.len();
        for i in 0..60 {
            stormy.requests.push(crate::workload::Request {
                id: base + i,
                arrival: 0.0,
                input_len: 2048,
                output_len: 8,
            });
        }
        let r_quiet = run_colocated(&c, &OPT_30B, &one_replica(&c), &quiet, None);
        let r_storm = run_colocated(&c, &OPT_30B, &one_replica(&c), &stormy, None);
        // Compare the same 10 decode-heavy requests.
        let lat = |rep: &crate::simulator::SimReport| {
            let mut v: Vec<f64> = rep
                .records
                .iter()
                .filter(|r| r.id < base)
                .map(|r| r.latency())
                .collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            crate::util::stats::mean(&v)
        };
        assert!(
            lat(&r_storm) > lat(&r_quiet) * 1.3,
            "no interference visible: {} vs {}",
            lat(&r_storm),
            lat(&r_quiet)
        );
    }

    #[test]
    fn disaggregation_within_range_of_colocation_at_small_scale() {
        // At 4-GPU scale the paper's own Table 4 shows disaggregation and
        // colocation trading wins per workload; assert the simulator keeps
        // them in the same ballpark (the decisive gaps appear at cluster
        // scale in the Fig. 6/7 harnesses).
        use crate::scheduler::{self, ScheduleOptions};
        let c = settings::homogeneous_small();
        let trace = Trace::offline(WorkloadKind::Hphd, 80, 2);
        let colo = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, None);
        let mut opts = ScheduleOptions::new(WorkloadKind::Hphd);
        opts.max_rounds = 6;
        opts.force_k = Some(2);
        let sched = scheduler::schedule(&c, &OPT_30B, &opts).unwrap();
        let disagg = crate::simulator::run_disaggregated(&c, &OPT_30B, &sched.placement, &trace);
        let ratio = disagg.tokens_per_s() / colo.tokens_per_s();
        assert!(
            (0.4..2.5).contains(&ratio),
            "disagg {} vs colo {}",
            disagg.tokens_per_s(),
            colo.tokens_per_s()
        );
    }

    #[test]
    fn chunked_prefill_improves_light_decode_workloads() {
        // Appendix D: chunked prefill helps most on HPLD/LPLD.
        let c = settings::homogeneous_small();
        let trace = Trace::offline(WorkloadKind::Hpld, 60, 3);
        let plain = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, None);
        let chunked = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, Some(512));
        assert_eq!(plain.records.len(), chunked.records.len());
        // Chunked must not be drastically worse; typically better on HPLD.
        assert!(chunked.tokens_per_s() > plain.tokens_per_s() * 0.8);
    }

    #[test]
    fn multiple_replicas_share_load() {
        let c = settings::homogeneous();
        let two = vec![
            ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers]),
            ReplicaConfig::new(vec![(4..8).collect()], vec![OPT_30B.n_layers]),
        ];
        let one = vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])];
        let trace = Trace::offline(WorkloadKind::Lphd, 100, 4);
        let r2 = run_colocated(&c, &OPT_30B, &two, &trace, None);
        let r1 = run_colocated(&c, &OPT_30B, &one, &trace, None);
        // Decode throughput is batch-bound, so doubling replicas mostly
        // helps the prefill phase here; require a strict improvement.
        assert!(r2.tokens_per_s() > r1.tokens_per_s(), "{} vs {}", r2.tokens_per_s(), r1.tokens_per_s());
    }
}
