//! Bench: §5.4-style rescheduling case study — steady-state throughput with
//! and without online rescheduling on a phased LPHD→HPLD trace, an
//! *oscillating* LPHD↔HPLD trace (the hysteresis must bound the switch
//! count), plus the warm-start vs cold-start re-plan wall-clock.
//! HEXGEN2_FULL=1 lengthens the phases to full-study durations.
use hexgen2::cluster::settings;
use hexgen2::experiments::{resched, ExpOpts};
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{self, ScheduleOptions};
use hexgen2::util::bench;
use hexgen2::workload::WorkloadKind;

fn main() {
    let opts = ExpOpts::from_env();
    let cluster = settings::case_study();
    let Some(spec) = resched::default_phases(&cluster, &OPT_30B, &opts) else {
        eprintln!("no feasible placement on {}", cluster.name);
        return;
    };
    let Some(cs) = resched::case_resched(&cluster, &OPT_30B, &spec, &opts) else {
        eprintln!("case study failed to schedule");
        return;
    };
    cs.table.print("Rescheduling case study (case_study cluster, OPT-30B)");
    resched::print_summary(&cs);

    // Oscillating mix at the same rate: LPHD -> HPLD -> LPHD -> HPLD. Three
    // sustained shifts; the hysteresis + net-benefit gate must keep the
    // switch count at or below that.
    let rate = spec[0].1;
    let phase_s = if opts.quick { 90.0 } else { 300.0 };
    let osc = [
        (WorkloadKind::Lphd, rate, phase_s),
        (WorkloadKind::Hpld, rate, phase_s),
        (WorkloadKind::Lphd, rate, phase_s),
        (WorkloadKind::Hpld, rate, phase_s),
    ];
    if let Some(ocs) = resched::case_resched(&cluster, &OPT_30B, &osc, &opts) {
        ocs.table.print("Oscillating trace (LPHD <-> HPLD x2)");
        println!(
            "oscillation: {} drift event(s), {} switch(es) for 3 sustained shifts (no thrash)",
            ocs.n_events, ocs.n_switches
        );
    }

    // Time the warm vs cold re-plan directly (same cluster, HPLD target).
    let mut base = opts.sched_opts(WorkloadKind::Lphd);
    base.force_k = Some(4);
    let incumbent = scheduler::schedule(&cluster, &OPT_30B, &base)
        .expect("incumbent")
        .placement;
    let mut shifted = base.clone();
    shifted.workload = WorkloadKind::Hpld;
    bench::time("resched/replan-cold-case-hpld", 1, 5, || {
        std::hint::black_box(scheduler::schedule(&cluster, &OPT_30B, &shifted));
    });
    bench::time("resched/replan-warm-case-hpld", 1, 5, || {
        std::hint::black_box(hexgen2::rescheduler::warmstart::replan(
            &cluster, &OPT_30B, &shifted, &incumbent,
        ));
    });
}
