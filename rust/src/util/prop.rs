//! Mini property-testing harness (the offline registry has no proptest).
//!
//! `check(seed, cases, |rng| ...)` runs a closure over many deterministic
//! random cases; on failure it reports the case index and per-case seed so
//! the exact failure reproduces with `case(seed)`. Used by the scheduler,
//! max-flow, router, and simulator invariant tests (DESIGN.md §8).

use crate::util::rng::Rng;

/// Run `cases` property checks. The closure gets a per-case RNG and returns
/// `Err(msg)` to fail. Panics with the reproducing seed on failure.
pub fn check<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(1_000_003).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (reproduce with seed {case_seed}): {msg}"
            );
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check(1, 200, |rng| {
            let a = rng.range(0, 100);
            let b = rng.range(0, 100);
            prop_assert!(a + b >= a, "overflow {a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_bad_property() {
        check(2, 200, |rng| {
            let a = rng.range(0, 100);
            prop_assert!(a < 99, "hit {a}");
            Ok(())
        });
    }
}
