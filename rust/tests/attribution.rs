//! Critical-path latency attribution contracts (DESIGN.md §16): per-request
//! blame vectors sum *bit-exactly* to measured end-to-end latency at sample
//! rate 1.0 on every serving shape (disaggregated, shared-NIC heterogeneous,
//! colocated); the streaming `RecordMode::Windowed` accumulator reproduces
//! the Full-mode aggregates from the same event stream; and the bottleneck
//! advisor names the injected bottleneck in constructed scenarios — a
//! throttled KV NIC, a starved decode pool, an undersized prefill pool —
//! and prices levers against the incumbent partition. The satellite closed
//! loop: with attribution on, `ReschedBackend`'s drift audit records carry
//! the blamed component.

use std::collections::BTreeMap;

use hexgen2::cluster::settings;
use hexgen2::costmodel::{ReplicaConfig, TaskProfile};
use hexgen2::deploy::{DeploymentSpec, HexGen2Planner, ReschedBackend, SimBackend};
use hexgen2::model::OPT_30B;
use hexgen2::rescheduler::MonitorConfig;
use hexgen2::scheduler::{self, Objective, Placement, ScheduleOptions};
use hexgen2::simulator::{
    run_colocated_cfg, run_disaggregated_cfg, LinkModel, RecordMode, SimConfig, SimReport,
};
use hexgen2::telemetry::attribution::{
    self, ADMISSION_WAIT, COMPONENT_NAMES, DECODE_BATCH_WAIT, KV_SERIALIZE_WAIT, KV_TRANSMIT,
    N_COMPONENTS,
};
use hexgen2::telemetry::{advise, AdvisorCtx, AttrReport, AuditRecord, Lane, TraceEvent};
use hexgen2::workload::{Trace, WorkloadKind};

fn schedule(
    cluster: &hexgen2::cluster::Cluster,
    kind: WorkloadKind,
    k: usize,
    seed: u64,
) -> Placement {
    let mut opts = ScheduleOptions::new(kind);
    opts.max_rounds = 4;
    opts.force_k = Some(k);
    opts.seed = seed;
    scheduler::schedule(cluster, &OPT_30B, &opts).expect("schedules").placement
}

fn attributed(cfg: SimConfig) -> SimConfig {
    SimConfig { trace: true, trace_sample_rate: 1.0, attribution: true, ..cfg }
}

/// The conservation invariant at sample 1.0: every finished request has a
/// blame vector whose components sum bit-exactly to its measured latency,
/// and the per-request spans agree with the engine's own records.
fn assert_blame_conserved(rep: &SimReport, what: &str) {
    let attr = rep.attr.as_ref().unwrap_or_else(|| panic!("{what}: attribution was on"));
    assert_eq!(attr.n, rep.records.len(), "{what}: one blame vector per completion");
    assert_eq!(attr.requests.len(), attr.n, "{what}: Full mode keeps per-request vectors");
    let by_id: BTreeMap<u32, &hexgen2::simulator::RequestRecord> =
        rep.records.iter().map(|r| (r.id as u32, r)).collect();
    for rb in &attr.requests {
        let rec = by_id
            .get(&rb.req)
            .unwrap_or_else(|| panic!("{what}: blamed request {} has no record", rb.req));
        assert_eq!(rb.arrival, rec.arrival, "{what}: arrival of request {}", rb.req);
        assert_eq!(rb.finish, rec.completion, "{what}: completion of request {}", rb.req);
        // The invariant itself: bit-exact, not within-epsilon.
        assert_eq!(
            rb.blame.total(),
            rb.latency(),
            "{what}: request {} blame does not sum to latency",
            rb.req
        );
        for i in 0..N_COMPONENTS {
            assert!(
                rb.blame.c[i] >= -1e-9 * rb.latency().max(1.0),
                "{what}: request {} component {} is negative: {}",
                rb.req,
                COMPONENT_NAMES[i],
                rb.blame.c[i]
            );
        }
    }
    // Aggregate residual is pure summation re-ordering: ulp scale.
    assert!(
        attr.residual_s().abs() <= 1e-9 * attr.latency_sum.max(1.0),
        "{what}: aggregate residual {} vs Σ latency {}",
        attr.residual_s(),
        attr.latency_sum
    );
    // The KV anchor accumulates in engine emission order on both sides.
    assert_eq!(
        attr.kv_wait_seen_s, rep.stats.kv_link_wait_s,
        "{what}: KV queue-wait anchor not bit-exact"
    );
}

#[test]
fn blame_conserves_latency_case_study_disagg() {
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let trace = Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 11);
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &attributed(SimConfig::default()));
    assert!(rep.stats.kv_transfers > 0, "disagg run moved no KV");
    assert_blame_conserved(&rep, "case_study disagg");
    let attr = rep.attr.as_ref().unwrap();
    // A disaggregated run transfers KV, so route/NIC blame exists and the
    // route map's serialize column folds only finished requests' waits.
    assert!(!attr.per_route.is_empty(), "no KV route blame on a disagg run");
    assert!(!attr.per_nic.is_empty());
}

#[test]
fn blame_conserves_latency_het1_shared_nic() {
    // Heterogeneous slow routes + serialized NICs: waits are nonzero and
    // the KV components must still close bit-exactly.
    let c = settings::het1();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 7);
    let trace = Trace::offline(WorkloadKind::Lphd, 80, 13);
    let cfg = SimConfig { link: LinkModel::SharedNic, ..SimConfig::default() };
    let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &attributed(cfg));
    assert_blame_conserved(&rep, "het1 shared-NIC disagg");
}

#[test]
fn blame_conserves_latency_colocated() {
    let c = settings::homogeneous_small();
    let replicas = vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])];
    let trace = Trace::online(WorkloadKind::Lpld, 1.5, 60.0, 3);
    let rep = run_colocated_cfg(
        &c,
        &OPT_30B,
        &replicas,
        &trace,
        Some(512),
        &attributed(SimConfig::default()),
    );
    assert_blame_conserved(&rep, "colocated chunked prefill");
    // Colocated serving moves no KV: those components stay exactly zero.
    let attr = rep.attr.as_ref().unwrap();
    assert_eq!(attr.totals.c[KV_SERIALIZE_WAIT], 0.0);
    assert_eq!(attr.totals.c[KV_TRANSMIT], 0.0);
    assert!(attr.per_route.is_empty());
}

#[test]
fn attribution_does_not_perturb_the_simulation() {
    // The attribution tee is observation only: records and counters equal
    // the trace-only run's bit-for-bit.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let trace = Trace::online(WorkloadKind::Lphd, 2.0, 60.0, 11);
    let plain = SimConfig { trace: true, trace_sample_rate: 1.0, ..SimConfig::default() };
    let off = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &plain);
    let on = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &attributed(SimConfig::default()));
    assert!(off.attr.is_none());
    assert!(on.attr.is_some());
    assert_eq!(off.records.len(), on.records.len());
    assert_eq!(off.tokens_per_s(), on.tokens_per_s());
    assert_eq!(off.stats.events, on.stats.events);
    assert_eq!(off.stats.kv_link_wait_s, on.stats.kv_link_wait_s);
    for (x, y) in off.records.iter().zip(&on.records) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.completion, y.completion);
    }
}

#[test]
fn windowed_attribution_matches_full_mode() {
    // Satellite: the streaming accumulator sees the identical event stream
    // (tracing never perturbs the engine), so every aggregate — totals,
    // window series, sketch quantiles, the KV anchor — matches Full mode
    // bit-for-bit; only the per-request vectors are dropped.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let trace = Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 11);
    let full = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &attributed(SimConfig::default()));
    let wcfg = SimConfig { record_mode: RecordMode::Windowed, ..SimConfig::default() };
    let win = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &attributed(wcfg));
    let (fa, wa) = (full.attr.as_ref().unwrap(), win.attr.as_ref().unwrap());
    assert!(!fa.requests.is_empty(), "Full mode keeps per-request vectors");
    assert!(wa.requests.is_empty(), "Windowed mode must drop per-request vectors");
    assert_eq!(fa.n, wa.n);
    assert_eq!(fa.open_at_end, wa.open_at_end);
    for i in 0..N_COMPONENTS {
        assert_eq!(fa.totals.c[i], wa.totals.c[i], "component {}", COMPONENT_NAMES[i]);
    }
    assert_eq!(fa.latency_sum, wa.latency_sum);
    assert_eq!(fa.ttft_sum, wa.ttft_sum);
    assert_eq!(fa.kv_wait_seen_s, wa.kv_wait_seen_s);
    assert_eq!(fa.windows, wa.windows);
    assert_eq!(fa.per_replica, wa.per_replica);
    assert_eq!(fa.per_route, wa.per_route);
    assert_eq!(fa.per_nic, wa.per_nic);
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(fa.ttft_sketch.quantile(q), wa.ttft_sketch.quantile(q), "ttft p{q}");
        assert_eq!(fa.tbt_sketch.quantile(q), wa.tbt_sketch.quantile(q), "tbt p{q}");
        assert_eq!(fa.latency_sketch.quantile(q), wa.latency_sketch.quantile(q), "latency p{q}");
    }
    // Windowed-memory contract: the report works without any per-request
    // state surviving, and the blame still sums to the measured latency.
    assert!(
        (wa.residual_s()).abs() <= 1e-9 * wa.latency_sum.max(1.0),
        "windowed residual {}",
        wa.residual_s()
    );
}

// ---------------------------------------------------------------------------
// Injected-bottleneck advisor scenarios
// ---------------------------------------------------------------------------

/// One fully-controlled request chain: every phase duration is injected, so
/// the dominant blame component is known by construction.
#[allow(clippy::too_many_arguments)]
fn chain(
    req: u32,
    t0: f64,
    admission: f64,
    prefill: f64,
    kv_wait: f64,
    kv_xmit: f64,
    batch_wait: f64,
    decode: f64,
) -> Vec<(f64, TraceEvent)> {
    let t_admit = t0 + admission;
    let t_pd = t_admit + prefill;
    let t_kv = t_pd + kv_wait + kv_xmit;
    let t_join = t_kv + batch_wait;
    let t_fin = t_join + decode;
    vec![
        (t0, TraceEvent::Arrive { req }),
        (t_admit, TraceEvent::Admit { req, replica: 0 }),
        (t_admit, TraceEvent::PrefillChunk { req, replica: 0, chunk: 0 }),
        (t_admit, TraceEvent::Burst { replica: 0, lane: Lane::Prefill, dur_s: prefill }),
        (t_pd, TraceEvent::PrefillDone { req, replica: 0 }),
        (t_pd, TraceEvent::KvEnqueue { req, src: 0, dst: 1, bytes: 1e6, wait_s: kv_wait }),
        (t_kv, TraceEvent::KvDone { req, src: 0, dst: 1 }),
        (t_join, TraceEvent::DecodeJoin { req, replica: 1 }),
        (t_fin, TraceEvent::Finish { req, replica: 1, output_len: 8 }),
    ]
}

fn report_of(chains: Vec<Vec<(f64, TraceEvent)>>) -> AttrReport {
    let mut a = attribution::Attributor::new(60.0, true);
    let mut events: Vec<(f64, TraceEvent)> = chains.into_iter().flatten().collect();
    events.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    for (t, ev) in events {
        a.observe(t, ev);
    }
    a.finish()
}

#[test]
fn advisor_names_throttled_kv_nic() {
    // Every request queues ~5 s behind a serialized NIC; everything else
    // is fast. The advisor must blame KV serialization and prescribe
    // bandwidth.
    let rep = report_of(
        (0..8).map(|i| chain(i, i as f64 * 20.0, 0.05, 0.2, 5.0, 1.0, 0.05, 0.3)).collect(),
    );
    assert_eq!(rep.n, 8);
    assert_eq!(rep.dominant().0, KV_SERIALIZE_WAIT);
    assert_eq!(rep.dominant_name(), "kv_serialize_wait");
    let advice = advise(&rep, None);
    assert_eq!(advice[0].component_name(), "kv_serialize_wait");
    assert_eq!(advice[0].lever, "add-kv-bandwidth");
    assert!(advice[0].share > 0.5, "injected bottleneck owns the latency");
    // The NIC split points at the throttled egress NIC.
    let (wait, _xmit) = rep.per_nic.get(&0).copied().expect("NIC 0 blamed");
    assert!((wait - 8.0 * 5.0).abs() < 1e-9);
}

#[test]
fn advisor_names_starved_decode_pool() {
    // KV arrives promptly but requests sit ~6 s waiting for a decode slot.
    let rep = report_of(
        (0..8).map(|i| chain(i, i as f64 * 20.0, 0.05, 0.2, 0.05, 0.1, 6.0, 0.4)).collect(),
    );
    assert_eq!(rep.dominant().0, DECODE_BATCH_WAIT);
    let advice = advise(&rep, None);
    assert_eq!(advice[0].component_name(), "decode_batch_wait");
    assert_eq!(advice[0].lever, "shift-pd-split-toward-decode");
    assert!(advice[0].share > 0.5);
}

#[test]
fn advisor_names_undersized_prefill_pool() {
    // Admission queues ~4 s before a prefill slot opens (and prefill itself
    // runs 2 s): prefill-side blame dominates and the lever shifts the P:D
    // split toward prefill.
    let rep = report_of(
        (0..8).map(|i| chain(i, i as f64 * 20.0, 4.0, 2.0, 0.05, 0.1, 0.05, 0.3)).collect(),
    );
    assert_eq!(rep.dominant().0, ADMISSION_WAIT);
    let advice = advise(&rep, None);
    assert_eq!(advice[0].component_name(), "admission_wait");
    assert_eq!(advice[0].lever, "shift-pd-split-toward-prefill");
    // The prefill family (admission + queue + compute) owns the latency.
    let prefill_side: f64 = advice
        .iter()
        .filter(|a| a.lever == "shift-pd-split-toward-prefill")
        .map(|a| a.share)
        .sum();
    assert!(prefill_side > 0.5, "prefill-side share {prefill_side}");
}

#[test]
fn advisor_prices_levers_against_the_incumbent() {
    // With a real incumbent partition in context, every advice line carries
    // the incumbent's re-scored objective; un-discounting the KV fabric
    // can only help (apply_kv_contention never raises a score).
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let (s_in, s_out) = WorkloadKind::Lphd.mean_lengths();
    let ctx = AdvisorCtx {
        cluster: &c,
        model: &OPT_30B,
        task: TaskProfile::new(1, s_in, s_out),
        period: 600.0,
        groups: p.groups.iter().map(|g| g.devices.clone()).collect(),
        objective: Objective::Throughput,
        link: Some(LinkModel::SharedNic),
    };
    let rep = report_of(
        (0..4).map(|i| chain(i, i as f64 * 20.0, 0.05, 0.2, 5.0, 1.0, 0.05, 0.3)).collect(),
    );
    let advice = advise(&rep, Some(&ctx));
    assert!(!advice.is_empty());
    assert!(
        advice[0].baseline_score > 0.0,
        "incumbent re-score failed: {}",
        advice[0].baseline_score
    );
    for a in &advice {
        assert!(a.predicted_score.is_finite() && a.predicted_score >= 0.0);
        assert_eq!(a.baseline_score, advice[0].baseline_score, "one shared baseline");
        if a.lever == "add-kv-bandwidth" {
            assert!(
                a.gain() >= -1e-12,
                "dropping the KV discount lowered the score: {}",
                a.gain()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Deploy + rescheduler integration
// ---------------------------------------------------------------------------

#[test]
fn deployment_report_carries_attribution() {
    let spec = DeploymentSpec::new(settings::case_study(), OPT_30B)
        .workload(WorkloadKind::Lphd)
        .quick(true)
        .force_k(4)
        .max_rounds(4)
        .attribution(true);
    let dep = spec.plan(&HexGen2Planner).expect("plans");
    let trace = Trace::offline(WorkloadKind::Lphd, 40, 4);
    let rep = dep.run(&SimBackend, &trace).expect("runs");
    assert!(rep.attr.is_some(), "attribution implies tracing and a report");
    let j = dep.report_json(&rep);
    let a = j.get("attribution").expect("report embeds the attribution block");
    assert_eq!(a.get("schema").unwrap().as_str(), Some("hexgen2-attr/v1"));
    assert_eq!(
        a.get("n_requests").unwrap().as_usize(),
        Some(rep.records.len()),
        "every completion attributed"
    );
    let resid = a.get("conservation_residual_s").unwrap().as_f64().unwrap();
    let lat = a.get("latency_sum_s").unwrap().as_f64().unwrap();
    assert!(resid.abs() <= 1e-9 * lat.max(1.0), "residual {resid} vs Σ latency {lat}");
    let advisor = a.get("advisor").unwrap().as_arr().unwrap();
    assert!(!advisor.is_empty(), "disagg plan prices at least one lever");
    assert!(
        advisor[0].get("baseline_score").unwrap().as_f64().unwrap() > 0.0,
        "deploy layer supplied the advisor context"
    );
}

#[test]
fn drift_audit_records_carry_blamed_component() {
    // Satellite closed loop: attribution on + a microsecond KV threshold —
    // the pre-epoch blame report's dominant component is stamped into
    // every drift the monitor fires.
    let spec = DeploymentSpec::new(settings::case_study(), OPT_30B)
        .workload(WorkloadKind::Lphd)
        .quick(true)
        .force_k(4)
        .max_rounds(4)
        .link(LinkModel::SharedNic)
        .attribution(true);
    let dep = spec.plan(&HexGen2Planner).expect("plans");
    let trace = Trace::online(WorkloadKind::Lphd, 6.0, 120.0, 5);
    let backend = ReschedBackend {
        monitor: MonitorConfig {
            window: 30.0,
            min_samples: 10,
            dwell: 3.0,
            rate_band: 1e9,
            kv_wait_threshold_s: 1e-6,
        },
        modeled_replan_s: 5.0,
    };
    let rep = dep.run(&backend, &trace).expect("resched runs");
    let drifts: Vec<&AuditRecord> =
        rep.audit.iter().filter(|r| matches!(r, AuditRecord::Drift { .. })).collect();
    assert!(!drifts.is_empty(), "contention never fired a drift");
    for d in &drifts {
        let AuditRecord::Drift { blamed, .. } = d else { unreachable!() };
        assert!(
            COMPONENT_NAMES.contains(&blamed.as_str()),
            "drift blamed {blamed:?}, not an attribution component"
        );
    }
}
