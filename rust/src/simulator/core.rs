//! The unified discrete-event simulation core: **one** event engine driving
//! **pluggable per-replica phase policies**.
//!
//! Before this module existed, `disagg.rs` and `colocated.rs` were two
//! parallel event loops with duplicated event enums, replica structs,
//! admission logic, and metrics plumbing. Now there is a single
//! [`simulate`] driver — clock, replica arena, request router, KV-link
//! queues, quiesce/drain/activate rescheduling, and record collection —
//! and everything phase-specific lives behind the [`ReplicaPolicy`] trait:
//!
//! - [`DisaggPrefill`]: token-budget prefill batching (Fig. 1), optionally
//!   SARATHI-style chunked so long prompts interleave with later arrivals.
//! - [`DisaggDecode`]: continuous batching gated on KV-cache arrival.
//! - [`Colocated`]: interleaved prefill+decode iterations (HexGen / vLLM
//!   style), chunked or not — the interference baseline the paper
//!   disaggregates away from.
//!
//! Event lifecycle (see DESIGN.md §9 for the full diagram):
//!
//! ```text
//! Arrive(r) ──router──▶ entry replica ─▶ Service(i) ─▶ outcomes:
//!     KvReady(r)   → KV link queue → KvArrive{p,d,r} → decode replica
//!     FirstToken(r)→ TTFT recorded (colocated: first token in place)
//!     Finished(r)  → RequestRecord
//! Resched(i) quiesces the active set (unstarted work → holding buffer);
//! Activate(i) builds the switch's replicas — disaggregated *or* colocated —
//! and flushes the holding buffer, so the §3.3 drain/activate machinery
//! works for any policy mix.
//! ```
//!
//! Two admission models ([`Sizing`]): the legacy *static mean-length*
//! sizing (batch caps frozen at trace-mean lengths, as in the original
//! engines) and *per-request accounting*, where every resident request
//! reserves its actual token footprint against the replica's memory
//! ([`CostModel::token_capacity`]) and waits in queue under memory
//! pressure — the regime where heavy-tailed traces behave nothing like
//! their means. KV transfers are owned end-to-end by the
//! [`kvtransfer`](crate::kvtransfer) subsystem (DESIGN.md §11): the engine
//! hands every prefill→decode cache to a
//! [`TransferScheduler`](crate::kvtransfer::TransferScheduler), which picks
//! a route under the configured [`RouteModel`] (flow-proportional legacy,
//! least-loaded, or ETA-greedy), reserves the link under the configured
//! [`LinkModel`] (per-route or shared-NIC), optionally pipelines the push
//! in layer-wise chunks that overlap the producing prefill burst, and
//! accounts everything in a link-load ledger exported through
//! [`SimStats`] / [`SimReport::link_loads`](super::SimReport).

use std::collections::{BTreeMap, VecDeque};

use crate::cluster::Cluster;
use crate::costmodel::{CostModel, ReplicaConfig, TaskProfile, MAX_DECODE_BATCH};
use crate::kvtransfer::{
    EvictRecord, LinkModel, PrefixPool, PrefixTier, RouteModel, TransferConfig, TransferScheduler,
};
use crate::model::LlmSpec;
use crate::scheduler::Placement;
use crate::telemetry::{Lane, NoopSink, Recorder, TraceEvent, TraceSink};
use crate::workload::{Request, Trace, TraceSource, WorkloadKind};

use super::events::EventQueue;
use super::metrics::{RequestRecord, SimReport, SimStats, WindowedAgg};
use super::{slo_base, PREFILL_TOKEN_BUDGET};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Fraction of a prefill replica's [`CostModel::token_capacity`] carved out
/// as its prefix-pool GPU budget when [`SimConfig::prefix_gpu_budget`] is
/// not set (LMCache-style: the cache shares device memory with live KV).
pub const PREFIX_POOL_GPU_FRACTION: f64 = 0.2;

/// Host → GPU re-load bandwidth for host-tier prefix hits, bytes/s
/// (PCIe-class staging path: pinned host memory over a 16 GB/s link).
pub const HOST_RELOAD_BYTES_PER_S: f64 = 16.0e9;

/// How replicas admit work against their memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Sizing {
    /// Pre-size batches from the trace's *mean* lengths (the original
    /// engines' behaviour): prefill batch = largest memory-feasible batch
    /// at the mean input length, decode slots = `max_decode_batch` at the
    /// mean task profile.
    #[default]
    StaticMean,
    /// Per-request KV/memory accounting at admission time: each resident
    /// request reserves its actual `s_in` (+ generation budget on decode /
    /// colocated replicas) against [`CostModel::token_capacity`]; requests
    /// that do not fit wait in queue (observable as
    /// [`SimStats::mem_stalls`]), and requests larger than every replica's
    /// memory are rejected rather than wedging the queue.
    PerRequest,
}

/// What the engine keeps per completed request (DESIGN.md §14).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RecordMode {
    /// One [`RequestRecord`] per completion (the historical behaviour):
    /// exact percentiles, `windowed()` sub-reports, per-request `--json`
    /// spans — at O(trace length) memory.
    #[default]
    Full,
    /// Fold each completion into a [`WindowedAgg`] (sums + t-digest
    /// quantile sketches) and keep no per-request records: O(1) memory per
    /// completion, so million-request streaming runs fit in RAM.
    /// Percentiles and SLO scales become sketch approximations (exact up
    /// to the centroid cap, ≲2% relative error beyond it), and
    /// `windowed()` / per-request trace spans are unavailable.
    Windowed,
}

/// Knobs of one simulation run. `Default` reproduces the pre-refactor
/// engines' behaviour except that the static prefill-batch cap is derived
/// from device memory instead of the old hardcoded `1..=16` scan.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub sizing: Sizing,
    /// Per-request records vs windowed aggregates (DESIGN.md §14).
    pub record_mode: RecordMode,
    /// SARATHI-style chunked prefill for **disaggregated** prefill replicas
    /// (tokens per chunk). Colocated replicas carry their chunk size in
    /// [`ServingSpec::Colocated`] because it is part of the plan.
    pub chunked_prefill: Option<usize>,
    /// How concurrent KV transfers contend for the fabric (defined by the
    /// transfer engine; `PerRoute` is the legacy assumption).
    pub link: LinkModel,
    /// How each transfer picks among its max-flow-feasible routes
    /// (`FlowProportional` is the legacy §3.3 rule, bit-identical to the
    /// pre-subsystem in-core path).
    pub kv_route: RouteModel,
    /// Layer-wise pipelined KV push: layers per chunk (`None` = whole-cache
    /// transfer). See [`TransferScheduler`] for the overlap model.
    pub kv_chunk_layers: Option<usize>,
    /// Pin the static prefill-batch search bound (None = derive it from
    /// device memory via [`CostModel::max_prefill_batch`]). The golden
    /// parity suite pins this to 16 — the pre-refactor magic constant — to
    /// isolate the engine refactor from that deliberate sizing fix.
    pub static_prefill_cap: Option<usize>,
    /// Record a flight-recorder trace (DESIGN.md §12). Off by default: the
    /// engine then runs with the [`NoopSink`] instantiation and every
    /// emission site compiles away — the PR-4 allocation-free hot path is
    /// untouched.
    pub trace: bool,
    /// Fraction of requests whose lifecycle events are kept (deterministic
    /// per-request hash; replica/engine-scoped events are always kept).
    pub trace_sample_rate: f64,
    /// Ring-buffer capacity of the recorder, in events.
    pub trace_buffer: usize,
    /// Per-prefill-replica prefix-pool GPU budget in tokens (`None` =
    /// [`PREFIX_POOL_GPU_FRACTION`] of the replica's token capacity).
    pub prefix_gpu_budget: Option<f64>,
    /// Host-tier prefix-pool budget in tokens (`None` =
    /// [`HOST_BUDGET_FACTOR`](crate::kvtransfer::prefix::HOST_BUDGET_FACTOR)
    /// × the summed GPU budgets).
    pub prefix_host_budget: Option<f64>,
    /// Critical-path latency attribution (DESIGN.md §16): tee every trace
    /// event through an [`Attributor`](crate::telemetry::Attributor)
    /// *before* sampling/ring wrap and attach the blame report to
    /// [`SimReport::attr`]. Requires [`SimConfig::trace`]; the attributor
    /// state is O(active requests), so it composes with
    /// [`RecordMode::Windowed`] streaming runs.
    pub attribution: bool,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            sizing: Sizing::default(),
            record_mode: RecordMode::default(),
            chunked_prefill: None,
            link: LinkModel::default(),
            kv_route: RouteModel::default(),
            kv_chunk_layers: None,
            static_prefill_cap: None,
            trace: false,
            trace_sample_rate: 1.0,
            trace_buffer: 1 << 20,
            prefix_gpu_budget: None,
            prefix_host_budget: None,
            attribution: false,
        }
    }
}

/// What to instantiate when a serving epoch starts: a disaggregated
/// placement or a set of colocated replicas.
#[derive(Clone, Debug)]
pub enum ServingSpec {
    Disaggregated(Placement),
    Colocated { replicas: Vec<ReplicaConfig>, chunked_prefill: Option<usize> },
}

/// One placement switch of a rescheduling scenario, generalized over
/// paradigms: at `at` the active replicas are quiesced; at `at + delay` the
/// new spec goes live. Unlike the old disagg-only switch type, `to` may be
/// colocated — rescheduling experiments run on the baselines for free.
#[derive(Clone, Debug)]
pub struct SwitchSpec {
    pub at: f64,
    pub delay: f64,
    pub to: ServingSpec,
    /// Workload the new epoch was (re-)planned for: its mean lengths size
    /// the new replicas' static batching. None = keep the trace's opening
    /// statistics.
    pub workload: Option<WorkloadKind>,
}

// ---------------------------------------------------------------------------
// Request store + feed (DESIGN.md §14)
// ---------------------------------------------------------------------------

/// Sliding-window request store: the engine's view of the trace. Requests
/// enter when their arrival event fires (pulled from the [`Feed`]) and are
/// retired when they finish or are rejected, so the window holds only the
/// *active* requests — the memory contract that lets a million-request
/// streaming run fit in RAM. Policies index it exactly like the former
/// `&[Request]` (`env.reqs[r]`); an index is valid from the request's
/// arrival until its retirement.
pub struct ReqStore {
    /// Engine index of `slots[0]`.
    base: usize,
    slots: VecDeque<Slot>,
    n_arrived: usize,
    n_finished: usize,
}

struct Slot {
    req: Request,
    /// When the prefill finished (≈ TTFT); 0.0 until stamped.
    prefill_done: f64,
    /// Tokens this request must actually prefill: `input_len`, minus the
    /// shared-prefix length when the prefix pool served a hit. Memory
    /// footprints and KV transfer sizes still use the full `input_len`
    /// (the reused prefix KV occupies the replica all the same).
    prefill_tokens: usize,
    /// The prefix pool has been consulted for this request (hit, miss, or
    /// re-admission after a host-tier re-load) — never look up twice.
    prefix_resolved: bool,
    /// Retired but not yet popped (retirement is strictly front-to-back).
    dead: bool,
}

impl ReqStore {
    fn new() -> ReqStore {
        ReqStore { base: 0, slots: VecDeque::new(), n_arrived: 0, n_finished: 0 }
    }

    /// Admit the next arriving request; returns its engine index.
    fn push(&mut self, req: Request) -> usize {
        let idx = self.base + self.slots.len();
        let prefill_tokens = req.input_len;
        self.slots.push_back(Slot {
            req,
            prefill_done: 0.0,
            prefill_tokens,
            prefix_resolved: false,
            dead: false,
        });
        self.n_arrived += 1;
        idx
    }

    fn set_prefill_done(&mut self, r: usize, t: f64) {
        self.slots[r - self.base].prefill_done = t;
    }

    fn prefill_done(&self, r: usize) -> f64 {
        self.slots[r - self.base].prefill_done
    }

    /// Tokens request `r` actually prefills (suffix-only after a prefix
    /// hit; full `input_len` otherwise).
    pub fn prefill_tokens(&self, r: usize) -> usize {
        self.slots[r - self.base].prefill_tokens
    }

    fn set_prefill_tokens(&mut self, r: usize, tokens: usize) {
        self.slots[r - self.base].prefill_tokens = tokens;
    }

    fn prefix_resolved(&self, r: usize) -> bool {
        self.slots[r - self.base].prefix_resolved
    }

    fn set_prefix_resolved(&mut self, r: usize) {
        self.slots[r - self.base].prefix_resolved = true;
    }

    /// Drop `r` from the window (finished or rejected — no event can
    /// reference it again). The front of the deque pops as soon as every
    /// older request is also dead, keeping the window at O(active).
    fn retire(&mut self, r: usize) {
        self.slots[r - self.base].dead = true;
        while self.slots.front().is_some_and(|s| s.dead) {
            self.slots.pop_front();
            self.base += 1;
        }
    }

    /// Live (arrived, not yet retired) request count.
    fn live(&self) -> usize {
        self.slots.len()
    }
}

impl std::ops::Index<usize> for ReqStore {
    type Output = Request;

    fn index(&self, r: usize) -> &Request {
        &self.slots[r - self.base].req
    }
}

/// Where the engine pulls its next request from: a borrowed materialized
/// trace or a streaming [`TraceSource`]. Both go through the same bounded
/// arrival frontier (only the next arrival lives in the event heap), so
/// streaming-vs-materialized parity is structural, not coincidental.
enum Feed<'a> {
    Slice { reqs: &'a [Request], next: usize },
    Stream(TraceSource),
}

impl Feed<'_> {
    fn next(&mut self) -> Option<Request> {
        match self {
            Feed::Slice { reqs, next } => {
                let r = reqs.get(*next).copied();
                if r.is_some() {
                    *next += 1;
                }
                r
            }
            Feed::Stream(s) => s.next(),
        }
    }

    /// Requests not yet pulled (drains a streaming source to count it —
    /// only used on the infeasible-initial-epoch bailout path).
    fn count_remaining(&mut self) -> usize {
        match self {
            Feed::Slice { reqs, next } => reqs.len() - *next,
            Feed::Stream(s) => s.by_ref().count(),
        }
    }

    /// Lower bound on the total request count (record preallocation).
    fn len_hint(&self) -> usize {
        match self {
            Feed::Slice { reqs, next } => reqs.len() - *next,
            Feed::Stream(s) => s.size_hint().0,
        }
    }
}

// ---------------------------------------------------------------------------
// The policy abstraction
// ---------------------------------------------------------------------------

/// Read-only simulation context plus the stats sink, handed to policies.
pub struct PolicyEnv<'a, 'b> {
    pub cm: &'a CostModel<'b>,
    /// The active-request window; index with the engine request index
    /// exactly as with the former `&[Request]` slice.
    pub reqs: &'a ReqStore,
    pub sim: &'a SimConfig,
    pub stats: &'a mut SimStats,
    /// Current event time.
    pub now: f64,
    /// Arena index of the replica being driven.
    pub replica: usize,
    /// Flight recorder, `None` when tracing is off. A plain trait object
    /// rather than a generic sink because policies live behind
    /// `dyn ReplicaPolicy`; with tracing off this is a constant `None`
    /// (the engine instantiates [`NoopSink`], whose
    /// [`active()`](TraceSink::active) is an `#[inline(always)]` `None`),
    /// so [`PolicyEnv::emit`] reduces to one predictable branch. Routing
    /// through the sink — not the raw [`Recorder`] — keeps wrapping sinks
    /// (the attribution tee) in the loop for policy-emitted events.
    pub trace: Option<&'a mut dyn TraceSink>,
}

impl PolicyEnv<'_, '_> {
    /// Record `ev` at the current event time (no-op when tracing is off).
    #[inline]
    pub fn emit(&mut self, ev: TraceEvent) {
        if let Some(sink) = self.trace.as_deref_mut() {
            sink.emit(self.now, ev);
        }
    }

    /// Count (and trace) one memory-pressure admission stall on the
    /// replica being driven.
    #[inline]
    pub fn mem_stall(&mut self) {
        self.stats.mem_stalls += 1;
        let replica = self.replica as u32;
        self.emit(TraceEvent::MemStall { replica });
    }
}

/// What a completed service burst did to each affected request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Prefill finished on a disaggregated prefill replica: the engine
    /// stamps TTFT and routes the KV cache to a decode replica.
    KvReady(usize),
    /// Prefill finished on a colocated replica: first token produced in
    /// place, no KV transfer.
    FirstToken(usize),
    /// All output tokens generated: the engine records the request.
    Finished(usize),
}

/// Coarse phase of a replica, used by the engine for routing decisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Prefill,
    Decode,
    Colocated,
}

/// One replica's serving discipline. The engine owns time and transport;
/// a policy owns queues, batch formation, and burst latencies. Adding a new
/// discipline (e.g. priority prefill, speculative decode) means implementing
/// this trait and appending instances from a `ServingSpec` — the driver,
/// router, link queues, and resched machinery come for free (DESIGN.md §9).
pub trait ReplicaPolicy {
    fn kind(&self) -> PolicyKind;
    fn cfg(&self) -> &ReplicaConfig;
    /// Queue a newly admitted request (entry replicas only).
    fn admit(&mut self, req: usize);
    /// KV cache of `req` arrived (decode replicas only).
    fn deliver_kv(&mut self, req: usize);
    /// KV transfer of `req` *out of* this replica completed: drop its
    /// reservation (prefill replicas under per-request accounting).
    fn release_kv(&mut self, req: usize, env: &mut PolicyEnv);
    /// Pull every not-yet-started request back out (quiesce drain).
    fn drain_unstarted(&mut self) -> Vec<usize>;
    /// Start a service burst if idle and work is admissible; returns the
    /// burst latency.
    fn try_start(&mut self, env: &mut PolicyEnv) -> Option<f64>;
    /// The burst the engine timed has completed; report per-request
    /// outcomes in occurrence order.
    fn service_done(&mut self, env: &mut PolicyEnv, out: &mut Vec<Outcome>);
    /// Outstanding work (least-loaded routing).
    fn load(&self) -> usize;
    /// Resident-token capacity (infinite under static sizing).
    fn mem_capacity_tokens(&self) -> f64;
    /// Currently reserved resident tokens.
    fn resident_tokens(&self) -> f64;
}

// ---------------------------------------------------------------------------
// Memory ledger (per-request accounting)
// ---------------------------------------------------------------------------

/// Token-denominated memory ledger of one replica. The Table-1 memory row
/// is linear in resident sequence tokens, so admission control reduces to a
/// scalar budget (see [`CostModel::token_capacity`]).
#[derive(Clone, Copy, Debug)]
struct MemLedger {
    capacity: f64,
    resident: f64,
    enabled: bool,
}

impl MemLedger {
    fn new(cm: &CostModel, cfg: &ReplicaConfig, sizing: Sizing) -> MemLedger {
        MemLedger {
            capacity: cm.token_capacity(cfg),
            resident: 0.0,
            enabled: sizing == Sizing::PerRequest,
        }
    }

    fn fits(&self, tokens: f64) -> bool {
        !self.enabled || self.resident + tokens <= self.capacity
    }

    fn reserve(&mut self, tokens: f64) {
        if self.enabled {
            self.resident += tokens;
        }
    }

    fn free(&mut self, tokens: f64) {
        if self.enabled {
            self.resident = (self.resident - tokens).max(0.0);
        }
    }

    fn capacity_or_inf(&self) -> f64 {
        if self.enabled {
            self.capacity
        } else {
            f64::INFINITY
        }
    }
}

/// A prompt whose prefill is split into chunks.
struct PendingPrefill {
    req: usize,
    remaining: usize,
}

struct Running {
    req: usize,
    generated: usize,
}

/// Token footprint a request pins on a replica that holds its KV through
/// generation (decode and colocated replicas): prompt + full output budget.
fn gen_footprint(r: &Request) -> f64 {
    (r.input_len + r.output_len) as f64
}

/// Shared chunk-admission rule (SARATHI-style, used by both the dedicated
/// prefill policy and the colocated policy so the two cannot drift): pull
/// queued prompts into the in-flight chunk set while slots remain and the
/// next chunk fits the shared iteration token budget; `footprint` is the
/// resident reservation a request takes (prompt-only on dedicated prefill,
/// prompt + generation budget on colocated). Stops — counting a stall —
/// when the head of the queue does not fit the memory ledger.
#[allow(clippy::too_many_arguments)]
fn admit_chunked(
    queue: &mut VecDeque<usize>,
    inflight: &mut Vec<PendingPrefill>,
    occupied_slots: usize,
    max_batch: usize,
    per_req: usize,
    ledger: &mut MemLedger,
    env: &mut PolicyEnv,
    footprint: impl Fn(&Request) -> f64,
) {
    let projected = |infl: &[PendingPrefill]| -> f64 {
        infl.iter().map(|p| p.remaining.min(per_req) as f64).sum()
    };
    while occupied_slots + inflight.len() < max_batch {
        let Some(&r) = queue.front() else { break };
        let remaining = env.reqs.prefill_tokens(r);
        let next_work = remaining.min(per_req) as f64;
        if !inflight.is_empty() && projected(inflight) + next_work > PREFILL_TOKEN_BUDGET {
            break;
        }
        let fp = footprint(&env.reqs[r]);
        if !ledger.fits(fp) {
            env.mem_stall();
            break;
        }
        queue.pop_front();
        ledger.reserve(fp);
        inflight.push(PendingPrefill { req: r, remaining });
    }
}

/// Shared per-iteration chunk work: process up to `per_req` tokens of each
/// in-flight prompt within the shared budget. Returns (tokens processed,
/// prompts touched).
fn chunk_work(inflight: &mut [PendingPrefill], per_req: usize, env: &mut PolicyEnv) -> (f64, usize) {
    let mut tokens = 0.0;
    let mut worked = 0usize;
    for p in inflight.iter_mut() {
        if tokens >= PREFILL_TOKEN_BUDGET && worked > 0 {
            break;
        }
        let work = p.remaining.min(per_req);
        if work == 0 {
            continue;
        }
        if env.trace.is_some() {
            // Chunk index of this iteration's work (0 for the first chunk;
            // whole-prompt mode is a single chunk 0).
            let total = env.reqs.prefill_tokens(p.req);
            let chunk = ((total - p.remaining) / per_req.max(1)) as u32;
            let replica = env.replica as u32;
            env.emit(TraceEvent::PrefillChunk { req: p.req as u32, replica, chunk });
        }
        tokens += work as f64;
        p.remaining -= work;
        worked += 1;
    }
    (tokens, worked)
}

// ---------------------------------------------------------------------------
// DisaggPrefill
// ---------------------------------------------------------------------------

/// Token-budget prefill batching (paper Fig. 1), optionally chunked.
pub struct DisaggPrefill {
    cfg: ReplicaConfig,
    queue: VecDeque<usize>,
    busy: bool,
    /// In-flight unchunked batch.
    batch: Vec<usize>,
    /// In-flight chunk-processed prompts (chunked mode).
    chunks: Vec<PendingPrefill>,
    max_batch: usize,
    chunk: Option<usize>,
    ledger: MemLedger,
}

impl ReplicaPolicy for DisaggPrefill {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Prefill
    }

    fn cfg(&self) -> &ReplicaConfig {
        &self.cfg
    }

    fn admit(&mut self, req: usize) {
        self.queue.push_back(req);
    }

    fn deliver_kv(&mut self, _req: usize) {
        debug_assert!(false, "KV delivered to a prefill replica");
    }

    fn release_kv(&mut self, req: usize, env: &mut PolicyEnv) {
        self.ledger.free(env.reqs[req].input_len as f64);
    }

    fn drain_unstarted(&mut self) -> Vec<usize> {
        self.queue.drain(..).collect()
    }

    fn try_start(&mut self, env: &mut PolicyEnv) -> Option<f64> {
        if self.busy {
            return None;
        }
        match self.chunk {
            None => {
                // Greedy batch under the Fig.-1 token budget; the first
                // request is always admitted so oversized prompts cannot
                // wedge the queue. Built in place into the (empty when not
                // busy) batch buffer — no per-burst allocation.
                debug_assert!(self.batch.is_empty());
                let mut tokens = 0.0;
                let mut max_len = 0usize;
                while let Some(&r) = self.queue.front() {
                    // Compute over the suffix a prefix hit left to prefill;
                    // reserve the full prompt (reused prefix KV included —
                    // it occupies this replica either way).
                    let len = env.reqs[r].input_len;
                    let work = env.reqs.prefill_tokens(r);
                    if !self.batch.is_empty()
                        && (tokens + work as f64 > PREFILL_TOKEN_BUDGET
                            || self.batch.len() >= self.max_batch)
                    {
                        break;
                    }
                    if !self.ledger.fits(len as f64) {
                        env.mem_stall();
                        break;
                    }
                    self.queue.pop_front();
                    self.ledger.reserve(len as f64);
                    tokens += work as f64;
                    max_len = max_len.max(work);
                    self.batch.push(r);
                }
                if self.batch.is_empty() {
                    return None;
                }
                let t = TaskProfile::new(self.batch.len(), max_len as f64, 0.0);
                let lat = env.cm.prefill_latency(&self.cfg, &t);
                self.busy = true;
                Some(lat)
            }
            Some(c) => {
                // SARATHI-style chunking on a dedicated prefill replica:
                // long prompts spread over iterations so later short
                // prompts interleave instead of queueing behind them. A
                // dedicated prefill replica only holds the prompt KV (it
                // ships after the transfer), hence the prompt-only
                // footprint.
                admit_chunked(
                    &mut self.queue,
                    &mut self.chunks,
                    0,
                    self.max_batch,
                    c,
                    &mut self.ledger,
                    env,
                    |r| r.input_len as f64,
                );
                let (tokens, worked) = chunk_work(&mut self.chunks, c, env);
                if worked == 0 {
                    return None;
                }
                let lat = env.cm.prefill_latency(&self.cfg, &TaskProfile::new(1, tokens, 0.0));
                self.busy = true;
                Some(lat)
            }
        }
    }

    fn service_done(&mut self, _env: &mut PolicyEnv, out: &mut Vec<Outcome>) {
        self.busy = false;
        if self.chunk.is_some() {
            self.chunks.retain(|p| {
                if p.remaining == 0 {
                    out.push(Outcome::KvReady(p.req));
                    false
                } else {
                    true
                }
            });
        } else {
            // Drain (not take) so the buffer's allocation is reused by the
            // next burst.
            for r in self.batch.drain(..) {
                out.push(Outcome::KvReady(r));
            }
        }
    }

    fn load(&self) -> usize {
        self.queue.len() + self.batch.len() + self.chunks.len()
    }

    fn mem_capacity_tokens(&self) -> f64 {
        self.ledger.capacity_or_inf()
    }

    fn resident_tokens(&self) -> f64 {
        self.ledger.resident
    }
}

// ---------------------------------------------------------------------------
// DisaggDecode
// ---------------------------------------------------------------------------

/// Continuous batching gated on KV-cache arrival.
pub struct DisaggDecode {
    cfg: ReplicaConfig,
    running: Vec<Running>,
    waiting: VecDeque<usize>,
    stepping: bool,
    max_batch: usize,
    ledger: MemLedger,
}

impl ReplicaPolicy for DisaggDecode {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Decode
    }

    fn cfg(&self) -> &ReplicaConfig {
        &self.cfg
    }

    fn admit(&mut self, _req: usize) {
        debug_assert!(false, "request routed to a decode replica without KV");
    }

    fn deliver_kv(&mut self, req: usize) {
        self.waiting.push_back(req);
    }

    fn release_kv(&mut self, _req: usize, _env: &mut PolicyEnv) {}

    fn drain_unstarted(&mut self) -> Vec<usize> {
        // Waiting requests already hold transferred KV here; they drain on
        // this replica rather than re-entering the prefill path.
        Vec::new()
    }

    fn try_start(&mut self, env: &mut PolicyEnv) -> Option<f64> {
        if self.stepping {
            return None;
        }
        // Continuous batching: admit waiting requests at step boundaries,
        // each reserving its full generation footprint under per-request
        // accounting.
        while self.running.len() < self.max_batch {
            let Some(&r) = self.waiting.front() else { break };
            let tok = gen_footprint(&env.reqs[r]);
            if !self.ledger.fits(tok) {
                env.mem_stall();
                break;
            }
            self.waiting.pop_front();
            self.ledger.reserve(tok);
            env.emit(TraceEvent::DecodeJoin { req: r as u32, replica: env.replica as u32 });
            self.running.push(Running { req: r, generated: 0 });
        }
        if self.running.is_empty() {
            return None;
        }
        let avg_ctx = self
            .running
            .iter()
            .map(|r| (env.reqs[r.req].input_len + r.generated) as f64)
            .sum::<f64>()
            / self.running.len() as f64;
        let lat = env.cm.decode_step_latency(&self.cfg, self.running.len(), avg_ctx);
        self.stepping = true;
        Some(lat)
    }

    fn service_done(&mut self, env: &mut PolicyEnv, out: &mut Vec<Outcome>) {
        self.stepping = false;
        let reqs = env.reqs;
        let mut freed = 0.0;
        for run in self.running.iter_mut() {
            run.generated += 1;
            if run.generated >= reqs[run.req].output_len {
                out.push(Outcome::Finished(run.req));
                freed += gen_footprint(&reqs[run.req]);
            }
        }
        self.ledger.free(freed);
        self.running.retain(|run| run.generated < reqs[run.req].output_len);
    }

    fn load(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    fn mem_capacity_tokens(&self) -> f64 {
        self.ledger.capacity_or_inf()
    }

    fn resident_tokens(&self) -> f64 {
        self.ledger.resident
    }
}

// ---------------------------------------------------------------------------
// Colocated
// ---------------------------------------------------------------------------

/// Interleaved prefill+decode iterations (Orca/vLLM continuous batching):
/// every admitted prefill delays all running decodes — the interference of
/// paper Fig. 1. Optional SARATHI chunking fuses a bounded prefill chunk
/// with the decode batch so the iteration costs max(prefill, decode)
/// instead of their sum (Appendix D).
pub struct Colocated {
    cfg: ReplicaConfig,
    queue: VecDeque<usize>,
    running: Vec<Running>,
    inflight: Vec<PendingPrefill>,
    iterating: bool,
    max_batch: usize,
    chunk: Option<usize>,
    ledger: MemLedger,
    /// Reused per-iteration scratch for prefills completing all chunks
    /// (promoted into `running` after the retain) — no per-event `Vec`.
    promote_buf: Vec<usize>,
}

impl ReplicaPolicy for Colocated {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Colocated
    }

    fn cfg(&self) -> &ReplicaConfig {
        &self.cfg
    }

    fn admit(&mut self, req: usize) {
        self.queue.push_back(req);
    }

    fn deliver_kv(&mut self, _req: usize) {
        debug_assert!(false, "KV routed to a colocated replica");
    }

    fn release_kv(&mut self, _req: usize, _env: &mut PolicyEnv) {}

    fn drain_unstarted(&mut self) -> Vec<usize> {
        self.queue.drain(..).collect()
    }

    fn try_start(&mut self, env: &mut PolicyEnv) -> Option<f64> {
        if self.iterating {
            return None;
        }
        // Per-iteration prefill token budget (Fig. 1 saturation point); in
        // chunked mode `chunk` additionally bounds per-request work so long
        // prompts spread over iterations. A colocated replica keeps the
        // request through generation, hence the prompt+output footprint.
        let per_req = self.chunk.unwrap_or(usize::MAX);
        admit_chunked(
            &mut self.queue,
            &mut self.inflight,
            self.running.len(),
            self.max_batch,
            per_req,
            &mut self.ledger,
            env,
            gen_footprint,
        );
        if self.running.is_empty() && self.inflight.is_empty() {
            return None;
        }
        // Prefill work this iteration: chunks (or whole remainders) within
        // the shared iteration budget.
        let (pf_tokens, pf_reqs) = chunk_work(&mut self.inflight, per_req, env);
        let avg_ctx = if self.running.is_empty() {
            0.0
        } else {
            self.running
                .iter()
                .map(|r| (env.reqs[r.req].input_len + r.generated) as f64)
                .sum::<f64>()
                / self.running.len() as f64
        };
        let mut lat = 0.0;
        if pf_reqs > 0 && self.chunk.is_some() {
            // SARATHI-style chunked prefill piggybacks the running decode
            // tokens into the prefill chunk: one fused kernel over
            // (chunk + batch) tokens. The weight scan that bounds the decode
            // step is shared with the prefill GEMM, so the fused iteration
            // costs the max of the two phases rather than their sum — this
            // is why chunking helps (Appendix D).
            let fused_tokens = pf_tokens + self.running.len() as f64;
            let pf_t = env.cm.prefill_latency(&self.cfg, &TaskProfile::new(1, fused_tokens, 0.0));
            let dec_t = if self.running.is_empty() {
                0.0
            } else {
                env.cm.decode_step_latency(&self.cfg, self.running.len(), avg_ctx)
            };
            lat += pf_t.max(dec_t);
        } else {
            // Plain continuous batching: prefill and decode serialize in the
            // iteration (the prefill-decoding interference of Fig. 1).
            if pf_reqs > 0 {
                let t = TaskProfile::new(pf_reqs, pf_tokens / pf_reqs as f64, 0.0);
                lat += env.cm.prefill_latency(&self.cfg, &t);
            }
            if !self.running.is_empty() {
                lat += env.cm.decode_step_latency(&self.cfg, self.running.len(), avg_ctx);
            }
        }
        self.iterating = true;
        Some(lat)
    }

    fn service_done(&mut self, env: &mut PolicyEnv, out: &mut Vec<Outcome>) {
        self.iterating = false;
        let reqs = env.reqs;
        let mut freed = 0.0;
        // Decode progress: finished requests report straight into `out`
        // (same order as the old intermediate Vec: running order first,
        // promotions after).
        for run in self.running.iter_mut() {
            run.generated += 1;
            if run.generated >= reqs[run.req].output_len {
                out.push(Outcome::Finished(run.req));
                freed += gen_footprint(&reqs[run.req]);
            }
        }
        self.running.retain(|run| run.generated < reqs[run.req].output_len);
        // Prefills that completed all chunks: first token produced. The
        // promotion buffer is taken (not allocated) so retain can fill it
        // while `inflight` is borrowed.
        let mut done_pf = std::mem::take(&mut self.promote_buf);
        debug_assert!(done_pf.is_empty());
        self.inflight.retain(|p| {
            if p.remaining == 0 {
                done_pf.push(p.req);
                false
            } else {
                true
            }
        });
        for r in done_pf.drain(..) {
            out.push(Outcome::FirstToken(r));
            if reqs[r].output_len <= 1 {
                out.push(Outcome::Finished(r));
                freed += gen_footprint(&reqs[r]);
            } else {
                env.emit(TraceEvent::DecodeJoin { req: r as u32, replica: env.replica as u32 });
                self.running.push(Running { req: r, generated: 1 });
            }
        }
        self.promote_buf = done_pf;
        self.ledger.free(freed);
    }

    fn load(&self) -> usize {
        self.queue.len() + self.running.len() + self.inflight.len()
    }

    fn mem_capacity_tokens(&self) -> f64 {
        self.ledger.capacity_or_inf()
    }

    fn resident_tokens(&self) -> f64 {
        self.ledger.resident
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(usize),
    /// Replica `i`'s service burst (prefill batch, decode step, or
    /// colocated iteration) completed.
    Service(usize),
    /// KV cache of request `r` finished transferring from prefill replica
    /// `p` to decode replica `d`.
    KvArrive { p: usize, d: usize, r: usize },
    /// Initiate switch `i`: quiesce the active replicas.
    Resched(usize),
    /// Switch `i`'s new epoch goes live.
    Activate(usize),
    /// Request `r`'s host-tier prefix KV finished re-loading to GPU; admit
    /// it for its suffix prefill.
    Reload(usize),
}

/// Telemetry lane of a policy kind (the trace module is
/// simulator-independent, hence the mirror type).
fn lane_of(kind: PolicyKind) -> Lane {
    match kind {
        PolicyKind::Prefill => Lane::Prefill,
        PolicyKind::Decode => Lane::Decode,
        PolicyKind::Colocated => Lane::Colocated,
    }
}

/// Outcome of consulting the prefix pool at admission time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PrefixRoute {
    /// GPU hit: admit on this holder (suffix-only prefill).
    Steer(usize),
    /// Host hit: admission deferred behind the re-load (`Ev::Reload`).
    Defer,
    /// No prefix, already resolved, or a miss: generic routing.
    Pass,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Router {
    /// Deficit-weighted by max-flow route weight (disaggregated entry).
    FlowWeighted,
    /// Least outstanding work (colocated entry).
    LeastLoaded,
}

struct Engine<'a, S: TraceSink> {
    cm: CostModel<'a>,
    /// Active-request window (indices handed out at arrival time).
    store: ReqStore,
    /// Where the next request comes from (materialized slice or stream).
    feed: Feed<'a>,
    sim: &'a SimConfig,
    replicas: Vec<Box<dyn ReplicaPolicy>>,
    kinds: Vec<PolicyKind>,
    /// Flow-proportional routing weight per replica (prefill entries).
    weight: Vec<f64>,
    /// Requests assigned so far per replica (deficit routing).
    assigned: Vec<f64>,
    /// The KV transfer engine: route table, link reservations, pipelined
    /// chunking, and the link-load ledger (DESIGN.md §11).
    kv: TransferScheduler,
    /// Cluster-wide prefix KV pool (DESIGN.md §15): per-prefill-replica
    /// GPU partitions with LRU spill to a host tier.
    prefix_pool: PrefixPool,
    /// Reused eviction-record buffer for pool publishes/flushes.
    evict_buf: Vec<EvictRecord>,
    /// Latency of the burst currently (or last) in flight per replica — the
    /// overlap window layer-wise pipelined transfers may ship into.
    burst_lat: Vec<f64>,
    /// Entry replicas of the current epoch.
    active: Vec<usize>,
    router: Router,
    q: EventQueue<Ev>,
    records: Vec<RequestRecord>,
    /// Windowed accumulator ([`RecordMode::Windowed`]); `None` keeps full
    /// per-request records.
    agg: Option<WindowedAgg>,
    /// Requests waiting out a migration blackout (no active entry replica).
    holding: Vec<usize>,
    /// Active set stashed at Resched time, restored if the switch is
    /// infeasible.
    quiesced: Vec<Vec<usize>>,
    /// Last observed resident tokens per replica + their running total
    /// (incremental peak tracking under per-request accounting — avoids a
    /// full arena scan per event).
    resident: Vec<f64>,
    resident_total: f64,
    /// Reused per-event buffers (the alloc-free hot loop): service-burst
    /// outcomes, and a usize scratch shared by admission filtering, KV
    /// route pooling, and quiesce drains — never live at the same time.
    outcome_buf: Vec<Outcome>,
    scratch: Vec<usize>,
    /// Timestamp of the last processed event (the serving span the ledger's
    /// NIC utilization is normalized by).
    t_end: f64,
    stats: SimStats,
    /// Flight recorder (DESIGN.md §12). Monomorphized: with [`NoopSink`]
    /// every `emit` call and `recorder().is_some()` gate folds away.
    sink: &'a mut S,
}

macro_rules! penv {
    ($self:ident, $i:expr, $now:expr) => {
        PolicyEnv {
            cm: &$self.cm,
            reqs: &$self.store,
            sim: $self.sim,
            stats: &mut $self.stats,
            now: $now,
            replica: $i,
            trace: $self.sink.active(),
        }
    };
}

impl<'a, S: TraceSink> Engine<'a, S> {
    /// Record an engine-level trace event (no-op under [`NoopSink`]).
    #[inline]
    fn emit(&mut self, t: f64, ev: TraceEvent) {
        self.sink.emit(t, ev);
    }

    /// Append one disaggregated placement's replicas to the arena. Returns
    /// the arena indices of the new entry (prefill) replicas, or None when
    /// the placement has no feasible prefill or decode replica.
    fn build_disagg(
        &mut self,
        placement: &Placement,
        s_in_mean: f64,
        task: &TaskProfile,
    ) -> Option<Vec<usize>> {
        let base = self.replicas.len();
        // BTreeMap: iterated below to wire prefill→decode routes, and route
        // order must be identical run-to-run for bit-identical replays.
        let mut p_of_group: BTreeMap<usize, usize> = BTreeMap::new();
        let mut d_of_group: BTreeMap<usize, usize> = BTreeMap::new();
        let mut new_p: Vec<usize> = Vec::new();
        let mut new_d: Vec<usize> = Vec::new();
        for (gi, g) in placement.groups.iter().enumerate() {
            let Some(cfg) = g.config.clone() else { continue };
            if g.capacity <= 0.0 {
                continue;
            }
            let idx = self.replicas.len();
            if g.is_prefill {
                let mb = match self.sim.sizing {
                    // Memory-limited prefill batch at the mean input length
                    // (bound derived from device memory, not a magic cap).
                    Sizing::StaticMean => {
                        let cap = self.sim.static_prefill_cap.unwrap_or(MAX_DECODE_BATCH);
                        self.cm.max_prefill_batch(&cfg, s_in_mean, cap)
                    }
                    // Per-request accounting: the ledger is the limit.
                    Sizing::PerRequest => MAX_DECODE_BATCH,
                };
                let ledger = MemLedger::new(&self.cm, &cfg, self.sim.sizing);
                // Carve this replica's prefix-pool partition out of its
                // token capacity before `cfg` moves into the policy box.
                let px_budget = self
                    .sim
                    .prefix_gpu_budget
                    .unwrap_or_else(|| PREFIX_POOL_GPU_FRACTION * self.cm.token_capacity(&cfg))
                    .max(0.0);
                self.prefix_pool.register_replica(idx, px_budget);
                p_of_group.insert(gi, idx);
                new_p.push(idx);
                self.push_replica(
                    Box::new(DisaggPrefill {
                        cfg,
                        queue: VecDeque::new(),
                        busy: false,
                        batch: Vec::new(),
                        chunks: Vec::new(),
                        max_batch: mb,
                        chunk: self.sim.chunked_prefill,
                        ledger,
                    }),
                    PolicyKind::Prefill,
                );
            } else {
                let mb = match self.sim.sizing {
                    Sizing::StaticMean => self.cm.max_decode_batch(&cfg, task).max(1),
                    Sizing::PerRequest => MAX_DECODE_BATCH,
                };
                let ledger = MemLedger::new(&self.cm, &cfg, self.sim.sizing);
                d_of_group.insert(gi, idx);
                new_d.push(idx);
                self.push_replica(
                    Box::new(DisaggDecode {
                        cfg,
                        running: Vec::new(),
                        waiting: VecDeque::new(),
                        stepping: false,
                        max_batch: mb,
                        ledger,
                    }),
                    PolicyKind::Decode,
                );
            }
        }
        if new_p.is_empty() || new_d.is_empty() {
            // Infeasible placement: roll back the partial build (the new
            // entries are all zero-resident, so the running total stands).
            self.prefix_pool.unregister_from(base);
            self.replicas.truncate(base);
            self.kinds.truncate(base);
            self.weight.truncate(base);
            self.assigned.truncate(base);
            self.resident.truncate(base);
            self.burst_lat.truncate(base);
            return None;
        }

        // Flow-proportional routing weights (§3.3: "communication frequency
        // is set to be proportional to these flow values") — registered
        // with the KV transfer engine, which owns the route table.
        for r in &placement.routes {
            let (Some(&p), Some(&d)) = (p_of_group.get(&r.prefill), d_of_group.get(&r.decode))
            else {
                continue;
            };
            if r.flow > 1e-9 {
                self.kv.add_route(p, d, r.flow);
                self.weight[p] += r.flow;
            }
        }
        // Fallback: if max-flow left a prefill replica unrouted, connect it
        // to every decode replica *of this placement* with a tiny weight so
        // requests are never stranded.
        for &p in &new_p {
            if self.weight[p] <= 0.0 {
                for &d in &new_d {
                    self.kv.add_fallback(p, d);
                }
                self.weight[p] = 1e-6 * new_d.len() as f64;
            }
        }
        Some(new_p)
    }

    /// Append colocated replicas to the arena; all of them are entries.
    fn build_colocated(
        &mut self,
        cfgs: &[ReplicaConfig],
        chunk: Option<usize>,
        task: &TaskProfile,
    ) -> Option<Vec<usize>> {
        let base = self.replicas.len();
        for cfg in cfgs {
            let feasible = match self.sim.sizing {
                Sizing::StaticMean => self.cm.memory_ok(cfg, task),
                Sizing::PerRequest => self.cm.token_capacity(cfg) > 0.0,
            };
            if !feasible {
                continue;
            }
            let mb = match self.sim.sizing {
                Sizing::StaticMean => self.cm.max_decode_batch(cfg, task).max(1),
                Sizing::PerRequest => MAX_DECODE_BATCH,
            };
            let ledger = MemLedger::new(&self.cm, cfg, self.sim.sizing);
            self.push_replica(
                Box::new(Colocated {
                    cfg: cfg.clone(),
                    queue: VecDeque::new(),
                    running: Vec::new(),
                    inflight: Vec::new(),
                    iterating: false,
                    max_batch: mb,
                    chunk,
                    ledger,
                    promote_buf: Vec::new(),
                }),
                PolicyKind::Colocated,
            );
        }
        if self.replicas.len() == base {
            None
        } else {
            Some((base..self.replicas.len()).collect())
        }
    }

    fn push_replica(&mut self, policy: Box<dyn ReplicaPolicy>, kind: PolicyKind) {
        self.replicas.push(policy);
        self.kinds.push(kind);
        self.weight.push(0.0);
        self.assigned.push(0.0);
        self.resident.push(0.0);
        self.burst_lat.push(0.0);
    }

    /// Re-read replica `i`'s resident tokens after a reserve/free and fold
    /// the delta into the running total + peak (per-request mode only).
    fn note_resident(&mut self, i: usize) {
        if self.sim.sizing != Sizing::PerRequest {
            return;
        }
        let now_res = self.replicas[i].resident_tokens();
        self.resident_total += now_res - self.resident[i];
        self.resident[i] = now_res;
        if self.resident_total > self.stats.peak_resident_tokens {
            self.stats.peak_resident_tokens = self.resident_total;
        }
    }

    fn build_spec(&mut self, spec: &ServingSpec, s_in: f64, s_out: f64) -> Option<(Vec<usize>, Router)> {
        let task = TaskProfile::new(1, s_in, s_out);
        match spec {
            ServingSpec::Disaggregated(p) => {
                self.build_disagg(p, s_in, &task).map(|a| (a, Router::FlowWeighted))
            }
            ServingSpec::Colocated { replicas, chunked_prefill } => self
                .build_colocated(replicas, *chunked_prefill, &task)
                .map(|a| (a, Router::LeastLoaded)),
        }
    }

    /// Token footprint request `r` pins on entry replica `i`.
    fn entry_footprint(&self, i: usize, r: usize) -> f64 {
        match self.kinds[i] {
            // A prefill replica holds the prompt KV until it is shipped.
            PolicyKind::Prefill => self.store[r].input_len as f64,
            // Colocated replicas keep the request through generation.
            _ => gen_footprint(&self.store[r]),
        }
    }

    /// Advance the bounded arrival frontier: pull the next request from the
    /// feed into the store and schedule its arrival. Exactly one future
    /// arrival lives in the event heap at any time, so heap and store are
    /// O(active requests) regardless of trace length. Feeds must be
    /// time-ordered (every constructor generates non-decreasing arrivals).
    fn pull_next_arrival(&mut self) {
        if let Some(req) = self.feed.next() {
            let at = req.arrival;
            let idx = self.store.push(req);
            self.stats.peak_live_requests = self.stats.peak_live_requests.max(self.store.live());
            self.q.push(at, Ev::Arrive(idx));
        }
    }

    /// Pick an entry replica among `cands` under the epoch's router.
    fn pick(&self, cands: &[usize]) -> usize {
        match self.router {
            // Deficit-weighted pick: argmax weight / (assigned + 1).
            Router::FlowWeighted => *cands
                .iter()
                .max_by(|&&a, &&b| {
                    let fa = self.weight[a] / (self.assigned[a] + 1.0);
                    let fb = self.weight[b] / (self.assigned[b] + 1.0);
                    fa.partial_cmp(&fb).unwrap()
                })
                .expect("no active entry replica"),
            // Least-outstanding-work routing.
            Router::LeastLoaded => *cands
                .iter()
                .min_by_key(|&&i| self.replicas[i].load())
                .expect("no active entry replica"),
        }
    }

    /// If the replica can start a burst, schedule its completion.
    fn try_start(&mut self, i: usize, now: f64) {
        let started = {
            let mut env = penv!(self, i, now);
            self.replicas[i].try_start(&mut env)
        };
        if let Some(lat) = started {
            self.emit(now, TraceEvent::Burst { replica: i as u32, lane: lane_of(self.kinds[i]), dur_s: lat });
            self.q.push(now + lat, Ev::Service(i));
            // Remembered as the pipelining window: KV produced by this
            // burst may overlap (part of) it when chunked transfer is on.
            self.burst_lat[i] = lat;
        }
        // try_start is where admissions reserve memory.
        self.note_resident(i);
    }

    /// Can GPU-tier prefix holder `p` serve request `r` right now? It must
    /// be an entry replica of the current epoch and (under per-request
    /// accounting) able to ever fit the request.
    fn eligible_prefix_holder(&self, p: usize, r: usize) -> bool {
        self.active.contains(&p)
            && (self.sim.sizing != Sizing::PerRequest
                || self.replicas[p].mem_capacity_tokens() >= self.entry_footprint(p, r))
    }

    /// Resolve request `r`'s shared prefix against the pool (exactly once
    /// per request): steer to a GPU-tier holder, defer behind a host-tier
    /// re-load, or fall through to the generic router.
    fn resolve_prefix(&mut self, r: usize, now: f64) -> PrefixRoute {
        let Some(px) = self.store[r].prefix else { return PrefixRoute::Pass };
        if self.store.prefix_resolved(r) {
            return PrefixRoute::Pass;
        }
        self.store.set_prefix_resolved(r);
        match self.prefix_pool.lookup(px.id) {
            Some(PrefixTier::Gpu(holder)) if self.eligible_prefix_holder(holder, r) => {
                // GPU hit: prefill only the suffix, on the holder.
                self.stats.prefix_hits += 1;
                self.stats.prefix_reused_tokens += px.len as f64;
                self.store.set_prefill_tokens(r, self.store[r].input_len - px.len);
                self.emit(
                    now,
                    TraceEvent::PrefixHit { req: r as u32, tokens: px.len as u32, host: false },
                );
                PrefixRoute::Steer(holder)
            }
            Some(PrefixTier::Host) => {
                // Host hit: the suffix discount still applies, but the
                // prefix KV must re-load host → GPU first; the request
                // re-enters admission when the re-load completes and the
                // entry is promoted onto whichever replica serves it.
                self.stats.prefix_host_hits += 1;
                self.stats.prefix_reused_tokens += px.len as f64;
                let bytes = self.cm.kv_bytes(px.len as f64, self.cm.model.n_layers);
                let reload_s = bytes / HOST_RELOAD_BYTES_PER_S;
                self.stats.prefix_reload_s += reload_s;
                self.store.set_prefill_tokens(r, self.store[r].input_len - px.len);
                self.emit(
                    now,
                    TraceEvent::PrefixHit { req: r as u32, tokens: px.len as u32, host: true },
                );
                self.q.push(now + reload_s, Ev::Reload(r));
                PrefixRoute::Defer
            }
            _ => {
                // Full miss (or the GPU holder left the active set and
                // cannot serve): full prefill, publish at the picked
                // replica. An ineligible holder's entry stays where it is
                // (`publish` only bumps recency on GPU-resident entries).
                self.stats.prefix_misses += 1;
                self.emit(now, TraceEvent::PrefixMiss { req: r as u32, prefix: px.id as u32 });
                PrefixRoute::Pass
            }
        }
    }

    /// Publish (or promote, after a host-hit re-load) request `r`'s shared
    /// prefix onto prefill replica `i`'s pool partition; spills and
    /// evictions made to fit it are traced via [`Engine::note_evictions`].
    fn publish_prefix(&mut self, i: usize, r: usize, now: f64) {
        let Some(px) = self.store[r].prefix else { return };
        let mut out = std::mem::take(&mut self.evict_buf);
        out.clear();
        self.prefix_pool.publish(px.id, px.len as f64, i, &mut out);
        self.note_evictions(now, &mut out);
        self.evict_buf = out;
    }

    /// Trace the pool's spill/eviction records (cumulative token totals
    /// live on the pool itself and land in [`SimStats`] at end of run).
    fn note_evictions(&mut self, now: f64, out: &mut Vec<EvictRecord>) {
        for ev in out.drain(..) {
            self.emit(
                now,
                TraceEvent::PrefixEvict {
                    prefix: ev.prefix as u32,
                    tokens: ev.tokens as u32,
                    to_host: ev.to_host,
                },
            );
        }
    }

    /// Route an arrived (or re-flushed) request to an entry replica, or
    /// hold it through a migration blackout.
    fn admit(&mut self, r: usize, now: f64) {
        if self.active.is_empty() {
            self.emit(now, TraceEvent::Hold { req: r as u32 });
            self.holding.push(r);
            return;
        }
        // Cache-aware routing (DESIGN.md §15): a GPU-tier prefix hit
        // overrides the generic router and steers to the holder; a
        // host-tier hit defers admission behind the re-load (the request
        // re-enters via `Ev::Reload` with its prefix already resolved).
        match self.resolve_prefix(r, now) {
            PrefixRoute::Steer(holder) => {
                if self.router == Router::FlowWeighted {
                    self.assigned[holder] += 1.0;
                }
                self.emit(now, TraceEvent::Admit { req: r as u32, replica: holder as u32 });
                self.replicas[holder].admit(r);
                self.try_start(holder, now);
                return;
            }
            PrefixRoute::Defer => return,
            PrefixRoute::Pass => {}
        }
        let i = if self.sim.sizing == Sizing::PerRequest {
            let mut fitting = std::mem::take(&mut self.scratch);
            fitting.clear();
            fitting.extend(
                self.active
                    .iter()
                    .copied()
                    .filter(|&i| self.replicas[i].mem_capacity_tokens() >= self.entry_footprint(i, r)),
            );
            if fitting.is_empty() {
                // Larger than every active replica's memory: reject rather
                // than wedge a queue forever.
                self.scratch = fitting;
                self.stats.rejected += 1;
                self.emit(now, TraceEvent::Reject { req: r as u32 });
                self.store.retire(r);
                return;
            }
            let i = self.pick(&fitting);
            self.scratch = fitting;
            i
        } else {
            self.pick(&self.active)
        };
        if self.router == Router::FlowWeighted {
            self.assigned[i] += 1.0;
        }
        self.emit(now, TraceEvent::Admit { req: r as u32, replica: i as u32 });
        // Publish-at-admit: a missed (or host-promoted) prefix becomes
        // GPU-resident on the serving prefill replica as soon as the
        // request is queued there — later queued requests for the same
        // prefix hit it (FIFO order keeps the reuse causally sound).
        if self.kinds[i] == PolicyKind::Prefill {
            self.publish_prefix(i, r, now);
        }
        self.replicas[i].admit(r);
        self.try_start(i, now);
    }

    /// Prefill of `r` finished on replica `p`: stamp TTFT, hand the cache
    /// to the KV transfer engine (route selection under the configured
    /// [`RouteModel`], link reservation, optional pipelined chunking), and
    /// schedule its arrival.
    fn route_kv(&mut self, p: usize, r: usize, now: f64) {
        self.store.set_prefill_done(r, now);
        self.emit(now, TraceEvent::PrefillDone { req: r as u32, replica: p as u32 });
        let mut pool = std::mem::take(&mut self.scratch);
        pool.clear();
        pool.extend(
            (0..self.replicas.len())
                .filter(|&d| self.kinds[d] == PolicyKind::Decode && self.kv.has_route(p, d)),
        );
        // Legacy fallback: an unrouted prefill replica sends to the first
        // decode replica in the arena.
        if pool.is_empty() {
            match (0..self.replicas.len()).find(|&d| self.kinds[d] == PolicyKind::Decode) {
                Some(d) => pool.push(d),
                None => {
                    // Unreachable for specs built by this engine (every
                    // disagg build has ≥1 decode replica; colocated never
                    // routes KV) — still account the drop and free the
                    // prefill-side reservation defensively.
                    self.scratch = pool;
                    self.stats.rejected += 1;
                    self.emit(now, TraceEvent::Reject { req: r as u32 });
                    {
                        let mut env = penv!(self, p, now);
                        self.replicas[p].release_kv(r, &mut env);
                    }
                    self.store.retire(r);
                    return;
                }
            }
        }
        if self.sim.sizing == Sizing::PerRequest {
            let footprint = gen_footprint(&self.store[r]);
            pool.retain(|&d| self.replicas[d].mem_capacity_tokens() >= footprint);
            if pool.is_empty() {
                // No decode replica can ever hold this generation: drop the
                // KV and report the request unserved.
                self.scratch = pool;
                self.stats.rejected += 1;
                self.emit(now, TraceEvent::Reject { req: r as u32 });
                {
                    let mut env = penv!(self, p, now);
                    self.replicas[p].release_kv(r, &mut env);
                }
                self.store.retire(r);
                return;
            }
        }
        // Hand the cache to the transfer engine. Transfer times are queried
        // lazily (`RouteModel::needs_xfer`): per candidate only when the
        // policy ranks by them, otherwise once for the chosen route — the
        // Table-1 query scans device pairs and this is the hot loop.
        let t_task = TaskProfile::new(1, self.store[r].input_len as f64, 0.0);
        let bytes = self.cm.kv_bytes(self.store[r].input_len as f64, self.cm.model.n_layers);
        let burst = self.burst_lat[p];
        let (cm, replicas, kv) = (&self.cm, &self.replicas, &mut self.kv);
        let tr = kv.enqueue(p, bytes, now, burst, &pool, |d| {
            cm.kv_transfer_time(replicas[p].cfg(), replicas[d].cfg(), &t_task)
        });
        self.scratch = pool;
        self.stats.kv_link_wait_s += tr.wait_s;
        self.emit(
            now,
            TraceEvent::KvEnqueue { req: r as u32, src: p as u32, dst: tr.dst as u32, bytes, wait_s: tr.wait_s },
        );
        if self.sink.recorder().is_some() {
            // Synthesize per-chunk transfer spans over the reserved link
            // window (the engine reserves the window as a whole; chunks
            // partition it evenly — see TransferScheduler's overlap model).
            let n = self.kv.config().chunks().max(1);
            let span = tr.done - tr.start;
            for c in 0..n {
                let cs = tr.start + span * c as f64 / n as f64;
                let ce = tr.start + span * (c + 1) as f64 / n as f64;
                self.emit(
                    now,
                    TraceEvent::KvXfer {
                        req: r as u32,
                        src: p as u32,
                        dst: tr.dst as u32,
                        chunk: c as u32,
                        n_chunks: n as u32,
                        start: cs,
                        end: ce,
                    },
                );
            }
        }
        self.q.push(tr.done, Ev::KvArrive { p, d: tr.dst, r });
    }

    fn finish(&mut self, r: usize, now: f64) {
        let req = self.store[r];
        let rec = RequestRecord {
            id: req.id,
            arrival: req.arrival,
            prefill_done: self.store.prefill_done(r),
            completion: now,
            input_len: req.input_len,
            output_len: req.output_len,
            slo_base: slo_base(self.cm.model, &req),
        };
        match &mut self.agg {
            Some(a) => a.push(&rec),
            None => self.records.push(rec),
        }
        self.store.n_finished += 1;
        self.store.retire(r);
    }

    fn run(
        &mut self,
        switches: &[SwitchSpec],
        base_means: (f64, f64),
    ) {
        while let Some((now, ev)) = self.q.pop() {
            // The event heap pops in time order, so this tracks the serving
            // span (the ledger's NIC-utilization denominator).
            self.t_end = now;
            self.stats.events += 1;
            match ev {
                Ev::Arrive(r) => {
                    // Bounded frontier: replace this arrival in the heap
                    // with the feed's next one before admitting.
                    self.pull_next_arrival();
                    self.emit(now, TraceEvent::Arrive { req: r as u32 });
                    self.admit(r, now)
                }
                Ev::Resched(i) => {
                    self.emit(now, TraceEvent::Quiesce { switch: i as u32 });
                    // Quiesce: stop admitting to the active replicas; pull
                    // their unstarted requests back into the holding buffer
                    // (arrival order preserved by sorting on request index).
                    // In-flight bursts and running decodes drain on the old
                    // epoch's replicas. The pulled-request buffer is the
                    // shared scratch, not a fresh Vec.
                    let old = std::mem::take(&mut self.active);
                    let mut pulled = std::mem::take(&mut self.scratch);
                    pulled.clear();
                    for &p in &old {
                        pulled.append(&mut self.replicas[p].drain_unstarted());
                    }
                    pulled.sort_unstable();
                    self.holding.extend(pulled.drain(..));
                    self.scratch = pulled;
                    // A quiesced prefill replica's GPU prefix cache
                    // flushes to the host tier (the device is being
                    // repurposed; host-tier KV survives the migration).
                    let mut evs = std::mem::take(&mut self.evict_buf);
                    evs.clear();
                    for &p in &old {
                        if self.kinds[p] == PolicyKind::Prefill {
                            self.prefix_pool.flush_replica(p, &mut evs);
                        }
                    }
                    self.note_evictions(now, &mut evs);
                    self.evict_buf = evs;
                    self.quiesced[i] = old;
                }
                Ev::Activate(i) => {
                    // Size the new replicas for the workload they were
                    // planned for (post-shift statistics), not the opening
                    // phase's.
                    let (s_in, s_out) = switches[i]
                        .workload
                        .map(|k| k.mean_lengths())
                        .unwrap_or(base_means);
                    match self.build_spec(&switches[i].to, s_in, s_out) {
                        Some((fresh, router)) => {
                            self.active = fresh;
                            self.router = router;
                            self.emit(now, TraceEvent::Activate { switch: i as u32, ok: true });
                        }
                        // Infeasible new epoch: resume the old replicas.
                        None => {
                            self.active = std::mem::take(&mut self.quiesced[i]);
                            self.emit(now, TraceEvent::Activate { switch: i as u32, ok: false });
                        }
                    }
                    for r in std::mem::take(&mut self.holding) {
                        self.admit(r, now);
                    }
                }
                Ev::Service(i) => {
                    // Outcomes land in the reused per-event buffer.
                    let mut out = std::mem::take(&mut self.outcome_buf);
                    out.clear();
                    {
                        let mut env = penv!(self, i, now);
                        self.replicas[i].service_done(&mut env, &mut out);
                    }
                    for o in out.drain(..) {
                        match o {
                            Outcome::KvReady(r) => self.route_kv(i, r, now),
                            Outcome::FirstToken(r) => {
                                self.store.set_prefill_done(r, now);
                                self.emit(
                                    now,
                                    TraceEvent::PrefillDone { req: r as u32, replica: i as u32 },
                                );
                            }
                            Outcome::Finished(r) => {
                                self.emit(
                                    now,
                                    TraceEvent::Finish {
                                        req: r as u32,
                                        replica: i as u32,
                                        output_len: self.store[r].output_len as u32,
                                    },
                                );
                                self.finish(r, now)
                            }
                        }
                    }
                    self.outcome_buf = out;
                    // Completions freed memory; the trailing try_start
                    // re-reads replica i's residency either way.
                    self.try_start(i, now);
                }
                Ev::KvArrive { p, d, r } => {
                    self.kv.complete(p, d);
                    self.emit(now, TraceEvent::KvDone { req: r as u32, src: p as u32, dst: d as u32 });
                    if self.sim.sizing == Sizing::PerRequest {
                        // The shipped KV frees prefill-side memory, which
                        // may unblock queued prompts.
                        let mut env = penv!(self, p, now);
                        self.replicas[p].release_kv(r, &mut env);
                        self.try_start(p, now);
                    }
                    self.replicas[d].deliver_kv(r);
                    self.try_start(d, now);
                }
                // Host-tier prefix KV re-loaded: admit for suffix prefill
                // (the prefix is already resolved, so this cannot recurse).
                Ev::Reload(r) => self.admit(r, now),
            }
        }
    }
}

/// Simulate a trace on the unified engine: an initial serving epoch, an
/// optional sequence of mid-trace switches (sorted, non-overlapping — each
/// `at + delay` before the next `at`), and the run's [`SimConfig`].
/// Requests that cannot be served at all are dropped from the records and
/// counted in [`SimStats::unserved`].
///
/// With [`SimConfig::trace`] set, the run records a flight-recorder trace
/// ([`SimReport::trace`]); otherwise the engine monomorphizes over
/// [`NoopSink`] and pays nothing.
pub fn simulate(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &ServingSpec,
    switches: &[SwitchSpec],
    trace: &Trace,
    cfg: &SimConfig,
) -> SimReport {
    let feed = Feed::Slice { reqs: &trace.requests, next: 0 };
    simulate_feed(cluster, model, initial, switches, feed, trace.kind, cfg)
}

/// Simulate a *streaming* trace: requests are pulled lazily from `source`
/// through the bounded arrival frontier, so memory stays O(active requests)
/// regardless of trace length (pair with [`RecordMode::Windowed`] for the
/// full contract — Full mode still accumulates one record per completion).
/// Aggregates are bit-identical to materializing the same source into a
/// [`Trace`] and calling [`simulate`]: both paths run the same feed
/// machinery (DESIGN.md §14).
pub fn simulate_stream(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &ServingSpec,
    switches: &[SwitchSpec],
    source: TraceSource,
    cfg: &SimConfig,
) -> SimReport {
    let kind = source.kind();
    simulate_feed(cluster, model, initial, switches, Feed::Stream(source), kind, cfg)
}

/// Shared driver: wraps the run in a flight recorder when asked.
fn simulate_feed(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &ServingSpec,
    switches: &[SwitchSpec],
    feed: Feed<'_>,
    kind: WorkloadKind,
    cfg: &SimConfig,
) -> SimReport {
    if cfg.trace && cfg.attribution {
        // Attribution tee (DESIGN.md §16): the attributor observes every
        // event before the ring's sampling/wrap, so the blame report is
        // exact even for sampled or truncated traces. Per-request blame
        // vectors are kept only in Full mode; Windowed keeps the O(1)
        // aggregates, matching the streaming memory contract.
        let keep = cfg.record_mode == RecordMode::Full;
        let mut ar = crate::telemetry::AttribRecorder::new(
            Recorder::new(cfg.trace_sample_rate, cfg.trace_buffer),
            crate::telemetry::Attributor::new(crate::telemetry::attribution::DEFAULT_WINDOW_S, keep),
        );
        let mut rep = simulate_sink(cluster, model, initial, switches, feed, kind, cfg, &mut ar);
        rep.trace = Some(ar.rec.into_log());
        rep.attr = Some(ar.attr.finish());
        rep
    } else if cfg.trace {
        let mut rec = Recorder::new(cfg.trace_sample_rate, cfg.trace_buffer);
        let mut rep = simulate_sink(cluster, model, initial, switches, feed, kind, cfg, &mut rec);
        rep.trace = Some(rec.into_log());
        rep
    } else {
        simulate_sink(cluster, model, initial, switches, feed, kind, cfg, &mut NoopSink)
    }
}

/// The engine run itself, generic over the trace sink.
#[allow(clippy::too_many_arguments)]
fn simulate_sink<S: TraceSink>(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &ServingSpec,
    switches: &[SwitchSpec],
    feed: Feed<'_>,
    kind: WorkloadKind,
    cfg: &SimConfig,
    sink: &mut S,
) -> SimReport {
    for s in switches {
        assert!(
            s.at.is_finite() && s.delay.is_finite() && s.at >= 0.0 && s.delay >= 0.0,
            "placement switch times must be finite and non-negative (at {}, delay {})",
            s.at,
            s.delay
        );
    }
    for w in switches.windows(2) {
        assert!(
            w[0].at + w[0].delay <= w[1].at,
            "placement switches must be sorted and non-overlapping"
        );
    }
    let cm = CostModel::new(cluster, model);
    let (s_in_mean, s_out_mean) = kind.mean_lengths();
    // Record arena sized up front in Full mode (every request finishes at
    // most once); Windowed keeps no records at all.
    let (records, agg) = match cfg.record_mode {
        RecordMode::Full => (Vec::with_capacity(feed.len_hint()), None),
        RecordMode::Windowed => (Vec::new(), Some(WindowedAgg::new())),
    };

    let mut eng = Engine {
        cm,
        store: ReqStore::new(),
        feed,
        sim: cfg,
        replicas: Vec::new(),
        kinds: Vec::new(),
        weight: Vec::new(),
        assigned: Vec::new(),
        kv: TransferScheduler::new(TransferConfig {
            route: cfg.kv_route,
            link: cfg.link,
            chunk_layers: cfg.kv_chunk_layers,
            n_layers: model.n_layers,
        }),
        prefix_pool: PrefixPool::new(cfg.prefix_host_budget),
        evict_buf: Vec::new(),
        burst_lat: Vec::new(),
        active: Vec::new(),
        router: Router::FlowWeighted,
        // Bounded arrival frontier: at most one future arrival plus the
        // resched/activate pairs and in-flight service/KV events live in
        // the heap — O(active), never O(trace length).
        q: EventQueue::with_capacity(64 + 2 * switches.len()),
        records,
        agg,
        holding: Vec::new(),
        quiesced: vec![Vec::new(); switches.len()],
        resident: Vec::new(),
        resident_total: 0.0,
        outcome_buf: Vec::new(),
        scratch: Vec::new(),
        t_end: 0.0,
        stats: SimStats::default(),
        sink,
    };

    // Replica arena: switches append; indices stay valid for in-flight
    // events, so a draining replica keeps serving after it is deactivated.
    let Some((active, router)) = eng.build_spec(initial, s_in_mean, s_out_mean) else {
        let unserved = eng.feed.count_remaining();
        let mut rep = match eng.agg.take() {
            Some(a) => SimReport::from_windowed(a),
            None => SimReport::from_records(vec![]),
        };
        rep.stats.unserved = unserved;
        return rep;
    };
    eng.active = active;
    eng.router = router;

    // Prime the bounded arrival frontier (each Arrive pop pulls the next).
    eng.pull_next_arrival();
    for (i, s) in switches.iter().enumerate() {
        eng.q.push(s.at, Ev::Resched(i));
        eng.q.push(s.at + s.delay, Ev::Activate(i));
    }

    eng.run(switches, (s_in_mean, s_out_mean));

    // Rejected (retired-unfinished) requests count as unserved, matching
    // the former done[]-scan semantics.
    eng.stats.unserved = eng.store.n_arrived - eng.store.n_finished;
    // Hand the recorder the replica lane map (Perfetto lane names).
    if let Some(rec) = eng.sink.recorder() {
        rec.set_lanes(eng.kinds.iter().map(|&k| lane_of(k)).collect());
    }
    // Export the transfer engine's ledger: the Copy summary onto SimStats,
    // the per-route detail onto the report.
    let kv_summary = eng.kv.ledger().summary(eng.t_end);
    eng.stats.kv_transfers = kv_summary.transfers;
    eng.stats.kv_bytes = kv_summary.bytes;
    eng.stats.kv_max_nic_util = kv_summary.max_nic_util;
    eng.stats.kv_wait_hist = kv_summary.wait_hist;
    // Prefix-pool ledger: cumulative publish/spill/evict totals plus the
    // end-of-run residency split (hit/miss counters accrued live).
    eng.stats.prefix_published_tokens = eng.prefix_pool.published_tokens;
    eng.stats.prefix_spilled_tokens = eng.prefix_pool.spilled_tokens;
    eng.stats.prefix_evicted_tokens = eng.prefix_pool.evicted_tokens;
    eng.stats.prefix_gpu_tokens = eng.prefix_pool.gpu_resident();
    eng.stats.prefix_host_tokens = eng.prefix_pool.host_resident();
    let link_loads = eng.kv.ledger().loads();
    let mut rep = match eng.agg.take() {
        Some(a) => SimReport::from_windowed(a),
        None => SimReport::from_records(eng.records),
    };
    rep.stats = eng.stats;
    rep.link_loads = link_loads;
    rep
}
