//! Cluster topology: devices, nodes, data centers, and the pairwise
//! bandwidth/latency matrices the scheduler consumes (paper Fig. 4).
//!
//! The paper measures these matrices with NCCL on RunPod rentals; we
//! synthesize them from the same link tiers the paper reports (NVLink and
//! PCIe within a server; InfiniBand / RoCE / Ethernet across servers; very
//! low-bandwidth links across data centers). The scheduling algorithm only
//! ever sees devices through these matrices plus the per-type specs, so the
//! substitution preserves its behaviour (DESIGN.md §1).

use super::gpu::GpuType;

pub type DeviceId = usize;

/// Inter-node link tiers, with (bandwidth bytes/s, latency seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkTier {
    /// InfiniBand 200 Gb/s (same rack / fabric).
    InfiniBand,
    /// 100 GbE RoCE-class datacenter Ethernet.
    Eth100G,
    /// 10 GbE commodity Ethernet.
    Eth10G,
    /// Cross-data-center WAN (~1 Gb/s): the "ultra-low" links §5.2 says
    /// the scheduler must avoid for KV traffic.
    CrossDc,
}

impl LinkTier {
    pub fn bandwidth(self) -> f64 {
        match self {
            LinkTier::InfiniBand => 25e9, // 200 Gb/s
            LinkTier::Eth100G => 12.5e9,  // 100 Gb/s
            LinkTier::Eth10G => 1.25e9,   // 10 Gb/s
            LinkTier::CrossDc => 0.125e9, // 1 Gb/s
        }
    }

    pub fn latency(self) -> f64 {
        match self {
            LinkTier::InfiniBand => 5e-6,
            LinkTier::Eth100G => 20e-6,
            LinkTier::Eth10G => 100e-6,
            LinkTier::CrossDc => 20e-3,
        }
    }
}

/// PCIe 4.0 x16 effective bandwidth (intra-node fallback when either GPU
/// lacks NVLink) and latency.
pub const PCIE_BW: f64 = 25e9;
pub const PCIE_LAT: f64 = 2e-6;
/// NVLink per-hop latency.
pub const NVLINK_LAT: f64 = 1e-6;

/// One GPU in the cluster.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub id: DeviceId,
    pub gpu: GpuType,
    /// Server (node) index; GPUs on the same node talk over NVLink/PCIe.
    pub node: usize,
    /// Data-center index; nodes in different DCs talk over LinkTier::CrossDc.
    pub dc: usize,
}

/// A group of identical GPUs in one server.
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    pub gpu: GpuType,
    pub count: usize,
    pub dc: usize,
}

/// The full heterogeneous cluster: devices plus measured-equivalent
/// bandwidth/latency matrices (symmetric; diagonal is intra-GPU and unused).
#[derive(Clone, Debug)]
pub struct Cluster {
    pub name: String,
    pub devices: Vec<Device>,
    /// bytes/s between device pairs.
    pub bandwidth: Vec<Vec<f64>>,
    /// seconds between device pairs.
    pub latency: Vec<Vec<f64>>,
}

impl Cluster {
    /// Build a cluster from node specs. `inter_node` maps a pair of node
    /// indices (same DC) to the tier connecting them.
    pub fn build(
        name: &str,
        nodes: &[NodeSpec],
        inter_node: impl Fn(usize, usize) -> LinkTier,
    ) -> Cluster {
        let mut devices = Vec::new();
        for (ni, spec) in nodes.iter().enumerate() {
            for _ in 0..spec.count {
                devices.push(Device { id: devices.len(), gpu: spec.gpu, node: ni, dc: spec.dc });
            }
        }
        let n = devices.len();
        let mut bandwidth = vec![vec![0.0; n]; n];
        let mut latency = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    bandwidth[i][j] = f64::INFINITY;
                    continue;
                }
                let (a, b) = (&devices[i], &devices[j]);
                let (bw, lat) = if a.node == b.node {
                    // Intra-node: NVLink when both endpoints support it
                    // (same type in our single-type nodes), else PCIe.
                    match (a.gpu.nvlink_bw(), b.gpu.nvlink_bw()) {
                        (Some(x), Some(y)) => (x.min(y), NVLINK_LAT),
                        _ => (PCIE_BW, PCIE_LAT),
                    }
                } else if a.dc != b.dc {
                    (LinkTier::CrossDc.bandwidth(), LinkTier::CrossDc.latency())
                } else {
                    let t = inter_node(a.node.min(b.node), a.node.max(b.node));
                    (t.bandwidth(), t.latency())
                };
                bandwidth[i][j] = bw;
                latency[i][j] = lat;
            }
        }
        Cluster { name: name.to_string(), devices, bandwidth, latency }
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Total rental cost, $/hour (the paper's budget axis).
    pub fn budget_per_hour(&self) -> f64 {
        self.devices.iter().map(|d| d.gpu.price_per_hour()).sum()
    }

    /// Total device memory, bytes.
    pub fn total_memory(&self) -> f64 {
        self.devices.iter().map(|d| d.gpu.mem_bytes()).sum()
    }

    /// Aggregate dense FP16 compute, FLOP/s.
    pub fn total_compute(&self) -> f64 {
        self.devices.iter().map(|d| d.gpu.tflops()).sum()
    }

    pub fn count_of(&self, t: GpuType) -> usize {
        self.devices.iter().filter(|d| d.gpu == t).count()
    }

    /// Best (highest-bandwidth) link between two device sets.
    pub fn best_link(&self, a: &[DeviceId], b: &[DeviceId]) -> (f64, f64) {
        let mut best = (0.0f64, f64::INFINITY);
        for &i in a {
            for &j in b {
                if i != j && self.bandwidth[i][j] > best.0 {
                    best = (self.bandwidth[i][j], self.latency[i][j]);
                }
            }
        }
        best
    }

    /// Render the Gbps bandwidth matrix like paper Fig. 4 (for `experiments fig4`).
    pub fn bandwidth_matrix_gbps(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} ({} GPUs, budget ${:.2}/h)\n",
            self.name,
            self.n(),
            self.budget_per_hour()
        ));
        for i in 0..self.n() {
            let row: Vec<String> = (0..self.n())
                .map(|j| {
                    if i == j {
                        "    -".to_string()
                    } else {
                        format!("{:5.0}", self.bandwidth[i][j] * 8.0 / 1e9)
                    }
                })
                .collect();
            out.push_str(&format!("{:>6} {}\n", self.devices[i].gpu.name(), row.join(" ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_node_cluster() -> Cluster {
        Cluster::build(
            "test",
            &[
                NodeSpec { gpu: GpuType::A100, count: 2, dc: 0 },
                NodeSpec { gpu: GpuType::L40, count: 2, dc: 0 },
                NodeSpec { gpu: GpuType::A6000, count: 2, dc: 1 },
            ],
            |_, _| LinkTier::Eth100G,
        )
    }

    #[test]
    fn matrix_is_symmetric_and_tiered() {
        let c = two_node_cluster();
        assert_eq!(c.n(), 6);
        for i in 0..c.n() {
            for j in 0..c.n() {
                assert_eq!(c.bandwidth[i][j], c.bandwidth[j][i]);
                assert_eq!(c.latency[i][j], c.latency[j][i]);
            }
        }
        // A100 pair: NVLink 600 GB/s.
        assert_eq!(c.bandwidth[0][1], 600e9);
        // L40 pair: PCIe (no NVLink).
        assert_eq!(c.bandwidth[2][3], PCIE_BW);
        // A6000 pair: NVLink bridge.
        assert_eq!(c.bandwidth[4][5], 112e9);
        // Same-DC inter-node: the chosen tier.
        assert_eq!(c.bandwidth[0][2], LinkTier::Eth100G.bandwidth());
        // Cross-DC: WAN.
        assert_eq!(c.bandwidth[0][4], LinkTier::CrossDc.bandwidth());
        assert!(c.bandwidth[0][4] < c.bandwidth[0][2]);
    }

    #[test]
    fn budget_and_counts() {
        let c = two_node_cluster();
        assert_eq!(c.count_of(GpuType::A100), 2);
        let want = 2.0 * 1.69 + 2.0 * 1.04 + 2.0 * 0.75;
        assert!((c.budget_per_hour() - want).abs() < 1e-9);
    }

    #[test]
    fn best_link_picks_max() {
        let c = two_node_cluster();
        let (bw, _) = c.best_link(&[0, 1], &[2, 3]);
        assert_eq!(bw, LinkTier::Eth100G.bandwidth());
        let (bw2, _) = c.best_link(&[0], &[1]);
        assert_eq!(bw2, 600e9);
    }
}
