//! Online serving comparison on heterogeneous setting 2: HexGen-2's
//! disaggregated placement vs the HexGen colocated baseline, at 75% of peak
//! arrival rate (paper §5.1 online protocol). Reports throughput, latency
//! percentiles and SLO attainment (Fig. 8 axes).
//!
//! Run:  cargo run --release --example serve_online

use hexgen2::baselines::hexgen::schedule_hexgen;
use hexgen2::cluster::settings;
use hexgen2::experiments::{online_rate, ExpOpts};
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{schedule, ScheduleOptions};
use hexgen2::simulator::{run_colocated, run_disaggregated};
use hexgen2::workload::{Trace, WorkloadKind};

fn main() {
    let cluster = settings::het2();
    let model = OPT_30B;
    let opts = ExpOpts::quick();
    let rate = online_rate(&cluster, &model, &opts);
    let trace = Trace::online(WorkloadKind::Online, rate, 240.0, 3);
    println!(
        "online trace: {} requests at {:.2} req/s on {}\n",
        trace.requests.len(),
        rate,
        cluster.name
    );

    let r = schedule(&cluster, &model, &ScheduleOptions::new(WorkloadKind::Online)).unwrap();
    let a = run_disaggregated(&cluster, &model, &r.placement, &trace);
    let plan = schedule_hexgen(&cluster, &model, WorkloadKind::Online, 0, 15).unwrap();
    let b = run_colocated(&cluster, &model, &plan.replicas, &trace, None);

    for (name, rep) in [("HEXGEN-2 (disaggregated)", &a), ("HEXGEN (colocated)", &b)] {
        println!(
            "{name:26} {:>6.0} tokens/s | avg {:.2}s p95 {:.2}s | TTFT {:.2}s | SLO@99 scale {:.1}",
            rep.tokens_per_s(),
            rep.avg_latency(),
            rep.p_latency(95.0),
            rep.avg_ttft(),
            rep.slo_scale_for_attainment(0.99),
        );
    }
}
