//! The transfer scheduler: per-link queues, bandwidth reservation,
//! layer-wise pipelined chunking, and the link-load ledger.
//!
//! Time is plain `f64` seconds and the scheduler never owns a clock — the
//! caller (the discrete-event simulator core, or a live coordinator) asks
//! it to [`enqueue`](TransferScheduler::enqueue) a transfer at `now` and
//! gets back the chosen destination and completion time. A link is a
//! busy-until reservation: under [`LinkModel::PerRoute`] each (src, dst)
//! pair has its own, under [`LinkModel::SharedNic`] every transfer leaving
//! `src` shares one (the source's egress NIC).
//!
//! **Layer-wise pipelined chunking** (`chunk_layers = Some(c)`): the KV of a
//! request ships in `ceil(n_layers / c)` chunks, and all but the last chunk
//! may overlap the producing prefill burst — layer `l`'s KV exists as soon
//! as layer `l`'s prefill completes, so only the final chunk is forced to
//! wait for the burst to end. The reservation model: the transfer's
//! *effective start* moves up to `min(burst, xfer·(n-1)/n)` seconds before
//! the burst finished, and its completion is never earlier than `now +
//! xfer/n` (the last chunk still has to transmit). On an uncontended link
//! the arrival is therefore `xfer - overlap_credit` after prefill — never
//! later than the whole-cache transfer — and under contention it degrades
//! to exactly the whole-cache queueing behaviour (`tests/kvtransfer.rs`
//! asserts the invariant).

use std::collections::{BTreeMap, HashMap};

use super::route::{Candidate, RouteModel};
use super::LinkModel;

/// Fixed configuration of one [`TransferScheduler`].
#[derive(Clone, Copy, Debug)]
pub struct TransferConfig {
    pub route: RouteModel,
    pub link: LinkModel,
    /// Layer-wise pipelined chunking: layers per chunk (`None` = whole-cache
    /// transfer, the legacy behaviour).
    pub chunk_layers: Option<usize>,
    /// Model depth (chunk count = `ceil(n_layers / chunk_layers)`).
    pub n_layers: usize,
}

impl TransferConfig {
    /// Number of chunks a transfer is split into (1 = whole-cache).
    pub fn chunks(&self) -> usize {
        match self.chunk_layers {
            Some(c) if c > 0 => self.n_layers.div_ceil(c).max(1),
            _ => 1,
        }
    }
}

/// Aggregate stats of one (src, dst) route in the ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkStat {
    pub transfers: usize,
    pub bytes: f64,
    /// Transmission seconds reserved on the link.
    pub busy_s: f64,
    /// Seconds transfers spent queued behind earlier reservations.
    pub wait_s: f64,
}

/// One route's load record, exported on
/// [`SimReport::link_loads`](crate::simulator::SimReport).
#[derive(Clone, Copy, Debug)]
pub struct LinkLoad {
    /// Source (prefill) replica index.
    pub src: usize,
    /// Destination (decode) replica index.
    pub dst: usize,
    pub transfers: usize,
    pub bytes: f64,
    pub busy_s: f64,
    pub wait_s: f64,
}

/// Copy-friendly roll-up of the ledger (lands in
/// [`SimStats`](crate::simulator::SimStats)).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvSummary {
    pub transfers: usize,
    pub bytes: f64,
    pub wait_s: f64,
    /// Max over source NICs of transmission-busy fraction of the span.
    pub max_nic_util: f64,
    /// Queue-wait histogram, bucket edges [`Ledger::HIST_EDGES_S`].
    pub wait_hist: [usize; 6],
}

/// The link-load ledger: every transfer's route, bytes, transmission time,
/// and queue wait, accumulated per (src, dst) route plus a global wait
/// histogram. This is the observability half of the planner↔engine loop:
/// its NIC busy fraction is the measured counterpart of the analytic
/// [`kv_nic_utilization`](crate::scheduler::objective::kv_nic_utilization)
/// the contention-aware objective predicts.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    links: BTreeMap<(usize, usize), LinkStat>,
    hist: [usize; 6],
    transfers: usize,
    bytes: f64,
    wait_s: f64,
}

impl Ledger {
    /// Upper edges (seconds) of the first five wait-histogram buckets; the
    /// sixth bucket is everything ≥ 10 s.
    pub const HIST_EDGES_S: [f64; 5] = [1e-3, 1e-2, 1e-1, 1.0, 10.0];

    fn record(&mut self, src: usize, dst: usize, bytes: f64, busy_s: f64, wait_s: f64) {
        let e = self.links.entry((src, dst)).or_default();
        e.transfers += 1;
        e.bytes += bytes;
        e.busy_s += busy_s;
        e.wait_s += wait_s;
        self.transfers += 1;
        self.bytes += bytes;
        self.wait_s += wait_s;
        let bucket = Ledger::HIST_EDGES_S
            .iter()
            .position(|&edge| wait_s < edge)
            .unwrap_or(Ledger::HIST_EDGES_S.len());
        // hexcheck: allow(P1) -- bucket is position() capped at HIST_EDGES_S.len(), always < hist.len() == 6
        self.hist[bucket] += 1;
    }

    pub fn transfers(&self) -> usize {
        self.transfers
    }

    pub fn bytes(&self) -> f64 {
        self.bytes
    }

    pub fn wait_s(&self) -> f64 {
        self.wait_s
    }

    pub fn wait_hist(&self) -> [usize; 6] {
        self.hist
    }

    /// Per-route load records, sorted by (src, dst) for deterministic output.
    pub fn loads(&self) -> Vec<LinkLoad> {
        let mut out: Vec<LinkLoad> = self
            .links
            .iter()
            .map(|(&(src, dst), s)| LinkLoad {
                src,
                dst,
                transfers: s.transfers,
                bytes: s.bytes,
                busy_s: s.busy_s,
                wait_s: s.wait_s,
            })
            .collect();
        out.sort_by_key(|l| (l.src, l.dst));
        out
    }

    /// Transmission-busy seconds per source NIC (all routes of a source
    /// summed — exact under `SharedNic`, offered-load under `PerRoute`).
    pub fn nic_busy_s(&self) -> Vec<(usize, f64)> {
        let mut per: BTreeMap<usize, f64> = BTreeMap::new();
        for (&(src, _), s) in &self.links {
            *per.entry(src).or_default() += s.busy_s;
        }
        per.into_iter().collect()
    }

    /// Roll-up over a serving span of `span` seconds.
    pub fn summary(&self, span: f64) -> KvSummary {
        let span = span.max(1e-9);
        let max_nic_util = self
            .nic_busy_s()
            .iter()
            .map(|&(_, busy)| busy / span)
            .fold(0.0f64, f64::max);
        KvSummary {
            transfers: self.transfers,
            bytes: self.bytes,
            wait_s: self.wait_s,
            max_nic_util,
            wait_hist: self.hist,
        }
    }
}

/// A scheduled transfer: where the cache goes and when it lands.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    /// Chosen destination (decode replica index).
    pub dst: usize,
    /// When transmission occupies the link (start of the reserved window;
    /// under pipelined chunking this may precede the enqueue time by the
    /// overlap credit). `done - start` is the transmission itself — the
    /// flight recorder's per-chunk span source.
    pub start: f64,
    /// Arrival time of the (last chunk of the) cache.
    pub done: f64,
    /// Queueing delay beyond the contention-free transfer.
    pub wait_s: f64,
}

/// The transfer scheduler: max-flow route table, per-link busy-until
/// reservations, in-flight counts, policy-driven route selection, and the
/// [`Ledger`].
pub struct TransferScheduler {
    cfg: TransferConfig,
    /// Max-flow route weights, keyed (src, dst) — §3.3 flow values.
    route_w: HashMap<(usize, usize), f64>,
    /// Transfers routed so far, keyed (dst, src) — the deficit counters
    /// (key order kept from the legacy engine for bit-parity).
    assigned_from: HashMap<(usize, usize), f64>,
    /// Busy-until reservation per link key.
    link_free: HashMap<(usize, usize), f64>,
    /// Transfers queued or in flight per link key.
    inflight: HashMap<(usize, usize), usize>,
    ledger: Ledger,
    /// Reused candidate buffer (the simulator's alloc-free hot loop).
    cand_buf: Vec<Candidate>,
}

impl TransferScheduler {
    pub fn new(cfg: TransferConfig) -> TransferScheduler {
        TransferScheduler {
            cfg,
            route_w: HashMap::new(),
            assigned_from: HashMap::new(),
            link_free: HashMap::new(),
            inflight: HashMap::new(),
            ledger: Ledger::default(),
            cand_buf: Vec::new(),
        }
    }

    pub fn config(&self) -> &TransferConfig {
        &self.cfg
    }

    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    /// Register a max-flow route (weights accumulate across epochs, exactly
    /// like the legacy in-core table).
    pub fn add_route(&mut self, src: usize, dst: usize, flow: f64) {
        *self.route_w.entry((src, dst)).or_default() += flow;
    }

    /// Register the tiny-weight fallback route the engine uses when
    /// max-flow left a prefill replica unrouted.
    pub fn add_fallback(&mut self, src: usize, dst: usize) {
        self.route_w.insert((src, dst), 1e-6);
    }

    pub fn has_route(&self, src: usize, dst: usize) -> bool {
        self.route_w.contains_key(&(src, dst))
    }

    fn key(&self, src: usize, dst: usize) -> (usize, usize) {
        match self.cfg.link {
            LinkModel::PerRoute => (src, dst),
            LinkModel::SharedNic => (src, usize::MAX),
        }
    }

    /// Route and reserve one KV transfer leaving `src` at `now`.
    ///
    /// `cands` lists the feasible destinations in ascending order (must be
    /// non-empty); `xfer_of` yields a route's Table-1 transmission seconds
    /// and is queried lazily — once per candidate only for policies that
    /// rank by it ([`RouteModel::needs_xfer`]), otherwise once for the
    /// chosen route (the per-candidate query is a device-pair link scan and
    /// this is the simulator's hot path). `overlap_s` is the duration of
    /// the prefill burst that produced this cache — the window layer-wise
    /// chunks may pipeline into (ignored without chunking). `bytes` feeds
    /// the ledger only.
    pub fn enqueue(
        &mut self,
        src: usize,
        bytes: f64,
        now: f64,
        overlap_s: f64,
        cands: &[usize],
        mut xfer_of: impl FnMut(usize) -> f64,
    ) -> Transfer {
        debug_assert!(!cands.is_empty(), "enqueue with no candidate route");
        let need_xfer = self.cfg.route.needs_xfer();
        let mut buf = std::mem::take(&mut self.cand_buf);
        buf.clear();
        for &dst in cands {
            let key = self.key(src, dst);
            buf.push(Candidate {
                dst,
                weight: self.route_w.get(&(src, dst)).copied().unwrap_or(1e-6),
                assigned: self.assigned_from.get(&(dst, src)).copied().unwrap_or(0.0),
                backlog_s: (self.link_free.get(&key).copied().unwrap_or(0.0) - now).max(0.0),
                queue_len: self.inflight.get(&key).copied().unwrap_or(0),
                xfer_s: if need_xfer { xfer_of(dst) } else { 0.0 },
            });
        }
        let pick = self.cfg.route.policy().pick(&buf);
        let dst = buf[pick].dst; // hexcheck: allow(P1) -- pick is an index into buf returned by RoutePolicy::pick
        let xfer = if need_xfer { buf[pick].xfer_s } else { xfer_of(dst) }; // hexcheck: allow(P1) -- same pick index, buf unchanged
        self.cand_buf = buf;

        *self.assigned_from.entry((dst, src)).or_default() += 1.0;
        let key = self.key(src, dst);
        let raw_free = self.link_free.get(&key).copied().unwrap_or(0.0);
        let chunks = self.cfg.chunks();
        let (start, done, wait_s) = if chunks > 1 {
            // Pipelined: the first (chunks-1) chunks may ship while the
            // prefill still runs, so the effective enqueue time moves back
            // by the overlap credit. The credit cap already guarantees the
            // last chunk transmits after `now`:
            //   done >= eff + xfer = now + xfer - credit >= now + xfer/chunks.
            let credit = overlap_s.max(0.0).min(xfer * (chunks as f64 - 1.0) / chunks as f64);
            let eff = now - credit;
            let start = raw_free.max(eff);
            let done = start + xfer;
            debug_assert!(done >= now + xfer / chunks as f64 - 1e-12);
            (start, done, done - (eff + xfer))
        } else {
            // Whole-cache: exactly the legacy reservation arithmetic.
            let free = raw_free.max(now);
            (free, free + xfer, free - now)
        };
        self.link_free.insert(key, done);
        *self.inflight.entry(key).or_default() += 1;
        self.ledger.record(src, dst, bytes, xfer, wait_s);
        Transfer { dst, start, done, wait_s }
    }

    /// A transfer previously enqueued on (src → dst) completed.
    pub fn complete(&mut self, src: usize, dst: usize) {
        let key = self.key(src, dst);
        if let Some(n) = self.inflight.get_mut(&key) {
            *n = n.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(route: RouteModel, link: LinkModel, chunk: Option<usize>) -> TransferConfig {
        TransferConfig { route, link, chunk_layers: chunk, n_layers: 48 }
    }

    #[test]
    fn whole_cache_matches_legacy_reservation() {
        let mut s = TransferScheduler::new(cfg(
            RouteModel::FlowProportional,
            LinkModel::PerRoute,
            None,
        ));
        s.add_route(0, 1, 10.0);
        // Idle link: no wait, done = now + xfer.
        let a = s.enqueue(0, 100.0, 5.0, 0.0, &[1], |_| 2.0);
        assert_eq!(a.dst, 1);
        assert_eq!(a.done, 7.0);
        assert_eq!(a.wait_s, 0.0);
        // Second transfer queues behind the first: wait = 7 - 6 = 1.
        let b = s.enqueue(0, 100.0, 6.0, 0.0, &[1], |_| 2.0);
        assert_eq!(b.done, 9.0);
        assert_eq!(b.wait_s, 1.0);
        let l = s.ledger().summary(9.0);
        assert_eq!(l.transfers, 2);
        assert_eq!(l.bytes, 200.0);
        assert!((l.wait_s - 1.0).abs() < 1e-12);
        // 4 s of transmission over a 9 s span on NIC 0.
        assert!((l.max_nic_util - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn shared_nic_serializes_across_destinations() {
        let mut s =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::SharedNic, None));
        s.add_route(0, 1, 1.0);
        s.add_route(0, 2, 1.0);
        let a = s.enqueue(0, 1.0, 0.0, 0.0, &[1], |_| 2.0);
        // Different destination, same NIC: still queues.
        let b = s.enqueue(0, 1.0, 0.0, 0.0, &[2], |_| 2.0);
        assert_eq!(a.done, 2.0);
        assert_eq!(b.done, 4.0);
        assert_eq!(b.wait_s, 2.0);
    }

    #[test]
    fn pipelined_chunks_never_later_than_whole_cache() {
        // 48 layers in 8-layer chunks = 6 chunks; xfer 6 s; burst 10 s.
        // Credit = min(10, 6*5/6) = 5 → done = now + 1 on an idle link.
        let mut chunked =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::PerRoute, Some(8)));
        chunked.add_route(0, 1, 1.0);
        let c = chunked.enqueue(0, 1.0, 20.0, 10.0, &[1], |_| 6.0);
        assert!((c.done - 21.0).abs() < 1e-12, "{}", c.done);
        assert_eq!(c.wait_s, 0.0);
        // Whole-cache reference on an identical fresh link: done = 26.
        let mut whole =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::PerRoute, None));
        whole.add_route(0, 1, 1.0);
        let w = whole.enqueue(0, 1.0, 20.0, 10.0, &[1], |_| 6.0);
        assert!((w.done - 26.0).abs() < 1e-12);
        assert!(c.done <= w.done);
        // Short burst: credit limited by the burst, done = 26 - 0.5.
        let mut short =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::PerRoute, Some(8)));
        short.add_route(0, 1, 1.0);
        let sres = short.enqueue(0, 1.0, 20.0, 0.5, &[1], |_| 6.0);
        assert!((sres.done - 25.5).abs() < 1e-12, "{}", sres.done);
        // The last chunk can never land before now + xfer/chunks.
        let mut floor =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::PerRoute, Some(8)));
        floor.add_route(0, 1, 1.0);
        let f = floor.enqueue(0, 1.0, 20.0, 1e9, &[1], |_| 6.0);
        assert!((f.done - 21.0).abs() < 1e-12, "{}", f.done);
    }

    #[test]
    fn pipelined_contended_degrades_to_whole_cache_queueing() {
        let mut s =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::PerRoute, Some(8)));
        s.add_route(0, 1, 1.0);
        // Saturate the link until t=100.
        let first = s.enqueue(0, 1.0, 0.0, 0.0, &[1], |_| 100.0);
        assert_eq!(first.done, 100.0);
        // A chunked transfer at t=50 starts when the link frees.
        let c = s.enqueue(0, 1.0, 50.0, 10.0, &[1], |_| 6.0);
        assert!((c.done - 106.0).abs() < 1e-12, "{}", c.done);
        assert!(c.wait_s > 0.0);
    }

    #[test]
    fn inflight_counts_track_completions() {
        let mut s =
            TransferScheduler::new(cfg(RouteModel::LeastLoaded, LinkModel::PerRoute, None));
        s.add_route(0, 1, 1.0);
        s.add_route(0, 2, 1.0);
        let a = s.enqueue(0, 1.0, 0.0, 0.0, &[1, 2], |_| 1.0);
        // Tie on idle links broken by weight (equal) → earliest = dst 1.
        assert_eq!(a.dst, 1);
        // Next transfer sees dst 1 backlogged and routes to dst 2.
        let b = s.enqueue(0, 1.0, 0.0, 0.0, &[1, 2], |_| 1.0);
        assert_eq!(b.dst, 2);
        s.complete(0, a.dst);
        s.complete(0, b.dst);
        assert_eq!(*s.inflight.values().max().unwrap(), 0);
    }

    #[test]
    fn eta_greedy_prefers_fast_route_on_shared_nic() {
        let mut s = TransferScheduler::new(cfg(RouteModel::EtaGreedy, LinkModel::SharedNic, None));
        s.add_route(0, 1, 100.0);
        s.add_route(0, 2, 1.0);
        // Same NIC backlog for both; the faster route wins regardless of
        // its tiny flow weight.
        let t = s.enqueue(0, 1.0, 0.0, 0.0, &[1, 2], |d| if d == 1 { 5.0 } else { 1.0 });
        assert_eq!(t.dst, 2);
    }

    #[test]
    fn ledger_histogram_buckets_waits() {
        let mut s =
            TransferScheduler::new(cfg(RouteModel::FlowProportional, LinkModel::PerRoute, None));
        s.add_route(0, 1, 1.0);
        let _ = s.enqueue(0, 1.0, 0.0, 0.0, &[1], |_| 0.5); // wait 0 → bucket 0
        let _ = s.enqueue(0, 1.0, 0.0, 0.0, &[1], |_| 0.5); // wait 0.5 → bucket 3
        let _ = s.enqueue(0, 1.0, 0.0, 0.0, &[1], |_| 20.0); // wait 1.0 → bucket 4
        let _ = s.enqueue(0, 1.0, 0.0, 0.0, &[1], |_| 1.0); // wait 21 → bucket 5
        assert_eq!(s.ledger().wait_hist(), [1, 0, 0, 1, 1, 1]);
        assert_eq!(s.ledger().loads().len(), 1);
        assert_eq!(s.ledger().loads()[0].transfers, 4);
    }
}
