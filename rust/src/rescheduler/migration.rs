//! Migration pricing: is switching from the incumbent placement to a
//! candidate worth it within one scheduling period T?
//!
//! The switch cost has two parts, both derived from the Table-1 cost model
//! and the cluster bandwidth matrix:
//! - **Drain**: in-flight work must finish on the old replicas — the worst
//!   residual over old groups (a saturated prefill batch, or half a decode
//!   generation at the group's memory-limited batch).
//! - **KV transfer**: requests mid-decode on groups whose device set changes
//!   carry their KV caches to the new decode replicas over the best
//!   old-group → new-decode links (Table 1's 2·s·H·B per layer).
//!
//! The net-benefit test ([`MigrationPlan::migrate`]) only approves a switch
//! whose projected throughput gain over one period amortizes the tokens lost
//! while draining + transferring — the rescheduler never flaps onto a
//! marginally-better placement.

use crate::cluster::Cluster;
use crate::costmodel::{CostModel, TaskProfile, PREFILL_SATURATION_TOKENS};
use crate::model::LlmSpec;
use crate::scheduler::{Objective, Placement};

/// Priced migration from an incumbent placement to a candidate.
#[derive(Clone, Copy, Debug)]
pub struct MigrationPlan {
    /// Time for in-flight work to finish on the old replicas, seconds.
    pub drain_s: f64,
    /// KV-cache bytes that must move to the new decode replicas.
    pub kv_bytes: f64,
    /// Time to move them over the cluster links, seconds.
    pub transfer_s: f64,
    /// Total serving stall: drain + transfer.
    pub total_delay_s: f64,
    /// Estimated tokens foregone during the stall (old throughput × stall).
    pub tokens_lost: f64,
    /// Projected extra tokens over one period T at the new placement's rate.
    pub gain_tokens: f64,
    /// Net-benefit verdict: gain amortizes the cost within one period.
    pub migrate: bool,
}

/// Sorted device list of a group (device-set identity across placements).
fn devset(devices: &[usize]) -> Vec<usize> {
    let mut v = devices.to_vec();
    v.sort_unstable();
    v
}

/// Price a switch `old` → `new` for traffic described by `task`, against a
/// scheduling period of `period` seconds. The net-benefit verdict compares
/// the two placements under `objective` — the same criterion the re-plan was
/// ranked by — so the gate and the warm-start agree on what "better" means.
pub fn plan(
    cluster: &Cluster,
    model: &LlmSpec,
    old: &Placement,
    new: &Placement,
    task: &TaskProfile,
    period: f64,
    objective: Objective,
) -> MigrationPlan {
    plan_under_load(cluster, model, old, new, task, period, objective, 0.0)
}

/// [`plan`] priced under observed/predicted NIC load: migration KV moves
/// share the serving fabric, so the transfer bandwidth is derated by
/// `nic_util` — the source NICs' KV busy fraction, either measured by the
/// transfer engine's ledger
/// ([`SimStats::kv_max_nic_util`](crate::simulator::SimStats)) or
/// predicted analytically from the incumbent
/// ([`objective::kv_nic_utilization`](crate::scheduler::objective::kv_nic_utilization)).
/// `nic_util = 0` reproduces the unloaded pricing exactly; utilization is
/// clamped at 95% so a saturated NIC prices migrations as very expensive
/// rather than impossible (drains still make progress as serving traffic
/// ebbs).
#[allow(clippy::too_many_arguments)]
pub fn plan_under_load(
    cluster: &Cluster,
    model: &LlmSpec,
    old: &Placement,
    new: &Placement,
    task: &TaskProfile,
    period: f64,
    objective: Objective,
    nic_util: f64,
) -> MigrationPlan {
    let cm = CostModel::new(cluster, model);
    let bw_derate = 1.0 - nic_util.clamp(0.0, 0.95);

    // ---- Drain: worst residual service time across old groups. ----
    let mut drain_s = 0.0f64;
    for g in &old.groups {
        let Some(cfg) = &g.config else { continue };
        if g.capacity <= 0.0 {
            continue;
        }
        let residual = if g.is_prefill {
            // One in-flight saturation batch (Fig. 1: replicas batch up to
            // ~2048 tokens per iteration).
            let b = ((PREFILL_SATURATION_TOKENS / task.s_in.max(1.0)).ceil() as usize).max(1);
            cm.prefill_latency(cfg, &TaskProfile { batch: b, s_out: 0.0, ..*task })
        } else {
            // Half a generation at the memory-limited batch.
            let mb = cm.max_decode_batch(cfg, task).max(1);
            cm.decode_latency(cfg, &task.with_batch(mb)) * 0.5
        };
        drain_s = drain_s.max(residual);
    }

    // ---- KV transfer: caches of requests mid-decode on groups that change. ----
    // A decode group whose exact device set also serves decode in the new
    // placement keeps its caches in place.
    let new_decode_sets: Vec<Vec<usize>> = new
        .groups
        .iter()
        .filter(|g| !g.is_prefill && g.capacity > 0.0)
        .map(|g| devset(&g.devices))
        .collect();
    let new_decode_devices: Vec<usize> =
        new_decode_sets.iter().flatten().copied().collect();
    let kv_per_request =
        model.kv_bytes_per_token(model.n_layers) * (task.s_in + 0.5 * task.s_out);
    let mut kv_bytes = 0.0f64;
    let mut transfer_s = 0.0f64;
    for (gi, g) in old.groups.iter().enumerate() {
        if g.is_prefill || g.capacity <= 0.0 {
            continue;
        }
        let Some(cfg) = &g.config else { continue };
        if new_decode_sets.contains(&devset(&g.devices)) {
            continue; // caches stay put
        }
        // Occupancy estimate: memory-limited batch × flow utilization.
        let util = old.group_utilization.get(gi).copied().unwrap_or(1.0).clamp(0.0, 1.0);
        let inflight = (cm.max_decode_batch(cfg, task) as f64 * util).ceil();
        if inflight <= 0.0 || new_decode_devices.is_empty() {
            continue;
        }
        let bytes = inflight * kv_per_request;
        kv_bytes += bytes;
        let (bw, lat) = cluster.best_link(&g.devices, &new_decode_devices);
        // Groups transfer in parallel; the slowest one bounds the stall.
        // Migration bytes compete with in-flight serving KV on the fabric:
        // only the un-reserved bandwidth fraction is available.
        let eff_bw = bw * bw_derate;
        let t = if eff_bw > 0.0 { lat + bytes / eff_bw } else { f64::INFINITY };
        transfer_s = transfer_s.max(t);
    }

    let total_delay_s = drain_s + transfer_s;
    let tokens_lost = old.tokens_per_s * total_delay_s;
    let gain_tokens = (new.tokens_per_s - old.tokens_per_s) * period;
    let migrate = total_delay_s.is_finite()
        && match objective {
            // Paper-default gate: the throughput gain over one period must
            // amortize the tokens foregone while draining + transferring.
            Objective::Throughput => {
                new.tokens_per_s > old.tokens_per_s && gain_tokens > tokens_lost
            }
            // Other objectives: require a >1% score improvement under the
            // chosen objective (the same hysteresis role the token
            // amortization plays for throughput — never flap onto a
            // marginally-better placement). Both placements are re-scored
            // under the *current* task: the incumbent's stored score was
            // computed under the workload it was planned for, which may
            // differ from the drifted traffic being priced here.
            _ => {
                let ns = objective.score(cluster, model, task, new);
                let os = objective.score(cluster, model, task, old);
                ns > os + os.abs() * 0.01
            }
        };
    MigrationPlan { drain_s, kv_bytes, transfer_s, total_delay_s, tokens_lost, gain_tokens, migrate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::scheduler::{self, ScheduleOptions};
    use crate::workload::WorkloadKind;

    fn incumbent() -> (crate::cluster::Cluster, Placement) {
        let c = settings::case_study();
        let mut o = ScheduleOptions::new(WorkloadKind::Lphd);
        o.max_rounds = 4;
        o.force_k = Some(4);
        let p = scheduler::schedule(&c, &OPT_30B, &o).unwrap().placement;
        (c, p)
    }

    #[test]
    fn identity_switch_refused() {
        let (c, p) = incumbent();
        let task = scheduler::task_for(WorkloadKind::Lphd);
        let m = plan(&c, &OPT_30B, &p, &p, &task, 600.0, Objective::Throughput);
        assert!(!m.migrate, "zero-gain switch approved: {m:?}");
        assert!(m.drain_s > 0.0, "no drain cost modeled");
        // Same device sets serve decode: no KV moves.
        assert_eq!(m.kv_bytes, 0.0);
        assert_eq!(m.transfer_s, 0.0);
    }

    #[test]
    fn marginal_gain_below_cost_refused() {
        let (c, p) = incumbent();
        let task = scheduler::task_for(WorkloadKind::Lphd);
        let mut better = p.clone();
        // A 0.001% projected gain can never amortize a real drain cost.
        better.tokens_per_s = p.tokens_per_s * 1.00001;
        let m = plan(&c, &OPT_30B, &p, &better, &task, 600.0, Objective::Throughput);
        assert!(m.tokens_lost > 0.0);
        assert!(m.gain_tokens > 0.0);
        assert!(!m.migrate, "drain+transfer cost exceeds gain yet approved: {m:?}");
    }

    #[test]
    fn loaded_nic_inflates_transfer_cost() {
        let (c, p) = incumbent();
        let task = scheduler::task_for(WorkloadKind::Lphd);
        let mut better = p.clone();
        better.tokens_per_s = p.tokens_per_s * 2.0;
        // Flip phases so KV actually moves.
        for g in better.groups.iter_mut() {
            g.is_prefill = !g.is_prefill;
        }
        let idle =
            plan_under_load(&c, &OPT_30B, &p, &better, &task, 600.0, Objective::Throughput, 0.0);
        let busy =
            plan_under_load(&c, &OPT_30B, &p, &better, &task, 600.0, Objective::Throughput, 0.9);
        assert!(idle.transfer_s > 0.0);
        // 90% reserved bandwidth → ~10x the transfer time (latency term
        // keeps it from being exact).
        assert!(
            busy.transfer_s > idle.transfer_s * 5.0,
            "loaded NIC barely priced: {} vs {}",
            busy.transfer_s,
            idle.transfer_s
        );
        assert_eq!(idle.kv_bytes, busy.kv_bytes, "load must not change what moves");
        // Saturation clamps rather than producing infinities.
        let sat =
            plan_under_load(&c, &OPT_30B, &p, &better, &task, 600.0, Objective::Throughput, 5.0);
        assert!(sat.transfer_s.is_finite());
        // The unloaded entry point is the legacy pricing bit-for-bit.
        let legacy = plan(&c, &OPT_30B, &p, &better, &task, 600.0, Objective::Throughput);
        assert_eq!(legacy.transfer_s, idle.transfer_s);
        assert_eq!(legacy.migrate, idle.migrate);
    }

    #[test]
    fn large_gain_approved() {
        let (c, p) = incumbent();
        let task = scheduler::task_for(WorkloadKind::Lphd);
        let mut better = p.clone();
        better.tokens_per_s = p.tokens_per_s * 2.0;
        // Flip phases so the KV-transfer path is exercised too.
        for g in better.groups.iter_mut() {
            g.is_prefill = !g.is_prefill;
        }
        let m = plan(&c, &OPT_30B, &p, &better, &task, 600.0, Objective::Throughput);
        assert!(m.kv_bytes > 0.0, "phase flip should move KV: {m:?}");
        assert!(m.transfer_s > 0.0);
        assert!(m.migrate, "2x gain refused: {m:?}");
    }
}
