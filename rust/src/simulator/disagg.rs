//! Disaggregated serving entry points — thin wrappers over the unified
//! event engine ([`core::simulate`](super::core::simulate)).
//!
//! The engine instantiates one [`DisaggPrefill`](super::core::DisaggPrefill)
//! policy per prefill group (token-budget batching, Fig. 1) and one
//! [`DisaggDecode`](super::core::DisaggDecode) per decode group (continuous
//! batching gated on KV arrival), routes requests proportionally to the
//! max-flow assignment, and serializes KV transfers through per-link
//! queues.
//!
//! Online rescheduling (the §3.3 loop): [`run_disaggregated_with_resched`]
//! takes a list of [`PlacementSwitch`]es; at each switch time a `Resched`
//! event quiesces the active replicas (their unstarted queue drains back to
//! a holding buffer, in-flight batches and running decodes complete on the
//! old placement — the drain), and after the switch's migration delay an
//! `Activate` event brings the new placement's replicas live and flushes
//! the held requests to them. The same quiesce/drain/activate machinery
//! works for colocated epochs through [`SwitchSpec`](super::SwitchSpec)
//! directly.

use crate::cluster::Cluster;
use crate::model::LlmSpec;
use crate::scheduler::Placement;
use crate::workload::{Trace, WorkloadKind};

use super::core::{simulate, ServingSpec, SimConfig, SwitchSpec};
use super::metrics::SimReport;

/// One placement switch of a rescheduling scenario: at time `at` the old
/// replicas are quiesced; at `at + delay` (drain + KV/weight migration, as
/// priced by `rescheduler::migration`) the new placement starts serving.
#[derive(Clone, Debug)]
pub struct PlacementSwitch {
    pub at: f64,
    pub delay: f64,
    pub placement: Placement,
    /// Workload the new placement was (re-)planned for: its mean lengths
    /// size the new replicas' batching (prefill memory batch, decode slot
    /// count). None = keep the trace's opening-phase statistics.
    pub workload: Option<WorkloadKind>,
}

impl From<&PlacementSwitch> for SwitchSpec {
    fn from(s: &PlacementSwitch) -> SwitchSpec {
        SwitchSpec {
            at: s.at,
            delay: s.delay,
            to: ServingSpec::Disaggregated(s.placement.clone()),
            workload: s.workload,
        }
    }
}

/// Simulate a trace against a placement. Requests that cannot be served at
/// all (no feasible replica) are dropped from the report.
pub fn run_disaggregated(
    cluster: &Cluster,
    model: &LlmSpec,
    placement: &Placement,
    trace: &Trace,
) -> SimReport {
    run_disaggregated_cfg(cluster, model, placement, trace, &SimConfig::default())
}

/// [`run_disaggregated`] with explicit engine knobs (chunked prefill,
/// per-request admission, link contention model).
pub fn run_disaggregated_cfg(
    cluster: &Cluster,
    model: &LlmSpec,
    placement: &Placement,
    trace: &Trace,
    cfg: &SimConfig,
) -> SimReport {
    simulate(
        cluster,
        model,
        &ServingSpec::Disaggregated(placement.clone()),
        &[],
        trace,
        cfg,
    )
}

/// Simulate a trace with mid-trace placement switches (the rescheduler's
/// closed loop). `switches` must be sorted by `at` and non-overlapping
/// (each `at + delay` before the next `at`). An infeasible switch placement
/// is skipped: the previously active replicas resume at activation time.
pub fn run_disaggregated_with_resched(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &Placement,
    switches: &[PlacementSwitch],
    trace: &Trace,
) -> SimReport {
    let sw: Vec<SwitchSpec> = switches.iter().map(SwitchSpec::from).collect();
    simulate(
        cluster,
        model,
        &ServingSpec::Disaggregated(initial.clone()),
        &sw,
        trace,
        &SimConfig::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::scheduler::{self, ScheduleOptions};
    use crate::workload::WorkloadKind;

    fn small_placement() -> (crate::cluster::Cluster, Placement) {
        let c = settings::homogeneous_small();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lpld);
        opts.max_rounds = 4;
        opts.force_k = Some(2);
        let r = scheduler::schedule(&c, &OPT_30B, &opts).unwrap();
        (c, r.placement)
    }

    #[test]
    fn all_requests_complete() {
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Lpld, 40, 1);
        let rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
        assert_eq!(rep.records.len(), 40, "lost requests");
        assert_eq!(rep.stats.unserved, 0);
        assert!(rep.tokens_per_s() > 0.0);
        for r in &rep.records {
            assert!(r.prefill_done >= r.arrival);
            assert!(r.completion > r.prefill_done);
        }
    }

    #[test]
    fn online_latency_below_offline_saturation() {
        let (c, p) = small_placement();
        // Gentle online load: latency should be near service time; heavy
        // offline load queues much more.
        let online = Trace::online(WorkloadKind::Lpld, 0.5, 100.0, 2);
        let offline = Trace::offline(WorkloadKind::Lpld, 200, 2);
        let r_on = run_disaggregated(&c, &OPT_30B, &p, &online);
        let r_off = run_disaggregated(&c, &OPT_30B, &p, &offline);
        assert!(r_on.avg_latency() < r_off.avg_latency(), "queueing not visible");
    }

    #[test]
    fn deterministic() {
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Hphd, 30, 5);
        let a = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let b = run_disaggregated(&c, &OPT_30B, &p, &trace);
        assert_eq!(a.tokens_per_s(), b.tokens_per_s());
        assert_eq!(a.records.len(), b.records.len());
    }

    #[test]
    fn estimated_throughput_aligns_with_simulated() {
        // §5.3: "the estimated serving throughput closely aligns with the
        // actual throughput" — within 2x either way here (estimator is a
        // steady-state bound; the simulator has queueing/startup effects).
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Lpld, 300, 3);
        let rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let est = p.tokens_per_s;
        let sim = rep.tokens_per_s();
        assert!(sim > est * 0.3 && sim < est * 3.0, "est {est} vs sim {sim}");
    }

    #[test]
    fn chunked_prefill_disagg_completes_and_keeps_throughput() {
        // The SARATHI-style chunking the engine now supports on dedicated
        // prefill replicas: long prompts spread over iterations, nothing is
        // lost, and throughput stays in the plain engine's ballpark.
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Hpld, 60, 4);
        let plain = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let cfg = SimConfig { chunked_prefill: Some(512), ..SimConfig::default() };
        let chunked = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
        assert_eq!(chunked.records.len(), plain.records.len(), "chunking lost requests");
        assert!(chunked.tokens_per_s() > plain.tokens_per_s() * 0.5);
        for r in &chunked.records {
            assert!(r.prefill_done >= r.arrival && r.completion > r.prefill_done);
        }
    }

    #[test]
    fn resched_no_requests_lost_across_switch() {
        // A mid-trace switch to a different placement must not lose or
        // duplicate any request, even with a blackout window.
        let (c, p) = small_placement();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lpld);
        opts.max_rounds = 4;
        opts.force_k = Some(2);
        opts.seed = 99;
        let p2 = scheduler::schedule(&c, &OPT_30B, &opts).unwrap().placement;
        let trace = Trace::online(WorkloadKind::Lpld, 1.0, 120.0, 4);
        let n = trace.requests.len();
        let switches = vec![PlacementSwitch { at: 60.0, delay: 5.0, placement: p2, workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), n, "requests lost across the switch");
        let mut ids: Vec<usize> = rep.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicated requests");
        for r in &rep.records {
            assert!(r.prefill_done >= r.arrival && r.completion > r.prefill_done);
        }
    }

    #[test]
    fn resched_identity_switch_is_benign() {
        // Switching to the same placement only inserts the blackout; all
        // requests still complete and throughput stays positive.
        let (c, p) = small_placement();
        let trace = Trace::online(WorkloadKind::Lpld, 0.8, 100.0, 6);
        let n = trace.requests.len();
        let switches = vec![PlacementSwitch { at: 50.0, delay: 2.0, placement: p.clone(), workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), n);
        assert!(rep.tokens_per_s() > 0.0);
    }

    #[test]
    fn resched_infeasible_switch_falls_back_to_old_placement() {
        use crate::scheduler::placement::GroupPlan;
        let (c, p) = small_placement();
        // A placement whose every group is dead: the switch must be skipped
        // and the old replicas must resume after the blackout.
        let dead = Placement {
            groups: vec![GroupPlan {
                devices: (0..c.n()).collect(),
                is_prefill: true,
                config: None,
                capacity: 0.0,
            }],
            routes: vec![],
            flow_value: 0.0,
            tokens_per_s: 0.0,
            group_utilization: vec![0.0],
            objective_score: 0.0,
        };
        let trace = Trace::online(WorkloadKind::Lpld, 0.8, 80.0, 7);
        let n = trace.requests.len();
        let switches = vec![PlacementSwitch { at: 40.0, delay: 3.0, placement: dead, workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), n, "fallback lost requests");
    }

    #[test]
    fn streaming_matches_materialized_bit_for_bit() {
        // DESIGN.md §14: pulling requests lazily from a TraceSource through
        // the bounded arrival frontier must reproduce the materialized
        // run's aggregates exactly — same seed, same SimReport, on the
        // paper's case-study cluster.
        use crate::simulator::core::simulate_stream;
        use crate::workload::TraceSource;
        let c = settings::case_study();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 4;
        opts.seed = 7;
        let p = scheduler::schedule(&c, &OPT_30B, &opts).unwrap().placement;
        let cfg = SimConfig::default();
        let spec = ServingSpec::Disaggregated(p);
        let trace = Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 11);
        let mat = simulate(&c, &OPT_30B, &spec, &[], &trace, &cfg);
        let src = TraceSource::online(WorkloadKind::Lphd, 2.0, 90.0, 11);
        let stream = simulate_stream(&c, &OPT_30B, &spec, &[], src, &cfg);
        assert_eq!(stream.records.len(), mat.records.len());
        assert_eq!(stream.makespan, mat.makespan);
        assert_eq!(stream.tokens_per_s(), mat.tokens_per_s());
        assert_eq!(stream.avg_latency(), mat.avg_latency());
        assert_eq!(stream.avg_ttft(), mat.avg_ttft());
        assert_eq!(stream.p_latency(99.0), mat.p_latency(99.0));
        assert_eq!(stream.slo_attainment(1.5), mat.slo_attainment(1.5));
        assert_eq!(stream.stats.events, mat.stats.events);
        assert_eq!(stream.stats.unserved, mat.stats.unserved);
        assert_eq!(stream.stats.kv_transfers, mat.stats.kv_transfers);
        assert_eq!(stream.stats.kv_bytes, mat.stats.kv_bytes);
        for (a, b) in stream.records.iter().zip(mat.records.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prefill_done, b.prefill_done);
            assert_eq!(a.completion, b.completion);
        }
        // The bounded frontier keeps the live set far below the trace
        // length even on this small run.
        assert!(stream.stats.peak_live_requests >= 1);
        assert!(stream.stats.peak_live_requests <= mat.records.len() + mat.stats.unserved);
    }

    #[test]
    fn windowed_mode_matches_full_on_exact_aggregates() {
        use crate::simulator::RecordMode;
        let (c, p) = small_placement();
        let trace = Trace::online(WorkloadKind::Lpld, 1.0, 80.0, 13);
        let full = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let cfg = SimConfig { record_mode: RecordMode::Windowed, ..SimConfig::default() };
        let win = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
        assert!(win.records.is_empty(), "windowed mode kept records");
        assert_eq!(win.completed(), full.completed());
        assert_eq!(win.makespan, full.makespan);
        assert_eq!(win.total_output_tokens, full.total_output_tokens);
        assert_eq!(win.total_input_tokens, full.total_input_tokens);
        assert_eq!(win.tokens_per_s(), full.tokens_per_s());
        assert_eq!(win.avg_latency(), full.avg_latency());
        assert_eq!(win.avg_ttft(), full.avg_ttft());
        assert_eq!(win.stats.events, full.stats.events);
        // Approximate metrics stay within the documented one-bucket bound.
        let (pw, pf) = (win.p_latency(99.0), full.p_latency(99.0));
        assert!(pw >= pf * 0.99 && pw <= pf * 1.14, "{pw} vs {pf}");
    }

    #[test]
    fn windowed_all_rejected_returns_empty_report() {
        // Regression (ISSUE 8 satellite): windowed mode + hard rejection of
        // every request must produce a well-formed zero report — no NaN, no
        // panic in the min/max folds.
        use crate::simulator::{RecordMode, Sizing};
        let (c, p) = small_placement();
        let mut trace = Trace::offline(WorkloadKind::Lpld, 8, 17);
        for r in trace.requests.iter_mut() {
            r.input_len = 50_000_000; // larger than any replica's memory
        }
        let cfg = SimConfig {
            sizing: Sizing::PerRequest,
            record_mode: RecordMode::Windowed,
            ..SimConfig::default()
        };
        let rep = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
        assert_eq!(rep.completed(), 0);
        assert_eq!(rep.stats.rejected, 8);
        assert_eq!(rep.stats.unserved, 8);
        assert_eq!(rep.makespan, 0.0);
        assert_eq!(rep.tokens_per_s(), 0.0);
        assert!(rep.avg_latency().is_finite());
        assert!(rep.p_latency(99.0).is_finite());
        assert_eq!(rep.slo_attainment(1.0), 0.0);
    }

    #[test]
    fn resched_blackout_delays_held_requests() {
        let (c, p) = small_placement();
        // All arrivals land inside the blackout: their TTFT must include the
        // wait until activation.
        let mut trace = Trace::offline(WorkloadKind::Lpld, 5, 8);
        for (i, r) in trace.requests.iter_mut().enumerate() {
            r.arrival = 10.0 + i as f64 * 0.01;
        }
        let switches =
            vec![PlacementSwitch { at: 9.0, delay: 20.0, placement: p.clone(), workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), 5);
        for r in &rep.records {
            assert!(
                r.prefill_done >= 29.0,
                "request served during blackout: prefill_done {}",
                r.prefill_done
            );
        }
    }
}
