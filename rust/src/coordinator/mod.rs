//! The live disaggregated serving coordinator (paper §4).
//!
//! Real tensors through real compiled modules: prefill replica workers and
//! decode replica workers run on OS threads, each owning its own PJRT
//! runtime (mirroring one-process-per-replica); KV caches move directly
//! between workers as per-request cache columns (optionally throttled to a
//! simulated link bandwidth); requests are dispatched and completions
//! collected by the coordinator, which is never on the KV path. The
//! discrete-event `simulator` answers the paper-scale questions; this module
//! proves the three layers compose on a real workload (examples/e2e_serve).

pub mod kvcache;
pub mod replica;
pub mod server;

pub use kvcache::KvSlots;
pub use replica::{Completion, KvPacket, KvThrottle, LiveRequest};
pub use server::{serve, CoordinatorConfig, LiveReport};
