//! Bench: regenerate paper Fig. 1 (batching effects on prefill vs decode)
//! and time the cost-model evaluation that produces it.
use hexgen2::experiments::batching;
use hexgen2::util::bench;

fn main() {
    let (p, d) = batching::fig1_batching();
    p.print("Fig. 1a: prefill batching (LLaMA-2-7B, 1xA100)");
    d.print("Fig. 1b: decode batching (LLaMA-2-7B, 1xA100)");
    bench::time("fig1/costmodel-eval", 3, 20, || {
        std::hint::black_box(batching::fig1_batching());
    });
}
