//! # HexGen-2: disaggregated LLM inference over heterogeneous GPUs
//!
//! A from-scratch reproduction of *HexGen-2: Disaggregated Generative
//! Inference of LLMs in Heterogeneous Environment* (ICLR 2025) as a
//! three-layer Rust + JAX + Pallas system. See DESIGN.md for the layer
//! inventory, the unified `deploy` API, and the paper-vs-reproduction
//! deviations.
//!
//! Layering:
//! - **Layer 3 (this crate)**: the scheduling algorithm (§3 of the paper:
//!   graph partition → max-flow → iterative refinement) with pluggable
//!   [`Objective`](scheduler::Objective)s, the online rescheduler
//!   (`rescheduler`: drift monitoring → warm-started re-plan → priced
//!   migration, closing the §3.3 per-period loop on live traffic), the KV
//!   transfer engine (`kvtransfer`: contention-aware routing, layer-wise
//!   pipelined transfers, and the link-load ledger fed back into the
//!   planner objective), the disaggregated serving coordinator, the
//!   discrete-event cluster
//!   simulator (including mid-trace placement switches), baselines, and the
//!   experiment harnesses — all tied together by the [`deploy`] API: one
//!   [`Planner`](deploy::Planner) trait over every system and one
//!   [`Backend`](deploy::Backend) trait over simulation and live serving,
//!   so `spec.plan(planner)?.run(backend, &trace)` is the single path every
//!   CLI subcommand, example, bench, and experiment goes through.
//! - **Layer 2/1 (python/compile)**: the JAX transformer + Pallas kernels,
//!   AOT-lowered to HLO text once; `runtime` executes those artifacts via
//!   PJRT with Python never on the request path.

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod costmodel;
pub mod deploy;
pub mod experiments;
pub mod kvtransfer;
pub mod model;
pub mod rescheduler;
pub mod util;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod telemetry;
pub mod workload;
