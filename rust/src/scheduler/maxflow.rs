//! Preflow-push (push–relabel) maximum flow (§3.3 of the paper, after
//! Cheriyan & Maheshwari 1989), with FIFO active-node selection and the gap
//! heuristic, over real-valued capacities.
//!
//! Besides the flow value, callers need the *flow assignment* per edge
//! (the paper uses these to set KV-communication frequencies, §3.3) and the
//! bottleneck / underutilized edge classification that drives the
//! max-flow-guided edge swap (§3.4) — both exposed here.

/// Opaque handle to an added edge (for querying flow afterwards).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeRef {
    node: usize,
    idx: usize,
}

#[derive(Clone, Debug)]
struct Edge {
    to: usize,
    cap: f64,
    flow: f64,
    /// index of the reverse edge in adj[to]
    rev: usize,
}

/// A directed flow network with float capacities.
#[derive(Clone, Debug)]
pub struct FlowNetwork {
    adj: Vec<Vec<Edge>>,
}

const EPS: f64 = 1e-9;

impl FlowNetwork {
    pub fn new(n: usize) -> FlowNetwork {
        FlowNetwork { adj: vec![Vec::new(); n] }
    }

    pub fn n(&self) -> usize {
        self.adj.len()
    }

    pub fn add_node(&mut self) -> usize {
        self.adj.push(Vec::new());
        self.adj.len() - 1
    }

    /// Add a directed edge u -> v with the given capacity.
    pub fn add_edge(&mut self, u: usize, v: usize, cap: f64) -> EdgeRef {
        assert!(u != v, "self-loop");
        assert!(cap >= 0.0, "negative capacity");
        let ui = self.adj[u].len();
        let vi = self.adj[v].len();
        self.adj[u].push(Edge { to: v, cap, flow: 0.0, rev: vi });
        self.adj[v].push(Edge { to: u, cap: 0.0, flow: 0.0, rev: ui });
        EdgeRef { node: u, idx: ui }
    }

    pub fn capacity(&self, e: EdgeRef) -> f64 {
        self.adj[e.node][e.idx].cap
    }

    /// Flow currently routed through the edge (after `max_flow`).
    pub fn flow(&self, e: EdgeRef) -> f64 {
        self.adj[e.node][e.idx].flow.max(0.0)
    }

    /// Utilization in [0,1]; 0 for zero-capacity edges.
    pub fn utilization(&self, e: EdgeRef) -> f64 {
        let c = self.capacity(e);
        if c <= 0.0 {
            0.0
        } else {
            (self.flow(e) / c).clamp(0.0, 1.0)
        }
    }

    /// Is this edge saturated (a bottleneck in §3.4's sense)?
    pub fn is_bottleneck(&self, e: EdgeRef) -> bool {
        let ed = &self.adj[e.node][e.idx];
        ed.cap > 0.0 && ed.flow >= ed.cap - EPS * (1.0 + ed.cap)
    }

    fn reset_flows(&mut self) {
        for v in &mut self.adj {
            for e in v {
                e.flow = 0.0;
            }
        }
    }

    /// Push–relabel max flow from s to t. Returns the flow value; per-edge
    /// assignments are queryable afterwards via `flow`.
    pub fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        let n = self.n();
        assert!(s != t && s < n && t < n);
        self.reset_flows();
        let mut height = vec![0usize; n];
        let mut excess = vec![0.0f64; n];
        height[s] = n;

        // Saturate all source edges.
        for i in 0..self.adj[s].len() {
            let (to, cap) = {
                let e = &self.adj[s][i];
                (e.to, e.cap)
            };
            if cap > 0.0 {
                self.push_raw(s, i, cap);
                excess[to] += cap;
                excess[s] -= cap;
            }
        }

        let mut queue: std::collections::VecDeque<usize> = (0..n)
            .filter(|&v| v != s && v != t && excess[v] > EPS)
            .collect();
        let mut in_queue = vec![false; n];
        for &v in &queue {
            in_queue[v] = true;
        }
        // Gap heuristic bookkeeping.
        let mut height_count = vec![0usize; 2 * n + 1];
        for &h in &height {
            height_count[h] += 1;
        }

        while let Some(u) = queue.pop_front() {
            in_queue[u] = false;
            // Discharge u.
            while excess[u] > EPS {
                let mut pushed = false;
                for i in 0..self.adj[u].len() {
                    let (to, residual) = {
                        let e = &self.adj[u][i];
                        (e.to, e.cap - e.flow)
                    };
                    if residual > EPS && height[u] == height[to] + 1 {
                        let delta = excess[u].min(residual);
                        self.push_raw(u, i, delta);
                        excess[u] -= delta;
                        excess[to] += delta;
                        if to != s && to != t && !in_queue[to] {
                            queue.push_back(to);
                            in_queue[to] = true;
                        }
                        pushed = true;
                        if excess[u] <= EPS {
                            break;
                        }
                    }
                }
                if !pushed {
                    // Relabel u to 1 + min reachable height.
                    let old = height[u];
                    let mut min_h = usize::MAX;
                    for e in &self.adj[u] {
                        if e.cap - e.flow > EPS {
                            min_h = min_h.min(height[e.to]);
                        }
                    }
                    if min_h == usize::MAX {
                        break; // no residual edges; excess is stuck (shouldn't happen)
                    }
                    height_count[old] -= 1;
                    height[u] = min_h + 1;
                    height_count[height[u]] += 1;
                    // Gap heuristic: if no node remains at `old`, lift all
                    // nodes above the gap out of reach.
                    if height_count[old] == 0 && old < n {
                        for v in 0..n {
                            if v != s && height[v] > old && height[v] <= n {
                                height_count[height[v]] -= 1;
                                height[v] = n + 1;
                                height_count[height[v]] += 1;
                            }
                        }
                    }
                    if height[u] > 2 * n {
                        break;
                    }
                }
            }
        }
        // Max flow = total into t.
        self.adj[t]
            .iter()
            .map(|e| -e.flow) // reverse edges carry negative of inflow
            .filter(|f| *f > 0.0)
            .sum()
    }

    fn push_raw(&mut self, u: usize, i: usize, delta: f64) {
        let (to, rev) = {
            let e = &mut self.adj[u][i];
            e.flow += delta;
            (e.to, e.rev)
        };
        self.adj[to][rev].flow -= delta;
    }

    /// Slow Edmonds–Karp reference implementation (tests only): BFS
    /// augmenting paths. Used by the property tests to cross-check
    /// push–relabel on random graphs.
    pub fn max_flow_reference(&mut self, s: usize, t: usize) -> f64 {
        self.reset_flows();
        let n = self.n();
        let mut total = 0.0;
        loop {
            // BFS for an augmenting path.
            let mut prev: Vec<Option<(usize, usize)>> = vec![None; n];
            let mut q = std::collections::VecDeque::new();
            q.push_back(s);
            let mut seen = vec![false; n];
            seen[s] = true;
            while let Some(u) = q.pop_front() {
                for (i, e) in self.adj[u].iter().enumerate() {
                    if !seen[e.to] && e.cap - e.flow > EPS {
                        seen[e.to] = true;
                        prev[e.to] = Some((u, i));
                        q.push_back(e.to);
                    }
                }
            }
            if !seen[t] {
                return total;
            }
            // Find bottleneck.
            let mut delta = f64::INFINITY;
            let mut v = t;
            while let Some((u, i)) = prev[v] {
                let e = &self.adj[u][i];
                delta = delta.min(e.cap - e.flow);
                v = u;
            }
            // Augment.
            let mut v = t;
            while let Some((u, i)) = prev[v] {
                self.push_raw(u, i, delta);
                v = u;
            }
            total += delta;
        }
    }

    /// Check flow conservation at every node except s and t (tests).
    pub fn check_conservation(&self, s: usize, t: usize) -> Result<(), String> {
        for v in 0..self.n() {
            if v == s || v == t {
                continue;
            }
            let net: f64 = self.adj[v].iter().map(|e| e.flow).sum();
            if net.abs() > 1e-6 {
                return Err(format!("node {v} violates conservation: net {net}"));
            }
        }
        for v in 0..self.n() {
            for e in &self.adj[v] {
                if e.flow > e.cap + 1e-6 {
                    return Err(format!("edge {v}->{} over capacity", e.to));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn trivial_path() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 5.0);
        g.add_edge(1, 2, 3.0);
        assert!((g.max_flow(0, 2) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn classic_diamond() {
        // Two disjoint paths 0->1->3 (cap 2) and 0->2->3 (cap 3), plus a
        // cross edge 1->2 enabling rerouting.
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 4.0);
        g.add_edge(0, 2, 3.0);
        let e12 = g.add_edge(1, 2, 2.0);
        g.add_edge(1, 3, 2.0);
        g.add_edge(2, 3, 5.0);
        let f = g.max_flow(0, 3);
        assert!((f - 7.0).abs() < 1e-9, "{f}");
        g.check_conservation(0, 3).unwrap();
        assert!(g.flow(e12) <= 2.0 + 1e-9);
    }

    #[test]
    fn disconnected_is_zero() {
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 5.0);
        g.add_edge(2, 3, 5.0);
        assert_eq!(g.max_flow(0, 3), 0.0);
    }

    #[test]
    fn bottleneck_detection() {
        let mut g = FlowNetwork::new(3);
        let a = g.add_edge(0, 1, 1.0);
        let b = g.add_edge(1, 2, 10.0);
        g.max_flow(0, 2);
        assert!(g.is_bottleneck(a));
        assert!(!g.is_bottleneck(b));
        assert!(g.utilization(b) < 0.2);
        assert!((g.utilization(a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fractional_capacities() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 0.3);
        g.add_edge(0, 1, 0.45); // parallel edge
        g.add_edge(1, 2, 0.5);
        let f = g.max_flow(0, 2);
        assert!((f - 0.5).abs() < 1e-9, "{f}");
    }

    #[test]
    fn matches_reference_on_random_graphs() {
        check(0xF10, 150, |rng| {
            let n = rng.range(4, 12);
            let mut g = FlowNetwork::new(n);
            let m = rng.range(n, 4 * n);
            for _ in 0..m {
                let u = rng.range(0, n);
                let mut v = rng.range(0, n);
                if u == v {
                    v = (v + 1) % n;
                }
                g.add_edge(u, v, rng.range_f64(0.0, 10.0));
            }
            let mut g2 = g.clone();
            let f1 = g.max_flow(0, n - 1);
            let f2 = g2.max_flow_reference(0, n - 1);
            prop_assert!((f1 - f2).abs() < 1e-6, "push-relabel {f1} != reference {f2}");
            g.check_conservation(0, n - 1).map_err(|e| e)?;
            Ok(())
        });
    }

    #[test]
    fn flow_value_equals_out_of_source() {
        check(0xF11, 60, |rng| {
            let n = rng.range(4, 10);
            let mut g = FlowNetwork::new(n);
            for _ in 0..rng.range(n, 3 * n) {
                let u = rng.range(0, n);
                let mut v = rng.range(0, n);
                if u == v {
                    v = (v + 1) % n;
                }
                g.add_edge(u, v, rng.range_f64(0.0, 5.0));
            }
            let f = g.max_flow(0, n - 1);
            let out_s: f64 = g.adj[0].iter().map(|e| e.flow.max(0.0)).sum::<f64>()
                - g.adj[0].iter().map(|e| (-e.flow).max(0.0)).sum::<f64>();
            prop_assert!((f - out_s).abs() < 1e-6, "value {f} vs source net {out_s}");
            Ok(())
        });
    }
}
