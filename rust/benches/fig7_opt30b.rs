//! Bench: regenerate paper Fig. 7 (OPT-30B end-to-end throughput grid).
use hexgen2::experiments::{endtoend, ExpOpts};
use hexgen2::model::OPT_30B;

fn main() {
    let opts = ExpOpts::from_env();
    let hets: &[&str] = if opts.quick { &["het1", "het4"] } else { &["het1", "het2", "het3", "het4"] };
    let t = endtoend::fig6_7_grid(&OPT_30B, hets, &opts);
    t.print("Fig. 7: OPT-30B throughput (tokens/s)");
    for (s, sp) in endtoend::speedup_summary(&t) {
        println!("  {s}: HEXGEN-2 / HEXGEN geo-mean speedup = {sp:.2}x");
    }
}
