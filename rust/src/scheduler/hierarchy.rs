//! Hierarchical zone planning (DESIGN.md §14): plan planet-scale clusters
//! by coarsening them into bandwidth-coherent *zones*, running the flat §3
//! search inside each zone independently, and stitching the zone plans with
//! a top-level max-flow over zone aggregates.
//!
//! The flat search's wall-clock grows superlinearly with device count
//! (spectral partition, per-group strategy search, and the proposal sweep
//! all widen with `n`). Zoning caps the working set each search sees at the
//! zone size, so total planner time scales with *zone* size times zone
//! count — and zones are embarrassingly parallel, so they fan out over
//! [`ScheduleOptions::threads`]. The price is optimality: groups can no
//! longer span zones, and cross-zone KV traffic is modelled at zone
//! granularity. The Table-5 extension quantifies both sides.
//!
//! Determinism contract: plans are bit-identical across thread counts. Zone
//! formation is the deterministic spectral cut, each zone search carries a
//! seed derived only from `(opts.seed, zone index)`, zone results join in
//! zone order, and the stitch solve is sequential. Hierarchical plans
//! legitimately *differ* from flat plans — that is the trade, not a bug.

use std::time::Instant;

use crate::cluster::{Cluster, Device, DeviceId};
use crate::costmodel::ReplicaConfig;
use crate::model::LlmSpec;

use super::maxflow::FlowNetwork;
use super::placement::{GroupPlan, KvRoute, Placement};
use super::{
    coarsen, objective, spectral, task_for, ConvergencePoint, EvalCache, ScheduleOptions,
    ScheduleResult, SearchStats,
};

/// Auto zone count for `--hierarchical` without an explicit `zones=`:
/// roughly 32 devices per zone, clamped to [2, 16] zones. 32 keeps each
/// zone search in the regime where the flat planner is fast, and 16 zones
/// saturates any realistic `--threads` fan-out.
pub fn auto_zone_count(n: usize) -> usize {
    (n / 32).clamp(2, 16)
}

/// Plan `cluster` hierarchically: cut into `zones` zones (0 = auto-size),
/// plan each zone with the flat search, stitch with a top-level max-flow.
///
/// Falls back to the flat planner (same options, `hierarchical` cleared)
/// when the cluster is too small to zone (< 4 devices), when no zone count
/// down to 2 yields zones of at least 2 devices, or when any zone search
/// fails — a hierarchical *request* never turns a schedulable cluster into
/// `None`.
pub fn schedule_hierarchical(
    cluster: &Cluster,
    model: &LlmSpec,
    opts: &ScheduleOptions,
    cache: &EvalCache,
    zones: usize,
) -> Option<ScheduleResult> {
    // hexcheck: allow(D2) -- wall-clock timing of the planner itself (ScheduleResult::elapsed_s); never feeds plan decisions
    let t0 = Instant::now();
    let n = cluster.n();
    let flat = || {
        let mut fo = opts.clone();
        fo.hierarchical = None;
        super::schedule_with_cache(cluster, model, &fo, cache)
    };
    if n < 4 {
        return flat();
    }
    let mut z = if zones == 0 { auto_zone_count(n) } else { zones };
    z = z.clamp(2, n / 2);

    // Zone formation: deterministic spectral k-way cut over the bandwidth
    // graph, shrinking z until every zone has at least 2 devices (a
    // singleton zone cannot host both phases of anything).
    let devs: Vec<DeviceId> = (0..n).collect();
    let zone_devs = loop {
        let parts = spectral::partition_k(cluster, &devs, z);
        if parts.iter().all(|p| p.len() >= 2) {
            break parts;
        }
        if z == 2 {
            return flat();
        }
        z -= 1;
    };

    let zone_clusters: Vec<Cluster> =
        zone_devs.iter().enumerate().map(|(zi, zd)| zone_cluster(cluster, zi, zd)).collect();

    // Plan zones independently. Each zone gets its own EvalCache: the
    // caller's cache binds to one (cluster, model) owner and fingerprint-
    // flushes on change, so sharing it across zone sub-clusters would
    // thrash it. Zone searches fan out over opts.threads; leftover workers
    // fan *into* each zone search (zo.threads), and both knobs are
    // result-invariant, so the join (in zone order) is bit-stable.
    let plan_zone = |zi: usize, zc: &Cluster| -> Option<ScheduleResult> {
        let zcache = if opts.use_eval_cache { EvalCache::new() } else { EvalCache::disabled() };
        let mut zo = opts.clone();
        zo.hierarchical = None;
        zo.threads = (opts.threads / z).max(1);
        zo.initial_groups = None;
        zo.force_k = None;
        zo.audit = false;
        zo.seed = opts.seed ^ (zi as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        super::schedule_with_cache(zc, model, &zo, &zcache)
    };
    let workers = opts.threads.clamp(1, z);
    let zone_results: Vec<Option<ScheduleResult>> = if workers <= 1 {
        zone_clusters.iter().enumerate().map(|(zi, zc)| plan_zone(zi, zc)).collect()
    } else {
        let chunk = z.div_ceil(workers);
        std::thread::scope(|s| {
            let plan_zone = &plan_zone;
            let handles: Vec<_> = zone_clusters
                .chunks(chunk)
                .enumerate()
                .map(|(ci, part)| {
                    s.spawn(move || {
                        part.iter()
                            .enumerate()
                            .map(|(j, zc)| plan_zone(ci * chunk + j, zc))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("zone planner panicked"))
                .collect()
        })
    };
    let mut zone_plans: Vec<ScheduleResult> = Vec::with_capacity(z);
    for r in zone_results {
        match r {
            Some(r) => zone_plans.push(r),
            None => return flat(),
        }
    }

    // Stitch: a 2z+2-node max-flow over zone aggregates. Node layout:
    // 0 = source, 1 = sink, 2+2z = P_z (zone z prefill side), 3+2z = D_z.
    //   src  -> P_z : summed capacity of zone z's prefill groups
    //   D_z  -> sink: summed capacity of zone z's decode groups
    //   P_z  -> D_z : the zone's own solved flow value (its internal KV
    //                 fabric already admits exactly that much)
    //   P_z  -> D_w : aggregate inter-zone KV budget — requests per period
    //                 the summed pairwise bandwidth between the two zones'
    //                 devices can carry (optimistic zone-granular bound).
    // Solve intra-zone edges first (recovers the sum of zone flows), then
    // open the inter-zone edges via set_capacity and warm re-solve
    // incrementally: the stitched value only ever adds cross-zone gains on
    // top of the zone-local base.
    let task = task_for(opts.workload);
    let period = opts.period;
    let aggs: Vec<(f64, f64, f64)> = zone_plans
        .iter()
        .map(|r| {
            let p = &r.placement;
            let pre: f64 =
                p.groups.iter().filter(|g| g.is_prefill).map(|g| g.capacity).sum();
            let dec: f64 =
                p.groups.iter().filter(|g| !g.is_prefill).map(|g| g.capacity).sum();
            (pre, dec, p.flow_value)
        })
        .collect();
    let zbw = coarsen::inter_group_bandwidth(cluster, &zone_devs);
    let kv_bytes = model.kv_bytes_per_token(model.n_layers) * task.s_in;
    let mut net = FlowNetwork::new(2 + 2 * z);
    for (zi, &(pre, dec, own)) in aggs.iter().enumerate() {
        net.add_edge(0, 2 + 2 * zi, pre);
        net.add_edge(3 + 2 * zi, 1, dec);
        net.add_edge(2 + 2 * zi, 3 + 2 * zi, own);
    }
    let mut inter = Vec::with_capacity(z * (z - 1));
    for zp in 0..z {
        for zd in 0..z {
            if zp == zd {
                continue;
            }
            let cap = if kv_bytes > 0.0 { period * zbw[zp][zd] / kv_bytes } else { 0.0 };
            inter.push((zp, zd, net.add_edge(2 + 2 * zp, 3 + 2 * zd, 0.0), cap));
        }
    }
    let _zone_local = net.max_flow_incremental(0, 1);
    for &(_, _, e, cap) in &inter {
        net.set_capacity(e, cap);
    }
    let flow_value = net.max_flow_incremental(0, 1);

    // Assemble the global placement: concatenate zone groups with devices
    // (and ReplicaConfig stages) remapped to global ids, offset the zone
    // routes, and synthesize one KV route per stitched cross-zone flow
    // (highest-capacity prefill group of the source zone to
    // highest-capacity decode group of the target zone, first index on
    // ties — the engine spreads actual transfers by flow weight).
    let mut groups: Vec<GroupPlan> = Vec::new();
    let mut routes: Vec<KvRoute> = Vec::new();
    let mut group_utilization: Vec<f64> = Vec::new();
    let mut offsets: Vec<usize> = Vec::with_capacity(z);
    for (zi, r) in zone_plans.iter().enumerate() {
        let off = groups.len();
        offsets.push(off);
        let map = &zone_devs[zi];
        for g in &r.placement.groups {
            let devices: Vec<DeviceId> = g.devices.iter().map(|&d| map[d]).collect();
            let config = g.config.as_ref().map(|c| {
                ReplicaConfig::new(
                    c.stages.iter().map(|st| st.iter().map(|&d| map[d]).collect()).collect(),
                    c.layers.clone(),
                )
            });
            groups.push(GroupPlan {
                devices,
                is_prefill: g.is_prefill,
                config,
                capacity: g.capacity,
            });
        }
        group_utilization.extend_from_slice(&r.placement.group_utilization);
        for rt in &r.placement.routes {
            routes.push(KvRoute {
                prefill: off + rt.prefill,
                decode: off + rt.decode,
                ..*rt
            });
        }
    }
    for &(zp, zd, e, cap) in &inter {
        let f = net.flow(e);
        if f <= 1e-9 {
            continue;
        }
        if let (Some(pg), Some(dg)) = (
            best_group(&zone_plans[zp].placement, offsets[zp], true),
            best_group(&zone_plans[zd].placement, offsets[zd], false),
        ) {
            routes.push(KvRoute { prefill: pg, decode: dg, flow: f, capacity: cap });
        }
    }

    let tokens_per_s = flow_value * task.s_out / period;
    let mut placement = Placement {
        groups,
        routes,
        flow_value,
        tokens_per_s,
        group_utilization,
        objective_score: 0.0,
    };
    let mut score = opts.objective.score(cluster, model, &task, &placement);
    if let Some(link) = opts.kv_contention {
        score = objective::apply_kv_contention(score, objective::kv_nic_utilization(&placement, link));
    }
    placement.objective_score = score;

    let mut stats = SearchStats::default();
    for r in &zone_plans {
        stats.evals += r.stats.evals;
        stats.eval_cache_hits += r.stats.eval_cache_hits;
        stats.strategy_misses += r.stats.strategy_misses;
        stats.strategy_hits += r.stats.strategy_hits;
        stats.partitions_explored += r.stats.partitions_explored;
    }
    stats.threads = opts.threads.max(1);
    let rounds = zone_plans.iter().map(|r| r.rounds).max().unwrap_or(0);
    let elapsed_s = t0.elapsed().as_secs_f64();
    Some(ScheduleResult {
        history: vec![ConvergencePoint {
            elapsed_s,
            round: rounds,
            tokens_per_s: placement.tokens_per_s,
            score: placement.objective_score,
        }],
        rounds,
        elapsed_s,
        stats,
        audit: Vec::new(),
        placement,
    })
}

/// Sub-cluster for one zone: devices renumbered to local ids with their
/// hardware identity (GPU type, node, DC) intact, bandwidth/latency sliced
/// from the parent matrices (diagonal ∞ slices through unchanged).
fn zone_cluster(cluster: &Cluster, zi: usize, devs: &[DeviceId]) -> Cluster {
    let devices: Vec<Device> = devs
        .iter()
        .enumerate()
        .map(|(i, &d)| Device { id: i, ..cluster.devices[d] })
        .collect();
    let bandwidth: Vec<Vec<f64>> = devs
        .iter()
        .map(|&a| devs.iter().map(|&b| cluster.bandwidth[a][b]).collect())
        .collect();
    let latency: Vec<Vec<f64>> = devs
        .iter()
        .map(|&a| devs.iter().map(|&b| cluster.latency[a][b]).collect())
        .collect();
    Cluster { name: format!("{}/zone{zi}", cluster.name), devices, bandwidth, latency }
}

/// Global index (zone offset + local index) of the zone's highest-capacity
/// group of the requested phase; first index wins ties.
fn best_group(p: &Placement, off: usize, prefill: bool) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, g) in p.groups.iter().enumerate() {
        if g.is_prefill != prefill {
            continue;
        }
        if best.map(|(_, c)| g.capacity > c).unwrap_or(true) {
            best = Some((i, g.capacity));
        }
    }
    best.map(|(i, _)| off + i)
}

#[cfg(test)]
mod tests {
    use super::super::{is_valid_partition, schedule, SwapMode};
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::workload::WorkloadKind;

    fn quick_opts() -> ScheduleOptions {
        let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
        opts.max_rounds = 2;
        opts.patience = 2;
        opts.proposals_per_round = 4;
        opts
    }

    /// The hierarchical planner must produce a valid global placement
    /// (every device in exactly one group, positive flow) and, per the
    /// determinism contract, bit-identical plans for any thread count.
    #[test]
    fn hierarchical_plan_valid_and_thread_count_invariant() {
        let c = settings::synthetic(64, 5);
        let mut opts = quick_opts();
        opts.hierarchical = Some(4);
        let r1 = schedule(&c, &OPT_30B, &opts).expect("hierarchical plan");
        let groups: Vec<Vec<DeviceId>> =
            r1.placement.groups.iter().map(|g| g.devices.clone()).collect();
        assert!(is_valid_partition(&c, &groups), "zone groups must tile the cluster");
        assert!(r1.placement.flow_value > 0.0);
        assert!(r1.placement.objective_score > 0.0);
        // Remapped replica stages must reference global device ids only.
        for g in &r1.placement.groups {
            if let Some(cfg) = &g.config {
                for st in &cfg.stages {
                    for d in st {
                        assert!(g.devices.contains(d), "stage device {d} outside its group");
                    }
                }
            }
        }
        let mut o4 = opts.clone();
        o4.threads = 4;
        let r4 = schedule(&c, &OPT_30B, &o4).expect("hierarchical plan (threaded)");
        assert_eq!(
            format!("{:?}", r1.placement),
            format!("{:?}", r4.placement),
            "hierarchical plans must be bit-identical across thread counts"
        );
    }

    /// Plan quality: zoning trades optimality for wall-clock, but the
    /// stitched objective must stay within 2x of the flat one-shot plan on
    /// a Table-5-style synthetic cluster (zone-local flows sum into the
    /// stitch base, so the gap comes only from groups that no longer span
    /// zones).
    #[test]
    fn hierarchical_objective_within_bound_of_flat() {
        let c = settings::synthetic(64, 7);
        let mut flat = ScheduleOptions::new(WorkloadKind::Lphd);
        flat.swap_mode = SwapMode::None;
        let mut hier = flat.clone();
        hier.hierarchical = Some(4);
        let rf = schedule(&c, &OPT_30B, &flat).expect("flat plan");
        let rh = schedule(&c, &OPT_30B, &hier).expect("hierarchical plan");
        assert!(
            rh.placement.objective_score >= 0.5 * rf.placement.objective_score,
            "hierarchical {} fell below half of flat {}",
            rh.placement.objective_score,
            rf.placement.objective_score
        );
    }

    /// `zones = 0` auto-sizes (~32 devices per zone) and must match the
    /// equivalent explicit zone count exactly.
    #[test]
    fn auto_zone_count_matches_explicit() {
        assert_eq!(auto_zone_count(64), 2);
        let c = settings::synthetic(64, 3);
        let mut auto = quick_opts();
        auto.swap_mode = SwapMode::None;
        auto.hierarchical = Some(0);
        let mut explicit = auto.clone();
        explicit.hierarchical = Some(2);
        let ra = schedule(&c, &OPT_30B, &auto).expect("auto-zoned plan");
        let re = schedule(&c, &OPT_30B, &explicit).expect("explicit plan");
        assert_eq!(format!("{:?}", ra.placement), format!("{:?}", re.placement));
    }
}
