//! vLLM-style baseline (Appendix F): colocated continuous batching on a
//! homogeneous cluster. Searches the best uniform (TP, replica count) split
//! by colocated-throughput estimate; serving behaviour (iteration-level
//! batching, optional chunked prefill per Appendix D) comes from
//! `simulator::colocated`.

use crate::cluster::Cluster;
use crate::costmodel::{ReplicaConfig, TaskProfile};
use crate::model::LlmSpec;
use crate::scheduler::{objective, Objective};
use crate::workload::WorkloadKind;

use super::hexgen::colocated_throughput;

/// A vLLM deployment: identical colocated replicas.
#[derive(Clone, Debug)]
pub struct VllmPlan {
    pub replicas: Vec<ReplicaConfig>,
    pub tensor_parallel: usize,
    pub tokens_per_s: f64,
    /// Score under the objective the sweep ranked by (equals
    /// `tokens_per_s` for [`Objective::Throughput`]).
    pub objective_score: f64,
}

/// Pick the best uniform TP degree (replicating the engine across the rest
/// of the cluster, data-parallel style), ranked by throughput.
pub fn schedule_vllm(cluster: &Cluster, model: &LlmSpec, workload: WorkloadKind) -> Option<VllmPlan> {
    schedule_vllm_with(cluster, model, workload, Objective::Throughput)
}

/// [`schedule_vllm`] with the TP sweep ranked by an arbitrary [`Objective`]
/// (ROADMAP PR-2 follow-up): the candidate set is fixed, so the argmax
/// under the active objective is at least as good — under that objective —
/// as re-scoring the throughput winner.
pub fn schedule_vllm_with(
    cluster: &Cluster,
    model: &LlmSpec,
    workload: WorkloadKind,
    objective: Objective,
) -> Option<VllmPlan> {
    let (s_in, s_out) = workload.mean_lengths();
    let task = TaskProfile::new(1, s_in, s_out);
    let n = cluster.n();
    let mut best: Option<VllmPlan> = None;
    for tp in [1usize, 2, 4, 8] {
        if tp > n || n % tp != 0 {
            continue;
        }
        let replicas: Vec<ReplicaConfig> = (0..n / tp)
            .map(|r| ReplicaConfig::new(vec![(r * tp..(r + 1) * tp).collect()], vec![model.n_layers]))
            .collect();
        let tput: f64 = replicas
            .iter()
            .map(|cfg| colocated_throughput(cluster, model, cfg, &task))
            .sum();
        if tput <= 0.0 {
            continue;
        }
        let score =
            objective::colocated_objective_score(cluster, model, &task, objective, &replicas, tput);
        if best.as_ref().map(|b| score > b.objective_score).unwrap_or(true) {
            best = Some(VllmPlan {
                replicas,
                tensor_parallel: tp,
                tokens_per_s: tput,
                objective_score: score,
            });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::{LLAMA2_70B, OPT_30B};
    use crate::simulator::run_colocated;
    use crate::workload::Trace;

    #[test]
    fn picks_feasible_tp() {
        let c = settings::homogeneous();
        let plan = schedule_vllm(&c, &LLAMA2_70B, WorkloadKind::Hphd).expect("plan");
        // 70B needs TP >= 4 on 80G GPUs.
        assert!(plan.tensor_parallel >= 4, "tp {}", plan.tensor_parallel);
        assert!(plan.tokens_per_s > 0.0);
    }

    #[test]
    fn smaller_model_allows_more_replicas() {
        let c = settings::homogeneous();
        let p70 = schedule_vllm(&c, &LLAMA2_70B, WorkloadKind::Lpld).unwrap();
        let p30 = schedule_vllm(&c, &OPT_30B, WorkloadKind::Lpld).unwrap();
        assert!(p30.replicas.len() >= p70.replicas.len());
    }

    #[test]
    fn objective_sweep_never_below_rescored_throughput_winner() {
        // The candidate set is fixed, so ranking by the active objective
        // dominates (under that objective) picking by throughput and then
        // re-scoring — the exact gap the ROADMAP follow-up closes.
        let c = settings::homogeneous();
        for objective in [
            Objective::CostPerToken,
            Objective::MeanLatency,
            Objective::SloGoodput { scale: 5.0 },
        ] {
            let aware =
                schedule_vllm_with(&c, &OPT_30B, WorkloadKind::Lphd, objective).expect("plans");
            let tput_winner = schedule_vllm(&c, &OPT_30B, WorkloadKind::Lphd).expect("plans");
            let (s_in, s_out) = WorkloadKind::Lphd.mean_lengths();
            let task = TaskProfile::new(1, s_in, s_out);
            let rescored = objective::colocated_objective_score(
                &c,
                &OPT_30B,
                &task,
                objective,
                &tput_winner.replicas,
                tput_winner.tokens_per_s,
            );
            assert!(
                aware.objective_score >= rescored - 1e-9 * rescored.abs().max(1.0),
                "{objective:?}: aware {} < rescored throughput winner {}",
                aware.objective_score,
                rescored
            );
        }
        // Throughput objective reproduces the legacy sweep exactly.
        let a = schedule_vllm(&c, &OPT_30B, WorkloadKind::Lphd).unwrap();
        let b = schedule_vllm_with(&c, &OPT_30B, WorkloadKind::Lphd, Objective::Throughput).unwrap();
        assert_eq!(a.tensor_parallel, b.tensor_parallel);
        assert_eq!(a.objective_score, a.tokens_per_s);
    }

    #[test]
    fn plan_simulates() {
        let c = settings::homogeneous();
        let plan = schedule_vllm(&c, &OPT_30B, WorkloadKind::Lphd).unwrap();
        let trace = Trace::offline(WorkloadKind::Lphd, 40, 1);
        let rep = run_colocated(&c, &OPT_30B, &plan.replicas, &trace, None);
        assert_eq!(rep.records.len(), 40);
    }
}
