//! Discrete-event queue: a min-heap of (time, seq) with deterministic
//! FIFO tie-breaking for simultaneous events.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event at a simulation time, carrying a payload.
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Preallocate for a known event volume (e.g. one arrival per request
    /// plus the resched pairs) — the serving loop then never regrows the
    /// heap.
    pub fn with_capacity(n: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(n), seq: 0 }
    }

    pub fn push(&mut self, time: f64, payload: E) {
        assert!(time.is_finite(), "non-finite event time");
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, payload });
    }

    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_ties() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
