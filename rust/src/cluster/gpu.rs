//! GPU device catalog: the four GPU types the paper's testbed uses (§5.1)
//! with their compute/memory/price characteristics.
//!
//! The paper rents these from RunPod; we reproduce their published hardware
//! specs (dense FP16/BF16 tensor TFLOPS, HBM/GDDR bandwidth, memory) and fit
//! hourly prices so that the six cluster settings land on (close to) the
//! paper's Figure-4 budgets. Absolute prices only matter through the
//! budget-matched comparisons.

/// One of the GPU models in the paper's heterogeneous pools.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuType {
    H100,
    A100,
    L40,
    A6000,
}

pub const ALL_GPU_TYPES: [GpuType; 4] = [GpuType::H100, GpuType::A100, GpuType::L40, GpuType::A6000];

impl GpuType {
    /// Dense FP16/BF16 tensor-core TFLOPS (c_d in paper Table 1), FLOP/s.
    pub fn tflops(self) -> f64 {
        match self {
            GpuType::H100 => 989e12, // H100 SXM BF16 dense
            GpuType::A100 => 312e12, // A100 SXM BF16 dense
            GpuType::L40 => 90.5e12, // L40 FP16 dense (181 w/ sparsity)
            GpuType::A6000 => 77.4e12, // RTX A6000 FP16 dense
        }
    }

    /// HBM/GDDR memory bandwidth (m_d in paper Table 1), bytes/s.
    pub fn mem_bw(self) -> f64 {
        match self {
            GpuType::H100 => 3.35e12,  // HBM3
            GpuType::A100 => 2.039e12, // HBM2e 80GB
            GpuType::L40 => 864e9,     // GDDR6
            GpuType::A6000 => 768e9,   // GDDR6
        }
    }

    /// Device memory capacity, bytes.
    pub fn mem_bytes(self) -> f64 {
        match self {
            GpuType::H100 => 80e9,
            GpuType::A100 => 80e9,
            GpuType::L40 => 48e9,
            GpuType::A6000 => 48e9,
        }
    }

    /// Achievable fraction of peak tensor FLOPS in serving GEMMs (MFU).
    /// Faster parts are harder to saturate at inference batch sizes; these
    /// follow published serving MFU measurements (~0.4-0.6) and are the
    /// calibration knob that maps Table 1's peak-FLOPS formulas onto
    /// realized throughput (DESIGN.md §Deviations).
    pub fn mfu(self) -> f64 {
        match self {
            GpuType::H100 => 0.45,
            GpuType::A100 => 0.55,
            GpuType::L40 => 0.60,
            GpuType::A6000 => 0.60,
        }
    }

    /// Effective tensor compute: peak * MFU (what the cost model uses).
    pub fn effective_tflops(self) -> f64 {
        self.tflops() * self.mfu()
    }

    /// Achievable fraction of peak HBM/GDDR bandwidth (stream-like loads).
    pub fn mem_bw_eff(self) -> f64 {
        self.mem_bw() * 0.8
    }

    /// Rental price, $/hour (fitted to the paper's Fig. 4 budgets; see
    /// `cluster::settings` tests for the computed per-setting budgets vs paper's).
    pub fn price_per_hour(self) -> f64 {
        match self {
            GpuType::H100 => 3.69,
            GpuType::A100 => 1.69,
            GpuType::L40 => 1.04,
            GpuType::A6000 => 0.75,
        }
    }

    /// Intra-node NVLink bandwidth between two GPUs of this type, bytes/s,
    /// if the type supports NVLink (L40 is PCIe-only; A6000 supports a
    /// 2-way NVLink bridge).
    pub fn nvlink_bw(self) -> Option<f64> {
        match self {
            GpuType::H100 => Some(900e9), // NVLink 4
            GpuType::A100 => Some(600e9), // NVLink 3
            GpuType::L40 => None,
            GpuType::A6000 => Some(112e9), // NVLink bridge (pairwise)
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GpuType::H100 => "H100",
            GpuType::A100 => "A100",
            GpuType::L40 => "L40",
            GpuType::A6000 => "A6000",
        }
    }

    pub fn from_name(s: &str) -> Option<GpuType> {
        match s.to_ascii_uppercase().as_str() {
            "H100" => Some(GpuType::H100),
            "A100" => Some(GpuType::A100),
            "L40" => Some(GpuType::L40),
            "A6000" => Some(GpuType::A6000),
            _ => None,
        }
    }
}

impl std::fmt::Display for GpuType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_generation_power() {
        assert!(GpuType::H100.tflops() > GpuType::A100.tflops());
        assert!(GpuType::A100.tflops() > GpuType::L40.tflops());
        assert!(GpuType::L40.tflops() > GpuType::A6000.tflops());
        assert!(GpuType::H100.mem_bw() > GpuType::A6000.mem_bw());
    }

    #[test]
    fn name_roundtrip() {
        for t in ALL_GPU_TYPES {
            assert_eq!(GpuType::from_name(t.name()), Some(t));
        }
        assert_eq!(GpuType::from_name("a100"), Some(GpuType::A100));
        assert_eq!(GpuType::from_name("B200"), None);
    }

    #[test]
    fn homogeneous_budget_matches_paper() {
        // Paper §5.1: 8xH100 on-demand = $29.52/h.
        let b = 8.0 * GpuType::H100.price_per_hour();
        assert!((b - 29.52).abs() < 1e-9, "{b}");
    }
}
