//! Run the HexGen-2 scheduling algorithm on heterogeneous setting 1 with
//! LLaMA-2-70B (the paper's flagship configuration) and print the chosen
//! placement in the paper's Table-2 format, plus the convergence trace.
//!
//! Run:  cargo run --release --example schedule_cluster

use hexgen2::cluster::settings;
use hexgen2::model::LLAMA2_70B;
use hexgen2::scheduler::{schedule, ScheduleOptions};
use hexgen2::workload::WorkloadKind;

fn main() {
    let cluster = settings::het1();
    println!("cluster {}: {} GPUs, ${:.2}/h\n", cluster.name, cluster.n(), cluster.budget_per_hour());

    for kind in [WorkloadKind::Online, WorkloadKind::Hpld, WorkloadKind::Lphd] {
        let opts = ScheduleOptions::new(kind);
        let r = schedule(&cluster, &LLAMA2_70B, &opts).expect("feasible placement");
        println!(
            "=== workload {} (scheduled in {:.2}s, {} rounds) ===",
            kind.name(),
            r.elapsed_s,
            r.rounds
        );
        println!("{}", r.placement.describe(&cluster));
    }
}
