//! PJRT runtime: the bridge that makes the Rust coordinator self-contained.
//!
//! `python/compile/aot.py` lowers the JAX model (with its Pallas kernels,
//! interpret=True) to HLO *text* once; this module loads those artifacts,
//! compiles them on the CPU PJRT client (`xla` crate, xla_extension 0.5.1),
//! and exposes typed prefill / decode-step calls. HLO text — not serialized
//! protos — is the interchange format because jax >= 0.5 emits 64-bit
//! instruction ids the bundled XLA rejects (see DESIGN.md §2).

pub mod engine;
pub mod manifest;

pub use engine::{argmax_rows, DecodeOut, ModelRuntime, PrefillOut};
pub use manifest::{artifacts_dir, load_manifests, ModelManifest, ModuleMeta, TensorMeta};
