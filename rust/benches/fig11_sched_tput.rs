//! Bench: regenerate paper Fig. 11 (simulated throughput of the placements
//! each scheduling strategy finds, het1).
use hexgen2::experiments::{convergence, ExpOpts};
use hexgen2::model::OPT_30B;

fn main() {
    convergence::fig11_throughput(&OPT_30B, &ExpOpts::from_env())
        .print("Fig. 11: scheduler-variant throughput (het1, OPT-30B)");
}
