//! Bench: §5.4-style rescheduling case study — steady-state throughput with
//! and without online rescheduling on a phased LPHD→HPLD trace, plus the
//! warm-start vs cold-start re-plan wall-clock. HEXGEN2_FULL=1 lengthens the
//! phases to full-study durations.
use hexgen2::cluster::settings;
use hexgen2::experiments::{resched, ExpOpts};
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{self, ScheduleOptions};
use hexgen2::util::bench;
use hexgen2::workload::WorkloadKind;

fn main() {
    let opts = ExpOpts::from_env();
    let cluster = settings::case_study();
    let Some(spec) = resched::default_phases(&cluster, &OPT_30B, &opts) else {
        eprintln!("no feasible placement on {}", cluster.name);
        return;
    };
    let Some(cs) = resched::case_resched(&cluster, &OPT_30B, &spec, &opts) else {
        eprintln!("case study failed to schedule");
        return;
    };
    cs.table.print("Rescheduling case study (case_study cluster, OPT-30B)");
    resched::print_summary(&cs);

    // Time the warm vs cold re-plan directly (same cluster, HPLD target).
    let mut base = opts.sched_opts(WorkloadKind::Lphd);
    base.force_k = Some(4);
    let incumbent = scheduler::schedule(&cluster, &OPT_30B, &base)
        .expect("incumbent")
        .placement;
    let mut shifted = base.clone();
    shifted.workload = WorkloadKind::Hpld;
    bench::time("resched/replan-cold-case-hpld", 1, 5, || {
        std::hint::black_box(scheduler::schedule(&cluster, &OPT_30B, &shifted));
    });
    bench::time("resched/replan-warm-case-hpld", 1, 5, || {
        std::hint::black_box(hexgen2::rescheduler::warmstart::replan(
            &cluster, &OPT_30B, &shifted, &incumbent,
        ));
    });
}
