//! Replica parallel configuration: an ordered pipeline of TP device groups
//! plus the per-stage layer counts (the paper's "parallel strategy" —
//! asymmetric TP/PP combinations over heterogeneous devices, Table 2).

use crate::cluster::DeviceId;

/// One model replica's parallel configuration.
///
/// `stages[j]` is the TP group serving pipeline stage j (d_ij in Table 1);
/// `layers[j]` is l_ij. Stages may have *different* TP degrees — that is the
/// asymmetric parallelism HexGen introduced and HexGen-2 inherits.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicaConfig {
    pub stages: Vec<Vec<DeviceId>>,
    pub layers: Vec<usize>,
}

impl ReplicaConfig {
    pub fn new(stages: Vec<Vec<DeviceId>>, layers: Vec<usize>) -> ReplicaConfig {
        assert_eq!(stages.len(), layers.len(), "stage/layer arity mismatch");
        assert!(!stages.is_empty(), "empty replica");
        assert!(stages.iter().all(|s| !s.is_empty()), "empty stage");
        ReplicaConfig { stages, layers }
    }

    /// Pipeline depth.
    pub fn pp(&self) -> usize {
        self.stages.len()
    }

    /// Reported TP degree (max stage width, as paper Table 2 reports).
    pub fn tp(&self) -> usize {
        self.stages.iter().map(|s| s.len()).max().unwrap_or(0)
    }

    /// All devices, in stage order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.stages.iter().flatten().copied().collect()
    }

    pub fn n_devices(&self) -> usize {
        self.stages.iter().map(|s| s.len()).sum()
    }

    pub fn total_layers(&self) -> usize {
        self.layers.iter().sum()
    }

    /// Human-readable strategy string matching the paper's Table-2 format.
    pub fn strategy_string(&self) -> String {
        format!("TP={},PP={}", self.tp(), self.pp())
    }
}

impl std::fmt::Display for ReplicaConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} stages[", self.strategy_string())?;
        for (i, s) in self.stages.iter().enumerate() {
            if i > 0 {
                write!(f, " | ")?;
            }
            write!(f, "{}l:{:?}", self.layers[i], s)?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let r = ReplicaConfig::new(vec![vec![0, 1], vec![2]], vec![30, 18]);
        assert_eq!(r.pp(), 2);
        assert_eq!(r.tp(), 2);
        assert_eq!(r.n_devices(), 3);
        assert_eq!(r.total_layers(), 48);
        assert_eq!(r.devices(), vec![0, 1, 2]);
        assert_eq!(r.strategy_string(), "TP=2,PP=2");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn rejects_mismatched_layers() {
        ReplicaConfig::new(vec![vec![0]], vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "empty stage")]
    fn rejects_empty_stage() {
        ReplicaConfig::new(vec![vec![0], vec![]], vec![1, 2]);
    }
}
