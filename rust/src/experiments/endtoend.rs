//! Figs. 6, 7, 8, 9: the end-to-end throughput/latency grids — HexGen-2 vs
//! HexGen on the heterogeneous settings and DistServe on the homogeneous
//! setting, across the four offline workload classes plus the online trace;
//! the 70%-budget cost-efficiency study (Fig. 9); and the heavy-tail
//! admission study exercising the unified simulator's per-request KV
//! accounting.

use crate::cluster::settings;
use crate::deploy::SimBackend;
use crate::model::LlmSpec;
use crate::simulator::Sizing;
use crate::util::bench::Table;
use crate::workload::{Trace, WorkloadKind, OFFLINE_KINDS};

use super::{offline_run, online_rate, online_run, spec_for, ExpOpts, System};

/// One row of the Fig. 6/7 grid: system × setting → 4 offline workloads +
/// online, all in tokens/s (every cell planned and run through the deploy
/// API via the system's planner).
fn grid_row(
    sys: System,
    setting: &str,
    model: &LlmSpec,
    opts: &ExpOpts,
) -> Option<Vec<String>> {
    let cluster = settings::by_name(setting)?;
    let planner = sys.planner();
    let mut cells = vec![setting.to_string(), sys.name().to_string()];
    for kind in OFFLINE_KINDS {
        let t = offline_run(planner, &cluster, model, kind, opts)
            .map(|r| r.tokens_per_s())
            .unwrap_or(0.0);
        cells.push(format!("{t:.0}"));
    }
    let rate = online_rate(&cluster, model, opts);
    let t =
        online_run(planner, &cluster, model, rate, opts).map(|r| r.tokens_per_s()).unwrap_or(0.0);
    cells.push(format!("{t:.0}"));
    Some(cells)
}

/// Fig. 6 (LLaMA-2-70B) / Fig. 7 (OPT-30B): heterogeneous settings 1..4
/// (HexGen-2 vs HexGen) plus the homogeneous DistServe reference.
pub fn fig6_7_grid(model: &LlmSpec, het_settings: &[&str], opts: &ExpOpts) -> Table {
    let mut t = Table::new(&[
        "setting", "system", "HPLD", "HPHD", "LPHD", "LPLD", "Online",
    ]);
    for s in het_settings {
        for sys in [System::HexGen2, System::HexGen] {
            if let Some(row) = grid_row(sys, s, model, opts) {
                t.row(&row);
            }
        }
    }
    if let Some(row) = grid_row(System::DistServe, "homogeneous", model, opts) {
        t.row(&row);
    }
    t
}

/// Fig. 8: online latency comparison — average latency and the SLO scale at
/// 99% attainment per system/setting.
pub fn fig8_latency(model: &LlmSpec, het_settings: &[&str], opts: &ExpOpts) -> Table {
    let mut t = Table::new(&[
        "setting", "system", "avg latency (s)", "p95 (s)", "SLO scale @99%",
    ]);
    let mut run = |sys: System, setting: &str| {
        let Some(cluster) = settings::by_name(setting) else { return };
        let rate = online_rate(&cluster, model, opts);
        if let Some(rep) = online_run(sys.planner(), &cluster, model, rate, opts) {
            t.row(&[
                setting.to_string(),
                sys.name().to_string(),
                format!("{:.2}", rep.avg_latency()),
                format!("{:.2}", rep.p_latency(95.0)),
                format!("{:.1}", rep.slo_scale_for_attainment(0.99)),
            ]);
        }
    };
    for s in het_settings {
        run(System::HexGen2, s);
        run(System::HexGen, s);
    }
    run(System::DistServe, "homogeneous");
    t
}

/// Fig. 9: HexGen-2 on het5 (70% budget) vs DistServe on the homogeneous
/// setting, per workload.
pub fn fig9_budget(model: &LlmSpec, opts: &ExpOpts) -> Table {
    let het5 = settings::het5();
    let hom = settings::homogeneous();
    let mut t = Table::new(&[
        "workload",
        "HEXGEN-2 het5 (70% budget)",
        "DISTSERVE homogeneous",
        "ratio",
    ]);
    for kind in OFFLINE_KINDS {
        let a = offline_run(System::HexGen2.planner(), &het5, model, kind, opts)
            .map(|r| r.tokens_per_s())
            .unwrap_or(0.0);
        let b = offline_run(System::DistServe.planner(), &hom, model, kind, opts)
            .map(|r| r.tokens_per_s())
            .unwrap_or(0.0);
        t.row(&[
            kind.name().to_string(),
            format!("{a:.0}"),
            format!("{b:.0}"),
            format!("{:.2}", if b > 0.0 { a / b } else { 0.0 }),
        ]);
    }
    t
}

/// Heavy-tail admission study: the same plans serving an extreme-dispersion
/// offline trace under static mean-length sizing vs per-request KV
/// accounting. Static sizing freezes batch caps at the trace *means*, which
/// a σ≈1.3 log-normal badly misrepresents; per-request accounting charges
/// actual lengths against replica memory and queues under pressure — the
/// `mem stalls` / `peak resident` columns make that pressure visible.
pub fn heavy_tail_admission(model: &LlmSpec, setting: &str, opts: &ExpOpts) -> Option<Table> {
    let cluster = settings::by_name(setting)?;
    let n = opts.offline_n().max(200);
    let trace = Trace::offline(WorkloadKind::HeavyTail, n, opts.seed.wrapping_add(83));
    let mut t = Table::new(&[
        "system",
        "admission",
        "tokens/s",
        "p95 lat (s)",
        "mem stalls",
        "peak resident (ktok)",
        "unserved",
    ]);
    for sys in [System::HexGen2, System::Vllm] {
        // Plan once per system: the admission model is a simulation-time
        // knob (deploy::backend::sim_config), not a planner input, so both
        // rows run on the identical plan.
        let spec = spec_for(&cluster, model, WorkloadKind::HeavyTail, opts);
        let mut dep = match spec.plan(sys.planner()) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("heavy_tail: {} planning failed: {e}", sys.name());
                continue;
            }
        };
        for (label, sizing) in
            [("static-mean", Sizing::StaticMean), ("per-request", Sizing::PerRequest)]
        {
            dep.spec.admission = sizing;
            let rep = match dep.run(&SimBackend, &trace) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("heavy_tail: {} ({label}) simulation failed: {e}", sys.name());
                    continue;
                }
            };
            t.row(&[
                sys.name().to_string(),
                label.to_string(),
                format!("{:.0}", rep.tokens_per_s()),
                format!("{:.2}", rep.p_latency(95.0)),
                format!("{}", rep.stats.mem_stalls),
                format!("{:.1}", rep.stats.peak_resident_tokens / 1000.0),
                format!("{}", rep.stats.unserved),
            ]);
        }
    }
    Some(t)
}

/// Summary ratios (DESIGN.md §6): geometric-mean HexGen-2/baseline
/// speedups over a grid table produced by `fig6_7_grid`.
pub fn speedup_summary(t: &Table) -> Vec<(String, f64)> {
    let rows = t.rows_for_test();
    let mut out = Vec::new();
    // Pair HEXGEN-2 rows with the HEXGEN row of the same setting.
    for w in rows.windows(2) {
        if w[0][1] == "HEXGEN-2" && w[1][1] == "HEXGEN" && w[0][0] == w[1][0] {
            let mut logsum = 0.0;
            let mut n = 0;
            for c in 2..w[0].len() {
                let a: f64 = w[0][c].parse().unwrap_or(0.0);
                let b: f64 = w[1][c].parse().unwrap_or(0.0);
                if a > 0.0 && b > 0.0 {
                    logsum += (a / b).ln();
                    n += 1;
                }
            }
            if n > 0 {
                out.push((w[0][0].clone(), (logsum / n as f64).exp()));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OPT_30B;

    #[test]
    fn small_grid_runs() {
        // One het setting, quick mode: the full grid is exercised by benches.
        let opts = ExpOpts { quick: true, seed: 3 };
        let t = fig6_7_grid(&OPT_30B, &["het4"], &opts);
        let rows = t.rows_for_test();
        assert_eq!(rows.len(), 3); // hexgen2, hexgen, distserve
        for r in &rows {
            for c in &r[2..] {
                let v: f64 = c.parse().unwrap();
                assert!(v > 0.0, "zero cell in {r:?}");
            }
        }
        let sp = speedup_summary(&t);
        assert_eq!(sp.len(), 1);
        assert!(sp[0].1 > 0.3, "HexGen-2 catastrophically behind: {sp:?}");
    }
}
