//! Full-evaluation memoization for the planner hot path.
//!
//! [`evaluate_partition`](super::evaluate_partition) is a pure function of
//! (cluster, model, task, period, partition, candidate budget, objective) —
//! and the §3.3 serving loop calls it with *heavily repeated* arguments:
//! refinement rounds re-propose partitions, GA generations re-breed
//! identical genomes, periodic re-plans under steady traffic replay the
//! whole search, and oscillating workloads revisit earlier plans. The
//! [`EvalCache`] memoizes whole evaluations across all of these, keyed by
//! the canonical partition signature plus every other input that can change
//! the result (objective, task lengths, period, candidate budget).
//!
//! Sharing rules:
//! - One cache may be shared across seeds, refinement rounds, GA
//!   generations, and warm-started re-plans — results are pure, so hits are
//!   always byte-identical to a recomputation and plans stay bit-identical
//!   with the cache on, off, or shared.
//! - A cache is bound to one (cluster, model) pair: the key deliberately
//!   omits them for compactness, and the cache self-invalidates (clears)
//!   if it observes a different pair — see [`EvalCache::evaluate`].
//! - Thread-safe (`&self` everywhere): the parallel proposal evaluation in
//!   [`schedule`](super::schedule) shares it across `std::thread::scope`
//!   workers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::TaskProfile;
use crate::kvtransfer::LinkModel;
use crate::model::LlmSpec;
use crate::telemetry::audit::{signature_hash, AuditRecord};

use super::flownet::FlowNetPool;
use super::objective::{kv_nic_utilization, Objective};
use super::strategy::StrategyCache;
use super::Placement;

/// Everything besides (cluster, model) that `evaluate_partition` depends on.
#[derive(Clone, PartialEq, Eq, Hash)]
struct EvalKey {
    /// Canonical partition signature (group/device order independent).
    sig: Vec<usize>,
    /// Objective discriminant + parameter bits.
    objective: (u8, u64),
    /// (batch, s_in bits, s_out bits).
    task: (usize, u64, u64),
    period_bits: u64,
    n_type_candidates: usize,
    /// Contention-aware objective term discriminant
    /// (`ScheduleOptions::kv_contention`): the penalty changes scores, so
    /// blind and contention-aware searches must not share entries.
    contention: u8,
    /// Cache-aware prefill discount bits
    /// (`ScheduleOptions::prefix_hit_rate`): the discount changes prefill
    /// capacities, so hit-blind and hit-aware searches must not share
    /// entries.
    prefix_bits: u64,
}

fn objective_bits(o: Objective) -> (u8, u64) {
    match o {
        Objective::Throughput => (0, 0),
        Objective::SloGoodput { scale } => (1, scale.to_bits()),
        Objective::MeanLatency => (2, 0),
        Objective::CostPerToken => (3, 0),
    }
}

fn contention_bits(c: Option<LinkModel>) -> u8 {
    match c {
        None => 0,
        Some(LinkModel::PerRoute) => 1,
        Some(LinkModel::SharedNic) => 2,
    }
}

/// Content fingerprint of everything `evaluate_partition` reads from the
/// cluster and model: device types/placement and both link matrices, plus
/// the model identity. Names alone are not enough — `Cluster` fields are
/// public, and a degraded-link or swapped-GPU variant with the same name
/// and size must not be served another topology's placements. FNV-1a over
/// the raw bits.
fn owner_fingerprint(cluster: &Cluster, model: &LlmSpec) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    for b in cluster.name.as_bytes() {
        mix(*b as u64);
    }
    for b in model.name.as_bytes() {
        mix(*b as u64);
    }
    mix(model.n_layers as u64);
    mix(model.hidden as u64);
    mix(model.bytes_per_elem.to_bits());
    for d in &cluster.devices {
        mix(d.gpu.tflops().to_bits());
        mix(d.gpu.mem_bytes().to_bits());
        mix(d.node as u64);
        mix(d.dc as u64);
    }
    for row in cluster.bandwidth.iter().chain(cluster.latency.iter()) {
        for v in row {
            mix(v.to_bits());
        }
    }
    h
}

/// Snapshot of an [`EvalCache`]'s counters (monotonic; subtract two
/// snapshots for a per-search delta).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EvalCounters {
    /// Memoized results served.
    pub hits: usize,
    /// `evaluate_partition` executions actually performed.
    pub misses: usize,
    /// Per-group strategy-search cache hits/misses (the inner layer).
    pub strategy_hits: usize,
    pub strategy_misses: usize,
    /// Unique partition evaluations currently held.
    pub unique_evals: usize,
}

/// Shared, thread-safe memo of whole partition evaluations, layered over
/// the per-group [`StrategyCache`].
pub struct EvalCache {
    map: Mutex<HashMap<EvalKey, Option<Placement>>>,
    strategy: StrategyCache,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// `false` disables memoization (A/B benchmarking) while keeping the
    /// execution counters — `misses` then counts every evaluation.
    enabled: bool,
    /// Content fingerprint of the (cluster, model) the entries belong to.
    owner: Mutex<Option<u64>>,
    /// Decision-audit capture (`ScheduleOptions::audit`): one
    /// [`AuditRecord::Candidate`] per `evaluate` call, hit or miss. Off by
    /// default — the hot path only pays a relaxed atomic load. Under
    /// parallel proposal evaluation the record *order* is
    /// thread-interleaved (the scores themselves stay deterministic), so
    /// audit files are for reading, not byte-diffing.
    audit_on: AtomicBool,
    audit: Mutex<Vec<AuditRecord>>,
}

impl Default for EvalCache {
    fn default() -> EvalCache {
        EvalCache::new()
    }
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache {
            map: Mutex::new(HashMap::new()),
            strategy: StrategyCache::new(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            enabled: true,
            owner: Mutex::new(None),
            audit_on: AtomicBool::new(false),
            audit: Mutex::new(Vec::new()),
        }
    }

    /// A cache that never memoizes whole evaluations: the uncached baseline
    /// of the perf harness. The inner per-group [`StrategyCache`] still
    /// memoizes (that layer predates this PR and is part of the status-quo
    /// baseline); `misses` counts every `evaluate_partition` execution
    /// either way, and results are identical — memoization is observable
    /// only through the counters.
    pub fn disabled() -> EvalCache {
        EvalCache { enabled: false, ..EvalCache::new() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Start capturing one [`AuditRecord::Candidate`] per evaluation
    /// (`ScheduleOptions::audit` / `--audit`; DESIGN.md §12).
    pub fn enable_audit(&self) {
        self.audit_on.store(true, Ordering::Relaxed);
    }

    /// Drain the captured candidate records (capture keeps running).
    pub fn take_audit(&self) -> Vec<AuditRecord> {
        std::mem::take(&mut *self.audit.lock().unwrap())
    }

    /// One candidate record: signature hash, score breakdown (final vs
    /// pre-discount, recovered by inverting `apply_kv_contention`'s
    /// piecewise map), analytic NIC utilization, and whether the memo
    /// served it.
    fn push_audit(
        &self,
        sig: &[usize],
        groups: usize,
        v: &Option<Placement>,
        kv_contention: Option<LinkModel>,
        cache_hit: bool,
    ) {
        let (score, raw_score, nic_util) = match v {
            Some(p) => {
                let util = kv_contention.map(|l| kv_nic_utilization(p, l)).unwrap_or(0.0);
                let s = p.objective_score;
                // Inverse of apply_kv_contention: recover the
                // pre-discount score from the discounted one.
                let raw = if util <= 1.0 {
                    s
                } else if s >= 0.0 {
                    s * util
                } else {
                    s / util
                };
                (s, raw, util)
            }
            // Infeasible candidates carry no score; 0.0 keeps the JSON
            // finite — `feasible: false` is the signal.
            None => (0.0, 0.0, 0.0),
        };
        self.audit.lock().unwrap().push(AuditRecord::Candidate {
            sig: signature_hash(sig),
            groups: groups as u32,
            score,
            raw_score,
            nic_util,
            cache_hit,
            feasible: v.is_some(),
        });
    }

    /// The shared per-group strategy cache (the inner memo layer).
    pub fn strategy(&self) -> &StrategyCache {
        &self.strategy
    }

    pub fn counters(&self) -> EvalCounters {
        EvalCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            strategy_hits: self.strategy.hits(),
            strategy_misses: self.strategy.misses(),
            unique_evals: self.map.lock().unwrap().len(),
        }
    }

    /// Memoized [`evaluate_partition`](super::evaluate_partition). The
    /// result is bit-identical to an uncached call: entries are pure
    /// functions of the key, and the key covers every input except
    /// (cluster, model), which the cache binds itself to — feeding a
    /// different pair flushes all entries first.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate(
        &self,
        cluster: &Cluster,
        model: &LlmSpec,
        task: &TaskProfile,
        period: f64,
        groups: &[Vec<DeviceId>],
        n_type_candidates: usize,
        objective: Objective,
        kv_contention: Option<LinkModel>,
    ) -> Option<Placement> {
        self.evaluate_pooled(
            cluster,
            model,
            task,
            period,
            groups,
            n_type_candidates,
            objective,
            kv_contention,
            1,
            &mut FlowNetPool::new(),
            0.0,
        )
    }

    /// [`EvalCache::evaluate`] with an inner worker budget for the miss
    /// path's per-group strategy search and a recycled solver allocation
    /// ([`FlowNetPool`]). Hits leave the pool untouched; misses adopt its
    /// skeleton and hand it back. Neither knob can change a memoized value
    /// — evaluation stays a pure function of the key, which is what keeps
    /// one cache shareable across searches, thread counts, and pool states.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate_pooled(
        &self,
        cluster: &Cluster,
        model: &LlmSpec,
        task: &TaskProfile,
        period: f64,
        groups: &[Vec<DeviceId>],
        n_type_candidates: usize,
        objective: Objective,
        kv_contention: Option<LinkModel>,
        threads: usize,
        pool: &mut FlowNetPool,
        prefix_hit_rate: f64,
    ) -> Option<Placement> {
        self.bind_owner(cluster, model);
        let key = EvalKey {
            sig: super::partition_signature(groups),
            objective: objective_bits(objective),
            task: (task.batch, task.s_in.to_bits(), task.s_out.to_bits()),
            period_bits: period.to_bits(),
            n_type_candidates,
            contention: contention_bits(kv_contention),
            prefix_bits: prefix_hit_rate.to_bits(),
        };
        let audit_on = self.audit_on.load(Ordering::Relaxed);
        if self.enabled {
            let hit = self.map.lock().unwrap().get(&key).cloned();
            if let Some(v) = hit {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if audit_on {
                    self.push_audit(&key.sig, groups.len(), &v, kv_contention, true);
                }
                return v;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let v = super::evaluate_partition_pooled(
            cluster,
            model,
            task,
            period,
            groups,
            n_type_candidates,
            objective,
            kv_contention,
            &self.strategy,
            threads,
            pool,
            prefix_hit_rate,
        );
        if audit_on {
            self.push_audit(&key.sig, groups.len(), &v, kv_contention, false);
        }
        if self.enabled {
            self.map.lock().unwrap().insert(key, v.clone());
        }
        v
    }

    /// Bind to (cluster, model) on first use; clear everything if a
    /// different — or mutated — pair shows up (the key omits them by
    /// design; the fingerprint hashes their actual contents).
    fn bind_owner(&self, cluster: &Cluster, model: &LlmSpec) {
        let fp = owner_fingerprint(cluster, model);
        let mut owner = self.owner.lock().unwrap();
        match *owner {
            Some(prev) if prev == fp => {}
            Some(_) => {
                // Both layers' keys omit cluster/model: flush them. The
                // counters deliberately keep running — they describe the
                // cache's lifetime, not one binding.
                *owner = Some(fp);
                self.map.lock().unwrap().clear();
                self.strategy.clear();
            }
            None => {
                *owner = Some(fp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::{LLAMA2_70B, OPT_30B};
    use crate::scheduler::{task_for, Objective};
    use crate::workload::WorkloadKind;

    fn groups() -> Vec<Vec<usize>> {
        vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]]
    }

    #[test]
    fn repeated_evaluations_hit() {
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Lphd);
        let cache = EvalCache::new();
        let a = cache.evaluate(&c, &OPT_30B, &task, 600.0, &groups(), 8, Objective::Throughput, None);
        let before = cache.counters();
        assert_eq!(before.misses, 1);
        // Same partition with groups and devices permuted: same signature.
        let permuted = vec![vec![3, 2], vec![1, 0], vec![6, 7], vec![4, 5]];
        let b = cache.evaluate(&c, &OPT_30B, &task, 600.0, &permuted, 8, Objective::Throughput, None);
        let after = cache.counters();
        assert_eq!(after.misses, 1, "permutation re-executed the evaluation");
        assert_eq!(after.hits, 1);
        assert_eq!(
            format!("{:?}", a),
            format!("{:?}", b),
            "memoized result differs from the original"
        );
    }

    #[test]
    fn distinct_objective_or_workload_miss() {
        let c = settings::case_study();
        let cache = EvalCache::new();
        let g = groups();
        let lphd = task_for(WorkloadKind::Lphd);
        let hpld = task_for(WorkloadKind::Hpld);
        let _ = cache.evaluate(&c, &OPT_30B, &lphd, 600.0, &g, 8, Objective::Throughput, None);
        let _ = cache.evaluate(&c, &OPT_30B, &hpld, 600.0, &g, 8, Objective::Throughput, None);
        let _ = cache.evaluate(&c, &OPT_30B, &lphd, 600.0, &g, 8, Objective::MeanLatency, None);
        let _ =
            cache.evaluate(&c, &OPT_30B, &lphd, 600.0, &g, 8, Objective::SloGoodput { scale: 2.0 }, None);
        let _ =
            cache.evaluate(&c, &OPT_30B, &lphd, 600.0, &g, 8, Objective::SloGoodput { scale: 4.0 }, None);
        assert_eq!(cache.counters().misses, 5, "keys collided across objective/workload");
        assert_eq!(cache.counters().hits, 0);
    }

    #[test]
    fn cached_equals_uncached_bitwise() {
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Hpld);
        let cached = EvalCache::new();
        let uncached = EvalCache::disabled();
        let g = groups();
        for _ in 0..2 {
            let a = cached.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
            let b = uncached.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
            assert_eq!(format!("{a:?}"), format!("{b:?}"));
        }
        assert_eq!(cached.counters().misses, 1);
        assert_eq!(uncached.counters().misses, 2, "disabled cache must re-execute");
    }

    #[test]
    fn mutated_cluster_flushes_entries() {
        // Same name, same size, different topology: the content fingerprint
        // must catch it (a degraded link must not be served the healthy
        // cluster's placements).
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Lphd);
        let cache = EvalCache::new();
        let g = groups();
        let _ = cache.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        let mut degraded = c.clone();
        degraded.bandwidth[0][7] /= 100.0;
        degraded.bandwidth[7][0] /= 100.0;
        let _ = cache.evaluate(&degraded, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        assert_eq!(cache.counters().hits, 0, "stale hit across a mutated topology");
        assert_eq!(cache.counters().misses, 2);
    }

    #[test]
    fn rebinding_model_flushes_entries() {
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Lphd);
        let cache = EvalCache::new();
        let g = groups();
        let _ = cache.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        assert_eq!(cache.counters().unique_evals, 1);
        // A different model must not serve the OPT-30B entry.
        let _ = cache.evaluate(&c, &LLAMA2_70B, &task, 600.0, &g, 8, Objective::Throughput, None);
        assert_eq!(cache.counters().hits, 0, "stale cross-model hit");
        assert_eq!(cache.counters().misses, 2);
    }

    #[test]
    fn audit_records_hits_and_misses() {
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Lphd);
        let cache = EvalCache::new();
        let g = groups();
        // Off by default: no records.
        let _ = cache.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        assert!(cache.take_audit().is_empty());
        cache.enable_audit();
        let v = cache.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        let _ = cache.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        let audit = cache.take_audit();
        assert_eq!(audit.len(), 2);
        let hits: Vec<bool> = audit
            .iter()
            .map(|r| match r {
                AuditRecord::Candidate { cache_hit, .. } => *cache_hit,
                other => panic!("unexpected record {other:?}"),
            })
            .collect();
        assert_eq!(hits, vec![true, true], "pre-audit entry should be served from the memo");
        if let AuditRecord::Candidate { score, raw_score, nic_util, feasible, .. } = &audit[0] {
            assert!(*feasible);
            assert_eq!(*score, v.as_ref().unwrap().objective_score);
            // No contention term: no discount.
            assert_eq!(*score, *raw_score);
            assert_eq!(*nic_util, 0.0);
        }
        // Drained: a second take returns nothing new until more evals run.
        assert!(cache.take_audit().is_empty());
    }

    #[test]
    fn contention_term_keys_separately() {
        // Blind and contention-aware evaluations score candidates
        // differently, so they must not share memo entries.
        let c = settings::case_study();
        let task = task_for(WorkloadKind::Lphd);
        let cache = EvalCache::new();
        let g = groups();
        let _ = cache.evaluate(&c, &OPT_30B, &task, 600.0, &g, 8, Objective::Throughput, None);
        let _ = cache.evaluate(
            &c,
            &OPT_30B,
            &task,
            600.0,
            &g,
            8,
            Objective::Throughput,
            Some(crate::kvtransfer::LinkModel::SharedNic),
        );
        assert_eq!(cache.counters().misses, 2, "contention term collided in the key");
        assert_eq!(cache.counters().hits, 0);
    }
}
