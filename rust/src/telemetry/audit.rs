//! Decision audit records: *why* the planner and the §3.3 online loop did
//! what they did (DESIGN.md §12).
//!
//! The scheduler pushes one [`AuditRecord::Candidate`] per evaluated
//! partition (objective score with the `kv_contention` discount unpacked,
//! EvalCache hit/miss); the rescheduler pushes [`AuditRecord::Drift`] /
//! [`AuditRecord::Replan`] / [`AuditRecord::MigrationGate`] records for
//! every drift window it acted on, so `--audit` can explain every accepted
//! *and* denied re-plan. Records are plain data exported through
//! [`audit_json`].
//!
//! Ordering caveat: candidate records are pushed from the planner's
//! parallel evaluation workers, so their order (unlike trace files) is
//! *not* deterministic across `threads > 1` runs — consumers must not diff
//! audit JSON byte-for-byte.

use crate::util::json::{self, Json};

/// One planner/rescheduler decision, in the order it was made.
#[derive(Clone, Debug, PartialEq)]
pub enum AuditRecord {
    /// One candidate partition evaluated by the scheduler.
    Candidate {
        /// FNV-1a hash of the canonical partition signature
        /// (`scheduler::partition_signature`) — stable across runs, cheap
        /// to diff.
        sig: u64,
        /// Number of model groups in the candidate.
        groups: u32,
        /// Final objective score (after the KV-contention discount).
        score: f64,
        /// Score before the discount (`== score` when contention-aware
        /// planning is off or the NIC is uncontended).
        raw_score: f64,
        /// Analytic worst NIC overcommit of the candidate's KV routes
        /// (`scheduler::objective::kv_nic_utilization`); 0 when
        /// contention-aware planning is off.
        nic_util: f64,
        /// Served from the EvalCache instead of re-running the pipeline.
        cache_hit: bool,
        /// Candidate produced a feasible placement.
        feasible: bool,
    },
    /// The drift monitor fired (§3.3 observation window).
    Drift {
        at: f64,
        /// `DriftKind` rendered as text ("workload", "rate", "kv").
        kind: String,
        rate: f64,
        mean_input: f64,
        mean_output: f64,
        n: u32,
        mean_kv_wait_s: f64,
        /// Blamed latency component (DESIGN.md §16): the dominant
        /// attribution component of the epoch the drift was observed in
        /// when attribution ran, else a coarse default derived from the
        /// drift kind ("kv-transfer", "mix", "rate").
        blamed: String,
    },
    /// A warm re-plan ran for a drift event.
    Replan {
        at: f64,
        /// Workload kind the re-plan targeted.
        to: String,
        /// Whether the migration gate let the new plan go live.
        accepted: bool,
    },
    /// The priced migration gate's verdict on a re-plan (§3.3 pricing).
    MigrationGate {
        at: f64,
        /// Live NIC utilization the transfer bandwidth was derated by.
        nic_util: f64,
        drain_s: f64,
        kv_bytes: f64,
        transfer_s: f64,
        total_delay_s: f64,
        tokens_lost: f64,
        gain_tokens: f64,
        accepted: bool,
    },
}

impl AuditRecord {
    pub fn to_json(&self) -> Json {
        match self {
            AuditRecord::Candidate { sig, groups, score, raw_score, nic_util, cache_hit, feasible } => {
                json::obj(vec![
                    ("record", json::s("candidate")),
                    ("sig", json::s(&format!("{sig:016x}"))),
                    ("groups", json::num(*groups as f64)),
                    ("score", json::num(*score)),
                    ("raw_score", json::num(*raw_score)),
                    ("kv_contention_discount", json::num(*raw_score - *score)),
                    ("nic_util", json::num(*nic_util)),
                    ("cache_hit", Json::Bool(*cache_hit)),
                    ("feasible", Json::Bool(*feasible)),
                ])
            }
            AuditRecord::Drift {
                at,
                kind,
                rate,
                mean_input,
                mean_output,
                n,
                mean_kv_wait_s,
                blamed,
            } => json::obj(vec![
                ("record", json::s("drift")),
                ("at", json::num(*at)),
                ("kind", json::s(kind)),
                ("rate", json::num(*rate)),
                ("mean_input", json::num(*mean_input)),
                ("mean_output", json::num(*mean_output)),
                ("window_n", json::num(*n as f64)),
                ("mean_kv_wait_s", json::num(*mean_kv_wait_s)),
                ("blamed", json::s(blamed)),
            ]),
            AuditRecord::Replan { at, to, accepted } => json::obj(vec![
                ("record", json::s("replan")),
                ("at", json::num(*at)),
                ("to", json::s(to)),
                ("accepted", Json::Bool(*accepted)),
            ]),
            AuditRecord::MigrationGate {
                at,
                nic_util,
                drain_s,
                kv_bytes,
                transfer_s,
                total_delay_s,
                tokens_lost,
                gain_tokens,
                accepted,
            } => json::obj(vec![
                ("record", json::s("migration_gate")),
                ("at", json::num(*at)),
                ("nic_util", json::num(*nic_util)),
                ("drain_s", json::num(*drain_s)),
                ("kv_bytes", json::num(*kv_bytes)),
                ("transfer_s", json::num(*transfer_s)),
                ("total_delay_s", json::num(*total_delay_s)),
                ("tokens_lost", json::num(*tokens_lost)),
                ("gain_tokens", json::num(*gain_tokens)),
                ("accepted", Json::Bool(*accepted)),
            ]),
        }
    }
}

/// FNV-1a over a canonical partition signature
/// (`scheduler::partition_signature` output) — the candidate fingerprint
/// audit records carry.
pub fn signature_hash(sig: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in sig {
        for b in (x as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The `--audit` file format: a schema header plus the records in decision
/// order.
pub fn audit_json(records: &[AuditRecord]) -> Json {
    let candidates = records
        .iter()
        .filter(|r| matches!(r, AuditRecord::Candidate { .. }))
        .count();
    let gates = records
        .iter()
        .filter(|r| matches!(r, AuditRecord::MigrationGate { .. }))
        .count();
    json::obj(vec![
        ("schema", json::s("hexgen2-audit/v1")),
        ("n_records", json::num(records.len() as f64)),
        ("n_candidates", json::num(candidates as f64)),
        ("n_migration_gates", json::num(gates as f64)),
        ("records", json::arr(records.iter().map(|r| r.to_json()).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_hash_is_stable_and_discriminating() {
        let a = signature_hash(&[0, 0, 1, 1]);
        assert_eq!(a, signature_hash(&[0, 0, 1, 1]));
        assert_ne!(a, signature_hash(&[0, 1, 0, 1]));
        assert_ne!(a, signature_hash(&[0, 0, 1]));
    }

    #[test]
    fn audit_json_counts_record_kinds() {
        let recs = vec![
            AuditRecord::Candidate {
                sig: 7,
                groups: 2,
                score: 10.0,
                raw_score: 12.0,
                nic_util: 1.2,
                cache_hit: false,
                feasible: true,
            },
            AuditRecord::Drift {
                at: 30.0,
                kind: "workload".into(),
                rate: 4.0,
                mean_input: 512.0,
                mean_output: 64.0,
                n: 20,
                mean_kv_wait_s: 0.0,
                blamed: "mix".into(),
            },
            AuditRecord::MigrationGate {
                at: 30.0,
                nic_util: 0.4,
                drain_s: 1.0,
                kv_bytes: 1e9,
                transfer_s: 2.0,
                total_delay_s: 3.0,
                tokens_lost: 100.0,
                gain_tokens: 5000.0,
                accepted: true,
            },
        ];
        let j = audit_json(&recs);
        assert_eq!(j.get("schema").unwrap().as_str(), Some("hexgen2-audit/v1"));
        assert_eq!(j.get("n_records").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("n_candidates").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("n_migration_gates").unwrap().as_usize(), Some(1));
        let recs_j = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs_j[0].get("record").unwrap().as_str(), Some("candidate"));
        // The discount field unpacks raw − final.
        assert_eq!(recs_j[0].get("kv_contention_discount").unwrap().as_f64(), Some(2.0));
        assert_eq!(recs_j[1].get("blamed").unwrap().as_str(), Some("mix"));
        assert_eq!(recs_j[2].get("accepted").unwrap().as_bool(), Some(true));
    }
}
