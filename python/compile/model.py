"""Layer-2 JAX model: a GPT-style decoder-only transformer with disaggregated
prefill / decode entry points.

This is the compute graph the Rust coordinator serves. It exists only at
compile time: `aot.py` lowers `prefill` and `decode_step` (per batch/seq
variant) to HLO text, and the Rust runtime executes those modules via PJRT.
Attention inside both entry points is the Layer-1 Pallas kernel
(interpret=True), so the kernels lower into the same HLO modules.

The disaggregation contract (what makes prefill/decode splittable across
replicas) is the KV-cache shape discipline:

  prefill(params, tokens[B,S], lengths[B])
      -> (logits[B,V], k_cache[L,B,S_max,H], v_cache[L,B,S_max,H])
  decode_step(params, token[B], pos[B], k_cache, v_cache)
      -> (logits[B,V], k_cache', v_cache')

Caches are fixed-capacity buffers; prefill fills positions [0, S), decode
appends at `pos`. A prefill replica's output caches are exactly a decode
replica's input caches — the Rust KV-transfer path moves those literals
(that movement is the KV communication the paper schedules).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import flash_prefill, paged_decode


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration of one transformer variant."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    vocab: int
    max_seq: int  # KV-cache capacity (prefill len + decode budget)
    mlp_ratio: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s in param_entries(self))


# The tiny config drives tests + quickstart; gpt-100m is the ~100M-parameter
# end-to-end driver model (examples/e2e_serve.rs).
TINY = ModelConfig("tiny", n_layers=4, d_model=256, n_heads=8, vocab=512, max_seq=192)
GPT_100M = ModelConfig(
    "gpt-100m", n_layers=12, d_model=768, n_heads=12, vocab=8192, max_seq=640
)

CONFIGS = {c.name: c for c in (TINY, GPT_100M)}


def param_entries(cfg: ModelConfig):
    """Deterministic flat ordering of all parameter tensors.

    This ordering IS the ABI between aot.py (which writes the blob and lists
    module parameters in this order) and the Rust runtime (which feeds
    literals in this order). Do not reorder.
    """
    h, m = cfg.d_model, cfg.d_model * cfg.mlp_ratio
    entries = [
        ("tok_emb", (cfg.vocab, h)),
        ("pos_emb", (cfg.max_seq, h)),
    ]
    for l in range(cfg.n_layers):
        entries += [
            (f"l{l}.ln1_scale", (h,)),
            (f"l{l}.ln1_bias", (h,)),
            (f"l{l}.wqkv", (h, 3 * h)),
            (f"l{l}.bqkv", (3 * h,)),
            (f"l{l}.wo", (h, h)),
            (f"l{l}.bo", (h,)),
            (f"l{l}.ln2_scale", (h,)),
            (f"l{l}.ln2_bias", (h,)),
            (f"l{l}.w1", (h, m)),
            (f"l{l}.b1", (m,)),
            (f"l{l}.w2", (m, h)),
            (f"l{l}.b2", (h,)),
        ]
    entries += [("lnf_scale", (h,)), ("lnf_bias", (h,))]
    return entries


def init_params(cfg: ModelConfig, seed: int = 0):
    """Seeded deterministic initialization; returns the flat tuple of arrays."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in param_entries(cfg):
        if name.endswith(("_scale",)):
            arr = np.ones(shape, np.float32)
        elif name.endswith(("_bias",)) or name.startswith("b", name.rfind(".") + 1):
            arr = np.zeros(shape, np.float32)
        else:
            arr = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
        out.append(jnp.asarray(arr))
    return tuple(out)


def _unflatten(cfg: ModelConfig, params):
    names = [n for n, _ in param_entries(cfg)]
    assert len(names) == len(params), (len(names), len(params))
    return dict(zip(names, params))


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * scale + bias


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def _split_heads(x, cfg):
    # [B, S, H] -> [B*nh, S, Dh]
    b, s, _ = x.shape
    x = x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    return x.reshape(b * cfg.n_heads, s, cfg.head_dim)


def _merge_heads(x, b, cfg):
    # [B*nh, S, Dh] -> [B, S, H]
    s = x.shape[1]
    x = x.reshape(b, cfg.n_heads, s, cfg.head_dim).transpose(0, 2, 1, 3)
    return x.reshape(b, s, cfg.d_model)


def prefill(cfg: ModelConfig, params, tokens, lengths, *, interpret=True):
    """Prefill entry point. See module docstring for the signature contract."""
    p = _unflatten(cfg, params)
    b, s = tokens.shape
    assert s <= cfg.max_seq
    x = p["tok_emb"][tokens] + p["pos_emb"][:s][None, :, :]
    k_cache = jnp.zeros((cfg.n_layers, b, cfg.max_seq, cfg.d_model), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    lens_bh = jnp.repeat(lengths, cfg.n_heads)  # [B*nh]

    for l in range(cfg.n_layers):
        h = _layernorm(x, p[f"l{l}.ln1_scale"], p[f"l{l}.ln1_bias"])
        qkv = h @ p[f"l{l}.wqkv"] + p[f"l{l}.bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)  # each [B, S, H]
        att = flash_prefill(
            _split_heads(q, cfg),
            _split_heads(k, cfg),
            _split_heads(v, cfg),
            lens_bh,
            interpret=interpret,
        )
        att = _merge_heads(att, b, cfg)
        x = x + att @ p[f"l{l}.wo"] + p[f"l{l}.bo"]
        h = _layernorm(x, p[f"l{l}.ln2_scale"], p[f"l{l}.ln2_bias"])
        x = x + _gelu(h @ p[f"l{l}.w1"] + p[f"l{l}.b1"]) @ p[f"l{l}.w2"] + p[f"l{l}.b2"]
        k_cache = lax.dynamic_update_slice(k_cache, k[None], (l, 0, 0, 0))
        v_cache = lax.dynamic_update_slice(v_cache, v[None], (l, 0, 0, 0))

    x = _layernorm(x, p["lnf_scale"], p["lnf_bias"])
    # Hidden state of the last *real* token per sequence.
    idx = jnp.clip(lengths - 1, 0, s - 1)
    last = jnp.take_along_axis(x, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = last @ p["tok_emb"].T
    return logits, k_cache, v_cache


def decode_step(cfg: ModelConfig, params, token, pos, k_cache, v_cache, *, interpret=True):
    """One decode step. `pos` is the 0-based position the new token occupies;
    its KV is written into the caches at `pos` and attention runs over
    positions [0, pos]."""
    p = _unflatten(cfg, params)
    b = token.shape[0]
    x = p["tok_emb"][token] + p["pos_emb"][pos]
    lens_bh = jnp.repeat(pos + 1, cfg.n_heads)

    def write_at(cache_l, upd, positions):
        # cache_l: [B, S_max, H], upd: [B, H], positions: [B]
        def one(c, u, pp):
            return lax.dynamic_update_slice(c, u[None, :], (pp, 0))

        return jnp.stack([one(cache_l[i], upd[i], positions[i]) for i in range(b)])

    for l in range(cfg.n_layers):
        h = _layernorm(x, p[f"l{l}.ln1_scale"], p[f"l{l}.ln1_bias"])
        qkv = h @ p[f"l{l}.wqkv"] + p[f"l{l}.bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)  # each [B, H]
        kc_l = write_at(k_cache[l], k, pos)
        vc_l = write_at(v_cache[l], v, pos)
        k_cache = k_cache.at[l].set(kc_l)
        v_cache = v_cache.at[l].set(vc_l)
        # [B, H] -> [B*nh, Dh]; caches [B, S_max, H] -> [B*nh, S_max, Dh]
        q_h = q.reshape(b * cfg.n_heads, cfg.head_dim)
        kc_h = kc_l.reshape(b, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        kc_h = kc_h.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, cfg.max_seq, cfg.head_dim)
        vc_h = vc_l.reshape(b, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        vc_h = vc_h.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, cfg.max_seq, cfg.head_dim)
        att = paged_decode(q_h, kc_h, vc_h, lens_bh, interpret=interpret)
        att = att.reshape(b, cfg.d_model)
        x = x + att @ p[f"l{l}.wo"] + p[f"l{l}.bo"]
        h = _layernorm(x, p[f"l{l}.ln2_scale"], p[f"l{l}.ln2_bias"])
        x = x + _gelu(h @ p[f"l{l}.w1"] + p[f"l{l}.b1"]) @ p[f"l{l}.w2"] + p[f"l{l}.b2"]

    x = _layernorm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["tok_emb"].T
    return logits, k_cache, v_cache


def forward_full_ref(cfg: ModelConfig, params, tokens):
    """Oracle: plain full-sequence forward (no kernels, no caches).

    Returns logits for every position [B, S, V]; used by tests to check
    prefill+decode equivalence.
    """
    p = _unflatten(cfg, params)
    b, s = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][:s][None, :, :]
    mask = jnp.tril(jnp.ones((s, s), bool))
    for l in range(cfg.n_layers):
        h = _layernorm(x, p[f"l{l}.ln1_scale"], p[f"l{l}.ln1_bias"])
        qkv = h @ p[f"l{l}.wqkv"] + p[f"l{l}.bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        qh = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
        kh = k.reshape(b, s, cfg.n_heads, cfg.head_dim)
        vh = v.reshape(b, s, cfg.n_heads, cfg.head_dim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / np.sqrt(cfg.head_dim)
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
        probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
        att = jnp.einsum("bhqk,bkhd->bqhd", probs, vh).reshape(b, s, cfg.d_model)
        x = x + att @ p[f"l{l}.wo"] + p[f"l{l}.bo"]
        h = _layernorm(x, p[f"l{l}.ln2_scale"], p[f"l{l}.ln2_bias"])
        x = x + _gelu(h @ p[f"l{l}.w1"] + p[f"l{l}.b1"]) @ p[f"l{l}.w2"] + p[f"l{l}.b2"]
    x = _layernorm(x, p["lnf_scale"], p["lnf_bias"])
    return x @ p["tok_emb"].T
