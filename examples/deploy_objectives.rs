//! Pluggable objectives through the unified deploy API: the same cluster /
//! model / workload planned four times, each ranked by a different
//! [`Objective`] — SLO-constrained and price-budget-constrained planning are
//! one-line spec changes, not new harnesses.
//!
//! Run:  cargo run --release --example deploy_objectives

use hexgen2::cluster::settings;
use hexgen2::deploy::{DeploymentSpec, HexGen2Planner, Objective, SimBackend};
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::objective::active_cost_per_hour;
use hexgen2::deploy::PlanKind;
use hexgen2::workload::{Trace, WorkloadKind};

fn main() {
    let cluster = settings::het1();
    let kind = WorkloadKind::Lphd;
    let trace = Trace::offline(kind, 80, 7);
    println!(
        "cluster {} (${:.2}/h), model {}, workload {}\n",
        cluster.name,
        cluster.budget_per_hour(),
        OPT_30B.name,
        kind.name()
    );

    for objective in [
        Objective::Throughput,
        Objective::SloGoodput { scale: 2.0 },
        Objective::MeanLatency,
        Objective::CostPerToken,
    ] {
        let spec = DeploymentSpec::new(cluster.clone(), OPT_30B)
            .workload(kind)
            .objective(objective)
            .quick(true);
        match spec.plan(&HexGen2Planner) {
            Ok(dep) => {
                let rep = dep.run(&SimBackend, &trace).expect("simulates");
                let active_cost = match &dep.plan.kind {
                    PlanKind::Disaggregated(p) => active_cost_per_hour(&dep.spec.cluster, p),
                    PlanKind::Colocated { .. } => dep.spec.cluster.budget_per_hour(),
                };
                println!(
                    "{:>16}: score {:>10.4} | est {:>5.0} tok/s | simulated {:>5.0} tok/s | \
                     avg latency {:>6.2}s | active ${:>5.2}/h",
                    objective.name(),
                    dep.plan.objective_score,
                    dep.plan.est_tokens_per_s,
                    rep.tokens_per_s(),
                    rep.avg_latency(),
                    active_cost,
                );
            }
            Err(e) => println!("{:>16}: no plan ({e})", objective.name()),
        }
    }
    println!("\neach row is the same spec with a different .objective(...) — nothing else changed");
}
