//! gpt-100m live-path hot-spot bench (§Perf): one decode step at batch 8 —
//! the dominant cost of the e2e driver. Skips silently if only the tiny
//! artifacts were built.
use hexgen2::runtime::{artifacts_dir, load_manifests, ModelRuntime};
use hexgen2::util::bench;

fn main() {
    let ok = load_manifests(&artifacts_dir()).map(|m| m.contains_key("gpt-100m")).unwrap_or(false);
    if !ok {
        eprintln!("skipping gpt100m_runtime bench: build artifacts with gpt-100m");
        return;
    }
    let rt = ModelRuntime::load_filtered(&artifacts_dir(), "gpt-100m", |m| {
        m.kind == "decode" && m.batch == 8
    })
    .expect("load");
    let dims = rt.manifest.cache_dims(8);
    let n: usize = dims.iter().product();
    let (k, v) = (vec![0f32; n], vec![0f32; n]);
    let token = vec![1i32; 8];
    let pos = vec![5i32; 8];
    bench::time("gpt100m/decode-step-b8", 2, 10, || {
        std::hint::black_box(rt.decode_step(8, &token, &pos, &k, &v).unwrap());
    });
}
