//! Bench: regenerate paper Table 2 (chosen placements per setting).
use hexgen2::experiments::{tables, ExpOpts};
use hexgen2::model::{LLAMA2_70B, OPT_30B};

fn main() {
    let opts = ExpOpts::from_env();
    let hets: &[&str] = if opts.quick { &["het1", "het4"] } else { &["het1", "het2", "het3", "het4"] };
    println!("=== Table 2: GPU deployment, strategy, and type ===");
    for setting in hets {
        for m in [&LLAMA2_70B, &OPT_30B] {
            if let Some(s) = tables::table2_placement(setting, m, &opts) {
                println!("--- {s}");
            }
        }
    }
}
