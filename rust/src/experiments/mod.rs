//! Experiment harnesses: one runner per table/figure of the paper's
//! evaluation (§5 + appendices). Each returns printable rows so the benches
//! (`rust/benches/`) and the CLI (`hexgen2 experiments <id>`) regenerate the
//! paper artifacts; DESIGN.md §6 records the validation protocol.
//!
//! Every (system, cluster, workload) cell goes through the unified
//! [`deploy`](crate::deploy) API: a [`DeploymentSpec`] planned by the
//! system's [`Planner`] and executed on the simulator [`Backend`] — the
//! harnesses iterate over planners instead of calling bespoke per-system
//! functions.

pub mod batching;
pub mod convergence;
pub mod endtoend;
pub mod kvrouting;
pub mod perf;
pub mod prefix;
pub mod resched;
pub mod tables;

use crate::cluster::Cluster;
use crate::deploy::{
    Backend, DeploymentSpec, DistServePlanner, HexGen2Planner, HexGenPlanner, Planner, SimBackend,
    VllmPlanner,
};
use crate::model::LlmSpec;
use crate::scheduler::{self, EvalCache, ScheduleOptions, SwapMode};
use crate::simulator::SimReport;
use crate::workload::{Trace, WorkloadKind};

/// Shared experiment options. `quick` shrinks traces and search budgets for
/// CI-speed runs (`cargo bench` default); full mode feeds the DESIGN.md §6
/// validation protocol.
#[derive(Clone, Copy, Debug)]
pub struct ExpOpts {
    pub quick: bool,
    pub seed: u64,
}

impl ExpOpts {
    pub fn quick() -> ExpOpts {
        ExpOpts { quick: true, seed: 0 }
    }

    pub fn full() -> ExpOpts {
        ExpOpts { quick: false, seed: 0 }
    }

    pub fn from_env() -> ExpOpts {
        if std::env::var("HEXGEN2_FULL").is_ok() {
            ExpOpts::full()
        } else {
            ExpOpts::quick()
        }
    }

    pub fn offline_n(&self) -> usize {
        if self.quick {
            80
        } else {
            300
        }
    }

    pub fn online_duration(&self) -> f64 {
        if self.quick {
            120.0
        } else {
            600.0
        }
    }

    pub fn sched_opts(&self, kind: WorkloadKind) -> ScheduleOptions {
        let mut o = ScheduleOptions::new(kind);
        o.seed = self.seed;
        if self.quick {
            o.max_rounds = 10;
            o.patience = 4;
            o.proposals_per_round = 8;
            o.type_candidates = 4;
        }
        o
    }

    pub fn ga_generations(&self) -> usize {
        if self.quick {
            6
        } else {
            25
        }
    }
}

/// The compared systems (§5.1 Baselines + Appendix F).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    HexGen2,
    HexGen,
    DistServe,
    Vllm,
}

impl System {
    pub fn name(self) -> &'static str {
        match self {
            System::HexGen2 => "HEXGEN-2",
            System::HexGen => "HEXGEN",
            System::DistServe => "DISTSERVE",
            System::Vllm => "VLLM",
        }
    }

    /// The system's planner in the unified deploy API.
    pub fn planner(self) -> &'static dyn Planner {
        match self {
            System::HexGen2 => &HexGen2Planner,
            System::HexGen => &HexGenPlanner,
            System::DistServe => &DistServePlanner,
            System::Vllm => &VllmPlanner,
        }
    }
}

/// The deployment spec for one experiment cell (quick budgets mirror
/// [`ExpOpts::sched_opts`]).
pub fn spec_for(
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    opts: &ExpOpts,
) -> DeploymentSpec {
    DeploymentSpec::new(cluster.clone(), *model).workload(kind).seed(opts.seed).quick(opts.quick)
}

/// Run one (planner, cluster, model, workload) cell: offline trace → tokens/s.
pub fn offline_run(
    planner: &dyn Planner,
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    opts: &ExpOpts,
) -> Option<SimReport> {
    let trace = Trace::offline(kind, opts.offline_n(), opts.seed.wrapping_add(17));
    run_trace(planner, cluster, model, kind, &trace, opts)
}

/// Run one online cell at `rate` req/s.
pub fn online_run(
    planner: &dyn Planner,
    cluster: &Cluster,
    model: &LlmSpec,
    rate: f64,
    opts: &ExpOpts,
) -> Option<SimReport> {
    let trace = Trace::online(WorkloadKind::Online, rate, opts.online_duration(), opts.seed + 29);
    run_trace(planner, cluster, model, WorkloadKind::Online, &trace, opts)
}

fn run_trace(
    planner: &dyn Planner,
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    trace: &Trace,
    opts: &ExpOpts,
) -> Option<SimReport> {
    let dep = spec_for(cluster, model, kind, opts).plan(planner).ok()?;
    dep.run(&SimBackend, trace).ok()
}

/// Run one cell on an arbitrary backend (rescheduling-enabled simulation,
/// live coordinator) — same path, different substrate.
pub fn run_on_backend(
    planner: &dyn Planner,
    backend: &dyn Backend,
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    trace: &Trace,
    opts: &ExpOpts,
) -> Option<SimReport> {
    let dep = spec_for(cluster, model, kind, opts).plan(planner).ok()?;
    dep.run(backend, trace).ok()
}

/// Online arrival rate for a cluster: 75% of HexGen-2's estimated peak
/// (§5.1 "we scale the average arrival rate to 75% of the cluster's peak
/// throughput"). Same rate is used for every system on that cluster.
pub fn online_rate(cluster: &Cluster, model: &LlmSpec, opts: &ExpOpts) -> f64 {
    let o = opts.sched_opts(WorkloadKind::Online);
    let peak_tokens = scheduler::schedule(cluster, model, &o)
        .map(|r| r.placement.tokens_per_s)
        .unwrap_or(100.0);
    let (_s_in, s_out) = WorkloadKind::Online.mean_lengths();
    0.75 * peak_tokens / s_out
}

/// Convergence curve of one scheduler variant (Fig. 10 axes).
pub fn convergence_curve(
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    mode: SwapMode,
    seed: u64,
    opts: &ExpOpts,
) -> Vec<(f64, f64)> {
    convergence_curve_cached(cluster, model, kind, mode, seed, opts, &EvalCache::new())
}

/// [`convergence_curve`] against a caller-owned [`EvalCache`]: the Fig.
/// 10/11 sweeps repeat (workload × seed) runs over one cluster/model pair,
/// and seeds/uniform layouts/re-proposed partitions recur heavily across
/// them — a shared cache serves those for free. Sharing never changes a
/// curve (memoized evaluations are bit-identical to recomputation).
pub fn convergence_curve_cached(
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    mode: SwapMode,
    seed: u64,
    opts: &ExpOpts,
    cache: &EvalCache,
) -> Vec<(f64, f64)> {
    let mut o = opts.sched_opts(kind);
    o.seed = seed;
    o.swap_mode = mode;
    scheduler::schedule_with_cache(cluster, model, &o, cache)
        .map(|r| r.history.iter().map(|p| (p.elapsed_s, p.tokens_per_s)).collect())
        .unwrap_or_default()
}

pub fn convergence_curve_ga(
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    seed: u64,
    opts: &ExpOpts,
) -> Vec<(f64, f64)> {
    convergence_curve_ga_cached(cluster, model, kind, seed, opts, &EvalCache::new())
}

/// GA convergence curve against a caller-owned [`EvalCache`] (ROADMAP PR-4
/// follow-up): GA populations re-breed identical genomes across seeds and
/// workloads, so one cache across the whole Fig. 10/11 sweep turns most
/// fitness calls into memo hits without changing any curve.
pub fn convergence_curve_ga_cached(
    cluster: &Cluster,
    model: &LlmSpec,
    kind: WorkloadKind,
    seed: u64,
    opts: &ExpOpts,
    cache: &EvalCache,
) -> Vec<(f64, f64)> {
    let mut o = opts.sched_opts(kind);
    o.seed = seed;
    scheduler::genetic::schedule_genetic_with_cache(cluster, model, &o, cache)
        .map(|r| r.history.iter().map(|p| (p.elapsed_s, p.tokens_per_s)).collect())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;

    #[test]
    fn every_system_produces_throughput() {
        let opts = ExpOpts { quick: true, seed: 1 };
        let hom = settings::homogeneous_small();
        for sys in [System::HexGen2, System::HexGen, System::DistServe, System::Vllm] {
            let rep = offline_run(sys.planner(), &hom, &OPT_30B, WorkloadKind::Lpld, &opts)
                .unwrap_or_else(|| panic!("{sys:?} failed"));
            assert!(rep.tokens_per_s() > 0.0, "{sys:?} zero throughput");
            assert_eq!(rep.records.len(), opts.offline_n(), "{sys:?} lost requests");
        }
    }

    #[test]
    fn online_rate_positive() {
        let opts = ExpOpts { quick: true, seed: 2 };
        let c = settings::homogeneous_small();
        let r = online_rate(&c, &OPT_30B, &opts);
        assert!(r > 0.0 && r.is_finite());
    }
}
