//! Simulation metrics: the paper's evaluation quantities (§2 "Inference
//! serving goal"): decode throughput (tokens/s), end-to-end latency
//! statistics, and SLO attainment at configurable SLO scales.

use crate::kvtransfer::LinkLoad;
use crate::telemetry::{AttrReport, AuditRecord, TraceLog};
use crate::util::stats;

/// Per-request timing record.
#[derive(Clone, Copy, Debug)]
pub struct RequestRecord {
    pub id: usize,
    pub arrival: f64,
    /// When the prefill finished (≈ time to first token).
    pub prefill_done: f64,
    /// When the last output token was generated.
    pub completion: f64,
    pub input_len: usize,
    pub output_len: usize,
    /// SLO base: the request's "single device execution latency" (§2),
    /// against which SLO scales are measured.
    pub slo_base: f64,
}

impl RequestRecord {
    pub fn latency(&self) -> f64 {
        self.completion - self.arrival
    }

    pub fn ttft(&self) -> f64 {
        self.prefill_done - self.arrival
    }
}

/// Engine-level counters the per-request records cannot express: memory
/// pressure, rejections, link contention. Filled by the unified simulation
/// core ([`simulate`](crate::simulator::simulate)); zeroed on reports built
/// purely from records (the live coordinator's report, and
/// [`SimReport::windowed`] sub-reports when the parent has no trace — with
/// tracing on, `windowed` reconstructs `mem_stalls` / `kv_link_wait_s`
/// from the flight recorder's events).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Simulation events processed (heap pops) — the denominator of the
    /// bench harness's events/sec tracing-overhead column.
    pub events: usize,
    /// Admissions deferred because a replica's KV/activation memory was
    /// full (per-request accounting mode): each count is one service
    /// boundary at which the head of a queue could not be admitted.
    pub mem_stalls: usize,
    /// Requests dropped because they exceed every eligible replica's
    /// resident-token capacity outright.
    pub rejected: usize,
    /// Requests that arrived but were never completed (rejected, stranded
    /// in a migration blackout, or still queued when events ran dry).
    pub unserved: usize,
    /// Peak total resident sequence tokens across all replicas
    /// (per-request accounting mode).
    pub peak_resident_tokens: f64,
    /// Total seconds KV transfers spent queued behind a busy link.
    pub kv_link_wait_s: f64,
    /// KV transfers completed (one per disaggregated prefill completion).
    pub kv_transfers: usize,
    /// KV bytes moved prefill→decode (Table 1's 2·s·H·B per layer).
    pub kv_bytes: f64,
    /// Max over source NICs of KV transmission-busy fraction of the serving
    /// span — the measured counterpart of the planner's analytic
    /// [`kv_nic_utilization`](crate::scheduler::objective::kv_nic_utilization).
    pub kv_max_nic_util: f64,
    /// Per-transfer queue-wait histogram; bucket edges are
    /// [`Ledger::HIST_EDGES_S`](crate::kvtransfer::Ledger::HIST_EDGES_S)
    /// (<1 ms, <10 ms, <100 ms, <1 s, <10 s, ≥10 s).
    pub kv_wait_hist: [usize; 6],
    /// Peak simultaneously-live (arrived, not yet finished or rejected)
    /// requests — the observable behind the streaming engine's O(active)
    /// memory contract (DESIGN.md §14): heap, request store, and replica
    /// queues are all bounded by this, never by trace length.
    pub peak_live_requests: usize,
    /// Prefix-pool GPU hits: requests steered to the replica already
    /// holding their prefix KV (suffix-only prefill). DESIGN.md §15.
    pub prefix_hits: usize,
    /// Prefix-pool host-tier hits: prefix KV re-loaded from host memory
    /// before the suffix prefill.
    pub prefix_host_hits: usize,
    /// Requests that declared a prefix the pool did not hold (or whose
    /// holder could not take them): full prefill + publish.
    pub prefix_misses: usize,
    /// Prefill tokens skipped thanks to prefix reuse (GPU + host hits).
    pub prefix_reused_tokens: f64,
    /// Cumulative tokens first published into the pool.
    pub prefix_published_tokens: f64,
    /// Cumulative tokens LRU-spilled GPU → host.
    pub prefix_spilled_tokens: f64,
    /// Cumulative tokens dropped from the host tier.
    pub prefix_evicted_tokens: f64,
    /// Pool tokens GPU-resident at end of run.
    pub prefix_gpu_tokens: f64,
    /// Pool tokens in the host tier at end of run.
    pub prefix_host_tokens: f64,
    /// Total seconds spent re-loading prefix KV from the host tier.
    pub prefix_reload_s: f64,
}

impl SimStats {
    /// Pool hit rate over prefix-declaring requests: (GPU + host hits) /
    /// (hits + misses); 0.0 when no prefix traffic ran.
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = (self.prefix_hits + self.prefix_host_hits) as f64;
        let total = hits + self.prefix_misses as f64;
        if total > 0.0 {
            hits / total
        } else {
            0.0
        }
    }
}

/// Centroid cap of [`QuantileSketch`]: larger = more accurate, still O(1)
/// memory. At 256 the worst-case rank error near the median is ~0.4% of
/// the population (vs ~13% *value* error for the log-bucket histograms
/// this replaced in PR 9).
const SKETCH_COMPRESSION: usize = 256;
/// Insertions buffered before a merge pass (amortizes the sort).
const SKETCH_BUFFER: usize = 64;

/// A t-digest-style merging quantile sketch: bounded memory, one-pass,
/// fully deterministic (values fold in completion order; merges use a
/// quantile-aware weight bound, so centroids stay small near the tails
/// where percentile queries care). With fewer than `SKETCH_COMPRESSION`
/// distinct insertions every centroid is a singleton and quantiles are
/// *exact* nearest-rank values.
#[derive(Clone, Debug, Default)]
pub struct QuantileSketch {
    /// (mean, weight), sorted by mean.
    centroids: Vec<(f64, f64)>,
    buffer: Vec<f64>,
    count: f64,
}

impl QuantileSketch {
    pub fn new() -> QuantileSketch {
        QuantileSketch::default()
    }

    /// Fold one value in. Non-finite values clamp: NaN / −∞ to 0.0 (they
    /// attain everything, matching the old histogram's saturate-to-low
    /// cast), +∞ to a huge sentinel that sorts above any real measurement.
    pub fn push(&mut self, x: f64) {
        let x = if x.is_finite() {
            x
        } else if x == f64::INFINITY {
            1e18
        } else {
            0.0
        };
        self.buffer.push(x);
        self.count += 1.0;
        if self.buffer.len() >= SKETCH_BUFFER {
            self.flush();
        }
    }

    pub fn count(&self) -> f64 {
        self.count
    }

    /// Merge the buffer into the centroid list and re-compress.
    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        self.buffer.sort_unstable_by(f64::total_cmp);
        let mut merged = Vec::with_capacity(self.centroids.len() + self.buffer.len());
        let (mut i, mut j) = (0, 0);
        while i < self.centroids.len() || j < self.buffer.len() {
            let take_buf = i >= self.centroids.len()
                || (j < self.buffer.len() && self.buffer[j] < self.centroids[i].0);
            if take_buf {
                merged.push((self.buffer[j], 1.0));
                j += 1;
            } else {
                merged.push(self.centroids[i]);
                i += 1;
            }
        }
        self.buffer.clear();
        self.centroids = compress(merged, self.count);
    }

    /// Sorted (mean, weight) view including any buffered values.
    fn view(&self) -> Vec<(f64, f64)> {
        let mut v = self.centroids.clone();
        v.extend(self.buffer.iter().map(|&x| (x, 1.0)));
        v.sort_unstable_by(|a, b| f64::total_cmp(&a.0, &b.0));
        v
    }

    /// Nearest-rank quantile, `q` in [0, 1]: the mean of the first
    /// centroid whose cumulative weight reaches `ceil(q·n)` (exact when
    /// centroids are singletons). 0.0 on an empty sketch.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count <= 0.0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count).ceil().max(1.0);
        let view = self.view();
        let mut seen = 0.0;
        for &(m, w) in &view {
            seen += w;
            if seen >= target - 1e-9 {
                return m;
            }
        }
        view.last().map_or(0.0, |&(m, _)| m)
    }

    /// Fraction of the population with value ≤ `x` (each centroid counts
    /// wholly at its mean). 0.0 on an empty sketch.
    pub fn le_fraction(&self, x: f64) -> f64 {
        if self.count <= 0.0 {
            return 0.0;
        }
        let ok: f64 = self
            .centroids
            .iter()
            .filter(|&&(m, _)| m <= x)
            .map(|&(_, w)| w)
            .chain(self.buffer.iter().filter(|&&b| b <= x).map(|_| 1.0))
            .sum();
        ok / self.count
    }
}

/// One greedy left-to-right merge pass: adjacent centroids merge while the
/// combined weight stays under the t-digest size bound
/// `4·n·q(1−q)/compression + 1` at the candidate's mid-quantile `q` —
/// small near the tails, largest at the median. A list already under the
/// cap is returned untouched (keeps small populations exact).
fn compress(cs: Vec<(f64, f64)>, total: f64) -> Vec<(f64, f64)> {
    if cs.len() <= SKETCH_COMPRESSION {
        return cs;
    }
    let k = SKETCH_COMPRESSION as f64;
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(SKETCH_COMPRESSION);
    let mut acc = 0.0; // weight fully to the left of `out.last()`
    for (m, w) in cs {
        if let Some(last) = out.last_mut() {
            let q = ((acc + (last.1 + w) * 0.5) / total).clamp(0.0, 1.0);
            let limit = 4.0 * total * q * (1.0 - q) / k + 1.0;
            if last.1 + w <= limit {
                let nw = last.1 + w;
                last.0 = (last.0 * last.1 + m * w) / nw;
                last.1 = nw;
                continue;
            }
            acc += last.1;
        }
        out.push((m, w));
    }
    out
}

/// O(1)-per-completion accumulator behind [`RecordMode::Windowed`]
/// (DESIGN.md §14): sums for exact means/throughput plus
/// [`QuantileSketch`]es for latency percentiles and SLO attainment. Exact
/// quantities: completion count, token totals, mean latency/TTFT,
/// makespan. Sketch-approximate (sub-percent rank error; exact below 256
/// completions): latency percentiles and SLO scales. Unavailable:
/// per-request records, `windowed()` sub-reports.
///
/// [`RecordMode::Windowed`]: crate::simulator::RecordMode::Windowed
#[derive(Clone, Debug)]
pub struct WindowedAgg {
    pub completed: usize,
    pub total_output_tokens: usize,
    pub total_input_tokens: usize,
    latency_sum: f64,
    ttft_sum: f64,
    first_arrival: f64,
    last_completion: f64,
    latency_sketch: QuantileSketch,
    slo_sketch: QuantileSketch,
}

impl Default for WindowedAgg {
    fn default() -> WindowedAgg {
        WindowedAgg::new()
    }
}

impl WindowedAgg {
    pub fn new() -> WindowedAgg {
        WindowedAgg {
            completed: 0,
            total_output_tokens: 0,
            total_input_tokens: 0,
            latency_sum: 0.0,
            ttft_sum: 0.0,
            first_arrival: f64::INFINITY,
            last_completion: 0.0,
            latency_sketch: QuantileSketch::new(),
            slo_sketch: QuantileSketch::new(),
        }
    }

    /// Fold one completion in (the engine's per-finish hot path).
    pub fn push(&mut self, r: &RequestRecord) {
        self.completed += 1;
        self.total_output_tokens += r.output_len;
        self.total_input_tokens += r.input_len;
        self.latency_sum += r.latency();
        self.ttft_sum += r.ttft();
        self.first_arrival = self.first_arrival.min(r.arrival);
        self.last_completion = self.last_completion.max(r.completion);
        self.latency_sketch.push(r.latency());
        self.slo_sketch.push(r.latency() / r.slo_base);
    }

    /// First arrival → last completion; 0.0 when nothing completed.
    fn makespan(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            (self.last_completion - self.first_arrival).max(1e-9)
        }
    }

    fn mean_latency(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_sum / self.completed as f64
        }
    }

    fn mean_ttft(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.ttft_sum / self.completed as f64
        }
    }

    /// Sketch percentile (nearest-rank; exact below the centroid cap);
    /// 0.0 when nothing completed.
    fn latency_percentile(&self, p: f64) -> f64 {
        self.latency_sketch.quantile(p / 100.0)
    }

    /// Fraction of completions whose latency/base ratio is within `scale`
    /// (sketch CDF); 0.0 when nothing completed.
    fn attainment(&self, scale: f64) -> f64 {
        self.slo_sketch.le_fraction(scale)
    }
}

/// Aggregated simulation report.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub records: Vec<RequestRecord>,
    /// Wall-clock span of the simulation (first arrival → last completion).
    pub makespan: f64,
    pub total_output_tokens: usize,
    pub total_input_tokens: usize,
    /// Engine-level counters (memory pressure, rejections, link waits).
    pub stats: SimStats,
    /// The KV transfer engine's per-route load ledger (empty for reports
    /// built purely from records — windowed sub-reports, the live
    /// coordinator — and for colocated runs, which move no KV).
    pub link_loads: Vec<LinkLoad>,
    /// Flight-recorder trace of the run ([`SimConfig::trace`]; DESIGN.md
    /// §12). `None` when tracing was off.
    pub trace: Option<TraceLog>,
    /// Planner/rescheduler decision audit (attached by the deploy layer
    /// when `--audit` is on; empty otherwise).
    pub audit: Vec<AuditRecord>,
    /// Windowed accumulator the report was built from
    /// ([`RecordMode::Windowed`](crate::simulator::RecordMode::Windowed));
    /// `None` for full-record reports. When set, `records` is empty and
    /// every metric below reads the aggregate instead.
    pub agg: Option<WindowedAgg>,
    /// Critical-path latency attribution ([`SimConfig::attribution`]
    /// (crate::simulator::SimConfig::attribution); DESIGN.md §16). `None`
    /// when attribution was off.
    pub attr: Option<AttrReport>,
}

impl SimReport {
    pub fn from_records(records: Vec<RequestRecord>) -> SimReport {
        let first = records.iter().map(|r| r.arrival).fold(f64::INFINITY, f64::min);
        let last = records.iter().map(|r| r.completion).fold(0.0f64, f64::max);
        let makespan = if records.is_empty() { 0.0 } else { (last - first).max(1e-9) };
        let total_output_tokens = records.iter().map(|r| r.output_len).sum();
        let total_input_tokens = records.iter().map(|r| r.input_len).sum();
        SimReport {
            records,
            makespan,
            total_output_tokens,
            total_input_tokens,
            stats: SimStats::default(),
            link_loads: Vec::new(),
            trace: None,
            audit: Vec::new(),
            agg: None,
            attr: None,
        }
    }

    /// Build a report from a windowed accumulator (streaming runs). The
    /// 0-completion edge (everything rejected, or an empty trace) yields a
    /// well-formed all-zero report, never NaN.
    pub fn from_windowed(agg: WindowedAgg) -> SimReport {
        SimReport {
            records: Vec::new(),
            makespan: agg.makespan(),
            total_output_tokens: agg.total_output_tokens,
            total_input_tokens: agg.total_input_tokens,
            stats: SimStats::default(),
            link_loads: Vec::new(),
            trace: None,
            audit: Vec::new(),
            agg: Some(agg),
            attr: None,
        }
    }

    /// Completed-request count, mode-independent (use instead of
    /// `records.len()`, which is always 0 under windowed mode).
    pub fn completed(&self) -> usize {
        match &self.agg {
            Some(a) => a.completed,
            None => self.records.len(),
        }
    }

    /// The paper's offline metric: generated tokens per second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.completed() == 0 {
            return 0.0;
        }
        self.total_output_tokens as f64 / self.makespan
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.latency()).collect()
    }

    pub fn avg_latency(&self) -> f64 {
        match &self.agg {
            Some(a) => a.mean_latency(),
            None => stats::mean(&self.latencies()),
        }
    }

    pub fn p_latency(&self, p: f64) -> f64 {
        match &self.agg {
            Some(a) => a.latency_percentile(p),
            None => stats::percentile(&self.latencies(), p),
        }
    }

    pub fn avg_ttft(&self) -> f64 {
        match &self.agg {
            Some(a) => a.mean_ttft(),
            None => stats::mean(&self.records.iter().map(|r| r.ttft()).collect::<Vec<_>>()),
        }
    }

    /// SLO attainment at the given scale: fraction of requests whose
    /// end-to-end latency is within `scale` × their single-device base
    /// latency (§2 "SLO scale"). Bucket-approximate under windowed mode.
    pub fn slo_attainment(&self, scale: f64) -> f64 {
        if let Some(a) = &self.agg {
            return a.attainment(scale);
        }
        if self.records.is_empty() {
            return 0.0;
        }
        let ok = self
            .records
            .iter()
            .filter(|r| r.latency() <= scale * r.slo_base)
            .count();
        ok as f64 / self.records.len() as f64
    }

    /// Sub-report of the requests that *arrived* in `[t0, t1)` — used by the
    /// rescheduler case studies to compare per-phase service quality before
    /// and after a workload shift.
    ///
    /// Engine counters: when the parent report carries a flight-recorder
    /// trace, the sub-report's [`SimStats::mem_stalls`] and
    /// [`SimStats::kv_link_wait_s`] are reconstructed from events stamped
    /// in `[t0, t1)` (by *event* time — a stall or transfer enqueued in
    /// the window, regardless of when its request arrived). Without a
    /// trace the engine's scalar counters cannot be attributed to a
    /// window, so they stay zero — a documented limitation, not data.
    /// Unavailable under [`RecordMode::Windowed`]
    /// (`records` is empty, so every sub-report is empty).
    ///
    /// [`RecordMode::Windowed`]: crate::simulator::RecordMode::Windowed
    pub fn windowed(&self, t0: f64, t1: f64) -> SimReport {
        let mut w = SimReport::from_records(
            self.records.iter().filter(|r| r.arrival >= t0 && r.arrival < t1).copied().collect(),
        );
        if let Some(log) = &self.trace {
            w.stats.mem_stalls = log.mem_stalls_in(t0, t1);
            w.stats.kv_link_wait_s = log.kv_wait_in(t0, t1);
        }
        w
    }

    /// Smallest SLO scale achieving the given attainment (bisection over
    /// scales; the paper's Fig. 8 reports latency deadlines at 99%).
    pub fn slo_scale_for_attainment(&self, target: f64) -> f64 {
        let (mut lo, mut hi) = (0.1, 1000.0);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.slo_attainment(mid) >= target {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, arrival: f64, done: f64, out: usize, base: f64) -> RequestRecord {
        RequestRecord {
            id,
            arrival,
            prefill_done: arrival + 0.1,
            completion: done,
            input_len: 100,
            output_len: out,
            slo_base: base,
        }
    }

    #[test]
    fn throughput_counts_output_tokens() {
        let r = SimReport::from_records(vec![rec(0, 0.0, 10.0, 50, 1.0), rec(1, 0.0, 10.0, 50, 1.0)]);
        assert!((r.tokens_per_s() - 10.0).abs() < 1e-9);
        assert_eq!(r.total_output_tokens, 100);
    }

    #[test]
    fn slo_attainment_scales() {
        // latencies 1.0 and 3.0, bases 1.0.
        let r = SimReport::from_records(vec![rec(0, 0.0, 1.0, 10, 1.0), rec(1, 0.0, 3.0, 10, 1.0)]);
        assert_eq!(r.slo_attainment(0.5), 0.0);
        assert_eq!(r.slo_attainment(1.5), 0.5);
        assert_eq!(r.slo_attainment(3.5), 1.0);
        let s99 = r.slo_scale_for_attainment(0.99);
        assert!((s99 - 3.0).abs() < 0.01, "{s99}");
    }

    #[test]
    fn windowed_filters_by_arrival() {
        let r = SimReport::from_records(vec![
            rec(0, 0.0, 5.0, 10, 1.0),
            rec(1, 10.0, 15.0, 20, 1.0),
            rec(2, 20.0, 25.0, 30, 1.0),
        ]);
        let w = r.windowed(10.0, 20.0);
        assert_eq!(w.records.len(), 1);
        assert_eq!(w.records[0].id, 1);
        assert_eq!(w.total_output_tokens, 20);
        assert!(r.windowed(100.0, 200.0).records.is_empty());
    }

    #[test]
    fn empty_report() {
        let r = SimReport::from_records(vec![]);
        assert_eq!(r.tokens_per_s(), 0.0);
        assert_eq!(r.slo_attainment(1.0), 0.0);
    }

    #[test]
    fn windowed_agg_tracks_exact_sums_and_approximate_percentiles() {
        let recs = vec![
            rec(0, 0.0, 1.0, 10, 1.0),
            rec(1, 0.0, 2.0, 20, 1.0),
            rec(2, 0.0, 4.0, 30, 1.0),
            rec(3, 0.0, 8.0, 40, 1.0),
        ];
        let mut agg = WindowedAgg::new();
        for r in &recs {
            agg.push(r);
        }
        let full = SimReport::from_records(recs);
        let win = SimReport::from_windowed(agg);
        // Exact quantities match bit-for-bit.
        assert_eq!(win.completed(), full.completed());
        assert_eq!(win.total_output_tokens, full.total_output_tokens);
        assert_eq!(win.total_input_tokens, full.total_input_tokens);
        assert_eq!(win.makespan, full.makespan);
        assert_eq!(win.avg_latency(), full.avg_latency());
        assert_eq!(win.avg_ttft(), full.avg_ttft());
        // Below the sketch's centroid cap every insertion is a singleton
        // centroid, so percentiles are exact nearest-rank values:
        // p50→2.0, p75→4.0, p100→8.0.
        for (p, exact) in [(50.0, 2.0), (75.0, 4.0), (100.0, 8.0)] {
            let approx = win.p_latency(p);
            assert!((approx - exact).abs() < 1e-12, "p{p}: {approx} vs {exact}");
        }
        // SLO attainment: latencies/base 1,2,4,8 — at scale 3 exactly two
        // requests attain (exact at small n).
        let att = win.slo_attainment(3.0);
        assert!((att - 0.5).abs() < 1e-12, "{att}");
        // The bisection works off the aggregate too.
        let s99 = win.slo_scale_for_attainment(0.99);
        assert!(s99 >= 8.0 && s99 <= 8.0 * 1.01, "{s99}");
    }

    #[test]
    fn quantile_sketch_is_accurate_and_deterministic() {
        // 100k values from a deterministic skewed stream: quantiles land
        // within a fraction of a percent in *rank*, which for this smooth
        // distribution is well under 2% in value — a ~10x improvement on
        // the 13% log-bucket bound it replaced.
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        let mut vals = Vec::with_capacity(100_000);
        let mut rng = crate::util::rng::Rng::new(42);
        for _ in 0..100_000 {
            let v = rng.exp(1.0) * (1.0 + 9.0 * rng.f64());
            vals.push(v);
            a.push(v);
            b.push(v);
        }
        vals.sort_unstable_by(f64::total_cmp);
        for q in [0.5, 0.9, 0.99] {
            let exact = vals[((q * vals.len() as f64).ceil() as usize - 1).min(vals.len() - 1)];
            let approx = a.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.02, "q{q}: {approx} vs {exact} (rel {rel})");
        }
        // Same stream → bit-identical sketch state.
        for q in [0.1, 0.5, 0.9, 0.999] {
            assert_eq!(a.quantile(q).to_bits(), b.quantile(q).to_bits());
        }
        // Memory stays bounded (the merge bound admits ~2x the nominal
        // cap plus unmergeable tail singletons).
        assert!(a.centroids.len() <= 4 * SKETCH_COMPRESSION, "{}", a.centroids.len());
        // CDF is consistent with the quantile at the median.
        let med = a.quantile(0.5);
        let frac = a.le_fraction(med);
        assert!((frac - 0.5).abs() < 0.02, "{frac}");
        // Non-finite handling: NaN folds low, +inf folds astronomically high.
        let mut s = QuantileSketch::new();
        s.push(f64::NAN);
        s.push(f64::INFINITY);
        s.push(1.0);
        assert_eq!(s.quantile(0.0), 0.0);
        assert!(s.quantile(1.0) > 1e17);
    }

    #[test]
    fn empty_windowed_report_is_well_formed() {
        // The 0-completed edge (windowed mode + hard rejection of every
        // request) must yield zeros, not NaN or a panic.
        let r = SimReport::from_windowed(WindowedAgg::new());
        assert_eq!(r.completed(), 0);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.tokens_per_s(), 0.0);
        assert_eq!(r.avg_latency(), 0.0);
        assert_eq!(r.avg_ttft(), 0.0);
        assert_eq!(r.p_latency(99.0), 0.0);
        assert_eq!(r.slo_attainment(1.0), 0.0);
        assert!(r.avg_latency().is_finite());
        assert!(r.slo_scale_for_attainment(0.99).is_finite());
    }
}
