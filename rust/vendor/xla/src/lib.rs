//! Stub of the `xla` (PJRT) binding surface used by `runtime/engine.rs`.
//!
//! The offline build environment for this repo does not ship the
//! `xla_extension` native library, so this stub mirrors the API the runtime
//! layer calls and reports the backend as unavailable at *runtime* (every
//! entry point returns [`Error`]). The analytic layers — scheduler, cost
//! model, simulator, experiments, and the new rescheduler — never touch this
//! module; the live-serving paths (`coordinator`, `runtime`) detect missing
//! AOT artifacts before constructing a client and skip cleanly.
//!
//! Vendoring the real crate in place of this one re-enables the live path
//! with no source changes in `src/`.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("PJRT backend not available: built against the in-tree xla stub (vendor the real xla crate to enable the live path)".to_string())
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Device-resident buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
