//! `hexgen2` — CLI for the HexGen-2 reproduction.
//!
//! Subcommands:
//!   schedule     run the scheduling algorithm on a cluster setting
//!   reschedule   online rescheduling case study on a phased (drifting) trace
//!   simulate     simulate a system serving a workload on a setting
//!   attribute    critical-path latency attribution + bottleneck advisor
//!   serve        live disaggregated serving over the AOT artifacts
//!   workload     generate and dump a request trace (JSON)
//!   experiments  regenerate a paper figure/table by id
//!   settings     print the cluster settings (paper Fig. 4)
//!   check        hexcheck static analysis over rust/src (DESIGN.md §13)

use anyhow::{anyhow, bail, Result};

use hexgen2::cluster::settings;
use hexgen2::coordinator::{self, CoordinatorConfig, LiveRequest};
use hexgen2::deploy::{self, DeploymentSpec, Objective, ReschedBackend, SimBackend};
use hexgen2::experiments::{self, ExpOpts};
use hexgen2::model::LlmSpec;
use hexgen2::scheduler::SwapMode;
use hexgen2::simulator::SimReport;
use hexgen2::telemetry;
use hexgen2::util::args::Args;
use hexgen2::util::json;
use hexgen2::util::rng::Rng;
use hexgen2::workload::{Trace, TraceSource, WorkloadKind};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &argv,
        &[
            "quick",
            "full",
            "verbose",
            "no-refine",
            "json",
            "resched",
            "no-eval-cache",
            "contention-aware",
            "update-baseline",
            "hierarchical",
            "windowed",
            "prefix-hit-aware",
        ],
    );
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn cluster_of(args: &Args) -> Result<hexgen2::cluster::Cluster> {
    let name = args.get_or("setting", "het1");
    settings::by_name(name)
        .ok_or_else(|| anyhow!("unknown setting {name} (try: {:?})", settings::PAPER_SETTINGS))
}

fn model_of(args: &Args) -> Result<LlmSpec> {
    let name = args.get_or("model", "llama2-70b");
    LlmSpec::by_name(name).ok_or_else(|| anyhow!("unknown model {name}"))
}

fn workload_of(args: &Args) -> Result<WorkloadKind> {
    let name = args.get_or("workload", "online");
    WorkloadKind::from_name(name).ok_or_else(|| anyhow!("unknown workload {name}"))
}

fn objective_of(args: &Args) -> Result<Objective> {
    let name = args.get_or("objective", "throughput");
    Objective::from_name(name).ok_or_else(|| {
        anyhow!("unknown objective {name} (try: throughput | slo-goodput[:SCALE] | mean-latency | cost-per-token)")
    })
}

/// Build the deployment spec shared by `schedule` and `simulate`.
fn spec_of(args: &Args) -> Result<DeploymentSpec> {
    // `--chunked-prefill` is the canonical flag (it now applies to
    // disaggregated prefill replicas too); `--chunk` stays as an alias.
    let chunk = args
        .get("chunked-prefill")
        .or_else(|| args.get("chunk"))
        .and_then(|c| c.parse().ok());
    let mut spec = DeploymentSpec::new(cluster_of(args)?, model_of(args)?)
        .workload(workload_of(args)?)
        .objective(objective_of(args)?)
        .seed(args.get_u64("seed", 0))
        .quick(args.has("quick"))
        .threads(args.get_usize("threads", 1))
        .eval_cache(!args.has("no-eval-cache"))
        .chunked_prefill(chunk);
    match args.get_or("admission", "static") {
        "static" | "mean" => {}
        "per-request" | "per_request" | "perreq" => {
            spec = spec.admission(hexgen2::simulator::Sizing::PerRequest);
        }
        other => bail!("unknown admission model {other} (try: static | per-request)"),
    }
    // KV transfer engine knobs (DESIGN.md §11).
    if let Some(l) = args.get("link") {
        let link = hexgen2::kvtransfer::LinkModel::from_name(l)
            .ok_or_else(|| anyhow!("unknown link model {l} (try: per-route | shared-nic)"))?;
        spec = spec.link(link);
    }
    if let Some(r) = args.get("kv-route") {
        let route = hexgen2::kvtransfer::RouteModel::from_name(r).ok_or_else(|| {
            anyhow!("unknown KV route model {r} (try: flow | least-loaded | eta-greedy)")
        })?;
        spec = spec.kv_route(route);
    }
    if let Some(c) = args.get("kv-chunk-layers") {
        let layers: usize = c
            .parse()
            .ok()
            .filter(|&x| x > 0)
            .ok_or_else(|| anyhow!("--kv-chunk-layers needs a positive layer count, got {c}"))?;
        spec = spec.kv_chunk_layers(Some(layers));
    }
    spec = spec.contention_aware(args.has("contention-aware"));
    // Prefix KV reuse (DESIGN.md §15): --prefix-share overrides the
    // workload class's reusable-prefix fraction (0 disables the pool);
    // --prefix-hit-aware lets the planner discount expected prefill demand
    // by the workload's expected hit rate.
    if let Some(s) = args.get("prefix-share") {
        let share: f64 = s
            .parse()
            .ok()
            .filter(|x: &f64| (0.0..=1.0).contains(x))
            .ok_or_else(|| anyhow!("--prefix-share needs a fraction in [0, 1], got {s}"))?;
        spec = spec.prefix_share(Some(share));
    }
    spec = spec.prefix_hit_aware(args.has("prefix-hit-aware"));
    // Flight recorder (DESIGN.md §12): --trace FILE / --prom FILE enable
    // event recording; --audit FILE enables planner decision capture;
    // --attribution FILE folds critical-path blame vectors out of the same
    // event stream (DESIGN.md §16).
    if args.get("trace").is_some() || args.get("prom").is_some() {
        spec = spec.trace(true);
    }
    if args.get("attribution").is_some() {
        spec = spec.attribution(true);
    }
    if let Some(r) = args.get("trace-sample") {
        let rate: f64 = r
            .parse()
            .ok()
            .filter(|x: &f64| (0.0..=1.0).contains(x))
            .ok_or_else(|| anyhow!("--trace-sample needs a rate in [0, 1], got {r}"))?;
        spec = spec.trace_sample(rate);
    }
    if args.get("audit").is_some() {
        spec = spec.audit(true);
    }
    if let Some(r) = args.get("rounds").and_then(|s| s.parse().ok()) {
        spec = spec.max_rounds(r);
    }
    if args.has("no-refine") {
        spec = spec.swap_mode(SwapMode::None);
    }
    // Hierarchical zone planning: bare --hierarchical auto-sizes zones
    // (~32 devices each); --hierarchical=N pins the zone count.
    if let Some(z) = args.get("hierarchical") {
        let zones: usize = z
            .parse()
            .map_err(|_| anyhow!("--hierarchical needs a zone count, got {z}"))?;
        spec = spec.hierarchical(Some(zones));
    } else if args.has("hierarchical") {
        spec = spec.hierarchical(Some(0));
    }
    spec = spec.windowed(args.has("windowed"));
    Ok(spec)
}

/// Resolve the planner: `--planner` wins; `--system` and `--algorithm` are
/// kept as aliases (`--algorithm random` selects the random-swap refinement
/// variant of the hexgen2 planner).
fn planner_of(args: &Args, spec: &mut DeploymentSpec) -> Result<&'static dyn deploy::Planner> {
    let name = match args.get("planner").or_else(|| args.get("system")) {
        Some(n) => n.to_string(),
        None => match args.get_or("algorithm", "ours") {
            "ours" => "hexgen2".to_string(),
            "random" => {
                spec.swap_mode = SwapMode::Random;
                "hexgen2".to_string()
            }
            other => other.to_string(),
        },
    };
    deploy::planner_by_name(&name)
        .ok_or_else(|| anyhow!("unknown planner {name} (try: hexgen2 | hexgen | distserve | vllm | genetic)"))
}

fn print_report(label: &str, rep: &SimReport) {
    println!(
        "{label}: {} requests, {:.0} tokens/s, avg latency {:.2}s, p95 {:.2}s, TTFT {:.2}s, SLO@99 scale {:.1}",
        rep.completed(),
        rep.tokens_per_s(),
        rep.avg_latency(),
        rep.p_latency(95.0),
        rep.avg_ttft(),
        rep.slo_scale_for_attainment(0.99),
    );
}

/// Parse the phased-trace syntax `KIND:RATE:DURATION[,KIND:RATE:DURATION...]`
/// (e.g. `LPHD:2.5:300,HPLD:2.5:600`): per phase, the workload class, the
/// Poisson arrival rate in req/s, and the phase duration in seconds.
fn parse_phases(s: &str) -> Result<Vec<(WorkloadKind, f64, f64)>> {
    s.split(',')
        .map(|p| {
            let parts: Vec<&str> = p.split(':').collect();
            if parts.len() != 3 {
                bail!("phase must be KIND:RATE:DURATION, got '{p}'");
            }
            let kind = WorkloadKind::from_name(parts[0])
                .ok_or_else(|| anyhow!("unknown workload '{}'", parts[0]))?;
            let rate: f64 =
                parts[1].parse().map_err(|_| anyhow!("bad rate '{}'", parts[1]))?;
            let dur: f64 =
                parts[2].parse().map_err(|_| anyhow!("bad duration '{}'", parts[2]))?;
            if !(rate > 0.0 && rate.is_finite()) || !(dur > 0.0 && dur.is_finite()) {
                bail!("rate and duration must be positive finite numbers in '{p}'");
            }
            Ok((kind, rate, dur))
        })
        .collect()
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "schedule" => {
            let mut spec = spec_of(args)?;
            let planner = planner_of(args, &mut spec)?;
            let dep = spec.plan(planner)?;
            if let Some(path) = args.get("audit") {
                let mut body = telemetry::audit_json(&dep.plan.audit).to_string_pretty();
                body.push('\n');
                std::fs::write(path, body).map_err(|e| anyhow!("writing {path}: {e}"))?;
                if !args.has("json") {
                    println!("wrote {} audit records to {path}", dep.plan.audit.len());
                }
            }
            if args.has("json") {
                println!("{}", dep.plan_json().to_string_pretty());
                return Ok(());
            }
            println!(
                "planned {} on {} with {} (objective {}) in {:.2}s: est {:.0} tokens/s, score {:.4}",
                dep.spec.model.name,
                dep.spec.cluster.name,
                planner.display_name(),
                dep.spec.objective.name(),
                dep.plan.elapsed_s,
                dep.plan.est_tokens_per_s,
                dep.plan.objective_score,
            );
            println!("{}", dep.describe());
            let st = &dep.plan.stats;
            if st.evals + st.eval_cache_hits > 0 {
                println!(
                    "search: {} evaluations executed, {} served from cache ({:.0}% hit rate), \
                     {} unique partitions explored, {} thread(s)",
                    st.evals,
                    st.eval_cache_hits,
                    st.hit_rate() * 100.0,
                    st.partitions_explored,
                    st.threads.max(1),
                );
            }
            if args.has("verbose") && !dep.plan.history.is_empty() {
                println!("convergence:");
                for p in &dep.plan.history {
                    println!(
                        "  t={:.2}s round={} est={:.0} tok/s score={:.4}",
                        p.elapsed_s, p.round, p.tokens_per_s, p.score
                    );
                }
            }
        }
        "reschedule" => {
            let cluster = cluster_of(args)?;
            let model = model_of(args)?;
            let opts = ExpOpts {
                quick: !args.has("full"),
                seed: args.get_u64("seed", 0),
            };
            let spec = match args.get("phases") {
                Some(s) => parse_phases(s)?,
                None => experiments::resched::default_phases(&cluster, &model, &opts)
                    .ok_or_else(|| anyhow!("no feasible placement on {}", cluster.name))?,
            };
            if spec.len() < 2 {
                bail!("need at least two phases (see --phases syntax in help)");
            }
            println!(
                "rescheduling case study on {} / {}: {}",
                cluster.name,
                model.name,
                spec.iter()
                    .map(|(k, r, d)| format!("{}@{r:.2}req/s x{d:.0}s", k.name()))
                    .collect::<Vec<_>>()
                    .join(" -> ")
            );
            let cs = experiments::resched::case_resched(&cluster, &model, &spec, &opts)
                .ok_or_else(|| anyhow!("static scheduling failed on {}", cluster.name))?;
            cs.table.print("Rescheduling case study: per-phase throughput");
            experiments::resched::print_summary(&cs);
        }
        "simulate" => {
            let mut spec = spec_of(args)?;
            let planner = planner_of(args, &mut spec)?;
            let kind = spec.workload;
            let seed = spec.seed;
            let n = args.get_usize("requests", 100);
            let json_out = args.has("json");
            let src = if kind == WorkloadKind::Online {
                let opts = ExpOpts { quick: true, seed };
                let rate = args
                    .get("rate")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or_else(|| experiments::online_rate(&spec.cluster, &spec.model, &opts));
                if !json_out {
                    println!("online rate: {rate:.2} req/s");
                }
                TraceSource::online(kind, rate, args.get_f64("duration", 120.0), seed)
            } else {
                TraceSource::offline(kind, n, seed)
            };
            let trace = match spec.prefix_share {
                Some(share) => Trace::from_source(src.with_prefix_share(share)),
                None => Trace::from_source(src),
            };
            let dep = spec.plan(planner)?;
            if !json_out {
                println!("plan:\n{}", dep.describe());
            }
            let rep = if args.has("resched") {
                dep.run(&ReschedBackend::default(), &trace)?
            } else {
                dep.run(&SimBackend, &trace)?
            };
            // Flight-recorder exports (DESIGN.md §12).
            if let Some(path) = args.get("trace") {
                let log = rep
                    .trace
                    .as_ref()
                    .ok_or_else(|| anyhow!("--trace requested but the run produced no trace"))?;
                let mut body = telemetry::chrome_trace(log).to_string_pretty();
                body.push('\n');
                std::fs::write(path, body).map_err(|e| anyhow!("writing {path}: {e}"))?;
                if !json_out {
                    println!(
                        "wrote {} trace events to {path} (Perfetto: ui.perfetto.dev)",
                        log.events.len()
                    );
                }
            }
            if let Some(path) = args.get("prom") {
                let log = rep
                    .trace
                    .as_ref()
                    .ok_or_else(|| anyhow!("--prom requested but the run produced no trace"))?;
                let body = telemetry::prometheus_dump(log, args.get_f64("prom-window", 60.0));
                std::fs::write(path, body).map_err(|e| anyhow!("writing {path}: {e}"))?;
                if !json_out {
                    println!("wrote Prometheus text dump to {path}");
                }
            }
            if let Some(path) = args.get("audit") {
                let mut records = dep.plan.audit.clone();
                records.extend(rep.audit.iter().cloned());
                let mut body = telemetry::audit_json(&records).to_string_pretty();
                body.push('\n');
                std::fs::write(path, body).map_err(|e| anyhow!("writing {path}: {e}"))?;
                if !json_out {
                    println!("wrote {} audit records to {path}", records.len());
                }
            }
            if let Some(path) = args.get("attribution") {
                let attr = rep.attr.as_ref().ok_or_else(|| {
                    anyhow!("--attribution requested but the run produced no attribution report")
                })?;
                let ctx = dep.advisor_ctx();
                let advice = telemetry::advise(attr, ctx.as_ref());
                let mut body = telemetry::attr_json(attr, &advice).to_string_pretty();
                body.push('\n');
                std::fs::write(path, body).map_err(|e| anyhow!("writing {path}: {e}"))?;
                if !json_out {
                    println!(
                        "wrote attribution report ({} requests, dominant: {}) to {path}",
                        attr.n,
                        attr.dominant_name()
                    );
                }
            }
            if json_out {
                println!("{}", dep.report_json(&rep).to_string_pretty());
            } else {
                print_report(
                    &format!(
                        "{} on {} ({}, objective {})",
                        planner.name(),
                        dep.spec.cluster.name,
                        kind.name(),
                        dep.spec.objective.name()
                    ),
                    &rep,
                );
            }
        }
        "attribute" => {
            // Plan + run with critical-path attribution on, then print the
            // ranked bottleneck report (DESIGN.md §16). `--out FILE` writes
            // the hexgen2-attr/v1 JSON; `--json` prints it instead of the
            // human-readable ranking.
            let mut spec = spec_of(args)?.attribution(true);
            let planner = planner_of(args, &mut spec)?;
            let kind = spec.workload;
            let seed = spec.seed;
            let json_out = args.has("json");
            let src = if kind == WorkloadKind::Online {
                let opts = ExpOpts { quick: true, seed };
                let rate = args
                    .get("rate")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or_else(|| experiments::online_rate(&spec.cluster, &spec.model, &opts));
                TraceSource::online(kind, rate, args.get_f64("duration", 120.0), seed)
            } else {
                TraceSource::offline(kind, args.get_usize("requests", 100), seed)
            };
            let trace = match spec.prefix_share {
                Some(share) => Trace::from_source(src.with_prefix_share(share)),
                None => Trace::from_source(src),
            };
            let dep = spec.plan(planner)?;
            let rep = if args.has("resched") {
                dep.run(&ReschedBackend::default(), &trace)?
            } else {
                dep.run(&SimBackend, &trace)?
            };
            let attr = rep
                .attr
                .as_ref()
                .ok_or_else(|| anyhow!("attribution run produced no report"))?;
            let ctx = dep.advisor_ctx();
            let advice = telemetry::advise(attr, ctx.as_ref());
            let out = telemetry::attr_json(attr, &advice);
            if let Some(path) = args.get("out") {
                let mut body = out.to_string_pretty();
                body.push('\n');
                std::fs::write(path, body).map_err(|e| anyhow!("writing {path}: {e}"))?;
                if !json_out {
                    println!("wrote attribution report to {path}");
                }
            }
            if json_out {
                println!("{}", out.to_string_pretty());
            } else {
                println!(
                    "critical-path attribution on {} / {} ({}): {} requests, \
                     {:.1}s total latency attributed, residual {:.3e}s, {} in flight at end",
                    dep.spec.cluster.name,
                    dep.spec.model.name,
                    kind.name(),
                    attr.n,
                    attr.latency_sum,
                    attr.residual_s(),
                    attr.open_at_end,
                );
                println!("what to fix next (blame-ranked, priced against planner levers):");
                for (rank, a) in advice.iter().enumerate() {
                    let priced = if ctx.is_some() {
                        format!(
                            ", score {:.4} -> {:.4} ({:+.4})",
                            a.baseline_score,
                            a.predicted_score,
                            a.gain()
                        )
                    } else {
                        String::new()
                    };
                    println!(
                        "  #{} {:<18} {:>9.2}s ({:>4.1}%)  lever: {}{}",
                        rank + 1,
                        a.component_name(),
                        a.blame_s,
                        a.share * 100.0,
                        a.lever,
                        priced,
                    );
                }
                if !attr.per_nic.is_empty() {
                    println!("per-NIC KV blame (src replica: serialize wait / transmit):");
                    for (nic, (w, x)) in &attr.per_nic {
                        println!("  nic {nic}: {w:.2}s / {x:.2}s");
                    }
                }
            }
        }
        "serve" => {
            let mut cfg = CoordinatorConfig::new(args.get_or("model", "tiny"));
            cfg.n_prefill = args.get_usize("prefill", 2);
            cfg.n_decode = args.get_usize("decode", 1);
            if let Some(bw) = args.get("throttle-mbps").and_then(|s| s.parse::<f64>().ok()) {
                cfg.kv_throttle = Some(coordinator::KvThrottle { bytes_per_s: bw * 1e6 / 8.0 });
            }
            let n = args.get_usize("requests", 16);
            let seed = args.get_u64("seed", 0);
            let mut rng = Rng::new(seed);
            let manifests = hexgen2::runtime::load_manifests(&cfg.artifacts)?;
            let mm = manifests
                .get(&cfg.model)
                .ok_or_else(|| anyhow!("model {} not in artifacts", cfg.model))?;
            let max_prompt = mm.prefill_modules().map(|m| m.seq).max().unwrap_or(64);
            let vocab = mm.config.vocab;
            let reqs: Vec<LiveRequest> = (0..n)
                .map(|id| {
                    let len = rng.range(8, max_prompt.min(mm.config.max_seq / 2));
                    LiveRequest {
                        id,
                        tokens: (0..len).map(|_| rng.range(0, vocab) as i32).collect(),
                        output_len: rng.range(4, 24),
                    }
                })
                .collect();
            let total_in: usize = reqs.iter().map(|r| r.tokens.len()).sum();
            println!(
                "serving {n} requests ({total_in} prompt tokens) on {} prefill + {} decode workers...",
                cfg.n_prefill, cfg.n_decode
            );
            let rep = coordinator::serve(&cfg, reqs)?;
            print_report("live", &rep.report);
            println!(
                "kv transferred: {:.1} MiB total; wall {:.2}s (incl. module compile)",
                rep.kv_bytes_total as f64 / (1 << 20) as f64,
                rep.elapsed_s
            );
            if args.has("verbose") {
                for (id, toks) in rep.outputs.iter().take(4) {
                    println!("  req {id}: {toks:?}");
                }
            }
        }
        "workload" => {
            let kind = workload_of(args)?;
            let n = args.get_usize("n", 10);
            let src = if kind == WorkloadKind::Online {
                TraceSource::online(
                    kind,
                    args.get_f64("rate", 2.0),
                    args.get_f64("duration", 30.0),
                    args.get_u64("seed", 0),
                )
            } else {
                TraceSource::offline(kind, n, args.get_u64("seed", 0))
            };
            let src = match args.get("prefix-share").and_then(|s| s.parse().ok()) {
                Some(share) => src.with_prefix_share(share),
                None => src,
            };
            let trace = Trace::from_source(src);
            let rows: Vec<json::Json> = trace
                .requests
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("id", json::num(r.id as f64)),
                        ("arrival", json::num(r.arrival)),
                        ("input_len", json::num(r.input_len as f64)),
                        ("output_len", json::num(r.output_len as f64)),
                    ];
                    if let Some(px) = r.prefix {
                        fields.push(("prefix_id", json::num(px.id as f64)));
                        fields.push(("prefix_len", json::num(px.len as f64)));
                    }
                    json::obj(fields)
                })
                .collect();
            println!("{}", json::arr(rows).to_string_pretty());
        }
        "bench" => {
            let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("planner");
            let quick = args.has("quick") || !args.has("full");
            match what {
                "planner" => {
                    let j = experiments::perf::bench_planner(quick, args.get_usize("threads", 2));
                    std::fs::write("BENCH_planner.json", j.to_string_pretty())
                        .map_err(|e| anyhow!("writing BENCH_planner.json: {e}"))?;
                    println!("wrote BENCH_planner.json");
                }
                "sim" => {
                    let n = args.get("requests").and_then(|s| s.parse().ok());
                    let j = experiments::perf::bench_sim(quick, n);
                    std::fs::write("BENCH_sim.json", j.to_string_pretty())
                        .map_err(|e| anyhow!("writing BENCH_sim.json: {e}"))?;
                    println!("wrote BENCH_sim.json");
                }
                other => bail!("unknown bench target {other} (try: planner | sim)"),
            }
        }
        "experiments" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
            let opts = if args.has("full") { ExpOpts::full() } else { ExpOpts::from_env() };
            run_experiment(id, &opts, args)?;
        }
        "settings" => {
            for name in settings::PAPER_SETTINGS {
                let c = settings::by_name(name).unwrap();
                println!("{}", c.bandwidth_matrix_gbps());
            }
        }
        "check" => run_check(args)?,
        _ => {
            println!(
                "hexgen2 — disaggregated LLM inference over heterogeneous GPUs (ICLR'25 reproduction)\n\n\
                 usage: hexgen2 <command> [options]\n\n\
                 Every planning command goes through the unified deploy API: pick a --planner\n\
                 (which system decides the placement) and an --objective (what it optimizes).\n\n\
                 \x20 --planner    hexgen2 | hexgen | distserve | vllm | genetic  (default hexgen2)\n\
                 \x20 --objective  throughput | slo-goodput[:SCALE] | mean-latency | cost-per-token\n\
                 \x20              (default throughput — the paper's §3 max-flow objective)\n\n\
                 commands:\n\
                 \x20 schedule    --setting het1 --model llama2-70b --workload online [--planner P]\n\
                 \x20             [--objective O] [--no-refine] [--rounds N] [--threads N]\n\
                 \x20             [--hierarchical[=ZONES]] [--no-eval-cache] [--audit FILE] [--json] [--verbose]\n\
                 \x20             plan only: print the placement (Table-2 style) or a JSON report.\n\
                 \x20             --threads fans candidate evaluation over worker threads (plans are\n\
                 \x20             bit-identical to sequential); --no-eval-cache disables evaluation\n\
                 \x20             memoization (A/B perf baseline, same plans). --hierarchical cuts the\n\
                 \x20             cluster into bandwidth-coherent zones (~32 devices each, or =ZONES),\n\
                 \x20             plans zones independently in parallel, and stitches with a top-level\n\
                 \x20             max-flow — planner time scales with zone size, not cluster size.\n\
                 \x20 reschedule  --setting case_study --model opt30b [--phases SPEC] [--seed N] [--full]\n\
                 \x20             online rescheduling case study on a phased (drifting) trace: detects every\n\
                 \x20             sustained workload shift, warm-starts re-plans from the incumbent placement,\n\
                 \x20             prices each migration, and compares static vs rescheduled per-phase\n\
                 \x20             throughput. Oscillating traces are handled; the hysteresis bounds the\n\
                 \x20             switch count.\n\
                 \x20             SPEC is KIND:RATE:DURATION[,KIND:RATE:DURATION...] — per phase, the workload\n\
                 \x20             class (HPLD|HPHD|LPHD|LPLD|online), Poisson rate in req/s, and seconds,\n\
                 \x20             e.g. --phases LPHD:2.5:300,HPLD:2.5:600,LPHD:2.5:300. Default: LPHD->HPLD\n\
                 \x20             at 75% of the static placement's estimated peak.\n\
                 \x20 simulate    --setting het1 --model opt-30b --workload hphd [--planner P] [--objective O]\n\
                 \x20             [--requests N] [--resched] [--json] [--chunked-prefill TOKENS]\n\
                 \x20             [--admission static|per-request] [--link per-route|shared-nic]\n\
                 \x20             [--kv-route flow|least-loaded|eta-greedy] [--kv-chunk-layers N]\n\
                 \x20             [--contention-aware] [--trace FILE] [--trace-sample RATE]\n\
                 \x20             [--audit FILE] [--prom FILE] [--prom-window SECONDS]\n\
                 \x20             [--attribution FILE] [--prefix-share F] [--prefix-hit-aware]\n\
                 \x20             plan + run on the unified discrete-event simulator (--resched enables the\n\
                 \x20             online rescheduling loop mid-trace; --chunked-prefill chunks prompts on\n\
                 \x20             both colocated and disaggregated prefill replicas; per-request admission\n\
                 \x20             charges actual request lengths against replica memory and reports\n\
                 \x20             mem_stalls/unserved — pair it with --workload heavy_tail).\n\
                 \x20             KV transfer engine knobs: --link picks the fabric contention model\n\
                 \x20             (shared-nic serializes every transfer leaving a prefill replica on its\n\
                 \x20             egress NIC); --kv-route picks how each transfer chooses among its\n\
                 \x20             max-flow routes (flow = paper \u{a7}3.3 proportional, least-loaded routes\n\
                 \x20             around backlogged links, eta-greedy minimizes predicted KV arrival);\n\
                 \x20             --kv-chunk-layers N ships the cache in N-layer chunks pipelined with the\n\
                 \x20             producing prefill burst; --contention-aware makes the *planner* rank\n\
                 \x20             candidate placements under predicted NIC load for the chosen --link\n\
                 \x20             (also applies to `schedule`). The --json report carries the transfer\n\
                 \x20             ledger (kv_transfers, kv_bytes, kv_max_nic_util, kv_link_wait_s).\n\
                 \x20             Flight recorder (DESIGN.md \u{a7}12): --trace FILE writes a Chrome\n\
                 \x20             trace-event JSON of every request's lifecycle (open in\n\
                 \x20             ui.perfetto.dev — one lane per replica + per KV link);\n\
                 \x20             --trace-sample R keeps a deterministic R fraction of requests;\n\
                 \x20             --audit FILE writes the planner/rescheduler decision audit (per-\n\
                 \x20             candidate score breakdowns, drift events, migration-gate pricing);\n\
                 \x20             --prom FILE writes Prometheus-style windowed counters plus\n\
                 \x20             p50/p95/p99 TTFT/TBT/latency summaries and the KV queue-wait\n\
                 \x20             histogram (--prom-window seconds per window, default 60). With\n\
                 \x20             tracing on, the --json report gains per-request span summaries.\n\
                 \x20             --attribution FILE writes the critical-path blame report\n\
                 \x20             (hexgen2-attr/v1, DESIGN.md \u{a7}16): per-request latency decomposed\n\
                 \x20             into admission / prefill / KV-transfer / decode components that\n\
                 \x20             sum bit-exactly to the measured latency, aggregated per replica,\n\
                 \x20             per KV route/NIC, and per window, with the ranked bottleneck\n\
                 \x20             advisor (also embedded in the --json report).\n\
                 \x20             --windowed streams metrics through an O(1) accumulator instead of\n\
                 \x20             per-request records (million-request runs in bounded memory; exact\n\
                 \x20             means/throughput, t-digest percentiles ≲2% relative error).\n\
                 \x20             Prefix KV reuse (DESIGN.md \u{a7}15): --workload prefix_chat|rag|agent\n\
                 \x20             draws Zipf-distributed hot shared prefixes (system prompts, RAG\n\
                 \x20             documents, agent histories); the engine keeps a cluster-wide prefix\n\
                 \x20             pool on the prefill replicas — a hit prefills only the suffix, a\n\
                 \x20             host-tier hit pays a PCIe re-load, a miss publishes for later reuse.\n\
                 \x20             --prefix-share F overrides the class's reusable fraction (0 disables\n\
                 \x20             the pool bit-identically to the legacy engine; arrivals/lengths are\n\
                 \x20             unchanged across a share sweep); --prefix-hit-aware lets the planner\n\
                 \x20             discount expected prefill demand by the expected hit rate, shifting\n\
                 \x20             the optimal partition decode-heavy (also applies to `schedule`).\n\
                 \x20             The --json report carries prefix_{hits,host_hits,misses,hit_rate,\n\
                 \x20             reused_tokens,published_tokens,spilled_tokens,evicted_tokens,reload_s}.\n\
                 \x20 attribute   --setting het1 --model opt-30b --workload hphd [--planner P] [--objective O]\n\
                 \x20             [--requests N] [--resched] [--windowed] [--out FILE] [--json]\n\
                 \x20             (accepts every `simulate` engine knob)\n\
                 \x20             run with critical-path attribution on and print the cluster\n\
                 \x20             bottleneck report: blame-ranked components, each priced against\n\
                 \x20             the planner lever that attacks it (shift the P:D split, add KV\n\
                 \x20             bandwidth, raise the chunk size) by re-scoring the incumbent\n\
                 \x20             partition with that capacity perturbed. --out writes the\n\
                 \x20             hexgen2-attr/v1 JSON; --windowed streams attribution in O(active)\n\
                 \x20             memory for million-request runs.\n\
                 \x20 serve       --model tiny --requests 16 --prefill 2 --decode 1 [--throttle-mbps N] [--verbose]\n\
                 \x20 workload    --workload hpld --n 10 [--prefix-share F]\n\
                 \x20             (classes: HPLD|HPHD|LPHD|LPLD|online|heavy_tail|prefix_chat|rag|agent)\n\
                 \x20 bench       planner|sim [--full] [--threads N] [--requests N]\n\
                 \x20             perf-regression harness (DESIGN.md \u{a7}10): replays the \u{a7}3.3 serving-loop\n\
                 \x20             planning workload cached vs uncached vs threaded and writes\n\
                 \x20             BENCH_planner.json / BENCH_sim.json (counter-based: evals, cache hit\n\
                 \x20             rate, partitions explored — deterministic where wall-time is not).\n\
                 \x20             bench sim also streams a windowed online trace (--requests, default\n\
                 \x20             100k quick / 1M full) for the events/sec @ 1M headline.\n\
                 \x20 experiments <fig1|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|table2|table3|table4|table5|table5h|appd|heavy_tail|kv_routing|prefix_reuse|all> [--full]\n\
                 \x20 settings    print bandwidth matrices (paper Fig. 4)\n\
                 \x20 check       [--src DIR] [--baseline FILE] [--json] [--update-baseline]\n\
                 \x20             hexcheck static analysis (DESIGN.md \u{a7}13): determinism (D1/D2/F1),\n\
                 \x20             panic hygiene (P1), and lock ordering (L1) over the crate source.\n\
                 \x20             Suppress a finding inline with `// hexcheck: allow(RULE) -- reason`;\n\
                 \x20             ratcheted debt lives in hexcheck-baseline.json and can only shrink\n\
                 \x20             (--update-baseline rewrites it after paying debt down). Exits\n\
                 \x20             nonzero on any new finding — CI runs this with --json."
            );
        }
    }
    Ok(())
}

/// `hexgen2 check`: run hexcheck over the crate source and gate against
/// the ratchet baseline (DESIGN.md §13).
fn run_check(args: &Args) -> Result<()> {
    use hexgen2::analysis::{self, baseline::Baseline};
    use std::path::{Path, PathBuf};

    // Default source root: `src/` when run from rust/ (CI), else
    // `rust/src/` from the repo root.
    let src_root: PathBuf = match args.get("src") {
        Some(p) => PathBuf::from(p),
        None if Path::new("src/lib.rs").exists() => PathBuf::from("src"),
        None => PathBuf::from("rust/src"),
    };
    if !src_root.is_dir() {
        bail!("source root {} not found (use --src DIR)", src_root.display());
    }
    let baseline_path: PathBuf = match args.get("baseline") {
        Some(p) => PathBuf::from(p),
        // hexcheck-baseline.json lives next to Cargo.toml, one level
        // above the source root.
        None => src_root
            .parent()
            .unwrap_or(Path::new("."))
            .join("hexcheck-baseline.json"),
    };

    let files = analysis::load_tree(&src_root)
        .map_err(|e| anyhow!("reading {}: {e}", src_root.display()))?;
    if files.is_empty() {
        bail!("no .rs files under {}", src_root.display());
    }
    let report = analysis::check_files(&files);

    if args.has("update-baseline") {
        let base = Baseline::from_findings(&report.findings);
        let mut body = base.to_json().to_string_pretty();
        body.push('\n');
        std::fs::write(&baseline_path, body)
            .map_err(|e| anyhow!("writing {}: {e}", baseline_path.display()))?;
        println!(
            "wrote {} ({} ratchet buckets from {} findings)",
            baseline_path.display(),
            base.counts.len(),
            report.findings.len(),
        );
        return Ok(());
    }

    let base = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => Baseline::parse(&text).map_err(|e| anyhow!("{}: {e}", baseline_path.display()))?,
        Err(_) => Baseline::default(),
    };
    let gate = analysis::baseline::gate(&report.findings, &base);

    if args.has("json") {
        println!("{}", analysis::report_json(&report, &gate).to_string_pretty());
    } else {
        println!(
            "hexcheck: {} file(s), {} finding(s) ({} suppressed, {} unused allow(s)), {} lock edge(s)",
            files.len(),
            report.findings.len(),
            report.suppressed.len(),
            report.unused_allows.len(),
            report.lock_edges.len(),
        );
        for f in &report.findings {
            println!("  {} {}:{} [{}] {}", f.rule, f.file, f.line, f.module, f.msg);
            if !f.snippet.is_empty() {
                println!("      {}", f.snippet);
            }
        }
        for (file, line, rule) in &report.unused_allows {
            println!("  note: unused allow({rule}) at {file}:{line} — delete it");
        }
        for g in &gate.shrinkable {
            println!(
                "  note: {}/{} debt shrank {} -> {} — run `hexgen2 check --update-baseline` to ratchet",
                g.rule, g.module, g.allowed, g.count
            );
        }
    }
    if !gate.ok() {
        let buckets: Vec<String> = gate
            .failures
            .iter()
            .map(|g| {
                format!(
                    "{}/{}: {} finding(s), {} allowed{}",
                    g.rule,
                    g.module,
                    g.count,
                    g.allowed,
                    if g.deny { " (deny)" } else { "" }
                )
            })
            .collect();
        bail!("hexcheck gate failed — {}", buckets.join("; "));
    }
    Ok(())
}

fn run_experiment(id: &str, opts: &ExpOpts, args: &Args) -> Result<()> {
    use hexgen2::experiments::{batching, convergence, endtoend, tables};
    use hexgen2::model::{LLAMA2_70B, OPT_30B};
    let het_all = ["het1", "het2", "het3", "het4"];
    let het_quick = ["het1", "het4"];
    let hets: &[&str] = if opts.quick { &het_quick } else { &het_all };
    match id {
        "list" => {
            println!("experiments: fig1 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig11 table2 table3 table4 table5 table5h appd heavy_tail kv_routing prefix_reuse all");
        }
        "fig1" => {
            let (p, d) = batching::fig1_batching();
            p.print("Fig. 1a: prefill batching (LLaMA-2-7B, 1xA100)");
            d.print("Fig. 1b: decode batching (LLaMA-2-7B, 1xA100)");
        }
        "fig4" => {
            for name in settings::PAPER_SETTINGS {
                println!("{}", settings::by_name(name).unwrap().bandwidth_matrix_gbps());
            }
        }
        "fig5" => {
            batching::fig5_trace(20_000, 7).print("Fig. 5: online trace length distribution");
        }
        "fig6" => {
            let t = endtoend::fig6_7_grid(&LLAMA2_70B, hets, opts);
            t.print("Fig. 6: LLaMA-2-70B throughput (tokens/s)");
            for (s, sp) in endtoend::speedup_summary(&t) {
                println!("  {s}: HEXGEN-2 / HEXGEN geo-mean speedup = {sp:.2}x");
            }
        }
        "fig7" => {
            let t = endtoend::fig6_7_grid(&OPT_30B, hets, opts);
            t.print("Fig. 7: OPT-30B throughput (tokens/s)");
            for (s, sp) in endtoend::speedup_summary(&t) {
                println!("  {s}: HEXGEN-2 / HEXGEN geo-mean speedup = {sp:.2}x");
            }
        }
        "fig8" => {
            endtoend::fig8_latency(&LLAMA2_70B, hets, opts).print("Fig. 8: online latency");
        }
        "fig9" => {
            endtoend::fig9_budget(&LLAMA2_70B, opts)
                .print("Fig. 9: 70% budget (het5) vs DistServe homogeneous, LLaMA-2-70B");
        }
        "fig10" => {
            let runs = args.get_usize("runs", if opts.quick { 3 } else { 15 });
            convergence::fig10_convergence(&OPT_30B, runs, opts)
                .print("Fig. 10: scheduler convergence (het1, OPT-30B)");
        }
        "fig11" => {
            convergence::fig11_throughput(&OPT_30B, opts)
                .print("Fig. 11: scheduler-variant throughput (het1, OPT-30B)");
        }
        "table2" => {
            for setting in hets {
                for m in [&LLAMA2_70B, &OPT_30B] {
                    if let Some(s) = tables::table2_placement(setting, m, opts) {
                        println!("--- {s}");
                    }
                }
            }
        }
        "table3" => {
            tables::table3_frameworks(&LLAMA2_70B, opts)
                .print("Table 3: framework comparison (LLaMA-2-70B)");
        }
        "table4" => {
            tables::table4_homogeneous(&OPT_30B, opts)
                .print("Table 4: homogeneous 4xH100 (OPT-30B)");
        }
        "table5" => {
            let sizes: Vec<usize> =
                if opts.quick { vec![16, 32, 64] } else { vec![64, 128, 192, 256, 320] };
            tables::table5_scalability(&LLAMA2_70B, &sizes, opts)
                .print("Table 5: scheduler scalability");
        }
        "table5h" => {
            // Hierarchical extension: flat vs zoned planner on ≥4x the
            // Table-5 quick sizes (wall-clock, objective retention).
            let sizes: Vec<usize> =
                if opts.quick { vec![64, 128] } else { vec![128, 256, 320] };
            tables::table5_hierarchical(&LLAMA2_70B, &sizes, opts)
                .print("Table 5 (ext): flat vs hierarchical zone planning");
        }
        "appd" => {
            tables::appd_chunked_prefill(&OPT_30B, opts)
                .print("Appendix D: chunked prefill vs plain colocation (OPT-30B)");
        }
        "heavy_tail" => {
            let setting = args.get_or("setting", "case_study");
            endtoend::heavy_tail_admission(&OPT_30B, setting, opts)
                .ok_or_else(|| anyhow!("unknown setting {setting}"))?
                .print("Heavy-tail admission: static mean-length sizing vs per-request KV accounting (OPT-30B)");
        }
        "kv_routing" => {
            let setting = args.get_or("setting", "case_study");
            hexgen2::experiments::kvrouting::kv_routing_table(&OPT_30B, setting, opts)
                .ok_or_else(|| anyhow!("unknown setting {setting}"))?
                .print("KV routing: route models x pipelined chunking under shared-NIC contention (OPT-30B, per-request admission)");
        }
        "prefix_reuse" => {
            let setting = args.get_or("setting", "case_study");
            let out = hexgen2::experiments::prefix::prefix_reuse(&OPT_30B, setting, opts)
                .ok_or_else(|| anyhow!("unknown setting {setting}"))?;
            out.table.print(
                "Prefix reuse: cluster-wide KV pool across share levels (OPT-30B, agent workload)",
            );
            hexgen2::experiments::prefix::print_summary(&out);
        }
        "all" => {
            for e in [
                "fig1", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "table2",
                "table3", "table4", "table5", "appd", "heavy_tail", "kv_routing", "prefix_reuse",
            ] {
                run_experiment(e, opts, args)?;
            }
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}
