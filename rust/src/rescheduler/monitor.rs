//! Workload monitoring + drift detection (the sensing half of the online
//! rescheduling loop).
//!
//! [`WorkloadMonitor`] ingests per-request observations (arrival time, input
//! length, output length) into a sliding time window and summarizes them as
//! [`WindowStats`] — arrival rate and mean prefill/decode lengths, the same
//! quantities §3.3's per-period scheduler keys on. [`DriftDetector`] turns
//! those stats into at most one [`DriftEvent`] per *sustained* shift: the
//! effective [`WorkloadKind`] (classified against the paper's heavy/light
//! thresholds) must differ from the baseline — or the arrival rate must
//! leave its hysteresis band — continuously for a dwell period before an
//! event fires, and firing re-baselines the detector, so transients and
//! threshold flapping never trigger spurious re-plans.

use std::collections::VecDeque;

use crate::workload::{WorkloadKind, HEAVY_DECODE_THRESHOLD, HEAVY_PREFILL_THRESHOLD};

/// Monitoring / drift-detection knobs.
#[derive(Clone, Copy, Debug)]
pub struct MonitorConfig {
    /// Sliding-window length, seconds.
    pub window: f64,
    /// Minimum observations before stats are reported (cold-start guard).
    pub min_samples: usize,
    /// A shift must persist this long (seconds) before an event fires.
    pub dwell: f64,
    /// Relative hysteresis band on the arrival rate: a rate drift fires only
    /// when |rate / baseline - 1| exceeds this.
    pub rate_band: f64,
}

impl Default for MonitorConfig {
    fn default() -> MonitorConfig {
        MonitorConfig { window: 30.0, min_samples: 20, dwell: 10.0, rate_band: 0.5 }
    }
}

impl MonitorConfig {
    /// The tuned sensing profile shared by the §5.4 case studies,
    /// [`deploy::ReschedBackend`](crate::deploy::ReschedBackend), and the
    /// rescheduler tests: a 20 s window reacts within a phase, 15 samples
    /// guard cold start, and the 10 s dwell + 60% rate band provide the
    /// no-thrash hysteresis. One definition so harnesses and backends can
    /// never silently diverge.
    pub fn case_study() -> MonitorConfig {
        MonitorConfig { window: 20.0, min_samples: 15, dwell: 10.0, rate_band: 0.6 }
    }
}

/// Windowed request statistics at a point in time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStats {
    /// Time the stats were taken.
    pub at: f64,
    /// Arrival rate over the window, requests/s.
    pub rate: f64,
    pub mean_input: f64,
    pub mean_output: f64,
    pub n: usize,
}

impl WindowStats {
    /// Classify the observed mix against the paper's §5.1 thresholds
    /// (prefill > 512 heavy, decode > 128 heavy).
    pub fn effective_kind(&self) -> WorkloadKind {
        let hp = self.mean_input > HEAVY_PREFILL_THRESHOLD as f64;
        let hd = self.mean_output > HEAVY_DECODE_THRESHOLD as f64;
        match (hp, hd) {
            (true, true) => WorkloadKind::Hphd,
            (true, false) => WorkloadKind::Hpld,
            (false, true) => WorkloadKind::Lphd,
            (false, false) => WorkloadKind::Lpld,
        }
    }
}

/// Sliding-window request monitor.
pub struct WorkloadMonitor {
    cfg: MonitorConfig,
    /// (arrival, input_len, output_len), arrival-ordered.
    buf: VecDeque<(f64, usize, usize)>,
}

impl WorkloadMonitor {
    pub fn new(cfg: MonitorConfig) -> WorkloadMonitor {
        WorkloadMonitor { cfg, buf: VecDeque::new() }
    }

    /// Record one request observation. Arrivals must be non-decreasing.
    pub fn observe(&mut self, t: f64, input_len: usize, output_len: usize) {
        while let Some(&(t0, _, _)) = self.buf.front() {
            if t0 < t - self.cfg.window {
                self.buf.pop_front();
            } else {
                break;
            }
        }
        self.buf.push_back((t, input_len, output_len));
    }

    /// Current window stats, or None during cold start.
    pub fn stats(&self, now: f64) -> Option<WindowStats> {
        let n = self.buf.len();
        if n < self.cfg.min_samples.max(2) {
            return None;
        }
        let span = (now - self.buf.front().unwrap().0).max(1e-9);
        let (si, so) = self
            .buf
            .iter()
            .fold((0usize, 0usize), |(a, b), &(_, i, o)| (a + i, b + o));
        Some(WindowStats {
            at: now,
            rate: n as f64 / span,
            mean_input: si as f64 / n as f64,
            mean_output: so as f64 / n as f64,
            n,
        })
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// What changed when a drift event fired.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftKind {
    /// The effective workload class crossed a heavy/light threshold.
    Workload { from: WorkloadKind, to: WorkloadKind },
    /// The arrival rate left its hysteresis band.
    Rate { from: f64, to: f64 },
}

/// A detected, sustained workload shift.
#[derive(Clone, Copy, Debug)]
pub struct DriftEvent {
    pub at: f64,
    pub kind: DriftKind,
    pub stats: WindowStats,
}

/// Hysteresis drift detector: fires exactly once per sustained shift.
pub struct DriftDetector {
    cfg: MonitorConfig,
    baseline: Option<(WorkloadKind, f64)>,
    /// Time the current (not yet sustained) deviation started.
    pending_since: Option<f64>,
}

impl DriftDetector {
    pub fn new(cfg: MonitorConfig) -> DriftDetector {
        DriftDetector { cfg, baseline: None, pending_since: None }
    }

    /// The (kind, rate) the detector currently considers normal.
    pub fn baseline(&self) -> Option<(WorkloadKind, f64)> {
        self.baseline
    }

    /// Feed the latest window stats; returns an event when a shift has been
    /// sustained for the dwell period. Firing re-baselines the detector.
    pub fn update(&mut self, stats: &WindowStats) -> Option<DriftEvent> {
        let kind = stats.effective_kind();
        let Some((bk, br)) = self.baseline else {
            self.baseline = Some((kind, stats.rate));
            return None;
        };
        let kind_shift = kind != bk;
        let rate_shift = br > 0.0 && (stats.rate / br - 1.0).abs() > self.cfg.rate_band;
        if !kind_shift && !rate_shift {
            // Steady traffic: re-center the rate baseline (EWMA) so a noisy
            // first window cannot arm the band forever. A genuine sustained
            // jump still trips it — re-centering only happens while inside.
            self.baseline = Some((bk, 0.9 * br + 0.1 * stats.rate));
            self.pending_since = None;
            return None;
        }
        match self.pending_since {
            None => {
                self.pending_since = Some(stats.at);
                None
            }
            Some(t0) if stats.at - t0 >= self.cfg.dwell => {
                self.pending_since = None;
                self.baseline = Some((kind, stats.rate));
                Some(DriftEvent {
                    at: stats.at,
                    kind: if kind_shift {
                        DriftKind::Workload { from: bk, to: kind }
                    } else {
                        DriftKind::Rate { from: br, to: stats.rate }
                    },
                    stats: *stats,
                })
            }
            Some(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MonitorConfig {
        MonitorConfig { window: 20.0, min_samples: 10, dwell: 10.0, rate_band: 0.6 }
    }

    #[test]
    fn classification_matches_thresholds() {
        let mk = |i: f64, o: f64| WindowStats { at: 0.0, rate: 1.0, mean_input: i, mean_output: o, n: 10 };
        assert_eq!(mk(1024.0, 64.0).effective_kind(), WorkloadKind::Hpld);
        assert_eq!(mk(1024.0, 256.0).effective_kind(), WorkloadKind::Hphd);
        assert_eq!(mk(256.0, 256.0).effective_kind(), WorkloadKind::Lphd);
        assert_eq!(mk(256.0, 64.0).effective_kind(), WorkloadKind::Lpld);
    }

    #[test]
    fn monitor_windows_and_rates() {
        let mut m = WorkloadMonitor::new(cfg());
        for k in 0..100 {
            m.observe(k as f64 * 0.5, 100, 50);
        }
        let s = m.stats(49.5).unwrap();
        // 20 s window at 2 req/s → ~40-41 samples.
        assert!(s.n >= 40 && s.n <= 42, "{}", s.n);
        assert!((s.rate - 2.0).abs() < 0.3, "{}", s.rate);
        assert_eq!(s.mean_input, 100.0);
        assert_eq!(s.mean_output, 50.0);
    }

    #[test]
    fn cold_start_reports_nothing() {
        let m = WorkloadMonitor::new(cfg());
        assert!(m.stats(0.0).is_none());
        let mut m = WorkloadMonitor::new(cfg());
        for k in 0..5 {
            m.observe(k as f64, 10, 10);
        }
        assert!(m.stats(5.0).is_none(), "below min_samples");
    }

    #[test]
    fn transient_blips_do_not_fire() {
        let c = cfg();
        let mut det = DriftDetector::new(c);
        let mk = |t: f64, i: f64| WindowStats { at: t, rate: 2.0, mean_input: i, mean_output: 256.0, n: 40 };
        assert!(det.update(&mk(0.0, 256.0)).is_none()); // baseline LPHD
        // A 5 s excursion above the prefill threshold: shorter than dwell.
        for t in [10.0, 12.0, 14.0] {
            assert!(det.update(&mk(t, 600.0)).is_none());
        }
        // Back to normal: pending resets, never fires.
        for t in [16.0, 30.0, 60.0] {
            assert!(det.update(&mk(t, 256.0)).is_none());
        }
        // A sustained excursion fires exactly once, then re-baselines.
        assert!(det.update(&mk(70.0, 900.0)).is_none());
        assert!(det.update(&mk(75.0, 900.0)).is_none());
        let e = det.update(&mk(81.0, 900.0)).expect("sustained shift fires");
        assert_eq!(
            e.kind,
            DriftKind::Workload { from: WorkloadKind::Lphd, to: WorkloadKind::Hphd }
        );
        for t in [85.0, 100.0, 200.0] {
            assert!(det.update(&mk(t, 900.0)).is_none(), "re-fired after re-baseline");
        }
    }

    #[test]
    fn rate_drift_respects_band() {
        let c = cfg();
        let mut det = DriftDetector::new(c);
        let mk = |t: f64, r: f64| WindowStats { at: t, rate: r, mean_input: 256.0, mean_output: 256.0, n: 40 };
        det.update(&mk(0.0, 2.0));
        // 30% above baseline: inside the 60% band.
        for t in [5.0, 20.0, 40.0] {
            assert!(det.update(&mk(t, 2.6)).is_none());
        }
        // 2.2x baseline sustained: fires once. The baseline has been EWMA
        // re-centered toward 2.6 meanwhile, still far below 4.4.
        assert!(det.update(&mk(50.0, 4.4)).is_none());
        let e = det.update(&mk(61.0, 4.4)).expect("rate drift fires");
        match e.kind {
            DriftKind::Rate { from, to } => {
                assert!(from > 1.9 && from < 2.7, "baseline drifted too far: {from}");
                assert_eq!(to, 4.4);
            }
            other => panic!("wrong kind {other:?}"),
        }
        assert!(det.update(&mk(70.0, 4.4)).is_none());
    }
}
