//! Integration property tests: scheduler output validity over random
//! clusters, workloads and seeds (DESIGN.md §8).

use hexgen2::cluster::settings;
use hexgen2::costmodel::CostModel;
use hexgen2::model::{LLAMA2_70B, OPT_30B};
use hexgen2::prop_assert;
use hexgen2::scheduler::{self, ScheduleOptions, SwapMode};
use hexgen2::util::prop::check;
use hexgen2::workload::WorkloadKind;

fn quick_opts(kind: WorkloadKind, seed: u64, mode: SwapMode) -> ScheduleOptions {
    let mut o = ScheduleOptions::new(kind);
    o.seed = seed;
    o.max_rounds = 6;
    o.patience = 3;
    o.proposals_per_round = 6;
    o.type_candidates = 3;
    o.swap_mode = mode;
    o
}

#[test]
fn placement_is_valid_on_random_clusters() {
    check(0xA11, 12, |rng| {
        let n_nodes = rng.range(2, 5);
        let cluster = settings::synthetic(n_nodes * 8, rng.next_u64());
        let model = if rng.bool(0.5) { OPT_30B } else { LLAMA2_70B };
        let kinds = [WorkloadKind::Hpld, WorkloadKind::Hphd, WorkloadKind::Lphd, WorkloadKind::Lpld];
        let kind = *rng.choice(&kinds);
        let mode = if rng.bool(0.5) { SwapMode::Guided } else { SwapMode::Random };
        let Some(r) = scheduler::schedule(&cluster, &model, &quick_opts(kind, rng.next_u64(), mode))
        else {
            return Ok(()); // tiny clusters may be infeasible for 70B: allowed
        };
        let p = &r.placement;

        // 1. Partition: every device in exactly one group.
        let mut all: Vec<usize> = p.groups.iter().flat_map(|g| g.devices.clone()).collect();
        all.sort_unstable();
        prop_assert!(all == (0..cluster.n()).collect::<Vec<_>>(), "not a partition");

        // 2. Both phases represented with positive capacity.
        prop_assert!(
            p.groups.iter().any(|g| g.is_prefill && g.capacity > 0.0),
            "no live prefill group"
        );
        prop_assert!(
            p.groups.iter().any(|g| !g.is_prefill && g.capacity > 0.0),
            "no live decode group"
        );

        // 3. Configs use exactly their group's devices and all model layers;
        //    memory limits hold at batch 1.
        let cm = CostModel::new(&cluster, &model);
        let task = scheduler::task_for(kind);
        for g in &p.groups {
            let Some(cfg) = &g.config else { continue };
            let mut a = cfg.devices();
            a.sort_unstable();
            let mut b = g.devices.clone();
            b.sort_unstable();
            prop_assert!(a == b, "config devices != group devices");
            prop_assert!(cfg.total_layers() == model.n_layers, "layer count wrong");
            prop_assert!(cm.memory_ok(cfg, &task.with_batch(1)), "memory violated");
        }

        // 4. Flow respects capacities; routed flow equals flow value.
        for route in &p.routes {
            prop_assert!(route.flow <= route.capacity + 1e-6, "route over capacity");
            prop_assert!(p.groups[route.prefill].is_prefill, "route from non-prefill");
            prop_assert!(!p.groups[route.decode].is_prefill, "route to non-decode");
        }
        let routed: f64 = p.routes.iter().map(|r| r.flow).sum();
        prop_assert!(
            (routed - p.flow_value).abs() < 1e-4 * (1.0 + p.flow_value),
            "kv flow {} != flow value {}",
            routed,
            p.flow_value
        );

        // 5. History is monotone.
        for w in r.history.windows(2) {
            prop_assert!(w[1].tokens_per_s >= w[0].tokens_per_s - 1e-9, "history regressed");
        }
        Ok(())
    });
}

#[test]
fn refinement_never_hurts() {
    check(0xA12, 8, |rng| {
        let cluster = settings::synthetic(16, rng.next_u64());
        let seed = rng.next_u64();
        let one_shot = quick_opts(WorkloadKind::Hphd, seed, SwapMode::None);
        let refined = quick_opts(WorkloadKind::Hphd, seed, SwapMode::Guided);
        let (Some(a), Some(b)) = (
            scheduler::schedule(&cluster, &OPT_30B, &one_shot),
            scheduler::schedule(&cluster, &OPT_30B, &refined),
        ) else {
            return Ok(());
        };
        prop_assert!(
            b.placement.tokens_per_s >= a.placement.tokens_per_s - 1e-9,
            "refinement regressed: {} -> {}",
            a.placement.tokens_per_s,
            b.placement.tokens_per_s
        );
        Ok(())
    });
}

#[test]
fn workload_shifts_resources() {
    // §5.2 finding (3): HPLD allocates relatively more prefill capacity than
    // LPHD on the same cluster.
    let cluster = settings::het2();
    let frac = |kind| {
        let r = scheduler::schedule(&cluster, &OPT_30B, &ScheduleOptions::new(kind)).unwrap();
        let p: f64 = r
            .placement
            .groups
            .iter()
            .filter(|g| g.is_prefill)
            .flat_map(|g| g.devices.iter())
            .map(|&d| cluster.devices[d].gpu.effective_tflops())
            .sum();
        let total: f64 = cluster.devices.iter().map(|d| d.gpu.effective_tflops()).sum();
        p / total
    };
    let hpld = frac(WorkloadKind::Hpld);
    let lphd = frac(WorkloadKind::Lphd);
    assert!(
        hpld >= lphd,
        "HPLD prefill share {hpld:.2} below LPHD {lphd:.2}"
    );
}

#[test]
fn kv_routes_avoid_cross_dc_links() {
    // §5.2 finding (4): KV communication goes through high-bandwidth links.
    let cluster = settings::het1();
    let r = scheduler::schedule(&cluster, &LLAMA2_70B, &ScheduleOptions::new(WorkloadKind::Online))
        .unwrap();
    let p = &r.placement;
    let mut cross_dc_flow = 0.0;
    let mut total_flow = 0.0;
    for route in &p.routes {
        if route.flow <= 1e-9 {
            continue;
        }
        total_flow += route.flow;
        // A route is cross-DC if every device pair between the two groups
        // spans data centers.
        let pg = &p.groups[route.prefill].devices;
        let dg = &p.groups[route.decode].devices;
        let same_dc = pg.iter().any(|&a| {
            dg.iter().any(|&b| cluster.devices[a].dc == cluster.devices[b].dc)
        });
        if !same_dc {
            cross_dc_flow += route.flow;
        }
    }
    assert!(
        cross_dc_flow <= total_flow * 0.25,
        "{:.0}% of KV flow crosses the WAN",
        100.0 * cross_dc_flow / total_flow
    );
}
