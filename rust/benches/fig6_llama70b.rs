//! Bench: regenerate paper Fig. 6 (LLaMA-2-70B end-to-end throughput grid).
//! HEXGEN2_FULL=1 runs all four heterogeneous settings at full trace sizes.
use hexgen2::experiments::{endtoend, ExpOpts};
use hexgen2::model::LLAMA2_70B;

fn main() {
    let opts = ExpOpts::from_env();
    let hets: &[&str] = if opts.quick { &["het1", "het2"] } else { &["het1", "het2", "het3", "het4"] };
    let t = endtoend::fig6_7_grid(&LLAMA2_70B, hets, &opts);
    t.print("Fig. 6: LLaMA-2-70B throughput (tokens/s)");
    for (s, sp) in endtoend::speedup_summary(&t) {
        println!("  {s}: HEXGEN-2 / HEXGEN geo-mean speedup = {sp:.2}x");
    }
}
