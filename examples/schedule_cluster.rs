//! Run the HexGen-2 scheduling algorithm on heterogeneous setting 1 with
//! LLaMA-2-70B (the paper's flagship configuration) through the unified
//! deploy API, and print the chosen placement in the paper's Table-2 format.
//!
//! Run:  cargo run --release --example schedule_cluster

use hexgen2::cluster::settings;
use hexgen2::deploy::{DeploymentSpec, HexGen2Planner};
use hexgen2::model::LLAMA2_70B;
use hexgen2::workload::WorkloadKind;

fn main() {
    let cluster = settings::het1();
    println!("cluster {}: {} GPUs, ${:.2}/h\n", cluster.name, cluster.n(), cluster.budget_per_hour());

    for kind in [WorkloadKind::Online, WorkloadKind::Hpld, WorkloadKind::Lphd] {
        let dep = DeploymentSpec::new(cluster.clone(), LLAMA2_70B)
            .workload(kind)
            .plan(&HexGen2Planner)
            .expect("feasible placement");
        println!(
            "=== workload {} (planned in {:.2}s, est {:.0} tokens/s) ===",
            kind.name(),
            dep.plan.elapsed_s,
            dep.plan.est_tokens_per_s
        );
        println!("{}", dep.describe());
    }
}
