//! Coarsening + secondary partition (paper §3.2 steps ii–iii): merge each
//! model-serving group into a super node, then partition the super-node
//! graph into prefill vs decode sides. Unlike the initial partition, the
//! secondary partition *maximizes* the inter-type edge weight so KV-cache
//! traffic crosses high-bandwidth links, while balancing phase capacity to
//! the workload's prefill/decode demand ratio.
//! Projection back to devices is implicit (groups keep their device lists).

use crate::cluster::{Cluster, DeviceId};

/// Super-node edge weights: total bandwidth between group pairs.
pub fn inter_group_bandwidth(cluster: &Cluster, groups: &[Vec<DeviceId>]) -> Vec<Vec<f64>> {
    let k = groups.len();
    let mut w = vec![vec![0.0; k]; k];
    for a in 0..k {
        for b in (a + 1)..k {
            let mut sum = 0.0;
            for &i in &groups[a] {
                for &j in &groups[b] {
                    sum += cluster.bandwidth[i][j];
                }
            }
            w[a][b] = sum;
            w[b][a] = sum;
        }
    }
    w
}

/// Inter-type edge weight of a type assignment (the quantity step ii
/// maximizes: bandwidth available for prefill→decode KV transfers).
pub fn inter_type_weight(w: &[Vec<f64>], is_prefill: &[bool]) -> f64 {
    let k = is_prefill.len();
    let mut sum = 0.0;
    for a in 0..k {
        for b in (a + 1)..k {
            if is_prefill[a] != is_prefill[b] {
                sum += w[a][b];
            }
        }
    }
    sum
}

/// Score a type assignment: primary term is the balanced-capacity bound
/// min(prefill demand service rate, decode demand service rate) — the
/// system can't run faster than its scarcer phase — with the inter-type
/// bandwidth as a tiebreaker favoring KV-friendly splits.
///
/// `caps[g] = (prefill_capacity, decode_capacity)` per group (requests per
/// period, 0 if the group cannot serve that phase).
pub fn score_assignment(
    w: &[Vec<f64>],
    caps: &[(f64, f64)],
    is_prefill: &[bool],
) -> f64 {
    let cap_p: f64 = caps
        .iter()
        .zip(is_prefill)
        .filter(|(_, &p)| p)
        .map(|(c, _)| c.0)
        .sum();
    let cap_d: f64 = caps
        .iter()
        .zip(is_prefill)
        .filter(|(_, &p)| !p)
        .map(|(c, _)| c.1)
        .sum();
    if cap_p <= 0.0 || cap_d <= 0.0 {
        return 0.0;
    }
    let bound = cap_p.min(cap_d);
    let total_w: f64 = w.iter().flatten().sum::<f64>() + 1e-30;
    let bw_frac = inter_type_weight(w, is_prefill) / total_w;
    bound * (1.0 + 0.05 * bw_frac)
}

/// Produce up to `max_out` candidate type assignments, best-scored first.
/// Exhaustive for K <= 14; greedy + local flips beyond.
pub fn type_candidates(
    w: &[Vec<f64>],
    caps: &[(f64, f64)],
    max_out: usize,
) -> Vec<Vec<bool>> {
    let k = caps.len();
    assert!(k >= 2, "need at least two groups to disaggregate");
    if k <= 14 {
        let mut scored: Vec<(f64, Vec<bool>)> = Vec::new();
        for mask in 1..(1u32 << k) - 1 {
            let assign: Vec<bool> = (0..k).map(|g| mask & (1 << g) != 0).collect();
            let s = score_assignment(w, caps, &assign);
            if s > 0.0 {
                scored.push((s, assign));
            }
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scored.into_iter().take(max_out).map(|(_, a)| a).collect()
    } else {
        // Greedy: assign each group to the phase where it is relatively
        // stronger, then fix emptiness and hill-climb with single flips.
        let mut assign: Vec<bool> = caps.iter().map(|&(p, d)| p >= d).collect();
        if assign.iter().all(|&x| x) {
            *assign.last_mut().unwrap() = false;
        }
        if assign.iter().all(|&x| !x) {
            assign[0] = true;
        }
        let mut best = score_assignment(w, caps, &assign);
        loop {
            let mut improved = false;
            for g in 0..k {
                let mut cand = assign.clone();
                cand[g] = !cand[g];
                if cand.iter().any(|&x| x) && cand.iter().any(|&x| !x) {
                    let s = score_assignment(w, caps, &cand);
                    if s > best {
                        best = s;
                        assign = cand;
                        improved = true;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        // Emit the greedy fixpoint plus its single-flip neighborhood.
        let mut out = vec![assign.clone()];
        for g in 0..k {
            if out.len() >= max_out {
                break;
            }
            let mut cand = assign.clone();
            cand[g] = !cand[g];
            if cand.iter().any(|&x| x) && cand.iter().any(|&x| !x) {
                out.push(cand);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;

    #[test]
    fn inter_group_bandwidth_symmetric() {
        let c = settings::het2();
        let groups: Vec<Vec<usize>> = vec![(0..3).collect(), (3..6).collect(), (6..12).collect()];
        let w = inter_group_bandwidth(&c, &groups);
        for a in 0..3 {
            assert_eq!(w[a][a], 0.0);
            for b in 0..3 {
                assert_eq!(w[a][b], w[b][a]);
            }
        }
        assert!(w[0][1] > 0.0);
    }

    #[test]
    fn inter_type_weight_counts_cross_edges_only() {
        let w = vec![
            vec![0.0, 5.0, 1.0],
            vec![5.0, 0.0, 2.0],
            vec![1.0, 2.0, 0.0],
        ];
        // groups 0,1 prefill; group 2 decode → cross edges (0,2)+(1,2)=3.
        assert_eq!(inter_type_weight(&w, &[true, true, false]), 3.0);
        assert_eq!(inter_type_weight(&w, &[true, false, false]), 6.0);
    }

    #[test]
    fn candidates_balanced_capacity_first() {
        // Two strong groups, two weak; best assignments split capacity.
        let caps = vec![(10.0, 10.0), (10.0, 10.0), (2.0, 2.0), (2.0, 2.0)];
        let w = vec![vec![1.0; 4]; 4];
        let cands = type_candidates(&w, &caps, 4);
        assert!(!cands.is_empty());
        let top = &cands[0];
        // Top candidate must put the two strong groups on different sides.
        assert_ne!(top[0], top[1], "{top:?}");
        for c in &cands {
            assert!(c.iter().any(|&x| x) && c.iter().any(|&x| !x));
        }
    }

    #[test]
    fn bandwidth_breaks_ties() {
        // Symmetric capacities; assignment separating the high-bandwidth
        // pair (0,1) across types should win the tiebreak.
        let caps = vec![(5.0, 5.0), (5.0, 5.0)];
        let mut w = vec![vec![0.0; 2]; 2];
        w[0][1] = 100.0;
        w[1][0] = 100.0;
        let cands = type_candidates(&w, &caps, 2);
        assert_ne!(cands[0][0], cands[0][1]);
    }

    #[test]
    fn greedy_path_for_large_k() {
        let k = 20;
        let caps: Vec<(f64, f64)> = (0..k)
            .map(|i| if i % 2 == 0 { (10.0, 1.0) } else { (1.0, 10.0) })
            .collect();
        let w = vec![vec![1.0; k]; k];
        let cands = type_candidates(&w, &caps, 5);
        assert!(!cands.is_empty());
        let top = &cands[0];
        // Greedy should assign even groups (prefill-strong) to prefill.
        let correct = (0..k).filter(|&i| top[i] == (i % 2 == 0)).count();
        assert!(correct >= k - 2, "greedy got {correct}/{k}");
    }
}
