//! LLM inference workloads: the paper's four offline workload classes
//! (HPLD / HPHD / LPHD / LPLD, §5.1), the online Azure-conversation-like
//! trace (Fig. 5) with Poisson arrivals, and the shared-prefix classes
//! (PREFIX_CHAT / RAG / AGENT, DESIGN.md §15) whose requests re-send
//! Zipf-distributed hot prefixes the cluster-wide prefix pool can reuse.
//!
//! Thresholds follow the paper: prefill > 512 tokens is "heavy"; decode
//! > 128 tokens is "heavy" (after Hu et al., 2024).

pub mod azure;

use crate::util::rng::Rng;

pub const HEAVY_PREFILL_THRESHOLD: usize = 512;
pub const HEAVY_DECODE_THRESHOLD: usize = 128;

/// Shared-prefix declaration carried by a request (DESIGN.md §15): the
/// leading `len` tokens of `input_len` are the prefix identified by `id`
/// (system prompt, hot RAG document, re-sent agent history). `len` is a
/// deterministic function of `id` ([`PrefixParams::prefix_len`]) so every
/// request agrees on a prefix's size — the pool's token accounting relies
/// on that.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Prefix {
    pub id: usize,
    pub len: usize,
}

/// One inference request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Arrival time in seconds from trace start (0.0 for offline traces).
    pub arrival: f64,
    pub input_len: usize,
    pub output_len: usize,
    /// Shared prefix this request re-sends, if any. `input_len` always
    /// *includes* the prefix tokens; this field only marks the reusable
    /// span for the prefix pool.
    pub prefix: Option<Prefix>,
}

/// The paper's workload classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Heavy prefill, light decoding (e.g. coding workloads).
    Hpld,
    /// Heavy prefill, heavy decoding.
    Hphd,
    /// Light prefill, heavy decoding (e.g. conversation with long answers).
    Lphd,
    /// Light prefill, light decoding.
    Lpld,
    /// Mixed online trace sampled from the Azure-conversation-like
    /// distribution (Fig. 5).
    Online,
    /// Extreme length dispersion (σ≈1.3 log-normal, outliers to 16k
    /// tokens): the stress case for per-request KV admission, where mean
    /// lengths say nothing about memory demand.
    HeavyTail,
    /// System-prompt-heavy chat: a small set of hot system prompts
    /// (prefixes) shared across conversations, long answers.
    PrefixChat,
    /// Retrieval-augmented generation: a larger catalogue of hot documents
    /// prepended to short questions, short extractive answers.
    Rag,
    /// Agent loops re-sending accumulated history each turn: near-certain
    /// prefix reuse, short tool-call outputs.
    Agent,
}

pub const OFFLINE_KINDS: [WorkloadKind; 4] =
    [WorkloadKind::Hpld, WorkloadKind::Hphd, WorkloadKind::Lphd, WorkloadKind::Lpld];

/// Shared-prefix population parameters of a prefix workload class
/// (DESIGN.md §15). Prefix ids are drawn Zipf(`zipf_s`) over
/// `n_prefixes`; a request declares its prefix reusable with probability
/// `share` (the `--prefix-share` override replaces this). Prefix lengths
/// are deterministic in the id so every request agrees on a prefix's
/// size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixParams {
    pub n_prefixes: usize,
    pub zipf_s: f64,
    pub share: f64,
    pub len_base: usize,
    pub len_step: usize,
}

impl PrefixParams {
    /// Length in tokens of prefix `id` — a pure function of the id.
    pub fn prefix_len(&self, id: usize) -> usize {
        self.len_base + (id % 8) * self.len_step
    }

    /// Draw a prefix id Zipf-distributed over the population. Consumes
    /// exactly one uniform draw (inverse-CDF walk over the unnormalized
    /// weights), independent of the outcome.
    pub fn sample_id(&self, rng: &mut Rng) -> usize {
        let n = self.n_prefixes.max(1);
        let mut total = 0.0;
        for i in 0..n {
            total += ((i + 1) as f64).powf(-self.zipf_s);
        }
        let mut target = rng.f64() * total;
        for i in 0..n {
            let w = ((i + 1) as f64).powf(-self.zipf_s);
            if target < w {
                return i;
            }
            target -= w;
        }
        n - 1
    }

    /// Zipf-weighted mean prefix length in tokens.
    pub fn mean_prefix_len(&self) -> f64 {
        let n = self.n_prefixes.max(1);
        let (mut num, mut den) = (0.0, 0.0);
        for i in 0..n {
            let w = ((i + 1) as f64).powf(-self.zipf_s);
            num += w * self.prefix_len(i) as f64;
            den += w;
        }
        num / den
    }
}

impl WorkloadKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Hpld => "HPLD",
            WorkloadKind::Hphd => "HPHD",
            WorkloadKind::Lphd => "LPHD",
            WorkloadKind::Lpld => "LPLD",
            WorkloadKind::Online => "Online",
            WorkloadKind::HeavyTail => "HEAVY_TAIL",
            WorkloadKind::PrefixChat => "PREFIX_CHAT",
            WorkloadKind::Rag => "RAG",
            WorkloadKind::Agent => "AGENT",
        }
    }

    pub fn from_name(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_uppercase().as_str() {
            "HPLD" => Some(WorkloadKind::Hpld),
            "HPHD" => Some(WorkloadKind::Hphd),
            "LPHD" => Some(WorkloadKind::Lphd),
            "LPLD" => Some(WorkloadKind::Lpld),
            "ONLINE" => Some(WorkloadKind::Online),
            "HEAVY_TAIL" | "HEAVY-TAIL" | "HEAVYTAIL" => Some(WorkloadKind::HeavyTail),
            "PREFIX_CHAT" | "PREFIX-CHAT" | "PREFIXCHAT" => Some(WorkloadKind::PrefixChat),
            "RAG" => Some(WorkloadKind::Rag),
            "AGENT" => Some(WorkloadKind::Agent),
            _ => None,
        }
    }

    /// Sample (input_len, output_len) for this class. For prefix classes
    /// this is the *suffix* (the unique part of the prompt) — the shared
    /// prefix is added on top during trace generation.
    pub fn sample_lengths(self, rng: &mut Rng) -> (usize, usize) {
        match self {
            WorkloadKind::Hpld => (azure::sample_heavy_prefill(rng), azure::sample_light_decode(rng)),
            WorkloadKind::Hphd => (azure::sample_heavy_prefill(rng), azure::sample_heavy_decode(rng)),
            WorkloadKind::Lphd => (azure::sample_light_prefill(rng), azure::sample_heavy_decode(rng)),
            WorkloadKind::Lpld => (azure::sample_light_prefill(rng), azure::sample_light_decode(rng)),
            WorkloadKind::Online => azure::sample_conversation(rng),
            WorkloadKind::HeavyTail => azure::sample_heavy_tail(rng),
            WorkloadKind::PrefixChat => {
                (azure::sample_light_prefill(rng), azure::sample_heavy_decode(rng))
            }
            WorkloadKind::Rag => (azure::sample_light_prefill(rng), azure::sample_light_decode(rng)),
            WorkloadKind::Agent => {
                (azure::sample_light_prefill(rng), azure::sample_light_decode(rng))
            }
        }
    }

    /// Shared-prefix population of this class, if it is a prefix class.
    pub fn prefix_params(self) -> Option<PrefixParams> {
        match self {
            WorkloadKind::PrefixChat => Some(PrefixParams {
                n_prefixes: 16,
                zipf_s: 1.2,
                share: 0.9,
                len_base: 512,
                len_step: 64,
            }),
            WorkloadKind::Rag => Some(PrefixParams {
                n_prefixes: 64,
                zipf_s: 1.0,
                share: 0.7,
                len_base: 1024,
                len_step: 128,
            }),
            WorkloadKind::Agent => Some(PrefixParams {
                n_prefixes: 24,
                zipf_s: 1.1,
                share: 0.95,
                len_base: 768,
                len_step: 96,
            }),
            _ => None,
        }
    }

    /// Representative task profile (mean lengths) used by the scheduler to
    /// size capacities for this workload class. Prefix classes include the
    /// mean shared-prefix tokens — the planner's demand model sees the
    /// full prompt; the expected *reused* fraction is discounted
    /// separately via [`WorkloadKind::expected_prefix_savings`].
    pub fn mean_lengths(self) -> (f64, f64) {
        match self {
            WorkloadKind::Hpld => (1024.0, 64.0),
            WorkloadKind::Hphd => (1024.0, 256.0),
            WorkloadKind::Lphd => (256.0, 256.0),
            WorkloadKind::Lpld => (256.0, 64.0),
            WorkloadKind::Online => (1020.0, 211.0),
            // Means alone badly undersell this class — that is the point.
            WorkloadKind::HeavyTail => (1100.0, 180.0),
            WorkloadKind::PrefixChat | WorkloadKind::Rag | WorkloadKind::Agent => {
                let (suffix, out) = match self {
                    WorkloadKind::Rag | WorkloadKind::Agent => (256.0, 64.0),
                    _ => (256.0, 256.0),
                };
                let px = match self.prefix_params() {
                    Some(pp) => pp.mean_prefix_len(),
                    None => 0.0,
                };
                (suffix + px, out)
            }
        }
    }

    /// Expected fraction of cluster prefill work a warm prefix pool
    /// removes for this class: declared-share × (mean prefix tokens /
    /// mean prompt tokens). This is what `--prefix-hit-aware` feeds the
    /// planner as `ScheduleOptions::prefix_hit_rate`. Zero for classes
    /// without prefixes.
    pub fn expected_prefix_savings(self, share_override: Option<f64>) -> f64 {
        match self.prefix_params() {
            None => 0.0,
            Some(pp) => {
                let share = share_override.unwrap_or(pp.share).clamp(0.0, 1.0);
                let (s_in, _) = self.mean_lengths();
                if s_in <= 0.0 {
                    return 0.0;
                }
                (share * pp.mean_prefix_len() / s_in).clamp(0.0, 0.95)
            }
        }
    }
}

/// A streaming source of requests (DESIGN.md §14): a pull-based generator
/// for traces too large to materialize. The engine core draws one request
/// at a time and keeps only a bounded arrival frontier in its event heap,
/// so a million-request run needs O(active requests) memory instead of
/// O(trace length).
///
/// Each constructor replicates the RNG stream of the matching [`Trace`]
/// constructor bit-exactly — in fact the `Trace` constructors are
/// implemented as collects over the source, so
/// `TraceSource::offline(k, n, s).collect::<Vec<_>>()` equals
/// `Trace::offline(k, n, s).requests` by construction.
pub struct TraceSource {
    kind: WorkloadKind,
    inner: SourceInner,
    /// `--prefix-share` override: replaces the class-intrinsic declared
    /// share. Generation consumes a *fixed* number of RNG draws per
    /// request regardless of this value, so arrivals and lengths are
    /// bit-identical across a share sweep ("equal load").
    prefix_share: Option<f64>,
    /// Test hook: replace the class-intrinsic prefix population (e.g. to
    /// sweep Zipf skew at fixed lengths).
    prefix_params: Option<PrefixParams>,
}

enum SourceInner {
    Offline { rng: Rng, kind: WorkloadKind, remaining: usize, next_id: usize },
    Online { rng: Rng, kind: WorkloadKind, rate: f64, duration: f64, t: f64, next_id: usize },
    Phases { rng: Rng, phases: Vec<(WorkloadKind, f64, f64)>, idx: usize, t0: f64, t: f64, next_id: usize },
    Materialized { requests: std::vec::IntoIter<Request> },
}

impl TraceSource {
    /// Streaming equivalent of [`Trace::offline`].
    pub fn offline(kind: WorkloadKind, n: usize, seed: u64) -> TraceSource {
        let rng = Rng::new(seed ^ 0x0FF1CE);
        TraceSource {
            kind,
            inner: SourceInner::Offline { rng, kind, remaining: n, next_id: 0 },
            prefix_share: None,
            prefix_params: None,
        }
    }

    /// Streaming equivalent of [`Trace::online`].
    pub fn online(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> TraceSource {
        let rng = Rng::new(seed ^ 0x0411_15E5);
        TraceSource {
            kind,
            inner: SourceInner::Online { rng, kind, rate, duration, t: 0.0, next_id: 0 },
            prefix_share: None,
            prefix_params: None,
        }
    }

    /// Streaming equivalent of [`Trace::phases`].
    pub fn phases(phases: &[(WorkloadKind, f64, f64)], seed: u64) -> TraceSource {
        assert!(!phases.is_empty(), "need at least one phase");
        for &(_, rate, duration) in phases {
            assert!(
                rate > 0.0 && rate.is_finite() && duration > 0.0 && duration.is_finite(),
                "phase rate/duration must be positive and finite"
            );
        }
        let rng = Rng::new(seed ^ 0x9_4A5E_D0);
        TraceSource {
            kind: phases[0].0,
            inner: SourceInner::Phases {
                rng,
                phases: phases.to_vec(),
                idx: 0,
                t0: 0.0,
                t: 0.0,
                next_id: 0,
            },
            prefix_share: None,
            prefix_params: None,
        }
    }

    /// Replay an already-materialized trace through the streaming
    /// interface (the parity bridge: every `Trace`-driven run is a
    /// `TraceSource`-driven run over this wrapper).
    pub fn replay(trace: &Trace) -> TraceSource {
        TraceSource {
            kind: trace.kind,
            inner: SourceInner::Materialized { requests: trace.requests.clone().into_iter() },
            prefix_share: None,
            prefix_params: None,
        }
    }

    pub fn kind(&self) -> WorkloadKind {
        self.kind
    }

    /// Override the declared prefix share (`--prefix-share`). Clamped to
    /// [0, 1]; 0 yields a trace whose requests carry no `prefix` at all
    /// while arrivals and lengths stay bit-identical to any other share.
    /// No effect on classes without prefixes or on `replay` sources.
    pub fn with_prefix_share(mut self, share: f64) -> TraceSource {
        self.prefix_share = Some(share.clamp(0.0, 1.0));
        self
    }

    /// Override the prefix population (test hook, e.g. Zipf-skew sweeps).
    pub fn with_prefix_params(mut self, params: PrefixParams) -> TraceSource {
        self.prefix_params = Some(params);
        self
    }
}

/// Attach the shared prefix to a freshly sampled request. Always consumes
/// exactly two uniform draws for prefix classes (keep?, which id?) and
/// none otherwise, so a share sweep replays identical arrivals/lengths.
/// The prefix tokens are part of `input_len` whether or not the request
/// declares them reusable.
fn gen_prefix(
    rng: &mut Rng,
    kind: WorkloadKind,
    share_override: Option<f64>,
    params_override: Option<PrefixParams>,
    input_len: &mut usize,
) -> Option<Prefix> {
    let pp = params_override.or_else(|| kind.prefix_params())?;
    let share = share_override.unwrap_or(pp.share).clamp(0.0, 1.0);
    let keep = rng.f64() < share;
    let id = pp.sample_id(rng);
    let len = pp.prefix_len(id);
    *input_len += len;
    if keep {
        Some(Prefix { id, len })
    } else {
        None
    }
}

impl Iterator for TraceSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        let (share, params) = (self.prefix_share, self.prefix_params);
        match &mut self.inner {
            SourceInner::Offline { rng, kind, remaining, next_id } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                let (mut input_len, output_len) = kind.sample_lengths(rng);
                let prefix = gen_prefix(rng, *kind, share, params, &mut input_len);
                let id = *next_id;
                *next_id += 1;
                Some(Request { id, arrival: 0.0, input_len, output_len, prefix })
            }
            SourceInner::Online { rng, kind, rate, duration, t, next_id } => {
                let prev = *t;
                *t += rng.exp(*rate);
                if *t <= prev {
                    *t = next_after(prev);
                }
                if *t >= *duration {
                    return None;
                }
                let (mut input_len, output_len) = kind.sample_lengths(rng);
                let prefix = gen_prefix(rng, *kind, share, params, &mut input_len);
                let id = *next_id;
                *next_id += 1;
                Some(Request { id, arrival: *t, input_len, output_len, prefix })
            }
            SourceInner::Phases { rng, phases, idx, t0, t, next_id } => {
                loop {
                    let &(kind, rate, duration) = phases.get(*idx)?;
                    let end = *t0 + duration;
                    let prev = *t;
                    *t += rng.exp(rate);
                    if *t <= prev {
                        *t = next_after(prev);
                    }
                    if *t >= end {
                        // Poisson arrivals are memoryless: the next phase
                        // restarts its clock at the boundary (carrying the
                        // overshoot gap would distort the first window
                        // after the boundary whenever rates differ).
                        *t0 = end;
                        *t = end;
                        *idx += 1;
                        continue;
                    }
                    let (mut input_len, output_len) = kind.sample_lengths(rng);
                    let prefix = gen_prefix(rng, kind, share, params, &mut input_len);
                    let id = *next_id;
                    *next_id += 1;
                    return Some(Request { id, arrival: *t, input_len, output_len, prefix });
                }
            }
            SourceInner::Materialized { requests } => requests.next(),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match &self.inner {
            SourceInner::Offline { remaining, .. } => (*remaining, Some(*remaining)),
            SourceInner::Materialized { requests } => requests.size_hint(),
            _ => (0, None),
        }
    }
}

/// A generated request trace.
#[derive(Clone, Debug)]
pub struct Trace {
    pub kind: WorkloadKind,
    pub requests: Vec<Request>,
}

impl Trace {
    /// Offline trace: `n` requests all available at t=0 ("requests arrive at
    /// a rate that fully utilizes the cluster", §5.1).
    pub fn offline(kind: WorkloadKind, n: usize, seed: u64) -> Trace {
        Trace { kind, requests: TraceSource::offline(kind, n, seed).collect() }
    }

    /// Online trace: Poisson arrivals at `rate` req/s for `duration` seconds
    /// (the paper scales rate to 75% of cluster peak). Arrival timestamps are
    /// strictly increasing: exponential gaps can round to zero in f64 once
    /// `t` is large, so equal timestamps are deduplicated at generation by
    /// nudging to the next representable instant.
    pub fn online(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
        Trace { kind, requests: TraceSource::online(kind, rate, duration, seed).collect() }
    }

    /// Phased trace for workload-drift scenarios (rescheduler case studies):
    /// each `(kind, rate, duration)` phase contributes Poisson arrivals over
    /// its own time window, concatenated on a single global clock. The
    /// trace's `kind` is the *first* phase's kind (the placement a static
    /// scheduler would provision for). Arrivals are strictly increasing
    /// across phase boundaries.
    pub fn phases(phases: &[(WorkloadKind, f64, f64)], seed: u64) -> Trace {
        let src = TraceSource::phases(phases, seed);
        Trace { kind: src.kind(), requests: src.collect() }
    }

    /// Phase boundary times of a phased trace spec: `boundaries[i]` is the
    /// start of phase i+1 (cumulative durations, excluding the final end).
    pub fn phase_boundaries(phases: &[(WorkloadKind, f64, f64)]) -> Vec<f64> {
        let mut out = Vec::new();
        let mut acc = 0.0;
        for &(_, _, d) in &phases[..phases.len().saturating_sub(1)] {
            acc += d;
            out.push(acc);
        }
        out
    }

    /// Materialize any configured source (the path `--prefix-share` takes:
    /// `TraceSource::offline(..).with_prefix_share(s)` → `Trace`).
    pub fn from_source(src: TraceSource) -> Trace {
        let kind = src.kind();
        Trace { kind, requests: src.collect() }
    }

    pub fn total_output_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.output_len).sum()
    }

    pub fn total_input_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.input_len).sum()
    }
}

/// Smallest f64 strictly greater than `x` (for deduplicating arrival
/// timestamps without pulling in the unstable-era `next_up`).
fn next_after(x: f64) -> f64 {
    if x == 0.0 {
        return f64::MIN_POSITIVE;
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_classes_respect_thresholds() {
        for kind in OFFLINE_KINDS {
            let t = Trace::offline(kind, 500, 7);
            assert_eq!(t.requests.len(), 500);
            for r in &t.requests {
                assert_eq!(r.arrival, 0.0);
                match kind {
                    WorkloadKind::Hpld => {
                        assert!(r.input_len > HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len <= HEAVY_DECODE_THRESHOLD);
                    }
                    WorkloadKind::Hphd => {
                        assert!(r.input_len > HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len > HEAVY_DECODE_THRESHOLD);
                    }
                    WorkloadKind::Lphd => {
                        assert!(r.input_len <= HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len > HEAVY_DECODE_THRESHOLD);
                    }
                    WorkloadKind::Lpld => {
                        assert!(r.input_len <= HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len <= HEAVY_DECODE_THRESHOLD);
                    }
                    _ => unreachable!(),
                }
            }
        }
    }

    #[test]
    fn online_poisson_rate() {
        let t = Trace::online(WorkloadKind::Online, 5.0, 200.0, 3);
        let n = t.requests.len() as f64;
        assert!((n / 200.0 - 5.0).abs() < 0.5, "rate {} off", n / 200.0);
        // arrivals strictly increasing (generation dedupes equal stamps)
        for w in t.requests.windows(2) {
            assert!(w[1].arrival > w[0].arrival, "{} !> {}", w[1].arrival, w[0].arrival);
        }
    }

    #[test]
    fn phased_trace_shifts_mix_at_boundary() {
        let spec = [(WorkloadKind::Lphd, 4.0, 50.0), (WorkloadKind::Hpld, 4.0, 50.0)];
        let t = Trace::phases(&spec, 11);
        assert_eq!(t.kind, WorkloadKind::Lphd);
        assert_eq!(Trace::phase_boundaries(&spec), vec![50.0]);
        // Strictly increasing across the whole trace, ids sequential.
        for (i, w) in t.requests.windows(2).enumerate() {
            assert!(w[1].arrival > w[0].arrival);
            assert_eq!(t.requests[i].id, i);
        }
        // Phase 1 requests are light-prefill, phase 2 heavy-prefill.
        for r in &t.requests {
            if r.arrival < 50.0 {
                assert!(r.input_len <= HEAVY_PREFILL_THRESHOLD, "LPHD phase got {}", r.input_len);
                assert!(r.output_len > HEAVY_DECODE_THRESHOLD);
            } else {
                assert!(r.input_len > HEAVY_PREFILL_THRESHOLD, "HPLD phase got {}", r.input_len);
                assert!(r.output_len <= HEAVY_DECODE_THRESHOLD);
            }
        }
        // Both phases populated at roughly the requested rate.
        let n1 = t.requests.iter().filter(|r| r.arrival < 50.0).count();
        let n2 = t.requests.len() - n1;
        assert!(n1 > 100 && n2 > 100, "{n1}/{n2}");
    }

    #[test]
    fn trace_source_matches_materialized_constructors() {
        // Bit-exact stream parity: the Trace constructors are collects over
        // TraceSource, and replay() round-trips a materialized trace.
        let off: Vec<Request> = TraceSource::offline(WorkloadKind::Hphd, 200, 9).collect();
        assert_eq!(off, Trace::offline(WorkloadKind::Hphd, 200, 9).requests);
        let on: Vec<Request> = TraceSource::online(WorkloadKind::Online, 4.0, 60.0, 3).collect();
        assert_eq!(on, Trace::online(WorkloadKind::Online, 4.0, 60.0, 3).requests);
        let spec = [(WorkloadKind::Lphd, 3.0, 40.0), (WorkloadKind::Hpld, 5.0, 40.0)];
        let ph: Vec<Request> = TraceSource::phases(&spec, 11).collect();
        assert_eq!(ph, Trace::phases(&spec, 11).requests);
        let t = Trace::online(WorkloadKind::Online, 2.0, 30.0, 5);
        let replayed: Vec<Request> = TraceSource::replay(&t).collect();
        assert_eq!(replayed, t.requests);
        assert_eq!(TraceSource::replay(&t).kind(), t.kind);
    }

    #[test]
    fn trace_source_offline_size_hint_is_exact() {
        let mut src = TraceSource::offline(WorkloadKind::Lpld, 5, 1);
        assert_eq!(src.size_hint(), (5, Some(5)));
        src.next();
        assert_eq!(src.size_hint(), (4, Some(4)));
    }

    #[test]
    fn next_after_strictly_increases() {
        for x in [0.0, 1.0, 123.456, 1e12] {
            assert!(next_after(x) > x);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Trace::offline(WorkloadKind::Hphd, 50, 9);
        let b = Trace::offline(WorkloadKind::Hphd, 50, 9);
        assert_eq!(a.requests, b.requests);
        let c = Trace::offline(WorkloadKind::Hphd, 50, 10);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn name_roundtrip() {
        for k in [
            WorkloadKind::Hpld,
            WorkloadKind::Hphd,
            WorkloadKind::Lphd,
            WorkloadKind::Lpld,
            WorkloadKind::Online,
            WorkloadKind::HeavyTail,
        ] {
            assert_eq!(WorkloadKind::from_name(k.name()), Some(k));
        }
        assert_eq!(WorkloadKind::from_name("hpld"), Some(WorkloadKind::Hpld));
        // CLI alias: `--workload heavy_tail`.
        assert_eq!(WorkloadKind::from_name("heavy_tail"), Some(WorkloadKind::HeavyTail));
    }

    #[test]
    fn prefix_kinds_roundtrip_and_have_params() {
        for k in [WorkloadKind::PrefixChat, WorkloadKind::Rag, WorkloadKind::Agent] {
            assert_eq!(WorkloadKind::from_name(k.name()), Some(k));
            let pp = k.prefix_params().expect("prefix class has params");
            assert!(pp.n_prefixes > 0 && pp.share > 0.0 && pp.share <= 1.0);
            // mean_lengths includes the mean prefix.
            let (s_in, _) = k.mean_lengths();
            assert!(s_in > pp.mean_prefix_len());
            let f = k.expected_prefix_savings(None);
            assert!(f > 0.0 && f < 1.0, "{f}");
        }
        assert_eq!(WorkloadKind::from_name("prefix_chat"), Some(WorkloadKind::PrefixChat));
        assert_eq!(WorkloadKind::Hpld.expected_prefix_savings(None), 0.0);
        assert_eq!(WorkloadKind::Hpld.prefix_params(), None);
    }

    #[test]
    fn prefix_share_sweep_keeps_load_identical() {
        // Fixed draw count: only the `prefix` field may differ across
        // shares — arrivals, lengths, and ids are bit-identical.
        let full = Trace::from_source(
            TraceSource::online(WorkloadKind::PrefixChat, 4.0, 50.0, 7).with_prefix_share(1.0),
        );
        let none = Trace::from_source(
            TraceSource::online(WorkloadKind::PrefixChat, 4.0, 50.0, 7).with_prefix_share(0.0),
        );
        let half = Trace::from_source(
            TraceSource::online(WorkloadKind::PrefixChat, 4.0, 50.0, 7).with_prefix_share(0.5),
        );
        assert_eq!(full.requests.len(), none.requests.len());
        assert_eq!(full.requests.len(), half.requests.len());
        for ((a, b), c) in full.requests.iter().zip(&none.requests).zip(&half.requests) {
            assert_eq!((a.arrival, a.input_len, a.output_len), (b.arrival, b.input_len, b.output_len));
            assert_eq!((a.arrival, a.input_len, a.output_len), (c.arrival, c.input_len, c.output_len));
            assert!(a.prefix.is_some(), "share 1.0 declares every prefix");
            assert!(b.prefix.is_none(), "share 0.0 declares none");
            if let Some(px) = a.prefix {
                assert!(px.len < a.input_len, "prefix is a strict prefix of the prompt");
                let pp = WorkloadKind::PrefixChat.prefix_params().expect("params");
                assert_eq!(px.len, pp.prefix_len(px.id));
                assert!(px.id < pp.n_prefixes);
            }
        }
        let kept = half.requests.iter().filter(|r| r.prefix.is_some()).count();
        assert!(kept > 0 && kept < half.requests.len(), "{kept}");
    }

    #[test]
    fn prefix_default_share_and_zipf_skew() {
        // Intrinsic share applies without an override.
        let t = Trace::offline(WorkloadKind::Agent, 400, 5);
        let declared = t.requests.iter().filter(|r| r.prefix.is_some()).count() as f64;
        assert!((declared / 400.0 - 0.95).abs() < 0.05, "{declared}");
        // Zipf skew concentrates mass on low ids: id 0 strictly most common.
        let mut counts = std::collections::BTreeMap::new();
        for r in &t.requests {
            if let Some(px) = r.prefix {
                *counts.entry(px.id).or_insert(0usize) += 1;
            }
        }
        let c0 = counts.get(&0).copied().unwrap_or(0);
        for (&id, &c) in &counts {
            if id != 0 {
                assert!(c0 > c, "id 0 ({c0}) should dominate id {id} ({c})");
            }
        }
    }

    #[test]
    fn token_totals() {
        let t = Trace::offline(WorkloadKind::Lpld, 10, 1);
        assert_eq!(t.total_output_tokens(), t.requests.iter().map(|r| r.output_len).sum::<usize>());
        assert!(t.total_input_tokens() > 0);
    }
}
