//! Discrete-event simulation of LLM serving on a heterogeneous cluster,
//! driven by the Table-1 cost model (the executable substitute for the
//! paper's RunPod testbed — DESIGN.md §1). Callers normally reach the
//! engine through [`deploy::SimBackend`](crate::deploy::SimBackend) /
//! [`deploy::ReschedBackend`](crate::deploy::ReschedBackend).
//!
//! One engine ([`core`], DESIGN.md §9): a single event-driven driver with
//! pluggable [`ReplicaPolicy`] phase policies —
//! - [`run_disaggregated`]: HexGen-2/DistServe-style serving over a
//!   [`Placement`](crate::scheduler::Placement) — prefill token-budget
//!   batching (optionally chunked), per-link KV-transfer queues, decode
//!   continuous batching gated on KV arrival.
//! - [`run_colocated`]: HexGen/vLLM-style colocated serving where each
//!   iteration interleaves prefill and decode on the same replica (the
//!   prefill-decoding interference the paper eliminates), with optional
//!   SARATHI-style chunked prefill (Appendix D).
//! - [`simulate`]: the core entry itself — arbitrary epoch sequences
//!   (disaggregated and/or colocated) with quiesce/drain/activate
//!   rescheduling, static-mean or per-request memory accounting
//!   ([`SimConfig`]).

pub mod colocated;
pub mod core;
pub mod disagg;
pub mod events;
pub mod metrics;

pub use colocated::{run_colocated, run_colocated_cfg};
// `self::` disambiguates the submodule from the `core` crate.
pub use self::core::{
    simulate, simulate_stream, Outcome, PolicyEnv, PolicyKind, RecordMode, ReplicaPolicy, ReqStore,
    ServingSpec, SimConfig, Sizing, SwitchSpec,
};
pub use disagg::{
    run_disaggregated, run_disaggregated_cfg, run_disaggregated_with_resched, PlacementSwitch,
};
// Link/route semantics are owned by the KV transfer subsystem (DESIGN.md
// §11); re-exported here because the simulator config carries them.
pub use crate::kvtransfer::{LinkModel, RouteModel};
pub use metrics::{RequestRecord, SimReport, SimStats, WindowedAgg};

use crate::cluster::GpuType;
use crate::model::LlmSpec;
use crate::workload::Request;

/// SLO base latency for a request: its "single device execution latency"
/// (§2) on a reference H100, from the Table-1 formulas with memory limits
/// ignored (the base is notional — SLO scales are multiples of it).
pub fn slo_base(model: &LlmSpec, req: &Request) -> f64 {
    let g = GpuType::H100;
    let h2 = (model.hidden * model.hidden) as f64;
    let l = model.n_layers as f64;
    let prefill = 24.0 * (req.input_len as f64).max(1.0) * h2 * l / g.tflops();
    let scan = 12.0 * h2 * model.bytes_per_elem * l / g.mem_bw();
    let step_flops = 24.0 * h2 * l / g.tflops();
    prefill + (scan + step_flops) * req.output_len as f64
}

/// Per-iteration prefill token budget (paper Fig. 1 saturation point).
pub const PREFILL_TOKEN_BUDGET: f64 = 2048.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LLAMA2_70B;

    #[test]
    fn slo_base_scales_with_lengths() {
        let short = Request { id: 0, arrival: 0.0, input_len: 128, output_len: 16, prefix: None };
        let long = Request { id: 1, arrival: 0.0, input_len: 1024, output_len: 256, prefix: None };
        let a = slo_base(&LLAMA2_70B, &short);
        let b = slo_base(&LLAMA2_70B, &long);
        assert!(b > a * 5.0, "{a} vs {b}");
        assert!(a > 0.0);
    }
}
