//! Comparator systems from the paper's evaluation (§5.1 "Baselines"):
//!
//! - [`hexgen`]: HexGen (Jiang et al., 2024b) — colocated serving over
//!   heterogeneous GPUs with asymmetric parallelism and a genetic-algorithm
//!   scheduler. No disaggregation.
//! - [`distserve`]: DistServe (Zhong et al., 2024) — disaggregated serving
//!   on a *homogeneous* cluster with per-phase parallelism search.
//! - [`vllm`]: vLLM-style colocated continuous batching on a homogeneous
//!   cluster (Appendix F), with optional chunked prefill (Appendix D).
//!
//! Each baseline reuses the same cost model and the same unified simulation
//! core (`simulator::core` — the colocated baselines run the
//! [`Colocated`](crate::simulator::core::Colocated) policy, DistServe the
//! disaggregated ones), so differences in results isolate the *system
//! design* (disaggregation + heterogeneity-aware scheduling), as in the
//! paper. Engine-level scenario knobs (per-request KV admission, chunked
//! prefill, link contention) apply to every baseline uniformly through
//! [`SimConfig`](crate::simulator::SimConfig).

pub mod distserve;
pub mod hexgen;
pub mod vllm;
