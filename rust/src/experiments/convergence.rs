//! Figs. 10 & 11 (§5.3): convergence of the scheduling algorithm — the full
//! max-flow-guided edge swap vs the truncated random-swap variant vs the
//! genetic algorithm, over het setting 1 and all four workloads, plus the
//! resulting serving throughputs.

use crate::cluster::settings;
use crate::model::LlmSpec;
use crate::scheduler::{self, genetic, EvalCache, SwapMode};
use crate::simulator::run_disaggregated;
use crate::util::bench::Table;
use crate::util::stats;
use crate::workload::{Trace, WorkloadKind, OFFLINE_KINDS};

use super::{convergence_curve_cached, convergence_curve_ga_cached, ExpOpts};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Guided,
    RandomSwap,
    Genetic,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Guided => "ours",
            Strategy::RandomSwap => "ours w/o edge swap",
            Strategy::Genetic => "genetic algorithm",
        }
    }

    pub const ALL: [Strategy; 3] = [Strategy::Guided, Strategy::RandomSwap, Strategy::Genetic];
}

pub fn curve(
    strategy: Strategy,
    model: &LlmSpec,
    kind: WorkloadKind,
    seed: u64,
    opts: &ExpOpts,
) -> Vec<(f64, f64)> {
    curve_shared(strategy, model, kind, seed, opts, &EvalCache::new())
}

/// Fig. 10: per strategy × workload, the final objective and the time to
/// converge, aggregated over `runs` seeded repetitions (paper uses 15).
/// One [`EvalCache`] is shared across the whole strategy × workload × seed
/// sweep (same cluster/model throughout): GA populations re-breed the same
/// genomes across seeds and the guided/random searches revisit seed
/// layouts, so repeats are memo hits — curves are bit-identical to
/// fresh-cache runs (the cache contract, asserted in the tests below).
pub fn fig10_convergence(model: &LlmSpec, runs: usize, opts: &ExpOpts) -> Table {
    let cache = EvalCache::new();
    let mut t = Table::new(&[
        "workload",
        "strategy",
        "final est. tokens/s (mean)",
        "std",
        "time to best (s, mean)",
    ]);
    for kind in OFFLINE_KINDS {
        for strat in Strategy::ALL {
            let mut finals = Vec::new();
            let mut times = Vec::new();
            for r in 0..runs {
                let curve = curve_shared(strat, model, kind, r as u64, opts, &cache);
                if let Some(&(_, best)) = curve.last() {
                    finals.push(best);
                    // First time reaching within 1% of the best value.
                    let t_best = curve
                        .iter()
                        .find(|(_, v)| *v >= best * 0.99)
                        .map(|(tt, _)| *tt)
                        .unwrap_or(0.0);
                    times.push(t_best);
                }
            }
            t.row(&[
                kind.name().to_string(),
                strat.name().to_string(),
                format!("{:.0}", stats::mean(&finals)),
                format!("{:.0}", stats::stddev(&finals)),
                format!("{:.2}", stats::mean(&times)),
            ]);
        }
    }
    t
}

/// [`curve`] against the sweep-shared [`EvalCache`].
fn curve_shared(
    strat: Strategy,
    model: &LlmSpec,
    kind: WorkloadKind,
    seed: u64,
    opts: &ExpOpts,
    cache: &EvalCache,
) -> Vec<(f64, f64)> {
    let c = settings::het1();
    match strat {
        Strategy::Guided => {
            convergence_curve_cached(&c, model, kind, SwapMode::Guided, seed, opts, cache)
        }
        Strategy::RandomSwap => {
            convergence_curve_cached(&c, model, kind, SwapMode::Random, seed, opts, cache)
        }
        Strategy::Genetic => convergence_curve_ga_cached(&c, model, kind, seed, opts, cache),
    }
}

/// Fig. 11: simulated serving throughput of the placements each strategy
/// found (het setting 1, four workloads). Shares one [`EvalCache`] across
/// the strategy × workload sweep, like [`fig10_convergence`].
pub fn fig11_throughput(model: &LlmSpec, opts: &ExpOpts) -> Table {
    let c = settings::het1();
    let cache = EvalCache::new();
    let mut t = Table::new(&["workload", "ours", "w/o edge swap", "genetic"]);
    for kind in OFFLINE_KINDS {
        let trace = Trace::offline(kind, opts.offline_n(), opts.seed + 5);
        let mut cells = vec![kind.name().to_string()];
        for strat in Strategy::ALL {
            let tput = match strat {
                Strategy::Guided | Strategy::RandomSwap => {
                    let mut o = opts.sched_opts(kind);
                    o.swap_mode = if strat == Strategy::Guided {
                        SwapMode::Guided
                    } else {
                        SwapMode::Random
                    };
                    scheduler::schedule_with_cache(&c, model, &o, &cache)
                        .map(|r| run_disaggregated(&c, model, &r.placement, &trace).tokens_per_s())
                        .unwrap_or(0.0)
                }
                Strategy::Genetic => {
                    let o = opts.sched_opts(kind);
                    genetic::schedule_genetic_with_cache(&c, model, &o, &cache)
                        .map(|r| run_disaggregated(&c, model, &r.placement, &trace).tokens_per_s())
                        .unwrap_or(0.0)
                }
            };
            cells.push(format!("{tput:.0}"));
        }
        t.row(&cells);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OPT_30B;

    #[test]
    fn curves_are_monotone_and_positive() {
        let opts = ExpOpts { quick: true, seed: 0 };
        for strat in Strategy::ALL {
            let c = curve(strat, &OPT_30B, WorkloadKind::Lpld, 0, &opts);
            assert!(!c.is_empty(), "{strat:?} empty curve");
            for w in c.windows(2) {
                assert!(w[1].1 >= w[0].1 - 1e-9, "{strat:?} regressed");
                assert!(w[1].0 >= w[0].0, "{strat:?} time went backwards");
            }
            assert!(c.last().unwrap().1 > 0.0);
        }
    }

    #[test]
    fn shared_cache_never_changes_a_curve() {
        // The fig10/11 sharing contract: a curve computed against a cache
        // pre-warmed by *other* runs (different strategy, seed, workload)
        // is bit-identical to a fresh-cache curve, and an exact repeat
        // through the shared cache is too.
        let opts = ExpOpts { quick: true, seed: 0 };
        let cache = EvalCache::new();
        // Warm the cache with unrelated runs.
        let _ = curve_shared(Strategy::Guided, &OPT_30B, WorkloadKind::Hpld, 7, &opts, &cache);
        let _ = curve_shared(Strategy::Genetic, &OPT_30B, WorkloadKind::Lpld, 3, &opts, &cache);
        for strat in Strategy::ALL {
            let fresh = curve(strat, &OPT_30B, WorkloadKind::Lpld, 0, &opts);
            let shared = curve_shared(strat, &OPT_30B, WorkloadKind::Lpld, 0, &opts, &cache);
            let repeat = curve_shared(strat, &OPT_30B, WorkloadKind::Lpld, 0, &opts, &cache);
            let values =
                |c: &Vec<(f64, f64)>| c.iter().map(|&(_, v)| v).collect::<Vec<f64>>();
            // Wall-clock differs run to run; the objective trajectory must not.
            assert_eq!(values(&fresh), values(&shared), "{strat:?} shared cache changed curve");
            assert_eq!(values(&shared), values(&repeat), "{strat:?} repeat changed curve");
        }
    }

    #[test]
    fn guided_final_at_least_random() {
        // The paper's headline §5.3 claim, in expectation. Use 2 seeds and
        // compare means to keep the test fast yet stable.
        let opts = ExpOpts { quick: true, seed: 0 };
        let avg = |strat| {
            let mut s = 0.0;
            for seed in 0..2u64 {
                s += curve(strat, &OPT_30B, WorkloadKind::Hphd, seed, &opts)
                    .last()
                    .map(|x| x.1)
                    .unwrap_or(0.0);
            }
            s / 2.0
        };
        let g = avg(Strategy::Guided);
        let r = avg(Strategy::RandomSwap);
        assert!(g >= r * 0.95, "guided {g} well below random {r}");
    }
}
