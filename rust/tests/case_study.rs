//! Appendix-E case study: the scheduling algorithm on the small 4xH100 +
//! 4xA100 cluster, where the paper walks through every phase and reports
//! that the output matches exhaustive search.

use hexgen2::cluster::settings;
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{self, Objective, ScheduleOptions, SwapMode};
use hexgen2::simulator::run_disaggregated;
use hexgen2::workload::{Trace, WorkloadKind};

#[test]
fn phase1_spectral_partition_groups_by_type() {
    // Appendix E step 1: groups come out homogeneous (H100s with H100s,
    // A100s with A100s) because NVLink islands dominate the cut.
    let c = settings::case_study();
    let devs: Vec<usize> = (0..c.n()).collect();
    let groups = scheduler::spectral::partition_k(&c, &devs, 4);
    for g in &groups {
        let types: std::collections::HashSet<_> =
            g.iter().map(|&d| c.devices[d].gpu).collect();
        assert_eq!(types.len(), 1, "mixed group {g:?}");
        assert_eq!(g.len(), 2, "expected pairs, got {g:?}");
    }
}

#[test]
fn full_algorithm_produces_balanced_disaggregation() {
    let c = settings::case_study();
    let mut opts = ScheduleOptions::new(WorkloadKind::Lphd);
    opts.force_k = Some(4);
    let r = scheduler::schedule(&c, &OPT_30B, &opts).expect("schedules");
    let p = &r.placement;
    assert_eq!(p.groups.len(), 4);
    // Both phases live, every group feasible.
    assert!(!p.prefill_indices().is_empty());
    assert!(!p.decode_indices().is_empty());
    for g in &p.groups {
        assert!(g.config.is_some(), "infeasible group in tiny case study");
        assert!(g.capacity > 0.0);
    }
    // LPHD: decode-heavy => at least half the GPUs serve decode (Appendix E
    // swaps devices toward decode for LPHD).
    let decode_gpus: usize = p.decode_indices().iter().map(|&g| p.groups[g].devices.len()).sum();
    assert!(decode_gpus >= 4, "only {decode_gpus} GPUs on decode for LPHD");
}

#[test]
fn matches_exhaustive_search_on_type_assignment() {
    // With the partition fixed to the spectral pairs, our secondary
    // partition + max-flow must find the same objective as brute force over
    // all 2^4 type assignments.
    let c = settings::case_study();
    let task = scheduler::task_for(WorkloadKind::Lphd);
    let devs: Vec<usize> = (0..c.n()).collect();
    let groups = scheduler::spectral::partition_k(&c, &devs, 4);

    let cache = hexgen2::scheduler::strategy::StrategyCache::new();
    let ours = scheduler::evaluate_partition(
        &c,
        &OPT_30B,
        &task,
        600.0,
        &groups,
        64,
        Objective::Throughput,
        &cache,
    )
    .expect("placement");

    let mut brute_best = 0.0f64;
    for mask in 1u32..15 {
        let assign: Vec<bool> = (0..4).map(|g| mask & (1 << g) != 0).collect();
        if let Some(p) = hexgen2::scheduler::flownet::evaluate_types(
            &c, &OPT_30B, &task, 600.0, &groups, &assign, &cache,
        ) {
            brute_best = brute_best.max(p.flow_value);
        }
    }
    assert!(
        (ours.flow_value - brute_best).abs() < 1e-6 * brute_best,
        "ours {} != exhaustive {}",
        ours.flow_value,
        brute_best
    );
}

#[test]
fn guided_matches_or_beats_random_on_case_study() {
    let c = settings::case_study();
    let run = |mode, seed| {
        let mut o = ScheduleOptions::new(WorkloadKind::Lphd);
        o.swap_mode = mode;
        o.seed = seed;
        o.max_rounds = 8;
        scheduler::schedule(&c, &OPT_30B, &o).unwrap().placement.tokens_per_s
    };
    let g: f64 = (0..3).map(|s| run(SwapMode::Guided, s)).sum();
    let rnd: f64 = (0..3).map(|s| run(SwapMode::Random, s)).sum();
    assert!(g >= rnd * 0.95, "guided {g} well below random {rnd}");
}

#[test]
fn placement_survives_simulation() {
    let c = settings::case_study();
    let r = scheduler::schedule(&c, &OPT_30B, &ScheduleOptions::new(WorkloadKind::Lphd)).unwrap();
    let trace = Trace::offline(WorkloadKind::Lphd, 100, 9);
    let rep = run_disaggregated(&c, &OPT_30B, &r.placement, &trace);
    assert_eq!(rep.records.len(), 100, "requests lost");
    assert!(rep.tokens_per_s() > 0.0);
}
