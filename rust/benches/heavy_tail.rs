//! Bench: heavy-tail admission study — static mean-length sizing vs
//! per-request KV accounting on an extreme-dispersion trace.
use hexgen2::experiments::{endtoend, ExpOpts};
use hexgen2::model::OPT_30B;

fn main() {
    endtoend::heavy_tail_admission(&OPT_30B, "case_study", &ExpOpts::from_env())
        .expect("case_study setting exists")
        .print("Heavy-tail admission: static mean-length sizing vs per-request KV accounting (OPT-30B)");
}
