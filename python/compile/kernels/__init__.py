"""Layer-1 Pallas kernels for HexGen-2 (compile-time only; never at runtime).

Exports the flash prefill attention and paged decode attention kernels plus
their pure-jnp oracles. See DESIGN.md section "Hardware-Adaptation".
"""

from .attention import flash_prefill
from .decode import paged_decode
from .ref import decode_attention_ref, prefill_attention_ref

__all__ = [
    "flash_prefill",
    "paged_decode",
    "prefill_attention_ref",
    "decode_attention_ref",
]
