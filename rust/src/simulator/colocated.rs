//! Colocated serving entry point — a thin wrapper over the unified event
//! engine ([`core::simulate`](super::core::simulate)) instantiating one
//! [`Colocated`](super::core::Colocated) policy per replica: each iteration
//! interleaves prefill and decode on the same GPUs — continuous batching à
//! la Orca/vLLM — so every admitted prefill delays all running decodes (the
//! interference of paper Fig. 1). Optional SARATHI-style chunked prefill
//! (Appendix D) caps the prefill tokens per iteration, trading interference
//! for prefill latency.
//!
//! Used by the HexGen and vLLM baselines (`baselines/`). Because the
//! colocated policy runs inside the same core as the disaggregated ones,
//! mid-trace rescheduling (quiesce → drain → activate) works on colocated
//! deployments too — pass [`SwitchSpec`](super::SwitchSpec)s with
//! [`ServingSpec::Colocated`](super::ServingSpec) epochs to
//! [`simulate`](super::simulate).

use crate::cluster::Cluster;
use crate::costmodel::ReplicaConfig;
use crate::model::LlmSpec;
use crate::workload::Trace;

use super::core::{simulate, ServingSpec, SimConfig};
use super::metrics::SimReport;

/// Simulate colocated continuous batching over one or more replicas.
/// `chunk` = Some(c) enables chunked prefill with c-token chunks.
pub fn run_colocated(
    cluster: &Cluster,
    model: &LlmSpec,
    replicas: &[ReplicaConfig],
    trace: &Trace,
    chunk: Option<usize>,
) -> SimReport {
    run_colocated_cfg(cluster, model, replicas, trace, chunk, &SimConfig::default())
}

/// [`run_colocated`] with explicit engine knobs (per-request admission,
/// link contention model — chunking stays a per-plan argument).
pub fn run_colocated_cfg(
    cluster: &Cluster,
    model: &LlmSpec,
    replicas: &[ReplicaConfig],
    trace: &Trace,
    chunk: Option<usize>,
    cfg: &SimConfig,
) -> SimReport {
    simulate(
        cluster,
        model,
        &ServingSpec::Colocated { replicas: replicas.to_vec(), chunked_prefill: chunk },
        &[],
        trace,
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::workload::WorkloadKind;

    fn one_replica(_c: &Cluster) -> Vec<ReplicaConfig> {
        vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])]
    }

    #[test]
    fn completes_all_requests() {
        let c = settings::homogeneous_small();
        let trace = Trace::offline(WorkloadKind::Lpld, 40, 1);
        let rep = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, None);
        assert_eq!(rep.records.len(), 40);
        assert_eq!(rep.stats.unserved, 0);
        assert!(rep.tokens_per_s() > 0.0);
    }

    #[test]
    fn prefill_storm_inflates_decode_latency() {
        // The interference mechanism itself (Fig. 1 bottom): the same trace
        // with an added storm of heavy prefills must delay the completions of
        // decode-heavy requests on a colocated replica.
        let c = settings::homogeneous_small();
        let quiet = Trace::offline(WorkloadKind::Lphd, 10, 7);
        let mut stormy = quiet.clone();
        let base = stormy.requests.len();
        for i in 0..60 {
            stormy.requests.push(crate::workload::Request {
                id: base + i,
                arrival: 0.0,
                input_len: 2048,
                output_len: 8,
                prefix: None,
            });
        }
        let r_quiet = run_colocated(&c, &OPT_30B, &one_replica(&c), &quiet, None);
        let r_storm = run_colocated(&c, &OPT_30B, &one_replica(&c), &stormy, None);
        // Compare the same 10 decode-heavy requests.
        let lat = |rep: &crate::simulator::SimReport| {
            let mut v: Vec<f64> = rep
                .records
                .iter()
                .filter(|r| r.id < base)
                .map(|r| r.latency())
                .collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            crate::util::stats::mean(&v)
        };
        assert!(
            lat(&r_storm) > lat(&r_quiet) * 1.3,
            "no interference visible: {} vs {}",
            lat(&r_storm),
            lat(&r_quiet)
        );
    }

    #[test]
    fn disaggregation_within_range_of_colocation_at_small_scale() {
        // At 4-GPU scale the paper's own Table 4 shows disaggregation and
        // colocation trading wins per workload; assert the simulator keeps
        // them in the same ballpark (the decisive gaps appear at cluster
        // scale in the Fig. 6/7 harnesses).
        use crate::scheduler::{self, ScheduleOptions};
        let c = settings::homogeneous_small();
        let trace = Trace::offline(WorkloadKind::Hphd, 80, 2);
        let colo = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, None);
        let mut opts = ScheduleOptions::new(WorkloadKind::Hphd);
        opts.max_rounds = 6;
        opts.force_k = Some(2);
        let sched = scheduler::schedule(&c, &OPT_30B, &opts).unwrap();
        let disagg = crate::simulator::run_disaggregated(&c, &OPT_30B, &sched.placement, &trace);
        let ratio = disagg.tokens_per_s() / colo.tokens_per_s();
        assert!(
            (0.4..2.5).contains(&ratio),
            "disagg {} vs colo {}",
            disagg.tokens_per_s(),
            colo.tokens_per_s()
        );
    }

    #[test]
    fn chunked_prefill_improves_light_decode_workloads() {
        // Appendix D: chunked prefill helps most on HPLD/LPLD.
        let c = settings::homogeneous_small();
        let trace = Trace::offline(WorkloadKind::Hpld, 60, 3);
        let plain = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, None);
        let chunked = run_colocated(&c, &OPT_30B, &one_replica(&c), &trace, Some(512));
        assert_eq!(plain.records.len(), chunked.records.len());
        // Chunked must not be drastically worse; typically better on HPLD.
        assert!(chunked.tokens_per_s() > plain.tokens_per_s() * 0.8);
    }

    #[test]
    fn multiple_replicas_share_load() {
        let c = settings::homogeneous();
        let two = vec![
            ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers]),
            ReplicaConfig::new(vec![(4..8).collect()], vec![OPT_30B.n_layers]),
        ];
        let one = vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])];
        let trace = Trace::offline(WorkloadKind::Lphd, 100, 4);
        let r2 = run_colocated(&c, &OPT_30B, &two, &trace, None);
        let r1 = run_colocated(&c, &OPT_30B, &one, &trace, None);
        // Decode throughput is batch-bound, so doubling replicas mostly
        // helps the prefill phase here; require a strict improvement.
        assert!(r2.tokens_per_s() > r1.tokens_per_s(), "{} vs {}", r2.tokens_per_s(), r1.tokens_per_s());
    }
}
