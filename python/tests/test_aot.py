"""AOT bridge: manifest schema, HLO text format, golden file, and
idempotent rebuild — the ABI the Rust runtime consumes."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, ["tiny"], quiet=True)
    return out


def test_manifest_schema(built):
    man = json.load(open(os.path.join(built, "manifest.json")))
    assert man["format"] == 1
    tiny = man["models"]["tiny"]
    assert tiny["config"]["n_layers"] == M.TINY.n_layers
    assert tiny["config"]["d_model"] == M.TINY.d_model
    kinds = {m["kind"] for m in tiny["modules"]}
    assert kinds == {"prefill", "decode"}
    for m in tiny["modules"]:
        assert os.path.exists(os.path.join(built, m["file"])), m["file"]
        assert len(m["outputs"]) == 3
        logits = m["outputs"][0]
        assert logits["shape"] == [m["batch"], M.TINY.vocab]


def test_params_blob_layout(built):
    man = json.load(open(os.path.join(built, "manifest.json")))["models"]["tiny"]
    blob = open(os.path.join(built, man["params_file"]), "rb").read()
    assert len(blob) == man["params_bytes"]
    params = M.init_params(M.TINY, seed=man["seed"])
    # Spot-check: first param tensor round-trips from the blob.
    meta = man["params"][0]
    arr = np.frombuffer(
        blob[meta["offset"] : meta["offset"] + meta["elems"] * 4], dtype="<f4"
    ).reshape(meta["shape"])
    np.testing.assert_array_equal(arr, np.asarray(params[0]))
    # Offsets are contiguous and cover the blob.
    end = 0
    for p in man["params"]:
        assert p["offset"] == end
        end += p["elems"] * 4
    assert end == len(blob)


def test_hlo_is_text_not_proto(built):
    man = json.load(open(os.path.join(built, "manifest.json")))["models"]["tiny"]
    path = os.path.join(built, man["modules"][0]["file"])
    head = open(path).read(200)
    # HLO *text* (xla_extension 0.5.1 rejects jax>=0.5 serialized protos).
    assert head.startswith("HloModule"), head


def test_golden_file(built):
    g = json.load(open(os.path.join(built, "tiny.golden.json")))
    assert g["model"] == "tiny"
    assert len(g["tokens"]) == g["batch"] * g["seq"]
    assert len(g["prefill_argmax"]) == g["batch"]
    assert all(0 <= t < M.TINY.vocab for t in g["prefill_argmax"])
    # Golden logits are finite.
    assert all(np.isfinite(x) for x in g["prefill_logits_head"])
    assert all(np.isfinite(x) for x in g["decode_logits_head"])


def test_pallas_lowering_is_portable(built):
    # interpret=True must leave no Mosaic/TPU custom-calls in the HLO.
    man = json.load(open(os.path.join(built, "manifest.json")))["models"]["tiny"]
    for m in man["modules"][:2]:
        text = open(os.path.join(built, m["file"])).read()
        assert "mosaic" not in text.lower(), m["file"]
        assert "tpu_custom_call" not in text.lower(), m["file"]
