//! Planner hot-path perf harness (DESIGN.md §10): the same serving-loop
//! replay as `hexgen2 bench planner`, plus micro-timings of the two layers
//! the PR optimizes — memoized vs uncached partition evaluation and
//! incremental vs cold max-flow re-solves. Counter outputs (evals, hit
//! rates) are deterministic; timings are environment-dependent context.

use hexgen2::cluster::settings;
use hexgen2::experiments::perf;
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{
    flownet::PartitionFlowNet, maxflow::FlowNetwork, strategy::StrategyCache, Objective,
};
use hexgen2::util::bench;
use hexgen2::util::rng::Rng;
use hexgen2::workload::WorkloadKind;

fn main() {
    // The serving-loop replay (writes nothing; prints per-case counters).
    let doc = perf::bench_planner(true, 2);
    println!("{}", doc.to_string_pretty());

    // Incremental vs cold max-flow on a random graph with capacity churn.
    let mut rng = Rng::new(11);
    let n = 48;
    let mut g = FlowNetwork::new(n);
    let mut edges = Vec::new();
    for _ in 0..n * 5 {
        let u = rng.range(0, n);
        let mut v = rng.range(0, n);
        if u == v {
            v = (v + 1) % n;
        }
        edges.push(g.add_edge(u, v, rng.range_f64(0.1, 10.0)));
    }
    let _ = g.max_flow_incremental(0, n - 1);
    let mut churn_rng = Rng::new(12);
    bench::time("planner_hotpath/maxflow-incremental-3-edge-churn", 3, 50, || {
        for _ in 0..3 {
            let e = edges[churn_rng.range(0, edges.len())];
            g.set_capacity(e, churn_rng.range_f64(0.1, 10.0));
        }
        std::hint::black_box(g.max_flow_incremental(0, n - 1));
    });
    let mut cold_rng = Rng::new(12);
    bench::time("planner_hotpath/maxflow-cold-3-edge-churn", 3, 50, || {
        let mut h = FlowNetwork::new(n);
        let mut es = Vec::with_capacity(edges.len());
        let mut build_rng = Rng::new(11);
        for _ in 0..n * 5 {
            let u = build_rng.range(0, n);
            let mut v = build_rng.range(0, n);
            if u == v {
                v = (v + 1) % n;
            }
            es.push(h.add_edge(u, v, build_rng.range_f64(0.1, 10.0)));
        }
        for _ in 0..3 {
            let e = es[cold_rng.range(0, es.len())];
            h.set_capacity(e, cold_rng.range_f64(0.1, 10.0));
        }
        std::hint::black_box(h.max_flow(0, n - 1));
    });

    // Type-assignment sweep: incremental PartitionFlowNet vs per-assignment
    // one-shot evaluation (both on a warm strategy cache).
    let c = settings::case_study();
    let task = hexgen2::scheduler::task_for(WorkloadKind::Lphd);
    let groups: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
    let cache = StrategyCache::new();
    // Warm the strategy entries once so both sides time the flow layer.
    let _ = hexgen2::scheduler::evaluate_partition(
        &c, &OPT_30B, &task, 600.0, &groups, 64, Objective::Throughput, &cache,
    );
    bench::time("planner_hotpath/type-sweep-incremental-14-assignments", 3, 30, || {
        let mut net = PartitionFlowNet::new(&c, &OPT_30B, &task, 600.0, &groups, &cache);
        for mask in 1u32..15 {
            let assign: Vec<bool> = (0..4).map(|g| mask & (1 << g) != 0).collect();
            std::hint::black_box(net.evaluate(&assign));
        }
    });
    bench::time("planner_hotpath/type-sweep-oneshot-14-assignments", 3, 30, || {
        for mask in 1u32..15 {
            let assign: Vec<bool> = (0..4).map(|g| mask & (1 << g) != 0).collect();
            std::hint::black_box(hexgen2::scheduler::flownet::evaluate_types(
                &c, &OPT_30B, &task, 600.0, &groups, &assign, &cache,
            ));
        }
    });
}
