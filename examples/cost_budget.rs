//! Cost-efficiency study (paper Fig. 9): HexGen-2 on heterogeneous setting 5
//! — 70% of the homogeneous budget — vs DistServe on 8xH100, per workload.
//!
//! Run:  cargo run --release --example cost_budget

use hexgen2::cluster::settings;
use hexgen2::experiments::{endtoend, ExpOpts};
use hexgen2::model::LLAMA2_70B;

fn main() {
    let het5 = settings::het5();
    let hom = settings::homogeneous();
    println!(
        "budgets: het5 ${:.2}/h vs homogeneous ${:.2}/h ({:.0}%)\n",
        het5.budget_per_hour(),
        hom.budget_per_hour(),
        100.0 * het5.budget_per_hour() / hom.budget_per_hour()
    );
    let t = endtoend::fig9_budget(&LLAMA2_70B, &ExpOpts::from_env());
    t.print("Fig. 9: throughput at 70% price budget (LLaMA-2-70B)");
    println!("\nratio >= 1.0 means the cheaper heterogeneous cluster matches or beats 8xH100.");
}
