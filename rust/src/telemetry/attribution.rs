//! Critical-path latency attribution and the cluster bottleneck advisor
//! (DESIGN.md §16).
//!
//! The flight recorder (§12) captures *what happened* to every request;
//! this module reconstructs *where the time went*. Each request's causal
//! chain — `Arrive → Admit/Hold → PrefillChunk* → PrefillDone → KvEnqueue
//! → KvXfer → KvDone → DecodeJoin → Finish` — folds into a per-request
//! **blame vector** of [`N_COMPONENTS`] non-overlapping components whose
//! sum equals the measured end-to-end latency *bit-exactly*
//! ([`BlameVector::close`]); the cluster-wide [`AttrReport`] aggregates
//! them per component, per replica, per KV route/NIC, and per time window
//! (TTFT vs TBT split), and [`advise`] ranks the dominant blame terms
//! against the planner's own levers by re-scoring the incumbent partition
//! through [`evaluate_partition_with`] with the corresponding capacity
//! perturbed.
//!
//! Two operating points, one accumulator:
//! - **Online** ([`AttribRecorder`]): wraps the ring-buffer [`Recorder`]
//!   as a [`TraceSink`]; the [`Attributor`] observes every event *before*
//!   sampling and ring wrap, so attribution stays exact even when the
//!   exported trace is sampled or truncated. State is O(active requests)
//!   — open chains die on `Finish`/`Reject` — so `RecordMode::Windowed`
//!   million-request runs get attribution inside the CI RSS guard.
//! - **Replay** ([`attribute_log`]): re-derive the same report from a
//!   finished [`TraceLog`] (exact only at sample rate 1.0 with no ring
//!   drops — the conservation caveat of `derive_metrics` applies).

use std::collections::BTreeMap;

use crate::cluster::Cluster;
use crate::costmodel::TaskProfile;
use crate::kvtransfer::LinkModel;
use crate::model::LlmSpec;
use crate::scheduler::strategy::StrategyCache;
use crate::scheduler::{evaluate_partition_with, Objective};
use crate::simulator::metrics::QuantileSketch;
use crate::util::json::{self, Json};

use super::{Lane, Recorder, TraceEvent, TraceLog, TraceSink};

/// Blame component indices, in the canonical (summation) order. The order
/// is load-bearing: per-request conservation folds the floating-point
/// residual into [`DECODE_COMPUTE`], the last term.
pub const ADMISSION_WAIT: usize = 0;
pub const PREFILL_QUEUE: usize = 1;
pub const PREFILL_COMPUTE: usize = 2;
pub const PREFILL_INTERLEAVE: usize = 3;
pub const KV_SERIALIZE_WAIT: usize = 4;
pub const KV_TRANSMIT: usize = 5;
pub const DECODE_BATCH_WAIT: usize = 6;
pub const DECODE_COMPUTE: usize = 7;
pub const N_COMPONENTS: usize = 8;

/// Component names, indexed by the constants above (the attr/v1 schema
/// keys).
pub const COMPONENT_NAMES: [&str; N_COMPONENTS] = [
    "admission_wait",
    "prefill_queue",
    "prefill_compute",
    "prefill_interleave",
    "kv_serialize_wait",
    "kv_transmit",
    "decode_batch_wait",
    "decode_compute",
];

/// Default aggregation window for the TTFT-vs-TBT split (matches the
/// Prometheus exporter's default).
pub const DEFAULT_WINDOW_S: f64 = 60.0;

/// One request's latency decomposition. Components are wall-clock seconds
/// of the request's own end-to-end span; they partition `[arrival,
/// finish]`, so concurrent requests legitimately blame the same busy
/// second of a replica (blame is per-request time, not device time).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BlameVector {
    pub c: [f64; N_COMPONENTS],
}

impl BlameVector {
    /// Sum in canonical component order (the conservation-invariant side).
    pub fn total(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..N_COMPONENTS {
            s += self.c[i];
        }
        s
    }

    /// Enforce the conservation invariant: iteratively fold the
    /// floating-point summation residual into the last component until
    /// `total() == latency` bit-exactly. Each pass is a compensated-sum
    /// refinement step; because every component is bounded by the latency,
    /// the residual is at ulp scale and the fixpoint lands in a step or
    /// two (the bound is pure paranoia).
    pub fn close(&mut self, latency: f64) {
        for _ in 0..32 {
            let r = latency - self.total();
            if r == 0.0 {
                return;
            }
            let before = self.c[DECODE_COMPUTE];
            self.c[DECODE_COMPUTE] += r;
            if self.c[DECODE_COMPUTE] == before {
                // Residual below the component's ulp: no further progress
                // is possible (never observed for non-degenerate chains).
                return;
            }
        }
    }
}

/// One finished request's attribution (`RecordMode::Full` only — the
/// windowed path keeps aggregates and drops per-request vectors).
#[derive(Clone, Copy, Debug)]
pub struct RequestBlame {
    pub req: u32,
    pub arrival: f64,
    pub finish: f64,
    /// Replica that generated the final token.
    pub replica: u32,
    pub blame: BlameVector,
}

impl RequestBlame {
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// Per-window TTFT-vs-TBT split (window of a request = the window its
/// `Finish` lands in, mirroring `SimReport::windowed` completion
/// bucketing).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowBlame {
    /// Summed `PrefillDone − Arrive` over the window's finishers.
    pub ttft_s: f64,
    /// Summed decode span (`Finish − PrefillDone`).
    pub tbt_s: f64,
    pub n: u32,
}

/// Open causal chain of an in-flight request. One entry per *active*
/// request — the windowed-mode memory contract.
#[derive(Clone, Copy, Debug, Default)]
struct Open {
    t_arrive: f64,
    t_admit: f64,
    t_first_work: f64,
    prefill_compute: f64,
    t_prefill_done: f64,
    prefill_replica: u32,
    kv_wait: f64,
    kv_src: u32,
    kv_dst: u32,
    t_kv_done: f64,
    t_join: f64,
    has_admit: bool,
    has_work: bool,
    has_prefill_done: bool,
    has_kv_done: bool,
    has_join: bool,
}

/// Streaming attribution accumulator: feed it every [`TraceEvent`] in
/// stamp order (via [`AttribRecorder`] online, or [`attribute_log`] in
/// replay) and [`Attributor::finish`] the report.
#[derive(Clone, Debug)]
pub struct Attributor {
    window_s: f64,
    keep_requests: bool,
    open: BTreeMap<u32, Open>,
    /// Requests whose prefill chunks were scheduled in the burst the
    /// engine is about to stamp, per replica (`PrefillChunk` precedes its
    /// `Burst` at the same timestamp).
    pending_chunks: BTreeMap<u32, Vec<u32>>,
    /// Last prefill burst per replica, `(start, dur)` — matches unchunked
    /// disaggregated prefills to their burst (`PrefillDone` lands
    /// bit-exactly on `start + dur`, the engine's own heap key).
    last_burst: BTreeMap<u32, (f64, f64)>,
    // --- aggregates (all O(replicas + routes + windows)) ---
    n: usize,
    totals: BlameVector,
    per_replica: BTreeMap<u32, BlameVector>,
    per_route: BTreeMap<(u32, u32), (f64, f64)>,
    per_nic: BTreeMap<u32, (f64, f64)>,
    stalls: BTreeMap<u32, usize>,
    windows: Vec<WindowBlame>,
    latency_sum: f64,
    ttft_sum: f64,
    /// KV queue-wait folded in engine emission order — the bit-exact
    /// anchor against `SimStats::kv_link_wait_s` (includes transfers whose
    /// requests never finished).
    kv_wait_seen_s: f64,
    ttft_sketch: QuantileSketch,
    tbt_sketch: QuantileSketch,
    latency_sketch: QuantileSketch,
    requests: Vec<RequestBlame>,
}

impl Attributor {
    /// `keep_requests` retains per-request [`RequestBlame`] vectors
    /// (`RecordMode::Full`); the windowed path passes `false` and keeps
    /// only the aggregates.
    pub fn new(window_s: f64, keep_requests: bool) -> Attributor {
        Attributor {
            window_s: if window_s > 0.0 { window_s } else { DEFAULT_WINDOW_S },
            keep_requests,
            open: BTreeMap::new(),
            pending_chunks: BTreeMap::new(),
            last_burst: BTreeMap::new(),
            n: 0,
            totals: BlameVector::default(),
            per_replica: BTreeMap::new(),
            per_route: BTreeMap::new(),
            per_nic: BTreeMap::new(),
            stalls: BTreeMap::new(),
            windows: Vec::new(),
            latency_sum: 0.0,
            ttft_sum: 0.0,
            kv_wait_seen_s: 0.0,
            ttft_sketch: QuantileSketch::new(),
            tbt_sketch: QuantileSketch::new(),
            latency_sketch: QuantileSketch::new(),
            requests: Vec::new(),
        }
    }

    /// In-flight chain count (the windowed-memory contract's observable).
    pub fn open_len(&self) -> usize {
        self.open.len()
    }

    /// Observe one event. Events must arrive in stamp order (the engine's
    /// emission order); same-stamp ordering follows emission order too,
    /// which the chunk→burst and done→burst matches rely on.
    pub fn observe(&mut self, t: f64, ev: TraceEvent) {
        match ev {
            TraceEvent::Arrive { req } => {
                let o = self.open.entry(req).or_default();
                o.t_arrive = t;
            }
            TraceEvent::Admit { req, replica } => {
                if let Some(o) = self.open.get_mut(&req) {
                    // Re-admission after a rescheduling blackout restarts
                    // the queue clock only if no prefill work ran yet;
                    // otherwise the blackout is interleave, not admission.
                    if !o.has_admit || !o.has_work {
                        o.t_admit = t;
                        o.has_admit = true;
                    }
                    o.prefill_replica = replica;
                }
            }
            TraceEvent::Hold { .. } => {}
            TraceEvent::Reject { req } => {
                self.open.remove(&req);
            }
            TraceEvent::MemStall { replica } => {
                *self.stalls.entry(replica).or_default() += 1;
            }
            TraceEvent::PrefillChunk { req, replica, .. } => {
                self.pending_chunks.entry(replica).or_default().push(req);
            }
            TraceEvent::Burst { replica, lane, dur_s } => {
                if let Some(reqs) = self.pending_chunks.get_mut(&replica) {
                    for req in reqs.drain(..) {
                        if let Some(o) = self.open.get_mut(&req) {
                            o.prefill_compute += dur_s;
                            if !o.has_work {
                                o.t_first_work = t;
                                o.has_work = true;
                            }
                        }
                    }
                }
                if lane == Lane::Prefill {
                    self.last_burst.insert(replica, (t, dur_s));
                }
            }
            TraceEvent::PrefillDone { req, replica } => {
                if let Some(o) = self.open.get_mut(&req) {
                    o.t_prefill_done = t;
                    o.prefill_replica = replica;
                    o.has_prefill_done = true;
                    if !o.has_work {
                        // Unchunked disaggregated prefill emits no chunk
                        // events; its whole-batch burst ends exactly at
                        // this stamp (`start + dur` is the engine's own
                        // completion key, so the f64 match is exact).
                        if let Some(&(bs, bd)) = self.last_burst.get(&replica) {
                            if bs + bd == t {
                                o.prefill_compute += bd;
                                o.t_first_work = bs;
                                o.has_work = true;
                            }
                        }
                    }
                }
            }
            TraceEvent::KvEnqueue { req, src, dst, wait_s, .. } => {
                self.kv_wait_seen_s += wait_s;
                if let Some(o) = self.open.get_mut(&req) {
                    o.kv_wait += wait_s;
                    o.kv_src = src;
                    o.kv_dst = dst;
                }
            }
            TraceEvent::KvXfer { .. } => {}
            TraceEvent::KvDone { req, src, dst } => {
                if let Some(o) = self.open.get_mut(&req) {
                    o.t_kv_done = t;
                    o.kv_src = src;
                    o.kv_dst = dst;
                    o.has_kv_done = true;
                }
            }
            TraceEvent::DecodeJoin { req, .. } => {
                if let Some(o) = self.open.get_mut(&req) {
                    if !o.has_join {
                        o.t_join = t;
                        o.has_join = true;
                    }
                }
            }
            TraceEvent::Finish { req, replica, output_len } => {
                if let Some(o) = self.open.remove(&req) {
                    self.fold(t, req, replica, output_len, &o);
                }
            }
            TraceEvent::Quiesce { .. }
            | TraceEvent::Activate { .. }
            | TraceEvent::PrefixHit { .. }
            | TraceEvent::PrefixMiss { .. }
            | TraceEvent::PrefixEvict { .. } => {}
        }
    }

    /// Decompose one finished chain and fold it into the aggregates.
    fn fold(&mut self, t: f64, req: u32, replica: u32, output_len: u32, o: &Open) {
        let latency = t - o.t_arrive;
        let t_admit = if o.has_admit { o.t_admit } else { o.t_arrive };
        let t_pd = if o.has_prefill_done { o.t_prefill_done } else { t };
        let mut b = BlameVector::default();
        b.c[ADMISSION_WAIT] = t_admit - o.t_arrive;
        if o.has_work {
            b.c[PREFILL_QUEUE] = o.t_first_work - t_admit;
            b.c[PREFILL_COMPUTE] = o.prefill_compute;
            // Remainder of the admit → prefill-done span: the request sat
            // admitted while *other* requests' chunks ran (SARATHI
            // interleaving), or drained through a rescheduling blackout.
            b.c[PREFILL_INTERLEAVE] =
                (t_pd - t_admit) - (o.t_first_work - t_admit) - o.prefill_compute;
        } else {
            // No burst ever matched (fully degenerate chain): the whole
            // span up to prefill-done is queueing.
            b.c[PREFILL_QUEUE] = t_pd - t_admit;
        }
        if o.has_kv_done {
            b.c[KV_SERIALIZE_WAIT] = o.kv_wait;
            // Chunked transfers credit prefill overlap, so `done −
            // prefill_done` is `wait + transmit − credit`; the ledger
            // bounds the credit by the transmit time, keeping this term
            // non-negative.
            b.c[KV_TRANSMIT] = (o.t_kv_done - t_pd) - o.kv_wait;
        }
        let t_ready = if o.has_kv_done { o.t_kv_done } else { t_pd };
        let t_join = if o.has_join { o.t_join } else { t_ready };
        b.c[DECODE_BATCH_WAIT] = t_join - t_ready;
        b.c[DECODE_COMPUTE] = t - t_join;
        b.close(latency);

        self.n += 1;
        self.latency_sum += latency;
        let ttft = t_pd - o.t_arrive;
        self.ttft_sum += ttft;
        for i in 0..N_COMPONENTS {
            self.totals.c[i] += b.c[i];
        }
        {
            let pre = self.per_replica.entry(o.prefill_replica).or_default();
            for i in ADMISSION_WAIT..=PREFILL_INTERLEAVE {
                pre.c[i] += b.c[i];
            }
        }
        {
            let dec = self.per_replica.entry(replica).or_default();
            dec.c[DECODE_BATCH_WAIT] += b.c[DECODE_BATCH_WAIT];
            dec.c[DECODE_COMPUTE] += b.c[DECODE_COMPUTE];
        }
        if o.has_kv_done {
            let r = self.per_route.entry((o.kv_src, o.kv_dst)).or_default();
            r.0 += b.c[KV_SERIALIZE_WAIT];
            r.1 += b.c[KV_TRANSMIT];
            let n = self.per_nic.entry(o.kv_src).or_default();
            n.0 += b.c[KV_SERIALIZE_WAIT];
            n.1 += b.c[KV_TRANSMIT];
        }
        let w = (t / self.window_s).max(0.0) as usize;
        if w >= self.windows.len() {
            self.windows.resize(w + 1, WindowBlame::default());
        }
        self.windows[w].ttft_s += ttft;
        self.windows[w].tbt_s += t - t_pd;
        self.windows[w].n += 1;
        self.ttft_sketch.push(ttft);
        self.tbt_sketch.push((t - t_pd) / (output_len.saturating_sub(1).max(1)) as f64);
        self.latency_sketch.push(latency);
        if self.keep_requests {
            self.requests.push(RequestBlame {
                req,
                arrival: o.t_arrive,
                finish: t,
                replica,
                blame: b,
            });
        }
    }

    /// Close the accumulator into the exported report. Requests still
    /// in flight are dropped (counted in [`AttrReport::open_at_end`]) —
    /// blame only covers completed chains, like every latency metric.
    pub fn finish(self) -> AttrReport {
        AttrReport {
            n: self.n,
            window_s: self.window_s,
            totals: self.totals,
            per_replica: self.per_replica,
            per_route: self.per_route,
            per_nic: self.per_nic,
            stalls: self.stalls,
            windows: self.windows,
            latency_sum: self.latency_sum,
            ttft_sum: self.ttft_sum,
            kv_wait_seen_s: self.kv_wait_seen_s,
            ttft_sketch: self.ttft_sketch,
            tbt_sketch: self.tbt_sketch,
            latency_sketch: self.latency_sketch,
            requests: self.requests,
            open_at_end: self.open.len(),
        }
    }
}

/// [`TraceSink`] that tees every event into an [`Attributor`] *before*
/// the ring-buffer [`Recorder`]'s sampling/wrap, so attribution is exact
/// regardless of `--trace-sample` or ring capacity.
#[derive(Clone, Debug)]
pub struct AttribRecorder {
    pub rec: Recorder,
    pub attr: Attributor,
}

impl AttribRecorder {
    pub fn new(rec: Recorder, attr: Attributor) -> AttribRecorder {
        AttribRecorder { rec, attr }
    }
}

impl TraceSink for AttribRecorder {
    #[inline]
    fn emit(&mut self, t: f64, ev: TraceEvent) {
        self.attr.observe(t, ev);
        self.rec.emit(t, ev);
    }

    #[inline]
    fn recorder(&mut self) -> Option<&mut Recorder> {
        Some(&mut self.rec)
    }

    #[inline]
    fn active(&mut self) -> Option<&mut dyn TraceSink> {
        Some(self)
    }
}

/// Replay attribution over a finished trace. Exact only when the log kept
/// everything (`sample_rate == 1.0`, `dropped == 0`); a sampled log still
/// yields an unbiased *per-kept-request* report.
pub fn attribute_log(log: &TraceLog, window_s: f64) -> AttrReport {
    let mut a = Attributor::new(window_s, true);
    for s in &log.events {
        a.observe(s.t, s.ev);
    }
    a.finish()
}

/// The cluster-wide bottleneck report (`hexgen2 attribute` /
/// `--attribution`, schema `hexgen2-attr/v1`).
#[derive(Clone, Debug)]
pub struct AttrReport {
    /// Finished requests attributed.
    pub n: usize,
    pub window_s: f64,
    /// Cluster-wide blame totals, seconds per component.
    pub totals: BlameVector,
    /// Prefill-side components on the prefill replica, decode-side on the
    /// finishing replica (KV components live in the route/NIC maps).
    pub per_replica: BTreeMap<u32, BlameVector>,
    /// `(src, dst) → (serialize_wait_s, transmit_s)`.
    pub per_route: BTreeMap<(u32, u32), (f64, f64)>,
    /// Egress NIC (prefill src) → `(serialize_wait_s, transmit_s)`.
    pub per_nic: BTreeMap<u32, (f64, f64)>,
    /// Memory-stall events per replica (stall *time* surfaces inside
    /// `prefill_queue`; these counters disambiguate which replica's
    /// memory caused it).
    pub stalls: BTreeMap<u32, usize>,
    pub windows: Vec<WindowBlame>,
    pub latency_sum: f64,
    pub ttft_sum: f64,
    /// Bit-exact anchor against `SimStats::kv_link_wait_s` (accumulated
    /// in engine emission order over *all* transfers).
    pub kv_wait_seen_s: f64,
    pub ttft_sketch: QuantileSketch,
    /// Per-request mean time-between-tokens (decode span / (out − 1)).
    pub tbt_sketch: QuantileSketch,
    pub latency_sketch: QuantileSketch,
    /// `RecordMode::Full` only; empty in windowed runs.
    pub requests: Vec<RequestBlame>,
    /// Chains still open when the run ended (unserved/in-flight).
    pub open_at_end: usize,
}

impl AttrReport {
    /// Aggregate conservation residual: `Σ latency − Σ blame`. Zero per
    /// request by construction; the aggregate differs only by summation
    /// re-ordering, so it stays at ulp scale.
    pub fn residual_s(&self) -> f64 {
        self.latency_sum - self.totals.total()
    }

    /// The dominant blame component `(index, seconds)`.
    pub fn dominant(&self) -> (usize, f64) {
        let mut best = 0;
        for i in 1..N_COMPONENTS {
            if self.totals.c[i] > self.totals.c[best] {
                best = i;
            }
        }
        (best, self.totals.c[best])
    }

    /// Name of the dominant component (the drift-audit blame tag).
    pub fn dominant_name(&self) -> &'static str {
        COMPONENT_NAMES[self.dominant().0]
    }
}

// ---------------------------------------------------------------------------
// Advisor
// ---------------------------------------------------------------------------

/// Everything the advisor needs to *price* a lever: the incumbent
/// partition and the planner inputs that scored it. Built by the deploy
/// layer from the spec + plan; without it ([`advise`] with `None`) the
/// advisor still ranks, it just cannot price.
#[derive(Clone, Debug)]
pub struct AdvisorCtx<'a> {
    pub cluster: &'a Cluster,
    pub model: &'a LlmSpec,
    pub task: TaskProfile,
    pub period: f64,
    /// Incumbent device partition (`Placement` group devices).
    pub groups: Vec<Vec<usize>>,
    pub objective: Objective,
    /// Link model the plan was chosen (and the run executed) under.
    pub link: Option<LinkModel>,
}

/// One ranked "what to fix next" line: a blame component, the planner
/// lever that attacks it, and the incumbent's re-scored objective with
/// the corresponding capacity perturbed.
#[derive(Clone, Debug)]
pub struct Advice {
    /// Component index (into [`COMPONENT_NAMES`]).
    pub component: usize,
    pub blame_s: f64,
    /// Fraction of total attributed latency.
    pub share: f64,
    pub lever: &'static str,
    /// Incumbent score under the run's own conditions (0 when unpriced).
    pub baseline_score: f64,
    /// Incumbent score with the lever's capacity perturbation applied.
    pub predicted_score: f64,
}

impl Advice {
    pub fn component_name(&self) -> &'static str {
        COMPONENT_NAMES[self.component]
    }

    /// Predicted objective gain of pulling the lever (0 when unpriced).
    pub fn gain(&self) -> f64 {
        self.predicted_score - self.baseline_score
    }
}

/// The planner lever that attacks a blame component.
pub fn lever_for(component: usize) -> &'static str {
    match component {
        ADMISSION_WAIT | PREFILL_QUEUE | PREFILL_COMPUTE => "shift-pd-split-toward-prefill",
        PREFILL_INTERLEAVE => "raise-chunk-size",
        KV_SERIALIZE_WAIT | KV_TRANSMIT => "add-kv-bandwidth",
        _ => "shift-pd-split-toward-decode",
    }
}

/// Re-score the incumbent with a perturbed task/link — the pricing
/// primitive (a fresh [`StrategyCache`] per call: the advisor runs once
/// per report, not in the planner's hot loop).
fn rescore(ctx: &AdvisorCtx, task: &TaskProfile, link: Option<LinkModel>) -> f64 {
    let cache = StrategyCache::new();
    evaluate_partition_with(
        ctx.cluster,
        ctx.model,
        task,
        ctx.period,
        &ctx.groups,
        6,
        ctx.objective,
        link,
        &cache,
    )
    .map(|p| p.objective_score)
    .unwrap_or(0.0)
}

/// Rank blame components (largest first) and price each against its
/// lever by re-scoring the incumbent through `evaluate_partition` with
/// the corresponding capacity perturbed:
///
/// - **add-kv-bandwidth** — drop the KV-contention discount (score the
///   partition as if the fabric kept up): the gap *is* the bandwidth
///   headroom.
/// - **shift-pd-split-toward-prefill / -decode** — lighten the blamed
///   phase's demand by 10% (`s_in`/`s_out` × 0.9): the score delta prices
///   what one step of P:D rebalancing buys.
/// - **raise-chunk-size** — interleave waits shrink as chunks grow;
///   modeled as the same 10% prefill-demand reclaim.
pub fn advise(rep: &AttrReport, ctx: Option<&AdvisorCtx>) -> Vec<Advice> {
    let mut order: Vec<usize> = (0..N_COMPONENTS).collect();
    // Stable by construction: sort_by on equal keys keeps index order.
    order.sort_by(|&a, &b| {
        rep.totals.c[b].partial_cmp(&rep.totals.c[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let total = rep.totals.total();
    let baseline = ctx.map(|c| rescore(c, &c.task, c.link)).unwrap_or(0.0);
    order
        .into_iter()
        .filter(|&i| rep.totals.c[i] > 0.0)
        .map(|i| {
            let lever = lever_for(i);
            let predicted = match ctx {
                None => 0.0,
                Some(c) => match lever {
                    "add-kv-bandwidth" => rescore(c, &c.task, None),
                    "shift-pd-split-toward-decode" => {
                        let t = TaskProfile::new(1, c.task.s_in, c.task.s_out * 0.9);
                        rescore(c, &t, c.link)
                    }
                    // toward-prefill and raise-chunk-size both reclaim
                    // prefill-side demand.
                    _ => {
                        let t = TaskProfile::new(1, c.task.s_in * 0.9, c.task.s_out);
                        rescore(c, &t, c.link)
                    }
                },
            };
            Advice {
                component: i,
                blame_s: rep.totals.c[i],
                share: if total > 0.0 { rep.totals.c[i] / total } else { 0.0 },
                lever,
                baseline_score: baseline,
                predicted_score: predicted,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Export
// ---------------------------------------------------------------------------

fn blame_obj(b: &BlameVector) -> Vec<(&'static str, Json)> {
    (0..N_COMPONENTS).map(|i| (COMPONENT_NAMES[i], json::num(b.c[i]))).collect()
}

/// The `--attribution` file format (schema `hexgen2-attr/v1`): blame
/// totals + shares, per-replica/route/NIC splits, the TTFT-vs-TBT window
/// series, sketch quantiles, and the ranked advisor verdicts.
pub fn attr_json(rep: &AttrReport, advice: &[Advice]) -> Json {
    let total = rep.totals.total();
    let mut share = BlameVector::default();
    if total > 0.0 {
        for i in 0..N_COMPONENTS {
            share.c[i] = rep.totals.c[i] / total;
        }
    }
    let per_replica: Vec<Json> = rep
        .per_replica
        .iter()
        .map(|(r, b)| {
            let mut fields = vec![("replica", json::num(*r as f64))];
            fields.extend(blame_obj(b));
            json::obj(fields)
        })
        .collect();
    let per_route: Vec<Json> = rep
        .per_route
        .iter()
        .map(|((s, d), (w, x))| {
            json::obj(vec![
                ("src", json::num(*s as f64)),
                ("dst", json::num(*d as f64)),
                ("serialize_wait_s", json::num(*w)),
                ("transmit_s", json::num(*x)),
            ])
        })
        .collect();
    let per_nic: Vec<Json> = rep
        .per_nic
        .iter()
        .map(|(n, (w, x))| {
            json::obj(vec![
                ("nic", json::num(*n as f64)),
                ("serialize_wait_s", json::num(*w)),
                ("transmit_s", json::num(*x)),
            ])
        })
        .collect();
    let stalls: Vec<Json> = rep
        .stalls
        .iter()
        .map(|(r, n)| {
            json::obj(vec![("replica", json::num(*r as f64)), ("stalls", json::num(*n as f64))])
        })
        .collect();
    let windows: Vec<Json> = rep
        .windows
        .iter()
        .enumerate()
        .filter(|(_, w)| w.n > 0)
        .map(|(i, w)| {
            json::obj(vec![
                ("window", json::num(i as f64)),
                ("t0_s", json::num(i as f64 * rep.window_s)),
                ("ttft_s", json::num(w.ttft_s)),
                ("tbt_s", json::num(w.tbt_s)),
                ("n", json::num(w.n as f64)),
            ])
        })
        .collect();
    let q = |sk: &QuantileSketch| {
        json::obj(vec![
            ("p50", json::num(sk.quantile(0.50))),
            ("p95", json::num(sk.quantile(0.95))),
            ("p99", json::num(sk.quantile(0.99))),
        ])
    };
    let advisor: Vec<Json> = advice
        .iter()
        .enumerate()
        .map(|(rank, a)| {
            json::obj(vec![
                ("rank", json::num(rank as f64)),
                ("component", json::s(a.component_name())),
                ("blame_s", json::num(a.blame_s)),
                ("share", json::num(a.share)),
                ("lever", json::s(a.lever)),
                ("baseline_score", json::num(a.baseline_score)),
                ("predicted_score", json::num(a.predicted_score)),
                ("gain", json::num(a.gain())),
            ])
        })
        .collect();
    json::obj(vec![
        ("schema", json::s("hexgen2-attr/v1")),
        ("n_requests", json::num(rep.n as f64)),
        ("open_at_end", json::num(rep.open_at_end as f64)),
        ("window_s", json::num(rep.window_s)),
        ("latency_sum_s", json::num(rep.latency_sum)),
        ("ttft_sum_s", json::num(rep.ttft_sum)),
        ("blame_total_s", json::num(total)),
        ("conservation_residual_s", json::num(rep.residual_s())),
        ("kv_wait_seen_s", json::num(rep.kv_wait_seen_s)),
        ("totals", json::obj(blame_obj(&rep.totals))),
        ("share", json::obj(blame_obj(&share))),
        ("per_replica", json::arr(per_replica)),
        ("per_route", json::arr(per_route)),
        ("per_nic", json::arr(per_nic)),
        ("mem_stalls", json::arr(stalls)),
        ("windows", json::arr(windows)),
        (
            "quantiles",
            json::obj(vec![
                ("ttft", q(&rep.ttft_sketch)),
                ("tbt", q(&rep.tbt_sketch)),
                ("latency", q(&rep.latency_sketch)),
            ]),
        ),
        ("advisor", json::arr(advisor)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A full disaggregated chain with every phase distinct.
    fn disagg_chain(req: u32) -> Vec<(f64, TraceEvent)> {
        vec![
            (0.0, TraceEvent::Arrive { req }),
            (1.0, TraceEvent::Admit { req, replica: 0 }),
            (1.0, TraceEvent::PrefillChunk { req, replica: 0, chunk: 0 }),
            (1.0, TraceEvent::Burst { replica: 0, lane: Lane::Prefill, dur_s: 0.5 }),
            (2.0, TraceEvent::PrefillChunk { req, replica: 0, chunk: 1 }),
            (2.0, TraceEvent::Burst { replica: 0, lane: Lane::Prefill, dur_s: 0.5 }),
            (2.5, TraceEvent::PrefillDone { req, replica: 0 }),
            (
                2.5,
                TraceEvent::KvEnqueue { req, src: 0, dst: 1, bytes: 1e6, wait_s: 0.25 },
            ),
            (3.5, TraceEvent::KvDone { req, src: 0, dst: 1 }),
            (4.0, TraceEvent::DecodeJoin { req, replica: 1 }),
            (6.0, TraceEvent::Finish { req, replica: 1, output_len: 16 }),
        ]
    }

    fn run(events: &[(f64, TraceEvent)]) -> AttrReport {
        let mut a = Attributor::new(60.0, true);
        for &(t, ev) in events {
            a.observe(t, ev);
        }
        a.finish()
    }

    #[test]
    fn disagg_chain_decomposes_every_phase() {
        let rep = run(&disagg_chain(0));
        assert_eq!(rep.n, 1);
        let b = rep.requests[0].blame;
        assert_eq!(b.c[ADMISSION_WAIT], 1.0);
        // First chunk starts the moment it was admitted.
        assert_eq!(b.c[PREFILL_QUEUE], 0.0);
        assert_eq!(b.c[PREFILL_COMPUTE], 1.0);
        // Admit 1.0 → done 2.5 is 1.5 s; 1.0 s computed → 0.5 s interleave.
        assert!((b.c[PREFILL_INTERLEAVE] - 0.5).abs() < 1e-12);
        assert_eq!(b.c[KV_SERIALIZE_WAIT], 0.25);
        // KvDone − PrefillDone = 1.0; minus 0.25 wait.
        assert!((b.c[KV_TRANSMIT] - 0.75).abs() < 1e-12);
        assert_eq!(b.c[DECODE_BATCH_WAIT], 0.5);
        assert_eq!(b.c[DECODE_COMPUTE], 2.0);
        // Conservation, bit-exact.
        assert_eq!(b.total(), 6.0);
        assert_eq!(rep.requests[0].latency(), 6.0);
        // Route/NIC split captured.
        assert_eq!(rep.per_route.get(&(0, 1)).unwrap().0, 0.25);
        assert_eq!(rep.per_nic.get(&0).unwrap().0, 0.25);
        assert_eq!(rep.kv_wait_seen_s, 0.25);
    }

    #[test]
    fn conservation_is_bit_exact_on_awkward_floats() {
        // Timestamps chosen so the naive sum of differences rounds.
        let t0 = 1.0 / 3.0;
        let ts = [t0, t0 + 0.1, t0 + 0.1 + 1e-9, t0 + 0.7, t0 + 0.7 + 0.3, t0 + 1.1, t0 + 2.3];
        let req = 7;
        let events = vec![
            (ts[0], TraceEvent::Arrive { req }),
            (ts[1], TraceEvent::Admit { req, replica: 0 }),
            (ts[2], TraceEvent::PrefillChunk { req, replica: 0, chunk: 0 }),
            (ts[2], TraceEvent::Burst { replica: 0, lane: Lane::Prefill, dur_s: 0.13 }),
            (ts[3], TraceEvent::PrefillDone { req, replica: 0 }),
            (ts[3], TraceEvent::KvEnqueue { req, src: 0, dst: 1, bytes: 1.0, wait_s: 0.017 }),
            (ts[4], TraceEvent::KvDone { req, src: 0, dst: 1 }),
            (ts[5], TraceEvent::DecodeJoin { req, replica: 1 }),
            (ts[6], TraceEvent::Finish { req, replica: 1, output_len: 4 }),
        ];
        let rep = run(&events);
        let b = rep.requests[0].blame;
        assert_eq!(b.total(), ts[6] - ts[0], "blame must sum bit-exactly to latency");
    }

    #[test]
    fn unchunked_prefill_matches_its_burst() {
        let req = 3;
        let events = vec![
            (0.0, TraceEvent::Arrive { req }),
            (0.5, TraceEvent::Admit { req, replica: 2 }),
            // Whole-batch burst, no chunk events (unchunked disagg).
            (1.0, TraceEvent::Burst { replica: 2, lane: Lane::Prefill, dur_s: 0.8 }),
            (1.8, TraceEvent::PrefillDone { req, replica: 2 }),
            (1.8, TraceEvent::KvEnqueue { req, src: 2, dst: 3, bytes: 1.0, wait_s: 0.0 }),
            (2.0, TraceEvent::KvDone { req, src: 2, dst: 3 }),
            (2.0, TraceEvent::DecodeJoin { req, replica: 3 }),
            (3.0, TraceEvent::Finish { req, replica: 3, output_len: 8 }),
        ];
        let rep = run(&events);
        let b = rep.requests[0].blame;
        assert!((b.c[PREFILL_COMPUTE] - 0.8).abs() < 1e-12);
        assert!((b.c[PREFILL_QUEUE] - 0.5).abs() < 1e-12);
        assert_eq!(b.c[PREFILL_INTERLEAVE], 0.0);
        assert_eq!(b.total(), 3.0);
    }

    #[test]
    fn colocated_chain_has_no_kv_or_batch_wait() {
        let req = 1;
        let events = vec![
            (0.0, TraceEvent::Arrive { req }),
            (0.2, TraceEvent::Admit { req, replica: 0 }),
            (0.4, TraceEvent::PrefillChunk { req, replica: 0, chunk: 0 }),
            (0.4, TraceEvent::Burst { replica: 0, lane: Lane::Colocated, dur_s: 0.3 }),
            (0.7, TraceEvent::PrefillDone { req, replica: 0 }),
            (0.7, TraceEvent::DecodeJoin { req, replica: 0 }),
            (1.5, TraceEvent::Finish { req, replica: 0, output_len: 8 }),
        ];
        let rep = run(&events);
        let b = rep.requests[0].blame;
        assert_eq!(b.c[KV_SERIALIZE_WAIT], 0.0);
        assert_eq!(b.c[KV_TRANSMIT], 0.0);
        assert_eq!(b.c[DECODE_BATCH_WAIT], 0.0);
        assert!((b.c[DECODE_COMPUTE] - 0.8).abs() < 1e-12);
        assert_eq!(b.total(), 1.5);
    }

    #[test]
    fn rejected_and_inflight_requests_are_not_attributed() {
        let mut a = Attributor::new(60.0, true);
        a.observe(0.0, TraceEvent::Arrive { req: 0 });
        a.observe(0.1, TraceEvent::Reject { req: 0 });
        a.observe(0.2, TraceEvent::Arrive { req: 1 });
        a.observe(0.3, TraceEvent::Admit { req: 1, replica: 0 });
        assert_eq!(a.open_len(), 1);
        let rep = a.finish();
        assert_eq!(rep.n, 0);
        assert_eq!(rep.open_at_end, 1);
    }

    #[test]
    fn windows_split_ttft_from_tbt() {
        let mut events = disagg_chain(0);
        // Second request finishing in a later window.
        events.extend(vec![
            (70.0, TraceEvent::Arrive { req: 1 }),
            (70.0, TraceEvent::Admit { req: 1, replica: 0 }),
            (71.0, TraceEvent::PrefillChunk { req: 1, replica: 0, chunk: 0 }),
            (71.0, TraceEvent::Burst { replica: 0, lane: Lane::Prefill, dur_s: 1.0 }),
            (72.0, TraceEvent::PrefillDone { req: 1, replica: 0 }),
            (72.0, TraceEvent::KvEnqueue { req: 1, src: 0, dst: 1, bytes: 1.0, wait_s: 0.0 }),
            (72.5, TraceEvent::KvDone { req: 1, src: 0, dst: 1 }),
            (72.5, TraceEvent::DecodeJoin { req: 1, replica: 1 }),
            (75.0, TraceEvent::Finish { req: 1, replica: 1, output_len: 8 }),
        ]);
        let rep = run(&events);
        assert_eq!(rep.windows.len(), 2);
        assert_eq!(rep.windows[0].n, 1);
        assert_eq!(rep.windows[1].n, 1);
        // Req 0: ttft 2.5, decode span 3.5. Req 1: ttft 2.0, span 3.0.
        assert!((rep.windows[0].ttft_s - 2.5).abs() < 1e-12);
        assert!((rep.windows[0].tbt_s - 3.5).abs() < 1e-12);
        assert!((rep.windows[1].ttft_s - 2.0).abs() < 1e-12);
        assert!((rep.windows[1].tbt_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn advisor_ranks_dominant_component_first() {
        let rep = run(&disagg_chain(0));
        let advice = advise(&rep, None);
        assert!(!advice.is_empty());
        // decode_compute (2.0 s) dominates this chain.
        assert_eq!(advice[0].component_name(), "decode_compute");
        assert_eq!(advice[0].lever, "shift-pd-split-toward-decode");
        assert_eq!(rep.dominant_name(), "decode_compute");
        // Shares sum to ~1 over the emitted advice (all components > 0
        // are listed).
        let s: f64 = advice.iter().map(|a| a.share).sum();
        assert!((s - 1.0).abs() < 1e-9, "{s}");
    }

    #[test]
    fn lever_mapping_covers_all_components() {
        assert_eq!(lever_for(KV_SERIALIZE_WAIT), "add-kv-bandwidth");
        assert_eq!(lever_for(KV_TRANSMIT), "add-kv-bandwidth");
        assert_eq!(lever_for(DECODE_BATCH_WAIT), "shift-pd-split-toward-decode");
        assert_eq!(lever_for(DECODE_COMPUTE), "shift-pd-split-toward-decode");
        assert_eq!(lever_for(ADMISSION_WAIT), "shift-pd-split-toward-prefill");
        assert_eq!(lever_for(PREFILL_QUEUE), "shift-pd-split-toward-prefill");
        assert_eq!(lever_for(PREFILL_COMPUTE), "shift-pd-split-toward-prefill");
        assert_eq!(lever_for(PREFILL_INTERLEAVE), "raise-chunk-size");
    }

    #[test]
    fn attr_json_schema_and_conservation_fields() {
        let rep = run(&disagg_chain(0));
        let advice = advise(&rep, None);
        let j = attr_json(&rep, &advice);
        assert_eq!(j.get("schema").unwrap().as_str(), Some("hexgen2-attr/v1"));
        assert_eq!(j.get("n_requests").unwrap().as_usize(), Some(1));
        let resid = j.get("conservation_residual_s").unwrap().as_f64().unwrap();
        assert_eq!(resid, 0.0, "single request: aggregate == per-request sum");
        let totals = j.get("totals").unwrap();
        assert_eq!(totals.get("decode_compute").unwrap().as_f64(), Some(2.0));
        let adv = j.get("advisor").unwrap().as_arr().unwrap();
        assert_eq!(adv[0].get("component").unwrap().as_str(), Some("decode_compute"));
        assert!(j.get("per_nic").unwrap().as_arr().unwrap().len() == 1);
    }

    #[test]
    fn attrib_recorder_sees_events_the_ring_drops() {
        // Sample rate 0 drops every request-scoped event from the ring,
        // but the attributor still sees (and attributes) everything.
        let mut ar = AttribRecorder::new(Recorder::new(0.0, 4), Attributor::new(60.0, true));
        for &(t, ev) in &disagg_chain(0) {
            ar.emit(t, ev);
        }
        assert_eq!(ar.rec.len(), 2, "only the replica-scoped bursts stay in the ring");
        let rep = ar.attr.finish();
        assert_eq!(rep.n, 1);
        assert_eq!(rep.requests[0].blame.total(), 6.0);
    }

    #[test]
    fn replay_matches_online_attribution() {
        let events = disagg_chain(0);
        let mut rec = Recorder::new(1.0, 1 << 12);
        let mut online = Attributor::new(60.0, true);
        for &(t, ev) in &events {
            online.observe(t, ev);
            rec.emit(t, ev);
        }
        let replay = attribute_log(&rec.into_log(), 60.0);
        let a = online.finish();
        assert_eq!(a.totals, replay.totals);
        assert_eq!(a.latency_sum, replay.latency_sum);
        assert_eq!(a.kv_wait_seen_s, replay.kv_wait_seen_s);
    }
}
