//! End-to-end validation driver (the DESIGN.md §6 "e2e" row): load the
//! ~100M-parameter `gpt-100m` model compiled by `make artifacts`, serve a
//! real batched workload through the full three-layer stack — Rust
//! coordinator → PJRT CPU executables → HLO lowered from the JAX model with
//! its Pallas attention kernels — and report latency/throughput. Falls back
//! to `tiny` when only the fast artifacts were built.
//!
//! Proves all layers compose: disaggregated prefill/decode replica workers,
//! flow-weighted routing, real KV-cache transfers between workers, decode
//! continuous batching over slot-managed caches. Results are recorded in
//! DESIGN.md §6.
//!
//! Run:  make artifacts && cargo run --release --example e2e_serve
//!       (HEXGEN2_E2E_REQS=N and HEXGEN2_E2E_MODEL=tiny|gpt-100m override)

use hexgen2::coordinator::{serve, CoordinatorConfig, KvThrottle, LiveRequest};
use hexgen2::runtime::{artifacts_dir, load_manifests};
use hexgen2::util::rng::Rng;
use hexgen2::util::stats;

fn main() -> anyhow::Result<()> {
    let manifests = load_manifests(&artifacts_dir())?;
    let model = std::env::var("HEXGEN2_E2E_MODEL").unwrap_or_else(|_| {
        if manifests.contains_key("gpt-100m") { "gpt-100m".into() } else { "tiny".into() }
    });
    let mm = manifests.get(&model).expect("model in manifest");
    let n_req: usize = std::env::var("HEXGEN2_E2E_REQS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if model == "tiny" { 48 } else { 24 });
    let max_prompt = mm.prefill_modules().map(|m| m.seq).max().unwrap_or(64);
    let decode_budget = mm.config.max_seq - max_prompt;
    println!(
        "e2e driver: model={model} ({} layers, d={}, vocab={}), {} requests",
        mm.config.n_layers, mm.config.d_model, mm.config.vocab, n_req
    );

    // Realistic mixed workload: prompts across the variant buckets, decode
    // lengths up to the cache budget.
    let mut rng = Rng::new(2026);
    let vocab = mm.config.vocab;
    let requests: Vec<LiveRequest> = (0..n_req)
        .map(|id| LiveRequest {
            id,
            tokens: (0..rng.range(16, max_prompt)).map(|_| rng.range(0, vocab) as i32).collect(),
            output_len: rng.range(8, decode_budget.min(64)),
        })
        .collect();
    let in_tokens: usize = requests.iter().map(|r| r.tokens.len()).sum();
    let out_tokens: usize = requests.iter().map(|r| r.output_len).sum();

    let mut cfg = CoordinatorConfig::new(&model);
    cfg.n_prefill = 2;
    cfg.n_decode = 2;
    // Exercise the KV-transfer path at a finite (fast) link speed so the
    // transfer cost is measured, not hidden.
    cfg.kv_throttle = Some(KvThrottle { bytes_per_s: 4e9 });

    println!(
        "dispatching {in_tokens} prompt tokens; expecting ~{out_tokens} generated tokens; \
         2 prefill + 2 decode workers, KV link 4 GB/s\n"
    );
    let rep = serve(&cfg, requests)?;

    let lat: Vec<f64> = rep.report.records.iter().map(|r| r.latency()).collect();
    let ttft: Vec<f64> = rep.report.records.iter().map(|r| r.ttft()).collect();
    println!("=== e2e results ({model}) ===");
    println!("completed:        {}/{}", rep.report.records.len(), n_req);
    println!("wall time:        {:.2}s (incl. module compile)", rep.elapsed_s);
    println!("serving span:     {:.2}s", rep.report.makespan);
    println!("decode tput:      {:.1} tokens/s", rep.report.tokens_per_s());
    println!(
        "latency:          avg {:.3}s  p50 {:.3}s  p95 {:.3}s",
        stats::mean(&lat),
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 95.0)
    );
    println!(
        "TTFT:             avg {:.3}s  p95 {:.3}s",
        stats::mean(&ttft),
        stats::percentile(&ttft, 95.0)
    );
    println!(
        "KV moved:         {:.1} MiB across {} transfers",
        rep.kv_bytes_total as f64 / (1 << 20) as f64,
        rep.outputs.len()
    );
    // Sanity: every request generated at least one token; decode budget respected.
    for (id, toks) in &rep.outputs {
        assert!(!toks.is_empty(), "request {id} generated nothing");
    }
    println!("\nall layers composed: JAX/Pallas -> HLO text -> PJRT -> rust coordinator OK");
    Ok(())
}
