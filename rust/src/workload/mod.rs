//! LLM inference workloads: the paper's four offline workload classes
//! (HPLD / HPHD / LPHD / LPLD, §5.1) and the online Azure-conversation-like
//! trace (Fig. 5), with Poisson arrivals.
//!
//! Thresholds follow the paper: prefill > 512 tokens is "heavy"; decode
//! > 128 tokens is "heavy" (after Hu et al., 2024).

pub mod azure;

use crate::util::rng::Rng;

pub const HEAVY_PREFILL_THRESHOLD: usize = 512;
pub const HEAVY_DECODE_THRESHOLD: usize = 128;

/// One inference request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Arrival time in seconds from trace start (0.0 for offline traces).
    pub arrival: f64,
    pub input_len: usize,
    pub output_len: usize,
}

/// The paper's workload classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Heavy prefill, light decoding (e.g. coding workloads).
    Hpld,
    /// Heavy prefill, heavy decoding.
    Hphd,
    /// Light prefill, heavy decoding (e.g. conversation with long answers).
    Lphd,
    /// Light prefill, light decoding.
    Lpld,
    /// Mixed online trace sampled from the Azure-conversation-like
    /// distribution (Fig. 5).
    Online,
    /// Extreme length dispersion (σ≈1.3 log-normal, outliers to 16k
    /// tokens): the stress case for per-request KV admission, where mean
    /// lengths say nothing about memory demand.
    HeavyTail,
}

pub const OFFLINE_KINDS: [WorkloadKind; 4] =
    [WorkloadKind::Hpld, WorkloadKind::Hphd, WorkloadKind::Lphd, WorkloadKind::Lpld];

impl WorkloadKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Hpld => "HPLD",
            WorkloadKind::Hphd => "HPHD",
            WorkloadKind::Lphd => "LPHD",
            WorkloadKind::Lpld => "LPLD",
            WorkloadKind::Online => "Online",
            WorkloadKind::HeavyTail => "HEAVY_TAIL",
        }
    }

    pub fn from_name(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_uppercase().as_str() {
            "HPLD" => Some(WorkloadKind::Hpld),
            "HPHD" => Some(WorkloadKind::Hphd),
            "LPHD" => Some(WorkloadKind::Lphd),
            "LPLD" => Some(WorkloadKind::Lpld),
            "ONLINE" => Some(WorkloadKind::Online),
            "HEAVY_TAIL" | "HEAVY-TAIL" | "HEAVYTAIL" => Some(WorkloadKind::HeavyTail),
            _ => None,
        }
    }

    /// Sample (input_len, output_len) for this class.
    pub fn sample_lengths(self, rng: &mut Rng) -> (usize, usize) {
        match self {
            WorkloadKind::Hpld => (azure::sample_heavy_prefill(rng), azure::sample_light_decode(rng)),
            WorkloadKind::Hphd => (azure::sample_heavy_prefill(rng), azure::sample_heavy_decode(rng)),
            WorkloadKind::Lphd => (azure::sample_light_prefill(rng), azure::sample_heavy_decode(rng)),
            WorkloadKind::Lpld => (azure::sample_light_prefill(rng), azure::sample_light_decode(rng)),
            WorkloadKind::Online => azure::sample_conversation(rng),
            WorkloadKind::HeavyTail => azure::sample_heavy_tail(rng),
        }
    }

    /// Representative task profile (mean lengths) used by the scheduler to
    /// size capacities for this workload class.
    pub fn mean_lengths(self) -> (f64, f64) {
        match self {
            WorkloadKind::Hpld => (1024.0, 64.0),
            WorkloadKind::Hphd => (1024.0, 256.0),
            WorkloadKind::Lphd => (256.0, 256.0),
            WorkloadKind::Lpld => (256.0, 64.0),
            WorkloadKind::Online => (1020.0, 211.0),
            // Means alone badly undersell this class — that is the point.
            WorkloadKind::HeavyTail => (1100.0, 180.0),
        }
    }
}

/// A streaming source of requests (DESIGN.md §14): a pull-based generator
/// for traces too large to materialize. The engine core draws one request
/// at a time and keeps only a bounded arrival frontier in its event heap,
/// so a million-request run needs O(active requests) memory instead of
/// O(trace length).
///
/// Each constructor replicates the RNG stream of the matching [`Trace`]
/// constructor bit-exactly — in fact the `Trace` constructors are
/// implemented as collects over the source, so
/// `TraceSource::offline(k, n, s).collect::<Vec<_>>()` equals
/// `Trace::offline(k, n, s).requests` by construction.
pub struct TraceSource {
    kind: WorkloadKind,
    inner: SourceInner,
}

enum SourceInner {
    Offline { rng: Rng, kind: WorkloadKind, remaining: usize, next_id: usize },
    Online { rng: Rng, kind: WorkloadKind, rate: f64, duration: f64, t: f64, next_id: usize },
    Phases { rng: Rng, phases: Vec<(WorkloadKind, f64, f64)>, idx: usize, t0: f64, t: f64, next_id: usize },
    Materialized { requests: std::vec::IntoIter<Request> },
}

impl TraceSource {
    /// Streaming equivalent of [`Trace::offline`].
    pub fn offline(kind: WorkloadKind, n: usize, seed: u64) -> TraceSource {
        let rng = Rng::new(seed ^ 0x0FF1CE);
        TraceSource { kind, inner: SourceInner::Offline { rng, kind, remaining: n, next_id: 0 } }
    }

    /// Streaming equivalent of [`Trace::online`].
    pub fn online(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> TraceSource {
        let rng = Rng::new(seed ^ 0x0411_15E5);
        TraceSource {
            kind,
            inner: SourceInner::Online { rng, kind, rate, duration, t: 0.0, next_id: 0 },
        }
    }

    /// Streaming equivalent of [`Trace::phases`].
    pub fn phases(phases: &[(WorkloadKind, f64, f64)], seed: u64) -> TraceSource {
        assert!(!phases.is_empty(), "need at least one phase");
        for &(_, rate, duration) in phases {
            assert!(
                rate > 0.0 && rate.is_finite() && duration > 0.0 && duration.is_finite(),
                "phase rate/duration must be positive and finite"
            );
        }
        let rng = Rng::new(seed ^ 0x9_4A5E_D0);
        TraceSource {
            kind: phases[0].0,
            inner: SourceInner::Phases {
                rng,
                phases: phases.to_vec(),
                idx: 0,
                t0: 0.0,
                t: 0.0,
                next_id: 0,
            },
        }
    }

    /// Replay an already-materialized trace through the streaming
    /// interface (the parity bridge: every `Trace`-driven run is a
    /// `TraceSource`-driven run over this wrapper).
    pub fn replay(trace: &Trace) -> TraceSource {
        TraceSource {
            kind: trace.kind,
            inner: SourceInner::Materialized { requests: trace.requests.clone().into_iter() },
        }
    }

    pub fn kind(&self) -> WorkloadKind {
        self.kind
    }
}

impl Iterator for TraceSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        match &mut self.inner {
            SourceInner::Offline { rng, kind, remaining, next_id } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                let (input_len, output_len) = kind.sample_lengths(rng);
                let id = *next_id;
                *next_id += 1;
                Some(Request { id, arrival: 0.0, input_len, output_len })
            }
            SourceInner::Online { rng, kind, rate, duration, t, next_id } => {
                let prev = *t;
                *t += rng.exp(*rate);
                if *t <= prev {
                    *t = next_after(prev);
                }
                if *t >= *duration {
                    return None;
                }
                let (input_len, output_len) = kind.sample_lengths(rng);
                let id = *next_id;
                *next_id += 1;
                Some(Request { id, arrival: *t, input_len, output_len })
            }
            SourceInner::Phases { rng, phases, idx, t0, t, next_id } => {
                loop {
                    let &(kind, rate, duration) = phases.get(*idx)?;
                    let end = *t0 + duration;
                    let prev = *t;
                    *t += rng.exp(rate);
                    if *t <= prev {
                        *t = next_after(prev);
                    }
                    if *t >= end {
                        // Poisson arrivals are memoryless: the next phase
                        // restarts its clock at the boundary (carrying the
                        // overshoot gap would distort the first window
                        // after the boundary whenever rates differ).
                        *t0 = end;
                        *t = end;
                        *idx += 1;
                        continue;
                    }
                    let (input_len, output_len) = kind.sample_lengths(rng);
                    let id = *next_id;
                    *next_id += 1;
                    return Some(Request { id, arrival: *t, input_len, output_len });
                }
            }
            SourceInner::Materialized { requests } => requests.next(),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match &self.inner {
            SourceInner::Offline { remaining, .. } => (*remaining, Some(*remaining)),
            SourceInner::Materialized { requests } => requests.size_hint(),
            _ => (0, None),
        }
    }
}

/// A generated request trace.
#[derive(Clone, Debug)]
pub struct Trace {
    pub kind: WorkloadKind,
    pub requests: Vec<Request>,
}

impl Trace {
    /// Offline trace: `n` requests all available at t=0 ("requests arrive at
    /// a rate that fully utilizes the cluster", §5.1).
    pub fn offline(kind: WorkloadKind, n: usize, seed: u64) -> Trace {
        Trace { kind, requests: TraceSource::offline(kind, n, seed).collect() }
    }

    /// Online trace: Poisson arrivals at `rate` req/s for `duration` seconds
    /// (the paper scales rate to 75% of cluster peak). Arrival timestamps are
    /// strictly increasing: exponential gaps can round to zero in f64 once
    /// `t` is large, so equal timestamps are deduplicated at generation by
    /// nudging to the next representable instant.
    pub fn online(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
        Trace { kind, requests: TraceSource::online(kind, rate, duration, seed).collect() }
    }

    /// Phased trace for workload-drift scenarios (rescheduler case studies):
    /// each `(kind, rate, duration)` phase contributes Poisson arrivals over
    /// its own time window, concatenated on a single global clock. The
    /// trace's `kind` is the *first* phase's kind (the placement a static
    /// scheduler would provision for). Arrivals are strictly increasing
    /// across phase boundaries.
    pub fn phases(phases: &[(WorkloadKind, f64, f64)], seed: u64) -> Trace {
        let src = TraceSource::phases(phases, seed);
        Trace { kind: src.kind(), requests: src.collect() }
    }

    /// Phase boundary times of a phased trace spec: `boundaries[i]` is the
    /// start of phase i+1 (cumulative durations, excluding the final end).
    pub fn phase_boundaries(phases: &[(WorkloadKind, f64, f64)]) -> Vec<f64> {
        let mut out = Vec::new();
        let mut acc = 0.0;
        for &(_, _, d) in &phases[..phases.len().saturating_sub(1)] {
            acc += d;
            out.push(acc);
        }
        out
    }

    pub fn total_output_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.output_len).sum()
    }

    pub fn total_input_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.input_len).sum()
    }
}

/// Smallest f64 strictly greater than `x` (for deduplicating arrival
/// timestamps without pulling in the unstable-era `next_up`).
fn next_after(x: f64) -> f64 {
    if x == 0.0 {
        return f64::MIN_POSITIVE;
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_classes_respect_thresholds() {
        for kind in OFFLINE_KINDS {
            let t = Trace::offline(kind, 500, 7);
            assert_eq!(t.requests.len(), 500);
            for r in &t.requests {
                assert_eq!(r.arrival, 0.0);
                match kind {
                    WorkloadKind::Hpld => {
                        assert!(r.input_len > HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len <= HEAVY_DECODE_THRESHOLD);
                    }
                    WorkloadKind::Hphd => {
                        assert!(r.input_len > HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len > HEAVY_DECODE_THRESHOLD);
                    }
                    WorkloadKind::Lphd => {
                        assert!(r.input_len <= HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len > HEAVY_DECODE_THRESHOLD);
                    }
                    WorkloadKind::Lpld => {
                        assert!(r.input_len <= HEAVY_PREFILL_THRESHOLD);
                        assert!(r.output_len <= HEAVY_DECODE_THRESHOLD);
                    }
                    _ => unreachable!(),
                }
            }
        }
    }

    #[test]
    fn online_poisson_rate() {
        let t = Trace::online(WorkloadKind::Online, 5.0, 200.0, 3);
        let n = t.requests.len() as f64;
        assert!((n / 200.0 - 5.0).abs() < 0.5, "rate {} off", n / 200.0);
        // arrivals strictly increasing (generation dedupes equal stamps)
        for w in t.requests.windows(2) {
            assert!(w[1].arrival > w[0].arrival, "{} !> {}", w[1].arrival, w[0].arrival);
        }
    }

    #[test]
    fn phased_trace_shifts_mix_at_boundary() {
        let spec = [(WorkloadKind::Lphd, 4.0, 50.0), (WorkloadKind::Hpld, 4.0, 50.0)];
        let t = Trace::phases(&spec, 11);
        assert_eq!(t.kind, WorkloadKind::Lphd);
        assert_eq!(Trace::phase_boundaries(&spec), vec![50.0]);
        // Strictly increasing across the whole trace, ids sequential.
        for (i, w) in t.requests.windows(2).enumerate() {
            assert!(w[1].arrival > w[0].arrival);
            assert_eq!(t.requests[i].id, i);
        }
        // Phase 1 requests are light-prefill, phase 2 heavy-prefill.
        for r in &t.requests {
            if r.arrival < 50.0 {
                assert!(r.input_len <= HEAVY_PREFILL_THRESHOLD, "LPHD phase got {}", r.input_len);
                assert!(r.output_len > HEAVY_DECODE_THRESHOLD);
            } else {
                assert!(r.input_len > HEAVY_PREFILL_THRESHOLD, "HPLD phase got {}", r.input_len);
                assert!(r.output_len <= HEAVY_DECODE_THRESHOLD);
            }
        }
        // Both phases populated at roughly the requested rate.
        let n1 = t.requests.iter().filter(|r| r.arrival < 50.0).count();
        let n2 = t.requests.len() - n1;
        assert!(n1 > 100 && n2 > 100, "{n1}/{n2}");
    }

    #[test]
    fn trace_source_matches_materialized_constructors() {
        // Bit-exact stream parity: the Trace constructors are collects over
        // TraceSource, and replay() round-trips a materialized trace.
        let off: Vec<Request> = TraceSource::offline(WorkloadKind::Hphd, 200, 9).collect();
        assert_eq!(off, Trace::offline(WorkloadKind::Hphd, 200, 9).requests);
        let on: Vec<Request> = TraceSource::online(WorkloadKind::Online, 4.0, 60.0, 3).collect();
        assert_eq!(on, Trace::online(WorkloadKind::Online, 4.0, 60.0, 3).requests);
        let spec = [(WorkloadKind::Lphd, 3.0, 40.0), (WorkloadKind::Hpld, 5.0, 40.0)];
        let ph: Vec<Request> = TraceSource::phases(&spec, 11).collect();
        assert_eq!(ph, Trace::phases(&spec, 11).requests);
        let t = Trace::online(WorkloadKind::Online, 2.0, 30.0, 5);
        let replayed: Vec<Request> = TraceSource::replay(&t).collect();
        assert_eq!(replayed, t.requests);
        assert_eq!(TraceSource::replay(&t).kind(), t.kind);
    }

    #[test]
    fn trace_source_offline_size_hint_is_exact() {
        let mut src = TraceSource::offline(WorkloadKind::Lpld, 5, 1);
        assert_eq!(src.size_hint(), (5, Some(5)));
        src.next();
        assert_eq!(src.size_hint(), (4, Some(4)));
    }

    #[test]
    fn next_after_strictly_increases() {
        for x in [0.0, 1.0, 123.456, 1e12] {
            assert!(next_after(x) > x);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Trace::offline(WorkloadKind::Hphd, 50, 9);
        let b = Trace::offline(WorkloadKind::Hphd, 50, 9);
        assert_eq!(a.requests, b.requests);
        let c = Trace::offline(WorkloadKind::Hphd, 50, 10);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn name_roundtrip() {
        for k in [
            WorkloadKind::Hpld,
            WorkloadKind::Hphd,
            WorkloadKind::Lphd,
            WorkloadKind::Lpld,
            WorkloadKind::Online,
            WorkloadKind::HeavyTail,
        ] {
            assert_eq!(WorkloadKind::from_name(k.name()), Some(k));
        }
        assert_eq!(WorkloadKind::from_name("hpld"), Some(WorkloadKind::Hpld));
        // CLI alias: `--workload heavy_tail`.
        assert_eq!(WorkloadKind::from_name("heavy_tail"), Some(WorkloadKind::HeavyTail));
    }

    #[test]
    fn token_totals() {
        let t = Trace::offline(WorkloadKind::Lpld, 10, 1);
        assert_eq!(t.total_output_tokens(), t.requests.iter().map(|r| r.output_len).sum::<usize>());
        assert!(t.total_input_tokens() > 0);
    }
}
