//! Trace exporters (DESIGN.md §12): Chrome trace-event JSON (Perfetto),
//! Prometheus-style text, and trace-derived metrics.
//!
//! [`derive_metrics`] is the conservation check: it recomputes the
//! simulator's headline numbers (tokens/s, TTFT, per-route KV bytes and
//! waits, mem stalls) *purely* from the event stream, mirroring the exact
//! fold order of `SimReport::from_records` and the engine's accumulators
//! so the results match bit-for-bit when the trace is complete
//! (`sample_rate == 1.0`, `dropped == 0`).

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

use super::{Stamped, TraceEvent, TraceLog};

// ---------------------------------------------------------------------------
// Chrome trace-event JSON
// ---------------------------------------------------------------------------

fn us(t: f64) -> f64 {
    t * 1e6
}

fn meta(name: &str, pid: u32, tid: Option<u32>, label: &str) -> Json {
    let mut fields = vec![
        ("ph", json::s("M")),
        ("name", json::s(name)),
        ("pid", json::num(pid as f64)),
        ("args", json::obj(vec![("name", json::s(label))])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", json::num(tid as f64)));
    }
    json::obj(fields)
}

fn span(name: &str, pid: u32, tid: u32, ts: f64, dur: f64, args: Json) -> Json {
    json::obj(vec![
        ("ph", json::s("X")),
        ("name", json::s(name)),
        ("pid", json::num(pid as f64)),
        ("tid", json::num(tid as f64)),
        ("ts", json::num(us(ts))),
        ("dur", json::num(us(dur))),
        ("args", args),
    ])
}

fn instant(name: &str, pid: u32, tid: u32, ts: f64, args: Json) -> Json {
    json::obj(vec![
        ("ph", json::s("i")),
        ("name", json::s(name)),
        ("pid", json::num(pid as f64)),
        ("tid", json::num(tid as f64)),
        ("ts", json::num(us(ts))),
        ("s", json::s("t")),
        ("args", args),
    ])
}

const PID_REPLICAS: u32 = 1;
const PID_LINKS: u32 = 2;

/// Export a [`TraceLog`] as Chrome trace-event JSON, viewable in Perfetto
/// (`ui.perfetto.dev`) or `chrome://tracing`. Process 1 holds one lane per
/// replica (named by serving discipline) plus an "engine" lane for
/// arrival/resched markers; process 2 holds one lane per KV route, with
/// transfer chunks as spans.
pub fn chrome_trace(log: &TraceLog) -> Json {
    let engine_tid = log.lanes.len() as u32;
    let mut events: Vec<Json> = Vec::with_capacity(log.events.len() + log.lanes.len() + 8);
    events.push(meta("process_name", PID_REPLICAS, None, "replicas"));
    events.push(meta("process_name", PID_LINKS, None, "kv-links"));
    for (i, lane) in log.lanes.iter().enumerate() {
        events.push(meta(
            "thread_name",
            PID_REPLICAS,
            Some(i as u32),
            &format!("r{i} {}", lane.name()),
        ));
    }
    events.push(meta("thread_name", PID_REPLICAS, Some(engine_tid), "engine"));

    // KV-route lanes in first-seen order.
    let mut route_tid: BTreeMap<(u32, u32), u32> = BTreeMap::new();
    let mut tid_of = |src: u32, dst: u32, events: &mut Vec<Json>| -> u32 {
        if let Some(&t) = route_tid.get(&(src, dst)) {
            return t;
        }
        let t = route_tid.len() as u32;
        route_tid.insert((src, dst), t);
        events.push(meta(
            "thread_name",
            PID_LINKS,
            Some(t),
            &format!("kv {src}\u{2192}{dst}"),
        ));
        t
    };

    for &Stamped { t, ev } in &log.events {
        let j = match ev {
            TraceEvent::Arrive { req } => instant(
                "arrive",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![("req", json::num(req as f64))]),
            ),
            TraceEvent::Hold { req } => instant(
                "hold",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![("req", json::num(req as f64))]),
            ),
            TraceEvent::Reject { req } => instant(
                "reject",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![("req", json::num(req as f64))]),
            ),
            TraceEvent::Quiesce { switch } => instant(
                "quiesce",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![("switch", json::num(switch as f64))]),
            ),
            TraceEvent::Activate { switch, ok } => instant(
                "activate",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![("switch", json::num(switch as f64)), ("ok", Json::Bool(ok))]),
            ),
            TraceEvent::Admit { req, replica } => instant(
                "admit",
                PID_REPLICAS,
                replica,
                t,
                json::obj(vec![("req", json::num(req as f64))]),
            ),
            TraceEvent::MemStall { replica } => {
                instant("mem-stall", PID_REPLICAS, replica, t, json::obj(vec![]))
            }
            TraceEvent::Burst { replica, lane, dur_s } => {
                span(lane.name(), PID_REPLICAS, replica, t, dur_s, json::obj(vec![]))
            }
            TraceEvent::PrefillChunk { req, replica, chunk } => instant(
                "prefill-chunk",
                PID_REPLICAS,
                replica,
                t,
                json::obj(vec![
                    ("req", json::num(req as f64)),
                    ("chunk", json::num(chunk as f64)),
                ]),
            ),
            TraceEvent::PrefillDone { req, replica } => instant(
                "prefill-done",
                PID_REPLICAS,
                replica,
                t,
                json::obj(vec![("req", json::num(req as f64))]),
            ),
            TraceEvent::DecodeJoin { req, replica } => instant(
                "decode-join",
                PID_REPLICAS,
                replica,
                t,
                json::obj(vec![("req", json::num(req as f64))]),
            ),
            TraceEvent::Finish { req, replica, output_len } => instant(
                "finish",
                PID_REPLICAS,
                replica,
                t,
                json::obj(vec![
                    ("req", json::num(req as f64)),
                    ("output_len", json::num(output_len as f64)),
                ]),
            ),
            TraceEvent::KvEnqueue { req, src, dst, bytes, wait_s } => {
                let tid = tid_of(src, dst, &mut events);
                instant(
                    "kv-enqueue",
                    PID_LINKS,
                    tid,
                    t,
                    json::obj(vec![
                        ("req", json::num(req as f64)),
                        ("bytes", json::num(bytes)),
                        ("wait_s", json::num(wait_s)),
                    ]),
                )
            }
            TraceEvent::KvXfer { req, src, dst, chunk, n_chunks, start, end } => {
                let tid = tid_of(src, dst, &mut events);
                span(
                    "kv-chunk",
                    PID_LINKS,
                    tid,
                    start,
                    (end - start).max(0.0),
                    json::obj(vec![
                        ("req", json::num(req as f64)),
                        ("chunk", json::num(chunk as f64)),
                        ("n_chunks", json::num(n_chunks as f64)),
                    ]),
                )
            }
            TraceEvent::KvDone { req, src, dst } => {
                let tid = tid_of(src, dst, &mut events);
                instant(
                    "kv-done",
                    PID_LINKS,
                    tid,
                    t,
                    json::obj(vec![("req", json::num(req as f64))]),
                )
            }
            TraceEvent::PrefixHit { req, tokens, host } => instant(
                "prefix-hit",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![
                    ("req", json::num(req as f64)),
                    ("tokens", json::num(tokens as f64)),
                    ("host", Json::Bool(host)),
                ]),
            ),
            TraceEvent::PrefixMiss { req, prefix } => instant(
                "prefix-miss",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![
                    ("req", json::num(req as f64)),
                    ("prefix", json::num(prefix as f64)),
                ]),
            ),
            TraceEvent::PrefixEvict { prefix, tokens, to_host } => instant(
                "prefix-evict",
                PID_REPLICAS,
                engine_tid,
                t,
                json::obj(vec![
                    ("prefix", json::num(prefix as f64)),
                    ("tokens", json::num(tokens as f64)),
                    ("to_host", Json::Bool(to_host)),
                ]),
            ),
        };
        events.push(j);
    }

    json::obj(vec![
        ("traceEvents", json::arr(events)),
        ("displayTimeUnit", json::s("ms")),
        (
            "otherData",
            json::obj(vec![
                ("schema", json::s("hexgen2-trace/v1")),
                ("sample_rate", json::num(log.sample_rate)),
                ("dropped", json::num(log.dropped as f64)),
                ("n_events", json::num(log.events.len() as f64)),
            ]),
        ),
    ])
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

/// Windowed counters in the Prometheus text exposition format: one sample
/// per `window_s`-wide window (label `window="k"` covering
/// `[k·window_s, (k+1)·window_s)`). `window_s <= 0` collapses to one
/// all-time window. After the counters come run-scoped p50/p95/p99
/// summaries for TTFT, per-request mean TBT, and end-to-end latency, and
/// the KV queue-wait histogram with the transfer ledger's bucket edges.
pub fn prometheus_dump(log: &TraceLog, window_s: f64) -> String {
    let t_max = log.events.last().map(|s| s.t).unwrap_or(0.0);
    let (window_s, n_win) = if window_s > 0.0 {
        (window_s, ((t_max / window_s).floor() as usize) + 1)
    } else {
        (t_max.max(1e-9), 1)
    };
    let mut completions = vec![0usize; n_win];
    let mut out_tokens = vec![0usize; n_win];
    let mut stalls = vec![0usize; n_win];
    let mut kv_wait = vec![0.0f64; n_win];
    let mut kv_bytes = vec![0.0f64; n_win];
    let mut n_events = vec![0usize; n_win];
    let mut px_hits = vec![0usize; n_win];
    let mut px_misses = vec![0usize; n_win];
    let mut px_evicts = vec![0usize; n_win];
    for s in &log.events {
        let w = ((s.t / window_s).floor() as usize).min(n_win - 1);
        n_events[w] += 1;
        match s.ev {
            TraceEvent::Finish { output_len, .. } => {
                completions[w] += 1;
                out_tokens[w] += output_len as usize;
            }
            TraceEvent::MemStall { .. } => stalls[w] += 1,
            TraceEvent::KvEnqueue { bytes, wait_s, .. } => {
                kv_wait[w] += wait_s;
                kv_bytes[w] += bytes;
            }
            TraceEvent::PrefixHit { .. } => px_hits[w] += 1,
            TraceEvent::PrefixMiss { .. } => px_misses[w] += 1,
            TraceEvent::PrefixEvict { .. } => px_evicts[w] += 1,
            _ => {}
        }
    }
    let mut out = String::new();
    let mut counter = |name: &str, help: &str, vals: &dyn Fn(usize) -> String| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
        for w in 0..n_win {
            out.push_str(&format!("{name}{{window=\"{w}\"}} {}\n", vals(w)));
        }
    };
    counter(
        "hexgen2_requests_completed_total",
        "Requests that finished generation in the window.",
        &|w| completions[w].to_string(),
    );
    counter(
        "hexgen2_output_tokens_total",
        "Output tokens generated in the window.",
        &|w| out_tokens[w].to_string(),
    );
    counter(
        "hexgen2_mem_stalls_total",
        "Admissions blocked on replica memory in the window.",
        &|w| stalls[w].to_string(),
    );
    counter(
        "hexgen2_kv_wait_seconds_total",
        "Seconds KV transfers queued behind busy links (by enqueue time).",
        &|w| format!("{}", kv_wait[w]),
    );
    counter(
        "hexgen2_kv_bytes_total",
        "KV bytes handed to the transfer engine (by enqueue time).",
        &|w| format!("{}", kv_bytes[w]),
    );
    counter(
        "hexgen2_prefix_hits_total",
        "Prefix-pool hits (GPU + host tier) in the window.",
        &|w| px_hits[w].to_string(),
    );
    counter(
        "hexgen2_prefix_misses_total",
        "Prefix-pool misses (full prefill + publish) in the window.",
        &|w| px_misses[w].to_string(),
    );
    counter(
        "hexgen2_prefix_evictions_total",
        "Prefix-pool spills/evictions in the window.",
        &|w| px_evicts[w].to_string(),
    );
    counter("hexgen2_trace_events_total", "Trace events recorded in the window.", &|w| {
        n_events[w].to_string()
    });

    // Run-scoped summary quantiles: TTFT, per-request mean TBT, and
    // end-to-end latency through the same t-digest sketch the windowed
    // aggregator uses (≲2% rank error, exact for small populations), plus
    // the transfer engine's queue-wait histogram re-derived from
    // `KvEnqueue` events with the ledger's own bucket edges
    // ([`Ledger::HIST_EDGES_S`](crate::kvtransfer::Ledger::HIST_EDGES_S)).
    use crate::kvtransfer::Ledger;
    use crate::simulator::metrics::QuantileSketch;
    let mut arrival: BTreeMap<u32, f64> = BTreeMap::new();
    let mut prefill_done: BTreeMap<u32, f64> = BTreeMap::new();
    let mut ttft = QuantileSketch::new();
    let mut tbt = QuantileSketch::new();
    let mut latency = QuantileSketch::new();
    let (mut ttft_sum, mut tbt_sum, mut lat_sum) = (0.0f64, 0.0f64, 0.0f64);
    let mut hist = [0usize; 6];
    let mut hist_wait_sum = 0.0f64;
    for &Stamped { t, ev } in &log.events {
        match ev {
            TraceEvent::Arrive { req } => {
                arrival.insert(req, t);
            }
            TraceEvent::PrefillDone { req, .. } => {
                prefill_done.insert(req, t);
            }
            TraceEvent::KvEnqueue { wait_s, .. } => {
                let b = Ledger::HIST_EDGES_S
                    .iter()
                    .position(|&edge| wait_s < edge)
                    .unwrap_or(Ledger::HIST_EDGES_S.len());
                hist[b] += 1;
                hist_wait_sum += wait_s;
            }
            TraceEvent::Finish { req, output_len, .. } => {
                let Some(&a) = arrival.get(&req) else { continue };
                let pd = prefill_done.get(&req).copied().unwrap_or(t);
                let l = t - a;
                let tt = pd - a;
                let per_tok = (t - pd) / (output_len.saturating_sub(1).max(1)) as f64;
                latency.push(l);
                lat_sum += l;
                ttft.push(tt);
                ttft_sum += tt;
                tbt.push(per_tok);
                tbt_sum += per_tok;
            }
            _ => {}
        }
    }
    let mut summary = |name: &str, help: &str, sk: &QuantileSketch, sum: f64| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} summary\n"));
        for (label, q) in [("0.5", 0.50), ("0.95", 0.95), ("0.99", 0.99)] {
            out.push_str(&format!("{name}{{quantile=\"{label}\"}} {}\n", sk.quantile(q)));
        }
        out.push_str(&format!("{name}_sum {sum}\n{name}_count {}\n", sk.count() as u64));
    };
    summary(
        "hexgen2_ttft_seconds",
        "Time to first token (arrival to prefill completion), t-digest quantiles.",
        &ttft,
        ttft_sum,
    );
    summary(
        "hexgen2_tbt_seconds",
        "Per-request mean time between tokens (decode span / (output_len - 1)).",
        &tbt,
        tbt_sum,
    );
    summary(
        "hexgen2_latency_seconds",
        "End-to-end request latency, t-digest quantiles.",
        &latency,
        lat_sum,
    );
    out.push_str(
        "# HELP hexgen2_kv_wait_seconds KV transfer queue wait (transfer-engine ledger buckets).\n\
         # TYPE hexgen2_kv_wait_seconds histogram\n",
    );
    let mut cum = 0usize;
    for (i, edge) in Ledger::HIST_EDGES_S.iter().enumerate() {
        cum += hist[i];
        out.push_str(&format!("hexgen2_kv_wait_seconds_bucket{{le=\"{edge}\"}} {cum}\n"));
    }
    cum += hist[Ledger::HIST_EDGES_S.len()];
    out.push_str(&format!("hexgen2_kv_wait_seconds_bucket{{le=\"+Inf\"}} {cum}\n"));
    out.push_str(&format!("hexgen2_kv_wait_seconds_sum {hist_wait_sum}\n"));
    out.push_str(&format!("hexgen2_kv_wait_seconds_count {cum}\n"));
    out
}

// ---------------------------------------------------------------------------
// Trace-derived metrics (the conservation check)
// ---------------------------------------------------------------------------

/// Metrics recomputed purely from the event stream.
#[derive(Clone, Debug, Default)]
pub struct DerivedMetrics {
    /// Requests with a `Finish` event.
    pub completions: usize,
    pub total_output_tokens: usize,
    /// First arrival → last completion, over *finished* requests — the
    /// same span `SimReport::from_records` computes from its records.
    pub makespan: f64,
    pub tokens_per_s: f64,
    /// Per-request TTFT (`PrefillDone − Arrive`), keyed by trace index;
    /// finished requests only.
    pub ttft: BTreeMap<u32, f64>,
    /// Per-request end-to-end latency (`Finish − Arrive`).
    pub latency: BTreeMap<u32, f64>,
    /// KV bytes per route, summed in enqueue order (bit-exact vs the
    /// transfer ledger).
    pub route_bytes: BTreeMap<(u32, u32), f64>,
    /// KV queue-wait seconds per route, summed in enqueue order.
    pub route_wait_s: BTreeMap<(u32, u32), f64>,
    pub route_transfers: BTreeMap<(u32, u32), usize>,
    /// Total KV queue wait (the engine's `SimStats::kv_link_wait_s`
    /// accumulation order).
    pub kv_wait_total_s: f64,
    pub mem_stalls: usize,
    pub rejects: usize,
    /// Prefix-pool GPU hits (`PrefixHit` with `host == false`) — conserved
    /// against `SimStats::prefix_hits` at sample 1.0.
    pub prefix_hits: usize,
    /// Prefix-pool host-tier hits (`PrefixHit` with `host == true`).
    pub prefix_host_hits: usize,
    /// Prefix-pool misses.
    pub prefix_misses: usize,
    /// Tokens spilled GPU → host (`PrefixEvict` with `to_host == true`),
    /// summed in event order.
    pub prefix_spilled_tokens: f64,
    /// Tokens dropped from the host tier.
    pub prefix_evicted_tokens: f64,
}

/// Recompute the simulator's headline metrics from a trace alone. With a
/// complete trace (`sample_rate == 1.0`, `dropped == 0`) every field
/// matches the engine's `SimReport` / `Ledger` counters exactly — the
/// conservation property the telemetry test suite pins.
pub fn derive_metrics(log: &TraceLog) -> DerivedMetrics {
    let mut m = DerivedMetrics::default();
    let mut arrival: BTreeMap<u32, f64> = BTreeMap::new();
    let mut prefill_done: BTreeMap<u32, f64> = BTreeMap::new();
    let mut completion: BTreeMap<u32, f64> = BTreeMap::new();
    for &Stamped { t, ev } in &log.events {
        match ev {
            TraceEvent::Arrive { req } => {
                arrival.insert(req, t);
            }
            // Chunked colocated prefills can re-stamp; keep the last, as
            // the engine's `prefill_done_at` overwrite does.
            TraceEvent::PrefillDone { req, .. } => {
                prefill_done.insert(req, t);
            }
            TraceEvent::Finish { req, output_len, .. } => {
                completion.insert(req, t);
                m.completions += 1;
                m.total_output_tokens += output_len as usize;
            }
            TraceEvent::KvEnqueue { src, dst, bytes, wait_s, .. } => {
                *m.route_bytes.entry((src, dst)).or_insert(0.0) += bytes;
                *m.route_wait_s.entry((src, dst)).or_insert(0.0) += wait_s;
                *m.route_transfers.entry((src, dst)).or_insert(0) += 1;
                m.kv_wait_total_s += wait_s;
            }
            TraceEvent::MemStall { .. } => m.mem_stalls += 1,
            TraceEvent::Reject { .. } => m.rejects += 1,
            TraceEvent::PrefixHit { host, .. } => {
                if host {
                    m.prefix_host_hits += 1;
                } else {
                    m.prefix_hits += 1;
                }
            }
            TraceEvent::PrefixMiss { .. } => m.prefix_misses += 1,
            TraceEvent::PrefixEvict { tokens, to_host, .. } => {
                if to_host {
                    m.prefix_spilled_tokens += tokens as f64;
                } else {
                    m.prefix_evicted_tokens += tokens as f64;
                }
            }
            _ => {}
        }
    }
    // Mirror `SimReport::from_records`: fold min over arrivals and max
    // over completions of *finished* requests (min/max folds are
    // order-independent, so iteration order vs record order is immaterial).
    let mut first = f64::INFINITY;
    let mut last = 0.0f64;
    for (&req, &done) in &completion {
        if let Some(&a) = arrival.get(&req) {
            first = first.min(a);
            last = last.max(done);
            m.latency.insert(req, done - a);
            if let Some(&p) = prefill_done.get(&req) {
                m.ttft.insert(req, p - a);
            }
        }
    }
    m.makespan = if m.completions == 0 { 0.0 } else { (last - first).max(1e-9) };
    m.tokens_per_s =
        if m.completions == 0 { 0.0 } else { m.total_output_tokens as f64 / m.makespan };
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Lane, Recorder};

    fn sample_log() -> TraceLog {
        let mut r = Recorder::new(1.0, 1 << 10);
        r.emit(0.0, TraceEvent::Arrive { req: 0 });
        r.emit(0.0, TraceEvent::Admit { req: 0, replica: 0 });
        r.emit(0.0, TraceEvent::Burst { replica: 0, lane: Lane::Prefill, dur_s: 0.5 });
        r.emit(0.5, TraceEvent::PrefillDone { req: 0, replica: 0 });
        r.emit(
            0.5,
            TraceEvent::KvEnqueue { req: 0, src: 0, dst: 1, bytes: 1e6, wait_s: 0.125 },
        );
        r.emit(
            0.5,
            TraceEvent::KvXfer {
                req: 0,
                src: 0,
                dst: 1,
                chunk: 0,
                n_chunks: 1,
                start: 0.625,
                end: 0.75,
            },
        );
        r.emit(0.75, TraceEvent::KvDone { req: 0, src: 0, dst: 1 });
        r.emit(0.75, TraceEvent::DecodeJoin { req: 0, replica: 1 });
        r.emit(2.0, TraceEvent::Finish { req: 0, replica: 1, output_len: 64 });
        r.set_lanes(vec![Lane::Prefill, Lane::Decode]);
        r.into_log()
    }

    #[test]
    fn chrome_trace_shape() {
        let j = chrome_trace(&sample_log());
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process metas + 2 replica lanes + engine lane + 1 route lane
        // + 9 events.
        assert_eq!(evs.len(), 15);
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(matches!(ph, "M" | "X" | "i"), "{ph}");
            assert!(e.get("pid").is_some());
        }
        // Spans carry µs timestamps/durations.
        let burst = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("prefill"))
            .unwrap();
        assert_eq!(burst.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(burst.get("dur").unwrap().as_f64(), Some(0.5e6));
        // Deterministic serialization (BTreeMap keys + fixed event order).
        assert_eq!(j.to_string_pretty(), chrome_trace(&sample_log()).to_string_pretty());
    }

    #[test]
    fn derive_metrics_from_sample() {
        let m = derive_metrics(&sample_log());
        assert_eq!(m.completions, 1);
        assert_eq!(m.total_output_tokens, 64);
        assert_eq!(m.makespan, 2.0);
        assert_eq!(m.tokens_per_s, 32.0);
        assert_eq!(m.ttft.get(&0).copied(), Some(0.5));
        assert_eq!(m.latency.get(&0).copied(), Some(2.0));
        assert_eq!(m.route_bytes.get(&(0, 1)).copied(), Some(1e6));
        assert_eq!(m.route_wait_s.get(&(0, 1)).copied(), Some(0.125));
        assert_eq!(m.kv_wait_total_s, 0.125);
    }

    #[test]
    fn prometheus_dump_windows() {
        let text = prometheus_dump(&sample_log(), 1.0);
        assert!(text.contains("# TYPE hexgen2_requests_completed_total counter"));
        // Finish at t=2.0 lands in window 2.
        assert!(text.contains("hexgen2_requests_completed_total{window=\"2\"} 1"));
        assert!(text.contains("hexgen2_output_tokens_total{window=\"2\"} 64"));
        assert!(text.contains("hexgen2_kv_wait_seconds_total{window=\"0\"} 0.125"));
        // Collapsed single window.
        let all = prometheus_dump(&sample_log(), 0.0);
        assert!(all.contains("hexgen2_requests_completed_total{window=\"0\"} 1"));
    }

    #[test]
    fn prometheus_dump_summaries_and_histogram() {
        let text = prometheus_dump(&sample_log(), 1.0);
        // Summary quantiles: one request, TTFT 0.5s, latency 2s — with a
        // single insertion every quantile is that exact value.
        assert!(text.contains("# TYPE hexgen2_ttft_seconds summary"), "{text}");
        assert!(text.contains("hexgen2_ttft_seconds{quantile=\"0.5\"} 0.5"), "{text}");
        assert!(text.contains("hexgen2_ttft_seconds{quantile=\"0.99\"} 0.5"), "{text}");
        assert!(text.contains("hexgen2_latency_seconds{quantile=\"0.95\"} 2\n"), "{text}");
        assert!(text.contains("hexgen2_latency_seconds_sum 2\n"), "{text}");
        assert!(text.contains("hexgen2_latency_seconds_count 1\n"), "{text}");
        assert!(text.contains("# TYPE hexgen2_tbt_seconds summary"), "{text}");
        assert!(text.contains("hexgen2_tbt_seconds_count 1\n"), "{text}");
        // KV wait histogram: the single 0.125s wait is ≥0.1 and <1, so the
        // cumulative buckets step from 0 to 1 at le="1".
        assert!(text.contains("# TYPE hexgen2_kv_wait_seconds histogram"), "{text}");
        assert!(text.contains("hexgen2_kv_wait_seconds_bucket{le=\"0.1\"} 0\n"), "{text}");
        assert!(text.contains("hexgen2_kv_wait_seconds_bucket{le=\"1\"} 1\n"), "{text}");
        assert!(text.contains("hexgen2_kv_wait_seconds_bucket{le=\"+Inf\"} 1\n"), "{text}");
        assert!(text.contains("hexgen2_kv_wait_seconds_sum 0.125\n"), "{text}");
        assert!(text.contains("hexgen2_kv_wait_seconds_count 1\n"), "{text}");
    }
}
