//! Golden parity: the unified simulation core reproduces the pre-refactor
//! engines' per-request timelines and aggregates within 1e-9.
//!
//! `legacy` below is a frozen, verbatim port of the two pre-refactor event
//! loops (`simulator/disagg.rs` @ 580 LoC and `simulator/colocated.rs` @
//! 353 LoC, commit 8e920f9) against the crate's public cost-model/queue
//! APIs. It exists only as the parity reference — the production path is
//! the single engine in `simulator::core`.
//!
//! The new engine runs with `static_prefill_cap: Some(16)`, pinning the one
//! deliberate sizing change of the refactor (the old hardcoded `1..=16`
//! prefill-batch scan, now memory-derived by default) so these tests
//! isolate the *engine* refactor. The cap fix itself is verified
//! independently in `costmodel` and `tests/sim_core.rs`.

use hexgen2::cluster::settings;
use hexgen2::model::OPT_30B;
use hexgen2::scheduler::{self, Placement, ScheduleOptions};
use hexgen2::simulator::{
    run_colocated_cfg, run_disaggregated_cfg, simulate, LinkModel, PlacementSwitch, RouteModel,
    ServingSpec, SimConfig, SimReport, SwitchSpec,
};
use hexgen2::workload::{Trace, WorkloadKind};

/// Frozen pre-refactor engines (reference implementation for parity only).
mod legacy {
    use std::collections::{HashMap, VecDeque};

    use hexgen2::cluster::Cluster;
    use hexgen2::costmodel::{CostModel, ReplicaConfig, TaskProfile};
    use hexgen2::model::LlmSpec;
    use hexgen2::scheduler::Placement;
    use hexgen2::simulator::events::EventQueue;
    use hexgen2::simulator::metrics::{RequestRecord, SimReport};
    use hexgen2::simulator::{slo_base, PlacementSwitch, PREFILL_TOKEN_BUDGET};
    use hexgen2::workload::{Request, Trace};

    #[derive(Clone, Copy, Debug)]
    enum Ev {
        Arrive(usize),
        PrefillDone(usize),
        KvArrive { d: usize, r: usize },
        Step(usize),
        Resched(usize),
        Activate(usize),
    }

    struct PrefillState {
        cfg: ReplicaConfig,
        queue: VecDeque<usize>,
        busy: bool,
        batch: Vec<usize>,
        max_batch: usize,
        assigned: f64,
        weight: f64,
    }

    struct Running {
        req: usize,
        generated: usize,
    }

    struct DecodeState {
        cfg: ReplicaConfig,
        running: Vec<Running>,
        waiting: VecDeque<usize>,
        stepping: bool,
        max_batch: usize,
        assigned_from: HashMap<usize, f64>,
    }

    #[allow(clippy::too_many_arguments)]
    fn build_replicas(
        cm: &CostModel,
        placement: &Placement,
        s_in_mean: f64,
        task: &TaskProfile,
        prefills: &mut Vec<PrefillState>,
        decodes: &mut Vec<DecodeState>,
        route_w: &mut HashMap<(usize, usize), f64>,
    ) -> Option<Vec<usize>> {
        let mut p_of_group: HashMap<usize, usize> = HashMap::new();
        let mut d_of_group: HashMap<usize, usize> = HashMap::new();
        let p_base = prefills.len();
        let d_base = decodes.len();
        for (gi, g) in placement.groups.iter().enumerate() {
            let Some(cfg) = g.config.clone() else { continue };
            if g.capacity <= 0.0 {
                continue;
            }
            if g.is_prefill {
                // The pre-refactor hardcoded 1..=16 prefill-batch scan.
                let mut mb = 1;
                for b in 1..=16 {
                    if cm.memory_ok(&cfg, &TaskProfile::new(b, s_in_mean, 0.0)) {
                        mb = b;
                    }
                }
                p_of_group.insert(gi, prefills.len());
                prefills.push(PrefillState {
                    cfg,
                    queue: VecDeque::new(),
                    busy: false,
                    batch: Vec::new(),
                    max_batch: mb,
                    assigned: 0.0,
                    weight: 0.0,
                });
            } else {
                let mb = cm.max_decode_batch(&cfg, task).max(1);
                d_of_group.insert(gi, decodes.len());
                decodes.push(DecodeState {
                    cfg,
                    running: Vec::new(),
                    waiting: VecDeque::new(),
                    stepping: false,
                    max_batch: mb,
                    assigned_from: HashMap::new(),
                });
            }
        }
        if prefills.len() == p_base || decodes.len() == d_base {
            prefills.truncate(p_base);
            decodes.truncate(d_base);
            return None;
        }
        for r in &placement.routes {
            let (Some(&p), Some(&d)) = (p_of_group.get(&r.prefill), d_of_group.get(&r.decode))
            else {
                continue;
            };
            if r.flow > 1e-9 {
                *route_w.entry((p, d)).or_default() += r.flow;
                prefills[p].weight += r.flow;
            }
        }
        for p in p_base..prefills.len() {
            if prefills[p].weight <= 0.0 {
                for d in d_base..decodes.len() {
                    route_w.insert((p, d), 1e-6);
                }
                prefills[p].weight = 1e-6 * (decodes.len() - d_base) as f64;
            }
        }
        Some((p_base..prefills.len()).collect())
    }

    fn pick_prefill(prefills: &[PrefillState], active: &[usize]) -> usize {
        *active
            .iter()
            .max_by(|&&a, &&b| {
                let fa = prefills[a].weight / (prefills[a].assigned + 1.0);
                let fb = prefills[b].weight / (prefills[b].assigned + 1.0);
                fa.partial_cmp(&fb).unwrap()
            })
            .expect("no active prefill replica")
    }

    fn maybe_start_prefill(
        p: usize,
        now: f64,
        prefills: &mut [PrefillState],
        reqs: &[Request],
        cm: &CostModel,
        q: &mut EventQueue<Ev>,
    ) {
        let st = &mut prefills[p];
        if st.busy || st.queue.is_empty() {
            return;
        }
        let mut batch = Vec::new();
        let mut tokens = 0.0;
        let mut max_len = 0usize;
        while let Some(&r) = st.queue.front() {
            let len = reqs[r].input_len;
            if !batch.is_empty()
                && (tokens + len as f64 > PREFILL_TOKEN_BUDGET || batch.len() >= st.max_batch)
            {
                break;
            }
            st.queue.pop_front();
            tokens += len as f64;
            max_len = max_len.max(len);
            batch.push(r);
        }
        let t = TaskProfile::new(batch.len(), max_len as f64, 0.0);
        let lat = cm.prefill_latency(&st.cfg, &t);
        st.busy = true;
        st.batch = batch;
        q.push(now + lat, Ev::PrefillDone(p));
    }

    fn maybe_start_step(
        d: usize,
        now: f64,
        decodes: &mut [DecodeState],
        reqs: &[Request],
        cm: &CostModel,
        q: &mut EventQueue<Ev>,
    ) {
        let st = &mut decodes[d];
        if st.stepping {
            return;
        }
        while st.running.len() < st.max_batch {
            match st.waiting.pop_front() {
                Some(r) => st.running.push(Running { req: r, generated: 0 }),
                None => break,
            }
        }
        if st.running.is_empty() {
            return;
        }
        let avg_ctx = st
            .running
            .iter()
            .map(|r| (reqs[r.req].input_len + r.generated) as f64)
            .sum::<f64>()
            / st.running.len() as f64;
        let lat = cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx);
        st.stepping = true;
        q.push(now + lat, Ev::Step(d));
    }

    pub fn run_disaggregated(
        cluster: &Cluster,
        model: &LlmSpec,
        placement: &Placement,
        trace: &Trace,
    ) -> SimReport {
        run_disaggregated_with_resched(cluster, model, placement, &[], trace)
    }

    pub fn run_disaggregated_with_resched(
        cluster: &Cluster,
        model: &LlmSpec,
        initial: &Placement,
        switches: &[PlacementSwitch],
        trace: &Trace,
    ) -> SimReport {
        let cm = CostModel::new(cluster, model);
        let (s_in_mean, s_out_mean) = trace.kind.mean_lengths();
        let task = TaskProfile::new(1, s_in_mean, s_out_mean);

        let mut prefills: Vec<PrefillState> = Vec::new();
        let mut decodes: Vec<DecodeState> = Vec::new();
        let mut route_w: HashMap<(usize, usize), f64> = HashMap::new();

        let Some(mut active_p) = build_replicas(
            &cm,
            initial,
            s_in_mean,
            &task,
            &mut prefills,
            &mut decodes,
            &mut route_w,
        ) else {
            return SimReport::from_records(vec![]);
        };

        let reqs = &trace.requests;
        let mut q: EventQueue<Ev> = EventQueue::new();
        for (i, r) in reqs.iter().enumerate() {
            q.push(r.arrival, Ev::Arrive(i));
        }
        for (i, s) in switches.iter().enumerate() {
            q.push(s.at, Ev::Resched(i));
            q.push(s.at + s.delay, Ev::Activate(i));
        }

        let mut link_free: HashMap<(usize, usize), f64> = HashMap::new();
        let mut prefill_done_at: Vec<f64> = vec![0.0; reqs.len()];
        let mut records: Vec<RequestRecord> = Vec::new();
        let mut holding: Vec<usize> = Vec::new();
        let mut quiesced: Vec<Vec<usize>> = vec![Vec::new(); switches.len()];

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrive(r) => {
                    if active_p.is_empty() {
                        holding.push(r);
                    } else {
                        let p = pick_prefill(&prefills, &active_p);
                        prefills[p].assigned += 1.0;
                        prefills[p].queue.push_back(r);
                        maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
                    }
                }
                Ev::Resched(i) => {
                    quiesced[i] = std::mem::take(&mut active_p);
                    let mut pulled: Vec<usize> = Vec::new();
                    for &p in &quiesced[i] {
                        pulled.extend(prefills[p].queue.drain(..));
                    }
                    pulled.sort_unstable();
                    holding.extend(pulled);
                }
                Ev::Activate(i) => {
                    let (sw_s_in, sw_s_out) = switches[i]
                        .workload
                        .map(|k| k.mean_lengths())
                        .unwrap_or((s_in_mean, s_out_mean));
                    let sw_task = TaskProfile::new(1, sw_s_in, sw_s_out);
                    match build_replicas(
                        &cm,
                        &switches[i].placement,
                        sw_s_in,
                        &sw_task,
                        &mut prefills,
                        &mut decodes,
                        &mut route_w,
                    ) {
                        Some(fresh) => active_p = fresh,
                        None => active_p = std::mem::take(&mut quiesced[i]),
                    }
                    for r in std::mem::take(&mut holding) {
                        let p = pick_prefill(&prefills, &active_p);
                        prefills[p].assigned += 1.0;
                        prefills[p].queue.push_back(r);
                        maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
                    }
                }
                Ev::PrefillDone(p) => {
                    let batch = std::mem::take(&mut prefills[p].batch);
                    for r in batch {
                        prefill_done_at[r] = now;
                        let d = (0..decodes.len())
                            .filter(|&d| route_w.contains_key(&(p, d)))
                            .max_by(|&a, &b| {
                                let wa = route_w[&(p, a)]
                                    / (decodes[a].assigned_from.get(&p).copied().unwrap_or(0.0)
                                        + 1.0);
                                let wb = route_w[&(p, b)]
                                    / (decodes[b].assigned_from.get(&p).copied().unwrap_or(0.0)
                                        + 1.0);
                                wa.partial_cmp(&wb).unwrap()
                            })
                            .unwrap_or(0);
                        *decodes[d].assigned_from.entry(p).or_default() += 1.0;
                        let t_task = TaskProfile::new(1, reqs[r].input_len as f64, 0.0);
                        let xfer = cm.kv_transfer_time(&prefills[p].cfg, &decodes[d].cfg, &t_task);
                        let free = link_free.get(&(p, d)).copied().unwrap_or(0.0).max(now);
                        let done = free + xfer;
                        link_free.insert((p, d), done);
                        q.push(done, Ev::KvArrive { d, r });
                    }
                    prefills[p].busy = false;
                    maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
                }
                Ev::KvArrive { d, r } => {
                    decodes[d].waiting.push_back(r);
                    maybe_start_step(d, now, &mut decodes, reqs, &cm, &mut q);
                }
                Ev::Step(d) => {
                    let st = &mut decodes[d];
                    st.stepping = false;
                    let mut finished = Vec::new();
                    for run in st.running.iter_mut() {
                        run.generated += 1;
                        if run.generated >= reqs[run.req].output_len {
                            finished.push(run.req);
                        }
                    }
                    st.running.retain(|run| run.generated < reqs[run.req].output_len);
                    for r in finished {
                        records.push(RequestRecord {
                            id: reqs[r].id,
                            arrival: reqs[r].arrival,
                            prefill_done: prefill_done_at[r],
                            completion: now,
                            input_len: reqs[r].input_len,
                            output_len: reqs[r].output_len,
                            slo_base: slo_base(model, &reqs[r]),
                        });
                    }
                    maybe_start_step(d, now, &mut decodes, reqs, &cm, &mut q);
                }
            }
        }

        SimReport::from_records(records)
    }

    // ------------------ legacy colocated engine ------------------

    #[derive(Clone, Copy, Debug)]
    enum CEv {
        Arrive(usize),
        IterDone(usize),
    }

    struct PendingPrefill {
        req: usize,
        remaining: usize,
    }

    struct Replica {
        cfg: ReplicaConfig,
        queue: VecDeque<PendingPrefill>,
        running: Vec<Running>,
        iterating: bool,
        max_batch: usize,
        inflight_prefill: Vec<PendingPrefill>,
    }

    pub fn run_colocated(
        cluster: &Cluster,
        model: &LlmSpec,
        replicas: &[ReplicaConfig],
        trace: &Trace,
        chunk: Option<usize>,
    ) -> SimReport {
        let cm = CostModel::new(cluster, model);
        let (s_in_mean, s_out_mean) = trace.kind.mean_lengths();
        let task = TaskProfile::new(1, s_in_mean, s_out_mean);

        let mut reps: Vec<Replica> = replicas
            .iter()
            .filter(|cfg| cm.memory_ok(cfg, &task))
            .map(|cfg| {
                let mb = cm.max_decode_batch(cfg, &task).max(1);
                Replica {
                    cfg: cfg.clone(),
                    queue: VecDeque::new(),
                    running: Vec::new(),
                    iterating: false,
                    max_batch: mb,
                    inflight_prefill: Vec::new(),
                }
            })
            .collect();
        if reps.is_empty() {
            return SimReport::from_records(vec![]);
        }

        let reqs = &trace.requests;
        let mut q: EventQueue<CEv> = EventQueue::new();
        for (i, r) in reqs.iter().enumerate() {
            q.push(r.arrival, CEv::Arrive(i));
        }

        let mut prefill_done_at = vec![0.0f64; reqs.len()];
        let mut records: Vec<RequestRecord> = Vec::new();

        fn maybe_start_iter(
            ri: usize,
            now: f64,
            reps: &mut [Replica],
            reqs: &[Request],
            cm: &CostModel,
            chunk: Option<usize>,
            q: &mut EventQueue<CEv>,
        ) {
            let st = &mut reps[ri];
            if st.iterating {
                return;
            }
            let per_req = chunk.unwrap_or(usize::MAX);
            let projected = |infl: &[PendingPrefill]| -> f64 {
                infl.iter().map(|p| p.remaining.min(per_req) as f64).sum()
            };
            while st.running.len() + st.inflight_prefill.len() < st.max_batch {
                let Some(p) = st.queue.front() else { break };
                let next_work = p.remaining.min(per_req) as f64;
                if !st.inflight_prefill.is_empty()
                    && projected(&st.inflight_prefill) + next_work > PREFILL_TOKEN_BUDGET
                {
                    break;
                }
                let p = st.queue.pop_front().unwrap();
                st.inflight_prefill.push(p);
            }
            if st.running.is_empty() && st.inflight_prefill.is_empty() {
                return;
            }
            let mut pf_tokens = 0.0;
            let mut pf_reqs = 0usize;
            for p in st.inflight_prefill.iter_mut() {
                if pf_tokens >= PREFILL_TOKEN_BUDGET && pf_reqs > 0 {
                    break;
                }
                let work = p.remaining.min(per_req);
                if work == 0 {
                    continue;
                }
                pf_tokens += work as f64;
                p.remaining -= work;
                pf_reqs += 1;
            }
            let avg_ctx = if st.running.is_empty() {
                0.0
            } else {
                st.running
                    .iter()
                    .map(|r| (reqs[r.req].input_len + r.generated) as f64)
                    .sum::<f64>()
                    / st.running.len() as f64
            };
            let mut lat = 0.0;
            if pf_reqs > 0 && chunk.is_some() {
                let fused_tokens = pf_tokens + st.running.len() as f64;
                let pf_t = cm.prefill_latency(&st.cfg, &TaskProfile::new(1, fused_tokens, 0.0));
                let dec_t = if st.running.is_empty() {
                    0.0
                } else {
                    cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx)
                };
                lat += pf_t.max(dec_t);
            } else {
                if pf_reqs > 0 {
                    let t = TaskProfile::new(pf_reqs, pf_tokens / pf_reqs as f64, 0.0);
                    lat += cm.prefill_latency(&st.cfg, &t);
                }
                if !st.running.is_empty() {
                    lat += cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx);
                }
            }
            st.iterating = true;
            q.push(now + lat, CEv::IterDone(ri));
        }

        while let Some((now, ev)) = q.pop() {
            match ev {
                CEv::Arrive(r) => {
                    let ri = (0..reps.len())
                        .min_by_key(|&i| {
                            reps[i].queue.len()
                                + reps[i].running.len()
                                + reps[i].inflight_prefill.len()
                        })
                        .unwrap();
                    reps[ri]
                        .queue
                        .push_back(PendingPrefill { req: r, remaining: reqs[r].input_len });
                    maybe_start_iter(ri, now, &mut reps, reqs, &cm, chunk, &mut q);
                }
                CEv::IterDone(ri) => {
                    let st = &mut reps[ri];
                    st.iterating = false;
                    let mut finished = Vec::new();
                    for run in st.running.iter_mut() {
                        run.generated += 1;
                        if run.generated >= reqs[run.req].output_len {
                            finished.push(run.req);
                        }
                    }
                    st.running.retain(|run| run.generated < reqs[run.req].output_len);
                    let mut done_pf = Vec::new();
                    st.inflight_prefill.retain(|p| {
                        if p.remaining == 0 {
                            done_pf.push(p.req);
                            false
                        } else {
                            true
                        }
                    });
                    for r in done_pf {
                        prefill_done_at[r] = now;
                        if reqs[r].output_len <= 1 {
                            finished.push(r);
                        } else {
                            st.running.push(Running { req: r, generated: 1 });
                        }
                    }
                    for r in finished {
                        records.push(RequestRecord {
                            id: reqs[r].id,
                            arrival: reqs[r].arrival,
                            prefill_done: prefill_done_at[r],
                            completion: now,
                            input_len: reqs[r].input_len,
                            output_len: reqs[r].output_len,
                            slo_base: slo_base(model, &reqs[r]),
                        });
                    }
                    maybe_start_iter(ri, now, &mut reps, reqs, &cm, chunk, &mut q);
                }
            }
        }

        SimReport::from_records(records)
    }
}

/// The unified engine pinned to the legacy prefill-batch cap.
fn legacy_compatible_cfg() -> SimConfig {
    SimConfig { static_prefill_cap: Some(16), ..SimConfig::default() }
}

fn assert_reports_match(new: &SimReport, old: &SimReport, what: &str) {
    assert_eq!(new.records.len(), old.records.len(), "{what}: record count");
    let mut a = new.records.clone();
    let mut b = old.records.clone();
    a.sort_by_key(|r| r.id);
    b.sort_by_key(|r| r.id);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id, "{what}: id order");
        assert_eq!(x.input_len, y.input_len, "{what}: input_len of {}", x.id);
        assert_eq!(x.output_len, y.output_len, "{what}: output_len of {}", x.id);
        assert!((x.arrival - y.arrival).abs() <= 1e-9, "{what}: arrival of {}", x.id);
        assert!(
            (x.prefill_done - y.prefill_done).abs() <= 1e-9,
            "{what}: prefill_done of {}: {} vs {}",
            x.id,
            x.prefill_done,
            y.prefill_done
        );
        assert!(
            (x.completion - y.completion).abs() <= 1e-9,
            "{what}: completion of {}: {} vs {}",
            x.id,
            x.completion,
            y.completion
        );
    }
    for (na, oa, label) in [
        (new.tokens_per_s(), old.tokens_per_s(), "tokens_per_s"),
        (new.avg_latency(), old.avg_latency(), "avg_latency"),
        (new.avg_ttft(), old.avg_ttft(), "avg_ttft"),
        (new.p_latency(95.0), old.p_latency(95.0), "p95"),
    ] {
        assert!(
            (na - oa).abs() <= 1e-9 * oa.abs().max(1.0),
            "{what}: {label} {na} vs {oa}"
        );
    }
}

fn schedule(
    cluster: &hexgen2::cluster::Cluster,
    kind: WorkloadKind,
    k: usize,
    seed: u64,
) -> Placement {
    let mut opts = ScheduleOptions::new(kind);
    opts.max_rounds = 4;
    opts.force_k = Some(k);
    opts.seed = seed;
    scheduler::schedule(cluster, &OPT_30B, &opts).expect("schedules").placement
}

#[test]
fn disagg_parity_on_case_study() {
    // The acceptance scenario: OPT-30B on the case_study setting.
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let cfg = legacy_compatible_cfg();
    for trace in [
        Trace::offline(WorkloadKind::Lphd, 60, 3),
        Trace::offline(WorkloadKind::Hpld, 40, 9),
        Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 5),
    ] {
        let old = legacy::run_disaggregated(&c, &OPT_30B, &p, &trace);
        let new = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
        assert!(!old.records.is_empty(), "legacy reference produced nothing");
        assert_reports_match(&new, &old, "case_study disagg");
    }
}

#[test]
fn disagg_parity_on_small_homogeneous() {
    let c = settings::homogeneous_small();
    let p = schedule(&c, WorkloadKind::Lpld, 2, 0);
    let cfg = legacy_compatible_cfg();
    for trace in [
        Trace::offline(WorkloadKind::Lpld, 40, 1),
        Trace::offline(WorkloadKind::Hphd, 30, 5),
    ] {
        let old = legacy::run_disaggregated(&c, &OPT_30B, &p, &trace);
        let new = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
        assert_reports_match(&new, &old, "homogeneous_small disagg");
    }
}

#[test]
fn resched_parity_across_switch() {
    // The quiesce → drain → activate path, timeline-for-timeline.
    let c = settings::case_study();
    let p1 = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let p2 = schedule(&c, WorkloadKind::Hpld, 4, 99);
    let trace = Trace::online(WorkloadKind::Lphd, 1.5, 120.0, 4);
    let switches = vec![PlacementSwitch {
        at: 60.0,
        delay: 5.0,
        placement: p2,
        workload: Some(WorkloadKind::Hpld),
    }];
    let old = legacy::run_disaggregated_with_resched(&c, &OPT_30B, &p1, &switches, &trace);
    let sw: Vec<SwitchSpec> = switches.iter().map(SwitchSpec::from).collect();
    let new = simulate(
        &c,
        &OPT_30B,
        &ServingSpec::Disaggregated(p1.clone()),
        &sw,
        &trace,
        &legacy_compatible_cfg(),
    );
    assert_eq!(old.records.len(), trace.requests.len(), "legacy lost requests");
    assert_reports_match(&new, &old, "resched switch");
}

#[test]
fn kv_engine_flow_proportional_parity_explicit_config() {
    // ISSUE 5 guard: the KV transfer *subsystem* in `FlowProportional`
    // whole-cache mode is the pre-subsystem in-core KV path bit-for-bit —
    // asserted with every transfer-engine knob spelled out explicitly
    // rather than relying on `Default`, on the acceptance scenario
    // (opt30b / case_study) including a mid-trace resched switch.
    let c = settings::case_study();
    let p1 = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let p2 = schedule(&c, WorkloadKind::Hpld, 4, 99);
    let cfg = SimConfig {
        static_prefill_cap: Some(16),
        link: LinkModel::PerRoute,
        kv_route: RouteModel::FlowProportional,
        kv_chunk_layers: None,
        ..SimConfig::default()
    };

    // Offline + online traces without a switch.
    for trace in [
        Trace::offline(WorkloadKind::Lphd, 60, 3),
        Trace::online(WorkloadKind::Lphd, 2.0, 90.0, 5),
    ] {
        let old = legacy::run_disaggregated(&c, &OPT_30B, &p1, &trace);
        let new =
            simulate(&c, &OPT_30B, &ServingSpec::Disaggregated(p1.clone()), &[], &trace, &cfg);
        assert!(!old.records.is_empty(), "legacy reference produced nothing");
        assert_reports_match(&new, &old, "kv engine flow-proportional");
        // Exactly one ledger transfer per served request (the subsystem is
        // observing, not changing, the legacy path).
        assert_eq!(new.stats.kv_transfers, new.records.len());
    }

    // Across a resched switch (quiesce → drain → activate).
    let trace = Trace::online(WorkloadKind::Lphd, 1.5, 120.0, 4);
    let switches = vec![PlacementSwitch {
        at: 60.0,
        delay: 5.0,
        placement: p2,
        workload: Some(WorkloadKind::Hpld),
    }];
    let old = legacy::run_disaggregated_with_resched(&c, &OPT_30B, &p1, &switches, &trace);
    let sw: Vec<SwitchSpec> = switches.iter().map(SwitchSpec::from).collect();
    let new = simulate(&c, &OPT_30B, &ServingSpec::Disaggregated(p1), &sw, &trace, &cfg);
    assert_reports_match(&new, &old, "kv engine flow-proportional resched");
}

#[test]
fn prefix_share_zero_matches_legacy_engine_bit_for_bit() {
    // ISSUE 9 guard: at `--prefix-share 0` a prefix class degrades to a
    // plain trace (no request declares a prefix), and the pool-wired
    // engine must reproduce the pre-pool timelines exactly — pinned here
    // against the frozen pre-refactor reference, which predates the pool
    // entirely and ignores the `prefix` field.
    use hexgen2::workload::TraceSource;
    let c = settings::case_study();
    let p = schedule(&c, WorkloadKind::Lphd, 4, 0);
    let cfg = legacy_compatible_cfg();
    for (kind, n, seed) in [
        (WorkloadKind::Agent, 60, 3),
        (WorkloadKind::Rag, 50, 9),
        (WorkloadKind::PrefixChat, 40, 5),
    ] {
        let trace = Trace::from_source(TraceSource::offline(kind, n, seed).with_prefix_share(0.0));
        assert!(
            trace.requests.iter().all(|r| r.prefix.is_none()),
            "share 0 still declared a prefix"
        );
        let old = legacy::run_disaggregated(&c, &OPT_30B, &p, &trace);
        let new = run_disaggregated_cfg(&c, &OPT_30B, &p, &trace, &cfg);
        assert!(!old.records.is_empty(), "legacy reference produced nothing");
        assert_reports_match(&new, &old, "share-0 prefix class disagg");
        assert_eq!(new.stats.prefix_misses, 0, "share 0 consulted the pool");
    }
}

#[test]
fn colocated_parity_plain_and_chunked() {
    use hexgen2::costmodel::ReplicaConfig;
    let c = settings::homogeneous_small();
    let replicas = vec![ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers])];
    let cfg = legacy_compatible_cfg();
    for (trace, chunk) in [
        (Trace::offline(WorkloadKind::Hpld, 60, 3), None),
        (Trace::offline(WorkloadKind::Hpld, 60, 3), Some(512)),
        (Trace::offline(WorkloadKind::Lphd, 50, 7), None),
        (Trace::online(WorkloadKind::Lpld, 1.0, 80.0, 2), None),
    ] {
        let old = legacy::run_colocated(&c, &OPT_30B, &replicas, &trace, chunk);
        let new = run_colocated_cfg(&c, &OPT_30B, &replicas, &trace, chunk, &cfg);
        assert_reports_match(&new, &old, "colocated");
    }
}

#[test]
fn colocated_parity_multi_replica() {
    use hexgen2::costmodel::ReplicaConfig;
    let c = settings::homogeneous();
    let replicas = vec![
        ReplicaConfig::new(vec![(0..4).collect()], vec![OPT_30B.n_layers]),
        ReplicaConfig::new(vec![(4..8).collect()], vec![OPT_30B.n_layers]),
    ];
    let trace = Trace::offline(WorkloadKind::Lphd, 100, 4);
    let old = legacy::run_colocated(&c, &OPT_30B, &replicas, &trace, None);
    let new = run_colocated_cfg(&c, &OPT_30B, &replicas, &trace, None, &legacy_compatible_cfg());
    assert_reports_match(&new, &old, "colocated multi-replica");
}
