//! PJRT execution engine: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the CPU PJRT client, and runs
//! prefill / decode-step calls with the parameter blob fed as leading
//! arguments (the ABI fixed by `model.param_entries` on the Python side).
//!
//! Python is never on this path: after `make artifacts` the Rust binary is
//! self-contained. PJRT client/executable handles are not Send/Sync, so
//! every replica worker thread owns its own `ModelRuntime` (mirroring the
//! paper's one-process-per-replica deployment).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{load_manifests, ModelManifest, ModuleMeta};

/// Output of a prefill call.
#[derive(Debug)]
pub struct PrefillOut {
    /// [B, V] row-major.
    pub logits: Vec<f32>,
    /// [L, B, S_max, H] row-major.
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

/// Output of a decode step.
#[derive(Debug)]
pub struct DecodeOut {
    pub logits: Vec<f32>,
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

/// Extra (non-parameter) input for one call: borrowed host data + dims.
enum ExtraInput<'a> {
    I32(&'a [i32], Vec<usize>),
    F32(&'a [f32], Vec<usize>),
}

impl<'a> ExtraInput<'a> {
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            ExtraInput::I32(d, dims) => client.buffer_from_host_buffer(d, dims, None).map_err(wrap),
            ExtraInput::F32(d, dims) => client.buffer_from_host_buffer(d, dims, None).map_err(wrap),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i = |dims: &[usize]| dims.iter().map(|&x| x as i64).collect::<Vec<i64>>();
        match self {
            ExtraInput::I32(d, dims) => {
                xla::Literal::vec1(d).reshape(&dims_i(dims)).map_err(wrap)
            }
            ExtraInput::F32(d, dims) => {
                xla::Literal::vec1(d).reshape(&dims_i(dims)).map_err(wrap)
            }
        }
    }
}

/// A loaded model: compiled executables + parameters.
///
/// Parameters are uploaded to device-resident `PjRtBuffer`s once at load and
/// passed to `execute_b` by reference — re-marshalling them per call (the
/// pre-optimization Literal path, ~368 MB per gpt-100m call) dominated the
/// hot loop; see DESIGN.md §5. Set HEXGEN2_LITERAL_PARAMS=1 to force
/// the old path (kept for the before/after ablation).
pub struct ModelRuntime {
    pub manifest: ModelManifest,
    client: xla::PjRtClient,
    /// Device-resident parameters (fast path).
    param_bufs: Vec<xla::PjRtBuffer>,
    /// Host literals (ablation path, populated only when requested).
    param_lits: Vec<xla::Literal>,
    use_literals: bool,
    prefill: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    decode: HashMap<usize, xla::PjRtLoadedExecutable>,
    /// Number of PJRT execute calls (perf accounting).
    pub exec_calls: std::cell::Cell<u64>,
}

impl ModelRuntime {
    /// Load and compile every module of `model` from the artifacts dir.
    pub fn load(dir: &Path, model: &str) -> Result<ModelRuntime> {
        Self::load_filtered(dir, model, |_| true)
    }

    /// Load only the modules `keep` accepts (replica workers compile just
    /// their own variants; also keeps tests fast).
    pub fn load_filtered(
        dir: &Path,
        model: &str,
        keep: impl Fn(&ModuleMeta) -> bool,
    ) -> Result<ModelRuntime> {
        let manifests = load_manifests(dir)?;
        let manifest = manifests
            .get(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest ({:?})", manifests.keys()))?
            .clone();
        let client = xla::PjRtClient::cpu().map_err(wrap)?;

        // Parameter blob -> literals in manifest (ABI) order.
        let blob_path: PathBuf = dir.join(&manifest.params_file);
        let blob = std::fs::read(&blob_path)
            .with_context(|| format!("reading {}", blob_path.display()))?;
        if blob.len() != manifest.params_bytes {
            bail!("params blob size {} != manifest {}", blob.len(), manifest.params_bytes);
        }
        let use_literals = std::env::var("HEXGEN2_LITERAL_PARAMS").is_ok();
        let mut param_bufs = Vec::new();
        let mut param_lits = Vec::new();
        for p in &manifest.params {
            let bytes = &blob[p.offset..p.offset + p.elems * 4];
            let mut vals = vec![0f32; p.elems];
            // Little-endian f32 (written with numpy '<f4').
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                vals[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            if use_literals {
                let dims: Vec<i64> = p.shape.iter().map(|&x| x as i64).collect();
                param_lits.push(xla::Literal::vec1(&vals).reshape(&dims).map_err(wrap)?);
            } else {
                param_bufs.push(
                    client.buffer_from_host_buffer(&vals, &p.shape, None).map_err(wrap)?,
                );
            }
        }

        let mut prefill = HashMap::new();
        let mut decode = HashMap::new();
        for md in &manifest.modules {
            if !keep(md) {
                continue;
            }
            let proto =
                xla::HloModuleProto::from_text_file(dir.join(&md.file).to_str().unwrap())
                    .map_err(wrap)
                    .with_context(|| md.file.clone())?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap)?;
            match md.kind.as_str() {
                "prefill" => {
                    prefill.insert((md.batch, md.seq), exe);
                }
                "decode" => {
                    decode.insert(md.batch, exe);
                }
                other => bail!("unknown module kind {other}"),
            }
        }
        Ok(ModelRuntime {
            manifest,
            client,
            param_bufs,
            param_lits,
            use_literals,
            prefill,
            decode,
            exec_calls: std::cell::Cell::new(0),
        })
    }

    pub fn prefill_variants(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self.prefill.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn decode_variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.decode.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Pick the smallest prefill variant that fits (batch >= b, seq >= s).
    pub fn select_prefill_variant(&self, b: usize, s: usize) -> Option<(usize, usize)> {
        self.prefill_variants()
            .into_iter()
            .filter(|&(vb, vs)| vb >= b && vs >= s)
            .min_by_key(|&(vb, vs)| (vb * vs, vb))
    }

    pub fn select_decode_variant(&self, b: usize) -> Option<usize> {
        self.decode_variants().into_iter().filter(|&vb| vb >= b).min()
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        extras: &[ExtraInput],
        meta: &ModuleMeta,
    ) -> Result<Vec<Vec<f32>>> {
        self.exec_calls.set(self.exec_calls.get() + 1);
        let result = if self.use_literals {
            // Ablation path: everything as host literals, re-marshalled by
            // PJRT on every call.
            let extra_lits: Vec<xla::Literal> = extras
                .iter()
                .map(|e| e.to_literal())
                .collect::<Result<Vec<_>>>()?;
            let mut args: Vec<&xla::Literal> = self.param_lits.iter().collect();
            args.extend(extra_lits.iter());
            exe.execute::<&xla::Literal>(&args).map_err(wrap)?
        } else {
            // Fast path: params stay device-resident; only the small/bulk
            // call inputs are uploaded (single copy each).
            let extra_bufs: Vec<xla::PjRtBuffer> = extras
                .iter()
                .map(|e| e.to_buffer(&self.client))
                .collect::<Result<Vec<_>>>()?;
            let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
            args.extend(extra_bufs.iter());
            exe.execute_b::<&xla::PjRtBuffer>(&args).map_err(wrap)?
        };
        let mut lit = result[0][0].to_literal_sync().map_err(wrap)?;
        let parts = lit.decompose_tuple().map_err(wrap)?;
        if parts.len() != meta.outputs.len() {
            bail!("module {} returned {} outputs, expected {}", meta.name, parts.len(), meta.outputs.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, m) in parts.iter().zip(&meta.outputs) {
            let v = p.to_vec::<f32>().map_err(wrap)?;
            if v.len() != m.elems() {
                bail!("output {} has {} elems, expected {}", m.name, v.len(), m.elems());
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Run a prefill batch. `tokens` is [B*S] row-major, `lengths` is [B].
    pub fn prefill(&self, batch: usize, seq: usize, tokens: &[i32], lengths: &[i32]) -> Result<PrefillOut> {
        let exe = self
            .prefill
            .get(&(batch, seq))
            .ok_or_else(|| anyhow!("no prefill variant b{batch} s{seq}"))?;
        let meta = self
            .manifest
            .prefill_modules()
            .find(|m| m.batch == batch && m.seq == seq)
            .unwrap()
            .clone();
        if tokens.len() != batch * seq || lengths.len() != batch {
            bail!("prefill arg shape mismatch");
        }
        let mut outs = self.run(
            exe,
            &[
                ExtraInput::I32(tokens, vec![batch, seq]),
                ExtraInput::I32(lengths, vec![batch]),
            ],
            &meta,
        )?;
        let v_cache = outs.pop().unwrap();
        let k_cache = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        Ok(PrefillOut { logits, k_cache, v_cache })
    }

    /// Run one decode step. Caches are [L, B, S_max, H] row-major.
    pub fn decode_step(
        &self,
        batch: usize,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
    ) -> Result<DecodeOut> {
        let exe = self
            .decode
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode variant b{batch}"))?;
        let meta = self.manifest.decode_modules().find(|m| m.batch == batch).unwrap().clone();
        let dims = self.manifest.cache_dims(batch);
        let n_cache: usize = dims.iter().product();
        if token.len() != batch || pos.len() != batch || k_cache.len() != n_cache || v_cache.len() != n_cache {
            bail!("decode arg shape mismatch");
        }
        let dims_v = dims.to_vec();
        let mut outs = self.run(
            exe,
            &[
                ExtraInput::I32(token, vec![batch]),
                ExtraInput::I32(pos, vec![batch]),
                ExtraInput::F32(k_cache, dims_v.clone()),
                ExtraInput::F32(v_cache, dims_v),
            ],
            &meta,
        )?;
        let v_cache = outs.pop().unwrap();
        let k_cache = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        Ok(DecodeOut { logits, k_cache, v_cache })
    }

    pub fn vocab(&self) -> usize {
        self.manifest.config.vocab
    }
}

/// Row-wise argmax over [B, V] logits.
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<i32> {
    logits
        .chunks_exact(vocab)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
        })
        .collect()
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::artifacts_dir;
    use crate::util::json::Json;

    fn runtime() -> Option<ModelRuntime> {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        // Load just the modules the tests touch (compilation dominates).
        Some(
            ModelRuntime::load_filtered(&artifacts_dir(), "tiny", |m| {
                (m.kind == "prefill" && ((m.batch, m.seq) == (2, 64) || (m.batch, m.seq) == (1, 64)))
                    || (m.kind == "decode" && m.batch <= 2)
            })
            .expect("load tiny"),
        )
    }

    #[test]
    fn matches_python_golden() {
        let Some(rt) = runtime() else { return };
        let text = std::fs::read_to_string(artifacts_dir().join("tiny.golden.json")).unwrap();
        let g = Json::parse(&text).unwrap();
        let b = g.get("batch").unwrap().as_usize().unwrap();
        let s = g.get("seq").unwrap().as_usize().unwrap();
        let tokens: Vec<i32> =
            g.get("tokens").unwrap().as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect();
        let lengths: Vec<i32> =
            g.get("lengths").unwrap().as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect();

        let out = rt.prefill(b, s, &tokens, &lengths).unwrap();
        // Head-of-logits match.
        let want: Vec<f64> = g
            .get("prefill_logits_head")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        let vocab = rt.vocab();
        for (bi, row) in want.chunks_exact(8).enumerate() {
            for (i, &w) in row.iter().enumerate() {
                let got = out.logits[bi * vocab + i] as f64;
                assert!((got - w).abs() < 1e-3, "prefill logits[{bi},{i}]: {got} vs {w}");
            }
        }
        // Argmax (first generated token) must match exactly.
        let am = argmax_rows(&out.logits, vocab);
        let want_am: Vec<i32> = g
            .get("prefill_argmax")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(am, want_am);

        // One decode step, KV carried over — numerics must track python.
        let pos: Vec<i32> = lengths.clone();
        let dec = rt.decode_step(b, &am, &pos, &out.k_cache, &out.v_cache).unwrap();
        let want_d: Vec<f64> = g
            .get("decode_logits_head")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        for (bi, row) in want_d.chunks_exact(8).enumerate() {
            for (i, &w) in row.iter().enumerate() {
                let got = dec.logits[bi * vocab + i] as f64;
                assert!((got - w).abs() < 1e-3, "decode logits[{bi},{i}]: {got} vs {w}");
            }
        }
        let dam = argmax_rows(&dec.logits, vocab);
        let want_dam: Vec<i32> = g
            .get("decode_argmax")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(dam, want_dam);
    }

    #[test]
    fn variant_selection() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.select_prefill_variant(1, 50), Some((1, 64)));
        assert_eq!(rt.select_prefill_variant(2, 64), Some((2, 64)));
        assert_eq!(rt.select_prefill_variant(99, 64), None);
        assert_eq!(rt.select_decode_variant(2), Some(2));
        assert_eq!(rt.select_decode_variant(1), Some(1));
        assert_eq!(rt.select_decode_variant(5), None);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = vec![0.1, 0.9, 0.0, 0.5, 0.2, 0.7];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 2]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.prefill(1, 64, &[0; 10], &[10]).is_err());
        assert!(rt.prefill(8, 999, &[0; 8], &[1; 8]).is_err());
        assert!(rt.decode_step(1, &[0], &[0], &[0.0; 4], &[0.0; 4]).is_err());
    }
}
