//! Event-driven simulation of disaggregated serving over a scheduler
//! [`Placement`]: request routing proportional to the max-flow assignment,
//! prefill batching with the Fig.-1 token budget, KV-cache transfers over
//! bandwidth-serialized links, and decode continuous batching.

use std::collections::{HashMap, VecDeque};

use crate::cluster::Cluster;
use crate::costmodel::{CostModel, ReplicaConfig, TaskProfile};
use crate::model::LlmSpec;
use crate::scheduler::Placement;
use crate::workload::{Request, Trace};

use super::events::EventQueue;
use super::metrics::{RequestRecord, SimReport};
use super::{slo_base, PREFILL_TOKEN_BUDGET};

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(usize),
    /// Prefill batch finished on prefill replica `p`.
    PrefillDone(usize),
    /// KV cache of request `r` arrived at decode replica `d`.
    KvArrive { d: usize, r: usize },
    /// One decode iteration finished on decode replica `d`.
    Step(usize),
}

struct PrefillState {
    cfg: ReplicaConfig,
    queue: VecDeque<usize>,
    busy: bool,
    batch: Vec<usize>,
    max_batch: usize,
    assigned: f64,
    weight: f64,
}

struct Running {
    req: usize,
    generated: usize,
}

struct DecodeState {
    cfg: ReplicaConfig,
    running: Vec<Running>,
    waiting: VecDeque<usize>,
    stepping: bool,
    max_batch: usize,
    assigned_from: HashMap<usize, f64>,
}

/// Simulate a trace against a placement. Requests that cannot be served at
/// all (no feasible replica) are dropped from the report.
pub fn run_disaggregated(
    cluster: &Cluster,
    model: &LlmSpec,
    placement: &Placement,
    trace: &Trace,
) -> SimReport {
    let cm = CostModel::new(cluster, model);
    let (s_in_mean, s_out_mean) = trace.kind.mean_lengths();
    let task = TaskProfile::new(1, s_in_mean, s_out_mean);

    // Live prefill/decode replica tables (placement indices preserved via maps).
    let mut prefills: Vec<PrefillState> = Vec::new();
    let mut p_of_group: HashMap<usize, usize> = HashMap::new();
    let mut decodes: Vec<DecodeState> = Vec::new();
    let mut d_of_group: HashMap<usize, usize> = HashMap::new();
    for (gi, g) in placement.groups.iter().enumerate() {
        let Some(cfg) = g.config.clone() else { continue };
        if g.capacity <= 0.0 {
            continue;
        }
        if g.is_prefill {
            // Memory-limited prefill batch (at the mean input length).
            let mut mb = 1;
            for b in 1..=16 {
                if cm.memory_ok(&cfg, &TaskProfile::new(b, s_in_mean, 0.0)) {
                    mb = b;
                }
            }
            p_of_group.insert(gi, prefills.len());
            prefills.push(PrefillState {
                cfg,
                queue: VecDeque::new(),
                busy: false,
                batch: Vec::new(),
                max_batch: mb,
                assigned: 0.0,
                weight: 0.0,
            });
        } else {
            let mb = cm.max_decode_batch(&cfg, &task).max(1);
            d_of_group.insert(gi, decodes.len());
            decodes.push(DecodeState {
                cfg,
                running: Vec::new(),
                waiting: VecDeque::new(),
                stepping: false,
                max_batch: mb,
                assigned_from: HashMap::new(),
            });
        }
    }
    if prefills.is_empty() || decodes.is_empty() {
        return SimReport::from_records(vec![]);
    }

    // Flow-proportional routing weights (§3.3: "communication frequency is
    // set to be proportional to these flow values").
    let mut route_w: HashMap<(usize, usize), f64> = HashMap::new();
    for r in &placement.routes {
        let (Some(&p), Some(&d)) = (p_of_group.get(&r.prefill), d_of_group.get(&r.decode)) else {
            continue;
        };
        if r.flow > 1e-9 {
            *route_w.entry((p, d)).or_default() += r.flow;
            prefills[p].weight += r.flow;
        }
    }
    // Fallback: if max-flow left a prefill replica unrouted, connect it to
    // every decode replica with a tiny weight so requests are never stranded.
    for p in 0..prefills.len() {
        if prefills[p].weight <= 0.0 {
            for d in 0..decodes.len() {
                route_w.insert((p, d), 1e-6);
            }
            prefills[p].weight = 1e-6 * decodes.len() as f64;
        }
    }

    let reqs = &trace.requests;
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (i, r) in reqs.iter().enumerate() {
        q.push(r.arrival, Ev::Arrive(i));
    }

    let mut link_free: HashMap<(usize, usize), f64> = HashMap::new();
    let mut prefill_done_at: Vec<f64> = vec![0.0; reqs.len()];
    let mut records: Vec<RequestRecord> = Vec::new();

    // Deficit-weighted pick: argmax weight / (assigned + 1).
    let pick_prefill = |prefills: &[PrefillState]| -> usize {
        (0..prefills.len())
            .max_by(|&a, &b| {
                let fa = prefills[a].weight / (prefills[a].assigned + 1.0);
                let fb = prefills[b].weight / (prefills[b].assigned + 1.0);
                fa.partial_cmp(&fb).unwrap()
            })
            .unwrap()
    };

    // Start a prefill batch if idle and work is queued.
    fn maybe_start_prefill(
        p: usize,
        now: f64,
        prefills: &mut [PrefillState],
        reqs: &[Request],
        cm: &CostModel,
        q: &mut EventQueue<Ev>,
    ) {
        let st = &mut prefills[p];
        if st.busy || st.queue.is_empty() {
            return;
        }
        let mut batch = Vec::new();
        let mut tokens = 0.0;
        let mut max_len = 0usize;
        while let Some(&r) = st.queue.front() {
            let len = reqs[r].input_len;
            if !batch.is_empty()
                && (tokens + len as f64 > PREFILL_TOKEN_BUDGET || batch.len() >= st.max_batch)
            {
                break;
            }
            st.queue.pop_front();
            tokens += len as f64;
            max_len = max_len.max(len);
            batch.push(r);
        }
        let t = TaskProfile::new(batch.len(), max_len as f64, 0.0);
        let lat = cm.prefill_latency(&st.cfg, &t);
        st.busy = true;
        st.batch = batch;
        q.push(now + lat, Ev::PrefillDone(p));
    }

    // Start a decode iteration if idle and work exists.
    fn maybe_start_step(
        d: usize,
        now: f64,
        decodes: &mut [DecodeState],
        reqs: &[Request],
        cm: &CostModel,
        q: &mut EventQueue<Ev>,
    ) {
        let st = &mut decodes[d];
        if st.stepping {
            return;
        }
        // Continuous batching: admit waiting requests at step boundaries.
        while st.running.len() < st.max_batch {
            match st.waiting.pop_front() {
                Some(r) => st.running.push(Running { req: r, generated: 0 }),
                None => break,
            }
        }
        if st.running.is_empty() {
            return;
        }
        let avg_ctx = st
            .running
            .iter()
            .map(|r| (reqs[r.req].input_len + r.generated) as f64)
            .sum::<f64>()
            / st.running.len() as f64;
        let lat = cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx);
        st.stepping = true;
        q.push(now + lat, Ev::Step(d));
    }

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::Arrive(r) => {
                let p = pick_prefill(&prefills);
                prefills[p].assigned += 1.0;
                prefills[p].queue.push_back(r);
                maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
            }
            Ev::PrefillDone(p) => {
                let batch = std::mem::take(&mut prefills[p].batch);
                for r in batch {
                    prefill_done_at[r] = now;
                    // Route KV to a decode replica, flow-proportionally.
                    let d = (0..decodes.len())
                        .filter(|&d| route_w.contains_key(&(p, d)))
                        .max_by(|&a, &b| {
                            let wa = route_w[&(p, a)]
                                / (decodes[a].assigned_from.get(&p).copied().unwrap_or(0.0) + 1.0);
                            let wb = route_w[&(p, b)]
                                / (decodes[b].assigned_from.get(&p).copied().unwrap_or(0.0) + 1.0);
                            wa.partial_cmp(&wb).unwrap()
                        })
                        .unwrap_or(0);
                    *decodes[d].assigned_from.entry(p).or_default() += 1.0;
                    // KV transfer over the (p,d) link; links serialize.
                    let t_task = TaskProfile::new(1, reqs[r].input_len as f64, 0.0);
                    let xfer =
                        cm.kv_transfer_time(&prefills[p].cfg, &decodes[d].cfg, &t_task);
                    let free = link_free.get(&(p, d)).copied().unwrap_or(0.0).max(now);
                    let done = free + xfer;
                    link_free.insert((p, d), done);
                    q.push(done, Ev::KvArrive { d, r });
                }
                prefills[p].busy = false;
                maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
            }
            Ev::KvArrive { d, r } => {
                decodes[d].waiting.push_back(r);
                maybe_start_step(d, now, &mut decodes, reqs, &cm, &mut q);
            }
            Ev::Step(d) => {
                let st = &mut decodes[d];
                st.stepping = false;
                let mut finished = Vec::new();
                for run in st.running.iter_mut() {
                    run.generated += 1;
                    if run.generated >= reqs[run.req].output_len {
                        finished.push(run.req);
                    }
                }
                st.running.retain(|run| run.generated < reqs[run.req].output_len);
                for r in finished {
                    records.push(RequestRecord {
                        id: reqs[r].id,
                        arrival: reqs[r].arrival,
                        prefill_done: prefill_done_at[r],
                        completion: now,
                        input_len: reqs[r].input_len,
                        output_len: reqs[r].output_len,
                        slo_base: slo_base(model, &reqs[r]),
                    });
                }
                maybe_start_step(d, now, &mut decodes, reqs, &cm, &mut q);
            }
        }
    }

    SimReport::from_records(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::scheduler::{self, ScheduleOptions};
    use crate::workload::WorkloadKind;

    fn small_placement() -> (crate::cluster::Cluster, Placement) {
        let c = settings::homogeneous_small();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lpld);
        opts.max_rounds = 4;
        opts.force_k = Some(2);
        let r = scheduler::schedule(&c, &OPT_30B, &opts).unwrap();
        (c, r.placement)
    }

    #[test]
    fn all_requests_complete() {
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Lpld, 40, 1);
        let rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
        assert_eq!(rep.records.len(), 40, "lost requests");
        assert!(rep.tokens_per_s() > 0.0);
        for r in &rep.records {
            assert!(r.prefill_done >= r.arrival);
            assert!(r.completion > r.prefill_done);
        }
    }

    #[test]
    fn online_latency_below_offline_saturation() {
        let (c, p) = small_placement();
        // Gentle online load: latency should be near service time; heavy
        // offline load queues much more.
        let online = Trace::online(WorkloadKind::Lpld, 0.5, 100.0, 2);
        let offline = Trace::offline(WorkloadKind::Lpld, 200, 2);
        let r_on = run_disaggregated(&c, &OPT_30B, &p, &online);
        let r_off = run_disaggregated(&c, &OPT_30B, &p, &offline);
        assert!(r_on.avg_latency() < r_off.avg_latency(), "queueing not visible");
    }

    #[test]
    fn deterministic() {
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Hphd, 30, 5);
        let a = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let b = run_disaggregated(&c, &OPT_30B, &p, &trace);
        assert_eq!(a.tokens_per_s(), b.tokens_per_s());
        assert_eq!(a.records.len(), b.records.len());
    }

    #[test]
    fn estimated_throughput_aligns_with_simulated() {
        // §5.3: "the estimated serving throughput closely aligns with the
        // actual throughput" — within 2x either way here (estimator is a
        // steady-state bound; the simulator has queueing/startup effects).
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Lpld, 300, 3);
        let rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let est = p.tokens_per_s;
        let sim = rep.tokens_per_s();
        assert!(sim > est * 0.3 && sim < est * 3.0, "est {est} vs sim {sim}");
    }
}
