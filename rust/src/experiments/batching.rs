//! Fig. 1: effects of batching on the two phases (LLaMA-2-7B, input 512, one
//! A100) — prefill throughput saturates at 2048 batched tokens while latency
//! keeps climbing; decode throughput scales with batch size.
//! Fig. 5: the online trace's input/output length distributions.

use crate::cluster::{Cluster, GpuType, LinkTier, NodeSpec};
use crate::costmodel::{CostModel, ReplicaConfig, TaskProfile};
use crate::model::LLAMA2_7B;
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::workload::azure;

fn one_a100() -> Cluster {
    Cluster::build(
        "1xA100",
        &[NodeSpec { gpu: GpuType::A100, count: 1, dc: 0 }],
        |_, _| LinkTier::InfiniBand,
    )
}

/// Fig. 1 rows: batched tokens vs prefill throughput/latency and batch size
/// vs decode throughput/latency.
pub fn fig1_batching() -> (Table, Table) {
    let c = one_a100();
    let m = LLAMA2_7B;
    let cm = CostModel::new(&c, &m);
    let cfg = ReplicaConfig::new(vec![vec![0]], vec![m.n_layers]);

    let mut prefill = Table::new(&["batched tokens", "throughput (tokens/s)", "latency (s)"]);
    for bt in [256, 512, 1024, 2048, 4096, 8192] {
        let b = (bt / 512).max(1);
        let t = TaskProfile::new(b, 512.0, 0.0);
        let lat = cm.prefill_latency(&cfg, &t);
        prefill.row(&[
            bt.to_string(),
            format!("{:.0}", (b as f64 * 512.0) / lat),
            format!("{:.3}", lat),
        ]);
    }

    let mut decode = Table::new(&["batch size", "throughput (tokens/s)", "latency (s/token)"]);
    for b in [1usize, 4, 16, 32, 64, 128] {
        let step = cm.decode_step_latency(&cfg, b, 512.0);
        decode.row(&[
            b.to_string(),
            format!("{:.0}", b as f64 / step),
            format!("{:.4}", step),
        ]);
    }
    (prefill, decode)
}

/// Fig. 5: histogram of the Azure-conversation-like online trace lengths.
pub fn fig5_trace(n: usize, seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let edges = [0usize, 128, 256, 512, 1024, 2048, 4096, usize::MAX];
    let mut in_counts = vec![0usize; edges.len() - 1];
    let mut out_counts = vec![0usize; edges.len() - 1];
    let mut in_sum = 0usize;
    let mut out_sum = 0usize;
    for _ in 0..n {
        let (i, o) = azure::sample_conversation(&mut rng);
        in_sum += i;
        out_sum += o;
        for b in 0..edges.len() - 1 {
            if i > edges[b] && i <= edges[b + 1] {
                in_counts[b] += 1;
            }
            if o > edges[b] && o <= edges[b + 1] {
                out_counts[b] += 1;
            }
        }
    }
    let mut t = Table::new(&["token bucket", "input %", "output %"]);
    for b in 0..edges.len() - 1 {
        let hi = if edges[b + 1] == usize::MAX { ">4096".to_string() } else { edges[b + 1].to_string() };
        t.row(&[
            format!("({}, {}]", edges[b], hi),
            format!("{:.1}", 100.0 * in_counts[b] as f64 / n as f64),
            format!("{:.1}", 100.0 * out_counts[b] as f64 / n as f64),
        ]);
    }
    t.row(&[
        "mean".to_string(),
        format!("{:.0} tok", in_sum as f64 / n as f64),
        format!("{:.0} tok", out_sum as f64 / n as f64),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shapes_match_paper() {
        let (prefill, decode) = fig1_batching();
        // Prefill throughput at 2048 equals 4096/8192 (saturation), and is
        // higher than at 256.
        let tput = |t: &Table, i: usize| -> f64 { t_rows(t)[i][1].parse().unwrap() };
        let lat = |t: &Table, i: usize| -> f64 { t_rows(t)[i][2].parse().unwrap() };
        assert!(tput(&prefill, 3) > tput(&prefill, 0) * 3.0, "no prefill ramp");
        assert!((tput(&prefill, 3) - tput(&prefill, 5)).abs() < 2.0, "no saturation");
        assert!(lat(&prefill, 5) > lat(&prefill, 3) * 1.5, "latency must escalate");
        // Decode throughput grows ~linearly at small batch.
        assert!(tput(&decode, 3) > tput(&decode, 0) * 10.0, "no decode batching win");
    }

    // Table has no public row accessor; reparse its formatting buffer.
    fn t_rows(t: &Table) -> Vec<Vec<String>> {
        t.rows_for_test()
    }

    #[test]
    fn fig5_distribution_sane() {
        let t = fig5_trace(5000, 3);
        let rows = t.rows_for_test();
        let total_in: f64 = rows[..rows.len() - 1].iter().map(|r| r[1].parse::<f64>().unwrap()).sum();
        assert!((total_in - 100.0).abs() < 2.0, "input buckets sum to {total_in}");
    }
}
