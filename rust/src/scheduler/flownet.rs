//! Flow-network construction + evaluation of a typed partition (paper §3.3).
//!
//! The directed graph has the coordinator h as both source and sink; each
//! model replica becomes a split compute node (in → out edge with capacity
//! = requests it can serve per period, Appendix A); valid connections are
//! (1) h → prefill-in, (2) decode-out → h, (3) prefill-out → decode-in with
//! capacity T / KV-transfer-cost. Preflow-push (maxflow.rs) then yields the
//! system throughput bound and the flow assignments that drive both KV
//! routing and the §3.4 edge-swap guidance.

use crate::cluster::{Cluster, DeviceId, LinkTier};
use crate::costmodel::{CostModel, TaskProfile};
use crate::model::LlmSpec;

use super::maxflow::FlowNetwork;
use super::placement::{GroupPlan, KvRoute, Placement};
use super::strategy::StrategyCache;

/// Evaluate one (partition, type assignment): choose per-group strategies,
/// build the flow network, run preflow-push, and package the placement.
/// Returns None when no prefill or no decode group is feasible at all.
pub fn evaluate_types(
    cluster: &Cluster,
    model: &LlmSpec,
    task: &TaskProfile,
    period: f64,
    groups: &[Vec<DeviceId>],
    is_prefill: &[bool],
    cache: &mut StrategyCache,
) -> Option<Placement> {
    assert_eq!(groups.len(), is_prefill.len());
    let cm = CostModel::new(cluster, model);

    // Phase-appropriate strategy per group (cached).
    let mut plans: Vec<GroupPlan> = Vec::with_capacity(groups.len());
    for (g, devs) in groups.iter().enumerate() {
        let (config, capacity) = if is_prefill[g] {
            match cache.best_prefill(cluster, model, devs, task) {
                Some((cfg, _lat)) => {
                    let cap = cm.prefill_capacity(&cfg, task, period);
                    (Some(cfg), cap)
                }
                None => (None, 0.0),
            }
        } else {
            match cache.best_decode(cluster, model, devs, task) {
                Some((cfg, _tput)) => {
                    let cap = cm.decode_capacity(&cfg, task, period);
                    (Some(cfg), cap)
                }
                None => (None, 0.0),
            }
        };
        plans.push(GroupPlan { devices: devs.clone(), is_prefill: is_prefill[g], config, capacity });
    }
    if !plans.iter().any(|p| p.is_prefill && p.capacity > 0.0)
        || !plans.iter().any(|p| !p.is_prefill && p.capacity > 0.0)
    {
        return None;
    }

    // Coordinator ingress/egress capacity (connection types (1) and (2)):
    // request/response payloads over the coordinator's NIC. Rarely binding,
    // but finite per the paper's formulation.
    let nic = LinkTier::Eth100G.bandwidth();
    let ingress_cap = period * nic / (task.s_in * model.bytes_per_elem).max(1.0);
    let egress_cap = period * nic / (task.s_out * model.bytes_per_elem).max(1.0);

    // Node layout: 0 = source (h), 1 = sink (h), then in/out per group.
    let k = groups.len();
    let node_in = |g: usize| 2 + 2 * g;
    let node_out = |g: usize| 3 + 2 * g;
    let mut net = FlowNetwork::new(2 + 2 * k);

    let mut compute_edges = Vec::with_capacity(k);
    for (g, plan) in plans.iter().enumerate() {
        compute_edges.push(net.add_edge(node_in(g), node_out(g), plan.capacity));
        if plan.is_prefill {
            net.add_edge(0, node_in(g), ingress_cap);
        } else {
            net.add_edge(node_out(g), 1, egress_cap);
        }
    }

    // KV edges (connection type (3)) with stage-order-optimized capacity.
    let mut kv_edges: Vec<(usize, usize, super::maxflow::EdgeRef, f64)> = Vec::new();
    for (p, pp) in plans.iter().enumerate() {
        if !pp.is_prefill || pp.capacity <= 0.0 {
            continue;
        }
        let Some(pcfg) = &pp.config else { continue };
        for (d, dp) in plans.iter().enumerate() {
            if dp.is_prefill || dp.capacity <= 0.0 {
                continue;
            }
            let Some(dcfg) = &dp.config else { continue };
            let t = cm.kv_transfer_time(pcfg, dcfg, &task.with_batch(1));
            let cap = if t <= 0.0 { ingress_cap } else { period / t };
            let e = net.add_edge(node_out(p), node_in(d), cap);
            kv_edges.push((p, d, e, cap));
        }
    }

    let flow_value = net.max_flow(0, 1);

    let group_utilization: Vec<f64> =
        compute_edges.iter().map(|&e| net.utilization(e)).collect();
    let routes: Vec<KvRoute> = kv_edges
        .iter()
        .map(|&(p, d, e, cap)| KvRoute { prefill: p, decode: d, flow: net.flow(e), capacity: cap })
        .collect();

    Some(Placement {
        groups: plans,
        routes,
        flow_value,
        tokens_per_s: flow_value * task.s_out / period,
        group_utilization,
        // Default (throughput) score; `evaluate_partition` re-scores under
        // the caller's chosen objective.
        objective_score: flow_value,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;

    #[test]
    fn evaluate_simple_disaggregation() {
        let c = settings::homogeneous_small(); // 4xH100
        let task = TaskProfile::new(1, 512.0, 128.0);
        let groups = vec![vec![0, 1], vec![2, 3]];
        let mut cache = StrategyCache::new();
        let p = evaluate_types(&c, &OPT_30B, &task, 600.0, &groups, &[true, false], &mut cache)
            .expect("feasible placement");
        assert!(p.flow_value > 0.0, "no flow");
        assert!(p.tokens_per_s > 0.0);
        assert_eq!(p.groups.len(), 2);
        assert!(p.groups[0].is_prefill && !p.groups[1].is_prefill);
        assert_eq!(p.routes.len(), 1);
        // Flow conservation at system level: route flow equals flow value.
        assert!((p.routes[0].flow - p.flow_value).abs() < 1e-6);
        // Utilization of the binding group is 1.
        let max_util = p.group_utilization.iter().cloned().fold(0.0f64, f64::max);
        assert!(max_util > 0.99, "{:?}", p.group_utilization);
    }

    #[test]
    fn infeasible_types_return_none() {
        let c = settings::homogeneous_small();
        let task = TaskProfile::new(1, 512.0, 128.0);
        let groups = vec![vec![0, 1], vec![2, 3]];
        let mut cache = StrategyCache::new();
        // All groups prefill: no decode side.
        assert!(evaluate_types(&c, &OPT_30B, &task, 600.0, &groups, &[true, true], &mut cache)
            .is_none());
    }

    #[test]
    fn slow_kv_link_caps_flow() {
        // Prefill in dc0, decode in dc1 (WAN): KV edge should bind well below
        // the compute capacities.
        let c = settings::het1();
        let task = TaskProfile::new(1, 512.0, 128.0);
        // group0: 2xH100 (dc0), group1: 4xA6000 (dc1).
        let groups = vec![vec![0, 1], vec![12, 13, 14, 15]];
        let mut cache = StrategyCache::new();
        let p = evaluate_types(&c, &OPT_30B, &task, 600.0, &groups, &[true, false], &mut cache)
            .expect("feasible");
        let kv = &p.routes[0];
        assert!(kv.capacity < p.groups[0].capacity, "KV not binding: {p:?}");
        assert!(p.flow_value <= kv.capacity + 1e-6);
    }

    #[test]
    fn multiple_replicas_add_flow() {
        let c = settings::homogeneous(); // 8xH100
        let task = TaskProfile::new(1, 512.0, 128.0);
        let two = vec![vec![0, 1], vec![2, 3]];
        let four = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        let mut cache = StrategyCache::new();
        let p2 = evaluate_types(&c, &OPT_30B, &task, 600.0, &two, &[true, false], &mut cache).unwrap();
        let p4 =
            evaluate_types(&c, &OPT_30B, &task, 600.0, &four, &[true, false, true, false], &mut cache)
                .unwrap();
        assert!(p4.flow_value > p2.flow_value * 1.5, "{} vs {}", p4.flow_value, p2.flow_value);
    }
}
