//! The six cluster settings from paper Fig. 4 (§5.1), plus synthetic large
//! clusters for the Table-5 scalability study.
//!
//! Node groupings follow plausible RunPod rental shapes (whole servers of a
//! single GPU type); inter-node fabrics mix InfiniBand / 100GbE / 10GbE, and
//! the larger settings span two data centers so the scheduler must route
//! around "ultra-low cross data center communication" (§5.2 finding 2).

use super::gpu::GpuType::{self, A100, A6000, H100, L40};
use super::topology::{Cluster, LinkTier, NodeSpec};
use crate::util::rng::Rng;

fn node(gpu: GpuType, count: usize, dc: usize) -> NodeSpec {
    NodeSpec { gpu, count, dc }
}

/// Homogeneous setting: 8xH100 in one server (paper budget $29.52/h).
pub fn homogeneous() -> Cluster {
    Cluster::build("homogeneous", &[node(H100, 8, 0)], |_, _| LinkTier::InfiniBand)
}

/// Heterogeneous setting 1: 2xH100, 6xA100, 4xL40, 8xA6000 (paper: $28.8/h).
pub fn het1() -> Cluster {
    Cluster::build(
        "het1",
        &[
            node(H100, 2, 0),  // node 0
            node(A100, 3, 0),  // node 1
            node(A100, 3, 0),  // node 2
            node(L40, 4, 0),   // node 3
            node(A6000, 4, 1), // node 4
            node(A6000, 4, 1), // node 5
        ],
        |a, b| match (a, b) {
            (0, 1) | (0, 2) | (1, 2) => LinkTier::InfiniBand, // H100/A100 pod
            (_, 3) => LinkTier::Eth10G,                       // L40 box on slow Ethernet
            (4, 5) => LinkTier::Eth100G,                      // A6000 pod fabric
            _ => LinkTier::Eth10G,
        },
    )
}

/// Heterogeneous setting 2: 3xH100+3xA100, 6xL40+6xA6000 (paper: $26.9/h).
pub fn het2() -> Cluster {
    Cluster::build(
        "het2",
        &[
            node(H100, 3, 0),  // node 0
            node(A100, 3, 0),  // node 1
            node(L40, 3, 0),   // node 2
            node(L40, 3, 0),   // node 3
            node(A6000, 3, 1), // node 4
            node(A6000, 3, 1), // node 5
        ],
        |a, b| match (a, b) {
            (0, 1) => LinkTier::InfiniBand,
            (2, 3) => LinkTier::Eth100G,
            (4, 5) => LinkTier::Eth100G,
            _ => LinkTier::Eth10G,
        },
    )
}

/// Heterogeneous setting 3: 6xA100, 12xL40, 6xA6000 (paper: $27.1/h).
pub fn het3() -> Cluster {
    Cluster::build(
        "het3",
        &[
            node(A100, 6, 0),  // node 0: one NVLink A100 server
            node(L40, 4, 0),   // nodes 1-3: L40 boxes
            node(L40, 4, 0),
            node(L40, 4, 1),
            node(A6000, 3, 1), // nodes 4-5
            node(A6000, 3, 1),
        ],
        |a, b| match (a, b) {
            (1, 2) => LinkTier::Eth100G,
            (3, 4) | (3, 5) | (4, 5) => LinkTier::Eth100G,
            _ => LinkTier::Eth10G,
        },
    )
}

/// Heterogeneous setting 4: 3xH100 + 9xA100 (paper: $26.3/h) — the
/// "high-end only" heterogeneous pool, single DC.
pub fn het4() -> Cluster {
    Cluster::build(
        "het4",
        &[
            node(H100, 3, 0), // node 0
            node(A100, 3, 0), // node 1
            node(A100, 3, 0), // node 2
            node(A100, 3, 0), // node 3
        ],
        |a, b| match (a, b) {
            (0, 1) => LinkTier::InfiniBand,
            (1, 2) | (2, 3) | (1, 3) => LinkTier::Eth100G,
            _ => LinkTier::Eth100G,
        },
    )
}

/// Heterogeneous setting 5: 4xA100, 6xL40, 10xA6000 at ~70% of the
/// homogeneous budget (paper: $20.5/h) — the cost-efficiency study (Fig. 9).
pub fn het5() -> Cluster {
    Cluster::build(
        "het5",
        &[
            node(A100, 4, 0),  // node 0
            node(L40, 3, 0),   // node 1
            node(L40, 3, 0),   // node 2
            node(A6000, 4, 1), // node 3
            node(A6000, 4, 1), // node 4
            node(A6000, 2, 1), // node 5
        ],
        |a, b| match (a, b) {
            (1, 2) => LinkTier::Eth100G,
            (3, 4) | (3, 5) | (4, 5) => LinkTier::Eth100G,
            _ => LinkTier::Eth10G,
        },
    )
}

/// Small homogeneous cluster for the Appendix-G case study: 4xH100.
pub fn homogeneous_small() -> Cluster {
    Cluster::build("hom-4xH100", &[node(H100, 4, 0)], |_, _| LinkTier::InfiniBand)
}

/// Appendix-E case study cluster: 4xH100 + 4xA100 in one DC.
pub fn case_study() -> Cluster {
    Cluster::build(
        "case-4H100-4A100",
        &[node(H100, 4, 0), node(A100, 4, 0)],
        |_, _| LinkTier::InfiniBand,
    )
}

pub fn by_name(name: &str) -> Option<Cluster> {
    match name {
        "homogeneous" | "hom" => Some(homogeneous()),
        "het1" => Some(het1()),
        "het2" => Some(het2()),
        "het3" => Some(het3()),
        "het4" => Some(het4()),
        "het5" => Some(het5()),
        "hom4" => Some(homogeneous_small()),
        "case" | "case_study" | "case-study" => Some(case_study()),
        _ => None,
    }
}

pub const PAPER_SETTINGS: [&str; 6] = ["homogeneous", "het1", "het2", "het3", "het4", "het5"];

/// Synthetic large cluster for the Table-5 scalability study: `n` GPUs in
/// 8-GPU nodes with types drawn uniformly and a randomly-tiered fabric.
pub fn synthetic(n: usize, seed: u64) -> Cluster {
    use super::gpu::ALL_GPU_TYPES;
    let mut rng = Rng::new(seed);
    assert!(n % 8 == 0, "synthetic clusters use 8-GPU nodes");
    let n_nodes = n / 8;
    let nodes: Vec<NodeSpec> = (0..n_nodes)
        .map(|i| NodeSpec {
            gpu: *rng.choice(&ALL_GPU_TYPES),
            count: 8,
            dc: if i < n_nodes / 2 { 0 } else { 1 },
        })
        .collect();
    // Deterministic pseudo-random fabric tiers per node pair.
    let tiers = [LinkTier::InfiniBand, LinkTier::Eth100G, LinkTier::Eth10G];
    let fabric_seed = seed.wrapping_mul(0x9E3779B97F4A7C15);
    Cluster::build(&format!("synthetic-{n}"), &nodes, move |a, b| {
        let h = fabric_seed ^ (a as u64).wrapping_mul(0x100000001B3) ^ (b as u64).wrapping_mul(0x1B873593);
        tiers[(h % 3) as usize]
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_gpu_mixes() {
        let c = het1();
        assert_eq!((c.count_of(H100), c.count_of(A100), c.count_of(L40), c.count_of(A6000)), (2, 6, 4, 8));
        let c = het2();
        assert_eq!((c.count_of(H100), c.count_of(A100), c.count_of(L40), c.count_of(A6000)), (3, 3, 6, 6));
        let c = het3();
        assert_eq!((c.count_of(A100), c.count_of(L40), c.count_of(A6000)), (6, 12, 6));
        let c = het4();
        assert_eq!((c.count_of(H100), c.count_of(A100)), (3, 9));
        let c = het5();
        assert_eq!((c.count_of(A100), c.count_of(L40), c.count_of(A6000)), (4, 6, 10));
    }

    #[test]
    fn budgets_near_paper() {
        // Paper Fig. 4 budgets; our fitted prices land within ~6%.
        let cases = [
            ("homogeneous", 29.52),
            ("het1", 28.8),
            ("het2", 26.9),
            ("het3", 27.1),
            ("het4", 26.3),
            ("het5", 20.5),
        ];
        for (name, paper) in cases {
            let b = by_name(name).unwrap().budget_per_hour();
            let rel = (b - paper).abs() / paper;
            assert!(rel < 0.08, "{name}: ours {b:.2} vs paper {paper} ({:.1}%)", rel * 100.0);
        }
        // het5 must be ~70% of homogeneous.
        let frac = het5().budget_per_hour() / homogeneous().budget_per_hour();
        assert!((0.6..0.75).contains(&frac), "{frac}");
    }

    #[test]
    fn het_settings_have_low_bandwidth_links() {
        // The paper stresses "notable bandwidth limitation and heterogeneity".
        for name in ["het1", "het2", "het3", "het5"] {
            let c = by_name(name).unwrap();
            let mut mn = f64::INFINITY;
            let mut mx: f64 = 0.0;
            for i in 0..c.n() {
                for j in 0..c.n() {
                    if i != j {
                        mn = mn.min(c.bandwidth[i][j]);
                        mx = mx.max(c.bandwidth[i][j]);
                    }
                }
            }
            assert!(mx / mn > 100.0, "{name} not heterogeneous enough ({mx} / {mn})");
        }
    }

    #[test]
    fn synthetic_sizes() {
        for n in [64, 128] {
            let c = synthetic(n, 1);
            assert_eq!(c.n(), n);
            // deterministic for the same seed
            let c2 = synthetic(n, 1);
            assert_eq!(c.devices[5].gpu, c2.devices[5].gpu);
            assert_eq!(c.bandwidth[0][n - 1], c2.bandwidth[0][n - 1]);
        }
    }
}
