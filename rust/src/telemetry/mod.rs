//! Flight recorder: end-to-end request tracing and planner decision audit
//! (DESIGN.md §12).
//!
//! The simulator's aggregates ([`SimStats`](crate::simulator::SimStats),
//! the KV [`Ledger`](crate::kvtransfer::Ledger), `SearchStats`) say *how
//! much* time went where; this module records *which* request spent it and
//! *why* the planner decided what it did. Three pieces:
//!
//! - **[`TraceSink`]** — the recording interface the unified simulation
//!   core is generic over. [`NoopSink`] is the zero-cost default: its
//!   methods are empty/`None` and `#[inline(always)]`, so with tracing off
//!   the engine monomorphizes every emission site away and the PR-4
//!   allocation-free hot loop is untouched. [`Recorder`] is the live sink:
//!   a bounded ring buffer of [`Stamped`] events with per-request
//!   sampling.
//! - **Event taxonomy** — [`TraceEvent`]: the request lifecycle (arrival,
//!   admit/hold/reject, mem-stall, prefill chunks, KV
//!   enqueue/transfer/done with route + queue wait, decode join, finish)
//!   plus engine-level resched markers. Request-scoped events are sampled
//!   by a deterministic per-request hash so one request's spans are kept
//!   or dropped *together*; replica- and engine-scoped events are always
//!   recorded.
//! - **[`TraceLog`]** — the exported recording: chronological events plus
//!   the replica lane map, consumed by [`export`] (Chrome trace-event
//!   JSON for Perfetto, Prometheus text, trace-derived metrics) and by
//!   `SimReport::windowed` to reconstruct per-window engine counters.
//!
//! Decision audit records ([`AuditRecord`](audit::AuditRecord)) are the
//! planner/rescheduler side of the same story: per-candidate objective
//! breakdowns and migration-gate pricing, exported as JSON.

pub mod attribution;
pub mod audit;
pub mod export;

pub use attribution::{
    advise, attr_json, attribute_log, Advice, AdvisorCtx, AttrReport, AttribRecorder, Attributor,
    RequestBlame,
};
pub use audit::{audit_json, AuditRecord};
pub use export::{chrome_trace, derive_metrics, prometheus_dump, DerivedMetrics};

/// Serving discipline of a replica lane (mirrors the simulator's
/// `PolicyKind`; duplicated here so `telemetry` has no simulator
/// dependency and can be consumed by the scheduler side too).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    Prefill,
    Decode,
    Colocated,
}

impl Lane {
    pub fn name(&self) -> &'static str {
        match self {
            Lane::Prefill => "prefill",
            Lane::Decode => "decode",
            Lane::Colocated => "colocated",
        }
    }
}

/// One typed span/instant event of the request lifecycle. `req` values are
/// trace indices (positions in `Trace::requests`), `replica`/`src`/`dst`
/// are simulation-arena indices, both `u32` to keep the event `Copy` and
/// small (24 B stamped): a full unsampled run is one event stream in the
/// ring, not a per-request allocation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Request entered the system (event time == its arrival time).
    Arrive { req: u32 },
    /// Routed to entry replica `replica` (queue admission).
    Admit { req: u32, replica: u32 },
    /// Parked in the holding buffer (migration blackout, no entry replica).
    Hold { req: u32 },
    /// Dropped: larger than every eligible replica's memory.
    Reject { req: u32 },
    /// Admission blocked at a service boundary: replica memory full.
    MemStall { replica: u32 },
    /// A service burst (prefill batch, decode step, colocated iteration)
    /// started at the event time and runs for `dur_s`.
    Burst { replica: u32, lane: Lane, dur_s: f64 },
    /// One SARATHI chunk of `req`'s prefill processed (chunk index from 0).
    PrefillChunk { req: u32, replica: u32, chunk: u32 },
    /// Prefill finished: first token (colocated) or KV ready (disagg) —
    /// the TTFT stamp.
    PrefillDone { req: u32, replica: u32 },
    /// KV cache handed to the transfer engine on route `src → dst`;
    /// `wait_s` is the queue wait behind the busy link.
    KvEnqueue { req: u32, src: u32, dst: u32, bytes: f64, wait_s: f64 },
    /// One pipelined chunk of the transfer occupies `[start, end]` on the
    /// link (whole-cache transfers emit a single chunk). Stamped at
    /// enqueue time so the ring stays time-ordered; the span lives in the
    /// payload.
    KvXfer { req: u32, src: u32, dst: u32, chunk: u32, n_chunks: u32, start: f64, end: f64 },
    /// KV cache fully arrived at the decode replica.
    KvDone { req: u32, src: u32, dst: u32 },
    /// Joined a decode/colocated running batch (continuous batching).
    DecodeJoin { req: u32, replica: u32 },
    /// All output tokens generated.
    Finish { req: u32, replica: u32, output_len: u32 },
    /// Rescheduling switch `switch`: active replicas quiesced.
    Quiesce { switch: u32 },
    /// Switch `switch` activated (`ok`) or rolled back as infeasible.
    Activate { switch: u32, ok: bool },
    /// Prefix-pool hit (DESIGN.md §15): `tokens` of prefill skipped.
    /// `host: false` → GPU hit, request steered to the holder (the
    /// `Admit` that follows names it); `host: true` → the prefix KV
    /// re-loads from the host tier first.
    PrefixHit { req: u32, tokens: u32, host: bool },
    /// Request declared prefix `prefix` but the pool could not serve it:
    /// full prefill, then the entry replica publishes.
    PrefixMiss { req: u32, prefix: u32 },
    /// Pool made room: prefix spilled GPU → host (`to_host`) or dropped
    /// from the host tier.
    PrefixEvict { prefix: u32, tokens: u32, to_host: bool },
}

impl TraceEvent {
    /// The request this event belongs to, if it is request-scoped (the
    /// sampling unit). Replica/engine-scoped events return `None` and are
    /// always recorded.
    pub fn req(&self) -> Option<u32> {
        match *self {
            TraceEvent::Arrive { req }
            | TraceEvent::Admit { req, .. }
            | TraceEvent::Hold { req }
            | TraceEvent::Reject { req }
            | TraceEvent::PrefillChunk { req, .. }
            | TraceEvent::PrefillDone { req, .. }
            | TraceEvent::KvEnqueue { req, .. }
            | TraceEvent::KvXfer { req, .. }
            | TraceEvent::KvDone { req, .. }
            | TraceEvent::DecodeJoin { req, .. }
            | TraceEvent::Finish { req, .. }
            | TraceEvent::PrefixHit { req, .. }
            | TraceEvent::PrefixMiss { req, .. } => Some(req),
            TraceEvent::MemStall { .. }
            | TraceEvent::Burst { .. }
            | TraceEvent::Quiesce { .. }
            | TraceEvent::Activate { .. }
            | TraceEvent::PrefixEvict { .. } => None,
        }
    }
}

/// A timestamped event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stamped {
    pub t: f64,
    pub ev: TraceEvent,
}

/// The recording interface the simulation core is generic over. The two
/// implementations bracket the cost spectrum: [`NoopSink`] (tracing off,
/// everything folds away under monomorphization) and [`Recorder`].
pub trait TraceSink {
    /// Record `ev` at simulation time `t`.
    fn emit(&mut self, t: f64, ev: TraceEvent);
    /// The live recorder, if any — the engine uses `is_some()` to gate
    /// trace-only work like per-chunk span synthesis, and drains the ring
    /// through it at the end of a run.
    fn recorder(&mut self) -> Option<&mut Recorder>;
    /// The sink itself when recording is active, `None` when it is a
    /// no-op. Policies receive this through `PolicyEnv` (as a plain
    /// `Option<&mut dyn TraceSink>`, since `PolicyEnv` cannot be generic
    /// behind `dyn ReplicaPolicy`) so policy-emitted events (decode joins,
    /// prefill chunks, mem-stalls) reach *wrapping* sinks — the
    /// attribution recorder — and not just the raw ring buffer.
    fn active(&mut self) -> Option<&mut dyn TraceSink>;
}

/// Tracing off: every emission site compiles to nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    #[inline(always)]
    fn emit(&mut self, _t: f64, _ev: TraceEvent) {}

    #[inline(always)]
    fn recorder(&mut self) -> Option<&mut Recorder> {
        None
    }

    #[inline(always)]
    fn active(&mut self) -> Option<&mut dyn TraceSink> {
        None
    }
}

/// FNV-1a over the request index: a deterministic, platform-independent
/// hash for sampling, so the same request keeps (or loses) *all* its spans
/// and same-seed runs produce byte-identical traces.
fn fnv1a(x: u32) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bounded ring-buffer recorder with per-request sampling.
#[derive(Clone, Debug)]
pub struct Recorder {
    sample_rate: f64,
    cap: usize,
    buf: Vec<Stamped>,
    /// Oldest slot once the ring has wrapped.
    head: usize,
    /// Events overwritten after the ring filled (metric conservation only
    /// holds when this stays 0 — see [`TraceLog::dropped`]).
    dropped: usize,
    lanes: Vec<Lane>,
}

impl Recorder {
    /// `sample_rate` is the kept fraction of *requests* (1.0 = everything);
    /// `cap` bounds the ring (0 is clamped to 1).
    pub fn new(sample_rate: f64, cap: usize) -> Recorder {
        Recorder {
            sample_rate,
            cap: cap.max(1),
            buf: Vec::new(),
            head: 0,
            dropped: 0,
            lanes: Vec::new(),
        }
    }

    /// Deterministic per-request sampling decision.
    pub fn sampled(&self, req: u32) -> bool {
        if self.sample_rate >= 1.0 {
            return true;
        }
        if self.sample_rate <= 0.0 {
            return false;
        }
        // Top 53 bits → uniform in [0, 1).
        let u = (fnv1a(req) >> 11) as f64 / (1u64 << 53) as f64;
        u < self.sample_rate
    }

    pub fn emit(&mut self, t: f64, ev: TraceEvent) {
        if let Some(r) = ev.req() {
            if !self.sampled(r) {
                return;
            }
        }
        let s = Stamped { t, ev };
        if self.buf.len() < self.cap {
            self.buf.push(s);
        } else {
            // Ring wrap: overwrite the oldest event.
            self.buf[self.head] = s;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Replica lane map (index = arena index), set by the engine at the
    /// end of a run.
    pub fn set_lanes(&mut self, lanes: Vec<Lane>) {
        self.lanes = lanes;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Finish the recording: rotate the ring back to chronological order.
    pub fn into_log(mut self) -> TraceLog {
        self.buf.rotate_left(self.head);
        TraceLog {
            events: self.buf,
            dropped: self.dropped,
            sample_rate: self.sample_rate,
            lanes: self.lanes,
        }
    }
}

impl TraceSink for Recorder {
    #[inline]
    fn emit(&mut self, t: f64, ev: TraceEvent) {
        Recorder::emit(self, t, ev)
    }

    #[inline]
    fn recorder(&mut self) -> Option<&mut Recorder> {
        Some(self)
    }

    #[inline]
    fn active(&mut self) -> Option<&mut dyn TraceSink> {
        Some(self)
    }
}

/// A finished recording: chronological events plus lane metadata.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    /// Events in time order (ring rotated on export).
    pub events: Vec<Stamped>,
    /// Events lost to ring-buffer wrap. Trace-derived metrics
    /// ([`derive_metrics`]) only conserve the engine's counters when this
    /// is 0 and `sample_rate` is 1.0.
    pub dropped: usize,
    pub sample_rate: f64,
    /// Serving discipline per arena replica index (Perfetto lane names).
    pub lanes: Vec<Lane>,
}

impl TraceLog {
    /// Mem-stall count among events stamped in `[t0, t1)` — the per-window
    /// reconstruction `SimReport::windowed` uses.
    pub fn mem_stalls_in(&self, t0: f64, t1: f64) -> usize {
        self.events
            .iter()
            .filter(|s| s.t >= t0 && s.t < t1)
            .filter(|s| matches!(s.ev, TraceEvent::MemStall { .. }))
            .count()
    }

    /// KV queue-wait seconds among transfers enqueued in `[t0, t1)`.
    pub fn kv_wait_in(&self, t0: f64, t1: f64) -> f64 {
        self.events
            .iter()
            .filter(|s| s.t >= t0 && s.t < t1)
            .filter_map(|s| match s.ev {
                TraceEvent::KvEnqueue { wait_s, .. } => Some(wait_s),
                _ => None,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_sink_records_nothing() {
        let mut s = NoopSink;
        s.emit(1.0, TraceEvent::Arrive { req: 0 });
        assert!(s.recorder().is_none());
        assert!(s.active().is_none());
    }

    #[test]
    fn recorder_keeps_events_in_order() {
        let mut r = Recorder::new(1.0, 1024);
        for i in 0..10u32 {
            r.emit(i as f64, TraceEvent::Arrive { req: i });
        }
        let log = r.into_log();
        assert_eq!(log.events.len(), 10);
        assert_eq!(log.dropped, 0);
        assert!(log.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn ring_wraps_and_counts_dropped() {
        let mut r = Recorder::new(1.0, 4);
        for i in 0..10u32 {
            r.emit(i as f64, TraceEvent::Arrive { req: i });
        }
        let log = r.into_log();
        assert_eq!(log.events.len(), 4);
        assert_eq!(log.dropped, 6);
        // Oldest events were overwritten; the survivors are chronological.
        assert_eq!(log.events[0].t, 6.0);
        assert!(log.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn sampling_is_deterministic_and_per_request() {
        let r = Recorder::new(0.5, 16);
        let kept: Vec<bool> = (0..64).map(|i| r.sampled(i)).collect();
        let again: Vec<bool> = (0..64).map(|i| r.sampled(i)).collect();
        assert_eq!(kept, again);
        let n = kept.iter().filter(|&&k| k).count();
        assert!(n > 8 && n < 56, "rate 0.5 kept {n}/64");
        // Replica-scoped events bypass sampling entirely.
        let mut r0 = Recorder::new(0.0, 16);
        r0.emit(0.0, TraceEvent::Arrive { req: 3 });
        r0.emit(0.0, TraceEvent::MemStall { replica: 1 });
        assert_eq!(r0.len(), 1);
    }

    #[test]
    fn windowed_helpers_filter_by_time() {
        let mut r = Recorder::new(1.0, 64);
        r.emit(1.0, TraceEvent::MemStall { replica: 0 });
        r.emit(5.0, TraceEvent::MemStall { replica: 0 });
        r.emit(
            5.0,
            TraceEvent::KvEnqueue { req: 0, src: 0, dst: 1, bytes: 8.0, wait_s: 0.25 },
        );
        let log = r.into_log();
        assert_eq!(log.mem_stalls_in(0.0, 2.0), 1);
        assert_eq!(log.mem_stalls_in(0.0, 10.0), 2);
        assert_eq!(log.kv_wait_in(0.0, 2.0), 0.0);
        assert_eq!(log.kv_wait_in(2.0, 10.0), 0.25);
    }
}
