//! Bench: regenerate paper Fig. 9 (70% price-budget cost-efficiency study).
use hexgen2::experiments::{endtoend, ExpOpts};
use hexgen2::model::LLAMA2_70B;

fn main() {
    endtoend::fig9_budget(&LLAMA2_70B, &ExpOpts::from_env())
        .print("Fig. 9: 70% budget (het5) vs DistServe homogeneous (LLaMA-2-70B)");
}
