//! Quickstart: load the tiny AOT model and serve a handful of requests
//! through the live disaggregated pipeline (2 prefill workers + 1 decode
//! worker), printing the generated token streams.
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use hexgen2::coordinator::{serve, CoordinatorConfig, LiveRequest};
use hexgen2::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut cfg = CoordinatorConfig::new("tiny");
    cfg.n_prefill = 2;
    cfg.n_decode = 1;

    let mut rng = Rng::new(7);
    let requests: Vec<LiveRequest> = (0..8)
        .map(|id| LiveRequest {
            id,
            tokens: (0..rng.range(10, 60)).map(|_| rng.range(0, 512) as i32).collect(),
            output_len: rng.range(4, 12),
        })
        .collect();

    println!("serving {} requests over 2 prefill + 1 decode workers...", requests.len());
    let rep = serve(&cfg, requests)?;
    for (id, tokens) in &rep.outputs {
        println!("request {id}: generated {tokens:?}");
    }
    println!(
        "\n{} requests in {:.2}s wall; {:.0} output tokens/s (serving span); {:.1} MiB of KV moved prefill->decode",
        rep.outputs.len(),
        rep.elapsed_s,
        rep.report.tokens_per_s(),
        rep.kv_bytes_total as f64 / (1 << 20) as f64,
    );
    Ok(())
}
