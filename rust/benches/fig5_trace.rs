//! Bench: regenerate paper Fig. 5 (online trace length distribution) and
//! time the trace sampler.
use hexgen2::experiments::batching;
use hexgen2::util::bench;
use hexgen2::workload::{Trace, WorkloadKind};

fn main() {
    batching::fig5_trace(20_000, 7).print("Fig. 5: online trace length distribution");
    bench::time("fig5/sample-20k-conversations", 1, 10, || {
        std::hint::black_box(batching::fig5_trace(20_000, 7));
    });
    bench::time("fig5/online-trace-gen", 1, 10, || {
        std::hint::black_box(Trace::online(WorkloadKind::Online, 5.0, 600.0, 1));
    });
}
