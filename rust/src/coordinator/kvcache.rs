//! KV-cache slot manager for decode replicas (the PagedAttention-style
//! block management of §4, adapted to the AOT shape discipline).
//!
//! A decode replica's compiled module works on fixed-capacity caches
//! [L, B, S_max, H]; this manager owns those buffers, allocates batch slots
//! to requests, and splices migrated per-request caches ([L, S_max, H],
//! the payload of a KV transfer) into slot columns. Layout is row-major, so
//! a (layer, slot) pane is one contiguous S_max*H block — inserts are L
//! memcpys, which is also exactly the wire format of the transfer.

/// Slot-managed KV cache buffers for one decode replica.
pub struct KvSlots {
    pub n_layers: usize,
    pub batch: usize,
    pub s_max: usize,
    pub hidden: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    occupied: Vec<bool>,
}

impl KvSlots {
    pub fn new(dims: [usize; 4]) -> KvSlots {
        let [l, b, s, h] = dims;
        KvSlots {
            n_layers: l,
            batch: b,
            s_max: s,
            hidden: h,
            k: vec![0.0; l * b * s * h],
            v: vec![0.0; l * b * s * h],
            occupied: vec![false; b],
        }
    }

    pub fn pane(&self) -> usize {
        self.s_max * self.hidden
    }

    /// Allocate a free slot, if any.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.occupied.iter().position(|&o| !o)?;
        self.occupied[slot] = true;
        Some(slot)
    }

    pub fn free(&mut self, slot: usize) {
        assert!(self.occupied[slot], "double free of slot {slot}");
        self.occupied[slot] = false;
    }

    pub fn n_occupied(&self) -> usize {
        self.occupied.iter().filter(|&&o| o).count()
    }

    pub fn is_full(&self) -> bool {
        self.n_occupied() == self.batch
    }

    /// Splice a migrated per-request cache ([L, S_max, H] row-major — the KV
    /// transfer payload) into a slot column.
    pub fn insert(&mut self, slot: usize, k_req: &[f32], v_req: &[f32]) {
        let pane = self.pane();
        assert!(slot < self.batch, "slot out of range");
        assert_eq!(k_req.len(), self.n_layers * pane, "bad k payload");
        assert_eq!(v_req.len(), self.n_layers * pane, "bad v payload");
        for l in 0..self.n_layers {
            let dst = (l * self.batch + slot) * pane;
            let src = l * pane;
            self.k[dst..dst + pane].copy_from_slice(&k_req[src..src + pane]);
            self.v[dst..dst + pane].copy_from_slice(&v_req[src..src + pane]);
        }
    }

    /// Extract one request's cache column from a *batch* cache
    /// [L, B, S_max, H] (used on the prefill side to build the transfer
    /// payload for request `b`).
    pub fn extract_request(
        batch_cache: &[f32],
        dims: [usize; 4],
        b: usize,
    ) -> Vec<f32> {
        let [l_n, b_n, s, h] = dims;
        assert!(b < b_n);
        assert_eq!(batch_cache.len(), l_n * b_n * s * h);
        let pane = s * h;
        let mut out = vec![0.0f32; l_n * pane];
        for l in 0..l_n {
            let src = (l * b_n + b) * pane;
            out[l * pane..(l + 1) * pane].copy_from_slice(&batch_cache[src..src + pane]);
        }
        out
    }

    pub fn k(&self) -> &[f32] {
        &self.k
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// Replace buffers with the decode module's updated caches.
    pub fn update(&mut self, k: Vec<f32>, v: Vec<f32>) {
        assert_eq!(k.len(), self.k.len());
        assert_eq!(v.len(), self.v.len());
        self.k = k;
        self.v = v;
    }

    /// Bytes a migrated request cache occupies (the KV transfer size).
    pub fn transfer_bytes(&self) -> usize {
        2 * self.n_layers * self.pane() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut s = KvSlots::new([2, 3, 4, 8]);
        let a = s.alloc().unwrap();
        let b = s.alloc().unwrap();
        let c = s.alloc().unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        assert!(s.is_full());
        assert!(s.alloc().is_none());
        s.free(b);
        assert_eq!(s.alloc(), Some(1));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut s = KvSlots::new([1, 1, 2, 2]);
        let a = s.alloc().unwrap();
        s.free(a);
        s.free(a);
    }

    #[test]
    fn insert_extract_roundtrip() {
        // Build a batch cache with recognizable values, extract request 1,
        // insert into slot 2 of a fresh manager, check exact placement.
        let dims = [2usize, 3, 4, 2]; // L=2 B=3 S=4 H=2
        let n: usize = dims.iter().product();
        let batch_cache: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let req = KvSlots::extract_request(&batch_cache, dims, 1);
        assert_eq!(req.len(), 2 * 4 * 2);
        // layer 0, request 1 starts at (0*3+1)*8 = 8.
        assert_eq!(req[0], 8.0);
        // layer 1, request 1 starts at (1*3+1)*8 = 32.
        assert_eq!(req[8], 32.0);

        let mut slots = KvSlots::new(dims);
        assert_eq!(slots.alloc(), Some(0));
        assert_eq!(slots.alloc(), Some(1));
        assert_eq!(slots.alloc(), Some(2));
        slots.insert(2, &req, &req);
        // layer 0, slot 2 pane starts at (0*3+2)*8 = 16.
        assert_eq!(slots.k()[16], 8.0);
        assert_eq!(slots.v()[16 + 7], 15.0);
        // layer 1, slot 2 pane starts at (1*3+2)*8 = 40.
        assert_eq!(slots.k()[40], 32.0);
    }

    #[test]
    fn transfer_bytes_formula() {
        let s = KvSlots::new([4, 2, 192, 256]);
        // 2 (K and V) * L * S_max * H * 4 bytes.
        assert_eq!(s.transfer_bytes(), 2 * 4 * 192 * 256 * 4);
    }
}
