//! Event-driven simulation of disaggregated serving over a scheduler
//! [`Placement`]: request routing proportional to the max-flow assignment,
//! prefill batching with the Fig.-1 token budget, KV-cache transfers over
//! bandwidth-serialized links, and decode continuous batching.
//!
//! Supports *online rescheduling* (the rescheduler subsystem's §3.3 loop):
//! [`run_disaggregated_with_resched`] takes a list of [`PlacementSwitch`]es;
//! at each switch time a `Resched` event quiesces the active replicas (their
//! unstarted queue drains back to a holding buffer, in-flight batches and
//! running decodes complete on the old placement — the drain), and after the
//! switch's migration delay an `Activate` event brings the new placement's
//! replicas live and flushes the held requests to them.

use std::collections::{HashMap, VecDeque};

use crate::cluster::Cluster;
use crate::costmodel::{CostModel, ReplicaConfig, TaskProfile};
use crate::model::LlmSpec;
use crate::scheduler::Placement;
use crate::workload::{Request, Trace, WorkloadKind};

use super::events::EventQueue;
use super::metrics::{RequestRecord, SimReport};
use super::{slo_base, PREFILL_TOKEN_BUDGET};

/// One placement switch of a rescheduling scenario: at time `at` the old
/// replicas are quiesced; at `at + delay` (drain + KV/weight migration, as
/// priced by `rescheduler::migration`) the new placement starts serving.
#[derive(Clone, Debug)]
pub struct PlacementSwitch {
    pub at: f64,
    pub delay: f64,
    pub placement: Placement,
    /// Workload the new placement was (re-)planned for: its mean lengths
    /// size the new replicas' batching (prefill memory batch, decode slot
    /// count). None = keep the trace's opening-phase statistics.
    pub workload: Option<WorkloadKind>,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(usize),
    /// Prefill batch finished on prefill replica `p` (arena index).
    PrefillDone(usize),
    /// KV cache of request `r` arrived at decode replica `d` (arena index).
    KvArrive { d: usize, r: usize },
    /// One decode iteration finished on decode replica `d` (arena index).
    Step(usize),
    /// Initiate placement switch `i`: quiesce the active replicas.
    Resched(usize),
    /// Switch `i`'s new placement goes live.
    Activate(usize),
}

struct PrefillState {
    cfg: ReplicaConfig,
    queue: VecDeque<usize>,
    busy: bool,
    batch: Vec<usize>,
    max_batch: usize,
    assigned: f64,
    weight: f64,
}

struct Running {
    req: usize,
    generated: usize,
}

struct DecodeState {
    cfg: ReplicaConfig,
    running: Vec<Running>,
    waiting: VecDeque<usize>,
    stepping: bool,
    max_batch: usize,
    assigned_from: HashMap<usize, f64>,
}

/// Append one placement's replicas to the arenas. Returns the arena indices
/// of the appended prefill replicas (the new active set), or None when the
/// placement has no feasible prefill or decode replica.
#[allow(clippy::too_many_arguments)]
fn build_replicas(
    cm: &CostModel,
    placement: &Placement,
    s_in_mean: f64,
    task: &TaskProfile,
    prefills: &mut Vec<PrefillState>,
    decodes: &mut Vec<DecodeState>,
    route_w: &mut HashMap<(usize, usize), f64>,
) -> Option<Vec<usize>> {
    let mut p_of_group: HashMap<usize, usize> = HashMap::new();
    let mut d_of_group: HashMap<usize, usize> = HashMap::new();
    let p_base = prefills.len();
    let d_base = decodes.len();
    for (gi, g) in placement.groups.iter().enumerate() {
        let Some(cfg) = g.config.clone() else { continue };
        if g.capacity <= 0.0 {
            continue;
        }
        if g.is_prefill {
            // Memory-limited prefill batch (at the mean input length).
            let mut mb = 1;
            for b in 1..=16 {
                if cm.memory_ok(&cfg, &TaskProfile::new(b, s_in_mean, 0.0)) {
                    mb = b;
                }
            }
            p_of_group.insert(gi, prefills.len());
            prefills.push(PrefillState {
                cfg,
                queue: VecDeque::new(),
                busy: false,
                batch: Vec::new(),
                max_batch: mb,
                assigned: 0.0,
                weight: 0.0,
            });
        } else {
            let mb = cm.max_decode_batch(&cfg, task).max(1);
            d_of_group.insert(gi, decodes.len());
            decodes.push(DecodeState {
                cfg,
                running: Vec::new(),
                waiting: VecDeque::new(),
                stepping: false,
                max_batch: mb,
                assigned_from: HashMap::new(),
            });
        }
    }
    if prefills.len() == p_base || decodes.len() == d_base {
        // Infeasible placement: roll back the partial build.
        prefills.truncate(p_base);
        decodes.truncate(d_base);
        return None;
    }

    // Flow-proportional routing weights (§3.3: "communication frequency is
    // set to be proportional to these flow values").
    for r in &placement.routes {
        let (Some(&p), Some(&d)) = (p_of_group.get(&r.prefill), d_of_group.get(&r.decode)) else {
            continue;
        };
        if r.flow > 1e-9 {
            *route_w.entry((p, d)).or_default() += r.flow;
            prefills[p].weight += r.flow;
        }
    }
    // Fallback: if max-flow left a prefill replica unrouted, connect it to
    // every decode replica *of this placement* with a tiny weight so requests
    // are never stranded.
    for p in p_base..prefills.len() {
        if prefills[p].weight <= 0.0 {
            for d in d_base..decodes.len() {
                route_w.insert((p, d), 1e-6);
            }
            prefills[p].weight = 1e-6 * (decodes.len() - d_base) as f64;
        }
    }
    Some((p_base..prefills.len()).collect())
}

/// Deficit-weighted pick among the active prefill replicas:
/// argmax weight / (assigned + 1).
fn pick_prefill(prefills: &[PrefillState], active: &[usize]) -> usize {
    *active
        .iter()
        .max_by(|&&a, &&b| {
            let fa = prefills[a].weight / (prefills[a].assigned + 1.0);
            let fb = prefills[b].weight / (prefills[b].assigned + 1.0);
            fa.partial_cmp(&fb).unwrap()
        })
        .expect("no active prefill replica")
}

// Start a prefill batch if idle and work is queued.
fn maybe_start_prefill(
    p: usize,
    now: f64,
    prefills: &mut [PrefillState],
    reqs: &[Request],
    cm: &CostModel,
    q: &mut EventQueue<Ev>,
) {
    let st = &mut prefills[p];
    if st.busy || st.queue.is_empty() {
        return;
    }
    let mut batch = Vec::new();
    let mut tokens = 0.0;
    let mut max_len = 0usize;
    while let Some(&r) = st.queue.front() {
        let len = reqs[r].input_len;
        if !batch.is_empty()
            && (tokens + len as f64 > PREFILL_TOKEN_BUDGET || batch.len() >= st.max_batch)
        {
            break;
        }
        st.queue.pop_front();
        tokens += len as f64;
        max_len = max_len.max(len);
        batch.push(r);
    }
    let t = TaskProfile::new(batch.len(), max_len as f64, 0.0);
    let lat = cm.prefill_latency(&st.cfg, &t);
    st.busy = true;
    st.batch = batch;
    q.push(now + lat, Ev::PrefillDone(p));
}

// Start a decode iteration if idle and work exists.
fn maybe_start_step(
    d: usize,
    now: f64,
    decodes: &mut [DecodeState],
    reqs: &[Request],
    cm: &CostModel,
    q: &mut EventQueue<Ev>,
) {
    let st = &mut decodes[d];
    if st.stepping {
        return;
    }
    // Continuous batching: admit waiting requests at step boundaries.
    while st.running.len() < st.max_batch {
        match st.waiting.pop_front() {
            Some(r) => st.running.push(Running { req: r, generated: 0 }),
            None => break,
        }
    }
    if st.running.is_empty() {
        return;
    }
    let avg_ctx = st
        .running
        .iter()
        .map(|r| (reqs[r.req].input_len + r.generated) as f64)
        .sum::<f64>()
        / st.running.len() as f64;
    let lat = cm.decode_step_latency(&st.cfg, st.running.len(), avg_ctx);
    st.stepping = true;
    q.push(now + lat, Ev::Step(d));
}

/// Simulate a trace against a placement. Requests that cannot be served at
/// all (no feasible replica) are dropped from the report.
pub fn run_disaggregated(
    cluster: &Cluster,
    model: &LlmSpec,
    placement: &Placement,
    trace: &Trace,
) -> SimReport {
    run_disaggregated_with_resched(cluster, model, placement, &[], trace)
}

/// Simulate a trace with mid-trace placement switches (the rescheduler's
/// closed loop). `switches` must be sorted by `at` and non-overlapping
/// (each `at + delay` before the next `at`). An infeasible switch placement
/// is skipped: the previously active replicas resume at activation time.
pub fn run_disaggregated_with_resched(
    cluster: &Cluster,
    model: &LlmSpec,
    initial: &Placement,
    switches: &[PlacementSwitch],
    trace: &Trace,
) -> SimReport {
    for s in switches {
        assert!(
            s.at.is_finite() && s.delay.is_finite() && s.at >= 0.0 && s.delay >= 0.0,
            "placement switch times must be finite and non-negative (at {}, delay {})",
            s.at,
            s.delay
        );
    }
    for w in switches.windows(2) {
        assert!(
            w[0].at + w[0].delay <= w[1].at,
            "placement switches must be sorted and non-overlapping"
        );
    }
    let cm = CostModel::new(cluster, model);
    let (s_in_mean, s_out_mean) = trace.kind.mean_lengths();
    let task = TaskProfile::new(1, s_in_mean, s_out_mean);

    // Replica arena: switches append; indices stay valid for in-flight
    // events, so a draining replica keeps serving after it is deactivated.
    let mut prefills: Vec<PrefillState> = Vec::new();
    let mut decodes: Vec<DecodeState> = Vec::new();
    let mut route_w: HashMap<(usize, usize), f64> = HashMap::new();

    let Some(mut active_p) =
        build_replicas(&cm, initial, s_in_mean, &task, &mut prefills, &mut decodes, &mut route_w)
    else {
        return SimReport::from_records(vec![]);
    };

    let reqs = &trace.requests;
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (i, r) in reqs.iter().enumerate() {
        q.push(r.arrival, Ev::Arrive(i));
    }
    for (i, s) in switches.iter().enumerate() {
        q.push(s.at, Ev::Resched(i));
        q.push(s.at + s.delay, Ev::Activate(i));
    }

    let mut link_free: HashMap<(usize, usize), f64> = HashMap::new();
    let mut prefill_done_at: Vec<f64> = vec![0.0; reqs.len()];
    let mut records: Vec<RequestRecord> = Vec::new();
    // Requests waiting out a migration blackout (no active prefill replica).
    let mut holding: Vec<usize> = Vec::new();
    // Active set stashed at Resched time, restored if the switch is infeasible.
    let mut quiesced: Vec<Vec<usize>> = vec![Vec::new(); switches.len()];

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::Arrive(r) => {
                if active_p.is_empty() {
                    holding.push(r);
                } else {
                    let p = pick_prefill(&prefills, &active_p);
                    prefills[p].assigned += 1.0;
                    prefills[p].queue.push_back(r);
                    maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
                }
            }
            Ev::Resched(i) => {
                // Quiesce: stop admitting to the active replicas; pull their
                // unstarted requests back into the holding buffer (arrival
                // order preserved by sorting on request id, which is
                // arrival-ordered for generated traces). In-flight prefill
                // batches and running decodes drain on the old placement.
                quiesced[i] = std::mem::take(&mut active_p);
                let mut pulled: Vec<usize> = Vec::new();
                for &p in &quiesced[i] {
                    pulled.extend(prefills[p].queue.drain(..));
                }
                pulled.sort_unstable();
                holding.extend(pulled);
            }
            Ev::Activate(i) => {
                // Size the new replicas for the workload they were planned
                // for (post-shift statistics), not the opening phase's.
                let (sw_s_in, sw_s_out) = switches[i]
                    .workload
                    .map(|k| k.mean_lengths())
                    .unwrap_or((s_in_mean, s_out_mean));
                let sw_task = TaskProfile::new(1, sw_s_in, sw_s_out);
                match build_replicas(
                    &cm,
                    &switches[i].placement,
                    sw_s_in,
                    &sw_task,
                    &mut prefills,
                    &mut decodes,
                    &mut route_w,
                ) {
                    Some(fresh) => active_p = fresh,
                    // Infeasible new placement: resume the old replicas.
                    None => active_p = std::mem::take(&mut quiesced[i]),
                }
                for r in std::mem::take(&mut holding) {
                    let p = pick_prefill(&prefills, &active_p);
                    prefills[p].assigned += 1.0;
                    prefills[p].queue.push_back(r);
                    maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
                }
            }
            Ev::PrefillDone(p) => {
                let batch = std::mem::take(&mut prefills[p].batch);
                for r in batch {
                    prefill_done_at[r] = now;
                    // Route KV to a decode replica, flow-proportionally.
                    let d = (0..decodes.len())
                        .filter(|&d| route_w.contains_key(&(p, d)))
                        .max_by(|&a, &b| {
                            let wa = route_w[&(p, a)]
                                / (decodes[a].assigned_from.get(&p).copied().unwrap_or(0.0) + 1.0);
                            let wb = route_w[&(p, b)]
                                / (decodes[b].assigned_from.get(&p).copied().unwrap_or(0.0) + 1.0);
                            wa.partial_cmp(&wb).unwrap()
                        })
                        .unwrap_or(0);
                    *decodes[d].assigned_from.entry(p).or_default() += 1.0;
                    // KV transfer over the (p,d) link; links serialize.
                    let t_task = TaskProfile::new(1, reqs[r].input_len as f64, 0.0);
                    let xfer = cm.kv_transfer_time(&prefills[p].cfg, &decodes[d].cfg, &t_task);
                    let free = link_free.get(&(p, d)).copied().unwrap_or(0.0).max(now);
                    let done = free + xfer;
                    link_free.insert((p, d), done);
                    q.push(done, Ev::KvArrive { d, r });
                }
                prefills[p].busy = false;
                maybe_start_prefill(p, now, &mut prefills, reqs, &cm, &mut q);
            }
            Ev::KvArrive { d, r } => {
                decodes[d].waiting.push_back(r);
                maybe_start_step(d, now, &mut decodes, reqs, &cm, &mut q);
            }
            Ev::Step(d) => {
                let st = &mut decodes[d];
                st.stepping = false;
                let mut finished = Vec::new();
                for run in st.running.iter_mut() {
                    run.generated += 1;
                    if run.generated >= reqs[run.req].output_len {
                        finished.push(run.req);
                    }
                }
                st.running.retain(|run| run.generated < reqs[run.req].output_len);
                for r in finished {
                    records.push(RequestRecord {
                        id: reqs[r].id,
                        arrival: reqs[r].arrival,
                        prefill_done: prefill_done_at[r],
                        completion: now,
                        input_len: reqs[r].input_len,
                        output_len: reqs[r].output_len,
                        slo_base: slo_base(model, &reqs[r]),
                    });
                }
                maybe_start_step(d, now, &mut decodes, reqs, &cm, &mut q);
            }
        }
    }

    SimReport::from_records(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::model::OPT_30B;
    use crate::scheduler::{self, ScheduleOptions};
    use crate::workload::WorkloadKind;

    fn small_placement() -> (crate::cluster::Cluster, Placement) {
        let c = settings::homogeneous_small();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lpld);
        opts.max_rounds = 4;
        opts.force_k = Some(2);
        let r = scheduler::schedule(&c, &OPT_30B, &opts).unwrap();
        (c, r.placement)
    }

    #[test]
    fn all_requests_complete() {
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Lpld, 40, 1);
        let rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
        assert_eq!(rep.records.len(), 40, "lost requests");
        assert!(rep.tokens_per_s() > 0.0);
        for r in &rep.records {
            assert!(r.prefill_done >= r.arrival);
            assert!(r.completion > r.prefill_done);
        }
    }

    #[test]
    fn online_latency_below_offline_saturation() {
        let (c, p) = small_placement();
        // Gentle online load: latency should be near service time; heavy
        // offline load queues much more.
        let online = Trace::online(WorkloadKind::Lpld, 0.5, 100.0, 2);
        let offline = Trace::offline(WorkloadKind::Lpld, 200, 2);
        let r_on = run_disaggregated(&c, &OPT_30B, &p, &online);
        let r_off = run_disaggregated(&c, &OPT_30B, &p, &offline);
        assert!(r_on.avg_latency() < r_off.avg_latency(), "queueing not visible");
    }

    #[test]
    fn deterministic() {
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Hphd, 30, 5);
        let a = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let b = run_disaggregated(&c, &OPT_30B, &p, &trace);
        assert_eq!(a.tokens_per_s(), b.tokens_per_s());
        assert_eq!(a.records.len(), b.records.len());
    }

    #[test]
    fn estimated_throughput_aligns_with_simulated() {
        // §5.3: "the estimated serving throughput closely aligns with the
        // actual throughput" — within 2x either way here (estimator is a
        // steady-state bound; the simulator has queueing/startup effects).
        let (c, p) = small_placement();
        let trace = Trace::offline(WorkloadKind::Lpld, 300, 3);
        let rep = run_disaggregated(&c, &OPT_30B, &p, &trace);
        let est = p.tokens_per_s;
        let sim = rep.tokens_per_s();
        assert!(sim > est * 0.3 && sim < est * 3.0, "est {est} vs sim {sim}");
    }

    #[test]
    fn resched_no_requests_lost_across_switch() {
        // A mid-trace switch to a different placement must not lose or
        // duplicate any request, even with a blackout window.
        let (c, p) = small_placement();
        let mut opts = ScheduleOptions::new(WorkloadKind::Lpld);
        opts.max_rounds = 4;
        opts.force_k = Some(2);
        opts.seed = 99;
        let p2 = scheduler::schedule(&c, &OPT_30B, &opts).unwrap().placement;
        let trace = Trace::online(WorkloadKind::Lpld, 1.0, 120.0, 4);
        let n = trace.requests.len();
        let switches = vec![PlacementSwitch { at: 60.0, delay: 5.0, placement: p2, workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), n, "requests lost across the switch");
        let mut ids: Vec<usize> = rep.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicated requests");
        for r in &rep.records {
            assert!(r.prefill_done >= r.arrival && r.completion > r.prefill_done);
        }
    }

    #[test]
    fn resched_identity_switch_is_benign() {
        // Switching to the same placement only inserts the blackout; all
        // requests still complete and throughput stays positive.
        let (c, p) = small_placement();
        let trace = Trace::online(WorkloadKind::Lpld, 0.8, 100.0, 6);
        let n = trace.requests.len();
        let switches = vec![PlacementSwitch { at: 50.0, delay: 2.0, placement: p.clone(), workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), n);
        assert!(rep.tokens_per_s() > 0.0);
    }

    #[test]
    fn resched_infeasible_switch_falls_back_to_old_placement() {
        use crate::scheduler::placement::GroupPlan;
        let (c, p) = small_placement();
        // A placement whose every group is dead: the switch must be skipped
        // and the old replicas must resume after the blackout.
        let dead = Placement {
            groups: vec![GroupPlan {
                devices: (0..c.n()).collect(),
                is_prefill: true,
                config: None,
                capacity: 0.0,
            }],
            routes: vec![],
            flow_value: 0.0,
            tokens_per_s: 0.0,
            group_utilization: vec![0.0],
            objective_score: 0.0,
        };
        let trace = Trace::online(WorkloadKind::Lpld, 0.8, 80.0, 7);
        let n = trace.requests.len();
        let switches = vec![PlacementSwitch { at: 40.0, delay: 3.0, placement: dead, workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), n, "fallback lost requests");
    }

    #[test]
    fn resched_blackout_delays_held_requests() {
        let (c, p) = small_placement();
        // All arrivals land inside the blackout: their TTFT must include the
        // wait until activation.
        let mut trace = Trace::offline(WorkloadKind::Lpld, 5, 8);
        for (i, r) in trace.requests.iter_mut().enumerate() {
            r.arrival = 10.0 + i as f64 * 0.01;
        }
        let switches =
            vec![PlacementSwitch { at: 9.0, delay: 20.0, placement: p.clone(), workload: None }];
        let rep = run_disaggregated_with_resched(&c, &OPT_30B, &p, &switches, &trace);
        assert_eq!(rep.records.len(), 5);
        for r in &rep.records {
            assert!(
                r.prefill_done >= 29.0,
                "request served during blackout: prefill_done {}",
                r.prefill_done
            );
        }
    }
}
