//! Heterogeneous cluster substrate: GPU catalog, topology (bandwidth /
//! latency matrices), and the paper's six evaluation settings.
//!
//! This replaces the paper's RunPod rentals + NCCL bandwidth measurement
//! (DESIGN.md §1): every downstream component (cost model, scheduler,
//! simulator) consumes clusters only through this interface.

pub mod gpu;
pub mod settings;
pub mod topology;

pub use gpu::{GpuType, ALL_GPU_TYPES};
pub use topology::{Cluster, Device, DeviceId, LinkTier, NodeSpec};
