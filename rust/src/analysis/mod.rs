//! `hexcheck` — in-repo static analysis for determinism, panic hygiene,
//! and lock ordering (DESIGN.md §13).
//!
//! Zero external dependencies: a lexical cleaner ([`lexer`]) feeds simple
//! per-line rule passes ([`rules`], [`lockorder`]), findings are filtered
//! through inline suppression comments (`allow(<rule>) -- <reason>` after
//! the `hexcheck:` marker — see [`lexer`] for the exact syntax),
//! and the remainder is gated against the checked-in ratchet
//! [`baseline`] (`rust/hexcheck-baseline.json`). Exposed as the
//! `hexgen2 check` subcommand; CI runs it with `--json` and fails on any
//! new finding.
//!
//! Rule ids are stable API (tests, baseline, and allows reference them):
//!
//! | id | name                 | what it catches                          |
//! |----|----------------------|------------------------------------------|
//! | D1 | map-iter-determinism | HashMap/HashSet iteration order escaping |
//! | D2 | banned-nondeterminism| wall clocks / ad-hoc RNG outside util    |
//! | P1 | panic-hygiene        | unwrap/panic!/indexing in library code   |
//! | F1 | float-fold           | f64 reductions in hash iteration order   |
//! | L1 | lock-order           | undeclared/mis-ranked/cyclic lock nests  |
//! | A0 | bad-allow            | malformed or reasonless suppressions     |

pub mod baseline;
pub mod lexer;
pub mod lockorder;
pub mod rules;

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::util::json::{self, Json};

/// One source file handed to the checker (path is repo-src-relative, e.g.
/// `scheduler/evalcache.rs`).
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub path: String,
    pub src: String,
}

/// One finding, pre- or post-suppression.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub module: String,
    pub msg: String,
    pub snippet: String,
}

/// Module bucket of a src-relative path: the first directory component,
/// or the file stem for crate-root files (`main.rs` → `main`).
pub fn module_of(path: &str) -> String {
    match path.split('/').next() {
        Some(first) if first != path => first.to_string(),
        _ => path.strip_suffix(".rs").unwrap_or(path).to_string(),
    }
}

/// A suppression that fired, kept for reporting.
#[derive(Clone, Debug)]
pub struct Suppressed {
    pub finding: Finding,
    pub reason: String,
}

/// Full result of a check run (pre-gate).
#[derive(Debug, Default)]
pub struct Report {
    /// Findings that survived suppression — the set the gate sees.
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppressed>,
    /// Allows that matched nothing: stale annotations worth deleting.
    /// (file, line, rule)
    pub unused_allows: Vec<(String, usize, String)>,
    /// Static lock graph, for reporting and the self-check test.
    pub lock_edges: Vec<lockorder::LockEdge>,
}

/// Run every rule over `files`, apply suppressions, detect lock cycles.
pub fn check_files(files: &[SourceFile]) -> Report {
    let mut raw: Vec<Finding> = Vec::new();
    let mut edges: Vec<lockorder::LockEdge> = Vec::new();
    let mut all_allows: Vec<(String, lexer::Allow)> = Vec::new();

    for file in files {
        let cleaned = lexer::clean(&file.src);
        let module = module_of(&file.path);
        rules::check_file(file, &cleaned, &module, &mut raw);
        lockorder::check_file(file, &cleaned, &module, &mut edges, &mut raw);
        for (line, why) in &cleaned.bad_allows {
            raw.push(Finding {
                rule: "A0".to_string(),
                file: file.path.clone(),
                line: *line,
                module: module.clone(),
                msg: format!("malformed suppression: {why}"),
                snippet: file
                    .src
                    .lines()
                    .nth(line - 1)
                    .unwrap_or("")
                    .trim()
                    .to_string(),
            });
        }
        for a in &cleaned.allows {
            all_allows.push((file.path.clone(), a.clone()));
        }
    }
    lockorder::detect_cycles(&edges, &mut raw);

    // Apply suppressions: an allow covers every finding of its rule on
    // its target line of its file.
    let mut report = Report { lock_edges: edges, ..Report::default() };
    let mut used = vec![false; all_allows.len()];
    for f in raw {
        let hit = all_allows.iter().enumerate().find(|(_, (path, a))| {
            *path == f.file && a.line == f.line && a.rule == f.rule
        });
        match hit {
            Some((i, (_, a))) => {
                used[i] = true;
                report.suppressed.push(Suppressed { finding: f, reason: a.reason.clone() });
            }
            None => report.findings.push(f),
        }
    }
    for (i, (path, a)) in all_allows.iter().enumerate() {
        if !used[i] {
            report.unused_allows.push((path.clone(), a.comment_line, a.rule.clone()));
        }
    }
    // Deterministic output order regardless of walk order.
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    report
        .suppressed
        .sort_by(|a, b| (&a.finding.file, a.finding.line).cmp(&(&b.finding.file, b.finding.line)));
    report.unused_allows.sort();
    report
}

/// Load every `.rs` file under `root` (sorted, recursive), with paths
/// relative to `root`.
pub fn load_tree(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    fn walk(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
        let mut entries: Vec<_> =
            fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
        entries.sort_by_key(|e| e.path());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                walk(&p, root, out)?;
            } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push(SourceFile { path: rel, src: fs::read_to_string(&p)? });
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

fn finding_json(f: &Finding) -> Json {
    json::obj(vec![
        ("rule", json::s(&f.rule)),
        ("file", json::s(&f.file)),
        ("line", json::num(f.line as f64)),
        ("module", json::s(&f.module)),
        ("msg", json::s(&f.msg)),
        ("snippet", json::s(&f.snippet)),
    ])
}

/// Machine-readable report (`hexgen2 check --json`), schema
/// `hexgen2-hexcheck/v1`.
pub fn report_json(report: &Report, gate: &baseline::GateResult) -> Json {
    let by_rule = |fs: &[Finding]| {
        let mut m: BTreeMap<&str, usize> = BTreeMap::new();
        for f in fs {
            *m.entry(f.rule.as_str()).or_insert(0) += 1;
        }
        json::obj(m.into_iter().map(|(k, v)| (k, json::num(v as f64))).collect())
    };
    json::obj(vec![
        ("schema", json::s("hexgen2-hexcheck/v1")),
        ("n_findings", json::num(report.findings.len() as f64)),
        ("n_suppressed", json::num(report.suppressed.len() as f64)),
        ("n_unused_allows", json::num(report.unused_allows.len() as f64)),
        ("ok", Json::Bool(gate.ok())),
        ("counts_by_rule", by_rule(&report.findings)),
        ("findings", json::arr(report.findings.iter().map(finding_json).collect())),
        (
            "suppressed",
            json::arr(
                report
                    .suppressed
                    .iter()
                    .map(|s| {
                        json::obj(vec![
                            ("rule", json::s(&s.finding.rule)),
                            ("file", json::s(&s.finding.file)),
                            ("line", json::num(s.finding.line as f64)),
                            ("reason", json::s(&s.reason)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "unused_allows",
            json::arr(
                report
                    .unused_allows
                    .iter()
                    .map(|(file, line, rule)| {
                        json::obj(vec![
                            ("file", json::s(file)),
                            ("line", json::num(*line as f64)),
                            ("rule", json::s(rule)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gate_failures",
            json::arr(
                gate.failures
                    .iter()
                    .map(|g| {
                        json::obj(vec![
                            ("rule", json::s(&g.rule)),
                            ("module", json::s(&g.module)),
                            ("count", json::num(g.count as f64)),
                            ("allowed", json::num(g.allowed as f64)),
                            ("deny", Json::Bool(g.deny)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "shrinkable",
            json::arr(
                gate.shrinkable
                    .iter()
                    .map(|g| {
                        json::obj(vec![
                            ("rule", json::s(&g.rule)),
                            ("module", json::s(&g.module)),
                            ("count", json::num(g.count as f64)),
                            ("allowed", json::num(g.allowed as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "lock_edges",
            json::arr(
                report
                    .lock_edges
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("held", json::s(&e.held)),
                            ("acquired", json::s(&e.acquired)),
                            ("file", json::s(&e.file)),
                            ("line", json::num(e.line as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(path: &str, src: &str) -> SourceFile {
        SourceFile { path: path.to_string(), src: src.to_string() }
    }

    #[test]
    fn module_buckets() {
        assert_eq!(module_of("scheduler/evalcache.rs"), "scheduler");
        assert_eq!(module_of("kvtransfer/engine.rs"), "kvtransfer");
        assert_eq!(module_of("main.rs"), "main");
        assert_eq!(module_of("lib.rs"), "lib");
    }

    #[test]
    fn suppression_round_trip() {
        let src = "\
fn f(m: HashMap<u32, f64>) {
    // hexcheck: allow(D1) -- replayed into a BTreeMap by the caller
    for (k, v) in &m { emit(k, v); }
}
";
        let r = check_files(&[file("scheduler/x.rs", src)]);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.suppressed.len(), 1);
        assert_eq!(r.suppressed[0].finding.rule, "D1");
        assert!(r.suppressed[0].reason.contains("BTreeMap"));
        assert!(r.unused_allows.is_empty());
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress_and_is_unused() {
        let src = "\
fn f(m: HashMap<u32, f64>) {
    // hexcheck: allow(P1) -- wrong rule id for this site
    for (k, v) in &m { emit(k, v); }
}
";
        let r = check_files(&[file("scheduler/x.rs", src)]);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "D1");
        assert_eq!(r.unused_allows.len(), 1);
        assert_eq!(r.unused_allows[0].2, "P1");
    }

    #[test]
    fn reasonless_allow_is_a0() {
        let src = "// hexcheck: allow(D1)\nfn f() {}\n";
        let r = check_files(&[file("model/x.rs", src)]);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "A0");
    }

    #[test]
    fn report_json_schema() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n";
        let r = check_files(&[file("model/x.rs", src)]);
        let base = baseline::Baseline::default();
        let g = baseline::gate(&r.findings, &base);
        assert!(!g.ok(), "P1 in model with empty baseline must gate");
        let doc = report_json(&r, &g);
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some("hexgen2-hexcheck/v1")
        );
        assert_eq!(doc.get("n_findings").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(false));
        // Round-trips through the in-tree parser.
        let back = Json::parse(&doc.to_string_pretty()).expect("report json parses");
        assert_eq!(back.get("n_findings").and_then(Json::as_usize), Some(1));
    }
}
