//! Minimal `anyhow`-compatible error shim.
//!
//! The offline crate registry this repo builds against carries no
//! third-party crates, so this in-tree shim provides the (small) subset of
//! the real `anyhow` API the codebase uses: [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` macros. Swapping in the real
//! crate is a one-line Cargo.toml change; nothing here depends on shim
//! internals.
//!
//! Semantics mirror `anyhow`:
//! - `Error` is an opaque, context-carrying error (it does NOT implement
//!   `std::error::Error`, which is what allows the blanket `From` below).
//! - `Display` shows the outermost message; `{:#}` (alternate) shows the
//!   whole chain joined by `": "`.
//! - `.context(..)` / `.with_context(..)` prepend a new outermost message.

use std::fmt;

/// Opaque error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Add an outer context message (used by the [`Context`] trait).
    pub fn wrap(mut self, m: impl fmt::Display) -> Error {
        self.chain.insert(0, m.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into `Error` (mirrors anyhow's blanket From).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context lines.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` (subset of anyhow's trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.wrap(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.wrap(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest.json".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest.json");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("reading manifest.json: "), "{alt}");
        assert!(alt.contains("no such file"), "{alt}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope {}", "x");
        }
        assert_eq!(f().unwrap_err().to_string(), "nope x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely-not-a-file-hexgen2")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_anyhow_result_nests() {
        let e: Error = Err::<(), _>(anyhow!("inner")).context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
