//! Prefix-reuse study (DESIGN.md §15): the cluster-wide prefix KV pool
//! across `--prefix-share` levels, at equal load. Arrivals and lengths are
//! bit-identical across the share sweep (generation consumes a fixed
//! number of RNG draws per request), and every row runs the *same*
//! placement — so hit rate is the only moving part, and the TTFT /
//! throughput deltas are attributable to the pool alone. The summary then
//! re-plans with `--prefix-hit-aware` and contrasts the decode-device
//! share: discounting expected prefill demand by the expected hit rate
//! shifts the optimal partition decode-heavy.

use crate::cluster::settings;
use crate::deploy::{DeploymentSpec, HexGen2Planner, PlanKind, SimBackend};
use crate::model::LlmSpec;
use crate::util::bench::Table;
use crate::workload::{Trace, TraceSource, WorkloadKind};

use super::ExpOpts;

/// One share level of the sweep.
#[derive(Clone, Copy, Debug)]
pub struct PrefixRow {
    pub share: f64,
    /// Measured pool hit rate (GPU + host hits over resolved lookups).
    pub hit_rate: f64,
    pub mean_ttft: f64,
    pub tokens_per_s: f64,
    pub reused_tokens: f64,
    pub spilled_tokens: f64,
}

/// The full study: the share sweep plus the hit-aware planner contrast.
pub struct PrefixStudy {
    pub table: Table,
    pub rows: Vec<PrefixRow>,
    /// Fraction of devices the hit-blind plan gives to decode groups.
    pub blind_decode_share: f64,
    /// Same under `--prefix-hit-aware` at the sweep's top share.
    pub aware_decode_share: f64,
    /// The expected hit rate the hit-aware planner discounted by.
    pub planner_hit_rate: f64,
}

/// Fraction of devices assigned to decode groups (0.0 for non-disaggregated
/// plans, which have no prefill/decode split to shift).
pub fn decode_device_share(kind: &PlanKind) -> f64 {
    match kind {
        PlanKind::Disaggregated(p) => {
            let total: usize = p.groups.iter().map(|g| g.devices.len()).sum();
            let dec: usize =
                p.groups.iter().filter(|g| !g.is_prefill).map(|g| g.devices.len()).sum();
            if total == 0 {
                0.0
            } else {
                dec as f64 / total as f64
            }
        }
        _ => 0.0,
    }
}

fn base_spec(model: &LlmSpec, setting: &str, opts: &ExpOpts) -> Option<DeploymentSpec> {
    let cluster = settings::by_name(setting)?;
    let mut spec = DeploymentSpec::new(cluster, *model)
        .workload(WorkloadKind::Agent)
        .seed(opts.seed)
        .quick(opts.quick);
    if setting == "case_study" {
        // Pin K as the case studies do so the contrast is stable across
        // search-budget changes.
        spec = spec.force_k(4);
    }
    Some(spec)
}

/// The share sweep + planner contrast on one setting. Returns `None` for
/// an unknown setting name.
pub fn prefix_reuse(model: &LlmSpec, setting: &str, opts: &ExpOpts) -> Option<PrefixStudy> {
    let shares: &[f64] =
        if opts.quick { &[0.0, 0.5, 0.95] } else { &[0.0, 0.25, 0.5, 0.75, 0.95] };
    let n = opts.offline_n().max(120);
    let mut table = Table::new(&[
        "prefix share",
        "hit rate",
        "mean TTFT (s)",
        "tokens/s",
        "reused tokens",
        "spilled tokens",
    ]);
    let mut rows = Vec::new();

    // One hit-blind plan serves the whole sweep: share is a trace/engine
    // knob, so every row runs the identical placement.
    let spec = base_spec(model, setting, opts)?;
    let dep = match spec.plan(&HexGen2Planner) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("prefix_reuse: planning failed on {setting}: {e}");
            return None;
        }
    };
    let blind_decode_share = decode_device_share(&dep.plan.kind);

    for &share in shares {
        let trace = Trace::from_source(
            TraceSource::offline(WorkloadKind::Agent, n, opts.seed.wrapping_add(53))
                .with_prefix_share(share),
        );
        let rep = match dep.run(&SimBackend, &trace) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("prefix_reuse: share {share} failed: {e}");
                continue;
            }
        };
        let row = PrefixRow {
            share,
            hit_rate: rep.stats.prefix_hit_rate(),
            mean_ttft: rep.avg_ttft(),
            tokens_per_s: rep.tokens_per_s(),
            reused_tokens: rep.stats.prefix_reused_tokens,
            spilled_tokens: rep.stats.prefix_spilled_tokens,
        };
        table.row(&[
            format!("{share:.2}"),
            format!("{:.2}", row.hit_rate),
            format!("{:.3}", row.mean_ttft),
            format!("{:.0}", row.tokens_per_s),
            format!("{:.0}", row.reused_tokens),
            format!("{:.0}", row.spilled_tokens),
        ]);
        rows.push(row);
    }

    // Planner contrast: same cluster/workload, but the planner discounts
    // expected prefill demand by the class's expected hit rate at the top
    // share level.
    let top_share = shares.last().copied().unwrap_or(0.95);
    let aware_spec =
        base_spec(model, setting, opts)?.prefix_share(Some(top_share)).prefix_hit_aware(true);
    let planner_hit_rate = aware_spec.expected_prefix_hit_rate();
    let aware_decode_share = match aware_spec.plan(&HexGen2Planner) {
        Ok(d) => decode_device_share(&d.plan.kind),
        Err(e) => {
            eprintln!("prefix_reuse: hit-aware planning failed on {setting}: {e}");
            blind_decode_share
        }
    };

    Some(PrefixStudy { table, rows, blind_decode_share, aware_decode_share, planner_hit_rate })
}

/// Headline lines under the table: pool gains at equal load, and the
/// hit-aware partition shift.
pub fn print_summary(s: &PrefixStudy) {
    if let (Some(base), Some(top)) = (s.rows.first(), s.rows.last()) {
        if base.share == 0.0 && top.share > 0.0 {
            println!(
                "prefix pool @ share {:.2}: mean TTFT {:.3}s -> {:.3}s ({:+.0}%), \
                 tokens/s {:.0} -> {:.0} ({:+.0}%), measured hit rate {:.2}",
                top.share,
                base.mean_ttft,
                top.mean_ttft,
                (top.mean_ttft / base.mean_ttft.max(1e-12) - 1.0) * 100.0,
                base.tokens_per_s,
                top.tokens_per_s,
                (top.tokens_per_s / base.tokens_per_s.max(1e-12) - 1.0) * 100.0,
                top.hit_rate,
            );
        }
    }
    println!(
        "hit-aware planner (expected hit rate {:.2}): decode device share {:.2} -> {:.2} \
         (hit-blind -> hit-aware)",
        s.planner_hit_rate, s.blind_decode_share, s.aware_decode_share,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OPT_30B;

    #[test]
    fn sweep_covers_shares_and_reuse_pays() {
        let opts = ExpOpts { quick: true, seed: 0 };
        let s = prefix_reuse(&OPT_30B, "case_study", &opts).expect("setting exists");
        assert_eq!(s.rows.len(), 3, "quick sweep is 3 share levels");
        let base = &s.rows[0];
        let top = s.rows.last().unwrap();
        assert_eq!(base.share, 0.0);
        assert_eq!(base.hit_rate, 0.0, "share 0 must never touch the pool");
        assert!(top.hit_rate >= 0.5, "top share should mostly hit, got {}", top.hit_rate);
        // The headline claim: reuse strictly improves BOTH mean TTFT and
        // throughput at equal load.
        assert!(
            top.mean_ttft < base.mean_ttft,
            "TTFT should drop: {} vs {}",
            top.mean_ttft,
            base.mean_ttft
        );
        assert!(
            top.tokens_per_s > base.tokens_per_s,
            "throughput should rise: {} vs {}",
            top.tokens_per_s,
            base.tokens_per_s
        );
        assert!(prefix_reuse(&OPT_30B, "nonexistent", &opts).is_none());
    }
}
