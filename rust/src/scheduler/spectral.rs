//! Spectral graph partitioning (paper §3.2 step i, after Alpert & Yao 1995):
//! a dense symmetric Jacobi eigensolver computes the Laplacian's
//! eigenvectors; recursive Fiedler-vector bisection with a memory-balance
//! constraint produces the K model-serving groups.
//!
//! Edge weights are pairwise bandwidths (so minimizing the cut keeps
//! high-bandwidth links *inside* groups for TP traffic); node weights are
//! device memories (groups must each hold a model replica, so memory — not
//! compute — is balanced, §3.2: "we balance memory rather than compute
//! capacity to avoid potential OOM issues").

use crate::cluster::{Cluster, DeviceId};

/// Cyclic Jacobi eigensolver for a dense symmetric matrix.
/// Returns (eigenvalues, eigenvectors) with eigenvectors\[k\] the unit
/// eigenvector for eigenvalues\[k\], sorted ascending.
pub fn jacobi_eigen(a: &[Vec<f64>]) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = a.len();
    let mut m: Vec<Vec<f64>> = a.to_vec();
    let mut v = vec![vec![0.0; n]; n];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i][j] * m[i][j];
            }
        }
        if off.sqrt() < 1e-10 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                if m[p][q].abs() < 1e-14 {
                    continue;
                }
                // Jacobi rotation annihilating m[p][q].
                let theta = (m[q][q] - m[p][p]) / (2.0 * m[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..n {
                    let (mip, miq) = (m[i][p], m[i][q]);
                    m[i][p] = c * mip - s * miq;
                    m[i][q] = s * mip + c * miq;
                }
                for j in 0..n {
                    let (mpj, mqj) = (m[p][j], m[q][j]);
                    m[p][j] = c * mpj - s * mqj;
                    m[q][j] = s * mpj + c * mqj;
                }
                for i in 0..n {
                    let (vip, viq) = (v[i][p], v[i][q]);
                    v[i][p] = c * vip - s * viq;
                    v[i][q] = s * vip + c * viq;
                }
            }
        }
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| m[i][i].partial_cmp(&m[j][j]).unwrap());
    let evals: Vec<f64> = idx.iter().map(|&i| m[i][i]).collect();
    let evecs: Vec<Vec<f64>> = idx.iter().map(|&k| (0..n).map(|i| v[i][k]).collect()).collect();
    (evals, evecs)
}

/// Graph Laplacian L = D - W over the given device subset, with weights
/// normalized by the max so Jacobi works in O(1)-scaled space.
fn laplacian(cluster: &Cluster, devs: &[DeviceId]) -> Vec<Vec<f64>> {
    let n = devs.len();
    let mut w = vec![vec![0.0; n]; n];
    let mut wmax: f64 = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let bw = cluster.bandwidth[devs[i]][devs[j]];
                w[i][j] = bw;
                wmax = wmax.max(bw);
            }
        }
    }
    if wmax <= 0.0 {
        wmax = 1.0;
    }
    let mut l = vec![vec![0.0; n]; n];
    for i in 0..n {
        let mut deg = 0.0;
        for j in 0..n {
            if i != j {
                let x = w[i][j] / wmax;
                l[i][j] = -x;
                deg += x;
            }
        }
        l[i][i] = deg;
    }
    l
}

/// Fiedler vector (eigenvector of the second-smallest Laplacian eigenvalue)
/// of the bandwidth graph over `devs`.
pub fn fiedler_vector(cluster: &Cluster, devs: &[DeviceId]) -> Vec<f64> {
    let l = laplacian(cluster, devs);
    let (_vals, vecs) = jacobi_eigen(&l);
    vecs[1].clone()
}

/// Bisect `devs` into two parts whose memory ratio approximates
/// `frac` : (1-frac), ordering by the Fiedler value so the cut follows the
/// spectral embedding.
pub fn bisect(cluster: &Cluster, devs: &[DeviceId], frac: f64) -> (Vec<DeviceId>, Vec<DeviceId>) {
    assert!(devs.len() >= 2);
    let f = fiedler_vector(cluster, devs);
    let mut order: Vec<usize> = (0..devs.len()).collect();
    order.sort_by(|&i, &j| f[i].partial_cmp(&f[j]).unwrap());
    let total_mem: f64 = devs.iter().map(|&d| cluster.devices[d].gpu.mem_bytes()).sum();
    let target = total_mem * frac;
    let mut acc = 0.0;
    let mut left = Vec::new();
    let mut right = Vec::new();
    for (rank, &i) in order.iter().enumerate() {
        let d = devs[i];
        let m = cluster.devices[d].gpu.mem_bytes();
        // Keep filling the left side until the target is met, but never
        // leave either side empty.
        let must_left = left.is_empty() && rank + 2 > order.len();
        let room_right = order.len() - rank > 1;
        if (acc + m * 0.5 <= target && room_right) || must_left || left.is_empty() {
            left.push(d);
            acc += m;
        } else {
            right.push(d);
        }
    }
    if right.is_empty() {
        right.push(left.pop().unwrap());
    }
    (left, right)
}

/// Partition `devs` into `k` memory-balanced groups by recursive spectral
/// bisection. Groups are non-empty and disjoint, covering all of `devs`.
pub fn partition_k(cluster: &Cluster, devs: &[DeviceId], k: usize) -> Vec<Vec<DeviceId>> {
    assert!(k >= 1);
    assert!(devs.len() >= k, "cannot split {} devices into {k} groups", devs.len());
    if k == 1 {
        return vec![devs.to_vec()];
    }
    let k_left = k / 2;
    let k_right = k - k_left;
    let frac = k_left as f64 / k as f64;
    let (l, r) = bisect(cluster, devs, frac);
    // Guarantee each side can host its group count.
    let (mut l, mut r) = (l, r);
    while l.len() < k_left {
        l.push(r.pop().unwrap());
    }
    while r.len() < k_right {
        r.push(l.pop().unwrap());
    }
    let mut out = partition_k(cluster, &l, k_left);
    out.extend(partition_k(cluster, &r, k_right));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn jacobi_small_known() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = vec![vec![2.0, 1.0], vec![1.0, 2.0]];
        let (vals, vecs) = jacobi_eigen(&a);
        assert!((vals[0] - 1.0).abs() < 1e-8);
        assert!((vals[1] - 3.0).abs() < 1e-8);
        // eigenvector for 1 is (1,-1)/sqrt(2) up to sign
        let v = &vecs[0];
        assert!((v[0] + v[1]).abs() < 1e-8, "{v:?}");
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        let mut rng = crate::util::rng::Rng::new(77);
        for _ in 0..20 {
            let n = rng.range(2, 8);
            let mut a = vec![vec![0.0; n]; n];
            for i in 0..n {
                for j in i..n {
                    let x = rng.range_f64(-2.0, 2.0);
                    a[i][j] = x;
                    a[j][i] = x;
                }
            }
            let (vals, vecs) = jacobi_eigen(&a);
            // Check A v = lambda v for each pair.
            for (k, v) in vecs.iter().enumerate() {
                for i in 0..n {
                    let av: f64 = (0..n).map(|j| a[i][j] * v[j]).sum();
                    assert!((av - vals[k] * v[i]).abs() < 1e-6, "eigenpair {k} broken");
                }
            }
        }
    }

    #[test]
    fn fiedler_separates_clusters() {
        // het1: the A6000 pod is in a different DC from the H100/A100 pod;
        // the Fiedler vector must separate DC0 from DC1 devices.
        let c = settings::het1();
        let devs: Vec<usize> = (0..c.n()).collect();
        let f = fiedler_vector(&c, &devs);
        let dc0: Vec<f64> = devs.iter().filter(|&&d| c.devices[d].dc == 0).map(|&d| f[d]).collect();
        let dc1: Vec<f64> = devs.iter().filter(|&&d| c.devices[d].dc == 1).map(|&d| f[d]).collect();
        let max0 = dc0.iter().cloned().fold(f64::MIN, f64::max);
        let min0 = dc0.iter().cloned().fold(f64::MAX, f64::min);
        let max1 = dc1.iter().cloned().fold(f64::MIN, f64::max);
        let min1 = dc1.iter().cloned().fold(f64::MAX, f64::min);
        // One DC entirely above the other in Fiedler coordinates.
        assert!(max0 < min1 || max1 < min0, "fiedler did not separate DCs");
    }

    #[test]
    fn partition_covers_and_balances() {
        let c = settings::het1();
        let devs: Vec<usize> = (0..c.n()).collect();
        for k in 2..=6 {
            let parts = partition_k(&c, &devs, k);
            assert_eq!(parts.len(), k);
            let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, devs, "k={k} not a partition");
            // Memory balance within 3x of ideal (KL refines further).
            let mems: Vec<f64> = parts
                .iter()
                .map(|g| g.iter().map(|&d| c.devices[d].gpu.mem_bytes()).sum::<f64>())
                .collect();
            let ideal = mems.iter().sum::<f64>() / k as f64;
            for m in &mems {
                assert!(*m > ideal / 4.0, "group too small: {m} vs ideal {ideal} (k={k})");
            }
        }
    }

    #[test]
    fn partition_random_clusters_property() {
        check(0x5bec, 25, |rng| {
            let n_nodes = rng.range(2, 6);
            let c = settings::synthetic(n_nodes * 8 / 8 * 8, rng.next_u64());
            let devs: Vec<usize> = (0..c.n()).collect();
            let k = rng.range(2, (c.n() / 2).min(8));
            let parts = partition_k(&c, &devs, k);
            prop_assert!(parts.len() == k, "wrong group count");
            let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
            all.sort_unstable();
            prop_assert!(all == devs, "not a partition");
            prop_assert!(parts.iter().all(|p| !p.is_empty()), "empty group");
            Ok(())
        });
    }
}
