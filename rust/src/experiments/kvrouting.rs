//! KV-routing study: the transfer engine's route models (and layer-wise
//! pipelined chunking) contrasted under shared-NIC contention with
//! per-request admission — the regime where HexGen-2's "communication is
//! what makes or breaks disaggregation" claim actually bites. One plan is
//! produced per setting (the KV knobs are engine-time, not planner inputs,
//! so every row runs the identical placement); the columns surface the
//! transfer ledger: mean per-transfer queue wait, worst NIC busy fraction,
//! and end-to-end service quality.

use crate::cluster::settings;
use crate::deploy::{DeploymentSpec, HexGen2Planner, SimBackend};
use crate::kvtransfer::{LinkModel, RouteModel};
use crate::model::LlmSpec;
use crate::simulator::Sizing;
use crate::util::bench::Table;
use crate::workload::{Trace, WorkloadKind};

use super::ExpOpts;

/// The route-model × chunking grid on one setting. Returns `None` for an
/// unknown setting name.
pub fn kv_routing_table(model: &LlmSpec, setting: &str, opts: &ExpOpts) -> Option<Table> {
    let cluster = settings::by_name(setting)?;
    // An offline flood keeps every link busy, so routing choices are
    // visible as queue waits rather than absorbed by idle bandwidth.
    let n = opts.offline_n().max(120);
    let trace = Trace::offline(WorkloadKind::Lphd, n, opts.seed.wrapping_add(41));
    let mut t = Table::new(&[
        "route",
        "kv transfer",
        "tokens/s",
        "mean kv wait (ms)",
        "max NIC util",
        "p95 lat (s)",
        "unserved",
    ]);
    let mut spec = DeploymentSpec::new(cluster, *model)
        .workload(WorkloadKind::Lphd)
        .seed(opts.seed)
        .quick(opts.quick)
        .admission(Sizing::PerRequest)
        .link(LinkModel::SharedNic);
    if setting == "case_study" {
        // The paper's Appendix-E cluster: pin K as the case studies do so
        // the table is stable across search-budget changes.
        spec = spec.force_k(4);
    }
    // Plan once: route model and chunking are engine knobs, so all rows run
    // the same placement and differences are attributable to the transfer
    // engine alone.
    let mut dep = match spec.plan(&HexGen2Planner) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("kv_routing: planning failed on {setting}: {e}");
            return Some(t);
        }
    };
    for route in RouteModel::ALL {
        for (label, chunk) in [("whole-cache", None), ("8-layer chunks", Some(8))] {
            dep.spec.kv_route = route;
            dep.spec.kv_chunk_layers = chunk;
            let rep = match dep.run(&SimBackend, &trace) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("kv_routing: {} ({label}) failed: {e}", route.name());
                    continue;
                }
            };
            let mean_wait_ms =
                rep.stats.kv_link_wait_s / rep.stats.kv_transfers.max(1) as f64 * 1000.0;
            t.row(&[
                route.name().to_string(),
                label.to_string(),
                format!("{:.0}", rep.tokens_per_s()),
                format!("{mean_wait_ms:.1}"),
                format!("{:.2}", rep.stats.kv_max_nic_util),
                format!("{:.2}", rep.p_latency(95.0)),
                format!("{}", rep.stats.unserved),
            ]);
        }
    }
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OPT_30B;

    #[test]
    fn table_covers_route_grid() {
        let opts = ExpOpts { quick: true, seed: 0 };
        let t = kv_routing_table(&OPT_30B, "case_study", &opts).expect("setting exists");
        let rows = t.rows_for_test();
        assert_eq!(rows.len(), 6, "3 route models x 2 transfer modes");
        for r in &rows {
            let tput: f64 = r[2].parse().unwrap();
            assert!(tput > 0.0, "zero throughput in {r:?}");
            let wait: f64 = r[3].parse().unwrap();
            assert!(wait >= 0.0);
        }
        assert!(kv_routing_table(&OPT_30B, "nonexistent", &opts).is_none());
    }
}
