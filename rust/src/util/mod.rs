//! Support utilities: deterministic RNG, stats, minimal JSON, CLI args,
//! bench harness, and a mini property-testing framework.
//!
//! These exist because the build environment's offline crate registry only
//! carries the `xla` crate's transitive closure (see DESIGN.md §2) — no
//! rand/serde/clap/criterion/proptest.

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
