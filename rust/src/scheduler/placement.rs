//! Model placement: the scheduler's output (paper §3.1's four decisions —
//! group partition, group type, per-group parallel strategy, KV routes).

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::ReplicaConfig;

/// One model-serving group with its chosen phase and parallel strategy.
#[derive(Clone, Debug)]
pub struct GroupPlan {
    pub devices: Vec<DeviceId>,
    pub is_prefill: bool,
    /// None if no feasible strategy exists for this group (it then takes no
    /// traffic; refinement will try to repair it).
    pub config: Option<ReplicaConfig>,
    /// Requests per scheduling period T this replica can serve (Appendix A).
    pub capacity: f64,
}

/// A KV-cache communication route between a prefill and a decode replica
/// with the flow assignment the max-flow algorithm produced (§3.3: "the
/// generated flow assignments ... are used to guide the KV cache
/// communication. The communication frequency is set to be proportional to
/// these flow values").
#[derive(Clone, Copy, Debug)]
pub struct KvRoute {
    /// Index into `Placement::groups` (a prefill group).
    pub prefill: usize,
    /// Index into `Placement::groups` (a decode group).
    pub decode: usize,
    /// Requests per period routed across this edge.
    pub flow: f64,
    /// Edge capacity (requests per period).
    pub capacity: f64,
}

/// Complete placement + flow solution for one partition.
#[derive(Clone, Debug)]
pub struct Placement {
    pub groups: Vec<GroupPlan>,
    pub routes: Vec<KvRoute>,
    /// Max-flow value: requests the system serves per period T.
    pub flow_value: f64,
    /// Estimated decode throughput, tokens/s (the paper's headline metric).
    pub tokens_per_s: f64,
    /// Per-group utilization (flow through the compute node / capacity).
    pub group_utilization: Vec<f64>,
    /// Score under the [`Objective`](super::Objective) the placement was
    /// ranked by (higher is better; equals `flow_value` for the paper's
    /// default throughput objective).
    pub objective_score: f64,
}

impl Placement {
    pub fn prefill_indices(&self) -> Vec<usize> {
        (0..self.groups.len()).filter(|&g| self.groups[g].is_prefill).collect()
    }

    pub fn decode_indices(&self) -> Vec<usize> {
        (0..self.groups.len()).filter(|&g| !self.groups[g].is_prefill).collect()
    }

    /// Paper-Table-2-style description: GPU composition, strategy, type.
    pub fn describe(&self, cluster: &Cluster) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "estimated throughput {:.0} tokens/s ({} prefill / {} decode groups)\n",
            self.tokens_per_s,
            self.prefill_indices().len(),
            self.decode_indices().len()
        ));
        for (gi, g) in self.groups.iter().enumerate() {
            // Count GPUs by type, e.g. "1xH100+1xA100".
            let mut counts: std::collections::BTreeMap<&str, usize> = Default::default();
            for &d in &g.devices {
                *counts.entry(cluster.devices[d].gpu.name()).or_default() += 1;
            }
            let comp: Vec<String> = counts.iter().map(|(t, c)| format!("{c}x{t}")).collect();
            let strat = g
                .config
                .as_ref()
                .map(|c| c.strategy_string())
                .unwrap_or_else(|| "infeasible".to_string());
            out.push_str(&format!(
                "  group {gi}: {:<22} {:<12} {} (util {:.0}%, cap {:.0} req/T)\n",
                comp.join("+"),
                strat,
                if g.is_prefill { "Prefill Instance" } else { "Decode Instance" },
                self.group_utilization.get(gi).copied().unwrap_or(0.0) * 100.0,
                g.capacity,
            ));
        }
        for r in &self.routes {
            if r.flow > 1e-9 {
                out.push_str(&format!(
                    "  kv route: group {} -> group {} flow {:.1} req/T (cap {:.1})\n",
                    r.prefill, r.decode, r.flow, r.capacity
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::settings;

    #[test]
    fn describe_formats_table2_style() {
        let c = settings::het1();
        let p = Placement {
            groups: vec![
                GroupPlan {
                    devices: vec![0, 2],
                    is_prefill: true,
                    config: Some(ReplicaConfig::new(vec![vec![0], vec![2]], vec![24, 24])),
                    capacity: 100.0,
                },
                GroupPlan {
                    devices: vec![1, 3],
                    is_prefill: false,
                    config: Some(ReplicaConfig::new(vec![vec![1], vec![3]], vec![24, 24])),
                    capacity: 80.0,
                },
            ],
            routes: vec![KvRoute { prefill: 0, decode: 1, flow: 50.0, capacity: 200.0 }],
            flow_value: 50.0,
            tokens_per_s: 123.0,
            group_utilization: vec![0.5, 0.62],
            objective_score: 50.0,
        };
        let s = p.describe(&c);
        assert!(s.contains("1xA100+1xH100"), "{s}");
        assert!(s.contains("TP=1,PP=2"), "{s}");
        assert!(s.contains("Prefill Instance"), "{s}");
        assert!(s.contains("Decode Instance"), "{s}");
        assert!(s.contains("kv route"), "{s}");
        assert_eq!(p.prefill_indices(), vec![0]);
        assert_eq!(p.decode_indices(), vec![1]);
    }
}
