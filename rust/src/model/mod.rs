//! LLM specifications used by the cost model and scheduler.
//!
//! The paper evaluates OPT-30B and LLaMA-2-70B (§5.1); the live serving path
//! runs the `tiny` / `gpt-100m` configs compiled by `python/compile/aot.py`.
//! Everything downstream consumes a model only through these analytic
//! quantities (parameter bytes, KV bytes/token, FLOPs), exactly as the
//! paper's Table-1 cost model does.

/// B_type in paper Table 1: bytes per element of the inference precision.
pub const BYTES_FP16: f64 = 2.0;

/// Analytic spec of a decoder-only transformer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LlmSpec {
    pub name: &'static str,
    pub n_layers: usize,
    /// Hidden dimension H in paper Table 1.
    pub hidden: usize,
    pub n_heads: usize,
    pub vocab: usize,
    /// B_type: bytes per element (2.0 = FP16 serving precision).
    pub bytes_per_elem: f64,
}

/// OPT-30B: 48 layers, H=7168 (Zhang et al., 2022).
pub const OPT_30B: LlmSpec =
    LlmSpec { name: "opt-30b", n_layers: 48, hidden: 7168, n_heads: 56, vocab: 50272, bytes_per_elem: BYTES_FP16 };

/// LLaMA-2-70B: 80 layers, H=8192 (Touvron et al., 2023). The paper's cost
/// model treats attention as MHA (Table 1 uses 2*s*H*B KV per layer), so we
/// keep the MHA-equivalent KV footprint rather than modeling GQA.
pub const LLAMA2_70B: LlmSpec =
    LlmSpec { name: "llama2-70b", n_layers: 80, hidden: 8192, n_heads: 64, vocab: 32000, bytes_per_elem: BYTES_FP16 };

/// LLaMA-2-7B: used only by the Fig. 1 batching-effect microstudy.
pub const LLAMA2_7B: LlmSpec =
    LlmSpec { name: "llama2-7b", n_layers: 32, hidden: 4096, n_heads: 32, vocab: 32000, bytes_per_elem: BYTES_FP16 };

/// The live-path models compiled by aot.py (f32 on the CPU PJRT backend).
pub const TINY: LlmSpec =
    LlmSpec { name: "tiny", n_layers: 4, hidden: 256, n_heads: 8, vocab: 512, bytes_per_elem: 4.0 };
pub const GPT_100M: LlmSpec =
    LlmSpec { name: "gpt-100m", n_layers: 12, hidden: 768, n_heads: 12, vocab: 8192, bytes_per_elem: 4.0 };

impl LlmSpec {
    /// Parameter bytes: Table 1's 12*H^2*B per layer, plus embeddings.
    pub fn param_bytes(&self) -> f64 {
        let h = self.hidden as f64;
        let per_layer = 12.0 * h * h * self.bytes_per_elem;
        per_layer * self.n_layers as f64 + (self.vocab as f64) * h * self.bytes_per_elem
    }

    /// Parameter bytes held by a stage of `layers` layers (no embeddings;
    /// matches Table 1's memory-limit term 12*H^2*B/|d| * l).
    pub fn stage_param_bytes(&self, layers: usize) -> f64 {
        let h = self.hidden as f64;
        12.0 * h * h * self.bytes_per_elem * layers as f64
    }

    /// KV-cache bytes per token across `layers` layers (K and V: 2*H*B each
    /// layer — Table 1's 2*b*s*H*B term).
    pub fn kv_bytes_per_token(&self, layers: usize) -> f64 {
        2.0 * self.hidden as f64 * self.bytes_per_elem * layers as f64
    }

    /// FLOPs for one token through one layer at batch 1: Table 1 uses
    /// 24*b*s*H^2 for prefill compute, i.e. 24*H^2 per token-layer.
    pub fn flops_per_token_layer(&self) -> f64 {
        24.0 * (self.hidden as f64) * (self.hidden as f64)
    }

    /// Approximate parameter count.
    pub fn n_params(&self) -> f64 {
        self.param_bytes() / self.bytes_per_elem
    }

    pub fn by_name(name: &str) -> Option<LlmSpec> {
        match name.to_ascii_lowercase().as_str() {
            "opt-30b" | "opt30b" | "opt_30b" => Some(OPT_30B),
            "llama2-70b" | "llama70b" | "llama2_70b" => Some(LLAMA2_70B),
            "llama2-7b" | "llama7b" => Some(LLAMA2_7B),
            "tiny" => Some(TINY),
            "gpt-100m" | "gpt100m" => Some(GPT_100M),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        // 12*H^2*L accounts for the non-embedding parameters; OPT-30B and
        // LLaMA-2-70B should land within ~15% of their nominal sizes.
        let opt = OPT_30B.n_params();
        assert!((25e9..35e9).contains(&opt), "{opt}");
        let llama = LLAMA2_70B.n_params();
        assert!((58e9..78e9).contains(&llama), "{llama}");
    }

    #[test]
    fn kv_bytes_match_table1() {
        // 2*H*B per layer per token; LLaMA-2-70B: 2*8192*2*80 = 2.62 MB/token.
        let kv = LLAMA2_70B.kv_bytes_per_token(LLAMA2_70B.n_layers);
        assert!((kv - 2.0 * 8192.0 * 2.0 * 80.0).abs() < 1e-6);
    }

    #[test]
    fn by_name_roundtrip() {
        for m in [OPT_30B, LLAMA2_70B, TINY, GPT_100M] {
            assert_eq!(LlmSpec::by_name(m.name), Some(m));
        }
        assert_eq!(LlmSpec::by_name("gpt-5"), None);
    }
}
